"""Repo-root pytest shim: make `pytest python/tests/` work from here.

The python package root is `python/` (build-time only); running pytest
from the repository root needs it on sys.path so `compile.*` and
`tests.*` resolve.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "python"))
