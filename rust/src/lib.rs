//! # immsched — IMMSched paper reproduction
//!
//! Interruptible multi-DNN scheduling via parallel multi-particle
//! optimizing subgraph isomorphism (Zhao et al., CS.AR 2026), built as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas fused PSO-step kernel —
//!   velocity/position updates, compatibility masking, reciprocal-multiply
//!   row normalization and the edge-preserving fitness `-‖Q − S G Sᵀ‖²`,
//!   gridded over particles (one particle ≙ one accelerator engine).
//! * **L2** (`python/compile/model.py`): one PSO *epoch* (K fused steps for
//!   N particles with local-best tracking) lowered AOT to HLO text.
//! * **L3** (this crate): everything else — the DNN workload models and
//!   tiling, the accelerator platform/energy model, the serial and parallel
//!   subgraph matchers, the six scheduling frameworks, the interrupt-driven
//!   coordinator with its global controller, and the benchmark harnesses
//!   that regenerate every table and figure of the paper.
//!
//! Python never runs at request time: the interrupt hot path executes
//! epochs through the [`runtime`] `EpochBackend` trait. The default
//! build uses the pure-native backend (no XLA anywhere, threaded across
//! particles under the `parallel` feature); with the off-by-default
//! `pjrt` cargo feature, `make artifacts` lowers the epoch once per
//! size class and the HLO text runs through the PJRT CPU client
//! (`xla` crate) instead.
//!
//! See `DESIGN.md` for the complete system inventory and experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

// The only unsafe in the repo is the counting global allocator in
// tests/alloc_free.rs (its own crate, with a local allow); the library
// itself is forbid-level unsafe-free.
#![forbid(unsafe_code)]
// CI parity: the clippy job runs with `-D warnings`; promoting the
// deny to the crate root makes a plain local `cargo build` match CI
// instead of drifting until the next push.
#![deny(warnings)]
#![deny(clippy::all)]

pub mod accel;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod graph;
pub mod lint;
pub mod matcher;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod testing;
pub mod util;
pub mod workload;

/// Crate-wide result alias (errors carry context via `anyhow`).
pub type Result<T> = anyhow::Result<T>;
