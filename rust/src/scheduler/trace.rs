//! Trace generation: periodic background jobs + stochastic urgent
//! arrivals (the open-ended scenario of Fig. 1c).
//!
//! Two urgent arrival processes are supported through one
//! [`ArrivalProcess`] sampler, shared with the cluster's open-loop
//! driver so the simulator and the live serving path replay the *same*
//! arrival model:
//!
//! * **Poisson(λ)** — exactly how the paper's LBT metric defines
//!   arrivals (§4.1.4);
//! * **Bursty (MMPP-style)** — a two-state Markov-modulated Poisson
//!   process: the rate alternates between λ (base state) and λ×burst
//!   (burst state) with exponentially distributed dwell times.  This is
//!   the "unpredictable task arrivals" stress pattern consolidated
//!   NPU serving must survive (PREMA §6).

use crate::accel::Platform;
use crate::util::Rng;
use crate::workload::{TilingConfig, WorkloadClass};

use super::task::{Priority, Task};

/// Which urgent arrival process a trace draws from.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson at the trace's `arrival_rate`.
    #[default]
    Poisson,
    /// Two-state MMPP: `arrival_rate` in the base state,
    /// `arrival_rate × burst_factor` in the burst state.
    Bursty {
        /// Rate multiplier inside a burst (> 1).
        burst_factor: f64,
        /// Mean burst-state dwell time (s).
        mean_burst: f64,
        /// Mean base-state dwell time (s).
        mean_gap: f64,
    },
    /// Non-stationary "diurnal" shape: a day-cycle cosine rate envelope
    /// (trough at phase 0, peak at phase ½) with MMPP-style flash-crowd
    /// bursts superimposed.  Realized by Lewis–Shedler thinning of the
    /// two-state MMPP: candidates are drawn at the full state rate and
    /// accepted with probability `envelope(t) ∈ [1-depth, 1]`.
    Diurnal {
        /// Envelope period (s) — one modeled "day".
        period: f64,
        /// Trough depth in [0, 1): the envelope dips to `1 - depth` of
        /// the base rate at phase 0 and recovers to 1 at phase ½.
        depth: f64,
        /// Rate multiplier inside a flash-crowd burst (> 1).
        burst_factor: f64,
        /// Mean burst-state dwell time (s).
        mean_burst: f64,
        /// Mean base-state dwell time (s).
        mean_gap: f64,
    },
}

impl ArrivalProcess {
    /// A reasonable bursty default: 8× rate bursts of ~20 ms mean every
    /// ~80 ms mean.
    pub fn bursty_default() -> Self {
        ArrivalProcess::Bursty { burst_factor: 8.0, mean_burst: 0.02, mean_gap: 0.08 }
    }

    /// A reasonable diurnal default: a 250 ms modeled "day" dipping to
    /// 20% of the base rate at the trough, with 4× flash-crowd bursts of
    /// ~20 ms mean every ~160 ms mean.  The short period keeps multiple
    /// full cycles inside typical sub-second trace horizons.
    pub fn diurnal_default() -> Self {
        ArrivalProcess::Diurnal {
            period: 0.25,
            depth: 0.8,
            burst_factor: 4.0,
            mean_burst: 0.02,
            mean_gap: 0.16,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson => "poisson",
            ArrivalProcess::Bursty { .. } => "bursty",
            ArrivalProcess::Diurnal { .. } => "diurnal",
        }
    }

    /// Stateful inter-arrival sampler starting in the base state at t=0.
    pub fn sampler(self, base_rate: f64) -> ArrivalSampler {
        ArrivalSampler { process: self, base_rate, in_burst: false, dwell_left: None, t: 0.0 }
    }
}

/// Draws successive inter-arrival gaps for one [`ArrivalProcess`].
/// For `Poisson` this consumes exactly one exponential draw per gap —
/// bit-identical to the historical trace generator.
#[derive(Clone, Debug)]
pub struct ArrivalSampler {
    process: ArrivalProcess,
    base_rate: f64,
    in_burst: bool,
    /// Remaining dwell time in the current MMPP state (lazily drawn).
    dwell_left: Option<f64>,
    /// Absolute arrival-clock time (s since the trace origin); drives
    /// the diurnal envelope phase.  Stationary shapes ignore it.
    t: f64,
}

impl ArrivalSampler {
    /// Time from the previous arrival to the next one.
    pub fn next_gap(&mut self, rng: &mut Rng) -> f64 {
        match self.process {
            ArrivalProcess::Poisson => rng.exponential(self.base_rate),
            ArrivalProcess::Bursty { burst_factor, mean_burst, mean_gap } => {
                let mut gap = 0.0;
                // walk MMPP states until an arrival lands inside one
                loop {
                    let rate = if self.in_burst {
                        self.base_rate * burst_factor.max(1.0)
                    } else {
                        self.base_rate
                    };
                    let dwell = match self.dwell_left {
                        Some(d) => d,
                        None => {
                            let mean = if self.in_burst {
                                mean_burst.max(1e-9)
                            } else {
                                mean_gap.max(1e-9)
                            };
                            let d = rng.exponential(1.0 / mean);
                            self.dwell_left = Some(d);
                            d
                        }
                    };
                    let candidate = rng.exponential(rate);
                    if candidate <= dwell {
                        self.dwell_left = Some(dwell - candidate);
                        return gap + candidate;
                    }
                    // no arrival before the state switch: advance time to
                    // the switch and redraw in the other state
                    gap += dwell;
                    self.in_burst = !self.in_burst;
                    self.dwell_left = None;
                }
            }
            ArrivalProcess::Diurnal { period, depth, burst_factor, mean_burst, mean_gap } => {
                let mut gap = 0.0;
                // MMPP dwell walk with Lewis–Shedler thinning: each
                // candidate advances the arrival clock, then survives
                // with probability envelope(t) ≤ 1, so the accepted
                // process has instantaneous rate envelope(t) × state
                // rate.  envelope ≥ 1-depth > 0 (depth is clamped below
                // 1), so acceptance is always possible and the clock
                // strictly advances on every rejected candidate.
                loop {
                    let rate = if self.in_burst {
                        self.base_rate * burst_factor.max(1.0)
                    } else {
                        self.base_rate
                    };
                    let dwell = match self.dwell_left {
                        Some(d) => d,
                        None => {
                            let mean = if self.in_burst {
                                mean_burst.max(1e-9)
                            } else {
                                mean_gap.max(1e-9)
                            };
                            let d = rng.exponential(1.0 / mean);
                            self.dwell_left = Some(d);
                            d
                        }
                    };
                    let candidate = rng.exponential(rate);
                    if candidate <= dwell {
                        self.dwell_left = Some(dwell - candidate);
                        gap += candidate;
                        self.t += candidate;
                        let phase = (self.t / period.max(1e-9)).fract();
                        let envelope = 1.0
                            - depth.clamp(0.0, 0.999)
                                * 0.5
                                * (1.0 + (std::f64::consts::TAU * phase).cos());
                        if rng.f64() < envelope {
                            return gap;
                        }
                        // thinned out: keep walking from the advanced clock
                    } else {
                        gap += dwell;
                        self.t += dwell;
                        self.in_burst = !self.in_burst;
                        self.dwell_left = None;
                    }
                }
            }
        }
    }
}

/// Trace parameters.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    pub class: WorkloadClass,
    /// Number of concurrent background streams.
    pub background_tasks: usize,
    /// Urgent base arrival rate λ (tasks/s).
    pub arrival_rate: f64,
    /// Urgent arrival process (Poisson by default; MMPP-style bursts for
    /// the cluster stress scenarios).
    pub process: ArrivalProcess,
    /// Horizon (s).
    pub horizon: f64,
    /// Urgent deadline = arrival + factor × isolated exec estimate.
    pub deadline_factor: f64,
    /// Inferences per job (batching keeps task durations realistic).
    pub batch: usize,
    pub tiling: TilingConfig,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            class: WorkloadClass::Simple,
            background_tasks: 4,
            arrival_rate: 50.0,
            process: ArrivalProcess::Poisson,
            horizon: 1.0,
            deadline_factor: 3.0,
            batch: 16,
            tiling: TilingConfig::default(),
            seed: 42,
        }
    }
}

/// Build the full arrival list, sorted by arrival time.
///
/// Background streams: each stream repeatedly re-issues one model of the
/// class with a period of ~1.5× its isolated execution time on an equal
/// share of the platform, producing steady engine occupancy for the
/// urgent tasks to preempt.  Urgent tasks: Poisson(λ) arrivals of random
/// class members with deadlines.
pub fn build_trace(cfg: &TraceConfig, platform: &Platform) -> Vec<Task> {
    let mut rng = Rng::new(cfg.seed);
    let models = cfg.class.models();
    let mut tasks: Vec<Task> = Vec::new();
    let mut next_id = 0;

    // achievable-execution estimates drive periods and deadlines — a
    // deadline below the platform's best-case execution time would make
    // every scheduler "fail" vacuously
    let exec = crate::scheduler::exec_model::ExecModel::new(*platform);
    let share = (platform.engines / cfg.background_tasks.max(1)).max(1);

    // cap per-stream instances so pathological parameter combinations
    // cannot explode the event queue
    const MAX_INSTANCES_PER_STREAM: usize = 400;
    for stream in 0..cfg.background_tasks {
        let model = models[stream % models.len()];
        let probe =
            Task::new(usize::MAX, model, Priority::Background, 0.0, cfg.tiling).with_batch(cfg.batch);
        let period = exec.tss(&probe, share).seconds * 1.5;
        // staggered starts, but guarantee at least one instance inside
        // the horizon even when the period exceeds it (weight-heavy LLM
        // streams on short horizons)
        let mut t = rng.f64() * period.min(cfg.horizon * 0.5);
        let mut count = 0;
        while t < cfg.horizon && count < MAX_INSTANCES_PER_STREAM {
            tasks.push(
                Task::new(next_id, model, Priority::Background, t, cfg.tiling).with_batch(cfg.batch),
            );
            next_id += 1;
            count += 1;
            t += period;
        }
    }

    // urgent arrivals (Poisson or MMPP-bursty); deadline relative to
    // execution on the partition the matcher will actually claim (≈ one
    // engine per tile).  The Poisson sampler consumes exactly the draws
    // the historical inline loop did, so default traces replay
    // bit-identically across this refactor.
    let mut sampler = cfg.process.sampler(cfg.arrival_rate);
    let mut t = sampler.next_gap(&mut rng);
    while t < cfg.horizon {
        let model = *rng.choose(&models);
        let task =
            Task::new(next_id, model, Priority::Urgent, t, cfg.tiling).with_batch(cfg.batch);
        let claim = task.tiles.len().clamp(1, platform.engines);
        let isolated = exec.tss(&task, claim).seconds;
        let deadline = t + cfg.deadline_factor * isolated.max(1e-6);
        tasks.push(task.with_deadline(deadline));
        next_id += 1;
        t += sampler.next_gap(&mut rng);
    }

    tasks.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    // re-number in arrival order so TaskId doubles as an arrival index
    for (i, task) in tasks.iter_mut().enumerate() {
        task.id = i;
    }
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(seed: u64, rate: f64) -> Vec<Task> {
        let cfg = TraceConfig { seed, arrival_rate: rate, horizon: 0.5, ..Default::default() };
        build_trace(&cfg, &Platform::edge())
    }

    #[test]
    fn arrivals_sorted_and_ids_sequential() {
        let t = trace(1, 50.0);
        assert!(!t.is_empty());
        for w in t.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        for (i, task) in t.iter().enumerate() {
            assert_eq!(task.id, i);
        }
    }

    #[test]
    fn urgent_tasks_have_deadlines() {
        let t = trace(2, 100.0);
        let urgent: Vec<_> = t.iter().filter(|t| t.is_urgent()).collect();
        assert!(!urgent.is_empty());
        for u in urgent {
            let d = u.deadline.expect("urgent without deadline");
            assert!(d > u.arrival);
        }
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let cfg = TraceConfig { seed: 3, arrival_rate: 200.0, horizon: 2.0, ..Default::default() };
        let t = build_trace(&cfg, &Platform::edge());
        let urgent = t.iter().filter(|t| t.is_urgent()).count();
        let expected = 200.0 * 2.0;
        assert!(
            (urgent as f64) > expected * 0.7 && (urgent as f64) < expected * 1.3,
            "got {urgent}, expected ~{expected}"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let a = trace(9, 50.0);
        let b = trace(9, 50.0);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.model, y.model);
            assert!((x.arrival - y.arrival).abs() < 1e-12);
        }
    }

    /// The Poisson sampler is a pure refactor: it consumes exactly one
    /// exponential draw per gap, so the stream matches the historical
    /// inline `rng.exponential` loop bit for bit.
    #[test]
    fn poisson_sampler_matches_inline_exponential_stream() {
        let mut a = Rng::new(77);
        let mut b = Rng::new(77);
        let mut sampler = ArrivalProcess::Poisson.sampler(120.0);
        for _ in 0..200 {
            assert_eq!(sampler.next_gap(&mut a).to_bits(), b.exponential(120.0).to_bits());
        }
    }

    /// The MMPP process actually bursts: same mean-ish load, but the
    /// inter-arrival gaps are far more dispersed than Poisson (the
    /// squared coefficient of variation of an exponential is 1).
    #[test]
    fn bursty_arrivals_are_overdispersed() {
        let gaps = |process: ArrivalProcess| -> Vec<f64> {
            let mut rng = Rng::new(5);
            let mut sampler = process.sampler(100.0);
            (0..4000).map(|_| sampler.next_gap(&mut rng)).collect()
        };
        let cv2 = |g: &[f64]| {
            let mean = g.iter().sum::<f64>() / g.len() as f64;
            let var = g.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / g.len() as f64;
            var / (mean * mean)
        };
        let poisson = cv2(&gaps(ArrivalProcess::Poisson));
        let bursty = cv2(&gaps(ArrivalProcess::bursty_default()));
        assert!((poisson - 1.0).abs() < 0.2, "poisson CV² should be ~1, got {poisson}");
        assert!(
            bursty > poisson * 1.5,
            "bursty CV² {bursty} not over-dispersed vs poisson {poisson}"
        );
    }

    /// Same-seed diurnal samplers emit bit-identical gap streams — the
    /// thinning loop must consume draws in one deterministic order.
    #[test]
    fn diurnal_sampler_same_seed_is_bit_identical() {
        let mut a = Rng::new(31);
        let mut b = Rng::new(31);
        let mut sa = ArrivalProcess::diurnal_default().sampler(150.0);
        let mut sb = ArrivalProcess::diurnal_default().sampler(150.0);
        for _ in 0..500 {
            assert_eq!(sa.next_gap(&mut a).to_bits(), sb.next_gap(&mut b).to_bits());
        }
    }

    /// The realized arrival density tracks the day-cycle envelope: far
    /// more arrivals land near the envelope peak (phase ½) than near
    /// the trough (phase 0), and the overall mean rate sits between the
    /// trough and peak of `envelope × MMPP state mix`.
    #[test]
    fn diurnal_mean_rate_tracks_envelope() {
        let period = 0.25;
        let process = ArrivalProcess::Diurnal {
            period,
            depth: 0.8,
            burst_factor: 4.0,
            mean_burst: 0.02,
            mean_gap: 0.16,
        };
        let base = 400.0;
        let mut rng = Rng::new(17);
        let mut sampler = process.sampler(base);
        let mut t = 0.0;
        let horizon = period * 200.0; // many full cycles
        let (mut peak, mut trough, mut total) = (0usize, 0usize, 0usize);
        while t < horizon {
            t += sampler.next_gap(&mut rng);
            total += 1;
            let phase = (t / period).fract();
            if (0.3..0.7).contains(&phase) {
                peak += 1;
            } else if !(0.1..0.9).contains(&phase) {
                trough += 1;
            }
        }
        assert!(
            peak > trough * 2,
            "arrivals should pile up at the envelope peak: peak={peak} trough={trough}"
        );
        // time-average envelope is 1-depth/2 = 0.6; the MMPP state mix
        // contributes a further ≥1 multiplier — accept a wide band
        let rate = total as f64 / horizon;
        assert!(
            rate > base * 0.35 && rate < base * 1.4,
            "mean rate {rate} should track ~0.6-0.8×{base}"
        );
    }

    #[test]
    fn diurnal_trace_is_deterministic_and_sorted() {
        let cfg = TraceConfig {
            seed: 23,
            arrival_rate: 120.0,
            process: ArrivalProcess::diurnal_default(),
            horizon: 0.5,
            ..Default::default()
        };
        let a = build_trace(&cfg, &Platform::edge());
        let b = build_trace(&cfg, &Platform::edge());
        assert_eq!(a.len(), b.len());
        assert!(a.iter().any(|t| t.is_urgent()));
        for (x, y) in a.iter().zip(&b) {
            assert!((x.arrival - y.arrival).abs() < 1e-12);
        }
    }

    #[test]
    fn bursty_trace_is_deterministic_and_sorted() {
        let cfg = TraceConfig {
            seed: 11,
            arrival_rate: 80.0,
            process: ArrivalProcess::bursty_default(),
            horizon: 0.5,
            ..Default::default()
        };
        let a = build_trace(&cfg, &Platform::edge());
        let b = build_trace(&cfg, &Platform::edge());
        assert_eq!(a.len(), b.len());
        assert!(a.iter().any(|t| t.is_urgent()));
        for (x, y) in a.iter().zip(&b) {
            assert!((x.arrival - y.arrival).abs() < 1e-12);
        }
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }
}
