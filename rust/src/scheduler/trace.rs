//! Trace generation: periodic background jobs + Poisson urgent arrivals
//! (the open-ended scenario of Fig. 1c; the Poisson process is exactly
//! how the paper's LBT metric defines arrivals, §4.1.4).

use crate::accel::Platform;
use crate::util::Rng;
use crate::workload::{TilingConfig, WorkloadClass};

use super::task::{Priority, Task};

/// Trace parameters.
#[derive(Clone, Copy, Debug)]
pub struct TraceConfig {
    pub class: WorkloadClass,
    /// Number of concurrent background streams.
    pub background_tasks: usize,
    /// Urgent Poisson rate λ (tasks/s).
    pub arrival_rate: f64,
    /// Horizon (s).
    pub horizon: f64,
    /// Urgent deadline = arrival + factor × isolated exec estimate.
    pub deadline_factor: f64,
    /// Inferences per job (batching keeps task durations realistic).
    pub batch: usize,
    pub tiling: TilingConfig,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            class: WorkloadClass::Simple,
            background_tasks: 4,
            arrival_rate: 50.0,
            horizon: 1.0,
            deadline_factor: 3.0,
            batch: 16,
            tiling: TilingConfig::default(),
            seed: 42,
        }
    }
}

/// Build the full arrival list, sorted by arrival time.
///
/// Background streams: each stream repeatedly re-issues one model of the
/// class with a period of ~1.5× its isolated execution time on an equal
/// share of the platform, producing steady engine occupancy for the
/// urgent tasks to preempt.  Urgent tasks: Poisson(λ) arrivals of random
/// class members with deadlines.
pub fn build_trace(cfg: &TraceConfig, platform: &Platform) -> Vec<Task> {
    let mut rng = Rng::new(cfg.seed);
    let models = cfg.class.models();
    let mut tasks: Vec<Task> = Vec::new();
    let mut next_id = 0;

    // achievable-execution estimates drive periods and deadlines — a
    // deadline below the platform's best-case execution time would make
    // every scheduler "fail" vacuously
    let exec = crate::scheduler::exec_model::ExecModel::new(*platform);
    let share = (platform.engines / cfg.background_tasks.max(1)).max(1);

    // cap per-stream instances so pathological parameter combinations
    // cannot explode the event queue
    const MAX_INSTANCES_PER_STREAM: usize = 400;
    for stream in 0..cfg.background_tasks {
        let model = models[stream % models.len()];
        let probe =
            Task::new(usize::MAX, model, Priority::Background, 0.0, cfg.tiling).with_batch(cfg.batch);
        let period = exec.tss(&probe, share).seconds * 1.5;
        // staggered starts, but guarantee at least one instance inside
        // the horizon even when the period exceeds it (weight-heavy LLM
        // streams on short horizons)
        let mut t = rng.f64() * period.min(cfg.horizon * 0.5);
        let mut count = 0;
        while t < cfg.horizon && count < MAX_INSTANCES_PER_STREAM {
            tasks.push(
                Task::new(next_id, model, Priority::Background, t, cfg.tiling).with_batch(cfg.batch),
            );
            next_id += 1;
            count += 1;
            t += period;
        }
    }

    // urgent Poisson arrivals; deadline relative to execution on the
    // partition the matcher will actually claim (≈ one engine per tile)
    let mut t = rng.exponential(cfg.arrival_rate);
    while t < cfg.horizon {
        let model = *rng.choose(&models);
        let task =
            Task::new(next_id, model, Priority::Urgent, t, cfg.tiling).with_batch(cfg.batch);
        let claim = task.tiles.len().clamp(1, platform.engines);
        let isolated = exec.tss(&task, claim).seconds;
        let deadline = t + cfg.deadline_factor * isolated.max(1e-6);
        tasks.push(task.with_deadline(deadline));
        next_id += 1;
        t += rng.exponential(cfg.arrival_rate);
    }

    tasks.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    // re-number in arrival order so TaskId doubles as an arrival index
    for (i, task) in tasks.iter_mut().enumerate() {
        task.id = i;
    }
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trace(seed: u64, rate: f64) -> Vec<Task> {
        let cfg = TraceConfig { seed, arrival_rate: rate, horizon: 0.5, ..Default::default() };
        build_trace(&cfg, &Platform::edge())
    }

    #[test]
    fn arrivals_sorted_and_ids_sequential() {
        let t = trace(1, 50.0);
        assert!(!t.is_empty());
        for w in t.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        for (i, task) in t.iter().enumerate() {
            assert_eq!(task.id, i);
        }
    }

    #[test]
    fn urgent_tasks_have_deadlines() {
        let t = trace(2, 100.0);
        let urgent: Vec<_> = t.iter().filter(|t| t.is_urgent()).collect();
        assert!(!urgent.is_empty());
        for u in urgent {
            let d = u.deadline.expect("urgent without deadline");
            assert!(d > u.arrival);
        }
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let cfg = TraceConfig { seed: 3, arrival_rate: 200.0, horizon: 2.0, ..Default::default() };
        let t = build_trace(&cfg, &Platform::edge());
        let urgent = t.iter().filter(|t| t.is_urgent()).count();
        let expected = 200.0 * 2.0;
        assert!(
            (urgent as f64) > expected * 0.7 && (urgent as f64) < expected * 1.3,
            "got {urgent}, expected ~{expected}"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let a = trace(9, 50.0);
        let b = trace(9, 50.0);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.model, y.model);
            assert!((x.arrival - y.arrival).abs() < 1e-12);
        }
    }
}
