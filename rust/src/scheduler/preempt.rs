//! Preemption policy (paper §3.3 + Fig. 4): the adaptive "single-core
//! preemption ratio" and max-slack victim selection.
//!
//! * the **ratio** caps how much of the platform one interrupt may
//!   claim; it adapts with the urgent task's deadline pressure — a tight
//!   deadline may reclaim more engines, a loose one fewer (so background
//!   work keeps making progress);
//! * among preemptible candidates, victims with the **largest
//!   execution-time slack** are reclaimed first ("prioritizes preempting
//!   the task with the largest execution-time slack, so as to avoid
//!   deadline violations of the original tasks caused by preemption").

use super::task::Priority;
use crate::util::ord::nan_least_cmp;

/// Policy parameters.
#[derive(Clone, Copy, Debug)]
pub struct PreemptPolicy {
    /// Base fraction of engines one interrupt may claim.
    pub base_ratio: f64,
    /// Ratio ceiling under maximal deadline pressure.
    pub max_ratio: f64,
    /// Deadline-pressure pivot: pressure 1.0 = deadline equals the
    /// estimated isolated execution time (no slack at all).
    pub pressure_pivot: f64,
}

impl Default for PreemptPolicy {
    fn default() -> Self {
        Self { base_ratio: 0.5, max_ratio: 0.875, pressure_pivot: 2.0 }
    }
}

/// A preemption candidate (engine currently idle or owned by a
/// lower-priority task).
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    pub engine: usize,
    /// Owner priority (None = idle engine).
    pub owner_priority: Option<Priority>,
    /// Owner's execution-time slack: time remaining until its own
    /// deadline minus its remaining work (idle engines: +inf).
    pub owner_slack: f64,
}

impl PreemptPolicy {
    /// Adaptive ratio for an urgent task whose deadline allows
    /// `deadline_slack = (deadline - now) / isolated_exec` headroom.
    /// `deadline_slack <= pivot` pushes the ratio toward `max_ratio`.
    pub fn adaptive_ratio(&self, deadline_slack: f64) -> f64 {
        if !deadline_slack.is_finite() {
            return self.base_ratio;
        }
        let pressure = (self.pressure_pivot / deadline_slack.max(1e-9)).clamp(0.0, 2.0) / 2.0;
        self.base_ratio + (self.max_ratio - self.base_ratio) * pressure
    }

    /// Select up to `ratio × total_engines` victims: idle engines first,
    /// then background-owned by descending slack, then (only if the
    /// policy ever allows it) normal-priority by descending slack.
    /// Urgent owners are never selected.
    pub fn select_victims(
        &self,
        candidates: &[Candidate],
        total_engines: usize,
        deadline_slack: f64,
    ) -> Vec<usize> {
        let cap = ((total_engines as f64) * self.adaptive_ratio(deadline_slack))
            .floor()
            .max(1.0) as usize;
        let mut idle: Vec<&Candidate> =
            candidates.iter().filter(|c| c.owner_priority.is_none()).collect();
        idle.sort_by_key(|c| c.engine);
        let mut owned: Vec<&Candidate> = candidates
            .iter()
            .filter(|c| {
                matches!(c.owner_priority, Some(Priority::Background) | Some(Priority::Normal))
            })
            .collect();
        // max-slack first within each priority class; Background before
        // Normal; an owner with NaN slack (unknown headroom) sorts last
        // in its class, so it is reclaimed only once every known-slack
        // victim is taken
        owned.sort_by(|a, b| {
            let pa = a.owner_priority.unwrap();
            let pb = b.owner_priority.unwrap();
            pa.cmp(&pb) // Background < Normal: Background first
                .then(nan_least_cmp(b.owner_slack, a.owner_slack))
                .then(a.engine.cmp(&b.engine))
        });
        idle.into_iter()
            .chain(owned)
            .take(cap)
            .map(|c| c.engine)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(engine: usize, prio: Option<Priority>, slack: f64) -> Candidate {
        Candidate { engine, owner_priority: prio, owner_slack: slack }
    }

    #[test]
    fn ratio_adapts_to_pressure() {
        let p = PreemptPolicy::default();
        // loose deadline (10x isolated): near base ratio
        assert!((p.adaptive_ratio(10.0) - p.base_ratio).abs() < 0.08);
        // tight deadline (1x isolated): pushed toward max
        assert!(p.adaptive_ratio(1.0) > 0.8);
        // monotone in pressure
        assert!(p.adaptive_ratio(1.0) > p.adaptive_ratio(3.0));
        assert!(p.adaptive_ratio(3.0) > p.adaptive_ratio(8.0));
        // never exceeds the ceiling
        assert!(p.adaptive_ratio(1e-6) <= p.max_ratio + 1e-12);
    }

    #[test]
    fn idle_engines_claimed_before_victims() {
        let p = PreemptPolicy::default();
        let cands = vec![
            cand(0, Some(Priority::Background), 5.0),
            cand(1, None, f64::INFINITY),
            cand(2, Some(Priority::Background), 1.0),
            cand(3, None, f64::INFINITY),
        ];
        let victims = p.select_victims(&cands, 8, 3.0);
        assert!(victims.len() >= 2);
        assert_eq!(&victims[..2], &[1, 3], "idle engines must come first");
    }

    #[test]
    fn max_slack_victims_first() {
        let p = PreemptPolicy::default();
        let cands = vec![
            cand(0, Some(Priority::Background), 1.0),
            cand(1, Some(Priority::Background), 9.0),
            cand(2, Some(Priority::Background), 4.0),
        ];
        let victims = p.select_victims(&cands, 4, 3.0); // cap = 2
        assert_eq!(victims, vec![1, 2], "largest slack preempted first");
    }

    #[test]
    fn background_preempted_before_normal() {
        let p = PreemptPolicy::default();
        let cands = vec![
            cand(0, Some(Priority::Normal), 100.0),
            cand(1, Some(Priority::Background), 0.5),
        ];
        let victims = p.select_victims(&cands, 2, 3.0);
        assert_eq!(victims[0], 1);
    }

    #[test]
    fn nan_slack_victim_taken_last_not_panicking() {
        // regression: the slack tiebreak was partial_cmp(..).unwrap(),
        // so one owner with a poisoned (NaN) slack estimate aborted
        // victim selection for the whole interrupt
        let p = PreemptPolicy::default();
        let cands = vec![
            cand(0, Some(Priority::Background), f64::NAN),
            cand(1, Some(Priority::Background), 1.0),
            cand(2, Some(Priority::Background), 9.0),
        ];
        let victims = p.select_victims(&cands, 4, 3.0); // cap = 2
        assert_eq!(victims, vec![2, 1], "NaN slack must rank below known slack");
    }

    #[test]
    fn cap_respected_and_at_least_one() {
        let p = PreemptPolicy { base_ratio: 0.25, max_ratio: 0.5, pressure_pivot: 2.0 };
        let cands: Vec<Candidate> =
            (0..16).map(|e| cand(e, Some(Priority::Background), e as f64)).collect();
        let loose = p.select_victims(&cands, 16, 100.0);
        assert_eq!(loose.len(), 4); // 0.25 * 16
        let tight = p.select_victims(&cands, 16, 0.5);
        assert!(tight.len() > 4 && tight.len() <= 8);
        // degenerate platform still yields one victim
        let one = p.select_victims(&cands[..1], 1, 100.0);
        assert_eq!(one.len(), 1);
    }
}
