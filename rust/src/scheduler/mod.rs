//! Multi-DNN scheduling frameworks + event-driven platform simulator.
//!
//! Six frameworks are implemented behind one [`Framework`] trait:
//!
//! | framework | paradigm | preemptive | interruptible | matcher |
//! |-----------|----------|------------|---------------|---------|
//! | PREMA     | LTS      | ✓          | ×             | token heuristic (CPU) |
//! | Planaria  | LTS      | ✓          | ×             | fission search (CPU)  |
//! | MoCA      | LTS      | ✓          | ×             | memory-aware heuristic (CPU) |
//! | CD-MSA    | LTS      | ✓          | ×             | deadline-aware heuristic (CPU) |
//! | IsoSched  | TSS      | ✓          | ×             | serial Ullmann (CPU)  |
//! | IMMSched  | TSS      | ✓          | ✓             | parallel PSO (on-accelerator) |
//!
//! (paper Table 1).  "Interruptible" = scheduling latency small enough to
//! handle *unpredictable* triggers online; the LTS baselines and IsoSched
//! pay their (measured or modeled) serial CPU search latency on every
//! urgent arrival, IMMSched pays the on-accelerator PSO episode cost.

pub mod exec_model;
pub mod frameworks;
pub mod lts_policies;
pub mod metrics;
pub mod preempt;
pub mod sim;
pub mod task;
pub mod trace;

pub use exec_model::{ExecEstimate, ExecModel, Paradigm};
pub use frameworks::{
    make_framework, make_isosched_with_engine, Framework, FrameworkKind, SchedDecision,
    SchedRequest,
};
pub use metrics::{lbt_sweep, MetricSet, SimSummary};
pub use preempt::{Candidate, PreemptPolicy};
pub use sim::{SimConfig, SimResult, Simulator, TaskRecord};
pub use task::{Priority, Task, TaskId};
pub use trace::{build_trace, ArrivalProcess, ArrivalSampler, TraceConfig};
