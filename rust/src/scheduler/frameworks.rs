//! The six scheduling frameworks behind one trait.
//!
//! * LTS baselines (PREMA / Planaria / MoCA / CD-MSA) model their
//!   published CPU-side scheduling searches as op counts fed through
//!   [`MatcherCostModel::cpu_*`]-style accounting; their relative cost
//!   ordering (MoCA < PREMA < CD-MSA < Planaria) follows the published
//!   algorithm complexities and reproduces the paper's Fig. 6 ordering.
//! * IsoSched runs the *actual* serial Ullmann matcher on the real tile
//!   and target graphs; its latency is the measured node count through
//!   the CPU cost model.
//! * IMMSched runs the *actual* quantized PSO matcher; its latency is
//!   the measured episode through the on-accelerator cost model.
//!
//! Both TSS frameworks build their problems through the typed
//! [`MatchProblem`] API and run them through the pluggable
//! [`MatchEngine`] interface — the same chain the coordinator's
//! `MatchService` drives — so the serial baseline is swappable (see
//! [`make_isosched_with_engine`]) and the episode telemetry
//! ([`crate::coordinator::EngineWork`]) feeds the cost models.
//!
//! Matching episodes are memoized per (model, target size): repeated
//! urgent arrivals of the same model reuse the measured episode instead
//! of re-running the matcher — the simulator stays fast without losing
//! measured grounding.

use std::collections::HashMap;

use crate::accel::{build_target_graph, Platform};
use crate::coordinator::{
    CancelToken, DenseCache, EngineBudget, EngineOutcome, MatchEngine, MatchProblem,
    QuantizedEngine, UllmannEngine,
};
use crate::matcher::{MatcherCost, MatcherCostModel, PsoConfig, QuantizedOutcome, UllmannStats};
use crate::workload::ModelId;

use super::exec_model::Paradigm;
use super::task::Task;

/// Framework selector (paper Table 1 rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FrameworkKind {
    Prema,
    Planaria,
    Moca,
    CdMsa,
    IsoSched,
    ImmSched,
}

impl FrameworkKind {
    pub const ALL: [FrameworkKind; 6] = [
        FrameworkKind::Prema,
        FrameworkKind::CdMsa,
        FrameworkKind::Planaria,
        FrameworkKind::Moca,
        FrameworkKind::IsoSched,
        FrameworkKind::ImmSched,
    ];

    pub fn name(self) -> &'static str {
        match self {
            FrameworkKind::Prema => "PREMA",
            FrameworkKind::Planaria => "Planaria",
            FrameworkKind::Moca => "MoCA",
            FrameworkKind::CdMsa => "CD-MSA",
            FrameworkKind::IsoSched => "IsoSched",
            FrameworkKind::ImmSched => "IMMSched",
        }
    }

    pub fn from_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "prema" => Some(FrameworkKind::Prema),
            "planaria" => Some(FrameworkKind::Planaria),
            "moca" => Some(FrameworkKind::Moca),
            "cdmsa" | "cd-msa" => Some(FrameworkKind::CdMsa),
            "isosched" => Some(FrameworkKind::IsoSched),
            "immsched" => Some(FrameworkKind::ImmSched),
            _ => None,
        }
    }
}

/// What the simulator hands a framework on an urgent arrival.
pub struct SchedRequest<'a> {
    pub task: &'a Task,
    pub now: f64,
    /// Engine ids the policy allows preempting (idle + low-priority,
    /// capped by the preemption ratio).
    pub preemptible: Vec<usize>,
    /// Queue length at arrival (drives the CPU heuristics' work).
    pub queue_len: usize,
}

/// A framework's answer.
#[derive(Clone, Debug, Default)]
pub struct SchedDecision {
    /// Scheduling latency (s) — elapses before execution can start.
    pub sched_seconds: f64,
    /// Energy burned scheduling (J).
    pub sched_joules: f64,
    /// Engines claimed for the urgent task (empty if infeasible).
    pub engines: Vec<usize>,
    /// Whether a feasible placement was found.
    pub feasible: bool,
}

/// Common behavior of all six frameworks.
pub trait Framework: Send {
    fn kind(&self) -> FrameworkKind;
    fn paradigm(&self) -> Paradigm;
    /// Table 1 columns.
    fn preemptive(&self) -> bool {
        true
    }
    fn interruptible(&self) -> bool {
        false
    }
    /// Handle an urgent arrival.
    fn schedule_urgent(&mut self, req: &SchedRequest) -> SchedDecision;

    /// Pick the next queued task to dispatch (index into `queue`).
    /// Default: FIFO.  The LTS baselines override this with their
    /// published policies (`lts_policies`).
    fn pick_next(&self, queue: &[super::lts_policies::TaskView], now: f64) -> Option<usize> {
        let _ = now;
        (!queue.is_empty()).then_some(0)
    }
}

/// Instantiate a framework.
pub fn make_framework(
    kind: FrameworkKind,
    platform: Platform,
    pso: PsoConfig,
) -> Box<dyn Framework> {
    match kind {
        FrameworkKind::Prema => Box::new(LtsHeuristic::new(kind, platform, 2.0e4)),
        FrameworkKind::CdMsa => Box::new(LtsHeuristic::new(kind, platform, 4.0e4)),
        FrameworkKind::Planaria => Box::new(LtsHeuristic::new(kind, platform, 1.0e5)),
        FrameworkKind::Moca => Box::new(LtsHeuristic::new(kind, platform, 1.0e4)),
        FrameworkKind::IsoSched => Box::new(IsoSched::new(platform)),
        FrameworkKind::ImmSched => Box::new(ImmSched::new(platform, pso)),
    }
}

/// IsoSched with an explicit serial [`MatchEngine`] — the baseline-swap
/// hook (e.g. [`crate::coordinator::Vf2Engine`] instead of Ullmann)
/// behind the same TSS matching path.
pub fn make_isosched_with_engine(
    platform: Platform,
    engine: Box<dyn MatchEngine + Send>,
) -> Box<dyn Framework> {
    Box::new(IsoSched::with_engine(platform, engine))
}

/// Run one episode of `engine` on the (tile DAG → preemptible target)
/// problem of an urgent request.  Shared by the TSS frameworks.
fn solve_typed(
    engine: &mut dyn MatchEngine,
    platform: &Platform,
    req: &SchedRequest,
    node_budget: u64,
) -> Option<(EngineOutcome, Vec<usize>, usize, usize)> {
    let mut pre = vec![false; platform.engines];
    for &e in &req.preemptible {
        pre[e] = true;
    }
    let (target, vertex_engine) = build_target_graph(platform, &pre);
    if target.is_empty() {
        return None;
    }
    let problem = MatchProblem::from_dags(&req.task.tiles.dag, &target);
    let (n, m) = (problem.n(), problem.m());
    let cancel = CancelToken::new();
    let mut dense = DenseCache::default();
    let mreq = problem.request(req.task.id as u64, req.task.priority, req.task.deadline);
    let mut budget = EngineBudget {
        nodes: node_budget,
        cancel: &cancel,
        expires_at: None,
        epoch_quota: None,
        dense: &mut dense,
    };
    let outcome = engine.solve(&mreq, &mut budget);
    Some((outcome, vertex_engine, n, m))
}

// ---------------------------------------------------------------------------
// LTS baselines
// ---------------------------------------------------------------------------

/// Shared skeleton of the four LTS baselines.
///
/// `ops_factor` scales the modeled CPU search: PREMA's token/priority
/// pass is cheap, MoCA's memory-contention heuristic cheaper still,
/// CD-MSA's cooperative deadline pass heavier, Planaria's fission
/// search heaviest (it explores subarray splits per layer).  The search
/// volume grows with layers × queue length × engines, matching the
/// published algorithms' loops.
struct LtsHeuristic {
    kind: FrameworkKind,
    platform: Platform,
    ops_factor: f64,
    cost_model: MatcherCostModel,
}

impl LtsHeuristic {
    fn new(kind: FrameworkKind, platform: Platform, ops_factor: f64) -> Self {
        Self { kind, platform, ops_factor, cost_model: MatcherCostModel::default() }
    }
}

impl Framework for LtsHeuristic {
    fn kind(&self) -> FrameworkKind {
        self.kind
    }

    fn paradigm(&self) -> Paradigm {
        Paradigm::Lts
    }

    fn pick_next(&self, queue: &[super::lts_policies::TaskView], now: f64) -> Option<usize> {
        use super::lts_policies as pol;
        match self.kind {
            FrameworkKind::Prema => pol::prema_pick(queue, now),
            FrameworkKind::Planaria => pol::planaria_pick(queue, now),
            FrameworkKind::Moca => {
                // per-dispatch DRAM budget: one scheduling epoch (10 ms)
                // of LPDDR4 bandwidth
                pol::moca_pick(queue, (25.6e9 * 0.01) as u64)
            }
            FrameworkKind::CdMsa => {
                let credit = vec![0.5; queue.len()];
                pol::cdmsa_pick(queue, &credit, now)
            }
            _ => (!queue.is_empty()).then_some(0),
        }
    }

    fn schedule_urgent(&mut self, req: &SchedRequest) -> SchedDecision {
        // modeled CPU search volume: per-layer re-planning over the
        // resident queue (clamped — published planners cap their window)
        let layers = req.task.layers.max(1) as f64;
        let queue = req.queue_len.clamp(1, 32) as f64;
        let ops = self.ops_factor * layers * queue * (self.platform.engines as f64).sqrt();
        let seconds = self.cost_model.cpu_dispatch_s
            + ops / (self.cost_model.cpu_hz * self.cost_model.cpu_ops_per_cycle);
        SchedDecision {
            sched_seconds: seconds,
            sched_joules: seconds * self.cost_model.cpu_watts,
            // LTS always claims the whole array (single-tenant execution
            // with time multiplexing).
            engines: (0..self.platform.engines).collect(),
            feasible: true,
        }
    }
}

// ---------------------------------------------------------------------------
// IsoSched (TSS + serial Ullmann on CPU)
// ---------------------------------------------------------------------------

struct IsoSched {
    platform: Platform,
    cost_model: MatcherCostModel,
    /// node budget before the serial matcher gives up
    budget: u64,
    /// the serial baseline engine (Ullmann by default, swappable)
    engine: Box<dyn MatchEngine + Send>,
    cache: MatchCache,
}

impl IsoSched {
    fn new(platform: Platform) -> Self {
        Self::with_engine(platform, Box::new(UllmannEngine))
    }

    fn with_engine(platform: Platform, engine: Box<dyn MatchEngine + Send>) -> Self {
        Self {
            platform,
            cost_model: MatcherCostModel::default(),
            budget: 500_000,
            engine,
            cache: MatchCache::default(),
        }
    }

    fn match_once(&mut self, req: &SchedRequest) -> (MatcherCost, Option<Vec<usize>>) {
        let Some((outcome, vertex_engine, n, m)) =
            solve_typed(&mut *self.engine, &self.platform, req, self.budget)
        else {
            return (MatcherCost::zero(), None);
        };
        match outcome {
            EngineOutcome::Served(rep) => {
                let stats = UllmannStats {
                    nodes_visited: rep.work.nodes_visited,
                    refine_passes: rep.work.refine_passes,
                    refuted: 0,
                };
                let cost = self.cost_model.cpu_serial(&stats, n, m);
                let engines = rep.mappings.first().map(|mp| {
                    mp.iter().flatten().map(|&v| vertex_engine[v]).collect::<Vec<_>>()
                });
                (cost, engines)
            }
            _ => (MatcherCost::zero(), None),
        }
    }
}

impl Framework for IsoSched {
    fn kind(&self) -> FrameworkKind {
        FrameworkKind::IsoSched
    }

    fn paradigm(&self) -> Paradigm {
        Paradigm::Tss
    }

    fn schedule_urgent(&mut self, req: &SchedRequest) -> SchedDecision {
        let key = (req.task.model, req.preemptible.len());
        if let Some((cost, mapped)) = self.cache.lookup(key) {
            return decision_from(cost, mapped);
        }
        let (cost, mapped) = self.match_once(req);
        self.cache.record(key, cost, &mapped);
        decision_from(cost, mapped)
    }
}

fn decision_from(cost: MatcherCost, mapped: Option<Vec<usize>>) -> SchedDecision {
    SchedDecision {
        sched_seconds: cost.seconds,
        sched_joules: cost.joules,
        feasible: mapped.is_some(),
        engines: mapped.unwrap_or_default(),
    }
}

/// Host-side matcher memoization shared by the TSS frameworks.
///
/// Successes are cached immediately — the *modeled* cost is still charged
/// on every request, only the host recomputation is skipped.  Failures
/// are NOT cached until they repeat (`FAILURE_THRESHOLD`), because a
/// single unlucky preemptible-set composition must not poison every
/// later request of the same (model, set-size) key.
#[derive(Default)]
struct MatchCache {
    hits: HashMap<(ModelId, usize), (MatcherCost, Option<Vec<usize>>)>,
    failures: HashMap<(ModelId, usize), (u32, MatcherCost)>,
}

const FAILURE_THRESHOLD: u32 = 2;

impl MatchCache {
    fn lookup(&self, key: (ModelId, usize)) -> Option<(MatcherCost, Option<Vec<usize>>)> {
        if let Some(hit) = self.hits.get(&key) {
            return Some(hit.clone());
        }
        if let Some((count, cost)) = self.failures.get(&key) {
            if *count >= FAILURE_THRESHOLD {
                return Some((*cost, None));
            }
        }
        None
    }

    fn record(&mut self, key: (ModelId, usize), cost: MatcherCost, mapped: &Option<Vec<usize>>) {
        match mapped {
            Some(_) => {
                self.hits.insert(key, (cost, mapped.clone()));
                self.failures.remove(&key);
            }
            None => {
                let entry = self.failures.entry(key).or_insert((0, cost));
                entry.0 += 1;
                entry.1 = cost;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// IMMSched (TSS + on-accelerator quantized PSO)
// ---------------------------------------------------------------------------

struct ImmSched {
    platform: Platform,
    pso: PsoConfig,
    cost_model: MatcherCostModel,
    /// the on-accelerator matcher model behind the engine interface
    engine: QuantizedEngine,
    cache: MatchCache,
}

impl ImmSched {
    fn new(platform: Platform, pso: PsoConfig) -> Self {
        Self {
            platform,
            pso,
            cost_model: MatcherCostModel::default(),
            engine: QuantizedEngine::new(pso),
            cache: MatchCache::default(),
        }
    }

    fn match_once(&mut self, req: &SchedRequest) -> (MatcherCost, Option<Vec<usize>>) {
        let Some((outcome, vertex_engine, n, m)) =
            solve_typed(&mut self.engine, &self.platform, req, self.pso.repair_budget)
        else {
            return (MatcherCost::zero(), None);
        };
        match outcome {
            EngineOutcome::Served(rep) => {
                // rebuild the datapath op counts the cost model charges
                let modeled = QuantizedOutcome {
                    epochs_run: rep.epochs_run,
                    steps_run: rep.work.steps_run,
                    mac_ops: rep.work.mac_ops,
                    eltwise_ops: rep.work.eltwise_ops,
                    argmax_ops: rep.work.argmax_ops,
                    repair_nodes: rep.work.repair_nodes,
                    ..Default::default()
                };
                let cost =
                    self.cost_model.accel_pso(&modeled, n, m, self.pso.particles, &self.platform);
                let engines = rep.mappings.first().map(|mp| {
                    mp.iter().flatten().map(|&v| vertex_engine[v]).collect::<Vec<_>>()
                });
                (cost, engines)
            }
            _ => (MatcherCost::zero(), None),
        }
    }
}

impl Framework for ImmSched {
    fn kind(&self) -> FrameworkKind {
        FrameworkKind::ImmSched
    }

    fn paradigm(&self) -> Paradigm {
        Paradigm::Tss
    }

    fn interruptible(&self) -> bool {
        true
    }

    fn schedule_urgent(&mut self, req: &SchedRequest) -> SchedDecision {
        let key = (req.task.model, req.preemptible.len());
        if let Some((cost, mapped)) = self.cache.lookup(key) {
            return decision_from(cost, mapped);
        }
        let (cost, mapped) = self.match_once(req);
        self.cache.record(key, cost, &mapped);
        decision_from(cost, mapped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Vf2Engine;
    use crate::scheduler::task::Priority;
    use crate::workload::TilingConfig;

    fn request(task: &Task, engines: usize) -> SchedRequest<'_> {
        SchedRequest { task, now: 0.0, preemptible: (0..engines).collect(), queue_len: 3 }
    }

    fn mk_task(model: ModelId) -> Task {
        Task::new(0, model, Priority::Urgent, 0.0, TilingConfig { max_tiles: 16, split_factor: 2 })
    }

    #[test]
    fn table1_capability_matrix() {
        let p = Platform::edge();
        for kind in FrameworkKind::ALL {
            let f = make_framework(kind, p, PsoConfig::default());
            assert!(f.preemptive(), "{:?} preemptive", kind);
            let expect_tss = matches!(kind, FrameworkKind::IsoSched | FrameworkKind::ImmSched);
            assert_eq!(f.paradigm() == Paradigm::Tss, expect_tss, "{kind:?} paradigm");
            assert_eq!(f.interruptible(), kind == FrameworkKind::ImmSched, "{kind:?} interruptible");
        }
    }

    #[test]
    fn immsched_schedules_faster_than_isosched_and_lts() {
        let p = Platform::edge();
        let task = mk_task(ModelId::MobileNetV2);
        let req = request(&task, 32);
        let mut imm = make_framework(FrameworkKind::ImmSched, p, PsoConfig::default());
        let mut iso = make_framework(FrameworkKind::IsoSched, p, PsoConfig::default());
        let mut planaria = make_framework(FrameworkKind::Planaria, p, PsoConfig::default());
        let d_imm = imm.schedule_urgent(&req);
        let d_iso = iso.schedule_urgent(&req);
        let d_pla = planaria.schedule_urgent(&req);
        assert!(d_imm.feasible, "IMMSched should place MobileNetV2");
        assert!(
            d_imm.sched_seconds < d_iso.sched_seconds,
            "imm {} >= iso {}",
            d_imm.sched_seconds,
            d_iso.sched_seconds
        );
        assert!(d_imm.sched_seconds < d_pla.sched_seconds);
    }

    #[test]
    fn decisions_are_cached() {
        let p = Platform::edge();
        let task = mk_task(ModelId::ResNet50);
        let mut imm = make_framework(FrameworkKind::ImmSched, p, PsoConfig::default());
        let a = imm.schedule_urgent(&request(&task, 32));
        let b = imm.schedule_urgent(&request(&task, 32));
        assert_eq!(a.sched_seconds, b.sched_seconds);
        assert_eq!(a.engines, b.engines);
    }

    #[test]
    fn claimed_engines_subset_of_preemptible() {
        let p = Platform::edge();
        let task = mk_task(ModelId::MobileNetV2);
        let pre: Vec<usize> = (10..42).collect();
        let req = SchedRequest { task: &task, now: 0.0, preemptible: pre.clone(), queue_len: 1 };
        let mut imm = make_framework(FrameworkKind::ImmSched, p, PsoConfig::default());
        let d = imm.schedule_urgent(&req);
        if d.feasible {
            for e in &d.engines {
                assert!(pre.contains(e), "engine {e} not preemptible");
            }
        }
    }

    #[test]
    fn lts_cost_ordering_matches_paper() {
        // MoCA < PREMA < CD-MSA < Planaria in scheduling latency.
        let p = Platform::cloud();
        let task = mk_task(ModelId::Qwen7B);
        let req = request(&task, 64);
        let lat = |kind| {
            make_framework(kind, p, PsoConfig::default()).schedule_urgent(&req).sched_seconds
        };
        let moca = lat(FrameworkKind::Moca);
        let prema = lat(FrameworkKind::Prema);
        let cdmsa = lat(FrameworkKind::CdMsa);
        let planaria = lat(FrameworkKind::Planaria);
        assert!(moca < prema && prema < cdmsa && cdmsa < planaria);
    }

    /// The serial baseline is swappable behind the same TSS path: an
    /// IsoSched built on VF2 still places the workload, through the
    /// identical `MatchEngine` interface.
    #[test]
    fn isosched_serial_engine_is_swappable() {
        let p = Platform::edge();
        let task = mk_task(ModelId::MobileNetV2);
        let req = request(&task, 32);
        let mut iso_vf2 = make_isosched_with_engine(p, Box::new(Vf2Engine));
        let d = iso_vf2.schedule_urgent(&req);
        assert!(d.feasible, "VF2-backed IsoSched should place MobileNetV2");
        assert!(d.sched_seconds > 0.0);
        let mut iso_ull = make_framework(FrameworkKind::IsoSched, p, PsoConfig::default());
        assert!(iso_ull.schedule_urgent(&req).feasible);
    }

    #[test]
    fn empty_preemptible_set_is_infeasible_for_tss() {
        let p = Platform::edge();
        let task = mk_task(ModelId::MobileNetV2);
        let req = SchedRequest { task: &task, now: 0.0, preemptible: vec![], queue_len: 1 };
        let mut imm = make_framework(FrameworkKind::ImmSched, p, PsoConfig::default());
        let d = imm.schedule_urgent(&req);
        assert!(!d.feasible);
        assert!(d.engines.is_empty());
    }
}
