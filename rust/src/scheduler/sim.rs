//! Event-driven platform simulator.
//!
//! Task-granular discrete-event simulation: tasks arrive (background
//! streams + Poisson urgent triggers), the selected framework schedules
//! them, engines execute them under the paradigm's execution model, and
//! the run produces per-task records + an energy ledger — the raw
//! material for Speedup / LBT / Energy-efficiency (Figs. 6-8).
//!
//! Semantics per paradigm:
//! * **LTS**: the whole array is one resource; one task runs at a time;
//!   urgent arrivals preempt after the framework's scheduling latency,
//!   paying a DRAM checkpoint/restore on the victim.
//! * **TSS**: engines are spatially partitioned; background tasks own
//!   fixed shares; an urgent arrival triggers the subgraph matcher —
//!   since the `MatchService` redesign the TSS frameworks run it through
//!   the typed sparse request + pluggable [`crate::coordinator::MatchEngine`]
//!   chain — which claims preemptible engines (idle first, then the
//!   victims with the largest slack, capped by the single-core
//!   preemption ratio); victims pause and resume when the urgent task
//!   finishes.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::accel::{EnergyBook, Platform};
use crate::coordinator::{Admission, Popped, QueuedRequest, RequestRouter};
use crate::matcher::PsoConfig;

use super::exec_model::{ExecModel, Paradigm};
use super::frameworks::{make_framework, Framework, FrameworkKind, SchedRequest};
use super::task::{Priority, Task, TaskId};

/// Simulation parameters.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    pub platform_kind: crate::accel::PlatformKind,
    pub framework: FrameworkKind,
    pub pso: PsoConfig,
    /// Single-core preemption ratio: max fraction of engines one urgent
    /// task may claim (paper Fig. 4).
    pub preemption_ratio: f64,
    /// Background streams (for the TSS share size).
    pub background_streams: usize,
    /// Stop draining events after `horizon × drain_factor`.
    pub drain_factor: f64,
    /// Optional urgent-admission gate: `Some(depth)` routes urgent
    /// arrivals through a real bounded [`RequestRouter`] (the same
    /// admission stage the live `MatchService` uses) instead of handing
    /// each one to the framework immediately — scheduling episodes are
    /// serialized onto one modeled controller, expired or over-depth
    /// arrivals are shed before a scheduling episode is wasted, and
    /// shed tasks show up as never-started records.  `None` (default)
    /// preserves the historical analytic arrival path exactly.
    pub admission_depth: Option<usize>,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            platform_kind: crate::accel::PlatformKind::Edge,
            framework: FrameworkKind::ImmSched,
            pso: PsoConfig::default(),
            preemption_ratio: 0.5,
            background_streams: 4,
            // generous drain so slow (LTS) frameworks still finish their
            // queues and latency ratios stay finite
            drain_factor: 100.0,
            admission_depth: None,
        }
    }
}

/// Per-task outcome.
#[derive(Clone, Debug)]
pub struct TaskRecord {
    pub id: TaskId,
    pub model: crate::workload::ModelId,
    pub priority: Priority,
    pub arrival: f64,
    /// Scheduling latency paid (urgent tasks; 0 for dispatch-queue tasks).
    pub sched_seconds: f64,
    /// Execution start (None = never started).
    pub started: Option<f64>,
    /// Completion time (None = unfinished at drain end).
    pub completed: Option<f64>,
    pub deadline: Option<f64>,
}

impl TaskRecord {
    /// Total latency (scheduling + queueing + execution).
    pub fn total_latency(&self) -> Option<f64> {
        self.completed.map(|c| c - self.arrival)
    }

    pub fn deadline_met(&self) -> bool {
        match (self.completed, self.deadline) {
            (Some(c), Some(d)) => c <= d,
            (Some(_), None) => true,
            (None, _) => false,
        }
    }
}

/// Full run result.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub records: Vec<TaskRecord>,
    pub energy: EnergyBook,
    pub horizon: f64,
    pub framework: FrameworkKind,
}

impl SimResult {
    pub fn urgent(&self) -> impl Iterator<Item = &TaskRecord> {
        self.records.iter().filter(|r| r.priority == Priority::Urgent)
    }

    pub fn completed_count(&self) -> usize {
        self.records.iter().filter(|r| r.completed.is_some()).count()
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum EventKind {
    Arrive,
    SchedDone,
    Complete { version: u64 },
}

#[derive(Clone, Copy, Debug)]
struct Event {
    time: f64,
    task: TaskId,
    kind: EventKind,
}

// BinaryHeap is a max-heap; order events by ascending time via Reverse +
// total order on the f64 bits (times are finite).
#[derive(PartialEq)]
struct OrdEvent(Event);

impl Eq for OrdEvent {}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.task == other.task && self.kind == other.kind
    }
}

impl PartialOrd for OrdEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // total_cmp: a NaN event time orders after every real time
        // instead of panicking the event heap
        self.0.time.total_cmp(&other.0.time).then(self.0.task.cmp(&other.0.task))
    }
}

#[derive(Clone, Debug, PartialEq)]
enum RunState {
    Pending,
    Scheduling,
    Running { ends: f64, version: u64 },
    Paused { remaining: f64 },
    Queued,
    Done,
    Dropped,
}

struct LiveTask {
    task: Task,
    state: RunState,
    engines: Vec<usize>,
    record: TaskRecord,
    /// duration of one uninterrupted execution on its allocation
    exec_seconds: f64,
    retries: usize,
}

/// The simulator.
pub struct Simulator {
    cfg: SimConfig,
    platform: Platform,
    exec: ExecModel,
    framework: Box<dyn Framework>,
}

impl Simulator {
    pub fn new(cfg: SimConfig) -> Self {
        let platform = Platform::get(cfg.platform_kind);
        Self {
            cfg,
            platform,
            exec: ExecModel::new(platform),
            framework: make_framework(cfg.framework, platform, cfg.pso),
        }
    }

    /// Run a trace to completion (bounded drain).
    pub fn run(&mut self, tasks: Vec<Task>, horizon: f64) -> SimResult {
        let paradigm = self.framework.paradigm();
        let n_engines = self.platform.engines;
        let mut energy = EnergyBook::new();
        let mut owner: Vec<Option<TaskId>> = vec![None; n_engines];
        let mut queue: Vec<TaskId> = Vec::new(); // dispatch FIFO
        let mut version: u64 = 0;

        let mut live: Vec<LiveTask> = tasks
            .into_iter()
            .map(|task| LiveTask {
                record: TaskRecord {
                    id: task.id,
                    model: task.model,
                    priority: task.priority,
                    arrival: task.arrival,
                    sched_seconds: 0.0,
                    started: None,
                    completed: None,
                    deadline: task.deadline,
                },
                exec_seconds: 0.0,
                engines: Vec::new(),
                state: RunState::Pending,
                retries: 0,
                task,
            })
            .collect();

        let mut events: BinaryHeap<Reverse<OrdEvent>> = live
            .iter()
            .map(|lt| {
                Reverse(OrdEvent(Event { time: lt.task.arrival, task: lt.task.id, kind: EventKind::Arrive }))
            })
            .collect();

        let drain_end = horizon * self.cfg.drain_factor;

        // Optional urgent-admission gate (see `SimConfig::admission_depth`):
        // arrivals are admitted into a real bounded router and popped onto
        // one serialized modeled controller, instead of every arrival
        // starting its scheduling episode instantly.
        let mut gate = self.cfg.admission_depth.map(|d| RequestRouter::new(d.max(1)));
        let mut sched_busy: Option<TaskId> = None;

        while let Some(Reverse(OrdEvent(ev))) = events.pop() {
            let now = ev.time;
            if now > drain_end {
                break;
            }
            match ev.kind {
                EventKind::Arrive => {
                    let is_urgent = live[ev.task].task.priority == Priority::Urgent;
                    if is_urgent {
                        if let Some(router) = gate.as_mut() {
                            let ticket = QueuedRequest::new(
                                ev.task as u64,
                                Priority::Urgent,
                                live[ev.task].record.deadline,
                                now,
                            );
                            match router.admit(ticket, now) {
                                Admission::Shed => live[ev.task].state = RunState::Dropped,
                                Admission::Admitted { evicted } => {
                                    if let Some(victim) = evicted {
                                        live[victim as usize].state = RunState::Dropped;
                                    }
                                }
                            }
                            // controller free → start the best admitted episode
                            while sched_busy.is_none() {
                                match router.pop(now) {
                                    None => break,
                                    Some(Popped::Shed(victim)) => {
                                        live[victim.id as usize].state = RunState::Dropped;
                                    }
                                    Some(Popped::Serve(next)) => {
                                        let tid = next.id as usize;
                                        sched_busy = Some(tid);
                                        self.begin_scheduling(tid, now, &mut live, &owner, &queue, &mut events, &mut energy);
                                    }
                                }
                            }
                        } else {
                            // interrupt: run the framework's matcher
                            self.begin_scheduling(ev.task, now, &mut live, &owner, &queue, &mut events, &mut energy);
                        }
                    } else {
                        queue.push(ev.task);
                        live[ev.task].state = RunState::Queued;
                        self.dispatch(paradigm, now, &mut live, &mut owner, &mut queue, &mut events, &mut version, &mut energy);
                    }
                }
                EventKind::SchedDone => {
                    if sched_busy == Some(ev.task) {
                        sched_busy = None;
                    }
                    self.on_sched_done(ev.task, now, paradigm, &mut live, &mut owner, &mut queue, &mut events, &mut version, &mut energy);
                    if let Some(router) = gate.as_mut() {
                        while sched_busy.is_none() {
                            match router.pop(now) {
                                None => break,
                                Some(Popped::Shed(victim)) => {
                                    live[victim.id as usize].state = RunState::Dropped;
                                }
                                Some(Popped::Serve(next)) => {
                                    let tid = next.id as usize;
                                    sched_busy = Some(tid);
                                    self.begin_scheduling(tid, now, &mut live, &owner, &queue, &mut events, &mut energy);
                                }
                            }
                        }
                    }
                }
                EventKind::Complete { version: v } => {
                    if let RunState::Running { version: cur, .. } = live[ev.task].state {
                        if cur != v {
                            continue; // stale completion
                        }
                    } else {
                        continue;
                    }
                    self.on_complete(ev.task, now, paradigm, &mut live, &mut owner, &mut queue, &mut events, &mut version, &mut energy);
                }
            }
        }

        // static energy over the whole activity window
        let last = live
            .iter()
            .filter_map(|lt| lt.record.completed)
            .fold(horizon, f64::max);
        energy.add_static(&self.exec.energy, n_engines, last);

        SimResult {
            records: live.into_iter().map(|lt| lt.record).collect(),
            energy,
            horizon,
            framework: self.cfg.framework,
        }
    }

    /// Preemptible engine set for an urgent request, via the §3.3
    /// policy: idle engines first, then max-slack Background victims,
    /// capped by the adaptive single-core preemption ratio (deadline
    /// pressure raises the cap).
    fn preemptible_set(
        &self,
        urgent_tid: TaskId,
        now: f64,
        live: &[LiveTask],
        owner: &[Option<TaskId>],
    ) -> Vec<usize> {
        let urgent = &live[urgent_tid];
        let policy = crate::scheduler::preempt::PreemptPolicy {
            base_ratio: self.cfg.preemption_ratio,
            ..Default::default()
        };
        let candidates: Vec<crate::scheduler::preempt::Candidate> = owner
            .iter()
            .enumerate()
            .filter_map(|(e, o)| match o {
                None => Some(crate::scheduler::preempt::Candidate {
                    engine: e,
                    owner_priority: None,
                    owner_slack: f64::INFINITY,
                }),
                Some(tid) if live[*tid].task.priority == Priority::Background => {
                    // slack proxy for deadline-free background work: time
                    // remaining on its current run (large remaining =
                    // cheapest to delay proportionally)
                    let slack = match live[*tid].state {
                        RunState::Running { ends, .. } => (ends - now).max(0.0),
                        _ => 0.0,
                    };
                    Some(crate::scheduler::preempt::Candidate {
                        engine: e,
                        owner_priority: Some(Priority::Background),
                        owner_slack: slack,
                    })
                }
                _ => None,
            })
            .collect();
        let est = self.exec.tss(&urgent.task, urgent.task.tiles.len().max(1));
        let deadline_slack = urgent
            .record
            .deadline
            .map(|d| ((d - now) / est.seconds.max(1e-12)).max(0.0))
            .unwrap_or(f64::INFINITY);
        let mut set = policy.select_victims(&candidates, self.platform.engines, deadline_slack);
        set.sort_unstable();
        set
    }

    #[allow(clippy::too_many_arguments)]
    fn begin_scheduling(
        &mut self,
        tid: TaskId,
        now: f64,
        live: &mut [LiveTask],
        owner: &[Option<TaskId>],
        queue: &[TaskId],
        events: &mut BinaryHeap<Reverse<OrdEvent>>,
        energy: &mut EnergyBook,
    ) {
        let preemptible = self.preemptible_set(tid, now, live, owner);
        let req = SchedRequest { task: &live[tid].task, now, preemptible, queue_len: queue.len() + 1 };
        let decision = self.framework.schedule_urgent(&req);
        energy.add_scheduling(decision.sched_joules);
        live[tid].record.sched_seconds += decision.sched_seconds;
        live[tid].state = RunState::Scheduling;
        live[tid].engines = decision.engines.clone();
        // stash feasibility in retries sentinel: engines empty = infeasible
        events.push(Reverse(OrdEvent(Event {
            time: now + decision.sched_seconds,
            task: tid,
            kind: EventKind::SchedDone,
        })));
    }

    #[allow(clippy::too_many_arguments)]
    fn on_sched_done(
        &mut self,
        tid: TaskId,
        now: f64,
        paradigm: Paradigm,
        live: &mut [LiveTask],
        owner: &mut [Option<TaskId>],
        queue: &mut Vec<TaskId>,
        events: &mut BinaryHeap<Reverse<OrdEvent>>,
        version: &mut u64,
        energy: &mut EnergyBook,
    ) {
        let feasible = !live[tid].engines.is_empty();
        if !feasible {
            // bounded retries when the platform frees up; drop past deadline
            let deadline = live[tid].record.deadline.unwrap_or(f64::INFINITY);
            if now > deadline || live[tid].retries >= 3 {
                live[tid].state = RunState::Dropped;
            } else {
                live[tid].retries += 1;
                // re-enter the scheduler shortly (poll when state changes
                // is approximated by a fixed back-off tied to exec scale)
                let backoff = 1e-4;
                events.push(Reverse(OrdEvent(Event {
                    time: now + backoff,
                    task: tid,
                    kind: EventKind::Arrive,
                })));
                live[tid].state = RunState::Pending;
            }
            return;
        }

        match paradigm {
            Paradigm::Lts => {
                // preempt whatever runs on the array
                let running: Vec<TaskId> = owner.iter().flatten().copied().collect();
                for victim in dedup(running) {
                    self.pause_task(victim, now, live, owner, energy, Paradigm::Lts);
                }
                for e in owner.iter_mut() {
                    *e = Some(tid);
                }
                let est = self.exec.lts(&live[tid].task);
                self.start_task(tid, now, est.seconds, est.joules, (0..owner.len()).collect(), live, events, version, energy);
            }
            Paradigm::Tss => {
                // Sanitize the claim against *current* ownership: the
                // framework's answer may be stale (engines claimed by a
                // later-arriving urgent task in the meantime).  Urgent
                // and Normal owners are never preempted; the claim is
                // re-filled from currently idle or Background-owned
                // engines, preserving the claimed partition size.
                let want = live[tid].engines.len();
                let mut engines: Vec<usize> = live[tid]
                    .engines
                    .iter()
                    .copied()
                    .filter(|&e| match owner[e] {
                        None => true,
                        Some(o) => live[o].task.priority == Priority::Background,
                    })
                    .collect();
                if engines.len() < want {
                    for e in 0..owner.len() {
                        if engines.len() >= want {
                            break;
                        }
                        if engines.contains(&e) {
                            continue;
                        }
                        let ok = match owner[e] {
                            None => true,
                            Some(o) => live[o].task.priority == Priority::Background,
                        };
                        if ok {
                            engines.push(e);
                        }
                    }
                }
                if engines.is_empty() {
                    // nothing reclaimable right now — treat as infeasible
                    live[tid].engines.clear();
                    live[tid].state = RunState::Pending;
                    let deadline = live[tid].record.deadline.unwrap_or(f64::INFINITY);
                    if now > deadline || live[tid].retries >= 3 {
                        live[tid].state = RunState::Dropped;
                    } else {
                        live[tid].retries += 1;
                        events.push(Reverse(OrdEvent(Event {
                            time: now + 1e-4,
                            task: tid,
                            kind: EventKind::Arrive,
                        })));
                    }
                    return;
                }
                live[tid].engines = engines.clone();
                // pause victims owning any claimed engine
                let mut victims: Vec<TaskId> = Vec::new();
                for &e in &engines {
                    if let Some(v) = owner[e] {
                        if v != tid {
                            victims.push(v);
                        }
                    }
                }
                for v in dedup(victims) {
                    self.pause_task(v, now, live, owner, energy, Paradigm::Tss);
                }
                for &e in &engines {
                    owner[e] = Some(tid);
                }
                let est = self.exec.tss(&live[tid].task, engines.len());
                self.start_task(tid, now, est.seconds, est.joules, engines, live, events, version, energy);
            }
        }
        let _ = queue;
    }

    #[allow(clippy::too_many_arguments)]
    fn start_task(
        &mut self,
        tid: TaskId,
        now: f64,
        seconds: f64,
        joules: f64,
        engines: Vec<usize>,
        live: &mut [LiveTask],
        events: &mut BinaryHeap<Reverse<OrdEvent>>,
        version: &mut u64,
        energy: &mut EnergyBook,
    ) {
        *version += 1;
        live[tid].exec_seconds = seconds;
        live[tid].engines = engines;
        live[tid].state = RunState::Running { ends: now + seconds, version: *version };
        if live[tid].record.started.is_none() {
            live[tid].record.started = Some(now);
        }
        // charge the full execution energy at start (volume-based model)
        energy.compute_j += joules;
        events.push(Reverse(OrdEvent(Event {
            time: now + seconds,
            task: tid,
            kind: EventKind::Complete { version: *version },
        })));
    }

    fn pause_task(
        &mut self,
        tid: TaskId,
        now: f64,
        live: &mut [LiveTask],
        owner: &mut [Option<TaskId>],
        energy: &mut EnergyBook,
        paradigm: Paradigm,
    ) {
        if let RunState::Running { ends, .. } = live[tid].state {
            let remaining = (ends - now).max(0.0);
            // preemption overhead: checkpoint cost added to remaining
            let ov = match paradigm {
                Paradigm::Lts => self.exec.lts_preempt_overhead(&live[tid].task),
                Paradigm::Tss => {
                    self.exec.tss_preempt_overhead(&live[tid].task, live[tid].engines.len())
                }
            };
            energy.dram_j += if paradigm == Paradigm::Lts { ov.joules } else { 0.0 };
            energy.noc_j += if paradigm == Paradigm::Tss { ov.joules } else { 0.0 };
            live[tid].state = RunState::Paused { remaining: remaining + ov.seconds };
            for e in owner.iter_mut() {
                if *e == Some(tid) {
                    *e = None;
                }
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn on_complete(
        &mut self,
        tid: TaskId,
        now: f64,
        paradigm: Paradigm,
        live: &mut [LiveTask],
        owner: &mut [Option<TaskId>],
        queue: &mut Vec<TaskId>,
        events: &mut BinaryHeap<Reverse<OrdEvent>>,
        version: &mut u64,
        energy: &mut EnergyBook,
    ) {
        live[tid].state = RunState::Done;
        live[tid].record.completed = Some(now);
        for e in owner.iter_mut() {
            if *e == Some(tid) {
                *e = None;
            }
        }
        // resume paused victims onto freed engines
        let paused: Vec<TaskId> = live
            .iter()
            .filter(|lt| matches!(lt.state, RunState::Paused { .. }))
            .map(|lt| lt.task.id)
            .collect();
        for v in paused {
            let want = live[v].engines.len().max(1);
            let free: Vec<usize> =
                (0..owner.len()).filter(|&e| owner[e].is_none()).take(want).collect();
            if free.len() >= want.min(owner.len()) && !free.is_empty() {
                if let RunState::Paused { remaining } = live[v].state {
                    for &e in &free {
                        owner[e] = Some(v);
                    }
                    // resume: no extra energy (already charged at start)
                    *version += 1;
                    live[v].engines = free;
                    live[v].state = RunState::Running { ends: now + remaining, version: *version };
                    events.push(Reverse(OrdEvent(Event {
                        time: now + remaining,
                        task: v,
                        kind: EventKind::Complete { version: *version },
                    })));
                }
            }
        }
        self.dispatch(paradigm, now, live, owner, queue, events, version, energy);
    }

    /// Dispatch queued (non-urgent) tasks onto free capacity.
    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        &mut self,
        paradigm: Paradigm,
        now: f64,
        live: &mut [LiveTask],
        owner: &mut [Option<TaskId>],
        queue: &mut Vec<TaskId>,
        events: &mut BinaryHeap<Reverse<OrdEvent>>,
        version: &mut u64,
        energy: &mut EnergyBook,
    ) {
        match paradigm {
            Paradigm::Lts => {
                // whole array, one task at a time; dispatch order follows
                // the framework's published policy (PREMA tokens, Planaria
                // laxity, MoCA contention, CD-MSA EDF)
                if owner.iter().any(|o| o.is_some()) || queue.is_empty() {
                    return;
                }
                let views: Vec<crate::scheduler::lts_policies::TaskView> = queue
                    .iter()
                    .map(|&tid| crate::scheduler::lts_policies::TaskView {
                        id: tid,
                        priority: live[tid].task.priority,
                        arrival: live[tid].task.arrival,
                        remaining: self.exec.lts(&live[tid].task).seconds,
                        deadline: live[tid].record.deadline,
                        dram_bytes: live[tid].task.weight_bytes + 2 * live[tid].task.act_bytes,
                    })
                    .collect();
                let Some(pick) = self.framework.pick_next(&views, now) else { return };
                let tid = queue.remove(pick);
                for e in owner.iter_mut() {
                    *e = Some(tid);
                }
                let est = self.exec.lts(&live[tid].task);
                self.start_task(tid, now, est.seconds, est.joules, (0..owner.len()).collect(), live, events, version, energy);
            }
            Paradigm::Tss => {
                let share = (owner.len() / self.cfg.background_streams.max(1)).max(1);
                while !queue.is_empty() {
                    let free: Vec<usize> =
                        (0..owner.len()).filter(|&e| owner[e].is_none()).collect();
                    if free.len() < share {
                        break;
                    }
                    let tid = queue.remove(0);
                    let engines: Vec<usize> = free.into_iter().take(share).collect();
                    for &e in &engines {
                        owner[e] = Some(tid);
                    }
                    let est = self.exec.tss(&live[tid].task, engines.len());
                    self.start_task(tid, now, est.seconds, est.joules, engines, live, events, version, energy);
                }
            }
        }
    }
}

fn dedup(mut v: Vec<TaskId>) -> Vec<TaskId> {
    v.sort_unstable();
    v.dedup();
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::trace::{build_trace, TraceConfig};
    use crate::workload::WorkloadClass;

    fn run_sim(framework: FrameworkKind, rate: f64, seed: u64) -> SimResult {
        let cfg = SimConfig { framework, ..Default::default() };
        let trace_cfg = TraceConfig {
            class: WorkloadClass::Simple,
            arrival_rate: rate,
            horizon: 0.05,
            seed,
            ..Default::default()
        };
        let platform = Platform::get(cfg.platform_kind);
        let tasks = build_trace(&trace_cfg, &platform);
        Simulator::new(cfg).run(tasks, trace_cfg.horizon)
    }

    #[test]
    fn conservation_no_task_lost_or_duplicated() {
        let res = run_sim(FrameworkKind::ImmSched, 40.0, 1);
        let mut ids: Vec<TaskId> = res.records.iter().map(|r| r.id).collect();
        let n = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "duplicate task records");
        // every record is either completed, or never-started (dropped/starved)
        for r in &res.records {
            if let (Some(s), Some(c)) = (r.started, r.completed) {
                assert!(c >= s, "task {} completed before start", r.id);
                assert!(s >= r.arrival, "task {} started before arrival", r.id);
            }
        }
    }

    #[test]
    fn immsched_completes_most_urgent_tasks() {
        let res = run_sim(FrameworkKind::ImmSched, 40.0, 2);
        let urgent: Vec<_> = res.urgent().collect();
        assert!(!urgent.is_empty());
        let met = urgent.iter().filter(|r| r.deadline_met()).count();
        assert!(
            met * 2 >= urgent.len(),
            "IMMSched met only {met}/{} deadlines",
            urgent.len()
        );
    }

    #[test]
    fn lts_baseline_misses_more_deadlines_than_immsched() {
        let imm = run_sim(FrameworkKind::ImmSched, 40.0, 3);
        let pla = run_sim(FrameworkKind::Planaria, 40.0, 3);
        let rate = |res: &SimResult| {
            let urgent: Vec<_> = res.urgent().collect();
            urgent.iter().filter(|r| r.deadline_met()).count() as f64 / urgent.len().max(1) as f64
        };
        assert!(
            rate(&imm) >= rate(&pla),
            "imm {} < planaria {}",
            rate(&imm),
            rate(&pla)
        );
    }

    #[test]
    fn energy_ledger_populated() {
        let res = run_sim(FrameworkKind::ImmSched, 20.0, 4);
        assert!(res.energy.total() > 0.0);
        assert!(res.energy.scheduling_j > 0.0, "scheduling energy uncharged");
    }

    /// The opt-in urgent-admission gate: scheduling episodes serialize
    /// onto one modeled controller and a bounded queue sheds overflow /
    /// expired arrivals *before* a scheduling episode is wasted.  Under
    /// a serial-matcher baseline at high λ the gate must actually bind.
    #[test]
    fn admission_gate_sheds_under_overload() {
        let run = || {
            let cfg = SimConfig {
                framework: FrameworkKind::Planaria,
                admission_depth: Some(1),
                ..Default::default()
            };
            let trace_cfg = TraceConfig {
                class: WorkloadClass::Simple,
                arrival_rate: 400.0,
                horizon: 0.05,
                seed: 21,
                ..Default::default()
            };
            let platform = Platform::get(cfg.platform_kind);
            let tasks = build_trace(&trace_cfg, &platform);
            Simulator::new(cfg).run(tasks, trace_cfg.horizon)
        };
        let res = run();
        let urgent: Vec<_> = res.urgent().collect();
        assert!(urgent.len() >= 5, "overload trace too small: {}", urgent.len());
        let never_started = urgent.iter().filter(|r| r.started.is_none()).count();
        assert!(never_started > 0, "depth-1 gate never shed under 400/s serial scheduling");
        // conservation: every record still accounted for exactly once
        let mut ids: Vec<TaskId> = res.records.iter().map(|r| r.id).collect();
        let n = ids.len();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n);
        // determinism with the gate enabled
        let again = run();
        assert_eq!(res.records.len(), again.records.len());
        for (x, y) in res.records.iter().zip(&again.records) {
            assert_eq!(x.started.is_some(), y.started.is_some());
            assert_eq!(x.completed.is_some(), y.completed.is_some());
        }
    }

    /// The gate leaves an uncontended interruptible framework essentially
    /// unaffected: IMMSched's µs-scale episodes rarely overlap, so the
    /// same trace still completes urgent work.
    #[test]
    fn admission_gate_keeps_immsched_serving() {
        let cfg = SimConfig {
            framework: FrameworkKind::ImmSched,
            admission_depth: Some(16),
            ..Default::default()
        };
        let trace_cfg = TraceConfig {
            class: WorkloadClass::Simple,
            arrival_rate: 40.0,
            horizon: 0.05,
            seed: 2,
            ..Default::default()
        };
        let platform = Platform::get(cfg.platform_kind);
        let tasks = build_trace(&trace_cfg, &platform);
        let res = Simulator::new(cfg).run(tasks, trace_cfg.horizon);
        let urgent: Vec<_> = res.urgent().collect();
        assert!(!urgent.is_empty());
        let completed = urgent.iter().filter(|r| r.completed.is_some()).count();
        assert!(completed * 2 >= urgent.len(), "gated IMMSched lost urgent work");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_sim(FrameworkKind::ImmSched, 30.0, 7);
        let b = run_sim(FrameworkKind::ImmSched, 30.0, 7);
        assert_eq!(a.records.len(), b.records.len());
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.completed.is_some(), y.completed.is_some());
            if let (Some(cx), Some(cy)) = (x.completed, y.completed) {
                assert!((cx - cy).abs() < 1e-12);
            }
        }
    }
}
