//! Task model: a DNN inference job with priority, arrival and deadline.

use std::collections::HashMap;
use std::sync::Mutex;

use once_cell::sync::Lazy;

use crate::workload::{build_model, tile_layer_graph, ModelId, TileDag, TilingConfig};

/// Memoized (model, tiling) → tile DAG + volume stats.  Traces create
/// hundreds of task instances per model; building + tiling an LLM layer
/// graph per instance would dominate the simulator's runtime.
static MODEL_CACHE: Lazy<Mutex<HashMap<(ModelId, usize, usize), CachedModel>>> =
    Lazy::new(|| Mutex::new(HashMap::new()));

#[derive(Clone)]
struct CachedModel {
    tiles: TileDag,
    macs: u64,
    act_bytes: u64,
    weight_bytes: u64,
    layers: usize,
}

fn cached_model(model: ModelId, tiling: TilingConfig) -> CachedModel {
    let key = (model, tiling.max_tiles, tiling.split_factor);
    let mut cache = MODEL_CACHE.lock().unwrap();
    cache
        .entry(key)
        .or_insert_with(|| {
            let graph = build_model(model);
            CachedModel {
                tiles: tile_layer_graph(&graph, tiling),
                macs: graph.total_macs(),
                act_bytes: graph.total_act_bytes(),
                weight_bytes: graph.total_weight_bytes(),
                layers: graph.len(),
            }
        })
        .clone()
}

/// Task identifier within one simulation.
pub type TaskId = usize;

/// Priority classes (paper §3.3: "running tasks are classified into
/// different priority levels according to their urgency").
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Steady-state periodic work — preemption victims.
    Background,
    /// Normal latency-sensitive work.
    Normal,
    /// Unpredictable urgent task with a hard deadline — the interrupt
    /// trigger.
    Urgent,
}

impl Priority {
    /// Stable lowercase name — the wire protocol's interchange form.
    pub fn name(self) -> &'static str {
        match self {
            Priority::Background => "background",
            Priority::Normal => "normal",
            Priority::Urgent => "urgent",
        }
    }

    /// Inverse of [`Self::name`] (`None` for unknown names).
    pub fn from_name(name: &str) -> Option<Self> {
        Some(match name {
            "background" => Priority::Background,
            "normal" => Priority::Normal,
            "urgent" => Priority::Urgent,
            _ => return None,
        })
    }
}

/// One DNN inference job.
#[derive(Clone, Debug)]
pub struct Task {
    pub id: TaskId,
    pub model: ModelId,
    pub priority: Priority,
    /// Arrival time (s).
    pub arrival: f64,
    /// Absolute deadline (s); urgent tasks always carry one.
    pub deadline: Option<f64>,
    /// Tile DAG (the matcher's query graph for urgent tasks).
    pub tiles: TileDag,
    /// Layer count of the original model graph (tiling granularity
    /// context for the NoC-traffic estimate).
    pub layers: usize,
    /// Total MAC work.
    pub macs: u64,
    /// Total activation traffic (bytes).
    pub act_bytes: u64,
    /// Total weight bytes (DRAM-resident for LTS).
    pub weight_bytes: u64,
}

impl Task {
    /// Build a task for `model` with the given tiling.
    pub fn new(
        id: TaskId,
        model: ModelId,
        priority: Priority,
        arrival: f64,
        tiling: TilingConfig,
    ) -> Self {
        let cached = cached_model(model, tiling);
        Self {
            id,
            model,
            priority,
            arrival,
            deadline: None,
            macs: cached.macs,
            act_bytes: cached.act_bytes,
            weight_bytes: cached.weight_bytes,
            layers: cached.layers,
            tiles: cached.tiles,
        }
    }

    pub fn with_deadline(mut self, deadline: f64) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Scale the job to a `batch` of inferences (weights shared, compute
    /// and activations scale).  Keeps simulated task durations in a
    /// realistic regime on the very fast modeled platforms.
    pub fn with_batch(mut self, batch: usize) -> Self {
        self.macs *= batch as u64;
        self.act_bytes *= batch as u64;
        self
    }

    pub fn is_urgent(&self) -> bool {
        self.priority == Priority::Urgent
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_ordering_matches_urgency() {
        assert!(Priority::Urgent > Priority::Normal);
        assert!(Priority::Normal > Priority::Background);
    }

    #[test]
    fn task_carries_workload_volumes() {
        let t = Task::new(0, ModelId::MobileNetV2, Priority::Normal, 0.0, TilingConfig::default());
        assert!(t.macs > 100_000_000);
        assert!(t.tiles.len() >= 2);
        assert!(t.deadline.is_none());
        let t = t.with_deadline(1.5);
        assert_eq!(t.deadline, Some(1.5));
    }
}
