//! The four LTS baselines' *dispatch policies* — the published
//! algorithms, not just cost constants.
//!
//! The simulator uses these to order the LTS run queue (which task gets
//! the array next); the CPU-side *scheduling latency* of re-running each
//! policy on an urgent arrival is modeled in `frameworks.rs`.
//!
//! * **PREMA** (Choi & Rhu, HPCA'20): token-based preemption — every
//!   waiting task accrues tokens ∝ wait × priority; highest tokens wins;
//!   a task whose tokens exceed the running task's by the preemption
//!   threshold may preempt at a layer boundary.
//! * **Planaria** (Ghodrati et al., MICRO'20): deadline-pressure-ordered
//!   admission with spatial fission — the array splits into subarrays
//!   sized by each admitted task's compute share.
//! * **MoCA** (Kim et al., HPCA'23): memory-centric — tasks are ordered
//!   to minimize aggregate DRAM-bandwidth contention; the most
//!   memory-starved admitted task gets priority.
//! * **CD-MSA** (Wang et al., TPDS'23): cooperative deadline-aware —
//!   earliest-deadline-first with a cooperation bonus for tasks that
//!   underuse their reservation.

use super::task::Priority;
use crate::util::ord::{nan_greatest_cmp, nan_least_cmp};

/// What a policy sees about one queued/running task.
#[derive(Clone, Copy, Debug)]
pub struct TaskView {
    pub id: usize,
    pub priority: Priority,
    pub arrival: f64,
    /// Estimated remaining execution time on the full array (s).
    pub remaining: f64,
    /// Absolute deadline if any.
    pub deadline: Option<f64>,
    /// DRAM traffic volume of the task (bytes) — MoCA's contention input.
    pub dram_bytes: u64,
}

fn priority_weight(p: Priority) -> f64 {
    match p {
        Priority::Urgent => 8.0,
        Priority::Normal => 2.0,
        Priority::Background => 1.0,
    }
}

// ---------------------------------------------------------------------------
// PREMA
// ---------------------------------------------------------------------------

/// PREMA token state.
#[derive(Clone, Copy, Debug)]
pub struct PremaParams {
    /// Tokens needed to preempt the running task.
    pub preempt_threshold: f64,
}

impl Default for PremaParams {
    fn default() -> Self {
        Self { preempt_threshold: 4.0 }
    }
}

/// Tokens of a task at time `now` (PREMA Eq. 1-style: wait × weight).
pub fn prema_tokens(view: &TaskView, now: f64) -> f64 {
    (now - view.arrival).max(0.0) * priority_weight(view.priority)
}

/// Pick the queued task with the most tokens (ties: earliest arrival).
/// NaN-keyed tasks (poisoned arrival) never win the pick.
pub fn prema_pick(queue: &[TaskView], now: f64) -> Option<usize> {
    queue
        .iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| {
            nan_least_cmp(prema_tokens(a, now), prema_tokens(b, now))
                .then(nan_greatest_cmp(b.arrival, a.arrival))
        })
        .map(|(i, _)| i)
}

/// Should `candidate` preempt `running` under PREMA's token rule?
pub fn prema_should_preempt(
    params: &PremaParams,
    candidate: &TaskView,
    running: &TaskView,
    now: f64,
) -> bool {
    prema_tokens(candidate, now) >= prema_tokens(running, now) + params.preempt_threshold
}

// ---------------------------------------------------------------------------
// Planaria
// ---------------------------------------------------------------------------

/// Planaria's admission score: deadline pressure (laxity⁻¹) — tasks
/// closest to violating their deadline get the array (or the largest
/// fission share) first.
pub fn planaria_score(view: &TaskView, now: f64) -> f64 {
    match view.deadline {
        Some(d) => {
            let laxity = (d - now - view.remaining).max(1e-9);
            1.0 / laxity
        }
        None => 1e-6 * priority_weight(view.priority), // best-effort tail
    }
}

pub fn planaria_pick(queue: &[TaskView], now: f64) -> Option<usize> {
    queue
        .iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| nan_least_cmp(planaria_score(a, now), planaria_score(b, now)))
        .map(|(i, _)| i)
}

/// Fission: split `total_subarrays` among admitted tasks ∝ remaining
/// compute (each admitted task gets ≥ 1 subarray).
pub fn planaria_fission(admitted: &[TaskView], total_subarrays: usize) -> Vec<usize> {
    if admitted.is_empty() {
        return Vec::new();
    }
    let total_work: f64 = admitted.iter().map(|t| t.remaining.max(1e-12)).sum();
    let mut shares: Vec<usize> = admitted
        .iter()
        .map(|t| ((t.remaining.max(1e-12) / total_work) * total_subarrays as f64).floor() as usize)
        .map(|s| s.max(1))
        .collect();
    // trim overshoot from the largest shares
    while shares.iter().sum::<usize>() > total_subarrays {
        let i = shares
            .iter()
            .enumerate()
            .max_by_key(|(_, &s)| s)
            .map(|(i, _)| i)
            .unwrap();
        if shares[i] > 1 {
            shares[i] -= 1;
        } else {
            break;
        }
    }
    shares
}

// ---------------------------------------------------------------------------
// MoCA
// ---------------------------------------------------------------------------

/// MoCA's contention-aware pick: among queued tasks, prefer the one
/// whose DRAM demand best fits the remaining bandwidth budget of the
/// current epoch (most memory-starved among fitting; else the smallest).
pub fn moca_pick(queue: &[TaskView], bandwidth_budget_bytes: u64) -> Option<usize> {
    if queue.is_empty() {
        return None;
    }
    let fitting: Vec<(usize, &TaskView)> = queue
        .iter()
        .enumerate()
        .filter(|(_, t)| t.dram_bytes <= bandwidth_budget_bytes)
        .collect();
    if let Some((i, _)) = fitting
        .iter()
        .max_by(|(_, a), (_, b)| {
            priority_weight(a.priority)
                .total_cmp(&priority_weight(b.priority))
                .then(a.dram_bytes.cmp(&b.dram_bytes))
        })
        .copied()
    {
        return Some(i);
    }
    // nothing fits: take the smallest demand (MoCA throttles it)
    queue
        .iter()
        .enumerate()
        .min_by_key(|(_, t)| t.dram_bytes)
        .map(|(i, _)| i)
}

// ---------------------------------------------------------------------------
// CD-MSA
// ---------------------------------------------------------------------------

/// CD-MSA: earliest-deadline-first with a cooperation bonus.
/// `coop_credit[i]` ∈ [0, 1] is how much of its reservation task i has
/// historically ceded; higher credit breaks deadline ties first.
/// A NaN deadline, credit or arrival demotes the task, never wedges the
/// queue (feasibility itself is [`cdmsa_admissible`], which the
/// simulator consults separately).
pub fn cdmsa_pick(queue: &[TaskView], coop_credit: &[f64], _now: f64) -> Option<usize> {
    assert_eq!(queue.len(), coop_credit.len());
    queue
        .iter()
        .enumerate()
        .min_by(|(i, a), (j, b)| {
            let da = a.deadline.unwrap_or(f64::INFINITY);
            let db = b.deadline.unwrap_or(f64::INFINITY);
            nan_greatest_cmp(da, db)
                .then(nan_least_cmp(coop_credit[*j], coop_credit[*i]))
                .then(nan_greatest_cmp(a.arrival, b.arrival))
        })
        .map(|(i, _)| i)
}

/// CD-MSA admission: would starting `view` now still meet its deadline?
pub fn cdmsa_admissible(view: &TaskView, now: f64) -> bool {
    view.deadline.map_or(true, |d| now + view.remaining <= d)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(id: usize, priority: Priority, arrival: f64) -> TaskView {
        TaskView { id, priority, arrival, remaining: 0.01, deadline: None, dram_bytes: 1 << 20 }
    }

    #[test]
    fn prema_tokens_accrue_with_wait_and_weight() {
        let bg = view(0, Priority::Background, 0.0);
        let urgent = view(1, Priority::Urgent, 0.5);
        // at t=1: bg waited 1.0 (tokens 1), urgent waited 0.5 (tokens 4)
        assert!(prema_tokens(&urgent, 1.0) > prema_tokens(&bg, 1.0));
        let q = [bg, urgent];
        assert_eq!(prema_pick(&q, 1.0), Some(1));
        // long-starved background eventually wins (no starvation)
        let q = [view(0, Priority::Background, 0.0), view(1, Priority::Urgent, 9.9)];
        assert_eq!(prema_pick(&q, 10.0), Some(0));
    }

    #[test]
    fn prema_preemption_needs_threshold() {
        let p = PremaParams::default();
        let running = view(0, Priority::Background, 0.0);
        let mut cand = view(1, Priority::Urgent, 1.0);
        assert!(!prema_should_preempt(&p, &cand, &running, 1.1)); // 0.8 < 1.1+4
        cand.arrival = 0.0;
        assert!(prema_should_preempt(&p, &cand, &running, 1.0)); // 8 >= 1+4
    }

    #[test]
    fn planaria_prefers_tightest_laxity() {
        let mut a = view(0, Priority::Normal, 0.0);
        a.deadline = Some(1.0);
        a.remaining = 0.5;
        let mut b = view(1, Priority::Normal, 0.0);
        b.deadline = Some(2.0);
        b.remaining = 0.5;
        assert_eq!(planaria_pick(&[a, b], 0.0), Some(0));
        // laxity shrinks as time passes; still task 0
        assert_eq!(planaria_pick(&[a, b], 0.4), Some(0));
    }

    #[test]
    fn planaria_fission_shares_sum_and_floor() {
        let mut a = view(0, Priority::Normal, 0.0);
        a.remaining = 0.9;
        let mut b = view(1, Priority::Normal, 0.0);
        b.remaining = 0.1;
        let shares = planaria_fission(&[a, b], 16);
        assert_eq!(shares.len(), 2);
        assert!(shares.iter().sum::<usize>() <= 16);
        assert!(shares[0] > shares[1]);
        assert!(shares[1] >= 1);
    }

    #[test]
    fn moca_picks_fitting_then_smallest() {
        let mut small = view(0, Priority::Background, 0.0);
        small.dram_bytes = 1 << 20;
        let mut big = view(1, Priority::Background, 0.0);
        big.dram_bytes = 1 << 30;
        // both fit: higher-priority/bigger-demand tie-break
        let q = [small, big];
        assert!(moca_pick(&q, 2 << 30).is_some());
        // only small fits
        assert_eq!(moca_pick(&q, 2 << 20), Some(0));
        // nothing fits: smallest demand picked for throttling
        assert_eq!(moca_pick(&q, 1 << 10), Some(0));
    }

    #[test]
    fn cdmsa_edf_with_coop_tiebreak() {
        let mut a = view(0, Priority::Normal, 0.0);
        a.deadline = Some(5.0);
        let mut b = view(1, Priority::Normal, 0.1);
        b.deadline = Some(3.0);
        let mut c = view(2, Priority::Normal, 0.2);
        c.deadline = Some(3.0);
        // b and c tie on deadline; c has more cooperation credit
        let pick = cdmsa_pick(&[a, b, c], &[0.0, 0.2, 0.9], 1.0);
        assert_eq!(pick, Some(2));
    }

    #[test]
    fn prema_nan_arrival_never_wins_and_never_panics() {
        // regression: the old comparator was partial_cmp(..).unwrap(),
        // which aborted the whole episode on one NaN-keyed task
        let fresh = view(0, Priority::Normal, 2.0);
        let poisoned = view(1, Priority::Normal, f64::NAN);
        // NaN arrival → NaN wait, but f64::max(NaN-now, 0.0) is 0.0, so
        // tokens tie at 0 and the arrival tiebreak must demote the NaN
        assert_eq!(prema_pick(&[fresh, poisoned], 2.0), Some(0));
        assert_eq!(prema_pick(&[poisoned, fresh], 2.0), Some(1));
        // all-NaN queue still returns *something* deterministically
        assert!(prema_pick(&[poisoned, poisoned], 2.0).is_some());
    }

    #[test]
    fn planaria_nan_inputs_cannot_panic_the_pick() {
        // a NaN remaining (or deadline) is absorbed by the laxity floor —
        // `(..).max(1e-9)` ignores NaN — so the score stays finite; and
        // the comparator is nan_least_cmp rather than
        // partial_cmp(..).unwrap(), so even a genuinely NaN score
        // (poisoned best-effort weight) demotes instead of aborting
        let mut poisoned = view(0, Priority::Normal, 0.0);
        poisoned.deadline = Some(1.0);
        poisoned.remaining = f64::NAN;
        assert!(planaria_score(&poisoned, 0.0).is_finite());
        let sane = view(1, Priority::Normal, 0.0);
        assert!(planaria_pick(&[poisoned, sane], 0.0).is_some());
    }

    #[test]
    fn cdmsa_nan_keys_demote_instead_of_panicking() {
        // NaN deadline loses to any real deadline
        let mut nan_dl = view(0, Priority::Normal, 0.0);
        nan_dl.deadline = Some(f64::NAN);
        let mut real_dl = view(1, Priority::Normal, 0.0);
        real_dl.deadline = Some(3.0);
        assert_eq!(cdmsa_pick(&[nan_dl, real_dl], &[0.5, 0.5], 1.0), Some(1));
        // NaN cooperation credit loses the tiebreak
        let mut a = view(0, Priority::Normal, 0.0);
        a.deadline = Some(3.0);
        let mut b = view(1, Priority::Normal, 0.0);
        b.deadline = Some(3.0);
        assert_eq!(cdmsa_pick(&[a, b], &[f64::NAN, 0.1], 1.0), Some(1));
        assert_eq!(cdmsa_pick(&[a, b], &[0.1, f64::NAN], 1.0), Some(0));
    }

    #[test]
    fn cdmsa_admission_checks_feasibility() {
        let mut t = view(0, Priority::Normal, 0.0);
        t.deadline = Some(1.0);
        t.remaining = 0.5;
        assert!(cdmsa_admissible(&t, 0.4));
        assert!(!cdmsa_admissible(&t, 0.6));
    }
}
