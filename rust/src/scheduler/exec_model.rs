//! Execution-cost model: what a task costs under LTS vs TSS.
//!
//! The structural difference (paper Fig. 3):
//! * **LTS** runs one task at a time on the whole array, layer by layer;
//!   every inter-layer activation round-trips through DRAM and weights
//!   stream from DRAM — energy pays [`EnergyModel::dram_byte`] per byte
//!   and time pays the DRAM bandwidth wall.
//! * **TSS** cascades layers across an engine partition; inter-layer
//!   activations move over the NoC (0.64 pJ/bit/hop) and stay on-chip;
//!   weights load once into engine SRAM.

use crate::accel::{EnergyModel, Platform};

use super::task::Task;

/// Scheduling paradigm (paper Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Paradigm {
    Lts,
    Tss,
}

/// Estimated execution time + energy for one task instance.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecEstimate {
    pub seconds: f64,
    pub joules: f64,
    /// Bytes that hit DRAM (LTS checkpoint/restore also adds here).
    pub dram_bytes: u64,
    /// Bytes that crossed the NoC.
    pub noc_bytes: u64,
}

/// Execution model bound to a platform.
#[derive(Clone, Copy, Debug)]
pub struct ExecModel {
    pub platform: Platform,
    pub energy: EnergyModel,
    /// DRAM bandwidth (bytes/s) — LPDDR4-class edge memory.
    pub dram_bw: f64,
    /// Array utilization for dense layers.
    pub utilization: f64,
}

impl ExecModel {
    pub fn new(platform: Platform) -> Self {
        Self {
            platform,
            energy: EnergyModel::default(),
            dram_bw: 25.6e9,
            utilization: 0.6,
        }
    }

    /// Effective MACs/s on `k` engines.
    pub fn rate(&self, k: usize) -> f64 {
        self.platform.engine_macs() as f64 * k as f64 * self.platform.clock_hz * self.utilization
    }

    /// LTS estimate: whole array, DRAM-coupled layers.
    ///
    /// Time = max(compute, DRAM streaming) — the array stalls on
    /// whichever is slower; energy pays DRAM for weights + 2× activations
    /// (write + read back between layers).
    pub fn lts(&self, task: &Task) -> ExecEstimate {
        let compute_s = task.macs as f64 / self.rate(self.platform.engines);
        let dram_bytes = task.weight_bytes + 2 * task.act_bytes;
        let dram_s = dram_bytes as f64 / self.dram_bw;
        let seconds = compute_s.max(dram_s) + compute_s.min(dram_s) * 0.2; // imperfect overlap
        let joules = task.macs as f64 * self.energy.mac_int8
            + dram_bytes as f64 * self.energy.dram_byte
            + self.energy.static_energy(self.platform.engines, seconds);
        ExecEstimate { seconds, joules, dram_bytes, noc_bytes: 0 }
    }

    /// TSS estimate on a `k`-engine partition: cascaded tiles, NoC-coupled.
    ///
    /// Only *segment-boundary* activations cross the NoC (intra-segment
    /// layers are fused on one engine — that is the whole point of Layer
    /// Concatenate-and-Split); weights stream from DRAM once.
    pub fn tss(&self, task: &Task, k: usize) -> ExecEstimate {
        let k = k.max(1);
        let compute_s = task.macs as f64 / self.rate(k);
        // pipeline fill: one segment depth of latency
        let fill_s = compute_s / task.tiles.num_segments.max(1) as f64;
        // fraction of layer boundaries that are segment boundaries
        let boundary_frac =
            (task.tiles.num_segments as f64 / task.layers.max(1) as f64).min(1.0);
        let noc_bytes = (task.act_bytes as f64 * boundary_frac) as u64;
        let hops = 1.5;
        let noc_s = noc_bytes as f64 * 8.0 / (crate::accel::noc::LINK_BITS * self.platform.clock_hz)
            / k as f64; // links in parallel across the cascade
        let dram_bytes = task.weight_bytes; // weights loaded once
        let dram_s = dram_bytes as f64 / self.dram_bw;
        let seconds = compute_s.max(noc_s).max(dram_s) + fill_s;
        let joules = task.macs as f64 * self.energy.mac_int8
            + noc_bytes as f64 * 8.0 * hops * self.energy.noc_bit_hop
            + task.act_bytes as f64 * self.energy.sram_byte * 2.0
            + dram_bytes as f64 * self.energy.dram_byte
            + self.energy.static_energy(k, seconds);
        ExecEstimate { seconds, joules, dram_bytes, noc_bytes }
    }

    /// LTS preemption overhead: checkpoint the running layer's
    /// activations to DRAM and restore them later.
    pub fn lts_preempt_overhead(&self, victim: &Task) -> ExecEstimate {
        // one layer's activations ≈ act_bytes / layers; round-trip ×2
        let per_layer = victim.act_bytes / victim.tiles.len().max(1) as u64;
        let bytes = per_layer * 2;
        let seconds = bytes as f64 / self.dram_bw;
        ExecEstimate {
            seconds,
            joules: bytes as f64 * self.energy.dram_byte,
            dram_bytes: bytes,
            noc_bytes: 0,
        }
    }

    /// TSS preemption overhead: drain in-flight tiles of the victim
    /// partition into engine SRAM (no DRAM round-trip).
    pub fn tss_preempt_overhead(&self, victim: &Task, k: usize) -> ExecEstimate {
        let per_tile = victim.act_bytes / victim.tiles.len().max(1) as u64;
        let bytes = per_tile * k.max(1) as u64 / 4;
        let seconds = bytes as f64 * 8.0 / (crate::accel::noc::LINK_BITS * self.platform.clock_hz);
        ExecEstimate {
            seconds,
            joules: bytes as f64 * self.energy.sram_byte,
            dram_bytes: 0,
            noc_bytes: bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::task::Priority;
    use crate::workload::{ModelId, TilingConfig};

    fn task(model: ModelId) -> Task {
        Task::new(0, model, Priority::Normal, 0.0, TilingConfig::default())
    }

    #[test]
    fn tss_beats_lts_on_energy() {
        let m = ExecModel::new(Platform::edge());
        // activation-heavy CNN: DRAM round-trips dominate LTS — big gap
        let t = task(ModelId::ResNet50);
        let (lts, tss) = (m.lts(&t), m.tss(&t, m.platform.engines / 2));
        assert!(lts.joules > 1.5 * tss.joules, "resnet lts {} vs tss {}", lts.joules, tss.joules);
        // weight-dominated LLM: weights hit DRAM either way, but TSS
        // still wins on the activation traffic
        let t = task(ModelId::Qwen7B);
        let (lts, tss) = (m.lts(&t), m.tss(&t, m.platform.engines / 2));
        assert!(lts.joules > tss.joules, "qwen lts {} vs tss {}", lts.joules, tss.joules);
    }

    #[test]
    fn more_engines_run_faster() {
        let m = ExecModel::new(Platform::edge());
        let t = task(ModelId::ResNet50);
        assert!(m.tss(&t, 32).seconds < m.tss(&t, 8).seconds);
    }

    #[test]
    fn lts_preempt_costs_dram() {
        let m = ExecModel::new(Platform::edge());
        let t = task(ModelId::UNet);
        let lts_ov = m.lts_preempt_overhead(&t);
        let tss_ov = m.tss_preempt_overhead(&t, 16);
        assert!(lts_ov.dram_bytes > 0);
        assert_eq!(tss_ov.dram_bytes, 0);
        assert!(lts_ov.joules > tss_ov.joules);
    }

    #[test]
    fn llm_is_dram_bound_under_lts() {
        let m = ExecModel::new(Platform::edge());
        let t = task(ModelId::Llama3_8B);
        let est = m.lts(&t);
        let compute_s = t.macs as f64 / m.rate(m.platform.engines);
        assert!(est.seconds > compute_s, "LLM LTS must be memory-bound");
    }
}
