//! Evaluation metrics (paper §4.1.4): Speedup, Latency-Bound Throughput
//! and Energy efficiency.

use crate::util::stats::geomean;

use super::sim::SimResult;
use super::task::Priority;

/// Aggregate metrics of one simulation run.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimSummary {
    /// Mean total latency of completed urgent tasks (s).
    pub urgent_latency: f64,
    /// Mean scheduling latency of urgent tasks (s).
    pub sched_latency: f64,
    /// Urgent deadline hit rate in [0,1].
    pub deadline_rate: f64,
    /// Completed tasks (all priorities).
    pub completed: usize,
    /// Total energy (J).
    pub energy_j: f64,
    /// Throughput: completed tasks per second of horizon.
    pub throughput: f64,
    /// Energy efficiency: completed tasks per joule.
    pub tasks_per_joule: f64,
}

/// Summarize a run.
pub fn summarize(res: &SimResult) -> SimSummary {
    let urgent: Vec<_> = res
        .records
        .iter()
        .filter(|r| r.priority == Priority::Urgent)
        .collect();
    let completed_urgent: Vec<f64> =
        urgent.iter().filter_map(|r| r.total_latency()).collect();
    let urgent_latency = if completed_urgent.is_empty() {
        f64::INFINITY
    } else {
        completed_urgent.iter().sum::<f64>() / completed_urgent.len() as f64
    };
    let sched_latency = if urgent.is_empty() {
        0.0
    } else {
        urgent.iter().map(|r| r.sched_seconds).sum::<f64>() / urgent.len() as f64
    };
    let deadline_rate = if urgent.is_empty() {
        1.0
    } else {
        urgent.iter().filter(|r| r.deadline_met()).count() as f64 / urgent.len() as f64
    };
    let completed = res.completed_count();
    let energy_j = res.energy.total();
    let throughput = completed as f64 / res.horizon.max(1e-12);
    SimSummary {
        urgent_latency,
        sched_latency,
        deadline_rate,
        completed,
        energy_j,
        throughput,
        tasks_per_joule: completed as f64 / energy_j.max(1e-18),
    }
}

/// A named collection of per-(platform, class) metric values, aggregated
/// with the geometric mean the way the paper reports cross-workload
/// averages.
#[derive(Clone, Debug, Default)]
pub struct MetricSet {
    values: Vec<f64>,
}

impl MetricSet {
    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn geomean(&self) -> f64 {
        geomean(&self.values)
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            f64::NAN
        } else {
            self.values.iter().sum::<f64>() / self.values.len() as f64
        }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

/// Latency-Bound Throughput: the highest Poisson rate λ at which the
/// urgent deadline hit rate stays ≥ `target_rate` (paper: "the maximum
/// queries-per-second achieved by the system under a Poisson arrival
/// rate").  `run` executes a simulation at a given λ and returns the
/// deadline hit rate; the sweep doubles λ until failure then bisects.
pub fn lbt_sweep(mut run: impl FnMut(f64) -> f64, target_rate: f64, lambda0: f64) -> f64 {
    let mut lo = 0.0;
    let mut hi = lambda0.max(1.0);
    // find an upper bracket
    let mut tries = 0;
    while run(hi) >= target_rate {
        lo = hi;
        hi *= 2.0;
        tries += 1;
        if tries > 16 {
            return hi; // system never saturates in range — report the cap
        }
    }
    if lo == 0.0 {
        // even lambda0 fails; bisect downward from lambda0
        lo = 0.0;
    }
    for _ in 0..12 {
        let mid = 0.5 * (lo + hi);
        if run(mid) >= target_rate {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::EnergyBook;
    use crate::scheduler::sim::TaskRecord;
    use crate::scheduler::FrameworkKind;
    use crate::workload::ModelId;

    fn record(priority: Priority, arrival: f64, completed: Option<f64>, deadline: Option<f64>) -> TaskRecord {
        TaskRecord {
            id: 0,
            model: ModelId::MobileNetV2,
            priority,
            arrival,
            sched_seconds: 0.001,
            started: completed.map(|c| c - 0.01),
            completed,
            deadline,
        }
    }

    fn result(records: Vec<TaskRecord>) -> SimResult {
        let mut energy = EnergyBook::new();
        energy.compute_j = 2.0;
        SimResult { records, energy, horizon: 1.0, framework: FrameworkKind::ImmSched }
    }

    #[test]
    fn summary_computes_rates() {
        let res = result(vec![
            record(Priority::Urgent, 0.0, Some(0.1), Some(0.2)),  // met
            record(Priority::Urgent, 0.0, Some(0.5), Some(0.2)),  // missed
            record(Priority::Background, 0.0, Some(0.3), None),
        ]);
        let s = summarize(&res);
        assert!((s.deadline_rate - 0.5).abs() < 1e-12);
        assert_eq!(s.completed, 3);
        assert!((s.throughput - 3.0).abs() < 1e-12);
        assert!((s.urgent_latency - 0.3).abs() < 1e-12);
        assert!((s.tasks_per_joule - 1.5).abs() < 1e-12);
    }

    #[test]
    fn lbt_finds_threshold_of_synthetic_system() {
        // synthetic system: meets deadlines iff λ <= 100
        let lbt = lbt_sweep(|l| if l <= 100.0 { 1.0 } else { 0.0 }, 0.9, 10.0);
        assert!((lbt - 100.0).abs() < 2.0, "lbt {lbt}");
    }

    #[test]
    fn lbt_caps_when_never_saturating() {
        let lbt = lbt_sweep(|_| 1.0, 0.9, 10.0);
        assert!(lbt > 1e5);
    }

    #[test]
    fn metric_set_geomean() {
        let mut m = MetricSet::default();
        m.push(1.0);
        m.push(100.0);
        assert!((m.geomean() - 10.0).abs() < 1e-9);
    }
}
