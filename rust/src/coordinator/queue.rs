//! Request router + priority queue for the coordinator front-end.
//!
//! The interrupt service loop (event_loop.rs) serializes matching onto
//! the controller thread; this module is the admission stage in front of
//! it: requests are classified, deadline-tagged, queued by (priority,
//! deadline) and expired requests are shed *before* they waste a
//! matching episode — the L3 backpressure mechanism.

use std::collections::BinaryHeap;

use crate::scheduler::Priority;

/// A queued interrupt request (payload-agnostic: the router orders ids).
#[derive(Clone, Debug, PartialEq)]
pub struct QueuedRequest {
    pub id: u64,
    pub priority: Priority,
    /// Absolute deadline (s since epoch start); None = best-effort.
    pub deadline: Option<f64>,
    /// Enqueue time.
    pub enqueued_at: f64,
}

impl Eq for QueuedRequest {}

impl PartialOrd for QueuedRequest {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueuedRequest {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // max-heap: higher priority first, then earlier deadline, then FIFO
        self.priority
            .cmp(&other.priority)
            .then_with(|| {
                let da = self.deadline.unwrap_or(f64::INFINITY);
                let db = other.deadline.unwrap_or(f64::INFINITY);
                db.partial_cmp(&da).unwrap() // earlier deadline = greater
            })
            .then_with(|| other.enqueued_at.partial_cmp(&self.enqueued_at).unwrap())
    }
}

/// Router statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RouterStats {
    pub admitted: u64,
    pub shed_expired: u64,
    pub shed_capacity: u64,
    pub served: u64,
}

/// Bounded priority router.
#[derive(Debug)]
pub struct RequestRouter {
    heap: BinaryHeap<QueuedRequest>,
    capacity: usize,
    stats: RouterStats,
}

impl RequestRouter {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self { heap: BinaryHeap::new(), capacity, stats: RouterStats::default() }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn stats(&self) -> RouterStats {
        self.stats
    }

    /// Admit a request.  Returns `false` if shed (expired on arrival or
    /// queue full of higher-priority work).
    pub fn admit(&mut self, req: QueuedRequest, now: f64) -> bool {
        if req.deadline.is_some_and(|d| d <= now) {
            self.stats.shed_expired += 1;
            return false;
        }
        if self.heap.len() >= self.capacity {
            // shed the *worst* queued request if the newcomer beats it;
            // otherwise shed the newcomer (bounded queue, no livelock)
            let worst_is_better = self.heap.iter().min().map_or(false, |w| *w >= req);
            if worst_is_better {
                self.stats.shed_capacity += 1;
                return false;
            }
            // rebuild without the single worst element
            let mut all: Vec<QueuedRequest> = std::mem::take(&mut self.heap).into_vec();
            if let Some(pos) = all
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| a.cmp(b))
                .map(|(i, _)| i)
            {
                all.swap_remove(pos);
                self.stats.shed_capacity += 1;
            }
            self.heap = all.into();
        }
        self.stats.admitted += 1;
        self.heap.push(req);
        true
    }

    /// Pop the next request to serve, shedding anything already expired.
    pub fn next(&mut self, now: f64) -> Option<QueuedRequest> {
        while let Some(req) = self.heap.pop() {
            if req.deadline.is_some_and(|d| d <= now) {
                self.stats.shed_expired += 1;
                continue;
            }
            self.stats.served += 1;
            return Some(req);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, priority: Priority, deadline: Option<f64>, t: f64) -> QueuedRequest {
        QueuedRequest { id, priority, deadline, enqueued_at: t }
    }

    #[test]
    fn priority_then_deadline_then_fifo() {
        let mut r = RequestRouter::new(16);
        r.admit(req(1, Priority::Background, None, 0.0), 0.0);
        r.admit(req(2, Priority::Urgent, Some(5.0), 0.1), 0.1);
        r.admit(req(3, Priority::Urgent, Some(2.0), 0.2), 0.2);
        r.admit(req(4, Priority::Normal, None, 0.3), 0.3);
        assert_eq!(r.next(0.5).unwrap().id, 3, "earliest-deadline urgent first");
        assert_eq!(r.next(0.5).unwrap().id, 2);
        assert_eq!(r.next(0.5).unwrap().id, 4, "normal before background");
        assert_eq!(r.next(0.5).unwrap().id, 1);
        assert!(r.next(0.5).is_none());
    }

    #[test]
    fn expired_requests_shed_on_admit_and_pop() {
        let mut r = RequestRouter::new(4);
        assert!(!r.admit(req(1, Priority::Urgent, Some(1.0), 0.0), 2.0), "already expired");
        assert!(r.admit(req(2, Priority::Urgent, Some(3.0), 2.0), 2.0));
        // expires while queued
        assert!(r.next(4.0).is_none());
        let s = r.stats();
        assert_eq!(s.shed_expired, 2);
        assert_eq!(s.served, 0);
    }

    #[test]
    fn capacity_sheds_worst_not_best() {
        let mut r = RequestRouter::new(2);
        r.admit(req(1, Priority::Background, None, 0.0), 0.0);
        r.admit(req(2, Priority::Normal, None, 0.1), 0.1);
        // urgent newcomer evicts the background request
        assert!(r.admit(req(3, Priority::Urgent, Some(9.0), 0.2), 0.2));
        assert_eq!(r.len(), 2);
        assert_eq!(r.next(0.3).unwrap().id, 3);
        assert_eq!(r.next(0.3).unwrap().id, 2);
        assert_eq!(r.stats().shed_capacity, 1);
    }

    #[test]
    fn background_newcomer_shed_when_full_of_better() {
        let mut r = RequestRouter::new(2);
        r.admit(req(1, Priority::Urgent, Some(9.0), 0.0), 0.0);
        r.admit(req(2, Priority::Urgent, Some(8.0), 0.0), 0.0);
        assert!(!r.admit(req(3, Priority::Background, None, 0.1), 0.1));
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn fifo_within_equal_priority_and_deadline() {
        let mut r = RequestRouter::new(8);
        r.admit(req(10, Priority::Normal, None, 0.0), 0.0);
        r.admit(req(11, Priority::Normal, None, 1.0), 1.0);
        assert_eq!(r.next(2.0).unwrap().id, 10);
        assert_eq!(r.next(2.0).unwrap().id, 11);
    }
}
