//! Admission router for the coordinator front-end.
//!
//! The match service serializes episodes onto the controller thread;
//! this module is the admission stage in front of it: submissions are
//! deadline-tagged, queued by (priority, deadline, FIFO), and expired or
//! over-depth requests are shed *before* they waste a matching episode —
//! the L3 backpressure mechanism.  The service loop
//! ([`super::service::MatchService`]) drives [`RequestRouter::admit`] on
//! every submission and [`RequestRouter::pop`] before every episode.

use std::collections::BinaryHeap;

use crate::scheduler::Priority;

/// One queued admission ticket (payload-agnostic: the service maps ids
/// back to owned problems).
#[derive(Clone, Debug, PartialEq)]
pub struct QueuedRequest {
    pub id: u64,
    pub priority: Priority,
    /// Absolute deadline (s on the service clock); None = best-effort.
    pub deadline: Option<f64>,
    /// Enqueue time (telemetry).
    pub enqueued_at: f64,
    /// Admission sequence number — the FIFO tiebreak (assigned by the
    /// router; total and collision-free where enqueue timestamps are
    /// not).
    seq: u64,
}

impl QueuedRequest {
    pub fn new(id: u64, priority: Priority, deadline: Option<f64>, enqueued_at: f64) -> Self {
        Self { id, priority, deadline, enqueued_at, seq: 0 }
    }
}

impl Eq for QueuedRequest {}

impl PartialOrd for QueuedRequest {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueuedRequest {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Max-heap: higher priority first, then earlier deadline, then
        // FIFO.  Deadlines compare via `total_cmp` — a NaN deadline is
        // a total-order citizen (it sorts after +inf, i.e. best-effort)
        // instead of panicking the heap.
        self.priority
            .cmp(&other.priority)
            .then_with(|| {
                let da = self.deadline.unwrap_or(f64::INFINITY);
                let db = other.deadline.unwrap_or(f64::INFINITY);
                db.total_cmp(&da) // earlier deadline = greater
            })
            .then_with(|| other.seq.cmp(&self.seq)) // earlier admission = greater
    }
}

/// Router statistics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RouterStats {
    pub admitted: u64,
    pub shed_expired: u64,
    pub shed_capacity: u64,
    /// Requests popped for service.  The episode may still be skipped
    /// (caller cancelled while queued), so this can exceed the
    /// controller's `requests` count.
    pub served: u64,
    /// Current queue depth at the moment the stats were read — the
    /// load signal cluster route policies balance on.
    pub depth: u64,
}

/// Admission verdict for one submission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Admitted — possibly evicting the worst queued request (its id;
    /// the service answers the victim with a shed response).
    Admitted { evicted: Option<u64> },
    /// Shed on arrival: expired deadline, or the queue is full of
    /// higher-ranked work.
    Shed,
}

impl Admission {
    pub fn admitted(&self) -> bool {
        matches!(self, Admission::Admitted { .. })
    }
}

/// One step of the admission pop.
#[derive(Clone, Debug)]
pub enum Popped {
    /// The next request to serve.
    Serve(QueuedRequest),
    /// An expired request shed on the way — notify its submitter and
    /// pop again.
    Shed(QueuedRequest),
}

/// Bounded priority router.
#[derive(Debug)]
pub struct RequestRouter {
    heap: BinaryHeap<QueuedRequest>,
    capacity: usize,
    next_seq: u64,
    stats: RouterStats,
}

impl RequestRouter {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Self { heap: BinaryHeap::new(), capacity, next_seq: 0, stats: RouterStats::default() }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn stats(&self) -> RouterStats {
        let mut stats = self.stats;
        stats.depth = self.heap.len() as u64;
        stats
    }

    /// The best queued request, if any (not removed).
    pub fn peek(&self) -> Option<&QueuedRequest> {
        self.heap.peek()
    }

    /// Admit a request.  Expired-on-arrival requests are shed; at
    /// capacity either the worst queued request is evicted (newcomer
    /// outranks it) or the newcomer is shed (bounded queue, no
    /// livelock).
    pub fn admit(&mut self, mut req: QueuedRequest, now: f64) -> Admission {
        if req.deadline.is_some_and(|d| d <= now) {
            self.stats.shed_expired += 1;
            return Admission::Shed;
        }
        req.seq = self.next_seq;
        self.next_seq += 1;
        let mut evicted = None;
        if self.heap.len() >= self.capacity {
            let worst_outranks_newcomer = self.heap.iter().min().is_some_and(|w| *w >= req);
            if worst_outranks_newcomer {
                self.stats.shed_capacity += 1;
                return Admission::Shed;
            }
            // rebuild without the single worst element
            let mut all: Vec<QueuedRequest> = std::mem::take(&mut self.heap).into_vec();
            if let Some(pos) =
                all.iter().enumerate().min_by(|(_, a), (_, b)| a.cmp(b)).map(|(i, _)| i)
            {
                evicted = Some(all.swap_remove(pos).id);
                self.stats.shed_capacity += 1;
            }
            self.heap = all.into();
        }
        self.stats.admitted += 1;
        self.heap.push(req);
        Admission::Admitted { evicted }
    }

    /// One pop step: the best queued request, or an expired one shed on
    /// the way (callers notify the victim and pop again).
    pub fn pop(&mut self, now: f64) -> Option<Popped> {
        let req = self.heap.pop()?;
        if req.deadline.is_some_and(|d| d <= now) {
            self.stats.shed_expired += 1;
            return Some(Popped::Shed(req));
        }
        self.stats.served += 1;
        Some(Popped::Serve(req))
    }

    /// Pop the next serveable request, silently discarding expired ones
    /// (callers that don't track shed victims).
    pub fn next(&mut self, now: f64) -> Option<QueuedRequest> {
        while let Some(step) = self.pop(now) {
            if let Popped::Serve(req) = step {
                return Some(req);
            }
        }
        None
    }

    /// Put a popped request back, keeping its original admission `seq`
    /// (FIFO tiebreak survives) and undoing the pop's `served` count —
    /// for episodes preempted before they started.
    pub fn restore(&mut self, req: QueuedRequest) {
        self.stats.served = self.stats.served.saturating_sub(1);
        self.heap.push(req);
    }

    /// Empty the queue (service shutdown).  Every drained request counts
    /// as capacity-shed.
    pub fn drain(&mut self) -> Vec<QueuedRequest> {
        let drained = std::mem::take(&mut self.heap).into_sorted_vec();
        self.stats.shed_capacity += drained.len() as u64;
        drained
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, priority: Priority, deadline: Option<f64>, t: f64) -> QueuedRequest {
        QueuedRequest::new(id, priority, deadline, t)
    }

    #[test]
    fn priority_then_deadline_then_fifo() {
        let mut r = RequestRouter::new(16);
        assert!(r.admit(req(1, Priority::Background, None, 0.0), 0.0).admitted());
        assert!(r.admit(req(2, Priority::Urgent, Some(5.0), 0.1), 0.1).admitted());
        assert!(r.admit(req(3, Priority::Urgent, Some(2.0), 0.2), 0.2).admitted());
        assert!(r.admit(req(4, Priority::Normal, None, 0.3), 0.3).admitted());
        assert_eq!(r.next(0.5).unwrap().id, 3, "earliest-deadline urgent first");
        assert_eq!(r.next(0.5).unwrap().id, 2);
        assert_eq!(r.next(0.5).unwrap().id, 4, "normal before background");
        assert_eq!(r.next(0.5).unwrap().id, 1);
        assert!(r.next(0.5).is_none());
    }

    #[test]
    fn expired_requests_shed_on_admit_and_pop() {
        let mut r = RequestRouter::new(4);
        assert_eq!(r.admit(req(1, Priority::Urgent, Some(1.0), 0.0), 2.0), Admission::Shed);
        assert!(r.admit(req(2, Priority::Urgent, Some(3.0), 2.0), 2.0).admitted());
        // expires while queued — pop reports the victim, next() skips it
        match r.pop(4.0) {
            Some(Popped::Shed(victim)) => assert_eq!(victim.id, 2),
            other => panic!("expected shed, got {other:?}"),
        }
        let s = r.stats();
        assert_eq!(s.shed_expired, 2);
        assert_eq!(s.served, 0);
    }

    #[test]
    fn capacity_sheds_worst_not_best_and_reports_victim() {
        let mut r = RequestRouter::new(2);
        assert!(r.admit(req(1, Priority::Background, None, 0.0), 0.0).admitted());
        assert!(r.admit(req(2, Priority::Normal, None, 0.1), 0.1).admitted());
        // urgent newcomer evicts the background request — by id
        assert_eq!(
            r.admit(req(3, Priority::Urgent, Some(9.0), 0.2), 0.2),
            Admission::Admitted { evicted: Some(1) }
        );
        assert_eq!(r.len(), 2);
        assert_eq!(r.next(0.3).unwrap().id, 3);
        assert_eq!(r.next(0.3).unwrap().id, 2);
        assert_eq!(r.stats().shed_capacity, 1);
    }

    #[test]
    fn background_newcomer_shed_when_full_of_better() {
        let mut r = RequestRouter::new(2);
        assert!(r.admit(req(1, Priority::Urgent, Some(9.0), 0.0), 0.0).admitted());
        assert!(r.admit(req(2, Priority::Urgent, Some(8.0), 0.0), 0.0).admitted());
        assert_eq!(r.admit(req(3, Priority::Background, None, 0.1), 0.1), Admission::Shed);
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn fifo_within_equal_priority_and_deadline() {
        let mut r = RequestRouter::new(8);
        // identical enqueue timestamps: the admission sequence number
        // still makes the order deterministic FIFO
        assert!(r.admit(req(10, Priority::Normal, None, 0.0), 0.0).admitted());
        assert!(r.admit(req(11, Priority::Normal, None, 0.0), 0.0).admitted());
        assert_eq!(r.next(2.0).unwrap().id, 10);
        assert_eq!(r.next(2.0).unwrap().id, 11);
    }

    /// Regression: a NaN deadline used to panic the heap's
    /// `partial_cmp(..).unwrap()`; `total_cmp` orders it after every
    /// real deadline (best-effort) instead.
    #[test]
    fn nan_deadline_is_ordered_not_panicking() {
        let mut r = RequestRouter::new(8);
        assert!(r.admit(req(1, Priority::Normal, Some(f64::NAN), 0.0), 0.0).admitted());
        assert!(r.admit(req(2, Priority::Normal, Some(1.0), 0.0), 0.0).admitted());
        assert!(r.admit(req(3, Priority::Normal, None, 0.0), 0.0).admitted());
        // finite deadline first, then best-effort (None), then NaN —
        // NaN > +inf in the total order
        assert_eq!(r.next(0.5).unwrap().id, 2);
        assert_eq!(r.next(0.5).unwrap().id, 3);
        assert_eq!(r.next(0.5).unwrap().id, 1);
        assert!(r.next(0.5).is_none());
    }

    #[test]
    fn restore_keeps_fifo_position_and_stats() {
        let mut r = RequestRouter::new(8);
        assert!(r.admit(req(1, Priority::Normal, None, 0.0), 0.0).admitted());
        assert!(r.admit(req(2, Priority::Normal, None, 0.1), 0.1).admitted());
        let Some(Popped::Serve(first)) = r.pop(0.2) else { panic!("expected a pop") };
        assert_eq!(first.id, 1);
        r.restore(first);
        // a later same-priority admission must not jump ahead of it
        assert!(r.admit(req(3, Priority::Normal, None, 0.3), 0.3).admitted());
        assert_eq!(r.next(0.4).unwrap().id, 1, "restored request keeps its place");
        assert_eq!(r.next(0.4).unwrap().id, 2);
        assert_eq!(r.next(0.4).unwrap().id, 3);
        assert_eq!(r.stats().served, 3, "restore must undo the aborted pop's count");
    }

    #[test]
    fn drain_empties_and_counts() {
        let mut r = RequestRouter::new(4);
        assert!(r.admit(req(1, Priority::Normal, None, 0.0), 0.0).admitted());
        assert!(r.admit(req(2, Priority::Urgent, None, 0.0), 0.0).admitted());
        let drained = r.drain();
        assert_eq!(drained.len(), 2);
        assert!(r.is_empty());
        assert_eq!(r.stats().shed_capacity, 2);
    }
}
