//! Interrupt service loop: a dedicated controller thread owning the PJRT
//! runtime, fed by an mpsc channel (offline substitute for the tokio
//! actor pattern, DESIGN.md §4).
//!
//! Request flow (paper Fig. 1c): an urgent task arrives → the caller
//! sends an [`InterruptRequest`] with the query/target/mask and a
//! response channel → the controller thread runs the matching episode →
//! the caller receives the [`InterruptResponse`].  The controller thread
//! is the *only* owner of the PJRT client, so the hot path is lock-free.

use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::matcher::{Mapping, PsoConfig};
use crate::util::MatF;

use super::controller::{ControllerStats, GlobalController, MatchOutcome};

/// One urgent-task interrupt.
pub struct InterruptRequest {
    pub mask: MatF,
    pub q: MatF,
    pub g: MatF,
    /// Reply channel for this request.
    pub respond: mpsc::Sender<InterruptResponse>,
}

/// The controller's answer.
#[derive(Clone, Debug)]
pub struct InterruptResponse {
    pub mappings: Vec<Mapping>,
    pub best_fitness: f32,
    pub epochs_run: usize,
    pub host_seconds: f64,
    pub used_pjrt: bool,
}

impl From<MatchOutcome> for InterruptResponse {
    fn from(o: MatchOutcome) -> Self {
        Self {
            used_pjrt: o.path == super::controller::MatchPath::Pjrt,
            mappings: o.mappings,
            best_fitness: o.best_fitness,
            epochs_run: o.epochs_run,
            host_seconds: o.host_seconds,
        }
    }
}

enum Msg {
    Interrupt(InterruptRequest),
    Stats(mpsc::Sender<ControllerStats>),
    Shutdown,
}

/// Handle to a running coordinator thread.
pub struct CoordinatorHandle {
    tx: mpsc::Sender<Msg>,
    join: Option<JoinHandle<()>>,
}

impl CoordinatorHandle {
    /// Spawn the controller thread.  Artifact/client failures degrade to
    /// the native matcher inside the thread (never fatal).
    pub fn spawn(config: PsoConfig) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let join = std::thread::Builder::new()
            .name("immsched-controller".into())
            .spawn(move || {
                let mut controller = match GlobalController::new(config) {
                    Ok(c) => c,
                    Err(e) => {
                        crate::log_warn!("controller init degraded: {e:#}");
                        GlobalController::native_only(config)
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Interrupt(req) => {
                            let outcome = controller.find_mapping(&req.mask, &req.q, &req.g);
                            // receiver may have given up (deadline) — ignore errors
                            let _ = req.respond.send(outcome.into());
                        }
                        Msg::Stats(reply) => {
                            let _ = reply.send(controller.stats());
                        }
                        Msg::Shutdown => break,
                    }
                }
            })?;
        Ok(Self { tx, join: Some(join) })
    }

    /// Submit an interrupt and wait for the answer.
    pub fn match_blocking(&self, mask: MatF, q: MatF, g: MatF) -> Result<InterruptResponse> {
        let (respond, rx) = mpsc::channel();
        self.tx
            .send(Msg::Interrupt(InterruptRequest { mask, q, g, respond }))
            .map_err(|_| anyhow::anyhow!("controller thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("controller dropped the request"))
    }

    /// Submit an interrupt without blocking; returns the receiver.
    pub fn match_async(
        &self,
        mask: MatF,
        q: MatF,
        g: MatF,
    ) -> Result<mpsc::Receiver<InterruptResponse>> {
        let (respond, rx) = mpsc::channel();
        self.tx
            .send(Msg::Interrupt(InterruptRequest { mask, q, g, respond }))
            .map_err(|_| anyhow::anyhow!("controller thread gone"))?;
        Ok(rx)
    }

    /// Controller telemetry.
    pub fn stats(&self) -> Result<ControllerStats> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Msg::Stats(tx)).map_err(|_| anyhow::anyhow!("controller thread gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("controller dropped the request"))
    }
}

impl Drop for CoordinatorHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_chain, NodeKind};
    use crate::matcher::{build_mask, mapping_is_feasible};

    fn chain_problem(n: usize, m: usize) -> (MatF, MatF, MatF) {
        let qd = gen_chain(n, NodeKind::Compute);
        let gd = gen_chain(m, NodeKind::Universal);
        (build_mask(&qd, &gd), qd.adjacency(), gd.adjacency())
    }

    #[test]
    fn interrupt_round_trip() {
        let handle = CoordinatorHandle::spawn(PsoConfig { seed: 9, ..Default::default() }).unwrap();
        let (mask, q, g) = chain_problem(4, 8);
        let resp = handle.match_blocking(mask, q.clone(), g.clone()).unwrap();
        assert!(!resp.mappings.is_empty());
        assert!(mapping_is_feasible(&resp.mappings[0], &q, &g));
        let stats = handle.stats().unwrap();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.matched, 1);
    }

    #[test]
    fn concurrent_interrupts_are_serialized_safely() {
        let handle = CoordinatorHandle::spawn(PsoConfig { seed: 10, ..Default::default() }).unwrap();
        let mut rxs = Vec::new();
        for i in 0..4 {
            let (mask, q, g) = chain_problem(3 + i % 2, 8);
            rxs.push((q.clone(), g.clone(), handle.match_async(mask, q, g).unwrap()));
        }
        for (q, g, rx) in rxs {
            let resp = rx.recv().unwrap();
            assert!(!resp.mappings.is_empty());
            assert!(mapping_is_feasible(&resp.mappings[0], &q, &g));
        }
        assert_eq!(handle.stats().unwrap().requests, 4);
    }

    #[test]
    fn shutdown_on_drop_does_not_hang() {
        let handle = CoordinatorHandle::spawn(PsoConfig::default()).unwrap();
        drop(handle);
    }
}
