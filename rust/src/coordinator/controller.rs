//! The global controller (paper §3.4): epoch orchestration + consensus
//! fusion over [`EpochBackend`]-executed PSO epochs.
//!
//! The controller owns a set of per-size-class epoch backends. In a
//! default build these are pure-native ([`crate::runtime::NativeEpochBackend`]);
//! with the `pjrt` feature and built artifacts they are PJRT executables.
//! Problems larger than every size class degrade to the quantized
//! native matcher ([`MatchPath::NativeFallback`]).
//!
//! Interrupts whose compatibility mask has an empty candidate row are
//! rejected before particle init (§3.2): no total mapping can exist,
//! so neither the epoch path nor the fallback matcher could ever
//! succeed.

use anyhow::Result;

use crate::graph::Csr;
use crate::matcher::consensus::{elite_consensus_flat, rank_fitness_desc};
use crate::matcher::{
    has_empty_row, mapping_is_feasible_csr, project_greedy_flat, Mapping, PsoConfig,
    QuantizedMatcher,
};
use crate::runtime::{BackendKind, EpochBackend, EpochInputs, EpochOutputs, SizeClass};
use crate::util::{MatF, Rng};

/// Which execution path served a match request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatchPath {
    /// AOT artifact through PJRT (the accelerated hot path, `pjrt`
    /// feature).
    Pjrt,
    /// Pure-native epoch backend (default build): same epoch contract,
    /// threaded across particles.
    NativeEpoch,
    /// Native quantized matcher (fallback: no backend fits the problem,
    /// or an epoch failed).
    NativeFallback,
    /// Rejected before any search: a query vertex had an empty
    /// candidate row in the compatibility mask.
    Rejected,
}

/// Result of one interrupt's subgraph-matching episode.
#[derive(Clone, Debug)]
pub struct MatchOutcome {
    pub mappings: Vec<Mapping>,
    pub best_fitness: f32,
    pub epochs_run: usize,
    pub path: MatchPath,
    /// Wall-clock of the episode on this host (telemetry; the simulator
    /// uses the analytic cost model instead).
    pub host_seconds: f64,
}

impl MatchOutcome {
    pub fn matched(&self) -> bool {
        !self.mappings.is_empty()
    }
}

/// Cumulative controller telemetry.
#[derive(Clone, Copy, Debug, Default)]
pub struct ControllerStats {
    pub requests: u64,
    pub matched: u64,
    pub fallbacks: u64,
    /// Interrupts rejected by the empty-candidate-row witness.
    pub rejected: u64,
    pub epochs_total: u64,
}

/// The global controller.  Owns the epoch backends; single-threaded by
/// design (the event loop serializes requests onto it) — the epoch
/// *inside* a backend may still fan out across particles.
pub struct GlobalController {
    config: PsoConfig,
    backends: Vec<Box<dyn EpochBackend>>,
    stats: ControllerStats,
}

impl GlobalController {
    /// Build the backend set. With the `pjrt` feature, every usable
    /// artifact in the registry is compiled; missing/corrupt artifacts
    /// are tolerated (logged + skipped). Whenever no PJRT backend comes
    /// up — or the feature is off — the native epoch backends serve the
    /// default size classes, so a fresh checkout always has a working
    /// epoch path.
    pub fn new(config: PsoConfig) -> Result<Self> {
        let mut backends: Vec<Box<dyn EpochBackend>> = Vec::new();
        #[cfg(feature = "pjrt")]
        {
            use crate::runtime::{ArtifactRegistry, EpochRunner, RuntimeClient};
            match ArtifactRegistry::discover(&ArtifactRegistry::default_dir()) {
                Ok(registry) => match RuntimeClient::cpu() {
                    Ok(client) => {
                        for artifact in registry.all() {
                            match EpochRunner::load(&client, artifact) {
                                Ok(r) => backends.push(Box::new(r)),
                                Err(e) => crate::log_warn!(
                                    "artifact '{}' unusable: {e:#}; skipping",
                                    artifact.name
                                ),
                            }
                        }
                    }
                    Err(e) => {
                        crate::log_warn!("PJRT client unavailable: {e:#}; native epoch backends")
                    }
                },
                Err(e) => crate::log_warn!("no artifacts: {e:#}; native epoch backends"),
            }
        }
        if backends.is_empty() {
            backends = crate::runtime::NativeEpochBackend::default_set()
                .into_iter()
                .map(|b| {
                    let b = b.with_threads(config.threads).with_relaxed(config.relaxed);
                    Box::new(b) as Box<dyn EpochBackend>
                })
                .collect();
        }
        Ok(Self { config, backends, stats: ControllerStats::default() })
    }

    /// A controller with no epoch backends at all — every request takes
    /// the quantized-matcher fallback (tests / forced fallback).
    pub fn native_only(config: PsoConfig) -> Self {
        Self { config, backends: Vec::new(), stats: ControllerStats::default() }
    }

    pub fn stats(&self) -> ControllerStats {
        self.stats
    }

    /// Whether any PJRT-compiled backend is installed.
    pub fn has_pjrt(&self) -> bool {
        self.backends.iter().any(|b| b.kind() == BackendKind::Pjrt)
    }

    /// Whether any epoch backend (native or PJRT) is installed.
    pub fn has_epoch_backend(&self) -> bool {
        !self.backends.is_empty()
    }

    /// Serve one interrupt: find feasible mappings of `query` into
    /// `target` under `mask`.
    pub fn find_mapping(&mut self, mask: &MatF, q: &MatF, g: &MatF) -> MatchOutcome {
        self.stats.requests += 1;
        let started = std::time::Instant::now();

        // §3.2 fast reject, before particle init: an empty candidate
        // row means no total mapping exists. The mask arrives unpacked
        // (f32, the PSO/artifact interchange form), so the short-circuit
        // dense scan is the allocation-free check here; callers that
        // already hold a packed mask get the word-wise
        // `BitMask::has_empty_row` — the two witnesses are
        // property-tested equal (`prop_bitmask_matches_dense_mask`).
        if has_empty_row(mask) {
            self.stats.rejected += 1;
            return MatchOutcome {
                mappings: Vec::new(),
                best_fitness: f32::NEG_INFINITY,
                epochs_run: 0,
                path: MatchPath::Rejected,
                host_seconds: started.elapsed().as_secs_f64(),
            };
        }

        let (n, m) = (q.rows(), g.rows());
        let backend_idx = self.backends.iter().position(|b| b.class().fits(n, m));

        let mut outcome = match backend_idx {
            Some(idx) => match self.run_backend(idx, mask, q, g) {
                Ok(o) => o,
                Err(e) => {
                    crate::log_warn!("epoch backend failed: {e:#}; native fallback");
                    self.stats.fallbacks += 1;
                    self.run_native(mask, q, g)
                }
            },
            None => {
                if !self.backends.is_empty() {
                    crate::log_warn!("problem {n}x{m} exceeds all size classes; native fallback");
                }
                self.stats.fallbacks += 1;
                self.run_native(mask, q, g)
            }
        };
        outcome.host_seconds = started.elapsed().as_secs_f64();
        if outcome.matched() {
            self.stats.matched += 1;
        }
        self.stats.epochs_total += outcome.epochs_run as u64;
        outcome
    }

    /// T-epoch outer loop over one epoch backend: the paper's consensus-
    /// guided exploration, with projection + verification on the
    /// controller. Episode-lifetime buffers (inputs, outputs, candidate
    /// staging, S*/S̄) are allocated once up front and reused every
    /// epoch.
    fn run_backend(
        &mut self,
        backend_idx: usize,
        mask: &MatF,
        q: &MatF,
        g: &MatF,
    ) -> Result<MatchOutcome> {
        let cfg = self.config;
        let backend = &mut self.backends[backend_idx];
        let class = backend.class();
        let (n, m) = (q.rows(), g.rows());
        let (pn, pm, parts) = (class.n, class.m, class.particles);
        let mut rng = Rng::new(cfg.seed ^ 0xC0DE);

        // padded, flat inputs; padding rows keep zero mask + zero S
        let mut inputs = EpochInputs::zeros(class);
        inputs.coefs = [cfg.w, cfg.c1, cfg.c2, cfg.c3];
        pad_into(&mut inputs.mask, mask, pn, pm);
        pad_into(&mut inputs.q, q, pn, pn);
        pad_into(&mut inputs.g, g, pm, pm);

        // query edge list for the per-candidate verification
        let q_csr = Csr::from_dense(q);

        let mut best_fitness = f32::NEG_INFINITY;
        let mut mappings: Vec<Mapping> = Vec::new();
        let mut s_star: Vec<f32> = vec![0.0; pn * pm];
        let mut s_bar: Vec<f32> = vec![0.0; pn * pm];
        let mut have_star = false;
        let mut epochs_run = 0;
        let mut epoch_out = EpochOutputs::zeros(class);
        // unpadded candidate staging (top-left n×m of a padded particle)
        let mut cand = vec![0.0f32; n * m];

        for epoch in 0..cfg.epochs {
            epochs_run += 1;
            // fresh particles every epoch (Algorithm 1 line 4)
            for p in 0..parts {
                init_padded_particle(
                    &mut inputs.s[p * pn * pm..(p + 1) * pn * pm],
                    mask,
                    pn,
                    pm,
                    &mut rng,
                );
            }
            inputs.v.iter_mut().for_each(|x| *x = 0.0);
            inputs.s_local.copy_from_slice(&inputs.s);
            inputs.f_local.iter_mut().for_each(|x| *x = f32::NEG_INFINITY);
            if have_star {
                inputs.s_star.copy_from_slice(&s_star);
                inputs.s_bar.copy_from_slice(&s_bar);
            } else {
                inputs.s_star.copy_from_slice(&inputs.s[..pn * pm]);
                inputs.s_bar.copy_from_slice(&inputs.s[..pn * pm]);
            }
            inputs.seed = (cfg.seed as u32).wrapping_add(epoch as u32 * 7919);

            backend.run_epoch_into(&inputs, &mut epoch_out)?;

            // controller-side: rank particles, update S*, project+verify
            let order = rank_fitness_desc(&epoch_out.f_local);
            let best = order[0];
            if epoch_out.f_local[best] > best_fitness {
                best_fitness = epoch_out.f_local[best];
                s_star.copy_from_slice(&epoch_out.s_local[best * pn * pm..(best + 1) * pn * pm]);
                have_star = true;
            }

            // S̄ from the stacked local-best snapshots, clone-free
            elite_consensus_flat(
                &epoch_out.s_local,
                parts,
                pn,
                pm,
                &epoch_out.f_local,
                cfg.elite,
                &mut s_bar,
            );

            for p in 0..parts {
                let flat = &epoch_out.s[p * pn * pm..(p + 1) * pn * pm];
                for i in 0..n {
                    cand[i * m..(i + 1) * m].copy_from_slice(&flat[i * pm..i * pm + m]);
                }
                let candidate = project_greedy_flat(&cand, mask.as_slice(), n, m);
                if mapping_is_feasible_csr(&candidate, &q_csr, g) && !mappings.contains(&candidate)
                {
                    mappings.push(candidate);
                }
            }
            if !mappings.is_empty() && cfg.early_exit {
                break;
            }
        }

        // final repair attempt if the swarm converged but projection failed
        if mappings.is_empty() {
            let (repaired, _) = crate::matcher::ullmann_find_first(mask, q, g, cfg.repair_budget);
            if let Some(mp) = repaired {
                mappings.push(mp);
            }
        }

        let path = match backend.kind() {
            BackendKind::Pjrt => MatchPath::Pjrt,
            BackendKind::Native => MatchPath::NativeEpoch,
        };
        Ok(MatchOutcome { mappings, best_fitness, epochs_run, path, host_seconds: 0.0 })
    }

    fn run_native(&mut self, mask: &MatF, q: &MatF, g: &MatF) -> MatchOutcome {
        let out = QuantizedMatcher::new(self.config).run(mask, q, g);
        MatchOutcome {
            mappings: out.mappings,
            best_fitness: out.best_fitness,
            epochs_run: out.epochs_run,
            path: MatchPath::NativeFallback,
            host_seconds: 0.0,
        }
    }

    /// Size class the controller would use (None = fallback).
    pub fn class_for(&self, n: usize, m: usize) -> Option<SizeClass> {
        self.backends.iter().find(|b| b.class().fits(n, m)).map(|b| b.class())
    }
}

/// Copy `src` (r×c) into the top-left of a padded flat (pr×pc) buffer.
fn pad_into(dst: &mut [f32], src: &MatF, pr: usize, pc: usize) {
    assert!(src.rows() <= pr && src.cols() <= pc);
    dst.iter_mut().for_each(|x| *x = 0.0);
    for i in 0..src.rows() {
        dst[i * pc..i * pc + src.cols()].copy_from_slice(src.row(i));
    }
}

/// Extract the top-left (r×c) of a padded flat (pr×pc) buffer.
#[cfg(test)]
fn unpad(flat: &[f32], pr: usize, pc: usize, r: usize, c: usize) -> MatF {
    assert!(r <= pr && c <= pc);
    let mut out = MatF::zeros(r, c);
    for i in 0..r {
        out.row_mut(i).copy_from_slice(&flat[i * pc..i * pc + c]);
    }
    out
}

/// Random mask-respecting row-stochastic init of one padded particle.
fn init_padded_particle(flat: &mut [f32], mask: &MatF, pn: usize, pm: usize, rng: &mut Rng) {
    flat.iter_mut().for_each(|x| *x = 0.0);
    for i in 0..mask.rows() {
        let mut sum = 0.0;
        for j in 0..mask.cols() {
            if mask[(i, j)] != 0.0 {
                let v = rng.f32() + 1e-3;
                flat[i * pm + j] = v;
                sum += v;
            }
        }
        if sum > 0.0 {
            for j in 0..mask.cols() {
                flat[i * pm + j] /= sum;
            }
        }
    }
    let _ = pn;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_chain, NodeKind};
    use crate::matcher::{build_mask, mapping_is_feasible};

    fn chain_problem(n: usize, m: usize) -> (MatF, MatF, MatF) {
        let qd = gen_chain(n, NodeKind::Compute);
        let gd = gen_chain(m, NodeKind::Universal);
        (build_mask(&qd, &gd), qd.adjacency(), gd.adjacency())
    }

    #[test]
    fn native_fallback_matches() {
        let (mask, q, g) = chain_problem(4, 8);
        let mut ctl = GlobalController::native_only(PsoConfig { seed: 3, ..Default::default() });
        let out = ctl.find_mapping(&mask, &q, &g);
        assert_eq!(out.path, MatchPath::NativeFallback);
        assert!(out.matched());
        assert!(mapping_is_feasible(&out.mappings[0], &q, &g));
        assert_eq!(ctl.stats().fallbacks, 1);
        assert_eq!(ctl.stats().matched, 1);
    }

    /// A default controller always has a working epoch path, even with
    /// no artifacts and no XLA anywhere.
    #[test]
    fn default_controller_serves_native_epoch() {
        let mut ctl = GlobalController::new(PsoConfig { seed: 5, ..Default::default() })
            .expect("controller");
        assert!(ctl.has_epoch_backend());
        let (mask, q, g) = chain_problem(4, 8);
        let out = ctl.find_mapping(&mask, &q, &g);
        if !ctl.has_pjrt() {
            assert_eq!(out.path, MatchPath::NativeEpoch);
        }
        assert!(out.matched(), "epoch path found no mapping (fitness {})", out.best_fitness);
        assert!(mapping_is_feasible(&out.mappings[0], &q, &g));
        assert_eq!(ctl.stats().fallbacks, 0);
    }

    #[test]
    fn epoch_path_is_deterministic() {
        let (mask, q, g) = chain_problem(4, 8);
        let run = || {
            let mut ctl = GlobalController::new(PsoConfig { seed: 11, ..Default::default() })
                .expect("controller");
            ctl.find_mapping(&mask, &q, &g)
        };
        let a = run();
        let b = run();
        assert_eq!(a.mappings, b.mappings);
        assert_eq!(a.best_fitness, b.best_fitness);
        assert_eq!(a.epochs_run, b.epochs_run);
    }

    /// An interrupt whose mask has an empty candidate row is rejected
    /// before any epoch runs — no backend work, no fallback work.
    #[test]
    fn infeasible_mask_is_rejected_before_search() {
        let (mut mask, q, g) = chain_problem(4, 8);
        for j in 0..mask.cols() {
            mask[(2, j)] = 0.0; // query vertex 2 has no candidates
        }
        let mut ctl =
            GlobalController::new(PsoConfig { seed: 9, ..Default::default() }).expect("controller");
        let out = ctl.find_mapping(&mask, &q, &g);
        assert_eq!(out.path, MatchPath::Rejected);
        assert!(!out.matched());
        assert_eq!(out.epochs_run, 0);
        assert_eq!(ctl.stats().rejected, 1);
        assert_eq!(ctl.stats().epochs_total, 0);
        // the fallback-only controller rejects identically
        let mut fallback = GlobalController::native_only(PsoConfig::default());
        assert_eq!(fallback.find_mapping(&mask, &q, &g).path, MatchPath::Rejected);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_path_matches_when_artifacts_present() {
        let mut ctl = match GlobalController::new(PsoConfig { seed: 5, ..Default::default() }) {
            Ok(c) => c,
            Err(_) => return,
        };
        if !ctl.has_pjrt() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let (mask, q, g) = chain_problem(4, 8);
        let out = ctl.find_mapping(&mask, &q, &g);
        assert_eq!(out.path, MatchPath::Pjrt);
        assert!(out.matched(), "PJRT path found no mapping (fitness {})", out.best_fitness);
        assert!(mapping_is_feasible(&out.mappings[0], &q, &g));
    }

    #[test]
    fn oversized_problem_falls_back() {
        let mut ctl = match GlobalController::new(PsoConfig::default()) {
            Ok(c) => c,
            Err(_) => return,
        };
        // 200 query vertices exceeds every size class
        let big_q = gen_chain(200, NodeKind::Compute);
        let big_g = gen_chain(210, NodeKind::Universal);
        let mask = build_mask(&big_q, &big_g);
        let out = ctl.find_mapping(&mask, &big_q.adjacency(), &big_g.adjacency());
        assert_eq!(out.path, MatchPath::NativeFallback);
    }

    #[test]
    fn class_for_picks_smallest_fitting_backend() {
        let ctl = GlobalController::new(PsoConfig::default()).expect("controller");
        let small = ctl.class_for(4, 8).expect("4x8 must fit");
        assert!(small.fits(4, 8));
        assert!(ctl.class_for(500, 500).is_none());
    }

    #[test]
    fn pad_unpad_roundtrip() {
        let src = MatF::from_fn(3, 5, |i, j| (i * 5 + j) as f32);
        let mut flat = vec![0.0; 8 * 16];
        pad_into(&mut flat, &src, 8, 16);
        let back = unpad(&flat, 8, 16, 3, 5);
        assert_eq!(back, src);
        // padding region is zero
        assert_eq!(flat[3 * 16], 0.0);
        assert_eq!(flat[5], 0.0);
    }
}
