//! The global controller (paper §3.4): an ordered [`MatchEngine`] chain
//! behind the typed [`MatchRequest`] API.
//!
//! Engines are consulted in order per request; the first `Served` (or
//! `Cancelled`) outcome wins, `Unsupported`/`Failed` fall through:
//!
//! * [`EpochEngine`] — the paper's path: per-size-class epoch backends
//!   (pure-native by default, PJRT executables under the `pjrt`
//!   feature), consensus fusion between epochs, projection + sparse
//!   feasibility verification on the controller.  Interruptible at the
//!   epoch barrier via [`CancelToken`].
//! * [`QuantizedEngine`] — the u8/i32 fixed-point matcher; serves any
//!   problem shape (the universal fallback).
//! * [`UllmannEngine`] / [`Vf2Engine`] — the serial baselines (IsoSched
//!   and the VF2 family), swappable behind the same interface for
//!   benches and the simulator.
//!
//! Requests whose packed compatibility mask has an empty candidate row
//! are rejected word-wise (§3.2) before any engine runs: no total
//! mapping can exist.

use anyhow::Result;

use crate::graph::Csr;
use crate::matcher::consensus::{elite_consensus_flat, rank_fitness_desc};
use crate::matcher::{
    mapping_is_feasible_sparse, project_greedy_flat, ullmann_find_first, vf2_find_first, BitMask,
    Mapping, PsoConfig, QuantizedMatcher, SwarmSnapshot,
};
use crate::runtime::{BackendKind, EpochBackend, EpochInputs, EpochOutputs};
use crate::util::Rng;

use super::service::{
    CancelToken, DenseCache, EngineBudget, EngineOutcome, EngineReport, EngineWork, MatchEngine,
    MatchRequest,
};

/// Which execution path served (or disposed of) a match request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatchPath {
    /// AOT artifact through PJRT (the accelerated hot path, `pjrt`
    /// feature).
    Pjrt,
    /// Pure-native epoch backend (default build): same epoch contract,
    /// threaded across particles.
    NativeEpoch,
    /// Native quantized matcher (universal fallback).
    NativeFallback,
    /// Serial Ullmann baseline engine.
    Ullmann,
    /// Serial VF2 baseline engine.
    Vf2,
    /// Rejected before any search: a query vertex had an empty
    /// candidate row in the compatibility mask — or (misconfigured
    /// custom chains only) no engine could serve the problem shape.
    Rejected,
    /// Interrupted at an epoch barrier: higher-priority arrival,
    /// explicit cancel, or mid-episode deadline expiry.
    Cancelled,
    /// Shed by admission (expired deadline or bounded-queue eviction);
    /// never reached the controller.
    Shed,
}

impl MatchPath {
    /// Short display name.
    pub fn name(self) -> &'static str {
        match self {
            MatchPath::Pjrt => "pjrt",
            MatchPath::NativeEpoch => "native-epoch",
            MatchPath::NativeFallback => "quantized",
            MatchPath::Ullmann => "ullmann",
            MatchPath::Vf2 => "vf2",
            MatchPath::Rejected => "rejected",
            MatchPath::Cancelled => "cancelled",
            MatchPath::Shed => "shed",
        }
    }

    /// Inverse of [`Self::name`] (`None` for unknown names) — the wire
    /// protocol decodes response paths through this.
    pub fn from_name(name: &str) -> Option<Self> {
        Some(match name {
            "pjrt" => MatchPath::Pjrt,
            "native-epoch" => MatchPath::NativeEpoch,
            "quantized" => MatchPath::NativeFallback,
            "ullmann" => MatchPath::Ullmann,
            "vf2" => MatchPath::Vf2,
            "rejected" => MatchPath::Rejected,
            "cancelled" => MatchPath::Cancelled,
            "shed" => MatchPath::Shed,
            _ => return None,
        })
    }
}

/// Result of one request's subgraph-matching episode.
#[derive(Clone, Debug)]
pub struct MatchOutcome {
    pub mappings: Vec<Mapping>,
    pub best_fitness: f32,
    pub epochs_run: usize,
    pub path: MatchPath,
    /// Wall-clock of the episode on this host (telemetry; the simulator
    /// uses the analytic cost model instead).
    pub host_seconds: f64,
    /// The episode warm-started from the request's persisted snapshot.
    pub resumed: bool,
    /// Barrier snapshot of a cancelled episode (resubmit with it to
    /// warm-start; see [`SwarmSnapshot`]).
    pub snapshot: Option<SwarmSnapshot>,
}

impl MatchOutcome {
    pub fn matched(&self) -> bool {
        !self.mappings.is_empty()
    }
}

/// Cumulative controller telemetry.
#[derive(Clone, Copy, Debug, Default)]
pub struct ControllerStats {
    pub requests: u64,
    pub matched: u64,
    /// Requests served past the head of the engine chain.
    pub fallbacks: u64,
    /// Requests rejected by the empty-candidate-row witness.
    pub rejected: u64,
    /// Episodes interrupted at an epoch barrier.
    pub cancelled: u64,
    /// Episodes that warm-started from a persisted resume snapshot.
    pub resumed: u64,
    pub epochs_total: u64,
}

/// The global controller: owns the ordered engine chain + the shared
/// dense staging.  Single-threaded by design (the service loop
/// serializes requests onto it) — the epoch *inside* an engine may still
/// fan out across particles.
pub struct GlobalController {
    engines: Vec<Box<dyn MatchEngine>>,
    dense: DenseCache,
    node_budget: u64,
    /// Anchor for request deadlines (seconds on the caller's clock →
    /// host `Instant`); set by the service so deadlines become hard
    /// mid-episode expiry at epoch barriers.
    clock_base: Option<std::time::Instant>,
    /// Episode slicing: max epochs per episode before a barrier yield
    /// with a resume snapshot (see [`super::service::EngineBudget`]).
    epoch_quota: Option<usize>,
    stats: ControllerStats,
}

impl GlobalController {
    /// Default chain: the epoch engine (PJRT artifacts when compiled in
    /// and present, native per-size-class backends otherwise) followed
    /// by the quantized universal fallback.
    pub fn new(config: PsoConfig) -> Result<Self> {
        let engines: Vec<Box<dyn MatchEngine>> = vec![
            Box::new(EpochEngine::new(config)?),
            Box::new(QuantizedEngine::new(config)),
        ];
        Ok(Self::with_engines(engines))
    }

    /// Chain with no epoch backends at all — every request takes the
    /// quantized-matcher fallback (tests / forced fallback).
    pub fn fallback_only(config: PsoConfig) -> Self {
        Self::with_engines(vec![Box::new(QuantizedEngine::new(config))])
    }

    /// Arbitrary engine chain — the baseline-swap hook for benches, the
    /// CLI and the simulator.
    pub fn with_engines(engines: Vec<Box<dyn MatchEngine>>) -> Self {
        Self {
            engines,
            dense: DenseCache::default(),
            node_budget: 1_000_000,
            clock_base: None,
            epoch_quota: None,
            stats: ControllerStats::default(),
        }
    }

    /// Cap the node budget handed to serial engines.
    pub fn with_node_budget(mut self, nodes: u64) -> Self {
        self.node_budget = nodes;
        self
    }

    /// Anchor request deadlines to a host clock instant (the service's
    /// start).  Without a base, deadlines are admission metadata only.
    pub fn with_clock_base(mut self, base: std::time::Instant) -> Self {
        self.clock_base = Some(base);
        self
    }

    /// Bound every episode to at most `quota` epochs before it yields at
    /// the barrier with a resume snapshot (`None` = unbounded).
    pub fn with_epoch_quota(mut self, quota: Option<usize>) -> Self {
        self.epoch_quota = quota;
        self
    }

    pub fn stats(&self) -> ControllerStats {
        self.stats
    }

    /// Engine names in chain order.
    pub fn engine_names(&self) -> Vec<&'static str> {
        self.engines.iter().map(|e| e.name()).collect()
    }

    /// Serve one request through the engine chain.  `cancel` is the
    /// request's in-flight token; engines honor it at epoch barriers.
    pub fn serve(&mut self, req: &MatchRequest<'_>, cancel: &CancelToken) -> MatchOutcome {
        self.stats.requests += 1;
        // lint:allow(no-wallclock-core): telemetry-only episode timing (host_seconds)
        // and the service-anchored deadline clock; neither feeds match results or
        // ordering, and the epoch loop itself is deterministic
        let started = std::time::Instant::now();
        self.dense.clear();

        // §3.2 fast reject before any engine runs: the packed mask's
        // word-wise empty-row witness (64 candidates per word) — no
        // dense scan, no particle init.
        if req.mask.has_empty_row() {
            self.stats.rejected += 1;
            return MatchOutcome {
                mappings: Vec::new(),
                best_fitness: f32::NEG_INFINITY,
                epochs_run: 0,
                path: MatchPath::Rejected,
                host_seconds: started.elapsed().as_secs_f64(),
                resumed: false,
                snapshot: None,
            };
        }

        // deadline → hard host-clock expiry, checked at epoch barriers
        let expires_at = match (self.clock_base, req.deadline) {
            (Some(base), Some(d)) if d.is_finite() && d >= 0.0 => {
                base.checked_add(std::time::Duration::from_secs_f64(d.min(1e9)))
            }
            _ => None,
        };

        let mut outcome: Option<MatchOutcome> = None;
        for (idx, engine) in self.engines.iter_mut().enumerate() {
            let mut budget = EngineBudget {
                nodes: self.node_budget,
                cancel,
                expires_at,
                epoch_quota: self.epoch_quota,
                dense: &mut self.dense,
            };
            match engine.solve(req, &mut budget) {
                EngineOutcome::Served(report) => {
                    if idx > 0 {
                        self.stats.fallbacks += 1;
                    }
                    outcome = Some(MatchOutcome {
                        mappings: report.mappings,
                        best_fitness: report.best_fitness,
                        epochs_run: report.epochs_run,
                        path: report.path,
                        host_seconds: 0.0,
                        resumed: report.resumed,
                        snapshot: None,
                    });
                    break;
                }
                EngineOutcome::Cancelled { epochs_run, snapshot } => {
                    self.stats.cancelled += 1;
                    // a cancelled episode whose snapshot carries more
                    // history than it ran itself had warm-started
                    let resumed =
                        snapshot.as_ref().is_some_and(|s| s.epochs_done > epochs_run);
                    outcome = Some(MatchOutcome {
                        mappings: Vec::new(),
                        best_fitness: f32::NEG_INFINITY,
                        epochs_run,
                        path: MatchPath::Cancelled,
                        host_seconds: 0.0,
                        resumed,
                        snapshot,
                    });
                    break;
                }
                EngineOutcome::Unsupported => continue,
                EngineOutcome::Failed(e) => {
                    crate::log_warn!("engine '{}' failed: {e}; trying next", engine.name());
                    continue;
                }
            }
        }
        let mut outcome = outcome.unwrap_or_else(|| {
            crate::log_warn!("no engine in the chain served a {}x{} request", req.n(), req.m());
            self.stats.rejected += 1;
            MatchOutcome {
                mappings: Vec::new(),
                best_fitness: f32::NEG_INFINITY,
                epochs_run: 0,
                path: MatchPath::Rejected,
                host_seconds: 0.0,
                resumed: false,
                snapshot: None,
            }
        });
        outcome.host_seconds = started.elapsed().as_secs_f64();
        if outcome.matched() {
            self.stats.matched += 1;
        }
        if outcome.resumed {
            self.stats.resumed += 1;
        }
        self.stats.epochs_total += outcome.epochs_run as u64;
        outcome
    }
}

// ---------------------------------------------------------------------------
// EpochEngine — the PSO/epoch path (paper Algorithm 1)
// ---------------------------------------------------------------------------

/// T-epoch consensus-guided search over per-size-class epoch backends.
///
/// The request stays sparse until this boundary: the packed mask is
/// expanded once into episode staging, and the CSR adjacencies are
/// scattered straight into the backend's padded flat inputs — the f32
/// interchange the artifact calling convention pins.  The cancel token
/// is honored between epochs (never mid-kernel).
pub struct EpochEngine {
    config: PsoConfig,
    backends: Vec<Box<dyn EpochBackend>>,
    /// Unpadded n×m f32 mask staging (episode lifetime, reused).
    mask_nm: Vec<f32>,
    /// Unpadded n×m candidate staging for projection.
    cand: Vec<f32>,
}

impl EpochEngine {
    /// Build the backend set.  With the `pjrt` feature, every usable
    /// artifact in the registry is compiled; missing/corrupt artifacts
    /// are tolerated (logged + skipped).  Whenever no PJRT backend comes
    /// up — or the feature is off — the native epoch backends serve the
    /// default size classes, so a fresh checkout always has a working
    /// epoch path.
    pub fn new(config: PsoConfig) -> Result<Self> {
        let mut backends: Vec<Box<dyn EpochBackend>> = Vec::new();
        #[cfg(feature = "pjrt")]
        {
            use crate::runtime::{ArtifactRegistry, EpochRunner, RuntimeClient};
            match ArtifactRegistry::discover(&ArtifactRegistry::default_dir()) {
                Ok(registry) => match RuntimeClient::cpu() {
                    Ok(client) => {
                        for artifact in registry.all() {
                            match EpochRunner::load(&client, artifact) {
                                Ok(r) => backends.push(Box::new(r)),
                                Err(e) => crate::log_warn!(
                                    "artifact '{}' unusable: {e:#}; skipping",
                                    artifact.name
                                ),
                            }
                        }
                    }
                    Err(e) => {
                        crate::log_warn!("PJRT client unavailable: {e:#}; native epoch backends")
                    }
                },
                Err(e) => crate::log_warn!("no artifacts: {e:#}; native epoch backends"),
            }
        }
        if backends.is_empty() {
            backends = crate::runtime::NativeEpochBackend::default_set()
                .into_iter()
                .map(|b| {
                    let b = b.with_threads(config.threads).with_relaxed(config.relaxed);
                    Box::new(b) as Box<dyn EpochBackend>
                })
                .collect();
        }
        Ok(Self::with_backends(config, backends))
    }

    /// Explicit backend set (tests / custom size classes).
    pub fn with_backends(config: PsoConfig, backends: Vec<Box<dyn EpochBackend>>) -> Self {
        Self { config, backends, mask_nm: Vec::new(), cand: Vec::new() }
    }

    /// Whether any PJRT-compiled backend is installed.
    pub fn has_pjrt(&self) -> bool {
        self.backends.iter().any(|b| b.kind() == BackendKind::Pjrt)
    }

    fn run_episode(
        &mut self,
        backend_idx: usize,
        req: &MatchRequest<'_>,
        budget: &mut EngineBudget<'_>,
    ) -> Result<EngineOutcome> {
        let cfg = self.config;
        let Self { backends, mask_nm, cand, .. } = self;
        let backend = &mut backends[backend_idx];
        let class = backend.class();
        let (n, m) = (req.n(), req.m());
        let (pn, pm, parts) = (class.n, class.m, class.particles);

        // Expand the packed mask once into episode staging; together
        // with the padded scatters below this is the artifact-boundary
        // densification — the request itself stays sparse.
        mask_nm.clear();
        mask_nm.resize(n * m, 0.0);
        for i in 0..n {
            for j in 0..m {
                if req.mask.get(i, j) {
                    mask_nm[i * m + j] = 1.0;
                }
            }
        }

        let mut inputs = EpochInputs::zeros(class);
        inputs.coefs = [cfg.w, cfg.c1, cfg.c2, cfg.c3];
        pad_rows(&mut inputs.mask, mask_nm, n, m, pm);
        pad_edges(&mut inputs.q, req.query, pn);
        pad_edges(&mut inputs.g, req.target, pm);

        // Warm start: a fitting resume snapshot restores the barrier
        // state — S*/S̄ (scattered back into this class's padding), the
        // best fitness, the feasible set, the epoch counter and the
        // episode RNG — so the resumed epochs replay the exact stream
        // the uninterrupted episode would have drawn.  The snapshot is
        // padding-agnostic (unpadded n×m), so it survives migration to
        // a shard whose backend pads differently.
        let resume = req.resume.filter(|s| s.fits(n, m));
        let resumed = resume.is_some();
        let mut s_star: Vec<f32> = vec![0.0; pn * pm];
        let mut s_bar: Vec<f32> = vec![0.0; pn * pm];
        let (mut rng, mut best_fitness, mut mappings, mut have_star, start_epoch) =
            match resume {
                Some(snap) => {
                    pad_rows(&mut s_star, &snap.s_star, n, m, pm);
                    pad_rows(&mut s_bar, &snap.s_bar, n, m, pm);
                    (
                        snap.rng.clone(),
                        snap.best_fitness,
                        snap.mappings.clone(),
                        snap.have_star,
                        snap.epochs_done,
                    )
                }
                None => {
                    (Rng::new(cfg.seed ^ 0xC0DE), f32::NEG_INFINITY, Vec::new(), false, 0)
                }
            };
        let mut epochs_run = 0;
        let mut epoch_out = EpochOutputs::zeros(class);
        cand.clear();
        cand.resize(n * m, 0.0);

        for epoch in start_epoch..cfg.epochs {
            // The paper's interruptibility point: a higher-priority
            // arrival, an expired deadline, or an exhausted epoch quota
            // stops the episode between epochs, never mid-kernel — and
            // hands back the barrier snapshot so a resubmission resumes
            // here instead of starting over.
            if budget.interrupted() || budget.quota_reached(epochs_run) {
                return Ok(EngineOutcome::Cancelled {
                    epochs_run,
                    snapshot: Some(SwarmSnapshot {
                        n,
                        m,
                        s_star: gather_rows(&s_star, n, m, pm),
                        s_bar: gather_rows(&s_bar, n, m, pm),
                        best_fitness,
                        have_star,
                        epochs_done: epoch,
                        rng,
                        mappings,
                    }),
                });
            }
            epochs_run += 1;
            // fresh particles every epoch (Algorithm 1 line 4)
            for p in 0..parts {
                init_padded_particle(
                    &mut inputs.s[p * pn * pm..(p + 1) * pn * pm],
                    req.mask,
                    pm,
                    &mut rng,
                );
            }
            inputs.v.iter_mut().for_each(|x| *x = 0.0);
            inputs.s_local.copy_from_slice(&inputs.s);
            inputs.f_local.iter_mut().for_each(|x| *x = f32::NEG_INFINITY);
            if have_star {
                inputs.s_star.copy_from_slice(&s_star);
                inputs.s_bar.copy_from_slice(&s_bar);
            } else {
                inputs.s_star.copy_from_slice(&inputs.s[..pn * pm]);
                inputs.s_bar.copy_from_slice(&inputs.s[..pn * pm]);
            }
            inputs.seed = (cfg.seed as u32).wrapping_add(epoch as u32 * 7919);

            backend.run_epoch_into(&inputs, &mut epoch_out)?;

            // controller-side: rank particles, update S*, project+verify
            let order = rank_fitness_desc(&epoch_out.f_local);
            let best = order[0];
            if epoch_out.f_local[best] > best_fitness {
                best_fitness = epoch_out.f_local[best];
                s_star.copy_from_slice(&epoch_out.s_local[best * pn * pm..(best + 1) * pn * pm]);
                have_star = true;
            }

            // S̄ from the stacked local-best snapshots, clone-free
            elite_consensus_flat(
                &epoch_out.s_local,
                parts,
                pn,
                pm,
                &epoch_out.f_local,
                cfg.elite,
                &mut s_bar,
            );

            for p in 0..parts {
                let flat = &epoch_out.s[p * pn * pm..(p + 1) * pn * pm];
                for i in 0..n {
                    cand[i * m..(i + 1) * m].copy_from_slice(&flat[i * pm..i * pm + m]);
                }
                let candidate = project_greedy_flat(cand, mask_nm, n, m);
                if mapping_is_feasible_sparse(&candidate, req.query, req.target)
                    && !mappings.contains(&candidate)
                {
                    mappings.push(candidate);
                }
            }
            if !mappings.is_empty() && cfg.early_exit {
                break;
            }
        }

        let mut work =
            EngineWork { steps_run: epochs_run * class.k_steps * parts, ..Default::default() };
        if mappings.is_empty() {
            // final repair attempt if the swarm converged but projection
            // failed — the bounded serial search over the dense forms
            // (built at most once per episode, shared down the chain)
            let (mask_d, q_d, g_d) = budget.dense.get(req);
            let (repaired, stats) = ullmann_find_first(mask_d, q_d, g_d, cfg.repair_budget);
            work.repair_nodes = stats.nodes_visited;
            if let Some(mp) = repaired {
                mappings.push(mp);
            }
        }

        let path = match backend.kind() {
            BackendKind::Pjrt => MatchPath::Pjrt,
            BackendKind::Native => MatchPath::NativeEpoch,
        };
        Ok(EngineOutcome::Served(EngineReport {
            mappings,
            best_fitness,
            epochs_run,
            path,
            resumed,
            work,
        }))
    }
}

impl MatchEngine for EpochEngine {
    fn name(&self) -> &'static str {
        "epoch"
    }

    fn solve(&mut self, req: &MatchRequest<'_>, budget: &mut EngineBudget<'_>) -> EngineOutcome {
        let (n, m) = (req.n(), req.m());
        let Some(idx) = self.backends.iter().position(|b| b.class().fits(n, m)) else {
            return EngineOutcome::Unsupported;
        };
        match self.run_episode(idx, req, budget) {
            Ok(outcome) => outcome,
            Err(e) => EngineOutcome::Failed(format!("{e:#}")),
        }
    }
}

// ---------------------------------------------------------------------------
// QuantizedEngine — the u8/i32 fixed-point universal fallback
// ---------------------------------------------------------------------------

/// The quantized matcher behind the engine interface.  Serves any
/// problem shape; its op counters feed the on-accelerator cost model.
pub struct QuantizedEngine {
    config: PsoConfig,
}

impl QuantizedEngine {
    pub fn new(config: PsoConfig) -> Self {
        Self { config }
    }
}

impl MatchEngine for QuantizedEngine {
    fn name(&self) -> &'static str {
        "quantized"
    }

    fn solve(&mut self, req: &MatchRequest<'_>, budget: &mut EngineBudget<'_>) -> EngineOutcome {
        if budget.interrupted() {
            return EngineOutcome::Cancelled { epochs_run: 0, snapshot: None };
        }
        let (mask, q, g) = budget.dense.get(req);
        let out = QuantizedMatcher::new(self.config).run(mask, q, g);
        EngineOutcome::Served(EngineReport {
            best_fitness: out.best_fitness,
            epochs_run: out.epochs_run,
            path: MatchPath::NativeFallback,
            resumed: false,
            work: EngineWork {
                steps_run: out.steps_run,
                mac_ops: out.mac_ops,
                eltwise_ops: out.eltwise_ops,
                argmax_ops: out.argmax_ops,
                repair_nodes: out.repair_nodes,
                ..Default::default()
            },
            mappings: out.mappings,
        })
    }
}

// ---------------------------------------------------------------------------
// Serial baseline engines — Ullmann (IsoSched) and VF2
// ---------------------------------------------------------------------------

/// Serial Ullmann behind the engine interface (the IsoSched baseline).
pub struct UllmannEngine;

impl MatchEngine for UllmannEngine {
    fn name(&self) -> &'static str {
        "ullmann"
    }

    fn solve(&mut self, req: &MatchRequest<'_>, budget: &mut EngineBudget<'_>) -> EngineOutcome {
        if budget.interrupted() {
            return EngineOutcome::Cancelled { epochs_run: 0, snapshot: None };
        }
        let (mask, q, g) = budget.dense.get(req);
        let (found, stats) = ullmann_find_first(mask, q, g, budget.nodes);
        let mappings: Vec<Mapping> = found.into_iter().collect();
        EngineOutcome::Served(EngineReport {
            best_fitness: if mappings.is_empty() { f32::NEG_INFINITY } else { 0.0 },
            epochs_run: 0,
            path: MatchPath::Ullmann,
            resumed: false,
            work: EngineWork {
                nodes_visited: stats.nodes_visited,
                refine_passes: stats.refine_passes,
                ..Default::default()
            },
            mappings,
        })
    }
}

/// Serial VF2 behind the engine interface (the second serial baseline).
pub struct Vf2Engine;

impl MatchEngine for Vf2Engine {
    fn name(&self) -> &'static str {
        "vf2"
    }

    fn solve(&mut self, req: &MatchRequest<'_>, budget: &mut EngineBudget<'_>) -> EngineOutcome {
        if budget.interrupted() {
            return EngineOutcome::Cancelled { epochs_run: 0, snapshot: None };
        }
        let (mask, q, g) = budget.dense.get(req);
        let (found, stats) = vf2_find_first(mask, q, g, budget.nodes);
        let mappings: Vec<Mapping> = found.into_iter().collect();
        EngineOutcome::Served(EngineReport {
            best_fitness: if mappings.is_empty() { f32::NEG_INFINITY } else { 0.0 },
            epochs_run: 0,
            path: MatchPath::Vf2,
            resumed: false,
            work: EngineWork { nodes_visited: stats.states, ..Default::default() },
            mappings,
        })
    }
}

// ---------------------------------------------------------------------------
// Padding helpers — the artifact-boundary densification
// ---------------------------------------------------------------------------

/// Copy an r×c flat dense block into the top-left of a padded flat
/// buffer with `pc` columns (padding stays zero).
fn pad_rows(dst: &mut [f32], src: &[f32], r: usize, c: usize, pc: usize) {
    debug_assert!(src.len() == r * c && dst.len() >= r * pc);
    dst.iter_mut().for_each(|x| *x = 0.0);
    for i in 0..r {
        dst[i * pc..i * pc + c].copy_from_slice(&src[i * c..(i + 1) * c]);
    }
}

/// Gather the top-left r×c block of a padded flat buffer with `pc`
/// columns back into a dense r×c vector — the padding-agnostic form a
/// [`SwarmSnapshot`] stores so it survives shard migration.
fn gather_rows(src: &[f32], r: usize, c: usize, pc: usize) -> Vec<f32> {
    debug_assert!(src.len() >= r * pc);
    let mut out = vec![0.0f32; r * c];
    for i in 0..r {
        out[i * c..(i + 1) * c].copy_from_slice(&src[i * pc..i * pc + c]);
    }
    out
}

/// Scatter a CSR adjacency's edges into a padded pc×pc flat {0,1}
/// buffer.
fn pad_edges(dst: &mut [f32], adj: &Csr, pc: usize) {
    debug_assert!(adj.nodes() <= pc && dst.len() == pc * pc);
    dst.iter_mut().for_each(|x| *x = 0.0);
    for (u, v) in adj.edges() {
        dst[u as usize * pc + v as usize] = 1.0;
    }
}

/// Random mask-respecting row-stochastic init of one padded particle,
/// straight off the packed mask (consumes the RNG stream in the same
/// order as the dense-mask init it replaces).
fn init_padded_particle(flat: &mut [f32], mask: &BitMask, pm: usize, rng: &mut Rng) {
    flat.iter_mut().for_each(|x| *x = 0.0);
    for i in 0..mask.rows() {
        let mut sum = 0.0;
        for j in 0..mask.cols() {
            if mask.get(i, j) {
                let v = rng.f32() + 1e-3;
                flat[i * pm + j] = v;
                sum += v;
            }
        }
        if sum > 0.0 {
            for j in 0..mask.cols() {
                flat[i * pm + j] /= sum;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::MatchProblem;
    use crate::graph::{gen_chain, Dag, NodeKind};
    use crate::matcher::build_mask;
    use crate::scheduler::Priority;

    fn chain_problem(n: usize, m: usize) -> MatchProblem {
        let qd = gen_chain(n, NodeKind::Compute);
        let gd = gen_chain(m, NodeKind::Universal);
        MatchProblem::from_dags(&qd, &gd)
    }

    fn serve(ctl: &mut GlobalController, problem: &MatchProblem) -> MatchOutcome {
        let cancel = CancelToken::new();
        ctl.serve(&problem.request(1, Priority::Urgent, None), &cancel)
    }

    #[test]
    fn fallback_only_serves_quantized() {
        let problem = chain_problem(4, 8);
        let mut ctl = GlobalController::fallback_only(PsoConfig { seed: 3, ..Default::default() });
        let out = serve(&mut ctl, &problem);
        assert_eq!(out.path, MatchPath::NativeFallback);
        assert!(out.matched());
        assert!(mapping_is_feasible_sparse(&out.mappings[0], &problem.query, &problem.target));
        assert_eq!(ctl.stats().matched, 1);
        assert_eq!(ctl.stats().fallbacks, 0, "head-of-chain service is not a fallback");
    }

    /// A default controller always has a working epoch path, even with
    /// no artifacts and no XLA anywhere.
    #[test]
    fn default_controller_serves_epoch_chain() {
        let mut ctl = GlobalController::new(PsoConfig { seed: 5, ..Default::default() })
            .expect("controller");
        assert_eq!(ctl.engine_names(), vec!["epoch", "quantized"]);
        let problem = chain_problem(4, 8);
        let out = serve(&mut ctl, &problem);
        assert!(
            matches!(out.path, MatchPath::NativeEpoch | MatchPath::Pjrt),
            "unexpected path {:?}",
            out.path
        );
        assert!(out.matched(), "epoch path found no mapping (fitness {})", out.best_fitness);
        assert!(mapping_is_feasible_sparse(&out.mappings[0], &problem.query, &problem.target));
        assert_eq!(ctl.stats().fallbacks, 0);
    }

    #[test]
    fn epoch_path_is_deterministic() {
        let problem = chain_problem(4, 8);
        let run = || {
            let mut ctl = GlobalController::new(PsoConfig { seed: 11, ..Default::default() })
                .expect("controller");
            serve(&mut ctl, &problem)
        };
        let a = run();
        let b = run();
        assert_eq!(a.mappings, b.mappings);
        assert_eq!(a.best_fitness, b.best_fitness);
        assert_eq!(a.epochs_run, b.epochs_run);
    }

    /// A request whose mask has an empty candidate row is rejected
    /// before any engine runs — word-wise on the packed mask.
    #[test]
    fn infeasible_mask_is_rejected_before_search() {
        let qd = gen_chain(4, NodeKind::Compute);
        let gd = gen_chain(8, NodeKind::Universal);
        let mut mask = build_mask(&qd, &gd);
        for j in 0..mask.cols() {
            mask[(2, j)] = 0.0; // query vertex 2 has no candidates
        }
        let problem = MatchProblem::from_dense(&mask, &qd.adjacency(), &gd.adjacency());
        let mut ctl =
            GlobalController::new(PsoConfig { seed: 9, ..Default::default() }).expect("controller");
        let out = serve(&mut ctl, &problem);
        assert_eq!(out.path, MatchPath::Rejected);
        assert!(!out.matched());
        assert_eq!(out.epochs_run, 0);
        assert_eq!(ctl.stats().rejected, 1);
        assert_eq!(ctl.stats().epochs_total, 0);
        // the fallback-only chain rejects identically
        let mut fallback = GlobalController::fallback_only(PsoConfig::default());
        assert_eq!(serve(&mut fallback, &problem).path, MatchPath::Rejected);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn pjrt_path_matches_when_artifacts_present() {
        let engine = match EpochEngine::new(PsoConfig { seed: 5, ..Default::default() }) {
            Ok(e) => e,
            Err(_) => return,
        };
        if !engine.has_pjrt() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut ctl = GlobalController::with_engines(vec![
            Box::new(engine),
            Box::new(QuantizedEngine::new(PsoConfig { seed: 5, ..Default::default() })),
        ]);
        let problem = chain_problem(4, 8);
        let out = serve(&mut ctl, &problem);
        assert_eq!(out.path, MatchPath::Pjrt);
        assert!(out.matched(), "PJRT path found no mapping (fitness {})", out.best_fitness);
    }

    /// A 3-fan-out star cannot embed into a chain, but its full mask has
    /// no empty row — the epoch episode runs its whole budget unless
    /// something stops it (the deterministic long-running victim).
    fn infeasible_star_problem() -> MatchProblem {
        let mut q = crate::util::MatF::zeros(4, 4);
        q[(0, 1)] = 1.0;
        q[(0, 2)] = 1.0;
        q[(0, 3)] = 1.0;
        let gd = gen_chain(8, NodeKind::Universal);
        MatchProblem::from_dense(&crate::util::MatF::full(4, 8, 1.0), &q, &gd.adjacency())
    }

    /// Episode slicing + warm-start resume, end to end through the real
    /// engine chain: a quota'd episode yields `Cancelled` with a barrier
    /// snapshot, and the resumed episode is bit-identical to the cold
    /// run continued from that barrier — fewer epochs, same fitness,
    /// same (empty) feasible set.
    #[test]
    fn epoch_quota_yields_snapshot_and_resume_is_bit_exact() {
        let cfg = PsoConfig { seed: 21, epochs: 12, repair_budget: 500, ..Default::default() };
        let problem = infeasible_star_problem();
        let cancel = CancelToken::new();

        let mut cold_ctl = GlobalController::new(cfg).expect("controller");
        let cold = cold_ctl.serve(&problem.request(1, Priority::Normal, None), &cancel);
        assert_eq!(cold.epochs_run, 12, "infeasible episode must run its whole budget");
        assert!(!cold.resumed);
        assert!(cold.snapshot.is_none());

        let mut sliced = GlobalController::new(cfg).expect("controller").with_epoch_quota(Some(5));
        let head = sliced.serve(&problem.request(1, Priority::Normal, None), &cancel);
        assert_eq!(head.path, MatchPath::Cancelled);
        assert_eq!(head.epochs_run, 5);
        assert!(!head.resumed, "first slice is a cold start");
        let snap = head.snapshot.clone().expect("quota yield must carry a snapshot");
        assert_eq!(snap.epochs_done, 5);

        // resume on a *different* controller (migrated shard)
        let mut tail_ctl = GlobalController::new(cfg).expect("controller");
        let tail = tail_ctl
            .serve(&problem.request_resumed(1, Priority::Normal, None, Some(&snap)), &cancel);
        assert!(tail.resumed, "resumed episode must report the resumed signal");
        assert_eq!(tail.epochs_run, cold.epochs_run - 5, "resume must not redo burned epochs");
        assert_eq!(tail.best_fitness, cold.best_fitness, "resume diverged from the cold run");
        assert_eq!(tail.mappings, cold.mappings);
        assert_eq!(tail_ctl.stats().resumed, 1);

        // a re-sliced resume cancels again, with cumulative epoch history
        let head2 = sliced
            .serve(&problem.request_resumed(1, Priority::Normal, None, Some(&snap)), &cancel);
        assert_eq!(head2.path, MatchPath::Cancelled);
        assert!(head2.resumed, "cancelled-again episode had warm-started");
        assert_eq!(head2.snapshot.expect("snapshot").epochs_done, 10);
    }

    #[test]
    fn oversized_problem_falls_through_to_quantized() {
        let mut ctl = match GlobalController::new(PsoConfig::default()) {
            Ok(c) => c,
            Err(_) => return,
        };
        // 200 query vertices exceeds every size class
        let big_q = gen_chain(200, NodeKind::Compute);
        let big_g = gen_chain(210, NodeKind::Universal);
        let problem = MatchProblem::from_dags(&big_q, &big_g);
        let out = serve(&mut ctl, &problem);
        assert_eq!(out.path, MatchPath::NativeFallback);
        assert_eq!(ctl.stats().fallbacks, 1);
    }

    #[test]
    fn serial_engines_serve_through_the_same_chain_api() {
        let problem = chain_problem(4, 8);
        let chains: Vec<(Box<dyn MatchEngine>, MatchPath)> = vec![
            (
                Box::new(QuantizedEngine::new(PsoConfig { seed: 2, ..Default::default() })),
                MatchPath::NativeFallback,
            ),
            (Box::new(UllmannEngine), MatchPath::Ullmann),
            (Box::new(Vf2Engine), MatchPath::Vf2),
        ];
        for (engine, want) in chains {
            let mut ctl = GlobalController::with_engines(vec![engine]);
            let out = serve(&mut ctl, &problem);
            assert_eq!(out.path, want);
            assert!(out.matched(), "{want:?} engine failed the chain problem");
            assert!(mapping_is_feasible_sparse(&out.mappings[0], &problem.query, &problem.target));
        }
    }

    #[test]
    fn unsupported_head_engine_falls_through() {
        // an epoch engine with no backends serves nothing; the chain
        // must fall through to the quantized engine and count a fallback
        let cfg = PsoConfig { seed: 4, ..Default::default() };
        let mut ctl = GlobalController::with_engines(vec![
            Box::new(EpochEngine::with_backends(cfg, Vec::new())),
            Box::new(QuantizedEngine::new(cfg)),
        ]);
        let problem = chain_problem(4, 8);
        let out = serve(&mut ctl, &problem);
        assert_eq!(out.path, MatchPath::NativeFallback);
        assert_eq!(ctl.stats().fallbacks, 1);
    }

    #[test]
    fn padding_helpers_scatter_and_zero() {
        let src = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 2×3
        let mut dst = vec![9.0; 4 * 8];
        pad_rows(&mut dst, &src, 2, 3, 8);
        assert_eq!(&dst[0..3], &[1.0, 2.0, 3.0]);
        assert_eq!(dst[3], 0.0);
        assert_eq!(&dst[8..11], &[4.0, 5.0, 6.0]);
        assert!(dst[16..].iter().all(|&x| x == 0.0));

        let mut diamond = Dag::with_nodes(4, NodeKind::Compute);
        diamond.add_edge(0, 1);
        diamond.add_edge(0, 2);
        diamond.add_edge(1, 3);
        diamond.add_edge(2, 3);
        let csr = diamond.csr();
        let mut adj = vec![9.0f32; 6 * 6];
        pad_edges(&mut adj, &csr, 6);
        let dense = diamond.adjacency();
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(adj[i * 6 + j], dense[(i, j)], "({i},{j})");
            }
        }
        assert!(adj[4 * 6..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn particle_init_respects_packed_mask() {
        let qd = gen_chain(3, NodeKind::Compute);
        let gd = gen_chain(6, NodeKind::Universal);
        let problem = MatchProblem::from_dags(&qd, &gd);
        let mut rng = Rng::new(7);
        let pm = 8;
        let mut flat = vec![0.5f32; 4 * pm];
        init_padded_particle(&mut flat, &problem.mask, pm, &mut rng);
        let dense = problem.mask.to_matf();
        for i in 0..3 {
            let mut sum = 0.0;
            for j in 0..6 {
                if dense[(i, j)] == 0.0 {
                    assert_eq!(flat[i * pm + j], 0.0, "masked-out entry ({i},{j}) nonzero");
                }
                sum += flat[i * pm + j];
            }
            assert!((sum - 1.0).abs() < 1e-5, "row {i} sum {sum}");
        }
        // padding row untouched by mass
        assert!(flat[3 * pm..].iter().all(|&x| x == 0.0));
    }
}
