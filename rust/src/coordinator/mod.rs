//! L3 coordinator: the interrupt-driven control plane.
//!
//! * [`controller`] — the **global controller** of paper §3.4: owns the
//!   per-size-class epoch backends (pure-native by default, PJRT
//!   executables under the `pjrt` feature), launches PSO epochs, fuses
//!   multi-particle results into the global best `S*` and the elite
//!   consensus `S̄` between epochs, projects + Ullmann-verifies
//!   candidates, and manages the feasible-mapping set.  Falls back to
//!   the native quantized matcher when no backend fits (or artifacts
//!   are missing/corrupt — the failure injection path).
//! * [`event_loop`] — the interrupt service thread: urgent requests
//!   arrive over a channel, are matched on the controller thread (which
//!   exclusively owns the runtime backends — no locks on the hot path),
//!   and answered over per-request response channels.

pub mod controller;
pub mod event_loop;
pub mod queue;

pub use controller::{ControllerStats, GlobalController, MatchOutcome, MatchPath};
pub use event_loop::{CoordinatorHandle, InterruptRequest, InterruptResponse};
pub use queue::{QueuedRequest, RequestRouter, RouterStats};
