//! L3 coordinator: the interrupt-driven control plane behind one typed
//! front door.
//!
//! * [`service`] — the **`MatchService` API**: sparse owned problems
//!   ([`MatchProblem`]) and borrowed requests ([`MatchRequest`]) with
//!   priority/deadline metadata, the pluggable [`MatchEngine`] trait,
//!   cooperative [`CancelToken`] cancellation, and the threaded service
//!   front-end that wires admission to the controller.
//! * [`controller`] — the **global controller** of paper §3.4: an
//!   ordered engine chain ([`EpochEngine`] → [`QuantizedEngine`] by
//!   default, serial [`UllmannEngine`]/[`Vf2Engine`] swappable in),
//!   word-wise empty-row rejection on the packed mask, consensus fusion
//!   between epochs, projection + sparse feasibility verification.
//! * [`queue`] — the bounded admission router: (priority, deadline,
//!   FIFO) ordering via `total_cmp`, expiry shedding before an episode
//!   is wasted, worst-request eviction at capacity.
//!
//! Request lifecycle: **submit → admit → engine chain → outcome** — see
//! `rust/README.md` ("The MatchService request lifecycle").

pub mod controller;
pub mod queue;
pub mod service;

pub use controller::{
    ControllerStats, EpochEngine, GlobalController, MatchOutcome, MatchPath, QuantizedEngine,
    UllmannEngine, Vf2Engine,
};
pub use queue::{Admission, Popped, QueuedRequest, RequestRouter, RouterStats};
pub use service::{
    dense_adjacency, CancelToken, ControllerFactory, DenseCache, EngineBudget, EngineOutcome,
    EngineReport, EngineWork, MatchEngine, MatchProblem, MatchRequest, MatchResponse,
    MatchService, MatchTicket, RequestId, ServiceConfig, ServiceStats, SubmitOptions,
};
