//! The typed `MatchService` front-end: one request lifecycle for every
//! caller (CLI, simulator, benches, tests) — **submit → admit → engine
//! chain → outcome**.
//!
//! * [`MatchProblem`] owns one subgraph-matching instance in its sparse
//!   forms (query/target as [`Csr`] edge lists, compatibility as a packed
//!   [`BitMask`]); [`MatchRequest`] is the borrowed view of it that flows
//!   through [`GlobalController`] and the engines, tagged with
//!   [`Priority`], an optional deadline and a [`RequestId`].  Nothing
//!   dense crosses the API; the f32 interchange forms the artifact
//!   calling convention pins are materialized at most once per episode,
//!   at the backend boundary ([`DenseCache`] / the epoch padding).
//! * [`MatchEngine`] is the pluggable solver interface.  The controller
//!   walks an ordered chain of engines per request; implementations ship
//!   for the PSO/epoch path, the quantized matcher and the Ullmann/VF2
//!   serial baselines (see [`super::controller`]).
//! * [`MatchService`] is the threaded front door: submissions pass the
//!   bounded admission router (priority/deadline pop, expiry shedding
//!   *before* an episode is wasted) and are served one at a time on the
//!   controller thread, which exclusively owns the engines — no locks on
//!   the matching hot path.
//! * [`CancelToken`] makes in-flight episodes interruptible: a
//!   higher-priority arrival (or an explicit [`MatchTicket::cancel`])
//!   stops the running episode at the next epoch barrier — the
//!   "interruptible" in the paper's title.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::Result;

use crate::graph::{Csr, Dag};
use crate::matcher::{build_bitmask, BitMask, Mapping, PsoConfig, SwarmSnapshot};
use crate::obs::metrics::well;
use crate::obs::trace::{span, span_with, SpanKind};
use crate::scheduler::Priority;
use crate::util::MatF;

use super::controller::{ControllerStats, GlobalController, MatchOutcome, MatchPath};
use super::queue::{Admission, Popped, QueuedRequest, RequestRouter, RouterStats};

/// Unique id of one submitted request (assigned by the service; callers
/// constructing requests directly pick their own).
pub type RequestId = u64;

/// Cooperative cancellation flag shared between a submitter and the
/// episode serving its request.  Engines check it at epoch barriers —
/// never mid-kernel — so cancellation is cheap and deterministic.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> Self {
        Self(Arc::new(AtomicBool::new(false)))
    }

    /// Request cancellation at the next epoch barrier.
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Release);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// One owned subgraph-matching instance in its sparse forms.
#[derive(Clone, Debug)]
pub struct MatchProblem {
    /// Query adjacency (the urgent task's tile DAG), n vertices.
    pub query: Csr,
    /// Target adjacency (the preemptible engine graph), m vertices.
    pub target: Csr,
    /// Packed n×m compatibility mask (§3.2).
    pub mask: BitMask,
}

impl MatchProblem {
    /// Build from the two DAGs (mask from the degree + kind filters).
    pub fn from_dags(query: &Dag, target: &Dag) -> Self {
        Self { query: query.csr(), target: target.csr(), mask: build_bitmask(query, target) }
    }

    /// Build from dense f32 forms (tests / synthetic instances): packs
    /// the mask and sparsifies the adjacencies once, at the boundary.
    pub fn from_dense(mask: &MatF, q: &MatF, g: &MatF) -> Self {
        Self {
            query: Csr::from_dense(q),
            target: Csr::from_dense(g),
            mask: BitMask::from_matf(mask),
        }
    }

    /// Borrowed request view of this problem.
    pub fn request(
        &self,
        id: RequestId,
        priority: Priority,
        deadline: Option<f64>,
    ) -> MatchRequest<'_> {
        self.request_resumed(id, priority, deadline, None)
    }

    /// Borrowed request view carrying a warm-start snapshot from a
    /// previously cancelled episode (see [`SwarmSnapshot`]): engines that
    /// understand it resume from the persisted S*/S̄ instead of
    /// re-exploring from scratch.
    pub fn request_resumed<'a>(
        &'a self,
        id: RequestId,
        priority: Priority,
        deadline: Option<f64>,
        resume: Option<&'a SwarmSnapshot>,
    ) -> MatchRequest<'a> {
        MatchRequest {
            id,
            query: &self.query,
            target: &self.target,
            mask: &self.mask,
            priority,
            deadline,
            resume,
        }
    }

    /// Query vertex count.
    pub fn n(&self) -> usize {
        self.mask.rows()
    }

    /// Target vertex count.
    pub fn m(&self) -> usize {
        self.mask.cols()
    }
}

/// Borrowed view of one match request: sparse problem views + admission
/// metadata.  This is the only request shape [`GlobalController`] and
/// the engines accept.
#[derive(Clone, Copy)]
pub struct MatchRequest<'a> {
    pub id: RequestId,
    pub query: &'a Csr,
    pub target: &'a Csr,
    pub mask: &'a BitMask,
    pub priority: Priority,
    /// Absolute deadline on the service clock (s); `None` = best-effort.
    pub deadline: Option<f64>,
    /// Warm-start snapshot from a cancelled episode of the same problem.
    /// Engines that cannot use it simply ignore it.
    pub resume: Option<&'a SwarmSnapshot>,
}

impl MatchRequest<'_> {
    pub fn n(&self) -> usize {
        self.mask.rows()
    }

    pub fn m(&self) -> usize {
        self.mask.cols()
    }
}

/// Dense {0,1} adjacency of a CSR view (the interchange form dense-era
/// engines consume).
pub fn dense_adjacency(csr: &Csr) -> MatF {
    let n = csr.nodes();
    let mut out = MatF::zeros(n, n);
    for (u, v) in csr.edges() {
        out[(u as usize, v as usize)] = 1.0;
    }
    out
}

/// Lazily-built dense f32 forms of one request — the single
/// densification point of an episode.  The controller clears it per
/// request; every dense-consuming engine in the chain shares the same
/// build.
#[derive(Default)]
pub struct DenseCache {
    cached: Option<(MatF, MatF, MatF)>,
}

impl DenseCache {
    /// Forget the previous request's staging.
    pub fn clear(&mut self) {
        self.cached = None;
    }

    /// `(mask, q, g)` dense views, built on first use per episode.
    pub fn get(&mut self, req: &MatchRequest<'_>) -> (&MatF, &MatF, &MatF) {
        if self.cached.is_none() {
            self.cached = Some((
                req.mask.to_matf(),
                dense_adjacency(req.query),
                dense_adjacency(req.target),
            ));
        }
        let (mask, q, g) = self.cached.as_ref().expect("just built");
        (mask, q, g)
    }
}

/// Op-count telemetry from one engine episode — the cost models' inputs
/// (counters an engine does not track stay zero).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineWork {
    /// Fused PSO steps executed (particles × K × epochs).
    pub steps_run: usize,
    /// Serial backtracking nodes / VF2 states expanded.
    pub nodes_visited: u64,
    /// Ullmann refinement sweeps.
    pub refine_passes: u64,
    /// int8 MAC operations issued to the array model.
    pub mac_ops: u64,
    /// Element-wise PE operations.
    pub eltwise_ops: u64,
    /// Vector argmax reductions (projection).
    pub argmax_ops: u64,
    /// Ullmann-repair nodes expanded on the controller.
    pub repair_nodes: u64,
}

/// A completed engine episode.
#[derive(Clone, Debug)]
pub struct EngineReport {
    /// Feasible mappings found (possibly empty — a completed
    /// "no embedding" answer).
    pub mappings: Vec<Mapping>,
    pub best_fitness: f32,
    pub epochs_run: usize,
    /// Which execution path produced this report.
    pub path: MatchPath,
    /// The episode warm-started from the request's [`SwarmSnapshot`].
    pub resumed: bool,
    pub work: EngineWork,
}

/// What a [`MatchEngine`] produced for one request.
#[derive(Debug)]
pub enum EngineOutcome {
    /// The engine ran the episode to completion.
    Served(EngineReport),
    /// The problem shape is outside what this engine can serve; the
    /// chain consults the next engine.
    Unsupported,
    /// The episode was interrupted at an epoch barrier by the request's
    /// [`CancelToken`] (or its epoch quota).  Engines that maintain
    /// resumable swarm state hand back the barrier snapshot so a
    /// resubmission warm-starts instead of re-exploring.
    Cancelled { epochs_run: usize, snapshot: Option<SwarmSnapshot> },
    /// The engine failed (e.g. a backend error); the chain moves on.
    Failed(String),
}

/// Episode-scoped execution context handed to each engine in the chain.
pub struct EngineBudget<'a> {
    /// Node budget for serial backtracking engines.
    pub nodes: u64,
    /// Cooperative cancellation; engines check it at epoch barriers.
    pub cancel: &'a CancelToken,
    /// Hard episode expiry on the host clock (the request's deadline,
    /// anchored by the controller).  Checked at the same barriers as
    /// `cancel` — a deadline that expires *mid-episode* stops the
    /// episode instead of letting it run uselessly to completion.
    pub expires_at: Option<Instant>,
    /// Episode slicing: max epochs this episode may run before yielding
    /// at the barrier with a resume snapshot (`Cancelled`).  `None` =
    /// unbounded.  Deterministic — the knob the cluster (and the tests)
    /// use to bound episode occupancy on a shared shard.
    pub epoch_quota: Option<usize>,
    /// Shared dense staging: densified at most once per episode, reused
    /// by every dense-consuming engine in the chain.
    pub dense: &'a mut DenseCache,
}

impl EngineBudget<'_> {
    /// Whether the episode should stop at the next barrier (explicit
    /// cancel, preemption, or deadline expiry).
    pub fn interrupted(&self) -> bool {
        self.cancel.is_cancelled() || self.expires_at.is_some_and(|t| Instant::now() >= t)
    }

    /// Whether an episode that has already run `epochs_run` epochs has
    /// exhausted its per-episode slice.  A zero quota is treated as 1:
    /// every slice must make progress, or a resubmit loop would spin on
    /// identical snapshots forever.
    pub fn quota_reached(&self, epochs_run: usize) -> bool {
        self.epoch_quota.is_some_and(|q| epochs_run >= q.max(1))
    }
}

/// A pluggable matching engine.  [`GlobalController`] walks an ordered
/// chain of these per request; the first `Served` (or `Cancelled`)
/// outcome wins, `Unsupported`/`Failed` fall through to the next engine.
pub trait MatchEngine {
    /// Short engine name (telemetry / logs).
    fn name(&self) -> &'static str;
    /// Serve one request within the given budget.
    fn solve(&mut self, req: &MatchRequest<'_>, budget: &mut EngineBudget<'_>) -> EngineOutcome;
}

/// The service's answer to one submitted request.
#[derive(Clone, Debug)]
pub struct MatchResponse {
    pub id: RequestId,
    pub mappings: Vec<Mapping>,
    pub best_fitness: f32,
    pub epochs_run: usize,
    /// Wall-clock of the episode on this host (0 for shed requests).
    pub host_seconds: f64,
    /// Which path served — or shed/rejected/cancelled — the request.
    pub path: MatchPath,
    /// The episode warm-started from a persisted [`SwarmSnapshot`]
    /// instead of exploring from scratch.
    pub resumed: bool,
    /// Barrier snapshot of a cancelled episode: persist it (keyed by
    /// request id) and resubmit with it to warm-start — the cluster's
    /// `ResumeStore` does exactly that.
    pub snapshot: Option<SwarmSnapshot>,
}

impl MatchResponse {
    pub fn matched(&self) -> bool {
        !self.mappings.is_empty()
    }

    fn from_outcome(id: RequestId, o: MatchOutcome) -> Self {
        Self {
            id,
            mappings: o.mappings,
            best_fitness: o.best_fitness,
            epochs_run: o.epochs_run,
            host_seconds: o.host_seconds,
            path: o.path,
            resumed: o.resumed,
            snapshot: o.snapshot,
        }
    }

    /// Shed by admission.  A warm-start snapshot the submission carried
    /// is handed back untouched — shedding must never destroy persisted
    /// episode progress (the cluster re-stashes it for a later
    /// resubmission).
    fn shed(id: RequestId, snapshot: Option<SwarmSnapshot>) -> Self {
        Self {
            id,
            mappings: Vec::new(),
            best_fitness: f32::NEG_INFINITY,
            epochs_run: 0,
            host_seconds: 0.0,
            path: MatchPath::Shed,
            resumed: false,
            snapshot,
        }
    }

    /// Cancelled while still queued — the episode never started, so the
    /// (unused) resume snapshot is handed back for a later resubmission.
    fn cancelled(id: RequestId, epochs_run: usize, snapshot: Option<SwarmSnapshot>) -> Self {
        Self {
            id,
            mappings: Vec::new(),
            best_fitness: f32::NEG_INFINITY,
            epochs_run,
            host_seconds: 0.0,
            path: MatchPath::Cancelled,
            resumed: false,
            snapshot,
        }
    }
}

/// Admission knobs for a [`MatchService`].
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Bounded admission depth: at capacity, the worst queued request is
    /// evicted when a better one arrives (and the newcomer is shed when
    /// everything queued outranks it).
    pub queue_depth: usize,
    /// Episode slicing: max epochs one episode may occupy the controller
    /// before yielding at the barrier with a resume snapshot (answered
    /// as `Cancelled`; resubmit with the snapshot to continue).  `None`
    /// = episodes run to completion.
    pub epoch_quota: Option<usize>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self { queue_depth: 64, epoch_quota: None }
    }
}

/// Aggregate service telemetry: controller (episodes) + admission router
/// (queueing/shedding).  Published by the service thread before every
/// response, readable without blocking on the controller.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceStats {
    pub controller: ControllerStats,
    pub router: RouterStats,
}

/// A submitted request: await the response, or cancel the episode.
pub struct MatchTicket {
    pub id: RequestId,
    cancel: CancelToken,
    rx: mpsc::Receiver<MatchResponse>,
}

impl MatchTicket {
    /// Block until the service answers.
    pub fn wait(self) -> Result<MatchResponse> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("match service dropped the request"))
    }

    /// Non-blocking poll.
    pub fn try_wait(&self) -> Option<MatchResponse> {
        self.rx.try_recv().ok()
    }

    /// Stop the episode at its next epoch barrier (or before it starts).
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// The request's cancellation token.
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }
}

/// Builds the controller (engine chain) inside the service thread, so
/// engines never have to cross threads.
pub type ControllerFactory = Box<dyn FnOnce() -> GlobalController + Send>;

/// Request id, priority and cancel token of the episode currently on
/// the controller thread (preemption bookkeeping, plus the in-flight
/// inventory fleet supervision replays after a shard failure).
type InFlight = Option<(RequestId, Priority, CancelToken)>;

/// Caller-side knobs for one submission beyond (problem, priority,
/// deadline) — see [`MatchService::submit_with`].
#[derive(Debug, Default)]
pub struct SubmitOptions {
    /// Externally-assigned request id (cluster routers hand out globally
    /// unique ids across shards); `None` = the service assigns one.
    pub id: Option<RequestId>,
    /// Warm-start snapshot from a previously cancelled episode of the
    /// same problem (same shard or migrated).
    pub resume: Option<SwarmSnapshot>,
}

struct Submission {
    id: RequestId,
    problem: MatchProblem,
    priority: Priority,
    deadline: Option<f64>,
    resume: Option<SwarmSnapshot>,
    cancel: CancelToken,
    /// Flipped (before the response is sent) once this request has been
    /// answered — the submitter's preemption check reads it under the
    /// in-flight lock so it never cancels an episode on behalf of a
    /// request that is already done.
    answered: Arc<AtomicBool>,
    respond: mpsc::Sender<MatchResponse>,
}

/// Answer a submission (marks it answered first; see `Submission`).
fn answer(sub: Submission, resp: MatchResponse) {
    sub.answered.store(true, Ordering::Release);
    let _ = sub.respond.send(resp);
}

enum Msg {
    Submit(Submission),
    Shutdown,
}

/// Handle to a running match service (the coordinator front door).
///
/// Dropping the handle shuts the service down: the in-flight episode is
/// cancelled at its next epoch barrier and still-queued requests are
/// answered with [`MatchPath::Shed`].
pub struct MatchService {
    tx: mpsc::Sender<Msg>,
    join: Option<JoinHandle<()>>,
    start: Instant,
    next_id: AtomicU64,
    stats: Arc<Mutex<ServiceStats>>,
    inflight: Arc<Mutex<InFlight>>,
}

impl MatchService {
    /// Spawn with the default engine chain (epoch backends first, the
    /// quantized matcher as the universal fallback).  Engine/backend
    /// construction failures degrade to the fallback chain, never fatal.
    pub fn spawn(config: PsoConfig) -> Result<Self> {
        Self::spawn_configured(ServiceConfig::default(), config)
    }

    /// Default engine chain with explicit admission knobs — how the
    /// cluster spawns one shard per modeled accelerator.
    pub fn spawn_configured(cfg: ServiceConfig, config: PsoConfig) -> Result<Self> {
        Self::spawn_with(
            cfg,
            Box::new(move || match GlobalController::new(config) {
                Ok(c) => c,
                Err(e) => {
                    crate::log_warn!("controller init degraded: {e:#}");
                    GlobalController::fallback_only(config)
                }
            }),
        )
    }

    /// Spawn with an explicit controller factory — how benches, the CLI
    /// and the simulator swap engine chains in behind the same service
    /// API.
    pub fn spawn_with(cfg: ServiceConfig, factory: ControllerFactory) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let stats = Arc::new(Mutex::new(ServiceStats::default()));
        let inflight: Arc<Mutex<InFlight>> = Arc::new(Mutex::new(None));
        let start = Instant::now();
        let thread_stats = Arc::clone(&stats);
        let thread_inflight = Arc::clone(&inflight);
        let join = std::thread::Builder::new()
            .name("immsched-match-service".into())
            .spawn(move || service_loop(rx, cfg, factory, start, thread_stats, thread_inflight))?;
        Ok(Self { tx, join: Some(join), start, next_id: AtomicU64::new(1), stats, inflight })
    }

    /// Seconds since service start — the clock deadlines are measured on.
    pub fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Submit a request for admission.  A strictly lower-priority episode
    /// already running on the controller is cancelled at its next epoch
    /// barrier so this arrival can be served sooner.  (The service loop
    /// publishes the in-flight episode under the same lock it drains
    /// arrivals with, so a submission either observes the episode here
    /// or is ranked against it before the episode starts.)
    pub fn submit(
        &self,
        problem: MatchProblem,
        priority: Priority,
        deadline: Option<f64>,
    ) -> Result<MatchTicket> {
        self.submit_with(problem, priority, deadline, SubmitOptions::default())
    }

    /// [`Self::submit`] with an external request id and/or a warm-start
    /// snapshot (see [`SubmitOptions`]) — the shard-addressable entry
    /// point the cluster router uses.
    pub fn submit_with(
        &self,
        problem: MatchProblem,
        priority: Priority,
        deadline: Option<f64>,
        opts: SubmitOptions,
    ) -> Result<MatchTicket> {
        let id = opts.id.unwrap_or_else(|| self.next_id.fetch_add(1, Ordering::Relaxed));
        span_with(id, SpanKind::Submit, || format!("priority={}", priority.name()));
        let cancel = CancelToken::new();
        let answered = Arc::new(AtomicBool::new(false));
        let (respond, rx) = mpsc::channel();
        let sub = Submission {
            id,
            problem,
            priority,
            deadline,
            resume: opts.resume,
            cancel: cancel.clone(),
            answered: Arc::clone(&answered),
            respond,
        };
        self.tx
            .send(Msg::Submit(sub))
            .map_err(|_| anyhow::anyhow!("match service thread gone"))?;
        // Preempt only on behalf of a request that can still be served:
        // not dead-on-arrival, and not already answered (the service
        // publishes in-flight episodes under this same lock, so the
        // answered flag read here is current — without it, a submission
        // served before this check could cancel an unrelated episode).
        let admissible = !deadline.is_some_and(|d| d <= self.now());
        if admissible {
            let guard = self.inflight.lock().unwrap();
            if !answered.load(Ordering::Acquire) {
                if let Some((_, running, token)) = guard.as_ref() {
                    if *running < priority {
                        token.cancel();
                    }
                }
            }
        }
        Ok(MatchTicket { id, cancel, rx })
    }

    /// Submit and wait for the outcome.
    pub fn match_blocking(
        &self,
        problem: MatchProblem,
        priority: Priority,
        deadline: Option<f64>,
    ) -> Result<MatchResponse> {
        self.submit(problem, priority, deadline)?.wait()
    }

    /// Latest published telemetry (non-blocking; never waits on the
    /// controller thread).
    pub fn stats(&self) -> ServiceStats {
        *self.stats.lock().unwrap()
    }

    /// Priority of the episode currently being served, if any.
    pub fn in_flight(&self) -> Option<Priority> {
        self.in_flight_request().map(|(_, p)| p)
    }

    /// In-flight request inventory: id and priority of the episode on
    /// the controller right now.  Fleet supervision reads this through
    /// the stats probe so a dead shard's victim is known for replay.
    pub fn in_flight_request(&self) -> Option<(RequestId, Priority)> {
        self.inflight.lock().unwrap().as_ref().map(|(id, p, _)| (*id, *p))
    }
}

impl Drop for MatchService {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some((_, _, token)) = self.inflight.lock().unwrap().as_ref() {
            token.cancel();
        }
        if let Some(join) = self.join.take() {
            let _ = join.join();
        }
    }
}

fn service_loop(
    rx: mpsc::Receiver<Msg>,
    cfg: ServiceConfig,
    factory: ControllerFactory,
    start: Instant,
    stats: Arc<Mutex<ServiceStats>>,
    inflight: Arc<Mutex<InFlight>>,
) {
    // Anchor the controller's deadline clock to the service clock, so
    // request deadlines become hard mid-episode expiry at epoch barriers.
    let mut controller =
        factory().with_clock_base(start).with_epoch_quota(cfg.epoch_quota);
    let mut router = RequestRouter::new(cfg.queue_depth.max(1));
    let mut pending: BTreeMap<RequestId, Submission> = BTreeMap::new();
    let mut open = true;

    while open {
        // Block for work only when the queue is idle…
        if router.is_empty() {
            match rx.recv() {
                Ok(Msg::Submit(sub)) => admit_one(sub, &mut router, &mut pending, &stats, start),
                Ok(Msg::Shutdown) | Err(_) => open = false,
            }
        }
        // …then drain the arrival burst so admission ranks every
        // contender before the next episode starts.
        while open {
            match rx.try_recv() {
                Ok(Msg::Submit(sub)) => admit_one(sub, &mut router, &mut pending, &stats, start),
                Ok(Msg::Shutdown) | Err(mpsc::TryRecvError::Disconnected) => open = false,
                Err(mpsc::TryRecvError::Empty) => break,
            }
        }
        if !open {
            break;
        }
        let now = start.elapsed().as_secs_f64();
        match router.pop(now) {
            None => {}
            Some(Popped::Shed(ticket)) => {
                shed_response(ticket.id, &mut pending, &router, &stats);
            }
            Some(Popped::Serve(ticket)) => {
                let Some(mut sub) = pending.remove(&ticket.id) else { continue };
                // Close the preemption race: drain late arrivals and
                // publish the in-flight episode under one lock.  Every
                // submit either observes the episode (and cancels it at
                // the barrier) or lands in the queue right here, where a
                // strictly better request wins the controller instead.
                let outranked = {
                    let mut guard = inflight.lock().unwrap();
                    loop {
                        match rx.try_recv() {
                            Ok(Msg::Submit(late)) => {
                                admit_one(late, &mut router, &mut pending, &stats, start)
                            }
                            Ok(Msg::Shutdown) | Err(mpsc::TryRecvError::Disconnected) => {
                                open = false;
                                break;
                            }
                            Err(mpsc::TryRecvError::Empty) => break,
                        }
                    }
                    let outranked =
                        router.peek().is_some_and(|best| best.priority > sub.priority);
                    if !outranked {
                        *guard = Some((sub.id, sub.priority, sub.cancel.clone()));
                    }
                    outranked
                };
                if !open {
                    // shutdown raced the pop: shed instead of serving
                    *inflight.lock().unwrap() = None;
                    let id = sub.id;
                    well::SERVICE_SHED.inc();
                    span_with(id, SpanKind::Shed, || "reason=shutdown".to_string());
                    let snapshot = sub.resume.take();
                    answer(sub, MatchResponse::shed(id, snapshot));
                    continue;
                }
                if outranked {
                    // hand the controller to the better arrival; restore
                    // this request with its original admission order (no
                    // stat double-count, FIFO position kept)
                    router.restore(ticket);
                    pending.insert(sub.id, sub);
                    continue;
                }
                serve_one(&mut controller, sub, &inflight, &router, &stats);
            }
        }
    }

    // Shutdown: whatever is still queued is shed, not silently dropped.
    for ticket in router.drain() {
        shed_response(ticket.id, &mut pending, &router, &stats);
    }
}

fn admit_one(
    mut sub: Submission,
    router: &mut RequestRouter,
    pending: &mut BTreeMap<RequestId, Submission>,
    stats: &Arc<Mutex<ServiceStats>>,
    start: Instant,
) {
    let now = start.elapsed().as_secs_f64();
    let ticket = QueuedRequest::new(sub.id, sub.priority, sub.deadline, now);
    match router.admit(ticket, now) {
        Admission::Shed => {
            stats.lock().unwrap().router = router.stats();
            let id = sub.id;
            well::SERVICE_SHED.inc();
            span_with(id, SpanKind::Shed, || "reason=admission".to_string());
            let snapshot = sub.resume.take();
            answer(sub, MatchResponse::shed(id, snapshot));
        }
        Admission::Admitted { evicted } => {
            let id = sub.id;
            pending.insert(id, sub);
            stats.lock().unwrap().router = router.stats();
            well::SERVICE_ADMITTED.inc();
            span(id, SpanKind::Admit);
            if let Some(evicted_id) = evicted {
                if let Some(mut victim) = pending.remove(&evicted_id) {
                    well::SERVICE_SHED.inc();
                    span_with(evicted_id, SpanKind::Shed, || "reason=evicted".to_string());
                    let snapshot = victim.resume.take();
                    answer(victim, MatchResponse::shed(evicted_id, snapshot));
                }
            }
        }
    }
}

fn shed_response(
    id: RequestId,
    pending: &mut BTreeMap<RequestId, Submission>,
    router: &RequestRouter,
    stats: &Arc<Mutex<ServiceStats>>,
) {
    stats.lock().unwrap().router = router.stats();
    if let Some(mut sub) = pending.remove(&id) {
        well::SERVICE_SHED.inc();
        span_with(id, SpanKind::Shed, || "reason=expired".to_string());
        let snapshot = sub.resume.take();
        answer(sub, MatchResponse::shed(id, snapshot));
    }
}

/// Record an episode's lifecycle spans and hot-path counters from its
/// final response — one place, shared by the serve and preempt paths,
/// so the in-process and worker-hosted services emit identical
/// timelines.
fn record_episode_telemetry(resp: &MatchResponse) {
    if resp.resumed {
        well::SERVICE_RESUMED.inc();
        span(resp.id, SpanKind::Resume);
    }
    well::MATCHER_EPOCHS.add(resp.epochs_run as u64);
    span_with(resp.id, SpanKind::Slice, || {
        format!("epochs={} path={}", resp.epochs_run, resp.path.name())
    });
    if resp.path == MatchPath::Cancelled {
        well::SERVICE_PREEMPTED.inc();
        span(resp.id, SpanKind::Preempt);
        if resp.snapshot.is_some() {
            span_with(resp.id, SpanKind::Snapshot, || {
                format!("epochs_done={}", resp.epochs_run)
            });
        }
    }
}

/// Run one admitted episode.  The caller has already published the
/// in-flight slot under the drain lock; this clears it when done.
fn serve_one(
    controller: &mut GlobalController,
    mut sub: Submission,
    inflight: &Arc<Mutex<InFlight>>,
    router: &RequestRouter,
    stats: &Arc<Mutex<ServiceStats>>,
) {
    let response = if sub.cancel.is_cancelled() {
        // cancelled while queued — never reaches the controller; an
        // unused warm-start snapshot is handed back for resubmission
        let snapshot = sub.resume.take();
        MatchResponse::cancelled(sub.id, 0, snapshot)
    } else {
        let req =
            sub.problem.request_resumed(sub.id, sub.priority, sub.deadline, sub.resume.as_ref());
        let outcome = controller.serve(&req, &sub.cancel);
        MatchResponse::from_outcome(sub.id, outcome)
    };
    record_episode_telemetry(&response);
    *inflight.lock().unwrap() = None;
    {
        let mut published = stats.lock().unwrap();
        published.controller = controller.stats();
        published.router = router.stats();
    }
    answer(sub, response);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_chain, NodeKind};
    use crate::matcher::mapping_is_feasible_sparse;

    fn chain_problem(n: usize, m: usize) -> MatchProblem {
        let qd = gen_chain(n, NodeKind::Compute);
        let gd = gen_chain(m, NodeKind::Universal);
        MatchProblem::from_dags(&qd, &gd)
    }

    #[test]
    fn submit_round_trip() {
        let service = MatchService::spawn(PsoConfig { seed: 9, ..Default::default() }).unwrap();
        let problem = chain_problem(4, 8);
        let resp = service
            .match_blocking(problem.clone(), Priority::Urgent, None)
            .expect("service answers");
        assert!(resp.matched());
        assert!(mapping_is_feasible_sparse(&resp.mappings[0], &problem.query, &problem.target));
        assert_ne!(resp.path, MatchPath::Shed);
        let stats = service.stats();
        assert_eq!(stats.controller.requests, 1);
        assert_eq!(stats.controller.matched, 1);
        assert_eq!(stats.router.served, 1);
    }

    #[test]
    fn concurrent_submissions_are_serialized_safely() {
        let service = MatchService::spawn(PsoConfig { seed: 10, ..Default::default() }).unwrap();
        let mut tickets = Vec::new();
        for i in 0..4 {
            let problem = chain_problem(3 + i % 2, 8);
            let ticket = service.submit(problem.clone(), Priority::Normal, None).unwrap();
            tickets.push((problem, ticket));
        }
        for (problem, ticket) in tickets {
            let resp = ticket.wait().unwrap();
            assert!(resp.matched());
            assert!(mapping_is_feasible_sparse(&resp.mappings[0], &problem.query, &problem.target));
        }
        assert_eq!(service.stats().controller.requests, 4);
    }

    #[test]
    fn shutdown_on_drop_does_not_hang() {
        let service = MatchService::spawn(PsoConfig::default()).unwrap();
        drop(service);
    }

    #[test]
    fn dense_cache_builds_once_per_episode() {
        let problem = chain_problem(3, 6);
        let req = problem.request(1, Priority::Normal, None);
        let mut cache = DenseCache::default();
        {
            let (mask, q, g) = cache.get(&req);
            assert_eq!((mask.rows(), mask.cols()), (3, 6));
            assert_eq!(q.rows(), 3);
            assert_eq!(g.rows(), 6);
        }
        // dense forms agree with the sparse views
        let (mask, q, g) = cache.get(&req);
        assert_eq!(BitMask::from_matf(mask), problem.mask);
        assert_eq!(&Csr::from_dense(q), &problem.query);
        assert_eq!(&Csr::from_dense(g), &problem.target);
    }

    #[test]
    fn cancel_token_round_trip() {
        let token = CancelToken::new();
        assert!(!token.is_cancelled());
        let other = token.clone();
        other.cancel();
        assert!(token.is_cancelled());
    }
}
