//! Target-graph extraction: the preemptible engine subgraph → `G`.
//!
//! The matcher's target graph abstracts "which engines could the urgent
//! task occupy, and which on-chip links connect them" (paper §3.2: "the
//! preemptible PE array of the accelerator" as a DAG).  Engines are mesh
//! nodes; the TSS cascade streams tile outputs along mesh links, so the
//! target DAG contains an edge a→b when engines a, b are mesh-adjacent
//! and b follows a in the (row-major snake) cascade order — an acyclic
//! orientation of the mesh that matches how cascaded engines are chained.

use crate::graph::{Dag, NodeKind};

use super::platform::Platform;

/// Build the target DAG over a set of preemptible engines.
///
/// `preemptible[e]` marks engine `e` as available for the urgent task
/// (idle, or running a lower-priority task below its preemption ratio).
/// Vertices of the returned DAG are the preemptible engines in ascending
/// id order; `vertex_engine[v]` maps a vertex back to its engine id.
pub fn build_target_graph(p: &Platform, preemptible: &[bool]) -> (Dag, Vec<usize>) {
    assert_eq!(preemptible.len(), p.engines);
    let engines: Vec<usize> = (0..p.engines).filter(|&e| preemptible[e]).collect();
    let mut index_of = vec![usize::MAX; p.engines];
    for (v, &e) in engines.iter().enumerate() {
        index_of[e] = v;
    }

    let mut g = Dag::with_nodes(engines.len(), NodeKind::Universal);

    // snake order position: left-to-right on even rows, right-to-left on
    // odd rows — the cascade order TSS uses to chain engines
    let snake_pos = |e: usize| -> usize {
        let (x, y) = p.engine_xy(e);
        if y % 2 == 0 {
            y * p.mesh_cols + x
        } else {
            y * p.mesh_cols + (p.mesh_cols - 1 - x)
        }
    };

    // TSS cascades stream over the NoC, which reaches beyond immediate
    // mesh neighbors at one extra hop of latency; we admit links up to
    // 2 hops so the target graph's fan-out can host tile fan-outs from
    // Layer Concatenate-and-Split (without this, mesh degree ≤ 4 rejects
    // most NAS-cell queries outright).
    const REACH: usize = 2;
    for &e in &engines {
        for &f in &engines {
            if e != f && p.hops(e, f) <= REACH && snake_pos(e) < snake_pos(f) {
                g.add_edge(index_of[e], index_of[f]);
            }
        }
    }
    (g, engines)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::is_acyclic;

    #[test]
    fn full_mesh_target_is_connected_dag() {
        let p = Platform::edge();
        let (g, map) = build_target_graph(&p, &vec![true; p.engines]);
        assert_eq!(g.len(), 64);
        assert_eq!(map.len(), 64);
        assert!(is_acyclic(&g));
        // snake chain ⇒ exactly one global source and one global sink
        assert_eq!(g.sources().len(), 1);
        // interior engines have both mesh and snake links
        assert!(g.edge_count() >= 63, "must at least chain all engines");
    }

    #[test]
    fn partial_preemptible_set_restricts_vertices() {
        let p = Platform::edge();
        let mut pre = vec![false; p.engines];
        for e in [0usize, 1, 2, 8, 9, 10] {
            pre[e] = true;
        }
        let (g, map) = build_target_graph(&p, &pre);
        assert_eq!(g.len(), 6);
        assert_eq!(map, vec![0, 1, 2, 8, 9, 10]);
        assert!(is_acyclic(&g));
        // 0-1, 1-2 horizontal; 0-8, 1-9, 2-10 vertical; 9-8? snake row 1
        // goes right-to-left so 10->9->8:
        assert!(g.has_edge(0, 1) && g.has_edge(1, 2));
        assert!(g.has_edge(map.iter().position(|&e| e == 10).unwrap(),
                           map.iter().position(|&e| e == 9).unwrap()));
    }

    #[test]
    fn empty_preemptible_set_gives_empty_graph() {
        let p = Platform::edge();
        let (g, map) = build_target_graph(&p, &vec![false; p.engines]);
        assert!(g.is_empty());
        assert!(map.is_empty());
    }

    #[test]
    fn cloud_target_scales() {
        let p = Platform::cloud();
        let (g, _) = build_target_graph(&p, &vec![true; p.engines]);
        assert_eq!(g.len(), 128);
        assert!(is_acyclic(&g));
    }
}
