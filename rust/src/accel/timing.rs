//! Systolic-array timing model: cycles to execute a tile on one engine.
//!
//! A weight-stationary 128×128 array computes `rows×cols` MACs per cycle
//! at an op-dependent utilization (conv/matmul keep the array fed;
//! depthwise/pool/eltwise cannot fill both dimensions).  Fill+drain adds
//! `rows + cols` cycles per tile invocation.

use crate::graph::NodeKind;

use super::platform::Platform;

/// Per-engine timing parameters derived from a [`Platform`].
#[derive(Clone, Copy, Debug)]
pub struct EngineTiming {
    pub macs_per_cycle: u64,
    pub fill_drain_cycles: u64,
    pub clock_hz: f64,
}

impl EngineTiming {
    pub fn of(p: &Platform) -> Self {
        Self {
            macs_per_cycle: p.engine_macs(),
            fill_drain_cycles: (p.array_rows + p.array_cols) as u64,
            clock_hz: p.clock_hz,
        }
    }
}

/// Array utilization by tile kind.
///
/// Compute tiles (conv/matmul) stream well; comparison tiles use only the
/// comparator-augmented accumulator tree (paper §3.4), eltwise tiles only
/// one array dimension.
pub fn utilization(kind: NodeKind) -> f64 {
    match kind {
        NodeKind::Compute => 0.75,
        NodeKind::Compare => 0.10,
        NodeKind::Eltwise => 0.125,
        NodeKind::Move => 0.25,
        NodeKind::Universal => 0.75,
    }
}

/// Cycles for `macs` MACs of a `kind` tile on one engine.
pub fn tile_cycles(timing: &EngineTiming, kind: NodeKind, macs: u64) -> u64 {
    if macs == 0 {
        return timing.fill_drain_cycles;
    }
    let effective = (timing.macs_per_cycle as f64 * utilization(kind)).max(1.0);
    (macs as f64 / effective).ceil() as u64 + timing.fill_drain_cycles
}

/// Seconds for `macs` MACs of a `kind` tile on one engine.
pub fn tile_seconds(timing: &EngineTiming, kind: NodeKind, macs: u64) -> f64 {
    tile_cycles(timing, kind, macs) as f64 / timing.clock_hz
}

#[cfg(test)]
mod tests {
    use super::*;

    fn timing() -> EngineTiming {
        EngineTiming::of(&Platform::edge())
    }

    #[test]
    fn zero_work_costs_fill_drain() {
        let t = timing();
        assert_eq!(tile_cycles(&t, NodeKind::Compute, 0), 256);
    }

    #[test]
    fn compute_cycles_scale_linearly() {
        let t = timing();
        let one = tile_cycles(&t, NodeKind::Compute, 10_000_000);
        let two = tile_cycles(&t, NodeKind::Compute, 20_000_000);
        let ratio = (two - 256) as f64 / (one - 256) as f64;
        assert!((ratio - 2.0).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn compare_tiles_slower_than_compute() {
        let t = timing();
        let macs = 50_000_000;
        assert!(tile_cycles(&t, NodeKind::Compare, macs) > tile_cycles(&t, NodeKind::Compute, macs));
    }

    #[test]
    fn seconds_match_clock() {
        let t = timing();
        let cycles = tile_cycles(&t, NodeKind::Compute, 1_000_000);
        let secs = tile_seconds(&t, NodeKind::Compute, 1_000_000);
        assert!((secs - cycles as f64 / 700e6).abs() < 1e-15);
    }
}
