//! Engine-scratchpad (SRAM) model: banking, capacity planning and
//! CACTI-P-calibrated access energy (paper §4.1.1 models on-chip SRAM
//! with CACTI-P).
//!
//! The TSS cascade keeps a segment's weights + double-buffered tile
//! activations resident per engine; this module answers the two
//! questions the tiler and the energy book ask:
//! 1. does a segment *fit* an engine's scratchpad (capacity check that
//!    feeds the Layer Concatenate-and-Split budget)?
//! 2. what does a byte cost, as a function of macro size (CACTI's
//!    energy-per-access grows roughly with √capacity)?

/// One engine's scratchpad configuration.
#[derive(Clone, Copy, Debug)]
pub struct Scratchpad {
    /// Total bytes.
    pub bytes: u64,
    /// Independent banks (concurrent accesses without conflict).
    pub banks: usize,
    /// Word width in bytes (one access moves one word per bank).
    pub word_bytes: usize,
}

impl Scratchpad {
    /// The Table-2 platforms' engine scratchpads.
    pub fn for_engine(sram_bytes: u64) -> Self {
        Self { bytes: sram_bytes, banks: 8, word_bytes: 16 }
    }

    /// CACTI-P-style dynamic energy per byte (J): √capacity scaling
    /// anchored at 2 pJ/B for a 512 KiB macro (45 nm).
    pub fn energy_per_byte(&self) -> f64 {
        const ANCHOR_BYTES: f64 = 512.0 * 1024.0;
        const ANCHOR_J: f64 = 2.0e-12;
        ANCHOR_J * (self.bytes as f64 / ANCHOR_BYTES).sqrt().max(0.25)
    }

    /// Leakage power (W): CACTI-P's leakage grows ~linearly in capacity;
    /// anchored at 5 mW for 512 KiB (45 nm, low-leakage cells).
    pub fn leakage_watts(&self) -> f64 {
        const ANCHOR_BYTES: f64 = 512.0 * 1024.0;
        const ANCHOR_W: f64 = 5.0e-3;
        ANCHOR_W * self.bytes as f64 / ANCHOR_BYTES
    }

    /// Peak bytes/cycle the banks can source.
    pub fn bytes_per_cycle(&self) -> u64 {
        (self.banks * self.word_bytes) as u64
    }

    /// Capacity plan for one resident segment: weights + double-buffered
    /// input/output tiles.  Returns the bytes required.
    pub fn segment_footprint(weight_bytes: u64, tile_in_bytes: u64, tile_out_bytes: u64) -> u64 {
        weight_bytes + 2 * (tile_in_bytes + tile_out_bytes)
    }

    /// Whether a segment fits (with a 10% allocator margin).
    pub fn fits(&self, footprint: u64) -> bool {
        footprint as f64 <= self.bytes as f64 * 0.9
    }

    /// Cycles to stream `bytes` through the banks, including bank
    /// conflicts for a given conflict rate in [0, 1).
    pub fn stream_cycles(&self, bytes: u64, conflict_rate: f64) -> u64 {
        let ideal = bytes.div_ceil(self.bytes_per_cycle());
        (ideal as f64 * (1.0 + conflict_rate)).ceil() as u64
    }
}

/// Split a segment across `k` engines when it exceeds one scratchpad:
/// returns the minimum k (weights are partitioned, activations
/// replicated at the halo).
pub fn engines_needed(pad: &Scratchpad, weight_bytes: u64, tile_bytes: u64) -> usize {
    for k in 1..=4096usize {
        let per_engine =
            Scratchpad::segment_footprint(weight_bytes / k as u64, tile_bytes, tile_bytes);
        if pad.fits(per_engine) {
            return k;
        }
    }
    4096
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pad() -> Scratchpad {
        Scratchpad::for_engine(512 * 1024)
    }

    #[test]
    fn energy_anchored_and_scaling() {
        let p = pad();
        assert!((p.energy_per_byte() - 2.0e-12).abs() < 1e-15);
        let big = Scratchpad::for_engine(2 * 1024 * 1024);
        assert!(big.energy_per_byte() > p.energy_per_byte());
        let small = Scratchpad::for_engine(32 * 1024);
        assert!(small.energy_per_byte() < p.energy_per_byte());
    }

    #[test]
    fn leakage_scales_linearly() {
        let p = pad();
        let double = Scratchpad::for_engine(1024 * 1024);
        assert!((double.leakage_watts() / p.leakage_watts() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn capacity_check() {
        let p = pad();
        // 300 KiB weights + 2*(32+32) KiB buffers = 428 KiB < 90% of 512 KiB
        let fp = Scratchpad::segment_footprint(300 << 10, 32 << 10, 32 << 10);
        assert!(p.fits(fp));
        // 600 KiB weights never fit
        assert!(!p.fits(Scratchpad::segment_footprint(600 << 10, 0, 0)));
    }

    #[test]
    fn engines_needed_partitions_weights() {
        let p = pad();
        // 2 MiB of weights with 16 KiB tiles: needs ~5 engines
        let k = engines_needed(&p, 2 << 20, 16 << 10);
        assert!((4..=8).contains(&k), "k = {k}");
        // tiny segment: one engine
        assert_eq!(engines_needed(&p, 64 << 10, 8 << 10), 1);
    }

    #[test]
    fn stream_cycles_account_for_conflicts() {
        let p = pad();
        let clean = p.stream_cycles(1 << 20, 0.0);
        let contended = p.stream_cycles(1 << 20, 0.5);
        assert_eq!(clean, (1 << 20) / 128);
        assert!((contended as f64 / clean as f64 - 1.5).abs() < 0.01);
    }
}
