//! Accelerator hardware model: platforms (Table 2), systolic timing,
//! mesh NoC, SRAM/DRAM energy, the preemptible target graph, and the
//! ILP-style mapping tensors of §3.1.
//!
//! The paper synthesizes Verilog engines at FreePDK-45nm and models SRAM
//! with CACTI-P and the NoC with McPAT; here the same quantities come
//! from an analytic model with constants calibrated to the published
//! 45 nm numbers (DESIGN.md §4 records the substitution).  All evaluation
//! claims are *relative* (IMMSched vs baselines on identical hardware),
//! which the analytic model preserves.

pub mod dram;
pub mod energy;
pub mod ilp;
pub mod memory;
pub mod noc;
pub mod platform;
pub mod target_graph;
pub mod timing;

pub use dram::DramChannel;
pub use energy::{EnergyBook, EnergyModel};
pub use ilp::{MappingTensors, TensorDims};
pub use memory::{engines_needed, Scratchpad};
pub use noc::{Mesh, NocModel};
pub use platform::{Platform, PlatformKind};
pub use target_graph::build_target_graph;
pub use timing::{tile_cycles, tile_seconds, EngineTiming};
