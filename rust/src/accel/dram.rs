//! DRAM channel model: bandwidth sharing + row-buffer locality — the
//! off-chip substrate whose avoidance is the TSS paradigm's whole point
//! (paper Fig. 3).
//!
//! LPDDR4-class edge memory: one channel, shared by every LTS layer
//! round-trip.  The model answers (a) effective bandwidth under a given
//! access pattern (row hits stream at full rate, misses pay
//! activate+precharge), and (b) service time for a set of concurrent
//! streams (fair-share with a contention penalty — the effect MoCA's
//! policy manages).

/// Channel parameters (LPDDR4-3200 x32, 45 nm-era edge SoC).
#[derive(Clone, Copy, Debug)]
pub struct DramChannel {
    /// Peak bandwidth (bytes/s).
    pub peak_bw: f64,
    /// Row-buffer (page) size in bytes.
    pub row_bytes: u64,
    /// Row activate+precharge overhead, expressed as equivalent bytes of
    /// lost transfer time per row miss.
    pub miss_penalty_bytes: u64,
    /// Per-access energy (J/byte) for streaming transfers.
    pub energy_per_byte: f64,
}

impl Default for DramChannel {
    fn default() -> Self {
        Self {
            peak_bw: 25.6e9,
            row_bytes: 2048,
            miss_penalty_bytes: 256,
            energy_per_byte: 160.0e-12,
        }
    }
}

impl DramChannel {
    /// Effective bandwidth for an access pattern with `row_hit_rate` ∈
    /// [0, 1]: every miss wastes `miss_penalty_bytes` of transfer slots.
    pub fn effective_bw(&self, row_hit_rate: f64) -> f64 {
        let hit = row_hit_rate.clamp(0.0, 1.0);
        // per row_bytes transferred, (1-hit) misses each waste penalty
        let useful = self.row_bytes as f64;
        let wasted = (1.0 - hit) * self.miss_penalty_bytes as f64;
        self.peak_bw * useful / (useful + wasted)
    }

    /// Row-hit rate of a strided stream: consecutive within a row hits,
    /// one miss per row crossing.  `access_bytes` per element, `stride`
    /// elements apart.
    pub fn stream_hit_rate(&self, access_bytes: u64, stride_bytes: u64) -> f64 {
        let step = access_bytes.max(1) + stride_bytes;
        if step >= self.row_bytes {
            return 0.0; // every access opens a new row
        }
        let per_row = self.row_bytes / step.max(1);
        1.0 - 1.0 / per_row.max(1) as f64
    }

    /// Seconds to move `bytes` sequentially (hit rate ≈ 1 − step/row).
    pub fn stream_seconds(&self, bytes: u64) -> f64 {
        bytes as f64 / self.effective_bw(self.stream_hit_rate(64, 0))
    }

    /// Service time for `streams` concurrent sequential streams of the
    /// given sizes: fair-share bandwidth plus an interleaving penalty —
    /// concurrent streams destroy each other's row locality (hit rate
    /// degrades with the number of co-runners).
    pub fn contended_seconds(&self, stream_bytes: &[u64]) -> f64 {
        if stream_bytes.is_empty() {
            return 0.0;
        }
        let k = stream_bytes.len() as f64;
        // k interleaved streams: each switch likely lands in a different
        // row — hit rate falls as 1/k of the solo rate
        let solo_hit = self.stream_hit_rate(64, 0);
        let hit = solo_hit / k;
        let bw = self.effective_bw(hit);
        let total: u64 = stream_bytes.iter().sum();
        total as f64 / bw
    }

    /// Energy to move `bytes` (pattern-independent in this model).
    pub fn energy(&self, bytes: u64) -> f64 {
        bytes as f64 * self.energy_per_byte
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_streams_near_peak() {
        let d = DramChannel::default();
        let bw = d.effective_bw(d.stream_hit_rate(64, 0));
        assert!(bw > 0.9 * d.peak_bw, "sequential bw {bw:.3e}");
    }

    #[test]
    fn random_access_collapses_bandwidth() {
        let d = DramChannel::default();
        let random = d.effective_bw(0.0);
        let seq = d.effective_bw(1.0);
        assert!(random < seq * 0.95);
        assert!((seq - d.peak_bw).abs() < 1.0);
    }

    #[test]
    fn large_strides_always_miss() {
        let d = DramChannel::default();
        assert_eq!(d.stream_hit_rate(64, 4096), 0.0);
        assert!(d.stream_hit_rate(64, 0) > 0.9);
    }

    #[test]
    fn contention_worse_than_fair_share() {
        let d = DramChannel::default();
        let solo = d.stream_seconds(100 << 20);
        let four = d.contended_seconds(&[100 << 20; 4]);
        // 4 streams of the same size: ≥ 4x solo (fair share) plus
        // locality loss
        assert!(four > 4.0 * solo, "four {four} vs solo {solo}");
        assert!(four < 8.0 * solo, "contention penalty unreasonably large");
    }

    #[test]
    fn energy_linear_in_bytes() {
        let d = DramChannel::default();
        assert!((d.energy(2000) - 2.0 * d.energy(1000)).abs() < 1e-18);
    }
}
