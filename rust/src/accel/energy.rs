//! Energy model: 45 nm-calibrated per-op constants + an accounting book.
//!
//! Constants follow the standard 45 nm numbers (Horowitz ISSCC'14 and the
//! CACTI-P/McPAT models the paper uses): int8 MAC ≈ 0.3 pJ, SRAM ≈ 2
//! pJ/byte for the 0.5–1 MiB scratchpads of Table 2, DRAM ≈ 160 pJ/byte
//! (LPDDR4-class), NoC 0.64 pJ/bit/hop (McPAT, paper §4.1.1).
//!
//! The decisive *structural* property for the paper's Figure 8 is the
//! ~80× gap between DRAM and SRAM/NoC traffic costs: LTS schedulers
//! bounce inter-layer activations through DRAM, TSS schedulers keep them
//! on-chip.

use super::noc::HOP_PJ_PER_BIT;

/// Per-operation energy constants (joules).
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// One int8 MAC (array datapath).
    pub mac_int8: f64,
    /// One byte read or written to the engine scratchpad.
    pub sram_byte: f64,
    /// One byte read or written to DRAM.
    pub dram_byte: f64,
    /// One bit moved one NoC hop.
    pub noc_bit_hop: f64,
    /// Static/leakage power per engine (W) — idle engines still burn it.
    pub engine_static_w: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self {
            mac_int8: 0.3e-12,
            sram_byte: 2.0e-12,
            dram_byte: 160.0e-12,
            noc_bit_hop: HOP_PJ_PER_BIT * 1e-12,
            engine_static_w: 25.0e-3,
        }
    }
}

impl EnergyModel {
    /// Energy of `macs` int8 MACs (includes operand SRAM streaming).
    pub fn compute(&self, macs: u64, sram_bytes: u64) -> f64 {
        macs as f64 * self.mac_int8 + sram_bytes as f64 * self.sram_byte
    }

    /// Energy of a DRAM round-trip of `bytes` (read + later write counted
    /// separately by the caller).
    pub fn dram(&self, bytes: u64) -> f64 {
        bytes as f64 * self.dram_byte
    }

    /// Energy of a NoC transfer.
    pub fn noc(&self, bytes: u64, hops: usize) -> f64 {
        bytes as f64 * 8.0 * hops as f64 * self.noc_bit_hop
    }

    /// Static energy of `engines` engines over `seconds`.
    pub fn static_energy(&self, engines: usize, seconds: f64) -> f64 {
        self.engine_static_w * engines as f64 * seconds
    }
}

/// Mutable energy ledger for one simulation run.
#[derive(Clone, Debug, Default)]
pub struct EnergyBook {
    pub compute_j: f64,
    pub sram_j: f64,
    pub dram_j: f64,
    pub noc_j: f64,
    pub static_j: f64,
    /// Energy spent *running the scheduler itself* (CPU serial or
    /// on-accelerator matcher) — the paper's headline distinction.
    pub scheduling_j: f64,
}

impl EnergyBook {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn total(&self) -> f64 {
        self.compute_j + self.sram_j + self.dram_j + self.noc_j + self.static_j + self.scheduling_j
    }

    pub fn add_compute(&mut self, model: &EnergyModel, macs: u64) {
        self.compute_j += macs as f64 * model.mac_int8;
    }

    pub fn add_sram(&mut self, model: &EnergyModel, bytes: u64) {
        self.sram_j += bytes as f64 * model.sram_byte;
    }

    pub fn add_dram(&mut self, model: &EnergyModel, bytes: u64) {
        self.dram_j += model.dram(bytes);
    }

    pub fn add_noc(&mut self, model: &EnergyModel, bytes: u64, hops: usize) {
        self.noc_j += model.noc(bytes, hops);
    }

    pub fn add_static(&mut self, model: &EnergyModel, engines: usize, seconds: f64) {
        self.static_j += model.static_energy(engines, seconds);
    }

    pub fn add_scheduling(&mut self, joules: f64) {
        self.scheduling_j += joules;
    }

    pub fn merge(&mut self, other: &EnergyBook) {
        self.compute_j += other.compute_j;
        self.sram_j += other.sram_j;
        self.dram_j += other.dram_j;
        self.noc_j += other.noc_j;
        self.static_j += other.static_j;
        self.scheduling_j += other.scheduling_j;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dram_dominates_sram_and_noc() {
        let m = EnergyModel::default();
        let bytes = 1_000_000u64;
        let dram = m.dram(bytes);
        let sram = bytes as f64 * m.sram_byte;
        let noc5 = m.noc(bytes, 5);
        // 160 pJ/B DRAM vs 5-hop NoC at 0.64 pJ/bit ≈ 6.25× per byte
        assert!(dram > 5.0 * noc5, "dram {dram} vs noc {noc5}");
        assert!(dram > 50.0 * sram);
    }

    #[test]
    fn book_totals_add_up() {
        let m = EnergyModel::default();
        let mut b = EnergyBook::new();
        b.add_compute(&m, 1_000_000);
        b.add_dram(&m, 1000);
        b.add_noc(&m, 1000, 3);
        b.add_static(&m, 2, 0.001);
        b.add_scheduling(1e-6);
        let sum = b.compute_j + b.dram_j + b.noc_j + b.static_j + b.scheduling_j;
        assert!((b.total() - sum).abs() < 1e-18);
    }

    #[test]
    fn merge_accumulates() {
        let m = EnergyModel::default();
        let mut a = EnergyBook::new();
        a.add_dram(&m, 100);
        let mut b = EnergyBook::new();
        b.add_dram(&m, 100);
        a.merge(&b);
        assert!((a.dram_j - m.dram(200)).abs() < 1e-18);
    }
}
