//! ILP-style scheduling tensors (paper §3.1).
//!
//! IsoSched formalizes multi-DNN scheduling with two binary tensors
//!
//! ```text
//!   X ∈ {0,1}^{D×I×N×T×P}   compute mapping
//!   Y ∈ {0,1}^{D×I×K×T×L}   communication mapping
//! ```
//!
//! (D DNNs, I iterations, N tiles, T time slots, P engines, K transfers,
//! L links).  The tensors are the *declarative* form of a schedule; the
//! matcher searches the same space through subgraph isomorphism.  We keep
//! them as a validation artifact: any schedule the simulator produces can
//! be exported to (X, Y) and checked against the ILP constraints —
//! exclusivity, single-placement and dependency ordering — which gives
//! the property tests an independent correctness oracle.

/// Dimensions of the scheduling tensors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TensorDims {
    pub dnns: usize,
    pub iterations: usize,
    pub tiles: usize,
    pub slots: usize,
    pub engines: usize,
}

/// Sparse binary scheduling tensors: entries are index tuples.
#[derive(Clone, Debug, Default)]
pub struct MappingTensors {
    pub dims: Option<TensorDims>,
    /// X entries: (dnn, iteration, tile, slot, engine).
    pub x: Vec<(usize, usize, usize, usize, usize)>,
    /// Y entries: (dnn, iteration, transfer, slot, link).
    pub y: Vec<(usize, usize, usize, usize, usize)>,
}

impl MappingTensors {
    pub fn new(dims: TensorDims) -> Self {
        Self { dims: Some(dims), x: Vec::new(), y: Vec::new() }
    }

    /// Record "tile `t` of (dnn, iter) runs in `slot` on `engine`".
    pub fn place(&mut self, dnn: usize, iter: usize, tile: usize, slot: usize, engine: usize) {
        self.x.push((dnn, iter, tile, slot, engine));
    }

    /// Record "transfer `k` of (dnn, iter) uses `link` in `slot`".
    pub fn route(&mut self, dnn: usize, iter: usize, transfer: usize, slot: usize, link: usize) {
        self.y.push((dnn, iter, transfer, slot, link));
    }

    /// ILP constraint 1 — engine exclusivity: at most one tile per
    /// (slot, engine).
    pub fn check_engine_exclusive(&self) -> Result<(), String> {
        let mut seen = std::collections::HashSet::new();
        for &(d, i, t, s, p) in &self.x {
            if !seen.insert((s, p)) {
                return Err(format!("engine {p} double-booked in slot {s} (dnn {d} iter {i} tile {t})"));
            }
        }
        Ok(())
    }

    /// ILP constraint 2 — single placement: each (dnn, iter, tile) is
    /// placed exactly once.
    pub fn check_single_placement(&self) -> Result<(), String> {
        let mut seen = std::collections::HashSet::new();
        for &(d, i, t, _, _) in &self.x {
            if !seen.insert((d, i, t)) {
                return Err(format!("tile (dnn {d}, iter {i}, tile {t}) placed twice"));
            }
        }
        Ok(())
    }

    /// ILP constraint 3 — dependency order: for each dependency
    /// (tile a → tile b) of a DNN, slot(a) < slot(b).
    pub fn check_dependencies(&self, deps: &[(usize, usize)]) -> Result<(), String> {
        use std::collections::HashMap;
        let mut slot_of: HashMap<(usize, usize, usize), usize> = HashMap::new();
        for &(d, i, t, s, _) in &self.x {
            slot_of.insert((d, i, t), s);
        }
        for &(d, i, t, _, _) in &self.x {
            for &(a, b) in deps {
                if b == t {
                    if let (Some(&sa), Some(&sb)) = (slot_of.get(&(d, i, a)), slot_of.get(&(d, i, t))) {
                        if sa >= sb {
                            return Err(format!(
                                "dependency {a}->{b} violated for dnn {d} iter {i}: slots {sa} >= {sb}"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Bounds check against the declared dims.
    pub fn check_bounds(&self) -> Result<(), String> {
        let Some(d) = self.dims else { return Ok(()) };
        for &(dn, i, t, s, p) in &self.x {
            if dn >= d.dnns || i >= d.iterations || t >= d.tiles || s >= d.slots || p >= d.engines {
                return Err(format!("X entry ({dn},{i},{t},{s},{p}) out of bounds {d:?}"));
            }
        }
        Ok(())
    }

    /// Run every structural check.
    pub fn validate(&self, deps: &[(usize, usize)]) -> Result<(), String> {
        self.check_bounds()?;
        self.check_engine_exclusive()?;
        self.check_single_placement()?;
        self.check_dependencies(deps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> TensorDims {
        TensorDims { dnns: 2, iterations: 1, tiles: 4, slots: 8, engines: 4 }
    }

    #[test]
    fn valid_schedule_passes() {
        let mut m = MappingTensors::new(dims());
        m.place(0, 0, 0, 0, 0);
        m.place(0, 0, 1, 1, 1);
        m.place(1, 0, 0, 0, 2);
        assert!(m.validate(&[(0, 1)]).is_ok());
    }

    #[test]
    fn double_booking_detected() {
        let mut m = MappingTensors::new(dims());
        m.place(0, 0, 0, 3, 2);
        m.place(1, 0, 1, 3, 2);
        assert!(m.check_engine_exclusive().is_err());
    }

    #[test]
    fn double_placement_detected() {
        let mut m = MappingTensors::new(dims());
        m.place(0, 0, 0, 0, 0);
        m.place(0, 0, 0, 1, 1);
        assert!(m.check_single_placement().is_err());
    }

    #[test]
    fn dependency_violation_detected() {
        let mut m = MappingTensors::new(dims());
        m.place(0, 0, 0, 5, 0);
        m.place(0, 0, 1, 2, 1);
        assert!(m.check_dependencies(&[(0, 1)]).is_err());
    }

    #[test]
    fn out_of_bounds_detected() {
        let mut m = MappingTensors::new(dims());
        m.place(0, 0, 0, 0, 99);
        assert!(m.check_bounds().is_err());
    }
}
