//! On-chip network model: 2-D mesh, XY routing, McPAT-calibrated energy
//! (paper §4.1.1: per-hop energy 0.64 pJ/bit).

use super::platform::Platform;

/// Per-hop NoC energy in pJ/bit (McPAT 1.3, paper §4.1.1).
pub const HOP_PJ_PER_BIT: f64 = 0.64;

/// Link bandwidth per mesh link, bytes/s.  128-bit links at the platform
/// clock — one flit per cycle, the standard choice for Planaria-class
/// meshes.
pub const LINK_BITS: f64 = 128.0;

/// A mesh instance bound to a platform.
#[derive(Clone, Copy, Debug)]
pub struct Mesh {
    pub cols: usize,
    pub rows: usize,
    pub clock_hz: f64,
}

/// NoC cost model: latency + energy of tile transfers.
#[derive(Clone, Copy, Debug)]
pub struct NocModel {
    pub mesh: Mesh,
}

impl NocModel {
    pub fn of(p: &Platform) -> Self {
        Self {
            mesh: Mesh { cols: p.mesh_cols, rows: p.mesh_rows(), clock_hz: p.clock_hz },
        }
    }

    /// XY-routing hop count between engines.
    pub fn hops(&self, a: usize, b: usize) -> usize {
        let (ax, ay) = (a % self.mesh.cols, a / self.mesh.cols);
        let (bx, by) = (b % self.mesh.cols, b / self.mesh.cols);
        ax.abs_diff(bx) + ay.abs_diff(by)
    }

    /// Transfer seconds for `bytes` from engine `a` to engine `b`:
    /// serialization + per-hop router latency (1 cycle/hop).
    pub fn transfer_seconds(&self, a: usize, b: usize, bytes: u64) -> f64 {
        if a == b || bytes == 0 {
            return 0.0;
        }
        let bits = bytes as f64 * 8.0;
        let serialization = bits / LINK_BITS / self.mesh.clock_hz;
        let head_latency = self.hops(a, b) as f64 / self.mesh.clock_hz;
        serialization + head_latency
    }

    /// Transfer energy in joules (0.64 pJ/bit/hop).
    pub fn transfer_energy(&self, a: usize, b: usize, bytes: u64) -> f64 {
        let hops = self.hops(a, b) as f64;
        bytes as f64 * 8.0 * hops * HOP_PJ_PER_BIT * 1e-12
    }

    /// Mean hop distance over all engine pairs (used for aggregate
    /// estimates when placements are not pinned).
    pub fn mean_hops(&self) -> f64 {
        let n = self.mesh.cols * self.mesh.rows;
        let mut total = 0usize;
        let mut pairs = 0usize;
        for a in 0..n {
            for b in (a + 1)..n {
                total += self.hops(a, b);
                pairs += 1;
            }
        }
        if pairs == 0 {
            0.0
        } else {
            total as f64 / pairs as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::platform::Platform;

    fn noc() -> NocModel {
        NocModel::of(&Platform::edge())
    }

    #[test]
    fn zero_cost_on_self() {
        let n = noc();
        assert_eq!(n.transfer_seconds(3, 3, 4096), 0.0);
        assert_eq!(n.transfer_energy(3, 3, 4096), 0.0);
    }

    #[test]
    fn energy_matches_constant() {
        let n = noc();
        // engines 0 and 1 are adjacent: 1 hop
        let e = n.transfer_energy(0, 1, 1000);
        assert!((e - 1000.0 * 8.0 * 0.64e-12).abs() < 1e-18);
    }

    #[test]
    fn latency_grows_with_bytes_and_hops() {
        let n = noc();
        assert!(n.transfer_seconds(0, 1, 4096) < n.transfer_seconds(0, 1, 65536));
        assert!(n.transfer_seconds(0, 63, 4096) > n.transfer_seconds(0, 1, 4096));
    }

    #[test]
    fn mean_hops_reasonable_for_8x8() {
        let n = noc();
        let mh = n.mean_hops();
        // analytic mean Manhattan distance on 8x8 grid ≈ 5.25
        assert!((5.0..5.6).contains(&mh), "mean hops {mh}");
    }
}
