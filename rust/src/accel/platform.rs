//! Evaluation platforms (paper Table 2).
//!
//! We read Table 2 as: **Edge = 64 engines, Cloud = 128 engines**, each
//! engine a 128×128 int8 MAC systolic array clocked at 700 MHz (the
//! table's "MACs"/"Engines" columns are swapped relative to their values;
//! 64/128 can only be the engine counts since both rows share the
//! 128×128 entry and the Cloud platform must dominate the Edge one).
//! Only ratios enter the paper's claims, and those are preserved under
//! either reading.

/// Which evaluation platform.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlatformKind {
    Edge,
    Cloud,
}

impl PlatformKind {
    pub const ALL: [PlatformKind; 2] = [PlatformKind::Edge, PlatformKind::Cloud];

    pub fn name(self) -> &'static str {
        match self {
            PlatformKind::Edge => "Edge",
            PlatformKind::Cloud => "Cloud",
        }
    }
}

/// A concrete platform instance.
#[derive(Clone, Copy, Debug)]
pub struct Platform {
    pub kind: PlatformKind,
    /// Number of independent engines (PSO particles map 1:1 onto these).
    pub engines: usize,
    /// Systolic array rows per engine.
    pub array_rows: usize,
    /// Systolic array cols per engine.
    pub array_cols: usize,
    /// Clock in Hz.
    pub clock_hz: f64,
    /// Per-engine scratchpad (bytes) for cascaded tiles.
    pub sram_bytes: u64,
    /// Mesh side (engines arranged in a square-ish mesh).
    pub mesh_cols: usize,
}

impl Platform {
    /// Table 2, Edge row.
    pub fn edge() -> Self {
        Self {
            kind: PlatformKind::Edge,
            engines: 64,
            array_rows: 128,
            array_cols: 128,
            clock_hz: 700e6,
            sram_bytes: 512 * 1024,
            mesh_cols: 8,
        }
    }

    /// Table 2, Cloud row.
    pub fn cloud() -> Self {
        Self {
            kind: PlatformKind::Cloud,
            engines: 128,
            array_rows: 128,
            array_cols: 128,
            clock_hz: 700e6,
            sram_bytes: 1024 * 1024,
            mesh_cols: 16,
        }
    }

    pub fn get(kind: PlatformKind) -> Self {
        match kind {
            PlatformKind::Edge => Self::edge(),
            PlatformKind::Cloud => Self::cloud(),
        }
    }

    /// MACs per engine per cycle.
    pub fn engine_macs(&self) -> u64 {
        (self.array_rows * self.array_cols) as u64
    }

    /// Peak MACs/s of the whole platform.
    pub fn peak_macs_per_sec(&self) -> f64 {
        self.engine_macs() as f64 * self.engines as f64 * self.clock_hz
    }

    /// Mesh rows (engines / mesh_cols, rounded up).
    pub fn mesh_rows(&self) -> usize {
        self.engines.div_ceil(self.mesh_cols)
    }

    /// Mesh coordinates of an engine.
    pub fn engine_xy(&self, engine: usize) -> (usize, usize) {
        (engine % self.mesh_cols, engine / self.mesh_cols)
    }

    /// Manhattan hop distance between two engines.
    pub fn hops(&self, a: usize, b: usize) -> usize {
        let (ax, ay) = self.engine_xy(a);
        let (bx, by) = self.engine_xy(b);
        ax.abs_diff(bx) + ay.abs_diff(by)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cloud_doubles_edge_engines() {
        assert_eq!(Platform::edge().engines * 2, Platform::cloud().engines);
    }

    #[test]
    fn peak_rates() {
        let e = Platform::edge();
        // 64 engines * 16384 MACs * 700 MHz
        assert!((e.peak_macs_per_sec() - 64.0 * 16384.0 * 700e6).abs() < 1.0);
    }

    #[test]
    fn mesh_geometry() {
        let e = Platform::edge();
        assert_eq!(e.mesh_rows(), 8);
        assert_eq!(e.engine_xy(0), (0, 0));
        assert_eq!(e.engine_xy(9), (1, 1));
        assert_eq!(e.hops(0, 9), 2);
        assert_eq!(e.hops(0, 63), 14); // (7,7)
    }

    #[test]
    fn hops_symmetric_and_zero_on_self() {
        let c = Platform::cloud();
        assert_eq!(c.hops(5, 5), 0);
        assert_eq!(c.hops(3, 100), c.hops(100, 3));
    }
}
