//! TOML-subset parser: sections, scalar key/values, comments.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Scalar values the subset supports.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse the subset: map of `section.key` -> value ("" section for
/// top-level keys).
pub fn parse_toml(text: &str) -> Result<BTreeMap<String, TomlValue>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                bail!("line {}: malformed section header {line:?}", lineno + 1);
            }
            section = line[1..line.len() - 1].trim().to_string();
            if section.is_empty() {
                bail!("line {}: empty section name", lineno + 1);
            }
            continue;
        }
        let Some(eq) = line.find('=') else {
            bail!("line {}: expected key = value, got {line:?}", lineno + 1);
        };
        let key = line[..eq].trim();
        let val = line[eq + 1..].trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let parsed = parse_value(val)
            .ok_or_else(|| anyhow::anyhow!("line {}: cannot parse value {val:?}", lineno + 1))?;
        let full_key = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        out.insert(full_key, parsed);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str) -> Option<TomlValue> {
    if v.starts_with('"') && v.ends_with('"') && v.len() >= 2 {
        return Some(TomlValue::Str(v[1..v.len() - 1].to_string()));
    }
    match v {
        "true" => return Some(TomlValue::Bool(true)),
        "false" => return Some(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = v.parse::<i64>() {
        return Some(TomlValue::Int(i));
    }
    if let Ok(f) = v.parse::<f64>() {
        return Some(TomlValue::Float(f));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let text = r#"
# top comment
name = "immsched"
[pso]
particles = 16
w = 0.72
relaxed = true
[sim]
seed = 42   # trailing comment
"#;
        let m = parse_toml(text).unwrap();
        assert_eq!(m["name"], TomlValue::Str("immsched".into()));
        assert_eq!(m["pso.particles"], TomlValue::Int(16));
        assert_eq!(m["pso.w"], TomlValue::Float(0.72));
        assert_eq!(m["pso.relaxed"], TomlValue::Bool(true));
        assert_eq!(m["sim.seed"], TomlValue::Int(42));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let m = parse_toml("tag = \"a#b\"").unwrap();
        assert_eq!(m["tag"], TomlValue::Str("a#b".into()));
    }

    #[test]
    fn malformed_lines_error() {
        assert!(parse_toml("[broken").is_err());
        assert!(parse_toml("novalue").is_err());
        assert!(parse_toml("k = @@@").is_err());
        assert!(parse_toml("= 3").is_err());
    }

    #[test]
    fn int_coerces_to_float() {
        let m = parse_toml("x = 3").unwrap();
        assert_eq!(m["x"].as_float(), Some(3.0));
    }
}
