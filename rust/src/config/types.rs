//! Typed configuration: defaults + TOML-subset overlay + validation.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::accel::PlatformKind;
use crate::matcher::PsoConfig;
use crate::workload::WorkloadClass;

use super::parser::{parse_toml, TomlValue};

/// `[pso]` section — matcher hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct PsoSection {
    pub particles: usize,
    pub epochs: usize,
    pub steps: usize,
    pub w: f32,
    pub c1: f32,
    pub c2: f32,
    pub c3: f32,
    pub elite: usize,
    pub relaxed: bool,
    pub repair_budget: u64,
    /// Worker threads for the intra-epoch particle fan-out (0 = auto).
    pub threads: usize,
}

impl Default for PsoSection {
    fn default() -> Self {
        let d = PsoConfig::default();
        Self {
            particles: d.particles,
            epochs: d.epochs,
            steps: d.steps,
            w: d.w,
            c1: d.c1,
            c2: d.c2,
            c3: d.c3,
            elite: d.elite,
            relaxed: d.relaxed,
            repair_budget: d.repair_budget,
            threads: d.threads,
        }
    }
}

impl PsoSection {
    /// Materialize a matcher config with the given seed.
    pub fn to_pso_config(&self, seed: u64) -> PsoConfig {
        PsoConfig {
            particles: self.particles,
            epochs: self.epochs,
            steps: self.steps,
            w: self.w,
            c1: self.c1,
            c2: self.c2,
            c3: self.c3,
            elite: self.elite,
            relaxed: self.relaxed,
            early_exit: true,
            repair_budget: self.repair_budget,
            threads: self.threads,
            seed,
        }
    }
}

/// `[sim]` section — trace + simulation parameters.
#[derive(Clone, Copy, Debug)]
pub struct SimSection {
    pub seed: u64,
    /// Background (periodic) task count.
    pub background_tasks: usize,
    /// Urgent-task Poisson arrival rate λ (tasks/s).
    pub arrival_rate: f64,
    /// Simulated horizon in seconds.
    pub horizon: f64,
    /// Deadline slack factor for urgent tasks (deadline = arrival +
    /// factor × isolated execution time).
    pub deadline_factor: f64,
}

impl Default for SimSection {
    fn default() -> Self {
        Self {
            seed: 42,
            background_tasks: 4,
            arrival_rate: 50.0,
            horizon: 1.0,
            deadline_factor: 3.0,
        }
    }
}

/// `[workload]` section.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadSection {
    pub class: WorkloadClass,
    /// Tile budget for Layer Concatenate-and-Split.
    pub max_tiles: usize,
    pub split_factor: usize,
}

impl Default for WorkloadSection {
    fn default() -> Self {
        Self { class: WorkloadClass::Simple, max_tiles: 16, split_factor: 2 }
    }
}

/// `[scheduler]` section.
#[derive(Clone, Debug)]
pub struct SchedulerSection {
    /// Framework name: immsched | isosched | prema | planaria | moca | cdmsa.
    pub name: String,
    /// Adaptive single-core preemption ratio cap (fraction of engines a
    /// single interrupt may claim).
    pub preemption_ratio: f64,
    /// Use the PJRT artifact for the epoch (false = native fallback).
    pub use_pjrt: bool,
}

impl Default for SchedulerSection {
    fn default() -> Self {
        Self { name: "immsched".into(), preemption_ratio: 0.5, use_pjrt: true }
    }
}

/// Full configuration.
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub platform: PlatformKind,
    pub pso: PsoSection,
    pub sim: SimSection,
    pub workload: WorkloadSection,
    pub scheduler: SchedulerSection,
}

impl Default for PlatformKind {
    fn default() -> Self {
        PlatformKind::Edge
    }
}

impl Config {
    /// Parse a config file over the defaults.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml(&text)
    }

    /// Parse TOML-subset text over the defaults.
    pub fn from_toml(text: &str) -> Result<Self> {
        let map = parse_toml(text)?;
        let mut cfg = Config::default();
        cfg.apply(&map)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply `key=value` overrides (CLI `--set section.key=value`).
    pub fn apply_override(&mut self, spec: &str) -> Result<()> {
        let map = parse_toml(spec)?;
        self.apply(&map)?;
        self.validate()
    }

    fn apply(&mut self, map: &BTreeMap<String, TomlValue>) -> Result<()> {
        for (key, val) in map {
            match key.as_str() {
                "platform" => {
                    self.platform = match val.as_str() {
                        Some("edge") | Some("Edge") => PlatformKind::Edge,
                        Some("cloud") | Some("Cloud") => PlatformKind::Cloud,
                        other => bail!("unknown platform {other:?}"),
                    }
                }
                "pso.particles" => self.pso.particles = int(val, key)? as usize,
                "pso.epochs" => self.pso.epochs = int(val, key)? as usize,
                "pso.steps" => self.pso.steps = int(val, key)? as usize,
                "pso.w" => self.pso.w = float(val, key)? as f32,
                "pso.c1" => self.pso.c1 = float(val, key)? as f32,
                "pso.c2" => self.pso.c2 = float(val, key)? as f32,
                "pso.c3" => self.pso.c3 = float(val, key)? as f32,
                "pso.elite" => self.pso.elite = int(val, key)? as usize,
                "pso.relaxed" => self.pso.relaxed = boolean(val, key)?,
                "pso.repair_budget" => self.pso.repair_budget = int(val, key)? as u64,
                "pso.threads" => self.pso.threads = int(val, key)? as usize,
                "sim.seed" => self.sim.seed = int(val, key)? as u64,
                "sim.background_tasks" => self.sim.background_tasks = int(val, key)? as usize,
                "sim.arrival_rate" => self.sim.arrival_rate = float(val, key)?,
                "sim.horizon" => self.sim.horizon = float(val, key)?,
                "sim.deadline_factor" => self.sim.deadline_factor = float(val, key)?,
                "workload.class" => {
                    self.workload.class = match val.as_str() {
                        Some("simple") | Some("Simple") => WorkloadClass::Simple,
                        Some("middle") | Some("Middle") => WorkloadClass::Middle,
                        Some("complex") | Some("Complex") => WorkloadClass::Complex,
                        other => bail!("unknown workload class {other:?}"),
                    }
                }
                "workload.max_tiles" => self.workload.max_tiles = int(val, key)? as usize,
                "workload.split_factor" => self.workload.split_factor = int(val, key)? as usize,
                "scheduler.name" => {
                    self.scheduler.name = val
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("scheduler.name must be a string"))?
                        .to_string()
                }
                "scheduler.preemption_ratio" => self.scheduler.preemption_ratio = float(val, key)?,
                "scheduler.use_pjrt" => self.scheduler.use_pjrt = boolean(val, key)?,
                other => bail!("unknown config key {other:?}"),
            }
        }
        Ok(())
    }

    /// Structural validation.
    pub fn validate(&self) -> Result<()> {
        if self.pso.particles == 0 || self.pso.epochs == 0 || self.pso.steps == 0 {
            bail!("pso.particles/epochs/steps must be positive");
        }
        if !(0.0..=1.0).contains(&self.scheduler.preemption_ratio) {
            bail!("scheduler.preemption_ratio must be in [0,1]");
        }
        if self.sim.arrival_rate <= 0.0 || self.sim.horizon <= 0.0 {
            bail!("sim.arrival_rate and sim.horizon must be positive");
        }
        if self.workload.max_tiles < 2 {
            bail!("workload.max_tiles must be >= 2");
        }
        const KNOWN: [&str; 6] = ["immsched", "isosched", "prema", "planaria", "moca", "cdmsa"];
        if !KNOWN.contains(&self.scheduler.name.as_str()) {
            bail!("unknown scheduler {:?} (known: {KNOWN:?})", self.scheduler.name);
        }
        Ok(())
    }
}

fn int(v: &TomlValue, key: &str) -> Result<i64> {
    v.as_int().ok_or_else(|| anyhow::anyhow!("{key} must be an integer"))
}

fn float(v: &TomlValue, key: &str) -> Result<f64> {
    v.as_float().ok_or_else(|| anyhow::anyhow!("{key} must be a number"))
}

fn boolean(v: &TomlValue, key: &str) -> Result<bool> {
    v.as_bool().ok_or_else(|| anyhow::anyhow!("{key} must be a boolean"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn full_file_parses() {
        let cfg = Config::from_toml(
            r#"
platform = "cloud"
[pso]
particles = 32
relaxed = false
[sim]
arrival_rate = 100.0
[workload]
class = "complex"
[scheduler]
name = "isosched"
preemption_ratio = 0.25
"#,
        )
        .unwrap();
        assert_eq!(cfg.platform, PlatformKind::Cloud);
        assert_eq!(cfg.pso.particles, 32);
        assert!(!cfg.pso.relaxed);
        assert_eq!(cfg.workload.class, WorkloadClass::Complex);
        assert_eq!(cfg.scheduler.name, "isosched");
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(Config::from_toml("bogus = 1").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(Config::from_toml("[scheduler]\nname = \"nope\"").is_err());
        assert!(Config::from_toml("[scheduler]\npreemption_ratio = 2.0").is_err());
        assert!(Config::from_toml("[pso]\nparticles = 0").is_err());
    }

    #[test]
    fn overrides_apply() {
        let mut cfg = Config::default();
        cfg.apply_override("pso.steps = 99").unwrap();
        assert_eq!(cfg.pso.steps, 99);
        cfg.apply_override("pso.threads = 4").unwrap();
        assert_eq!(cfg.pso.threads, 4);
    }

    #[test]
    fn pso_section_converts() {
        let cfg = Config::default();
        let pso = cfg.pso.to_pso_config(7);
        assert_eq!(pso.seed, 7);
        assert_eq!(pso.particles, cfg.pso.particles);
    }
}
