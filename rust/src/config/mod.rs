//! Typed configuration system with a hand-rolled TOML-subset parser
//! (offline substitute for `serde` + `toml`, DESIGN.md §4).
//!
//! Supported syntax: `[section]` headers, `key = value` with string
//! (`"..."`), integer, float and boolean values, `#` comments.  That
//! subset covers everything the launcher and benches need.

mod parser;
mod types;

pub use parser::{parse_toml, TomlValue};
pub use types::{Config, PsoSection, SchedulerSection, SimSection, WorkloadSection};
