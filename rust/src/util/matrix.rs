//! Dense row-major f32 matrix — the lingua franca between the graph
//! layer (adjacency matrices), the matcher (relaxed mappings S) and the
//! PJRT runtime (flat literals).

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major `rows x cols` f32 matrix.
#[derive(Clone, PartialEq)]
pub struct MatF {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl MatF {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with `v`.
    pub fn full(rows: usize, cols: usize, v: f32) -> Self {
        Self { rows, cols, data: vec![v; rows * cols] }
    }

    /// Identity (square).
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a flat row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "MatF::from_vec size mismatch");
        Self { rows, cols, data }
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Flat row-major view.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Flat mutable row-major view.
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// One row as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// One row as a mutable slice.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Matrix product `self * other`.
    pub fn matmul(&self, other: &MatF) -> MatF {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let mut out = MatF::zeros(self.rows, other.cols);
        // ikj loop order: streams `other` rows, vectorizes the inner j loop.
        for i in 0..self.rows {
            let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue; // adjacency matrices are sparse in practice
                }
                let b_row = &other.data[k * other.cols..(k + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Transpose.
    pub fn transpose(&self) -> MatF {
        let mut out = MatF::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Frobenius norm squared of (self - other).
    pub fn sq_dist(&self, other: &MatF) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum()
    }

    /// Elementwise product in place.
    pub fn hadamard_assign(&mut self, other: &MatF) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a *= b;
        }
    }

    /// Renormalize every row to sum 1 (all-zero rows stay zero); the
    /// reciprocal-multiply formulation mirrors the paper's divider-free
    /// datapath and the Pallas kernel.
    pub fn row_normalize(&mut self) {
        let cols = self.cols;
        row_normalize_in_place(&mut self.data, cols);
    }

    /// Index of the max element in a row (ties -> lowest index).
    pub fn row_argmax(&self, i: usize) -> usize {
        let row = self.row(i);
        let mut best = 0;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        best
    }

    /// Sum of all entries.
    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }
}

/// Row-normalize a flat row-major buffer with `cols` columns in place
/// (all-zero rows stay zero). The slice twin of [`MatF::row_normalize`]
/// — the matcher hot path runs on borrowed flat buffers, not `MatF`s.
pub fn row_normalize_in_place(data: &mut [f32], cols: usize) {
    const EPS: f32 = 1e-9;
    if cols == 0 {
        return;
    }
    for row in data.chunks_mut(cols) {
        let sum: f32 = row.iter().sum();
        if sum > EPS {
            let recip = 1.0 / (sum + EPS);
            for x in row {
                *x *= recip;
            }
        } else {
            for x in row {
                *x = 0.0;
            }
        }
    }
}

impl Index<(usize, usize)> for MatF {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for MatF {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for MatF {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "MatF {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(12) {
                write!(f, "{:6.3} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 12 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = MatF::from_fn(3, 3, |i, j| (i * 3 + j) as f32);
        let i3 = MatF::eye(3);
        assert_eq!(a.matmul(&i3), a);
        assert_eq!(i3.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = MatF::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = MatF::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involutive() {
        let a = MatF::from_fn(3, 5, |i, j| (i * 5 + j) as f32);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn row_normalize_sums_to_one() {
        let mut a = MatF::from_fn(4, 6, |i, j| ((i + j) % 3) as f32 + 0.5);
        a.row_normalize();
        for i in 0..4 {
            let s: f32 = a.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
        }
    }

    #[test]
    fn row_normalize_zero_row_stays_zero() {
        let mut a = MatF::zeros(2, 4);
        a[(0, 1)] = 2.0;
        a.row_normalize();
        assert!(a.row(1).iter().all(|&x| x == 0.0));
        assert!((a.row(0).iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn row_argmax_ties_lowest() {
        let a = MatF::from_vec(1, 4, vec![0.5, 0.9, 0.9, 0.1]);
        assert_eq!(a.row_argmax(0), 1);
    }

    #[test]
    fn sq_dist_zero_on_self() {
        let a = MatF::from_fn(3, 3, |i, j| (i + j) as f32);
        assert_eq!(a.sq_dist(&a), 0.0);
    }
}
