//! Total orderings over `f64` with an explicit NaN convention.
//!
//! `partial_cmp(..).unwrap()` panics the moment a NaN reaches a
//! comparator — and scheduler inputs (slack, deadlines, arrival times,
//! fitness) are all derived floats, so one poisoned task could abort a
//! whole serving episode.  `f64::total_cmp` never panics but its IEEE
//! total order interleaves NaN with the sign bit (−NaN below −inf,
//! +NaN above +inf), which is the wrong tiebreak in both directions.
//!
//! These two comparators pin the convention the repo wants
//! (`no-float-unwrap-ord` in `immsched-lint` enforces their use):
//! *a NaN-keyed task never wins a pick and never wedges a queue* —
//! it sorts last, deterministically, whichever way the selection runs.

use std::cmp::Ordering;

/// Total order where every NaN compares greater than every real value
/// (NaNs are mutually equal).
///
/// Use in ascending sorts and `min_by`-style selections so NaN keys
/// rank last / never win.
pub fn nan_greatest_cmp(a: f64, b: f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Greater,
        (false, true) => Ordering::Less,
        (false, false) => a.total_cmp(&b),
    }
}

/// Total order where every NaN compares less than every real value
/// (NaNs are mutually equal).
///
/// Use in `max_by`-style selections (and descending sorts) so NaN keys
/// rank last / never win.
pub fn nan_least_cmp(a: f64, b: f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Less,
        (false, true) => Ordering::Greater,
        (false, false) => a.total_cmp(&b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reals_order_normally() {
        assert_eq!(nan_greatest_cmp(1.0, 2.0), Ordering::Less);
        assert_eq!(nan_greatest_cmp(2.0, 1.0), Ordering::Greater);
        assert_eq!(nan_greatest_cmp(1.0, 1.0), Ordering::Equal);
        assert_eq!(nan_least_cmp(1.0, 2.0), Ordering::Less);
        assert_eq!(nan_least_cmp(-0.0, 0.0), Ordering::Less); // total order, like total_cmp
    }

    #[test]
    fn nan_ranks_last_in_both_conventions() {
        let nan = f64::NAN;
        // ascending sort / min_by: NaN is the greatest value
        assert_eq!(nan_greatest_cmp(nan, f64::INFINITY), Ordering::Greater);
        assert_eq!(nan_greatest_cmp(f64::NEG_INFINITY, nan), Ordering::Less);
        assert_eq!(nan_greatest_cmp(nan, nan), Ordering::Equal);
        // max_by: NaN is the least value, so it can never be the max
        assert_eq!(nan_least_cmp(nan, f64::NEG_INFINITY), Ordering::Less);
        assert_eq!(nan_least_cmp(f64::INFINITY, nan), Ordering::Greater);
        assert_eq!(nan_least_cmp(nan, nan), Ordering::Equal);
    }

    #[test]
    fn sort_pushes_nan_to_the_tail() {
        let mut xs = vec![2.0, f64::NAN, -1.0, 3.0];
        xs.sort_by(|a, b| nan_greatest_cmp(*a, *b));
        assert_eq!(&xs[..3], &[-1.0, 2.0, 3.0]);
        assert!(xs[3].is_nan());
    }

    #[test]
    fn max_by_never_picks_nan() {
        let xs = [f64::NAN, 1.0, f64::NAN, 0.5];
        let best = xs
            .iter()
            .copied()
            .max_by(|a, b| nan_least_cmp(*a, *b))
            .unwrap();
        assert_eq!(best, 1.0);
    }
}
