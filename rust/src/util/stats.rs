//! Summary statistics used by the benches and the metrics module.

/// Streaming summary of a sample: count/mean/min/max/percentiles.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    values: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_iter(it: impl IntoIterator<Item = f64>) -> Self {
        let mut s = Self::new();
        for v in it {
            s.add(v);
        }
        s
    }

    pub fn add(&mut self, v: f64) {
        self.values.push(v);
        self.sorted = false;
    }

    pub fn count(&self) -> usize {
        self.values.len()
    }

    pub fn sum(&self) -> f64 {
        self.values.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.sum() / self.values.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (n - 1) as f64;
        var.sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            // NaN samples sort to the tail (the repo's queue.rs
            // NaN-orders-last convention): low/mid percentiles of a
            // partially poisoned sample stay finite, and a NaN sample
            // can no longer panic the sort outright
            self.values.sort_by(|a, b| crate::util::ord::nan_greatest_cmp(*a, *b));
            self.sorted = true;
        }
    }

    /// Percentile by nearest-rank (q in [0, 100]).
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let rank = ((q / 100.0) * (self.values.len() - 1) as f64).round() as usize;
        self.values[rank.min(self.values.len() - 1)]
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }
}

/// Geometric mean — the paper reports normalized speedups averaged across
/// workloads; geo-mean is the standard aggregator for ratios.
pub fn geomean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return f64::NAN;
    }
    let log_sum: f64 = values.iter().map(|v| v.max(1e-300).ln()).sum();
    (log_sum / values.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let s = Summary::from_iter([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::from_iter((1..=100).map(|i| i as f64));
        // nearest-rank median of 1..=100 is 50 or 51
        assert!((s.median() - 50.5).abs() <= 0.5, "median {}", s.median());
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn geomean_of_ratios() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[10.0, 10.0, 10.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn empty_summary_is_nan() {
        assert!(Summary::new().mean().is_nan());
    }

    #[test]
    fn percentile_with_nan_sample_does_not_panic_and_keeps_low_quantiles_finite() {
        // regression: the sort comparator was partial_cmp(..).unwrap(),
        // so one NaN latency sample aborted the whole bench summary;
        // now NaN sorts last, poisoning only the top of the distribution
        let mut s = Summary::from_iter([3.0, f64::NAN, 1.0, 2.0]);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.median(), 3.0); // rank round(1.5) = 2 of [1, 2, 3, NaN]
        assert!(s.percentile(100.0).is_nan());
    }
}
