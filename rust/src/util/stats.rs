//! Summary statistics used by the benches and the metrics module.

/// Streaming summary of a sample: count/mean/min/max/percentiles.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    values: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_iter(it: impl IntoIterator<Item = f64>) -> Self {
        let mut s = Self::new();
        for v in it {
            s.add(v);
        }
        s
    }

    pub fn add(&mut self, v: f64) {
        self.values.push(v);
        self.sorted = false;
    }

    pub fn count(&self) -> usize {
        self.values.len()
    }

    pub fn sum(&self) -> f64 {
        self.values.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.sum() / self.values.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Sample standard deviation.
    pub fn std(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (n - 1) as f64;
        var.sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            // NaN samples sort to the tail (the repo's queue.rs
            // NaN-orders-last convention): low/mid percentiles of a
            // partially poisoned sample stay finite, and a NaN sample
            // can no longer panic the sort outright
            self.values.sort_by(|a, b| crate::util::ord::nan_greatest_cmp(*a, *b));
            self.sorted = true;
        }
    }

    /// Percentile by nearest-rank (q in [0, 100]).
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let rank = ((q / 100.0) * (self.values.len() - 1) as f64).round() as usize;
        self.values[rank.min(self.values.len() - 1)]
    }

    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }
}

// ---------------------------------------------------------------------------
// NaN-safe replication aggregators
// ---------------------------------------------------------------------------
//
// The experiment harness aggregates per-replication metrics where a
// degenerate cell (zero completions, zero submissions) legitimately
// produces NaN for one replication.  These helpers skip NaN samples so
// one poisoned replication narrows the sample instead of poisoning the
// whole cell summary.  ±inf samples are *kept* — an infinite latency is
// a real (terrible) observation, not a hole in the data.

/// Mean of the non-NaN samples; NaN when none remain.
pub fn mean(values: &[f64]) -> f64 {
    let mut n = 0usize;
    let mut sum = 0.0;
    for v in values.iter().filter(|v| !v.is_nan()) {
        n += 1;
        sum += v;
    }
    if n == 0 {
        f64::NAN
    } else {
        sum / n as f64
    }
}

/// Sample standard deviation of the non-NaN samples; 0.0 when fewer
/// than two remain (a single replication has no spread to report).
pub fn stddev(values: &[f64]) -> f64 {
    let clean: Vec<f64> = values.iter().copied().filter(|v| !v.is_nan()).collect();
    if clean.len() < 2 {
        return 0.0;
    }
    let m = mean(&clean);
    let var =
        clean.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (clean.len() - 1) as f64;
    var.sqrt()
}

/// Half-width of the normal-approximation 95% confidence interval on the
/// mean (1.96·s/√n over the non-NaN samples).  NaN when no samples
/// remain; 0.0 for a single sample, matching [`stddev`].
pub fn ci95(values: &[f64]) -> f64 {
    let n = values.iter().filter(|v| !v.is_nan()).count();
    if n == 0 {
        return f64::NAN;
    }
    1.96 * stddev(values) / (n as f64).sqrt()
}

/// Geometric mean — the paper reports normalized speedups averaged across
/// workloads; geo-mean is the standard aggregator for ratios.
pub fn geomean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return f64::NAN;
    }
    let log_sum: f64 = values.iter().map(|v| v.max(1e-300).ln()).sum();
    (log_sum / values.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let s = Summary::from_iter([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::from_iter((1..=100).map(|i| i as f64));
        // nearest-rank median of 1..=100 is 50 or 51
        assert!((s.median() - 50.5).abs() <= 0.5, "median {}", s.median());
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn geomean_of_ratios() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[10.0, 10.0, 10.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn empty_summary_is_nan() {
        assert!(Summary::new().mean().is_nan());
    }

    #[test]
    fn nan_safe_aggregators_on_empty_input() {
        assert!(mean(&[]).is_nan());
        assert_eq!(stddev(&[]), 0.0);
        assert!(ci95(&[]).is_nan());
    }

    #[test]
    fn nan_safe_aggregators_on_single_sample() {
        assert_eq!(mean(&[3.5]), 3.5);
        assert_eq!(stddev(&[3.5]), 0.0);
        assert_eq!(ci95(&[3.5]), 0.0);
    }

    #[test]
    fn nan_safe_aggregators_skip_nan_samples() {
        let dirty = [2.0, f64::NAN, 4.0, f64::NAN, 6.0];
        assert!((mean(&dirty) - 4.0).abs() < 1e-12);
        assert!((stddev(&dirty) - 2.0).abs() < 1e-12);
        // n = 3 non-NaN samples: 1.96 · 2 / √3
        assert!((ci95(&dirty) - 1.96 * 2.0 / 3.0_f64.sqrt()).abs() < 1e-12);
        // all-NaN degrades like empty
        assert!(mean(&[f64::NAN, f64::NAN]).is_nan());
        assert_eq!(stddev(&[f64::NAN]), 0.0);
        assert!(ci95(&[f64::NAN]).is_nan());
    }

    #[test]
    fn percentile_with_nan_sample_does_not_panic_and_keeps_low_quantiles_finite() {
        // regression: the sort comparator was partial_cmp(..).unwrap(),
        // so one NaN latency sample aborted the whole bench summary;
        // now NaN sorts last, poisoning only the top of the distribution
        let mut s = Summary::from_iter([3.0, f64::NAN, 1.0, 2.0]);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.median(), 3.0); // rank round(1.5) = 2 of [1, 2, 3, NaN]
        assert!(s.percentile(100.0).is_nan());
    }
}
