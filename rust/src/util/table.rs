//! ASCII table / CSV rendering for the paper-figure benches.

/// A simple text table: header row + data rows, auto-sized columns.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>) -> Self {
        Self { title: title.into(), ..Default::default() }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        debug_assert!(self.header.is_empty() || cells.len() == self.header.len());
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let ncols = self.header.len().max(self.rows.iter().map(Vec::len).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, w) in widths.iter().enumerate() {
                let c = cells.get(i).map(String::as_str).unwrap_or("");
                s.push_str(&format!(" {:<w$} |", c, w = w));
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            out.push_str(&sep);
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render as CSV (title as a comment line).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("# {}\n", self.title));
        }
        if !self.header.is_empty() {
            out.push_str(&self.header.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a ratio the way the paper does ("×34.4").
pub fn fmt_ratio(x: f64) -> String {
    if x >= 100.0 {
        format!("×{:.0}", x)
    } else if x >= 10.0 {
        format!("×{:.1}", x)
    } else {
        format!("×{:.2}", x)
    }
}

/// Format seconds with an adaptive unit (ns/µs/ms/s).
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.3}s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(&["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("| a   | bbbb |"), "{s}");
        assert!(s.contains("| 333 | 4    |"), "{s}");
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("").header(&["x"]);
        t.row(vec!["a,b".into()]);
        assert!(t.to_csv().contains("\"a,b\""));
    }

    #[test]
    fn ratio_formatting() {
        assert_eq!(fmt_ratio(2722.2), "×2722");
        assert_eq!(fmt_ratio(34.43), "×34.4");
        assert_eq!(fmt_ratio(1.6), "×1.60");
    }

    #[test]
    fn time_formatting() {
        assert_eq!(fmt_time(0.5e-9 * 2.0), "1.0ns");
        assert_eq!(fmt_time(2.5e-3), "2.50ms");
    }
}
