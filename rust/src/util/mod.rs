//! Small self-contained utilities: deterministic RNG, statistics,
//! dense matrices, fixed-point helpers, text tables and a minimal JSON
//! value model (parser + renderer) for the tracked bench trajectories.
//!
//! Everything the crate needs that would normally come from `rand`,
//! `ndarray`, `prettytable` or `serde_json` lives here — the build is
//! fully offline and those crates are unavailable (DESIGN.md §4,
//! substitution table).

pub mod json;
pub mod logging;
pub mod matrix;
pub mod ord;
pub mod rng;
pub mod stats;
pub mod table;

pub use matrix::{row_normalize_in_place, MatF};
pub use ord::{nan_greatest_cmp, nan_least_cmp};
pub use rng::Rng;
pub use stats::Summary;
