//! Small self-contained utilities: deterministic RNG, statistics,
//! dense matrices, fixed-point helpers and text tables.
//!
//! Everything the crate needs that would normally come from `rand`,
//! `ndarray` or `prettytable` lives here — the build is fully offline and
//! those crates are unavailable (DESIGN.md §4, substitution table).

pub mod logging;
pub mod matrix;
pub mod rng;
pub mod stats;
pub mod table;

pub use matrix::{row_normalize_in_place, MatF};
pub use rng::Rng;
pub use stats::Summary;
