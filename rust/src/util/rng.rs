//! Deterministic, seedable RNG: xoshiro256** seeded via SplitMix64.
//!
//! Every stochastic component in the crate (trace generation, PSO
//! initialization, property tests) threads one of these through
//! explicitly, so whole simulations replay bit-identically from a seed —
//! a requirement for the paper-figure benches to be reproducible.

/// xoshiro256** 1.0 (Blackman & Vigna), seeded with SplitMix64.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the 256-bit state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        // State must not be all-zero; SplitMix64 of any seed never is.
        Self { s }
    }

    /// Derive an independent stream (for per-component sub-RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    /// The raw 256-bit generator state — what a serialized
    /// `SwarmSnapshot` carries across a process boundary so a migrated
    /// episode replays the exact stream the uninterrupted run would
    /// have drawn.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Restore a generator from [`Self::state`].  An all-zero state is
    /// invalid for xoshiro (it is a fixed point); it is replaced by the
    /// seed-0 state so a corrupted wire payload degrades to a valid —
    /// if different — stream instead of a generator stuck on zero.
    pub fn from_state(s: [u64; 4]) -> Self {
        if s == [0; 4] {
            return Rng::new(0);
        }
        Self { s }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) (n > 0), bias-free via rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential(rate) variate — inter-arrival times of the Poisson
    /// arrival process used by the LBT metric (paper §4.1.4).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c} out of range");
        }
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Rng::new(11);
        let rate = 4.0;
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn state_round_trip_continues_the_exact_stream() {
        let mut a = Rng::new(77);
        for _ in 0..123 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        assert_eq!(a, b);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn all_zero_state_degrades_to_a_valid_generator() {
        let mut r = Rng::from_state([0; 4]);
        assert_ne!(r.next_u64(), 0, "xoshiro must not be stuck on the zero fixed point");
    }
}
