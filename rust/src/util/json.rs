//! Minimal JSON value model: recursive-descent parser + pretty
//! renderer.
//!
//! The crate deliberately has no serde dependency (the default build
//! must work from a bare toolchain), but the tracked bench trajectories
//! (`BENCH_matcher.json`, `BENCH_cluster.json`) are append-style JSON
//! documents that both the bench binaries (read-modify-write) and the
//! paper-figure pipeline (read) consume.  This module is the shared,
//! dependency-free implementation: a full JSON value enum, a strict
//! parser with byte-offset error messages, and a deterministic renderer
//! (object key order preserved).
//!
//! Numbers are f64 (ample for perf counters; 2^53 integer fidelity).

use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// One JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key order is preserved (parse order / insertion order).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed,
    /// trailing garbage rejected).
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing garbage at byte {pos}");
        }
        Ok(value)
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Build an object from (key, value) pairs.
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Render with 2-space indentation and a trailing newline — the
    /// committed-file format of the bench trajectories.
    pub fn render(&self) -> String {
        let mut out = String::new();
        render_into(self, 0, &mut out);
        out.push('\n');
        out
    }
}

// ---------------------------------------------------------------------------
// codec primitives (shared by the shard wire protocol and snapshot serde)
// ---------------------------------------------------------------------------
//
// Bit-exact encoding rules for state that must survive a process
// boundary unchanged: f32 values travel as their u32 bit patterns
// (±inf/NaN and subnormals survive, where a pretty-printed float would
// not), and 64-bit words that may exceed 2^53 travel as 16-digit hex
// strings (JSON numbers are f64).  Both `matcher::SwarmSnapshot` serde
// and `cluster::wire` build on these — one implementation, no drift.

/// Upper bound on any decoded dimension (vertex counts, mask rows/cols,
/// swarm shapes).  A corrupt or hostile document must be rejected
/// *before* it sizes an allocation, and products of two dims stay far
/// from overflow.
pub const MAX_WIRE_DIM: usize = 1 << 20;

/// Encode an f32 as its u32 bit pattern (exact in an f64-backed number).
pub fn f32_bits(x: f32) -> Json {
    Json::Num(x.to_bits() as f64)
}

/// Decode [`f32_bits`] from one value.
pub fn decode_f32_bits(v: &Json) -> Result<f32> {
    let bits = v.as_f64().ok_or_else(|| anyhow!("f32 bit pattern is not a number"))?;
    if !((0.0..=u32::MAX as f64).contains(&bits) && bits.fract() == 0.0) {
        bail!("value {bits} is not an f32 bit pattern");
    }
    Ok(f32::from_bits(bits as u32))
}

/// Decode an [`f32_bits`]-encoded field.
pub fn get_f32_bits(v: &Json, key: &str) -> Result<f32> {
    let field = v.get(key).ok_or_else(|| anyhow!("missing f32 bit field {key:?}"))?;
    decode_f32_bits(field).map_err(|e| e.context(format!("field {key:?}")))
}

/// Encode a whole f32 slice as bit patterns.
pub fn f32_bits_arr(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| f32_bits(x)).collect())
}

/// Decode an [`f32_bits_arr`]-encoded field.
pub fn get_f32_bits_arr(v: &Json, key: &str) -> Result<Vec<f32>> {
    v.get(key)
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow!("missing f32 bit array {key:?}"))?
        .iter()
        .map(decode_f32_bits)
        .collect()
}

/// Encode a u64 as a 16-digit hex string (exact past 2^53).
pub fn hex_u64(x: u64) -> Json {
    Json::Str(format!("{x:016x}"))
}

/// Decode a [`hex_u64`]-encoded field.
pub fn get_hex_u64(v: &Json, key: &str) -> Result<u64> {
    let s = v
        .get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing hex field {key:?}"))?;
    u64::from_str_radix(s, 16).map_err(|_| anyhow!("bad hex field {key:?} = {s:?}"))
}

/// Decode one JSON value as a non-negative integer index or count.
/// This is the single checked number→usize conversion the wire codecs
/// use — the bounds check lives here so call sites never need a bare
/// `as` cast on untrusted input.
pub fn as_index(v: &Json) -> Result<usize> {
    let x = v.as_f64().ok_or_else(|| anyhow!("value is not a number"))?;
    if !(x >= 0.0 && x.fract() == 0.0 && x <= u64::MAX as f64) {
        bail!("value {x} is not an index");
    }
    Ok(x as usize)
}

/// Decode a non-negative integer field (an index or count).
pub fn get_usize(v: &Json, key: &str) -> Result<usize> {
    let field = v.get(key).ok_or_else(|| anyhow!("missing numeric field {key:?}"))?;
    as_index(field).map_err(|e| e.context(format!("field {key:?}")))
}

/// [`get_usize`] additionally bounded by [`MAX_WIRE_DIM`] — for any
/// field that sizes an allocation.
pub fn get_dim(v: &Json, key: &str) -> Result<usize> {
    let x = get_usize(v, key)?;
    if x > MAX_WIRE_DIM {
        bail!("dimension {key:?} = {x} exceeds the {MAX_WIRE_DIM} cap");
    }
    Ok(x)
}

/// Decode a u64 counter field (plain JSON number; fine below 2^53).
pub fn get_u64(v: &Json, key: &str) -> Result<u64> {
    Ok(get_usize(v, key)? as u64)
}

/// Decode a bool field.
pub fn get_bool(v: &Json, key: &str) -> Result<bool> {
    v.get(key).and_then(Json::as_bool).ok_or_else(|| anyhow!("missing bool field {key:?}"))
}

/// Decode a string field.
pub fn get_str<'v>(v: &'v Json, key: &str) -> Result<&'v str> {
    v.get(key).and_then(Json::as_str).ok_or_else(|| anyhow!("missing string field {key:?}"))
}

/// Encode a slice of optional indices (`None` → `null`) — the shape of
/// a matcher mapping.
pub fn encode_opt_indices(slots: &[Option<usize>]) -> Json {
    Json::Arr(slots.iter().map(|s| s.map_or(Json::Null, |x| Json::Num(x as f64))).collect())
}

/// Inverse of [`encode_opt_indices`].
pub fn decode_opt_indices(v: &Json) -> Result<Vec<Option<usize>>> {
    v.as_array()
        .ok_or_else(|| anyhow!("index list must be an array"))?
        .iter()
        .map(|slot| match slot {
            Json::Null => Ok(None),
            _ => {
                let x = slot.as_f64().ok_or_else(|| anyhow!("slot is not an index"))?;
                if !(x >= 0.0 && x.fract() == 0.0 && x <= MAX_WIRE_DIM as f64) {
                    bail!("slot {x} is not an in-range index");
                }
                Ok(Some(x as usize))
            }
        })
        .collect()
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}

impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, ch: u8) -> Result<()> {
    if *pos < b.len() && b[*pos] == ch {
        *pos += 1;
        Ok(())
    } else {
        bail!("expected {:?} at byte {}", ch as char, *pos)
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    let Some(&c) = b.get(*pos) else { bail!("unexpected end of input") };
    match c {
        b'{' => parse_object(b, pos),
        b'[' => parse_array(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        b'-' | b'0'..=b'9' => parse_number(b, pos),
        other => bail!("unexpected byte {:?} at {}", other as char, *pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        bail!("invalid literal at byte {}", *pos)
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos]).expect("ascii number bytes");
    let x: f64 =
        text.parse().map_err(|_| anyhow!("invalid number {text:?} at byte {start}"))?;
    Ok(Json::Num(x))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        let Some(&c) = b.get(*pos) else { bail!("unterminated string") };
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&esc) = b.get(*pos) else { bail!("unterminated escape") };
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let hex = b
                            .get(*pos..*pos + 4)
                            .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                        let code = u32::from_str_radix(
                            std::str::from_utf8(hex).map_err(|_| anyhow!("bad \\u escape"))?,
                            16,
                        )?;
                        *pos += 4;
                        // surrogate pairs are not needed by the bench files;
                        // map unpaired surrogates to U+FFFD
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    other => bail!("bad escape \\{}", other as char),
                }
            }
            _ => {
                // copy the raw UTF-8 byte run starting at c
                let start = *pos - 1;
                while *pos < b.len() && b[*pos] != b'"' && b[*pos] != b'\\' {
                    *pos += 1;
                }
                out.push_str(
                    std::str::from_utf8(&b[start..*pos])
                        .map_err(|_| anyhow!("invalid UTF-8 in string"))?,
                );
            }
        }
    }
}

fn parse_array(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(&b',') => {
                *pos += 1;
            }
            Some(&b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => bail!("expected ',' or ']' at byte {}", *pos),
        }
    }
}

fn parse_object(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'{')?;
    let mut fields = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(fields));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        expect(b, pos, b':')?;
        let value = parse_value(b, pos)?;
        fields.push((key, value));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(&b',') => {
                *pos += 1;
            }
            Some(&b'}') => {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            _ => bail!("expected ',' or '}}' at byte {}", *pos),
        }
    }
}

// ---------------------------------------------------------------------------
// renderer
// ---------------------------------------------------------------------------

fn render_into(v: &Json, indent: usize, out: &mut String) {
    let pad = "  ".repeat(indent);
    let pad_in = "  ".repeat(indent + 1);
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => {
            let _ = write!(out, "{b}");
        }
        Json::Num(x) => {
            if !x.is_finite() {
                // JSON has no NaN/±inf literal. A non-finite number here
                // means a degenerate metric (0/0 rate, empty percentile)
                // leaked into a document; rendering it raw would corrupt
                // the whole committed trajectory file for every later
                // reader. Degrade the one value to null instead.
                out.push_str("null");
            } else if x.fract() == 0.0 && x.abs() < 9.0e15 {
                let _ = write!(out, "{}", *x as i64);
            } else {
                let _ = write!(out, "{x}");
            }
        }
        Json::Str(s) => render_string(s, out),
        Json::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push_str("[\n");
            for (i, item) in items.iter().enumerate() {
                out.push_str(&pad_in);
                render_into(item, indent + 1, out);
                out.push_str(if i + 1 == items.len() { "\n" } else { ",\n" });
            }
            out.push_str(&pad);
            out.push(']');
        }
        Json::Obj(fields) => {
            if fields.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push_str("{\n");
            for (i, (k, val)) in fields.iter().enumerate() {
                out.push_str(&pad_in);
                render_string(k, out);
                out.push_str(": ");
                render_into(val, indent + 1, out);
                out.push_str(if i + 1 == fields.len() { "\n" } else { ",\n" });
            }
            out.push_str(&pad);
            out.push('}');
        }
    }
}

fn render_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_render_round_trip() {
        let text = r#"{
  "schema": "immsched.bench_matcher/v2",
  "entries": [
    {
      "label": "seed",
      "smoke": false,
      "speedup": 6.74,
      "count": 12,
      "missing": null,
      "nested": [1, -2.5, 3e2]
    }
  ]
}"#;
        let v = Json::parse(text).expect("parse");
        assert_eq!(v.get("schema").and_then(Json::as_str), Some("immsched.bench_matcher/v2"));
        let entries = v.get("entries").and_then(Json::as_array).expect("entries");
        assert_eq!(entries.len(), 1);
        let e = &entries[0];
        assert_eq!(e.get("speedup").and_then(Json::as_f64), Some(6.74));
        assert_eq!(e.get("count").and_then(Json::as_f64), Some(12.0));
        assert_eq!(e.get("missing"), Some(&Json::Null));
        assert_eq!(
            e.get("nested").and_then(Json::as_array).map(|a| a.len()),
            Some(3)
        );
        // render → reparse is identity
        let rendered = v.render();
        assert_eq!(Json::parse(&rendered).expect("reparse"), v);
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        assert!(Json::parse("{\"a\": 1} trailing").is_err());
        assert!(Json::parse("{\"a\": }").is_err());
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("\"open").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn strings_escape_correctly() {
        let v = Json::Obj(vec![("k\"ey\n".into(), Json::Str("a\\b\t".into()))]);
        let rendered = v.render();
        assert_eq!(Json::parse(&rendered).unwrap(), v);
    }

    #[test]
    fn non_finite_numbers_render_as_null_not_invalid_json() {
        // regression: a poisoned stat (NaN/±inf f64) must never emit
        // `NaN`/`inf` tokens that would make a committed BENCH_*.json
        // unparseable for every later run
        let v = Json::obj(vec![
            ("bad_a", Json::from(f64::NAN)),
            ("bad_b", Json::from(f64::INFINITY)),
            ("bad_c", Json::from(f64::NEG_INFINITY)),
            ("ok", Json::from(1.5)),
        ]);
        let rendered = v.render();
        assert!(!rendered.contains("NaN") && !rendered.contains("inf"), "{rendered}");
        let back = Json::parse(&rendered).expect("non-finite render must stay valid JSON");
        assert_eq!(back.get("bad_a"), Some(&Json::Null));
        assert_eq!(back.get("bad_b"), Some(&Json::Null));
        assert_eq!(back.get("bad_c"), Some(&Json::Null));
        assert_eq!(back.get("ok").and_then(Json::as_f64), Some(1.5));
        // nested positions go through the same renderer
        let arr = Json::Arr(vec![Json::from(f64::NAN), Json::from(2.0)]);
        let back = Json::parse(&arr.render()).expect("array render");
        assert_eq!(back.as_array().unwrap()[0], Json::Null);
    }

    #[test]
    fn integers_render_without_decimal_point() {
        let v = Json::obj(vec![("n", Json::from(42u64)), ("x", Json::from(1.5))]);
        let text = v.render();
        assert!(text.contains("\"n\": 42"), "{text}");
        assert!(text.contains("\"x\": 1.5"), "{text}");
    }
}
