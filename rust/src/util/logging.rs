//! Minimal leveled stderr logger — the offline substitute for the `log`
//! facade (the crate's only external dependencies are `anyhow` and
//! `once_cell`, DESIGN.md §4 substitution table).
//!
//! Library code emits through the [`crate::log_error!`],
//! [`crate::log_warn!`], [`crate::log_info!`] and [`crate::log_debug!`]
//! macros; binaries pick the verbosity with [`set_max_level`]. The
//! default level is [`Level::Warn`] so degradation messages (missing
//! artifacts, fallback paths) stay visible without any setup.

use std::sync::atomic::{AtomicU8, Ordering};

/// Message severity, ordered from most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }
}

/// 0 = everything off; otherwise the numeric value of the max [`Level`].
static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);

/// Allow messages up to and including `level`.
pub fn set_max_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Silence all logging (tests that exercise noisy failure paths).
pub fn disable() {
    MAX_LEVEL.store(0, Ordering::Relaxed);
}

/// Whether a message at `level` would currently be emitted.
pub fn enabled(level: Level) -> bool {
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one record to stderr (use the macros instead of calling this
/// directly).
pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{}] {}", level.tag(), args);
    }
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_matches_severity() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn filtering_follows_max_level() {
        set_max_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_max_level(Level::Debug);
        assert!(enabled(Level::Debug));
        disable();
        assert!(!enabled(Level::Error));
        // restore the default for other tests in this process
        set_max_level(Level::Warn);
    }
}
