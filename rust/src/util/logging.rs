//! Minimal leveled stderr logger — the offline substitute for the `log`
//! facade (the crate's only external dependencies are `anyhow` and
//! `once_cell`, DESIGN.md §4 substitution table).
//!
//! Library code emits through the [`crate::log_error!`],
//! [`crate::log_warn!`], [`crate::log_info!`] and [`crate::log_debug!`]
//! macros; binaries pick the verbosity with [`set_max_level`] (or let
//! the user override it via the `IMMSCHED_LOG` environment variable —
//! see [`init_from_env`]). The default level is [`Level::Warn`] so
//! degradation messages (missing artifacts, fallback paths) stay
//! visible without any setup.
//!
//! Every macro also takes a structured form — a leading brace block of
//! `key = value` fields rendered as trailing `key=value` pairs:
//!
//! ```text
//! crate::log_warn!({ shard = shard, attempt = n }, "redial failed: {e:#}");
//! // → [WARN] redial failed: ... shard=2 attempt=3
//! ```
//!
//! Fields are greppable and machine-splittable (the flight-recorder
//! dump uses the same `key=value` convention), and the field
//! expressions only evaluate when the level is enabled.

use std::sync::atomic::{AtomicU8, Ordering};

/// Message severity, ordered from most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }

    /// Parse a level name (the `IMMSCHED_LOG` vocabulary).
    pub fn from_name(name: &str) -> Option<Level> {
        Some(match name {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            _ => return None,
        })
    }
}

/// 0 = everything off; otherwise the numeric value of the max [`Level`].
static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);

/// Allow messages up to and including `level`.
pub fn set_max_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Silence all logging (tests that exercise noisy failure paths).
pub fn disable() {
    MAX_LEVEL.store(0, Ordering::Relaxed);
}

/// Whether a message at `level` would currently be emitted.
pub fn enabled(level: Level) -> bool {
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Apply the `IMMSCHED_LOG` environment override, if set: one of
/// `error`, `warn`, `info`, `debug`, or `off` (case-insensitive).
/// Binaries call this once at startup; an unknown value is itself
/// worth a warning rather than a silent default.
pub fn init_from_env() {
    let Ok(val) = std::env::var("IMMSCHED_LOG") else { return };
    match val.to_ascii_lowercase().as_str() {
        "off" | "none" | "0" => disable(),
        other => match Level::from_name(other) {
            Some(level) => set_max_level(level),
            None => {
                eprintln!("[WARN] IMMSCHED_LOG={val:?} is not error|warn|info|debug|off; ignored");
            }
        },
    }
}

/// Emit one record to stderr (use the macros instead of calling this
/// directly).
pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{}] {}", level.tag(), args);
    }
}

/// Emit one structured record: the message, then ordered `key=value`
/// fields (use the macros' brace form instead of calling this
/// directly).
pub fn log_kv(level: Level, args: std::fmt::Arguments<'_>, fields: &[(&str, String)]) {
    if enabled(level) {
        let mut line = format!("[{}] {}", level.tag(), args);
        for (key, value) in fields {
            line.push(' ');
            line.push_str(key);
            line.push('=');
            line.push_str(value);
        }
        eprintln!("{line}");
    }
}

/// Shared expansion for the four leveled macros: plain form forwards
/// `format_args!`; brace form evaluates fields only when the level is
/// enabled, then emits through [`log_kv`].
#[doc(hidden)]
#[macro_export]
macro_rules! __log_at {
    ($level:ident, { $($k:ident = $v:expr),+ $(,)? }, $($arg:tt)*) => {
        if $crate::util::logging::enabled($crate::util::logging::Level::$level) {
            $crate::util::logging::log_kv(
                $crate::util::logging::Level::$level,
                format_args!($($arg)*),
                &[$((stringify!($k), format!("{}", $v))),+],
            );
        }
    };
    ($level:ident, $($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::$level,
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::__log_at!(Error, $($arg)*) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::__log_at!(Warn, $($arg)*) };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::__log_at!(Info, $($arg)*) };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::__log_at!(Debug, $($arg)*) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_matches_severity() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn level_names_round_trip() {
        for (name, level) in [
            ("error", Level::Error),
            ("warn", Level::Warn),
            ("info", Level::Info),
            ("debug", Level::Debug),
        ] {
            assert_eq!(Level::from_name(name), Some(level));
        }
        assert_eq!(Level::from_name("trace"), None);
        assert_eq!(Level::from_name("WARN"), None); // callers lowercase first
    }

    #[test]
    fn structured_arm_renders_trailing_fields() {
        // the macros print to stderr, so exercise the rendering path
        // that log_kv uses directly
        let shard = 2usize;
        let fields: &[(&str, String)] =
            &[("shard", format!("{shard}")), ("attempt", format!("{}", 3))];
        let mut line = format!("[{}] {}", Level::Warn.tag(), format_args!("redial failed"));
        for (key, value) in fields {
            line.push(' ');
            line.push_str(key);
            line.push('=');
            line.push_str(value);
        }
        assert_eq!(line, "[WARN] redial failed shard=2 attempt=3");
    }

    #[test]
    fn structured_arm_compiles_against_every_level() {
        // typecheck-only: the branch never runs, so the global level is
        // untouched and parallel tests see no cross-talk
        if false {
            crate::log_error!({ code = 7 }, "boom");
            crate::log_warn!({ shard = 1, attempt = 2 }, "redial failed");
            crate::log_info!({ addr = "127.0.0.1:0" }, "listening");
            crate::log_debug!({ id = 42u64 }, "span {}", "open");
            crate::log_warn!("plain form still works: {}", 1);
        }
    }

    #[test]
    fn filtering_follows_max_level() {
        set_max_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_max_level(Level::Debug);
        assert!(enabled(Level::Debug));
        disable();
        assert!(!enabled(Level::Error));
        // restore the default for other tests in this process
        set_max_level(Level::Warn);
    }
}
