//! Report emission: ASCII tables to stdout + CSV into `reports/`.
//!
//! Every paper-figure bench routes its output through here so the same
//! run produces both the console comparison and a machine-readable CSV
//! (EXPERIMENTS.md links the CSVs).

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::util::table::Table;

pub mod figures;

/// Directory reports are written into (`$IMMSCHED_REPORTS` or `reports/`).
pub fn report_dir() -> PathBuf {
    std::env::var_os("IMMSCHED_REPORTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("reports"))
}

/// Print a table and persist it as `<report_dir>/<stem>.csv`.
pub fn emit(table: &Table, stem: &str) -> std::io::Result<PathBuf> {
    print!("{}", table.render());
    let dir = report_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{stem}.csv"));
    let mut f = std::fs::File::create(&path)?;
    f.write_all(table.to_csv().as_bytes())?;
    println!("[report] wrote {}", path.display());
    Ok(path)
}

/// Emit a simple named-series CSV (x, series1, series2, ...) for figures
/// that are line plots rather than bar groups (Fig. 2b traces).
pub fn emit_series(
    stem: &str,
    x_name: &str,
    series_names: &[&str],
    xs: &[f64],
    series: &[Vec<f64>],
) -> std::io::Result<PathBuf> {
    assert_eq!(series_names.len(), series.len());
    for s in series {
        assert_eq!(s.len(), xs.len());
    }
    let dir = report_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{stem}.csv"));
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{},{}", x_name, series_names.join(","))?;
    for (i, x) in xs.iter().enumerate() {
        let row: Vec<String> = series.iter().map(|s| format!("{}", s[i])).collect();
        writeln!(f, "{},{}", x, row.join(","))?;
    }
    println!("[report] wrote {}", path.display());
    Ok(path)
}

/// Write free-form text alongside the CSVs (bench summaries).
pub fn emit_text(stem: &str, body: &str) -> std::io::Result<PathBuf> {
    let dir = report_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{stem}.txt"));
    std::fs::write(&path, body)?;
    Ok(path)
}

/// True if `path` is writable for reports (used by failure-injection
/// tests).
pub fn dir_writable(path: &Path) -> bool {
    std::fs::create_dir_all(path).is_ok()
        && std::fs::write(path.join(".probe"), b"x")
            .map(|_| {
                let _ = std::fs::remove_file(path.join(".probe"));
            })
            .is_ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    // env-var mutation is process-global; serialize the tests that touch it
    static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn emit_series_roundtrip() {
        let _guard = ENV_LOCK.lock().unwrap();
        let dir = std::env::temp_dir().join("immsched_report_test");
        std::env::set_var("IMMSCHED_REPORTS", &dir);
        let p = emit_series("t_series", "step", &["a", "b"], &[0.0, 1.0], &[vec![1.0, 2.0], vec![3.0, 4.0]])
            .unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.starts_with("step,a,b\n0,1,3\n1,2,4"));
        std::env::remove_var("IMMSCHED_REPORTS");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn emit_table_writes_csv() {
        let _guard = ENV_LOCK.lock().unwrap();
        let dir = std::env::temp_dir().join("immsched_report_test2");
        std::env::set_var("IMMSCHED_REPORTS", &dir);
        let mut t = Table::new("x").header(&["a"]);
        t.row(vec!["1".into()]);
        let p = emit(&t, "t_table").unwrap();
        assert!(p.exists());
        std::env::remove_var("IMMSCHED_REPORTS");
        std::fs::remove_dir_all(&dir).ok();
    }
}
