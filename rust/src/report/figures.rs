//! Paper-figure generation: shared by the `cargo bench` harnesses and
//! the `paper_figures` example, so every table/figure of the evaluation
//! regenerates from one code path.
//!
//! Each function returns a [`Table`] (and writes line-series CSVs where
//! the paper plots curves).  Absolute values differ from the paper (our
//! substrate is an analytic simulator, not the authors' synthesized
//! RTL), but the *shape* — who wins, by roughly what factor, where the
//! gaps grow — is the reproduction target (DESIGN.md §6).

use crate::accel::{Platform, PlatformKind};
use crate::matcher::{
    build_mask, ullmann_find_first, MatcherCostModel, PsoConfig, PsoMatcher, QuantizedMatcher,
};
use crate::scheduler::{
    build_trace, metrics, FrameworkKind, SimConfig, SimResult, Simulator, TraceConfig,
};
use crate::util::table::{fmt_ratio, fmt_time, Table};
use crate::util::Rng;
use crate::workload::{ModelId, TilingConfig, WorkloadClass};

/// Knobs shared by all figure runs.
#[derive(Clone, Copy, Debug)]
pub struct FigureParams {
    /// Trace horizon per simulation (s).
    pub horizon: f64,
    /// Urgent Poisson rate for the speedup/energy figures (tasks/s).
    pub arrival_rate: f64,
    /// Deadline-hit target for the LBT sweep.
    pub lbt_target: f64,
    pub seed: u64,
}

impl Default for FigureParams {
    fn default() -> Self {
        Self { horizon: 0.03, arrival_rate: 100.0, lbt_target: 0.9, seed: 42 }
    }
}

/// One simulation cell: (platform, class, framework) at a given λ.
pub fn run_cell(
    platform: PlatformKind,
    class: WorkloadClass,
    framework: FrameworkKind,
    arrival_rate: f64,
    params: &FigureParams,
) -> SimResult {
    let p = Platform::get(platform);
    let trace_cfg = TraceConfig {
        class,
        arrival_rate,
        horizon: params.horizon,
        seed: params.seed,
        ..Default::default()
    };
    let tasks = build_trace(&trace_cfg, &p);
    let sim_cfg = SimConfig { platform_kind: platform, framework, ..Default::default() };
    Simulator::new(sim_cfg).run(tasks, params.horizon)
}

const CELLS: [(PlatformKind, WorkloadClass); 6] = [
    (PlatformKind::Edge, WorkloadClass::Simple),
    (PlatformKind::Edge, WorkloadClass::Middle),
    (PlatformKind::Edge, WorkloadClass::Complex),
    (PlatformKind::Cloud, WorkloadClass::Simple),
    (PlatformKind::Cloud, WorkloadClass::Middle),
    (PlatformKind::Cloud, WorkloadClass::Complex),
];

/// Table 1: framework capability matrix.
pub fn table1() -> Table {
    use crate::scheduler::frameworks::make_framework;
    let mut t = Table::new("Table 1: scheduling frameworks")
        .header(&["framework", "strategy", "preemptive", "interruptible"]);
    let p = Platform::edge();
    for kind in FrameworkKind::ALL {
        let f = make_framework(kind, p, PsoConfig::default());
        t.row(vec![
            kind.name().into(),
            match f.paradigm() {
                crate::scheduler::Paradigm::Lts => "LTS".into(),
                crate::scheduler::Paradigm::Tss => "TSS".into(),
            },
            if f.preemptive() { "yes" } else { "no" }.into(),
            if f.interruptible() { "yes" } else { "no" }.into(),
        ]);
    }
    t
}

/// Table 2: platform configurations.
pub fn table2() -> Table {
    let mut t = Table::new("Table 2: hardware platforms")
        .header(&["platform", "engines", "MACs/engine", "clock", "SRAM/engine"]);
    for p in [Platform::edge(), Platform::cloud()] {
        t.row(vec![
            p.kind.name().into(),
            p.engines.to_string(),
            format!("{}x{}", p.array_rows, p.array_cols),
            format!("{:.0} MHz", p.clock_hz / 1e6),
            format!("{} KiB", p.sram_bytes / 1024),
        ]);
    }
    t
}

/// Fig. 2a: scheduling time vs execution time for the CPU-serial
/// preemptive baseline (MoCA-like), Cloud platform; Scenario A = UNet
/// (the paper's "middle workload" example), B = Qwen (complex).
///
/// The serial matcher is run on the *realistic interrupt instance*: the
/// platform is busy, so the preemptible set barely exceeds the query
/// (tight fit) — that is exactly where Ullmann backtracking explodes
/// and why the paper profiles scheduling time as orders of magnitude
/// above execution time.
pub fn fig2a(_params: &FigureParams) -> Table {
    let mut t = Table::new("Fig 2a: CPU-serial scheduling vs execution time (Cloud, MoCA-like)")
        .header(&["scenario", "model", "exec time", "sched time (CPU)", "sched/exec", "IMMSched sched"]);
    let platform = Platform::cloud();
    let exec = crate::scheduler::exec_model::ExecModel::new(platform);
    let cost_model = MatcherCostModel::default();
    for (scenario, model) in [("A", ModelId::UNet), ("B", ModelId::Qwen7B)] {
        let task = crate::scheduler::Task::new(
            0,
            model,
            crate::scheduler::Priority::Urgent,
            0.0,
            TilingConfig { max_tiles: 32, split_factor: 2 },
        );
        let exec_t = exec.lts(&task).seconds;
        // CPU-serial scheduling: an unpredictable arrival forces the
        // MoCA/Planaria-class planner to re-plan the *whole resident
        // workload* — pairwise layer-interference analysis (quadratic in
        // total resident layers) swept over partition configurations
        // (∝ √engines).  This offline pass is what the paper profiles
        // as orders of magnitude above execution.
        let resident_dnns = 8.0;
        let total_layers = task.layers as f64 * resident_dnns;
        // ~1e4 CPU ops per layer-pair interference evaluation (cache /
        // bandwidth contention model), swept over √engines partition
        // configurations — the published planners' dominant loop.
        let ops_per_pair = 1.0e4;
        let ops = ops_per_pair * total_layers * total_layers * (platform.engines as f64).sqrt();
        let sched_cpu = ops / cost_model.cpu_hz;
        let q = task.tiles.dag.adjacency();
        // the serial scheduler enumerates candidate victim windows (which
        // contiguous engine region to reclaim) and runs the serial match
        // on each until one embeds — each window gets a 1M-node timeout.
        // This is the victim-selection loop an IsoSched-style serial
        // scheduler performs, and it is where the serial latency explodes.
        let window = (task.tiles.len() + 4).min(platform.engines);
        let mut sched_serial_match = 0.0;
        let mut matched_window = None;
        let mut last_mask = None;
        let mut offset = 0;
        while offset + window <= platform.engines {
            let mut pre = vec![false; platform.engines];
            for e in offset..offset + window {
                pre[e] = true;
            }
            let (target, _) = crate::accel::build_target_graph(&platform, &pre);
            let mask = build_mask(&task.tiles.dag, &target);
            let (found, stats) = ullmann_find_first(&mask, &q, &target.adjacency(), 1_000_000);
            sched_serial_match +=
                cost_model.cpu_serial(&stats, q.rows(), target.len()).seconds;
            last_mask = Some((mask, target));
            if found.is_some() {
                matched_window = Some(offset);
                break;
            }
            offset += 4;
        }
        let _ = matched_window;
        let total_sched = sched_cpu + sched_serial_match;
        // IMMSched's on-accelerator episode searches all windows at once
        // (the relaxed S spans the whole preemptible set)
        let (mask, target) = last_mask.expect("at least one window");
        let pso = PsoConfig::default();
        let out = QuantizedMatcher::new(pso).run(&mask, &q, &target.adjacency());
        let imm = cost_model.accel_pso(&out, q.rows(), target.len(), pso.particles, &platform);
        t.row(vec![
            scenario.into(),
            model.name().into(),
            fmt_time(exec_t),
            fmt_time(total_sched),
            format!("{:.1}x", total_sched / exec_t),
            fmt_time(imm.seconds),
        ]);
    }
    t
}

/// Fig. 2b: PSO stability with vs without continuous relaxation.
///
/// Stability is measured on the *mean current fitness* signal (not the
/// monotone best-so-far): the discrete coupling makes every particle's
/// evaluation jump between one-hot projections, so the swarm signal
/// oscillates; the relaxation smooths it (paper Fig. 2b).  We also
/// report the matched rate — the practical payoff of stable search.
pub fn fig2b(params: &FigureParams) -> (Table, Vec<f64>, Vec<Vec<f64>>) {
    let mut rng = Rng::new(params.seed);
    let (q, g, _) = crate::matcher::ullmann::plant_embedding(8, 20, 0.3, 0.3, &mut rng);
    let mask = crate::util::MatF::full(8, 20, 1.0);
    let steps = 48;
    let run = |relaxed: bool, seed: u64| {
        let cfg = PsoConfig {
            relaxed,
            early_exit: false,
            epochs: 1,
            steps,
            repair_budget: 0, // isolate the swarm itself — no Ullmann help
            seed,
            ..Default::default()
        };
        PsoMatcher::new(cfg).run(&mask, &q, &g)
    };
    let seeds = 5u64;
    let mut avg = [vec![0.0f64; steps], vec![0.0f64; steps]];
    let mut oscillation = [Vec::new(), Vec::new()];
    let mut best = [Vec::new(), Vec::new()];
    for s in 0..seeds {
        for (i, relaxed) in [(0, true), (1, false)] {
            let out = run(relaxed, params.seed + s);
            // normalized step-to-step jitter of the swarm-mean fitness
            let tr = &out.mean_fitness_trace;
            let scale = tr.iter().map(|f| f.abs()).fold(1e-6f32, f32::max) as f64;
            let jitter: f64 = tr
                .windows(2)
                .map(|w| ((w[1] - w[0]).abs() as f64) / scale)
                .sum::<f64>()
                / (steps - 1) as f64;
            oscillation[i].push(jitter);
            best[i].push(out.best_fitness as f64);
            for k in 0..steps {
                avg[i][k] += tr[k] as f64 / seeds as f64;
            }
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let std = |v: &[f64]| {
        let m = mean(v);
        (v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / v.len() as f64).sqrt()
    };
    let mut t = Table::new("Fig 2b: continuous relaxation stabilizes the search").header(&[
        "variant",
        "swarm jitter (norm. |Δf|/step)",
        "best-fitness std over seeds",
    ]);
    t.row(vec![
        "relaxed (IMMSched)".into(),
        format!("{:.4}", mean(&oscillation[0])),
        format!("{:.3}", std(&best[0])),
    ]);
    t.row(vec![
        "discrete coupling".into(),
        format!("{:.4}", mean(&oscillation[1])),
        format!("{:.3}", std(&best[1])),
    ]);
    let xs: Vec<f64> = (0..steps).map(|k| k as f64).collect();
    let [relaxed_avg, discrete_avg] = avg;
    (t, xs, vec![relaxed_avg, discrete_avg])
}

/// Shared engine for Figs. 6-8: run every framework on every cell once.
pub struct GridResults {
    /// [(platform, class, framework, summary)]
    pub cells: Vec<(PlatformKind, WorkloadClass, FrameworkKind, metrics::SimSummary)>,
}

pub fn run_grid(params: &FigureParams) -> GridResults {
    let mut cells = Vec::new();
    for (platform, class) in CELLS {
        for framework in FrameworkKind::ALL {
            let res = run_cell(platform, class, framework, params.arrival_rate, params);
            cells.push((platform, class, framework, metrics::summarize(&res)));
        }
    }
    GridResults { cells }
}

impl GridResults {
    fn get(&self, p: PlatformKind, c: WorkloadClass, f: FrameworkKind) -> &metrics::SimSummary {
        &self
            .cells
            .iter()
            .find(|(cp, cc, cf, _)| *cp == p && *cc == c && *cf == f)
            .expect("cell missing")
            .3
    }

    /// Geomean of `metric(IMMSched) / metric(baseline)` (or inverse)
    /// across all six cells.
    fn mean_ratio(&self, baseline: FrameworkKind, metric: impl Fn(&metrics::SimSummary) -> f64, higher_better: bool) -> f64 {
        let ratios: Vec<f64> = CELLS
            .iter()
            .map(|&(p, c)| {
                let ours = metric(self.get(p, c, FrameworkKind::ImmSched));
                let base = metric(self.get(p, c, baseline));
                if higher_better {
                    ours / base.max(1e-30)
                } else {
                    base / ours.max(1e-30)
                }
            })
            .collect();
        crate::util::stats::geomean(&ratios)
    }
}

/// Fig. 6: normalized Speedup (urgent total latency, baseline / IMMSched).
pub fn fig6(grid: &GridResults) -> Table {
    let mut t = Table::new("Fig 6: normalized speedup (urgent total latency vs IMMSched)")
        .header(&["platform", "class", "PREMA", "CD-MSA", "Planaria", "MoCA", "IsoSched", "IMMSched"]);
    for (p, c) in CELLS {
        let imm = grid.get(p, c, FrameworkKind::ImmSched).urgent_latency;
        let cell = |f: FrameworkKind| -> String {
            let lat = grid.get(p, c, f).urgent_latency;
            fmt_ratio(lat / imm.max(1e-30))
        };
        t.row(vec![
            p.name().into(),
            c.name().into(),
            cell(FrameworkKind::Prema),
            cell(FrameworkKind::CdMsa),
            cell(FrameworkKind::Planaria),
            cell(FrameworkKind::Moca),
            cell(FrameworkKind::IsoSched),
            "×1.00".into(),
        ]);
    }
    let mut avg_row = vec!["geomean".to_string(), "all".to_string()];
    for f in [
        FrameworkKind::Prema,
        FrameworkKind::CdMsa,
        FrameworkKind::Planaria,
        FrameworkKind::Moca,
        FrameworkKind::IsoSched,
    ] {
        avg_row.push(fmt_ratio(grid.mean_ratio(f, |s| s.urgent_latency, false)));
    }
    avg_row.push("×1.00".into());
    t.row(avg_row);
    t
}

/// Fig. 7: normalized LBT.  λ sweep per cell (bounded bisection).
pub fn fig7(params: &FigureParams) -> Table {
    let mut t = Table::new("Fig 7: normalized LBT (max sustainable urgent rate vs IMMSched)")
        .header(&["platform", "class", "PREMA", "CD-MSA", "Planaria", "MoCA", "IsoSched", "IMMSched [q/s]"]);
    let mut ratios: Vec<Vec<f64>> = vec![Vec::new(); 5];
    for (p, c) in CELLS {
        let lbt_of = |f: FrameworkKind| -> f64 {
            metrics::lbt_sweep(
                |lambda| {
                    // scale the horizon so every probe sees ~30 urgent
                    // arrivals — a fixed horizon under-samples low rates
                    // and turns the deadline rate into noise
                    let mut probe = *params;
                    probe.horizon = (30.0 / lambda).clamp(0.02, 0.5);
                    let res = run_cell(p, c, f, lambda, &probe);
                    let urgent = res.urgent().count();
                    if urgent < 5 {
                        return 1.0; // under-sampled: sustainable so far
                    }
                    metrics::summarize(&res).deadline_rate
                },
                params.lbt_target,
                20.0,
            )
            // floor: "below 1 query/s" is reported as 1 (the paper's
            // bars are normalized, never zero)
            .max(1.0)
        };
        let imm = lbt_of(FrameworkKind::ImmSched);
        let baselines = [
            FrameworkKind::Prema,
            FrameworkKind::CdMsa,
            FrameworkKind::Planaria,
            FrameworkKind::Moca,
            FrameworkKind::IsoSched,
        ];
        let mut row = vec![p.name().to_string(), c.name().to_string()];
        for (i, f) in baselines.iter().enumerate() {
            let b = lbt_of(*f);
            let ratio = imm / b.max(1e-9);
            ratios[i].push(ratio);
            row.push(format!("{}", fmt_ratio(ratio)));
        }
        row.push(format!("{imm:.0}"));
        t.row(row);
    }
    let mut avg = vec!["geomean".to_string(), "IMM vs base".to_string()];
    for r in &ratios {
        avg.push(fmt_ratio(crate::util::stats::geomean(r)));
    }
    avg.push("—".into());
    t.row(avg);
    t
}

/// Fig. 8: normalized energy efficiency (tasks/J, IMMSched / baseline).
pub fn fig8(grid: &GridResults) -> Table {
    let mut t = Table::new("Fig 8: normalized energy efficiency (tasks/J vs baselines)")
        .header(&["platform", "class", "PREMA", "CD-MSA", "Planaria", "MoCA", "IsoSched", "IMMSched [tasks/J]"]);
    for (p, c) in CELLS {
        let imm = grid.get(p, c, FrameworkKind::ImmSched).tasks_per_joule;
        let cell = |f: FrameworkKind| -> String {
            let b = grid.get(p, c, f).tasks_per_joule;
            fmt_ratio(imm / b.max(1e-30))
        };
        t.row(vec![
            p.name().into(),
            c.name().into(),
            cell(FrameworkKind::Prema),
            cell(FrameworkKind::CdMsa),
            cell(FrameworkKind::Planaria),
            cell(FrameworkKind::Moca),
            cell(FrameworkKind::IsoSched),
            format!("{:.1}", imm),
        ]);
    }
    let mut avg = vec!["geomean".to_string(), "all".to_string()];
    for f in [
        FrameworkKind::Prema,
        FrameworkKind::CdMsa,
        FrameworkKind::Planaria,
        FrameworkKind::Moca,
        FrameworkKind::IsoSched,
    ] {
        avg.push(fmt_ratio(grid.mean_ratio(f, |s| s.tasks_per_joule, true)));
    }
    avg.push("—".into());
    t.row(avg);
    t
}

// ---------------------------------------------------------------------------
// Perf-over-PRs trajectory (tracked bench JSONs)
// ---------------------------------------------------------------------------

/// Current schema tags of the tracked bench trajectory files.
pub const MATCHER_BENCH_SCHEMA: &str = "immsched.bench_matcher/v2";
pub const CLUSTER_BENCH_SCHEMA: &str = "immsched.bench_cluster/v1";
pub const EXPERIMENT_BENCH_SCHEMA: &str = "immsched.bench_experiment/v1";

/// Default locations of the tracked trajectories (repo root).
pub fn default_trajectory_paths() -> (std::path::PathBuf, std::path::PathBuf) {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    (root.join("BENCH_matcher.json"), root.join("BENCH_cluster.json"))
}

/// Parse a tracked bench trajectory document and return its entries.
///
/// The document must be `{ "schema": <expected>, "entries": [...] }`.
/// Anything else — in particular the retired single-run
/// `immsched.bench_matcher/v1` layout — is rejected **loudly** with a
/// migration hint instead of being silently merged into the trajectory.
pub fn load_bench_entries(
    text: &str,
    expected_schema: &str,
) -> anyhow::Result<Vec<crate::util::json::Json>> {
    use crate::util::json::Json;
    let doc = Json::parse(text)?;
    match doc.get("schema").and_then(Json::as_str) {
        Some(schema) if schema == expected_schema => {}
        Some(other) => anyhow::bail!(
            "bench trajectory schema mismatch: found {other:?}, expected \
             {expected_schema:?} — schema-v1 single-run files are no longer \
             merged; delete the file (or re-run the bench binary, which \
             rewrites it) to migrate"
        ),
        None => anyhow::bail!(
            "bench trajectory has no \"schema\" field (expected {expected_schema:?})"
        ),
    }
    let entries = doc
        .get("entries")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow::anyhow!("bench trajectory has no \"entries\" array"))?;
    Ok(entries.to_vec())
}

/// Append one run entry to the trajectory document at `path` and return
/// the new entry count — the single read-validate-append-write path both
/// bench binaries share.  A missing file starts a fresh trajectory; an
/// existing file must carry `expected_schema` (a retired v1 single-run
/// file fails loudly) unless `fresh` discards it deliberately.
pub fn append_bench_entry(
    path: &str,
    expected_schema: &str,
    entry: crate::util::json::Json,
    fresh: bool,
) -> anyhow::Result<usize> {
    append_bench_entry_pruned(path, expected_schema, entry, fresh, &|_| false)
}

/// [`append_bench_entry`] that first drops accumulated entries matching
/// `prune` — how `bench_matcher`'s first *measured* run supersedes the
/// analytic `measured: false` seed estimate instead of letting the two
/// sit side by side in the trajectory forever.
pub fn append_bench_entry_pruned(
    path: &str,
    expected_schema: &str,
    entry: crate::util::json::Json,
    fresh: bool,
    prune: &dyn Fn(&crate::util::json::Json) -> bool,
) -> anyhow::Result<usize> {
    use crate::util::json::Json;
    let mut entries: Vec<Json> = match (fresh, std::fs::read_to_string(path)) {
        (true, _) | (false, Err(_)) => Vec::new(),
        (false, Ok(text)) => load_bench_entries(&text, expected_schema)
            .map_err(|e| e.context(format!("refusing to append to {path}")))?,
    };
    let before = entries.len();
    entries.retain(|e| !prune(e));
    if entries.len() < before {
        crate::log_info!(
            "bench trajectory {path}: pruned {} superseded entries",
            before - entries.len()
        );
    }
    entries.push(entry);
    let count = entries.len();
    let doc = Json::obj(vec![
        ("schema", Json::from(expected_schema)),
        ("entries", Json::Arr(entries)),
    ]);
    std::fs::write(path, doc.render())?;
    Ok(count)
}

/// The perf-over-PRs trajectory: one row per accumulated bench entry
/// (matcher hot path, then cluster serving), plus the matcher line
/// series (largest-class sparse-fitness speedup and epoch latency per
/// entry) for the CSV plot.
///
/// Pass the *contents* of the tracked JSON files; `None` for a
/// trajectory that does not exist yet.
pub fn perf_trajectory(
    matcher_text: Option<&str>,
    cluster_text: Option<&str>,
) -> anyhow::Result<(Table, Vec<f64>, Vec<Vec<f64>>)> {
    use crate::util::json::Json;
    let num = |e: &Json, k: &str| e.get(k).and_then(Json::as_f64);
    let text = |e: &Json, k: &str| e.get(k).and_then(Json::as_str).unwrap_or("?").to_string();

    let mut t = Table::new("perf trajectory over PRs (tracked bench entries)").header(&[
        "source",
        "entry",
        "label",
        "largest class",
        "fitness speedup",
        "epoch latency",
        "service episode",
        "cluster p95",
        "SLO miss",
    ]);
    let mut xs = Vec::new();
    let mut speedups = Vec::new();
    let mut epoch_us = Vec::new();

    if let Some(matcher) = matcher_text {
        let entries = load_bench_entries(matcher, MATCHER_BENCH_SCHEMA)?;
        for (i, e) in entries.iter().enumerate() {
            let largest = text(e, "largest_class");
            let speedup = num(e, "largest_class_fitness_speedup").unwrap_or(f64::NAN);
            // per-class detail of the largest class, when present
            let class = e.get("classes").and_then(Json::as_array).and_then(|cs| {
                cs.iter().find(|c| c.get("class").and_then(Json::as_str) == Some(&largest))
            });
            let epoch_ns = class.and_then(|c| num(c, "epoch_native_ns"));
            let service_ns = class.and_then(|c| num(c, "service_episode_ns"));
            // smoke runs cover fewer/smaller classes and estimates are
            // not measurements — both are labeled in the table and kept
            // out of the plotted perf series (incomparable points)
            let smoke = e.get("smoke").and_then(Json::as_bool).unwrap_or(false);
            let measured = e.get("measured").and_then(Json::as_bool).unwrap_or(true);
            let tag = if smoke {
                " (smoke)"
            } else if !measured {
                " (estimate)"
            } else {
                ""
            };
            t.row(vec![
                "matcher".into(),
                i.to_string(),
                format!("{}{tag}", text(e, "label")),
                largest,
                format!("{speedup:.2}x"),
                epoch_ns.map_or("-".into(), |x| fmt_time(x / 1e9)),
                service_ns.map_or("-".into(), |x| fmt_time(x / 1e9)),
                "-".into(),
                "-".into(),
            ]);
            if !smoke && measured {
                xs.push(i as f64);
                speedups.push(speedup);
                epoch_us.push(epoch_ns.map_or(f64::NAN, |x| x / 1e3));
            }
        }
    }
    if let Some(cluster) = cluster_text {
        let entries = load_bench_entries(cluster, CLUSTER_BENCH_SCHEMA)?;
        for (i, e) in entries.iter().enumerate() {
            let submitted = num(e, "submitted").unwrap_or(0.0);
            let misses = num(e, "slo_misses").unwrap_or(0.0);
            t.row(vec![
                "cluster".into(),
                i.to_string(),
                text(e, "label"),
                format!("{} shards / {}", num(e, "shards").unwrap_or(0.0), text(e, "policy")),
                "-".into(),
                "-".into(),
                "-".into(),
                num(e, "p95_latency_s").map_or("-".into(), fmt_time),
                if submitted > 0.0 {
                    format!("{:.1}%", 100.0 * misses / submitted)
                } else {
                    "-".into()
                },
            ]);
        }
    }
    Ok((t, xs, vec![speedups, epoch_us]))
}

/// The observability table: per tracked cluster entry, what the
/// measurement plane itself costs (the bench's paired-run
/// `obs_overhead` block) next to the incident counters it exists to
/// explain.  Entries from before the plane existed render as `-`.
pub fn obs_trajectory(cluster_text: &str) -> anyhow::Result<Table> {
    use crate::util::json::Json;
    let entries = load_bench_entries(cluster_text, CLUSTER_BENCH_SCHEMA)?;
    let mut t = Table::new("observability plane (per tracked cluster entry)").header(&[
        "entry",
        "label",
        "transport",
        "mean lat (obs off)",
        "mean lat (obs on)",
        "overhead",
        "shard failures",
        "replays",
        "sheds at floor",
    ]);
    for (i, e) in entries.iter().enumerate() {
        let obs = e.get("obs_overhead");
        let failover = e.get("failover");
        let onum = |k: &str| obs.and_then(|o| o.get(k)).and_then(Json::as_f64);
        let fnum = |k: &str| failover.and_then(|f| f.get(k)).and_then(Json::as_f64);
        let count = |v: Option<f64>| v.map_or("-".into(), |x| format!("{x}"));
        t.row(vec![
            i.to_string(),
            e.get("label").and_then(Json::as_str).unwrap_or("?").into(),
            e.get("transport").and_then(Json::as_str).unwrap_or("?").into(),
            onum("mean_latency_off_s").map_or("-".into(), fmt_time),
            onum("mean_latency_on_s").map_or("-".into(), fmt_time),
            onum("overhead_pct").map_or("-".into(), |p| format!("{p:+.2}%")),
            count(fnum("shard_failures")),
            count(fnum("replays")),
            count(fnum("shed_at_floor")),
        ]);
    }
    Ok(t)
}

// ---------------------------------------------------------------------------
// Experiment-campaign report (cluster::experiment summaries)
// ---------------------------------------------------------------------------

/// The campaign's LBT curve: max sustainable arrival rate per route
/// policy at the configured SLO-miss threshold (the paper's Fig. 7
/// analogue over the modeled cluster).
pub fn experiment_lbt_table(summary: &crate::util::json::Json) -> Table {
    use crate::util::json::Json;
    let mut t = Table::new("LBT: max sustainable λ per route policy")
        .header(&["policy", "LBT (req/s)", "miss target", "probes", "note"]);
    for p in summary.get("lbt").and_then(Json::as_array).unwrap_or(&[]) {
        let num = |k: &str| p.get(k).and_then(Json::as_f64);
        let saturated = p.get("saturated_budget").and_then(Json::as_bool).unwrap_or(false);
        t.row(vec![
            p.get("policy").and_then(Json::as_str).unwrap_or("?").into(),
            num("lbt_rate").map_or("-".into(), |r| format!("{r:.1}")),
            num("target_miss").map_or("-".into(), fmt_ratio),
            num("probes").map_or("-".into(), |n| format!("{n}")),
            if saturated { "≥ (budget-capped)".into() } else { String::new() },
        ]);
    }
    t
}

/// Per-cell tail-latency / SLO-miss / preemption-waste table, one row
/// per grid cell in canonical cell order.
pub fn experiment_cells_table(summary: &crate::util::json::Json) -> Table {
    use crate::util::json::Json;
    const COLS: [&str; 9] =
        ["cell", "reps", "submitted", "SLO miss ±ci95", "p50", "p95", "p99", "waste", "resumes"];
    let mut t = Table::new("grid cells: tail latency, SLO miss, preemption waste").header(&COLS);
    for c in summary.get("cells").and_then(Json::as_array).unwrap_or(&[]) {
        let num = |k: &str| c.get(k).and_then(Json::as_f64);
        let agg = |k: &str, f: &str| c.get(k).and_then(|a| a.get(f)).and_then(Json::as_f64);
        let miss = agg("slo_miss_rate", "mean");
        let ci = agg("slo_miss_rate", "ci95").unwrap_or(0.0);
        t.row(vec![
            c.get("id").and_then(Json::as_str).unwrap_or("?").into(),
            num("reps").map_or("-".into(), |n| format!("{n}")),
            num("submitted_mean").map_or("-".into(), |n| format!("{n:.1}")),
            miss.map_or("-".into(), |m| format!("{} ±{:.3}", fmt_ratio(m), ci)),
            num("p50_s").map_or("-".into(), fmt_time),
            num("p95_s").map_or("-".into(), fmt_time),
            num("p99_s").map_or("-".into(), fmt_time),
            agg("preempt_waste", "mean").map_or("-".into(), fmt_ratio),
            num("resumes_mean").map_or("-".into(), |n| format!("{n:.1}")),
        ]);
    }
    t
}

/// The quota tournament: mean SLO-miss rate per epoch-quota spec across
/// every cell that used it, winner(s) flagged.
pub fn experiment_tournament_table(summary: &crate::util::json::Json) -> Table {
    use crate::util::json::Json;
    let mut t = Table::new("quota tournament: SLO-miss rate per epoch-quota policy")
        .header(&["quota", "mean SLO miss", "cells", "verdict"]);
    for q in summary.get("tournament").and_then(Json::as_array).unwrap_or(&[]) {
        let best = q.get("best").and_then(Json::as_bool).unwrap_or(false);
        t.row(vec![
            q.get("quota").and_then(Json::as_str).unwrap_or("?").into(),
            q.get("slo_miss_rate").and_then(Json::as_f64).map_or("-".into(), fmt_ratio),
            q.get("cells").and_then(Json::as_f64).map_or("-".into(), |n| format!("{n}")),
            if best { "wins/ties".into() } else { String::new() },
        ]);
    }
    t
}

/// The full rendered campaign report (LBT curve, quota tournament,
/// per-cell tables) — what `bench_experiment --report-out` writes and
/// CI uploads next to the trajectory.
pub fn experiment_report(summary: &crate::util::json::Json) -> Vec<Table> {
    vec![
        experiment_lbt_table(summary),
        experiment_tournament_table(summary),
        experiment_cells_table(summary),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_1_and_2_render() {
        let t1 = table1();
        assert!(t1.render().contains("IMMSched"));
        let t2 = table2();
        assert!(t2.render().contains("Cloud"));
    }

    #[test]
    fn fig2b_shows_relaxation_advantage() {
        let params = FigureParams { seed: 7, ..Default::default() };
        let (t, xs, series) = fig2b(&params);
        assert_eq!(xs.len(), 48);
        assert_eq!(series.len(), 2);
        assert!(!t.is_empty());
        // the relaxed swarm-mean trace jitters less than the discrete one
        let jitter = |s: &[f64]| -> f64 {
            let scale = s.iter().map(|f| f.abs()).fold(1e-9, f64::max);
            s.windows(2).map(|w| (w[1] - w[0]).abs() / scale).sum::<f64>()
        };
        assert!(
            jitter(&series[0]) < jitter(&series[1]),
            "relaxed jitter {} >= discrete jitter {}",
            jitter(&series[0]),
            jitter(&series[1])
        );
    }

    #[test]
    fn trajectory_accepts_v2_and_renders() {
        let matcher = r#"{
  "schema": "immsched.bench_matcher/v2",
  "entries": [
    {
      "label": "pr2-estimate",
      "largest_class": "huge",
      "largest_class_fitness_speedup": 6.74,
      "classes": [
        {"class": "huge", "epoch_native_ns": 10500000.0, "service_episode_ns": null}
      ]
    },
    {
      "label": "pr4",
      "largest_class": "huge",
      "largest_class_fitness_speedup": 7.1,
      "classes": [
        {"class": "huge", "epoch_native_ns": 9000000.0, "service_episode_ns": 1.5e7}
      ]
    }
  ]
}"#;
        let cluster = r#"{
  "schema": "immsched.bench_cluster/v1",
  "entries": [
    {"label": "pr4", "shards": 2, "policy": "deadline-aware",
     "submitted": 40, "slo_misses": 3, "p95_latency_s": 0.012}
  ]
}"#;
        let (t, xs, series) = perf_trajectory(Some(matcher), Some(cluster)).expect("trajectory");
        let rendered = t.render();
        assert!(rendered.contains("pr2-estimate"));
        assert!(rendered.contains("deadline-aware"));
        assert_eq!(xs.len(), 2);
        assert_eq!(series[0], vec![6.74, 7.1]);
        // missing trajectories are fine (fresh checkout)
        let (empty, xs, _) = perf_trajectory(None, None).expect("empty");
        assert!(xs.is_empty());
        assert!(!empty.render().is_empty());
    }

    /// A measured append prunes superseded analytic-estimate entries.
    #[test]
    fn pruned_append_drops_estimate_entries() {
        use crate::util::json::Json;
        let dir = std::env::temp_dir().join(format!("immsched-prune-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("traj.json");
        let path = path.to_str().unwrap();
        let estimate = Json::obj(vec![
            ("label", Json::from("pr2-seed-estimate")),
            ("measured", Json::from(false)),
        ]);
        let count =
            append_bench_entry(path, MATCHER_BENCH_SCHEMA, estimate, true).unwrap();
        assert_eq!(count, 1);
        let measured =
            Json::obj(vec![("label", Json::from("real-run")), ("measured", Json::from(true))]);
        let is_estimate =
            |e: &Json| e.get("measured").and_then(Json::as_bool) == Some(false);
        let count = append_bench_entry_pruned(
            path,
            MATCHER_BENCH_SCHEMA,
            measured,
            false,
            &is_estimate,
        )
        .unwrap();
        assert_eq!(count, 1, "the estimate must be superseded, not accumulated");
        let text = std::fs::read_to_string(path).unwrap();
        let entries = load_bench_entries(&text, MATCHER_BENCH_SCHEMA).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].get("label").and_then(Json::as_str), Some("real-run"));
        std::fs::remove_file(path).ok();
    }

    /// The observability table renders overhead + incident counters,
    /// and degrades to `-` on entries that predate the plane.
    #[test]
    fn obs_trajectory_renders_overhead_and_incidents() {
        let cluster = r#"{"schema": "immsched.bench_cluster/v1", "entries": [
            {"label": "pre-obs", "transport": "in-process"},
            {"label": "with-obs", "transport": "socket",
             "obs_overhead": {"mean_latency_off_s": 0.0100,
                              "mean_latency_on_s": 0.0101,
                              "overhead_pct": 1.0},
             "failover": {"shard_failures": 1, "replays": 3, "shed_at_floor": 0}}
        ]}"#;
        let text = obs_trajectory(cluster).expect("obs table").render();
        assert!(text.contains("with-obs"), "{text}");
        assert!(text.contains("+1.00%"), "{text}");
        assert!(text.contains("socket"), "{text}");
        // the pre-plane entry renders placeholders, not garbage
        let pre = text.lines().find(|l| l.contains("pre-obs")).expect("pre-obs row");
        assert!(pre.contains('-'), "{pre}");
    }

    /// The retired single-run v1 layout must fail loudly, never merge.
    #[test]
    fn trajectory_rejects_schema_v1_loudly() {
        let v1 = r#"{"schema": "immsched.bench_matcher/v1", "smoke": false, "classes": []}"#;
        let err = load_bench_entries(v1, MATCHER_BENCH_SCHEMA).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("immsched.bench_matcher/v1"), "error must name the bad schema: {msg}");
        assert!(msg.contains("expected"), "{msg}");
        let missing = load_bench_entries("{}", MATCHER_BENCH_SCHEMA).unwrap_err();
        assert!(format!("{missing:#}").contains("schema"));
    }

    #[test]
    fn single_cell_runs() {
        let params = FigureParams { horizon: 0.01, ..Default::default() };
        let res = run_cell(
            PlatformKind::Edge,
            WorkloadClass::Simple,
            FrameworkKind::ImmSched,
            50.0,
            &params,
        );
        assert!(res.completed_count() > 0);
    }
}
