//! `immsched-lint`: the dependency-free invariant linter.
//!
//! Everything this reproduction claims descends from one property —
//! bit-exact determinism (serial ≡ threaded PSO epochs, bit-identical
//! warm-start resume, a wire codec that survives a process hop).  The
//! rules in [`rules`] mechanize the invariants that property rests
//! on; this module turns them into a tier-1 gate: `tests/lint.rs` runs
//! the linter over the live tree under plain `cargo test`, and the
//! `lint` binary (`cargo run --release --bin lint`) walks `src/`,
//! `tests/` and `benches/`, prints findings, writes a machine-readable
//! JSON report, and exits nonzero on any finding.
//!
//! In the repo's own idiom (`util::json` precedent) the scanner is
//! token-level and dependency-free — no `syn`.  The [`lexer`] blanks
//! comments and string/char literals so quoted counter-examples never
//! trigger rules, maps `#[cfg(test)]` bodies for per-rule test
//! exemptions, and harvests suppression pragmas.
//!
//! # Pragmas
//!
//! A finding is suppressed by a line comment on the same line, or
//! standing alone directly above it (further comment-only lines may
//! intervene):
//!
//! ```text
//! // lint:allow(no-wallclock-core): telemetry-only timing, never ordering
//! ```
//!
//! The justification text after the colon is mandatory; a pragma
//! without one, naming an unknown rule, or suppressing nothing is
//! itself reported (as [`BAD_PRAGMA`] / [`UNUSED_PRAGMA`]), so stale
//! escapes cannot accumulate.

mod lexer;
mod rules;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

pub use lexer::{scrub, Pragma, Scrub};
pub use rules::{
    NO_FLOAT_UNWRAP_ORD, NO_HASH_ITER_DETERMINISM, NO_LOSSY_WIRE_CAST, NO_PANIC_TRANSPORT,
    NO_UNBOUNDED_RETRY, NO_WALLCLOCK_CORE, OBS_CLOCK_DISCIPLINE, RULES,
};

/// Schema tag carried by the JSON findings report.
pub const REPORT_SCHEMA: &str = "immsched.lint/v1";

/// A malformed `lint:allow` pragma: missing justification text, or an
/// unknown rule name.
pub const BAD_PRAGMA: &str = "lint-pragma";

/// A justified `lint:allow` pragma that suppresses nothing.
pub const UNUSED_PRAGMA: &str = "unused-lint-allow";

/// One linter finding, attributed to a file and 1-based line.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Crate-relative path, `/`-separated.
    pub path: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl Finding {
    /// `path:line: [rule] message` — the human-facing form.
    pub fn display_line(&self) -> String {
        format!("{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("path", Json::from(self.path.as_str())),
            ("line", Json::from(self.line)),
            ("rule", Json::from(self.rule)),
            ("message", Json::from(self.message.as_str())),
        ])
    }
}

/// The result of linting a tree: every finding, sorted by
/// (path, line, rule), plus how many files were scanned.
#[derive(Debug, Default)]
pub struct Report {
    pub root: String,
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Machine-readable form (uploaded as a CI artifact).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::from(REPORT_SCHEMA)),
            ("root", Json::from(self.root.as_str())),
            ("files_scanned", Json::from(self.files_scanned)),
            ("findings", Json::Arr(self.findings.iter().map(Finding::to_json).collect())),
        ])
    }
}

/// Lint one file's source text.  `rel_path` is the crate-relative,
/// `/`-separated path — it selects which rules are in scope.
pub fn lint_source(rel_path: &str, source: &str) -> Vec<Finding> {
    let scrubbed = lexer::scrub(source);
    let raw = rules::scan(rel_path, &scrubbed);
    let mut used = vec![false; scrubbed.pragmas.len()];
    let mut findings: Vec<Finding> = Vec::new();
    for f in raw {
        match pragma_covering(&scrubbed, f.line, f.rule) {
            Some(idx) => used[idx] = true,
            None => findings.push(Finding {
                path: rel_path.to_string(),
                line: f.line,
                rule: f.rule,
                message: f.message,
            }),
        }
    }
    for (idx, p) in scrubbed.pragmas.iter().enumerate() {
        if !RULES.contains(&p.rule.as_str()) {
            findings.push(Finding {
                path: rel_path.to_string(),
                line: p.line,
                rule: BAD_PRAGMA,
                message: format!("lint:allow names unknown rule {:?}", p.rule),
            });
        } else if !p.justified {
            findings.push(Finding {
                path: rel_path.to_string(),
                line: p.line,
                rule: BAD_PRAGMA,
                message: format!(
                    "lint:allow({}) has no justification — write \
                     `// lint:allow({}): <why this site is safe>`",
                    p.rule, p.rule
                ),
            });
        } else if !used[idx] {
            findings.push(Finding {
                path: rel_path.to_string(),
                line: p.line,
                rule: UNUSED_PRAGMA,
                message: format!("lint:allow({}) suppresses nothing — remove it", p.rule),
            });
        }
    }
    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}

/// Which justified pragma (if any) covers a finding of `rule` at
/// `line`: one trailing on the same line, or one standing alone above
/// it with only comment/blank lines in between.
fn pragma_covering(scrubbed: &Scrub, line: usize, rule: &str) -> Option<usize> {
    for (idx, p) in scrubbed.pragmas.iter().enumerate() {
        if p.rule != rule || !p.justified {
            continue;
        }
        if p.line == line {
            return Some(idx);
        }
        if p.line < line
            && !scrubbed.line_has_code(p.line)
            && (p.line + 1..line).all(|l| !scrubbed.line_has_code(l))
        {
            return Some(idx);
        }
    }
    None
}

/// Lint every `.rs` file under `<root>/{src,tests,benches}`.
pub fn lint_tree(root: &Path) -> Result<Report> {
    let mut files: Vec<PathBuf> = Vec::new();
    for dir in ["src", "tests", "benches"] {
        collect_rs(&root.join(dir), &mut files)?;
    }
    files.sort();
    let mut findings = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let source = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        findings.extend(lint_source(&rel, &source));
    }
    findings.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule))
    });
    Ok(Report {
        root: root.to_string_lossy().into_owned(),
        files_scanned: files.len(),
        findings,
    })
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("reading directory {}", dir.display()))?
    {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
