//! The seven `immsched-lint` rules and their module scopes.
//!
//! Every rule mechanizes one invariant the reproduction's claims rest
//! on (see `rust/README.md`, "Invariants enforced by static analysis"):
//! the tree stays bit-exactly deterministic, NaN-safe, and
//! panic-free across the transport boundary not because reviewers
//! remember to check, but because `cargo run --bin lint` fails when it
//! is not.
//!
//! Scopes are path prefixes relative to the crate root (`src/…`,
//! `tests/…`, `benches/…`); an entry ending in `/` matches a subtree,
//! anything else matches one file exactly.

use super::lexer::{find_ident, ident_at, is_ident_byte, match_brace, match_paren, skip_ws, Scrub};

/// `partial_cmp(..).unwrap()` / comparator callbacks built on
/// `partial_cmp` — one NaN operand panics the comparison.  Applies
/// everywhere, tests included.
pub const NO_FLOAT_UNWRAP_ORD: &str = "no-float-unwrap-ord";

/// `HashMap`/`HashSet` in deterministic modules — iteration order is
/// randomized per process and can leak into results or wire bytes.
pub const NO_HASH_ITER_DETERMINISM: &str = "no-hash-iter-determinism";

/// `Instant::now`/`SystemTime` outside the service/driver boundary —
/// core algorithms must be replayable; only relative timeouts cross
/// the wire.
pub const NO_WALLCLOCK_CORE: &str = "no-wallclock-core";

/// `.unwrap()`/`.expect()`/panicking macros/indexing in the transport
/// layer — a decode failure must stay a loud `Err`, never a worker
/// abort.  `#[cfg(test)]` bodies are exempt.
pub const NO_PANIC_TRANSPORT: &str = "no-panic-transport";

/// Bare `as` numeric casts in the wire codec — narrowing must go
/// through `From`/`TryFrom` or the checked `util::json` helpers so the
/// bit-exact encodings cannot silently truncate.
pub const NO_LOSSY_WIRE_CAST: &str = "no-lossy-wire-cast";

/// `loop`/`while` in the supervision/chaos layer with no visible bound
/// identifier (`max`/`cap`/`limit`/`budget`/`bound`/`threshold`) — a
/// recovery path that retries forever turns one dead worker into a
/// hung fleet.  Loops that are genuinely unbounded by design (the
/// heartbeat; a blocking wait whose failure paths all converge) carry
/// a `lint:allow` with the termination argument.
pub const NO_UNBOUNDED_RETRY: &str = "no-unbounded-retry";

/// Wall-clock reads (`Instant::now`/`SystemTime`) inside `src/obs/`
/// anywhere but `src/obs/clock.rs` — every observability stamp must go
/// through the `obs::clock` seam so the logical clock can make dumps
/// and traces bit-exactly reproducible in tests.
pub const OBS_CLOCK_DISCIPLINE: &str = "obs-clock-discipline";

/// All real rules (pragma-hygiene findings use separate names).
pub const RULES: [&str; 7] = [
    NO_FLOAT_UNWRAP_ORD,
    NO_HASH_ITER_DETERMINISM,
    NO_WALLCLOCK_CORE,
    NO_PANIC_TRANSPORT,
    NO_LOSSY_WIRE_CAST,
    NO_UNBOUNDED_RETRY,
    OBS_CLOCK_DISCIPLINE,
];

/// Modules whose iteration order / float ordering reaches results or
/// wire bytes ([`NO_HASH_ITER_DETERMINISM`]).
const DETERMINISTIC_MODULES: &[&str] = &[
    "src/matcher/",
    "src/graph/",
    "src/obs/",
    "src/cluster/wire.rs",
    "src/cluster/policy.rs",
    "src/cluster/experiment/",
    "src/scheduler/lts_policies.rs",
];

/// Boundary modules allowed to read the wall clock: binaries, benches,
/// tests, and the service/driver/socket layers that anchor relative
/// timeouts ([`NO_WALLCLOCK_CORE`] applies everywhere else).
const WALLCLOCK_BOUNDARY: &[&str] = &[
    "src/main.rs",
    "src/bin/",
    "benches/",
    "tests/",
    "examples/",
    "src/coordinator/service.rs",
    "src/cluster/mod.rs",
    "src/cluster/driver.rs",
    "src/cluster/transport.rs",
    "src/cluster/net/",
    "src/obs/clock.rs",
];

/// The transport layer ([`NO_PANIC_TRANSPORT`]): the wire codec, the
/// transports (including the socket subsystem), and the
/// supervision/chaos layers stacked on them — a panic anywhere here
/// aborts a worker or the supervisor itself.
const TRANSPORT_MODULES: &[&str] = &[
    "src/cluster/wire.rs",
    "src/cluster/transport.rs",
    "src/cluster/supervise.rs",
    "src/cluster/chaos.rs",
    "src/cluster/net/",
    "src/obs/",
];

/// The wire codec itself ([`NO_LOSSY_WIRE_CAST`]).
const WIRE_MODULES: &[&str] = &["src/cluster/wire.rs"];

/// The fault-recovery layer ([`NO_UNBOUNDED_RETRY`]): supervision,
/// chaos, the socket subsystem's reconnect/accept/heartbeat loops, and
/// the experiment harness's event/claim loops (a campaign that spins
/// forever is as dead as a worker that never reconnects).
const RETRY_MODULES: &[&str] = &[
    "src/cluster/supervise.rs",
    "src/cluster/chaos.rs",
    "src/cluster/net/",
    "src/cluster/experiment/",
];

fn in_listed(rel: &str, list: &[&str]) -> bool {
    list.iter().any(|m| if m.ends_with('/') { rel.starts_with(m) } else { rel == *m })
}

/// One pre-pragma finding (file attached by the caller).
#[derive(Clone, Debug)]
pub struct RawFinding {
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

/// Run every rule in scope for `rel` over one scrubbed file.
pub fn scan(rel: &str, scrub: &Scrub) -> Vec<RawFinding> {
    let mut out = Vec::new();
    float_unwrap_ord(scrub, &mut out);
    if in_listed(rel, DETERMINISTIC_MODULES) {
        hash_collections(scrub, &mut out);
    }
    if !in_listed(rel, WALLCLOCK_BOUNDARY) {
        wallclock(scrub, &mut out);
    }
    if in_listed(rel, TRANSPORT_MODULES) {
        panic_transport(scrub, &mut out);
    }
    if in_listed(rel, WIRE_MODULES) {
        lossy_casts(scrub, &mut out);
    }
    if in_listed(rel, RETRY_MODULES) {
        unbounded_retry(scrub, &mut out);
    }
    if rel.starts_with("src/obs/") && rel != "src/obs/clock.rs" {
        obs_clock(scrub, &mut out);
    }
    // one construct can trip a rule via several probes (e.g. a sort_by
    // whose callback also unwraps); collapse to one finding per line
    out.sort_by(|x, y| (x.line, x.rule).cmp(&(y.line, y.rule)));
    out.dedup_by(|x, y| x.line == y.line && x.rule == y.rule);
    out
}

// ---------------------------------------------------------------------------
// rule 1: no-float-unwrap-ord
// ---------------------------------------------------------------------------

fn float_unwrap_ord(scrub: &Scrub, out: &mut Vec<RawFinding>) {
    let code = &scrub.code;
    let bytes = code.as_bytes();
    // form A: a partial_cmp(...) call whose result is unwrapped
    for at in find_ident(code, "partial_cmp") {
        let open = skip_ws(bytes, at + "partial_cmp".len());
        if bytes.get(open) != Some(&b'(') {
            continue;
        }
        let Some(close) = match_paren(bytes, open) else { continue };
        let dot = skip_ws(bytes, close + 1);
        if bytes.get(dot) != Some(&b'.') {
            continue;
        }
        let name = ident_at(bytes, skip_ws(bytes, dot + 1));
        if name == b"unwrap" || name == b"expect" {
            out.push(RawFinding {
                line: scrub.line_of(at),
                rule: NO_FLOAT_UNWRAP_ORD,
                message: "partial_cmp(..).unwrap() panics on NaN; use total_cmp \
                          (NaN orders last, the queue.rs convention)"
                    .into(),
            });
        }
    }
    // form B: a comparator callback built on partial_cmp (sort_by &
    // friends) — even a non-panicking fallback makes the order lie
    for word in ["sort_by", "sort_unstable_by", "min_by", "max_by"] {
        for at in find_ident(code, word) {
            let open = skip_ws(bytes, at + word.len());
            if bytes.get(open) != Some(&b'(') {
                continue;
            }
            let Some(close) = match_paren(bytes, open) else { continue };
            let body = code.get(open..close).unwrap_or("");
            if !find_ident(body, "partial_cmp").is_empty() {
                out.push(RawFinding {
                    line: scrub.line_of(at),
                    rule: NO_FLOAT_UNWRAP_ORD,
                    message: format!(
                        "{word} comparator built on partial_cmp; use total_cmp so \
                         NaN has a defined (last) position"
                    ),
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// rule 2: no-hash-iter-determinism
// ---------------------------------------------------------------------------

fn hash_collections(scrub: &Scrub, out: &mut Vec<RawFinding>) {
    for word in ["HashMap", "HashSet"] {
        for at in find_ident(&scrub.code, word) {
            out.push(RawFinding {
                line: scrub.line_of(at),
                rule: NO_HASH_ITER_DETERMINISM,
                message: format!(
                    "{word} iteration order is randomized per process; use \
                     BTreeMap/BTreeSet (or sorted iteration) in deterministic modules"
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// rule 3: no-wallclock-core
// ---------------------------------------------------------------------------

fn wallclock(scrub: &Scrub, out: &mut Vec<RawFinding>) {
    let code = &scrub.code;
    let bytes = code.as_bytes();
    for at in find_ident(code, "Instant") {
        let colon = skip_ws(bytes, at + "Instant".len());
        if bytes.get(colon) == Some(&b':')
            && bytes.get(colon + 1) == Some(&b':')
            && ident_at(bytes, skip_ws(bytes, colon + 2)) == b"now"
        {
            out.push(RawFinding {
                line: scrub.line_of(at),
                rule: NO_WALLCLOCK_CORE,
                message: "Instant::now() outside the service/driver boundary makes \
                          core results unreplayable; thread a clock in from the caller"
                    .into(),
            });
        }
    }
    for at in find_ident(code, "SystemTime") {
        out.push(RawFinding {
            line: scrub.line_of(at),
            rule: NO_WALLCLOCK_CORE,
            message: "SystemTime outside the service/driver boundary makes core \
                      results unreplayable; only relative timeouts may cross the wire"
                .into(),
        });
    }
}

// ---------------------------------------------------------------------------
// rule 4: no-panic-transport
// ---------------------------------------------------------------------------

/// Keywords that may legitimately precede a `[` without forming an
/// index expression (`let [u, v] = …`, `match x { … }[`-adjacent, …).
const PRE_BRACKET_KEYWORDS: &[&[u8]] = &[
    b"let", b"else", b"match", b"return", b"in", b"if", b"while", b"loop", b"mut", b"ref",
    b"move", b"break", b"continue", b"as", b"unsafe",
];

fn panic_transport(scrub: &Scrub, out: &mut Vec<RawFinding>) {
    let code = &scrub.code;
    let bytes = code.as_bytes();
    let push = |out: &mut Vec<RawFinding>, at: usize, message: String| {
        let line = scrub.line_of(at);
        if !scrub.in_test_code(line) {
            out.push(RawFinding { line, rule: NO_PANIC_TRANSPORT, message });
        }
    };
    for word in ["unwrap", "expect"] {
        for at in find_ident(code, word) {
            if preceded_by_dot_or_path(bytes, at) {
                push(
                    out,
                    at,
                    format!(
                        ".{word}() in the transport layer turns a decode failure \
                         into a worker abort; propagate an Err instead"
                    ),
                );
            }
        }
    }
    for word in ["panic", "unreachable", "todo", "unimplemented"] {
        for at in find_ident(code, word) {
            if bytes.get(at + word.len()) == Some(&b'!') {
                push(
                    out,
                    at,
                    format!("{word}! in the transport layer aborts the worker; bail! instead"),
                );
            }
        }
    }
    for (at, &b) in bytes.iter().enumerate() {
        if b == b'[' && is_index_expression(bytes, at) {
            push(
                out,
                at,
                "indexing/slicing can panic in the transport layer; use get()/\
                 slice patterns or prove the bound and lint:allow with the proof"
                    .into(),
            );
        }
    }
}

fn preceded_by_dot_or_path(bytes: &[u8], at: usize) -> bool {
    let mut i = at;
    while i > 0 && bytes[i - 1].is_ascii_whitespace() {
        i -= 1;
    }
    i > 0 && (bytes[i - 1] == b'.' || (i > 1 && bytes[i - 1] == b':' && bytes[i - 2] == b':'))
}

/// A `[` opens an index expression when the previous non-space token is
/// an identifier (that is not a keyword), a `)`, or a `]`.
fn is_index_expression(bytes: &[u8], at: usize) -> bool {
    let mut p = at;
    while p > 0 && bytes[p - 1] == b' ' {
        p -= 1;
    }
    if p == 0 {
        return false;
    }
    let prev = bytes[p - 1];
    if prev == b')' || prev == b']' {
        return true;
    }
    if !is_ident_byte(prev) {
        return false;
    }
    let mut s = p - 1;
    while s > 0 && is_ident_byte(bytes[s - 1]) {
        s -= 1;
    }
    let word = bytes.get(s..p).unwrap_or(&[]);
    !PRE_BRACKET_KEYWORDS.contains(&word)
}

// ---------------------------------------------------------------------------
// rule 5: no-lossy-wire-cast
// ---------------------------------------------------------------------------

const NUMERIC_PRIMITIVES: &[&[u8]] = &[
    b"u8", b"u16", b"u32", b"u64", b"u128", b"usize", b"i8", b"i16", b"i32", b"i64", b"i128",
    b"isize", b"f32", b"f64",
];

fn lossy_casts(scrub: &Scrub, out: &mut Vec<RawFinding>) {
    let code = &scrub.code;
    let bytes = code.as_bytes();
    for at in find_ident(code, "as") {
        let target = ident_at(bytes, skip_ws(bytes, at + 2));
        if NUMERIC_PRIMITIVES.contains(&target) {
            out.push(RawFinding {
                line: scrub.line_of(at),
                rule: NO_LOSSY_WIRE_CAST,
                message: "bare `as` numeric cast in the wire codec can silently \
                          truncate; use From/TryFrom or the checked util::json helpers"
                    .into(),
            });
        }
    }
}

// ---------------------------------------------------------------------------
// rule 6: no-unbounded-retry
// ---------------------------------------------------------------------------

/// Identifier fragments that signal a loop is bounded (a counter
/// compared against a maximum, a budget, a threshold…).
const RETRY_BOUND_WORDS: &[&str] = &["max", "cap", "budget", "limit", "bound", "threshold"];

fn unbounded_retry(scrub: &Scrub, out: &mut Vec<RawFinding>) {
    let code = &scrub.code;
    let bytes = code.as_bytes();
    for word in ["loop", "while"] {
        for at in find_ident(code, word) {
            // the loop's full span: keyword → matching close brace of
            // its body (for `while`, the condition rides along, so a
            // bound in either the condition or the body counts)
            let Some(open) = next_brace(bytes, at + word.len()) else { continue };
            let Some(close) = match_brace(bytes, open) else { continue };
            let span = code.get(at..close).unwrap_or("");
            if !has_bound_ident(span) {
                let line = scrub.line_of(at);
                if scrub.in_test_code(line) {
                    continue;
                }
                out.push(RawFinding {
                    line,
                    rule: NO_UNBOUNDED_RETRY,
                    message: format!(
                        "{word} in the fault-recovery layer has no visible bound \
                         (no max/cap/limit/budget/bound/threshold identifier); bound \
                         the retry or lint:allow with the termination argument"
                    ),
                });
            }
        }
    }
}

/// First `{` at or after `from` (the loop body's opening brace).
fn next_brace(bytes: &[u8], from: usize) -> Option<usize> {
    bytes.iter().skip(from).position(|&b| b == b'{').map(|off| from + off)
}

/// Whether any identifier in `span` contains a bound-signalling
/// fragment (case-insensitive).
fn has_bound_ident(span: &str) -> bool {
    let bytes = span.as_bytes();
    let mut i = 0;
    while i < bytes.len() {
        if is_ident_byte(bytes[i]) && (i == 0 || !is_ident_byte(bytes[i - 1])) {
            let mut j = i;
            while j < bytes.len() && is_ident_byte(bytes[j]) {
                j += 1;
            }
            let ident = span.get(i..j).map(str::to_ascii_lowercase).unwrap_or_default();
            if RETRY_BOUND_WORDS.iter().any(|w| ident.contains(w)) {
                return true;
            }
            i = j;
        } else {
            i += 1;
        }
    }
    false
}

// ---------------------------------------------------------------------------
// rule 7: obs-clock-discipline
// ---------------------------------------------------------------------------

/// Same wall-clock token detection as rule 3, but scoped to the
/// observability subtree and pointing at the `obs::clock` seam — the
/// two rules stack there on purpose (an `obs/` wall-clock read is both
/// unreplayable *and* a clock-seam bypass).
fn obs_clock(scrub: &Scrub, out: &mut Vec<RawFinding>) {
    let code = &scrub.code;
    let bytes = code.as_bytes();
    for at in find_ident(code, "Instant") {
        let colon = skip_ws(bytes, at + "Instant".len());
        if bytes.get(colon) == Some(&b':')
            && bytes.get(colon + 1) == Some(&b':')
            && ident_at(bytes, skip_ws(bytes, colon + 2)) == b"now"
        {
            out.push(RawFinding {
                line: scrub.line_of(at),
                rule: OBS_CLOCK_DISCIPLINE,
                message: "Instant::now() in obs/ bypasses the obs::clock seam; stamp \
                          through clock::now_nanos() so the logical clock stays honest"
                    .into(),
            });
        }
    }
    for at in find_ident(code, "SystemTime") {
        out.push(RawFinding {
            line: scrub.line_of(at),
            rule: OBS_CLOCK_DISCIPLINE,
            message: "SystemTime in obs/ bypasses the obs::clock seam; stamp through \
                      clock::now_nanos() so the logical clock stays honest"
                .into(),
        });
    }
}
