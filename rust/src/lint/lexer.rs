//! The scrubbing lexer behind `immsched-lint`.
//!
//! Rules must see *code tokens only*: a doc comment quoting
//! `partial_cmp().unwrap()` as a cautionary tale, or a fixture snippet
//! embedded in a test as a raw string, must never produce a finding.
//! [`scrub`] therefore rewrites the source into an equal-length string
//! in which every comment, string literal, and char literal is blanked
//! to spaces (newlines kept, so byte offsets map to the original line
//! numbers), while harvesting `lint:allow` pragmas from plain `//`
//! line comments (doc comments only ever *quote* pragma syntax)
//! and mapping `#[cfg(test)] mod … { … }` regions so per-rule test-code
//! exemptions can be applied by line.
//!
//! This is a token-level scanner, not a parser — the repo deliberately
//! carries no `syn`-class dependency (see `util::json` for the same
//! trade).  The lexer handles the constructs that actually occur in
//! real Rust source: nested block comments, escapes in string/char
//! literals, raw strings (`r"…"`, `r#"…"#`), byte literals (`b"…"`,
//! `b'…'`, `br#"…"#`), and the char-literal-versus-lifetime ambiguity
//! of a lone `'`.  Non-ASCII bytes are blanked as well, so the scrubbed
//! text is pure ASCII and safe to slice at any offset.

/// One `// lint:allow(<rule>): <justification>` pragma.
#[derive(Clone, Debug)]
pub struct Pragma {
    /// 1-based line the pragma comment sits on.
    pub line: usize,
    /// The rule name between the parentheses (not yet validated).
    pub rule: String,
    /// Whether non-trivial justification text follows the rule name.
    pub justified: bool,
}

/// The scrubbed view of one source file.
pub struct Scrub {
    /// Same byte length as the input; comment/literal/non-ASCII bytes
    /// are spaces, newlines are preserved.
    pub code: String,
    /// Byte offset where each line begins (line 1 at offset 0).
    line_starts: Vec<usize>,
    /// Pragmas harvested from line comments, in source order.
    pub pragmas: Vec<Pragma>,
    /// Inclusive 1-based line ranges of `#[cfg(test)] mod` bodies.
    test_ranges: Vec<(usize, usize)>,
    /// Per line (0-indexed): does any non-whitespace code survive?
    code_lines: Vec<bool>,
}

impl Scrub {
    /// 1-based line number of a byte offset into the original source.
    pub fn line_of(&self, offset: usize) -> usize {
        self.line_starts.partition_point(|&s| s <= offset)
    }

    /// Is this 1-based line inside a `#[cfg(test)] mod` body?
    pub fn in_test_code(&self, line: usize) -> bool {
        self.test_ranges.iter().any(|&(lo, hi)| line >= lo && line <= hi)
    }

    /// Does this 1-based line carry any code after scrubbing?
    pub fn line_has_code(&self, line: usize) -> bool {
        line >= 1 && self.code_lines.get(line - 1).copied().unwrap_or(false)
    }
}

/// Blank comments and literals out of `src` (see module docs).
pub fn scrub(src: &str) -> Scrub {
    let bytes = src.as_bytes();
    let mut code: Vec<u8> = bytes.to_vec();
    // (byte offset, rule, justified) — lines resolved after the scan
    let mut raw_pragmas: Vec<(usize, String, bool)> = Vec::new();

    let mut i = 0;
    while i < bytes.len() {
        let b = bytes[i];
        if b == b'/' && bytes.get(i + 1) == Some(&b'/') {
            let start = i;
            // doc comments (`///`, `//!`) may *quote* pragma syntax —
            // only plain `//` comments carry live pragmas
            let doc = matches!(bytes.get(i + 2), Some(&b'/') | Some(&b'!'));
            while i < bytes.len() && bytes[i] != b'\n' {
                i += 1;
            }
            if !doc {
                if let Some((rule, justified)) = parse_pragma(&src[start..i]) {
                    raw_pragmas.push((start, rule, justified));
                }
            }
            blank(&mut code, start, i);
        } else if b == b'/' && bytes.get(i + 1) == Some(&b'*') {
            let start = i;
            let mut depth = 1usize;
            i += 2;
            while i < bytes.len() && depth > 0 {
                if bytes[i] == b'/' && bytes.get(i + 1) == Some(&b'*') {
                    depth += 1;
                    i += 2;
                } else if bytes[i] == b'*' && bytes.get(i + 1) == Some(&b'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            blank(&mut code, start, i);
        } else if b == b'"' {
            i = blank_string(&mut code, bytes, i);
        } else if b == b'r' && !prev_is_ident(bytes, i) {
            match raw_quote_after(bytes, i + 1) {
                Some(q) => i = blank_raw_string(&mut code, bytes, i, q),
                None => i += 1,
            }
        } else if b == b'b' && !prev_is_ident(bytes, i) {
            match bytes.get(i + 1) {
                Some(&b'"') => i = blank_string_from(&mut code, bytes, i, i + 1),
                Some(&b'\'') => i = blank_char_from(&mut code, bytes, i, i + 1),
                Some(&b'r') => match raw_quote_after(bytes, i + 2) {
                    Some(q) => i = blank_raw_string(&mut code, bytes, i, q),
                    None => i += 1,
                },
                _ => i += 1,
            }
        } else if b == b'\'' {
            i = char_or_lifetime(&mut code, bytes, i);
        } else {
            i += 1;
        }
    }

    // force pure ASCII so rule scans can slice anywhere (math glyphs in
    // the few identifiers-adjacent positions would only ever *hide* a
    // token, never invent one)
    for b in code.iter_mut() {
        if *b >= 0x80 {
            *b = b' ';
        }
    }
    let code = String::from_utf8(code).expect("scrubbed text is pure ASCII");

    let mut line_starts = vec![0usize];
    for (idx, &b) in bytes.iter().enumerate() {
        if b == b'\n' {
            line_starts.push(idx + 1);
        }
    }
    let code_lines: Vec<bool> = code.lines().map(|l| !l.trim().is_empty()).collect();

    let mut out = Scrub {
        code,
        line_starts,
        pragmas: Vec::new(),
        test_ranges: Vec::new(),
        code_lines,
    };
    out.pragmas = raw_pragmas
        .into_iter()
        .map(|(offset, rule, justified)| Pragma { line: out.line_of(offset), rule, justified })
        .collect();
    out.test_ranges = test_regions(&out.code)
        .into_iter()
        .map(|(open, close)| (out.line_of(open), out.line_of(close)))
        .collect();
    out
}

/// Parse `lint:allow(<rule>)[: justification]` out of one line comment.
fn parse_pragma(comment: &str) -> Option<(String, bool)> {
    let at = comment.find("lint:allow(")?;
    let rest = &comment[at + "lint:allow(".len()..];
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    let after = rest[close + 1..]
        .trim_start_matches(|c: char| c == ':' || c.is_whitespace())
        .trim();
    // a justification must carry real words — a bare colon or a couple
    // of punctuation characters do not explain anything
    Some((rule, after.len() >= 8))
}

/// Find every `#[cfg(test)] mod … { … }` body as a byte range.
fn test_regions(code: &str) -> Vec<(usize, usize)> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = code[from..].find("cfg(test)") {
        let at = from + pos;
        from = at + "cfg(test)".len();
        let Some(open) = find_mod_open(code, from) else { continue };
        let Some(close) = match_brace(bytes, open) else { continue };
        out.push((open, close));
    }
    out
}

/// From just past a `cfg(test)` attribute, locate the opening brace of
/// a `mod` item declared within the next few tokens (`None` when the
/// attribute gates something other than a module).
fn find_mod_open(code: &str, after: usize) -> Option<usize> {
    let window_end = (after + 160).min(code.len());
    let rel = find_ident(&code[after..window_end], "mod").into_iter().next()?;
    let brace = code[after + rel..].find('{')?;
    Some(after + rel + brace)
}

/// Whole-word occurrences of `word` in (scrubbed) `code`.
pub fn find_ident(code: &str, word: &str) -> Vec<usize> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = code[from..].find(word) {
        let at = from + pos;
        from = at + 1;
        let end = at + word.len();
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let after_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            out.push(at);
        }
    }
    out
}

/// Is this byte part of an identifier?
pub fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// First non-whitespace position at or after `i`.
pub fn skip_ws(bytes: &[u8], mut i: usize) -> usize {
    while i < bytes.len() && bytes[i].is_ascii_whitespace() {
        i += 1;
    }
    i
}

/// The identifier starting at `i` (empty when none starts there).
pub fn ident_at(bytes: &[u8], i: usize) -> &[u8] {
    let mut j = i;
    while j < bytes.len() && is_ident_byte(bytes[j]) {
        j += 1;
    }
    bytes.get(i..j).unwrap_or(&[])
}

/// Offset of the `)` matching the `(` at `open`.
pub fn match_paren(bytes: &[u8], open: usize) -> Option<usize> {
    match_delims(bytes, open, b'(', b')')
}

/// Offset of the `}` matching the `{` at `open`.
pub fn match_brace(bytes: &[u8], open: usize) -> Option<usize> {
    match_delims(bytes, open, b'{', b'}')
}

fn match_delims(bytes: &[u8], open: usize, od: u8, cd: u8) -> Option<usize> {
    let mut depth = 0usize;
    let mut i = open;
    while i < bytes.len() {
        if bytes[i] == od {
            depth += 1;
        } else if bytes[i] == cd {
            depth = depth.checked_sub(1)?;
            if depth == 0 {
                return Some(i);
            }
        }
        i += 1;
    }
    None
}

fn prev_is_ident(bytes: &[u8], i: usize) -> bool {
    i > 0 && is_ident_byte(bytes[i - 1])
}

/// After a raw-string prefix (`r` or `br`), the position of the opening
/// quote past any `#`s — `None` when this is not a raw string (e.g. a
/// raw identifier `r#match` or a plain ident starting with `r`).
fn raw_quote_after(bytes: &[u8], mut j: usize) -> Option<usize> {
    while bytes.get(j) == Some(&b'#') {
        j += 1;
    }
    (bytes.get(j) == Some(&b'"')).then_some(j)
}

fn blank(code: &mut [u8], start: usize, end: usize) {
    for b in code.iter_mut().take(end.min(code.len())).skip(start) {
        if *b != b'\n' {
            *b = b' ';
        }
    }
}

/// Blank a normal string literal whose opening quote is at `q`;
/// returns the offset just past the closing quote.
fn blank_string(code: &mut [u8], bytes: &[u8], q: usize) -> usize {
    blank_string_from(code, bytes, q, q)
}

fn blank_string_from(code: &mut [u8], bytes: &[u8], start: usize, q: usize) -> usize {
    let mut i = q + 1;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' => i += 2,
            b'"' => {
                i += 1;
                break;
            }
            _ => i += 1,
        }
    }
    blank(code, start, i);
    i
}

/// Blank a raw string: `start` is the prefix (`r`/`b`), `q` the opening
/// quote; the `#`s between them set the closing delimiter.
fn blank_raw_string(code: &mut [u8], bytes: &[u8], start: usize, q: usize) -> usize {
    let hashes = q - start - usize::from(bytes.get(start) == Some(&b'b')) - 1;
    let mut i = q + 1;
    while i < bytes.len() {
        if bytes[i] == b'"'
            && i + 1 + hashes <= bytes.len()
            && bytes[i + 1..i + 1 + hashes].iter().all(|&c| c == b'#')
        {
            i += 1 + hashes;
            break;
        }
        i += 1;
    }
    blank(code, start, i);
    i
}

/// Blank a definite char literal whose opening quote is at `q`.
fn blank_char_from(code: &mut [u8], bytes: &[u8], start: usize, q: usize) -> usize {
    let mut i = q + 1;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' => i += 2,
            b'\'' => {
                i += 1;
                break;
            }
            _ => i += 1,
        }
    }
    blank(code, start, i);
    i
}

/// A lone `'` opens a char literal iff a closing quote follows within
/// one (possibly escaped or multi-byte) character; otherwise it
/// introduces a lifetime and stays in place.
fn char_or_lifetime(code: &mut [u8], bytes: &[u8], q: usize) -> usize {
    match bytes.get(q + 1) {
        Some(&b'\\') => blank_char_from(code, bytes, q, q),
        Some(&c) => {
            let width = utf8_width(c);
            if bytes.get(q + 1 + width) == Some(&b'\'') {
                blank_char_from(code, bytes, q, q)
            } else {
                q + 1
            }
        }
        None => q + 1,
    }
}

fn utf8_width(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b < 0xE0 {
        2
    } else if b < 0xF0 {
        3
    } else {
        4
    }
}
