//! `bench_cluster` — the tracked cluster-serving pipeline.
//!
//! Two phases per run:
//!
//! 1. **Preempt → persist → resume proof.**  Every shard is loaded with
//!    a long-running Background episode (an infeasible full-mask star,
//!    epoch budget calibrated to ~hundreds of ms); urgent arrivals are
//!    then routed through the deadline-aware policy, which triggers
//!    cross-shard preemption of the weakest victims.  The cancelled
//!    victims' S*/S̄ snapshots land in the cluster's `ResumeStore`, the
//!    victims are resubmitted, and the run asserts the warm start: the
//!    `resumed` signal is set and the resumed episode's epoch count is
//!    strictly lower than a cold solve of the same request — in fact
//!    victim + resumed epochs must equal the cold budget exactly.
//! 2. **Open-loop trace.**  The MMPP-bursty, trace-driven arrival
//!    driver replays a `workload::models` mix against a second cluster
//!    (default epoch budget), collecting per-shard latency / SLO-miss /
//!    shed / preemption metrics.
//!
//! Results are appended to the `BENCH_cluster.json` trajectory at the
//! repo root (schema `immsched.bench_cluster/v1`).  `--smoke` runs the
//! acceptance scenario (≥2 shards, bursty arrivals, zero lost requests,
//! ≥1 cross-shard preemption, ≥1 warm-started resume) with tiny sizes
//! and fails loudly if any of it does not hold.
//!
//! `--process-shards` runs the identical scenario with every shard
//! hosted in an `immsched shard-worker` child process over the framed
//! wire protocol (the `immsched` binary must be built alongside this
//! one) — the trajectory's `transport` field lets the figure pipeline
//! compare in-process vs out-of-process serving overhead, preemption
//! and warm-start resume included.
//!
//! `--chaos SPEC` wraps every phase-2 shard transport in the
//! deterministic [`FaultInjectingTransport`] with the given scripted
//! schedule (`SEQ:FAULT` entries, e.g. `"2:kill,5:garbage"`), seeded
//! by `--chaos-seed`; the open-loop run then exercises the fleet's
//! failover paths and the trajectory records the failover and chaos
//! counters alongside the serving metrics.
//!
//! `--socket-shards` hosts every shard in an `immsched shard-listen`
//! child dialed over loopback TCP (`--socket-uds` over a Unix-domain
//! socket instead) — the full multi-host path: accept loop, framed
//! session per connection, reconnect-with-resume link supervision.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use immsched::cluster::driver::{run_open_loop, schedule_from_trace, DriverConfig, TimedRequest};
use immsched::cluster::net::{spawn_shard_listener, ListenerChild, SocketShard};
use immsched::cluster::transport::worker_binary;
use immsched::cluster::{
    policy_by_name, ChaosSchedule, ClusterConfig, FaultInjectingTransport, InProcessShard,
    MatchCluster, ProcessShard, RoutePolicy, ShardTransport, SupervisedFleet, SupervisorConfig,
};
use immsched::coordinator::{CancelToken, GlobalController, MatchPath, MatchProblem, ServiceConfig};
use immsched::graph::{gen_chain, NodeKind};
use immsched::matcher::PsoConfig;
use immsched::report::figures::{append_bench_entry, CLUSTER_BENCH_SCHEMA};
use immsched::scheduler::{ArrivalProcess, Priority};
use immsched::util::json::Json;
use immsched::util::table::fmt_time;
use immsched::util::MatF;
use immsched::workload::WorkloadClass;

struct Args {
    smoke: bool,
    fresh: bool,
    shards: usize,
    /// Host each shard in an `immsched shard-worker` child process
    /// over the wire protocol instead of an in-process service thread —
    /// the trajectory compares the two transports' overhead.
    process_shards: bool,
    /// Host each shard in an `immsched shard-listen` child dialed over
    /// loopback TCP — the full socket path, link supervision included.
    socket_shards: bool,
    /// As `--socket-shards`, but over a Unix-domain socket.
    socket_uds: bool,
    policy: String,
    rate: f64,
    horizon: f64,
    class: WorkloadClass,
    process: ArrivalProcess,
    seed: u64,
    label: String,
    out: String,
    /// Scripted chaos schedule for the open-loop phase (`SEQ:FAULT`
    /// entries); `None` = no fault injection.
    chaos: Option<String>,
    chaos_seed: u64,
    /// Enable the observability plane and write the flight-recorder
    /// dump here at the end of the run (and on any mid-run incident).
    obs_out: Option<String>,
}

impl Args {
    fn socket(&self) -> bool {
        self.socket_shards || self.socket_uds
    }

    fn transport_name(&self) -> &'static str {
        if self.socket_uds {
            "socket-uds"
        } else if self.socket_shards {
            "socket"
        } else if self.process_shards {
            "process"
        } else {
            "in-process"
        }
    }
}

fn parse_args() -> Result<Args> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let flag = |name: &str| argv.iter().position(|a| a == name).and_then(|i| argv.get(i + 1));
    let smoke = argv.iter().any(|a| a == "--smoke");
    let class = match flag("--class").map(String::as_str).unwrap_or("simple") {
        "simple" => WorkloadClass::Simple,
        "middle" => WorkloadClass::Middle,
        "complex" => WorkloadClass::Complex,
        other => bail!("unknown class {other:?} (simple|middle|complex)"),
    };
    let process = match flag("--process").map(String::as_str).unwrap_or("bursty") {
        "poisson" => ArrivalProcess::Poisson,
        "bursty" => ArrivalProcess::bursty_default(),
        other => bail!("unknown process {other:?} (poisson|bursty)"),
    };
    Ok(Args {
        smoke,
        fresh: argv.iter().any(|a| a == "--fresh"),
        process_shards: argv.iter().any(|a| a == "--process-shards"),
        socket_shards: argv.iter().any(|a| a == "--socket-shards"),
        socket_uds: argv.iter().any(|a| a == "--socket-uds"),
        shards: flag("--shards").map(|s| s.parse()).transpose()?.unwrap_or(2).max(1),
        policy: flag("--policy").cloned().unwrap_or_else(|| "deadline-aware".into()),
        rate: flag("--rate").map(|s| s.parse()).transpose()?.unwrap_or(200.0),
        horizon: flag("--horizon")
            .map(|s| s.parse())
            .transpose()?
            .unwrap_or(if smoke { 0.02 } else { 0.1 }),
        class,
        process,
        seed: flag("--seed").map(|s| s.parse()).transpose()?.unwrap_or(42),
        label: flag("--label").cloned().unwrap_or_else(|| "local".into()),
        out: flag("--out").cloned().unwrap_or_else(|| {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_cluster.json").into()
        }),
        chaos: flag("--chaos").cloned(),
        chaos_seed: flag("--chaos-seed").map(|s| s.parse()).transpose()?.unwrap_or(1337),
        obs_out: flag("--obs-out").cloned(),
    })
}

fn make_policy(name: &str) -> Result<Box<dyn RoutePolicy>> {
    policy_by_name(name).ok_or_else(|| {
        anyhow::anyhow!("unknown policy {name:?} (round-robin|least-queue|deadline-aware)")
    })
}

/// The listener address spec for one socket shard slot.
fn socket_spec(args: &Args, slot: usize) -> String {
    if args.socket_uds {
        let dir = std::env::temp_dir();
        format!("unix://{}/immsched-bench-{}-{slot}.sock", dir.display(), std::process::id())
    } else {
        "127.0.0.1:0".into()
    }
}

/// Spawn a cluster on the transport the run is benchmarking.  The
/// returned [`ListenerChild`] handles (socket transports only) must
/// outlive the cluster — dropping one kills its worker.
fn spawn_cluster(
    args: &Args,
    ccfg: ClusterConfig,
) -> Result<(MatchCluster, Vec<ListenerChild>)> {
    let policy = make_policy(&args.policy)?;
    if args.socket() {
        let mut children = Vec::with_capacity(args.shards);
        let mut transports: Vec<Arc<dyn ShardTransport>> = Vec::with_capacity(args.shards);
        for slot in 0..args.shards {
            transports.push(spawn_transport(args, &ccfg, slot, &mut children)?);
        }
        let cluster = MatchCluster::with_transports(transports, policy, ccfg.resume_capacity);
        Ok((cluster, children))
    } else if args.process_shards {
        Ok((MatchCluster::spawn_process_shards(ccfg, policy)?, Vec::new()))
    } else {
        Ok((MatchCluster::spawn(ccfg, policy)?, Vec::new()))
    }
}

/// One bare (un-wrapped) shard transport of the benchmarked kind; a
/// socket transport's listener child is appended to `children`.
fn spawn_transport(
    args: &Args,
    ccfg: &ClusterConfig,
    slot: usize,
    children: &mut Vec<ListenerChild>,
) -> Result<Arc<dyn ShardTransport>> {
    Ok(if args.socket() {
        let bin = worker_binary()?;
        let child =
            spawn_shard_listener(&bin, &socket_spec(args, slot), &[], Duration::from_secs(30))?;
        let shard = SocketShard::connect(child.addr().clone(), ccfg.service, ccfg.pso)?;
        children.push(child);
        Arc::new(shard)
    } else if args.process_shards {
        let bin = worker_binary()?;
        Arc::new(ProcessShard::spawn_at(&bin, ccfg.service, ccfg.pso)?)
    } else {
        Arc::new(InProcessShard::spawn(ccfg.service, ccfg.pso)?)
    })
}

/// Spawn the phase-2 cluster, wrapping every shard in the seeded
/// fault-injection decorator when `--chaos` is set.  Returns the
/// concrete chaos handles so the trajectory can read their counters.
fn spawn_chaos_cluster(
    args: &Args,
    ccfg: ClusterConfig,
    schedule: &ChaosSchedule,
) -> Result<(MatchCluster, Vec<Arc<FaultInjectingTransport>>, Vec<ListenerChild>)> {
    let policy = make_policy(&args.policy)?;
    let mut wrapped: Vec<Arc<dyn ShardTransport>> = Vec::with_capacity(args.shards);
    let mut chaos = Vec::with_capacity(args.shards);
    let mut children = Vec::new();
    for shard in 0..args.shards {
        let inner = spawn_transport(args, &ccfg, shard, &mut children)?;
        let c = Arc::new(FaultInjectingTransport::new(
            inner,
            schedule.clone(),
            args.chaos_seed ^ shard as u64,
        ));
        chaos.push(Arc::clone(&c));
        wrapped.push(c);
    }
    let cluster = MatchCluster::with_transports(wrapped, policy, ccfg.resume_capacity);
    Ok((cluster, chaos, children))
}

/// Price the observability plane: the same phase-2 schedule driven
/// through fresh in-process clusters with the plane off, then on (each
/// mode best-of-3 to damp scheduler noise).  In-process always — the
/// probe measures the instrumentation's hot-path cost, not transport
/// jitter.  Leaves the plane disabled; the caller restores `--obs-out`
/// state if needed.
fn measure_obs_overhead(
    args: &Args,
    dcfg: &DriverConfig,
    schedule: &[TimedRequest],
) -> Result<Json> {
    let run_once = |on: bool| -> Result<f64> {
        if on {
            immsched::obs::enable_all();
        } else {
            immsched::obs::disable_all();
        }
        immsched::obs::tracer().clear();
        immsched::obs::recorder().clear();
        let ccfg = ClusterConfig {
            shards: args.shards,
            service: ServiceConfig::default(),
            pso: PsoConfig { seed: args.seed, ..Default::default() },
            resume_capacity: 1024,
        };
        let cluster = MatchCluster::spawn(ccfg, make_policy(&args.policy)?)?;
        let fleet = SupervisedFleet::new(Arc::new(cluster), SupervisorConfig::default());
        let report = run_open_loop(&fleet, schedule, dcfg)?;
        fleet.drain()?;
        Ok(report.mean_latency())
    };
    let best_of = |on: bool| -> Result<f64> {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            best = best.min(run_once(on)?);
        }
        Ok(best)
    };
    let off = best_of(false)?;
    let on = best_of(true)?;
    immsched::obs::disable_all();
    let overhead_pct = if off > 0.0 { (on - off) / off * 100.0 } else { 0.0 };
    println!(
        "[bench_cluster] obs_overhead: mean latency off={} on={} ({overhead_pct:+.2}%)",
        fmt_time(off),
        fmt_time(on)
    );
    Ok(Json::obj(vec![
        ("mean_latency_off_s", Json::from(off)),
        ("mean_latency_on_s", Json::from(on)),
        ("overhead_pct", Json::from(overhead_pct)),
    ]))
}

/// A 3-fan-out star cannot embed into a chain, but its full mask has no
/// empty row — the episode runs its whole epoch budget unless preempted.
fn infeasible_star_problem() -> MatchProblem {
    let mut q = MatF::zeros(4, 4);
    q[(0, 1)] = 1.0;
    q[(0, 2)] = 1.0;
    q[(0, 3)] = 1.0;
    let gd = gen_chain(8, NodeKind::Universal);
    MatchProblem::from_dense(&MatF::full(4, 8, 1.0), &q, &gd.adjacency())
}

fn feasible_chain_problem() -> MatchProblem {
    let qd = gen_chain(4, NodeKind::Compute);
    let gd = gen_chain(8, NodeKind::Universal);
    MatchProblem::from_dags(&qd, &gd)
}

/// Measured outcome of the preempt→persist→resume proof.
struct ResumeProof {
    epoch_budget: usize,
    preemptions: u64,
    victim_epochs: usize,
    resumed_epochs: usize,
    resumed_ok: bool,
}

/// Calibrate an epoch budget so one cold infeasible episode runs for
/// roughly `target_s` — long enough that preemption reliably lands
/// mid-episode, short enough that the resumed tail stays cheap.
fn calibrate_epoch_budget(seed: u64, target_s: f64) -> Result<usize> {
    let probe_epochs = 256usize;
    let cfg = PsoConfig { seed, epochs: probe_epochs, early_exit: true, ..Default::default() };
    let mut ctl = GlobalController::new(cfg)?;
    let problem = infeasible_star_problem();
    let cancel = CancelToken::new();
    let t0 = Instant::now();
    let out = ctl.serve(&problem.request(1, Priority::Background, None), &cancel);
    let elapsed = t0.elapsed().as_secs_f64().max(1e-6);
    anyhow::ensure!(out.epochs_run == probe_epochs, "calibration episode ended early");
    let per_epoch = elapsed / probe_epochs as f64;
    Ok(((target_s / per_epoch) as usize).clamp(512, 4_000_000))
}

/// Phase 1: load every shard with a Background victim, preempt via
/// deadline-aware routing, resume the victims from their snapshots.
fn resume_proof(args: &Args, target_s: f64) -> Result<ResumeProof> {
    let epoch_budget = calibrate_epoch_budget(args.seed, target_s)?;
    println!(
        "[bench_cluster] resume proof: {} shards, calibrated epoch budget {epoch_budget}",
        args.shards
    );
    for attempt in 0..5 {
        // `_children` holds any socket workers alive for the attempt
        let (cluster, _children) = spawn_cluster(
            args,
            ClusterConfig {
                shards: args.shards,
                service: ServiceConfig::default(),
                pso: PsoConfig { seed: args.seed, epochs: epoch_budget, ..Default::default() },
                resume_capacity: 64,
            },
        )?;

        // fillers: one long-running Background episode per shard
        let mut fillers = Vec::new();
        for shard in 0..args.shards {
            fillers.push((
                cluster.submit_to(shard, infeasible_star_problem(), Priority::Background, None)?,
                infeasible_star_problem(),
            ));
        }
        for shard in 0..args.shards {
            let t0 = Instant::now();
            while cluster.views()[shard].in_flight != Some(Priority::Background) {
                if t0.elapsed() > Duration::from_secs(10) {
                    bail!("filler episode never started on shard {shard}");
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        // head start so the victims burn epochs before the preemptors land
        std::thread::sleep(Duration::from_secs_f64(target_s * 0.1));

        // hot arrivals through the policy → cross-shard preemption of
        // the weakest in-flight victims
        let mut urgents = Vec::new();
        for _ in 0..args.shards {
            urgents.push(cluster.submit(feasible_chain_problem(), Priority::Urgent, Some(30.0))?);
            std::thread::sleep(Duration::from_millis(2));
        }
        for u in urgents {
            let resp = u.wait()?;
            anyhow::ensure!(resp.matched(), "urgent request unserved during the proof");
        }

        // victims answer Cancelled; their snapshots are now persisted
        let mut victims = Vec::new();
        for (ticket, problem) in fillers {
            let id = ticket.id;
            let resp = ticket.wait()?;
            victims.push((id, problem, resp));
        }
        let preemptions = cluster.stats().preemptions();
        let best_victim = victims
            .iter()
            .filter(|(_, _, r)| r.path == MatchPath::Cancelled && r.epochs_run >= 1)
            .max_by_key(|(_, _, r)| r.epochs_run);
        let Some((victim_id, victim_problem, victim_resp)) = best_victim else {
            println!("[bench_cluster] attempt {attempt}: no mid-episode victim; retrying");
            continue;
        };
        let victim_id = *victim_id;

        // resume: resubmit the victim under its original id — the
        // persisted snapshot warm-starts it (possibly on another shard)
        anyhow::ensure!(
            cluster.resume_store().contains(victim_id),
            "victim snapshot missing from the resume store"
        );
        let resumed = cluster
            .resubmit(victim_id, victim_problem.clone(), Priority::Background, None)?
            .wait()?;
        let resumed_ok = resumed.resumed
            && resumed.path != MatchPath::Cancelled
            && resumed.epochs_run < epoch_budget
            && victim_resp.epochs_run + resumed.epochs_run == epoch_budget;
        println!(
            "[bench_cluster] attempt {attempt}: preemptions={preemptions} victim_epochs={} \
             resumed_epochs={} cold_epochs={epoch_budget} resumed_signal={}",
            victim_resp.epochs_run, resumed.epochs_run, resumed.resumed
        );
        if preemptions >= 1 && resumed_ok {
            return Ok(ResumeProof {
                epoch_budget,
                preemptions,
                victim_epochs: victim_resp.epochs_run,
                resumed_epochs: resumed.epochs_run,
                resumed_ok,
            });
        }
    }
    bail!("preempt→resume proof did not converge in 5 attempts")
}

fn main() -> Result<()> {
    let args = parse_args()?;
    immsched::util::logging::init_from_env();
    if let Some(path) = &args.obs_out {
        immsched::obs::enable_all();
        immsched::obs::recorder::set_dump_path(Some(path.into()));
    }
    println!(
        "[bench_cluster] smoke={} shards={} transport={} policy={} process={} rate={} horizon={}",
        args.smoke,
        args.shards,
        args.transport_name(),
        args.policy,
        args.process.name(),
        args.rate,
        args.horizon
    );

    // ---- phase 1: preempt → persist → resume --------------------------
    let target_s = if args.smoke { 0.3 } else { 0.8 };
    let proof = resume_proof(&args, target_s)?;

    // ---- phase 2: open-loop bursty trace ------------------------------
    let dcfg = DriverConfig {
        class: args.class,
        process: args.process,
        arrival_rate: args.rate,
        horizon: args.horizon,
        seed: args.seed,
        time_scale: 0.0,
        resubmit_cancelled: true,
        ..Default::default()
    };
    let schedule = schedule_from_trace(&dcfg);
    println!("[bench_cluster] trace: {} requests over {}s (modeled)", schedule.len(), args.horizon);
    let ccfg = ClusterConfig {
        shards: args.shards,
        service: ServiceConfig::default(),
        pso: PsoConfig { seed: args.seed, ..Default::default() },
        resume_capacity: 1024,
    };
    let chaos_schedule = match &args.chaos {
        Some(spec) => Some(ChaosSchedule::parse(spec)?),
        None => None,
    };
    let (cluster, chaos_shards, _children) = match &chaos_schedule {
        Some(cs) => {
            println!(
                "[bench_cluster] chaos: schedule {:?} seed {} on every shard",
                cs.summary(),
                args.chaos_seed
            );
            spawn_chaos_cluster(&args, ccfg, cs)?
        }
        None => {
            let (cluster, children) = spawn_cluster(&args, ccfg)?;
            (cluster, Vec::new(), children)
        }
    };
    let fleet = SupervisedFleet::new(Arc::new(cluster), SupervisorConfig::default());
    let report = run_open_loop(&fleet, &schedule, &dcfg)?;
    if let Err(e) = fleet.drain() {
        // a chaos-killed worker legitimately cannot drain
        println!("[bench_cluster] drain after run: {e:#}");
    }
    print!("{}", report.table().render());
    println!(
        "[bench_cluster] {} submitted, {} served, {} shed, {} resumed, {} SLO misses, wall {}",
        report.submitted(),
        report.served(),
        report.count_path(MatchPath::Shed),
        report.resumed(),
        report.slo_misses(),
        fmt_time(report.wall_seconds)
    );
    println!(
        "[bench_cluster] supervision: {} probes, {} shard failures, {} replays, {} sheds at floor",
        report.failover.probes,
        report.failover.shards_failed,
        report.failover.replays,
        report.failover.shed_at_floor
    );

    // ---- observability: final dump, then the overhead probe -----------
    if let Some(path) = &args.obs_out {
        // capture the main run's events before the probe clears them
        immsched::obs::recorder::dump_to_disk("bench-complete");
        println!("[bench_cluster] obs dump written to {path}");
    }
    let obs_overhead = measure_obs_overhead(&args, &dcfg, &schedule)?;
    let obs_overhead_pct =
        obs_overhead.get("overhead_pct").and_then(Json::as_f64).unwrap_or(0.0);
    if args.obs_out.is_some() {
        immsched::obs::enable_all();
    }

    // ---- acceptance (smoke) -------------------------------------------
    let lost = schedule.len() != report.submitted();
    if args.smoke {
        assert!(args.shards >= 2, "smoke needs >= 2 shards");
        assert!(
            matches!(args.process, ArrivalProcess::Bursty { .. }),
            "smoke needs bursty arrivals"
        );
        assert!(
            !lost,
            "lost requests: {} scheduled, {} answered",
            schedule.len(),
            report.submitted()
        );
        assert!(proof.preemptions >= 1, "no cross-shard preemption observed");
        assert!(proof.resumed_ok, "warm-started resume proof failed");
        assert!(
            proof.resumed_epochs < proof.epoch_budget,
            "resumed epoch count {} not below cold solve {}",
            proof.resumed_epochs,
            proof.epoch_budget
        );
        if chaos_schedule.as_ref().is_some_and(|cs| cs.summary().contains("kill")) {
            assert!(
                report.failover.shards_failed >= 1,
                "chaos killed a shard but supervision never declared a failure"
            );
        }
        assert!(
            obs_overhead_pct <= 2.0,
            "observability plane costs {obs_overhead_pct:.2}% mean latency (budget: 2%)"
        );
        println!("[bench_cluster] SMOKE OK");
    }

    // ---- trajectory entry ---------------------------------------------
    let entry = Json::obj(vec![
        ("label", Json::from(args.label.as_str())),
        ("smoke", Json::from(args.smoke)),
        ("shards", Json::from(args.shards)),
        ("transport", Json::from(args.transport_name())),
        ("policy", Json::from(args.policy.as_str())),
        ("process", Json::from(args.process.name())),
        ("arrival_rate", Json::from(args.rate)),
        ("horizon_s", Json::from(args.horizon)),
        ("submitted", Json::from(report.submitted())),
        ("served", Json::from(report.served())),
        ("shed", Json::from(report.count_path(MatchPath::Shed))),
        ("resumed", Json::from(report.resumed())),
        ("slo_misses", Json::from(report.slo_misses())),
        ("preemptions", Json::from(report.cluster.preemptions())),
        ("p50_latency_s", Json::from(report.latency_percentile(50.0))),
        ("p95_latency_s", Json::from(report.latency_percentile(95.0))),
        ("wall_seconds", Json::from(report.wall_seconds)),
        (
            "failover",
            Json::obj(vec![
                ("probes", Json::from(report.failover.probes)),
                ("probe_failures", Json::from(report.failover.probe_failures)),
                ("shard_failures", Json::from(report.failover.shards_failed)),
                ("replays", Json::from(report.failover.replays)),
                ("respawns", Json::from(report.failover.respawns)),
                ("shed_at_floor", Json::from(report.failover.shed_at_floor)),
            ]),
        ),
        (
            "chaos",
            match &chaos_schedule {
                None => Json::Null,
                Some(cs) => {
                    let mut kills = 0u64;
                    let mut drops = 0u64;
                    let mut garbage = 0u64;
                    let mut truncated = 0u64;
                    let mut delays = 0u64;
                    for c in &chaos_shards {
                        let s = c.stats();
                        kills += s.kills;
                        drops += s.dropped_replies;
                        garbage += s.garbage_frames;
                        truncated += s.truncated_frames;
                        delays += s.delays;
                    }
                    Json::obj(vec![
                        ("schedule", Json::from(cs.summary().as_str())),
                        ("seed", Json::from(args.chaos_seed)),
                        ("kills", Json::from(kills)),
                        ("dropped_replies", Json::from(drops)),
                        ("garbage_frames", Json::from(garbage)),
                        ("truncated_frames", Json::from(truncated)),
                        ("delays", Json::from(delays)),
                    ])
                }
            },
        ),
        ("obs_overhead", obs_overhead),
        (
            "resume_proof",
            Json::obj(vec![
                ("epoch_budget", Json::from(proof.epoch_budget)),
                ("preemptions", Json::from(proof.preemptions)),
                ("victim_epochs", Json::from(proof.victim_epochs)),
                ("resumed_epochs", Json::from(proof.resumed_epochs)),
            ]),
        ),
    ]);
    let count = append_bench_entry(&args.out, CLUSTER_BENCH_SCHEMA, entry, args.fresh)?;
    println!("[bench_cluster] wrote {} ({count} trajectory entries)", args.out);
    Ok(())
}
