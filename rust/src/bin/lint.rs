//! `immsched-lint` driver: walk the crate sources, print findings,
//! optionally write the JSON report, exit nonzero on any finding.
//!
//! ```text
//! cargo run --release --bin lint [-- --root <crate-dir>] [--report <findings.json>]
//! ```
//!
//! Exit codes: 0 clean, 1 findings, 2 usage/IO error.  The report is
//! written even when findings exist, so CI can upload it either way.

use std::path::PathBuf;
use std::process::ExitCode;

use immsched::lint;

fn main() -> ExitCode {
    let mut root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut report_path: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(dir) => root = PathBuf::from(dir),
                None => return usage("--root needs a directory"),
            },
            "--report" => match args.next() {
                Some(file) => report_path = Some(PathBuf::from(file)),
                None => return usage("--report needs a file path"),
            },
            other => return usage(&format!("unknown argument {other:?}")),
        }
    }

    let report = match lint::lint_tree(&root) {
        Ok(report) => report,
        Err(e) => {
            eprintln!("immsched-lint: {e:#}");
            return ExitCode::from(2);
        }
    };
    if let Some(path) = &report_path {
        if let Err(e) = std::fs::write(path, report.to_json().render()) {
            eprintln!("immsched-lint: writing {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }
    for finding in &report.findings {
        eprintln!("{}", finding.display_line());
    }
    if report.is_clean() {
        eprintln!("immsched-lint: {} files clean", report.files_scanned);
        ExitCode::SUCCESS
    } else {
        eprintln!(
            "immsched-lint: {} finding(s) across {} files",
            report.findings.len(),
            report.files_scanned
        );
        ExitCode::FAILURE
    }
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("immsched-lint: {msg}");
    eprintln!("usage: lint [--root <crate-dir>] [--report <findings.json>]");
    ExitCode::from(2)
}
