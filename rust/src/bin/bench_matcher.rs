//! `bench_matcher` — the tracked matcher-perf pipeline.
//!
//! Measures, per size class (the four native epoch classes plus a
//! `huge` class the dense path cannot serve comfortably):
//!
//! * **fitness sparse vs dense** — the sparse CSR [`FitnessKernel`]
//!   against the dense `edge_fitness` oracle on a realistic scenario
//!   (layered DNN-tile DAG pair + compatibility mask), with an
//!   agreement check on every sample;
//! * **native epoch latency** — steady-state `run_epoch_into` against a
//!   reused `EpochOutputs` (the interrupt hot path);
//! * **PSO end-to-end** — serial vs threaded episode on the
//!   `matcher_micro` planted-embedding scenario, asserting bit-identical
//!   traces;
//! * **service chain** — one episode through the typed `MatchRequest`
//!   API (sparse CSR + packed-mask request into `GlobalController`'s
//!   engine chain), the path every real interrupt takes.
//!
//! Results are printed as tables and **appended** to the
//! `BENCH_matcher.json` trajectory at the repo root (schema
//! `immsched.bench_matcher/v2`: `{ schema, entries: [...] }`, one entry
//! per run, accumulated over PRs — `report::figures::perf_trajectory`
//! plots them).  A schema-v1 single-run file is rejected loudly; pass
//! `--fresh` to start a new trajectory.  `--smoke` runs tiny sizes/reps
//! (CI keeps the binary and the JSON schema from rotting); `--out
//! <path>` overrides the output location, `--label <name>` tags the
//! entry (CI passes the commit).

use std::time::Instant;

use immsched::coordinator::{CancelToken, GlobalController, MatchProblem};
use immsched::graph::{gen_dag_layered, Dag, NodeKind};
use immsched::matcher::{
    build_bitmask, edge_fitness, ullmann::plant_embedding, FitnessKernel, PsoConfig, PsoMatcher,
};
use immsched::report::figures::{append_bench_entry_pruned, MATCHER_BENCH_SCHEMA};
use immsched::runtime::{
    EpochBackend, EpochInputs, EpochOutputs, NativeEpochBackend, SizeClass, NATIVE_SIZE_CLASSES,
};
use immsched::scheduler::Priority;
use immsched::util::json::Json;
use immsched::util::table::{fmt_time, Table};
use immsched::util::{MatF, Rng};

struct ClassSpec {
    name: &'static str,
    n: usize,
    m: usize,
    particles: usize,
    k_steps: usize,
    /// PSO end-to-end columns only run where the dense-era matcher was
    /// usable (the standard size classes).
    run_pso: bool,
}

/// The four native epoch classes (derived from the runtime constant so
/// the bench can never drift from the shipped hot path) plus a `huge`
/// class beyond what the dense-era matcher served.
fn class_specs() -> Vec<ClassSpec> {
    let mut specs: Vec<ClassSpec> = NATIVE_SIZE_CLASSES
        .iter()
        .map(|&(name, c)| ClassSpec {
            name,
            n: c.n,
            m: c.m,
            particles: c.particles,
            k_steps: c.k_steps,
            run_pso: true,
        })
        .collect();
    specs.push(ClassSpec { name: "huge", n: 128, m: 512, particles: 16, k_steps: 8, run_pso: false });
    specs
}

/// Per-class measurements (nanoseconds unless noted).
struct ClassResult {
    name: &'static str,
    n: usize,
    m: usize,
    q_edges: usize,
    g_edges: usize,
    mask_density: f64,
    fitness_dense_ns: f64,
    fitness_sparse_ns: f64,
    fitness_speedup: f64,
    epoch_native_ns: f64,
    pso_serial_ns: Option<f64>,
    pso_threaded_ns: Option<f64>,
    service_episode_ns: Option<f64>,
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let fresh = args.iter().any(|a| a == "--fresh");
    let flag = |name: &str| {
        args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
    };
    let out_path = flag("--out")
        .unwrap_or_else(|| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_matcher.json").into());
    let label = flag("--label").unwrap_or_else(|| "local".into());

    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!("[bench_matcher] smoke={smoke} worker_threads={threads} out={out_path} label={label}");

    let classes = class_specs();
    let class_count = if smoke { 2 } else { classes.len() };
    let mut results: Vec<ClassResult> = Vec::new();
    let mut checksum = 0.0f64; // defeats dead-code elimination across timed loops

    for spec in classes.iter().take(class_count) {
        let r = bench_class(spec, smoke, &mut checksum)?;
        results.push(r);
    }

    render_tables(&results);
    println!("[bench_matcher] checksum {checksum:.3}");

    let largest = results.last().expect("at least one class");
    println!(
        "[bench_matcher] sparse-vs-dense fitness speedup on largest class ({}): {:.2}x",
        largest.name, largest.fitness_speedup
    );
    if !smoke {
        assert!(
            largest.fitness_speedup >= 5.0,
            "sparse fitness kernel below the 5x bar on {}: {:.2}x",
            largest.name,
            largest.fitness_speedup
        );
    }

    let entry = entry_json(&results, smoke, threads, &label);
    // A full measured run supersedes any analytic estimate entry still
    // in the trajectory (the `pr2-seed-estimate` carried from authoring
    // environments without a rust toolchain): measured numbers land,
    // estimates leave — the figure pipeline never mixes the two.
    let prune = |e: &Json| !smoke && e.get("measured").and_then(Json::as_bool) == Some(false);
    let appended =
        append_bench_entry_pruned(&out_path, MATCHER_BENCH_SCHEMA, entry, fresh, &prune)?;
    println!("[bench_matcher] wrote {out_path} ({appended} trajectory entries)");
    Ok(())
}

/// Layered DNN-tile-shaped DAG with mixed computation kinds: layer
/// widths ~`width`, forward fanout ≤ `fanout` (the shape `workload::
/// tiling` emits for staged models).
fn gen_tile_dag(nodes: usize, width: usize, fanout: usize, rng: &mut Rng, target: bool) -> Dag {
    let mut widths = Vec::new();
    let mut left = nodes;
    while left > 0 {
        let w = width.min(left).max(1);
        widths.push(w);
        left -= w;
    }
    let kind0 = if target { NodeKind::Universal } else { NodeKind::Compute };
    let mut dag = gen_dag_layered(&widths, fanout, rng, kind0);
    // computation-type mix drives the kind filter of the mask
    const QUERY_KINDS: [NodeKind; 4] =
        [NodeKind::Compute, NodeKind::Compute, NodeKind::Eltwise, NodeKind::Compare];
    const TARGET_KINDS: [NodeKind; 10] = [
        NodeKind::Universal,
        NodeKind::Compute,
        NodeKind::Compare,
        NodeKind::Universal,
        NodeKind::Eltwise,
        NodeKind::Compute,
        NodeKind::Move,
        NodeKind::Universal,
        NodeKind::Compare,
        NodeKind::Eltwise,
    ];
    for u in 0..dag.len() {
        if target {
            dag.set_kind(u, TARGET_KINDS[u % TARGET_KINDS.len()]);
        } else {
            dag.set_kind(u, QUERY_KINDS[u % QUERY_KINDS.len()]);
        }
    }
    dag
}

fn bench_class(spec: &ClassSpec, smoke: bool, checksum: &mut f64) -> anyhow::Result<ClassResult> {
    let (n, m) = (spec.n, spec.m);
    let mut rng = Rng::new(0xBE7C4 ^ (n as u64) << 16 ^ m as u64);

    // realistic fitness scenario: tile DAG pair + compatibility mask
    let qd = gen_tile_dag(n, 4.max(n / 8), 2, &mut rng, false);
    let gd = gen_tile_dag(m, 8.max(m / 12), 3, &mut rng, true);
    let bits = build_bitmask(&qd, &gd);
    let mask = bits.to_matf();
    let (q, g) = (qd.adjacency(), gd.adjacency());

    // a few masked row-stochastic S samples, rotated through the loops
    let samples = 4usize;
    let s_set: Vec<MatF> = (0..samples)
        .map(|_| {
            let mut s = MatF::from_fn(n, m, |_, _| rng.f32() + 1e-3);
            s.hadamard_assign(&mask);
            s.row_normalize();
            s
        })
        .collect();

    let kernel = FitnessKernel::new(&q, &g);
    let mut scratch = kernel.scratch();

    // agreement check on every sample before timing anything
    for s in &s_set {
        let dense = edge_fitness(s, &q, &g);
        let sparse = kernel.eval(s.as_slice(), &mut scratch);
        let tol = 2e-3f32 * (1.0 + dense.abs());
        assert!(
            (dense - sparse).abs() <= tol,
            "{}: sparse {sparse} disagrees with dense {dense}",
            spec.name
        );
    }

    let reps = if smoke { 3 } else { (200_000_000 / (n * m * m).max(1)).clamp(10, 20_000) };
    let t_dense = time_per_rep(reps, |i| {
        *checksum += edge_fitness(&s_set[i % samples], &q, &g) as f64;
    });
    let t_sparse = time_per_rep(reps, |i| {
        *checksum += kernel.eval(s_set[i % samples].as_slice(), &mut scratch) as f64;
    });

    // native epoch latency (steady state: reused outputs, same backend)
    let class =
        SizeClass { n, m, particles: spec.particles, k_steps: spec.k_steps };
    let mut backend = NativeEpochBackend::new(spec.name, class);
    let mut inputs = EpochInputs::zeros(class);
    pad_mask_q_g(&mut inputs, &mask, &q, &g);
    init_particles(&mut inputs, class, &mut rng);
    let mut epoch_out = EpochOutputs::zeros(class);
    backend.run_epoch_into(&inputs, &mut epoch_out)?; // warm-up
    let epoch_reps =
        if smoke { 2 } else { (200_000_000 / (spec.particles * spec.k_steps * n * m).max(1)).clamp(3, 500) };
    let t_epoch = time_per_rep(epoch_reps, |i| {
        inputs.seed = i as u32;
        backend.run_epoch_into(&inputs, &mut epoch_out).expect("epoch");
    });

    // PSO end-to-end on the matcher_micro planted scenario
    let (mut t_serial, mut t_threaded) = (None, None);
    if spec.run_pso {
        let (pq, pg, _) = plant_embedding(n, m, 0.3, 0.1, &mut rng);
        let full = MatF::full(n, m, 1.0);
        let cfg = PsoConfig {
            seed: 11,
            epochs: 2,
            particles: 16,
            early_exit: true,
            ..Default::default()
        };
        let pso_reps = if smoke { 1 } else { 3 };
        let matcher = PsoMatcher::new(cfg);
        let serial_out = matcher.run_serial(&full, &pq, &pg);
        let threaded_out = matcher.run_threaded(&full, &pq, &pg);
        // the threaded epoch must be a pure speedup, never a divergence
        assert_eq!(serial_out.fitness_trace, threaded_out.fitness_trace, "{}", spec.name);
        assert_eq!(serial_out.mappings, threaded_out.mappings, "{}", spec.name);
        t_serial = Some(time_per_rep(pso_reps, |_| {
            *checksum += matcher.run_serial(&full, &pq, &pg).best_fitness as f64;
        }));
        t_threaded = Some(time_per_rep(pso_reps, |_| {
            *checksum += matcher.run_threaded(&full, &pq, &pg).best_fitness as f64;
        }));
    }

    // one full episode through the typed MatchRequest API: sparse
    // request → engine chain (epoch backends + quantized fallback)
    let mut t_service = None;
    if spec.run_pso {
        let problem = MatchProblem { query: qd.csr(), target: gd.csr(), mask: bits.clone() };
        let mut controller = GlobalController::new(PsoConfig {
            seed: 7,
            epochs: 2,
            repair_budget: 10_000,
            ..Default::default()
        })?;
        let cancel = CancelToken::new();
        let service_reps = if smoke { 1 } else { 3 };
        t_service = Some(time_per_rep(service_reps, |i| {
            let req = problem.request(i as u64, Priority::Urgent, None);
            *checksum += controller.serve(&req, &cancel).epochs_run as f64;
        }));
    }

    Ok(ClassResult {
        name: spec.name,
        n,
        m,
        q_edges: qd.edge_count(),
        g_edges: gd.edge_count(),
        mask_density: bits.density(),
        fitness_dense_ns: t_dense * 1e9,
        fitness_sparse_ns: t_sparse * 1e9,
        fitness_speedup: t_dense / t_sparse.max(1e-12),
        epoch_native_ns: t_epoch * 1e9,
        pso_serial_ns: t_serial.map(|t| t * 1e9),
        pso_threaded_ns: t_threaded.map(|t| t * 1e9),
        service_episode_ns: t_service.map(|t| t * 1e9),
    })
}

/// Seconds per repetition of `f` over `reps` calls.
fn time_per_rep(reps: usize, mut f: impl FnMut(usize)) -> f64 {
    let t0 = Instant::now();
    for i in 0..reps {
        f(i);
    }
    t0.elapsed().as_secs_f64() / reps.max(1) as f64
}

/// Copy an (n×m mask, n×n Q, m×m G) problem into class-padded inputs
/// (dims match exactly here; kept general for padded classes).
fn pad_mask_q_g(inputs: &mut EpochInputs, mask: &MatF, q: &MatF, g: &MatF) {
    inputs.mask.copy_from_slice(mask.as_slice());
    inputs.q.copy_from_slice(q.as_slice());
    inputs.g.copy_from_slice(g.as_slice());
}

/// Mask-respecting row-stochastic particle init for the epoch inputs.
fn init_particles(inputs: &mut EpochInputs, class: SizeClass, rng: &mut Rng) {
    let (p, n, m) = (class.particles, class.n, class.m);
    for part in 0..p {
        for i in 0..n {
            let row = &mut inputs.s[(part * n + i) * m..(part * n + i + 1) * m];
            let mut sum = 0.0f32;
            for (x, &mk) in row.iter_mut().zip(&inputs.mask[i * m..(i + 1) * m]) {
                *x = (rng.f32() + 1e-3) * mk;
                sum += *x;
            }
            if sum > 0.0 {
                row.iter_mut().for_each(|x| *x /= sum);
            }
        }
    }
    inputs.s_local.copy_from_slice(&inputs.s);
    inputs.s_star.copy_from_slice(&inputs.s[..n * m]);
    inputs.s_bar.copy_from_slice(&inputs.s[..n * m]);
    inputs.seed = 42;
}

fn render_tables(results: &[ClassResult]) {
    let mut t = Table::new("sparse vs dense fitness kernel (per evaluation)").header(&[
        "class",
        "n",
        "m",
        "|E_Q|",
        "|E_G|",
        "mask density",
        "dense",
        "sparse",
        "speedup",
    ]);
    for r in results {
        t.row(vec![
            r.name.to_string(),
            r.n.to_string(),
            r.m.to_string(),
            r.q_edges.to_string(),
            r.g_edges.to_string(),
            format!("{:.3}", r.mask_density),
            fmt_time(r.fitness_dense_ns / 1e9),
            fmt_time(r.fitness_sparse_ns / 1e9),
            format!("{:.2}x", r.fitness_speedup),
        ]);
    }
    print!("{}", t.render());

    let mut t = Table::new("hot-path latency (steady state)").header(&[
        "class",
        "epoch (native)",
        "pso serial",
        "pso threaded",
        "service chain",
    ]);
    for r in results {
        t.row(vec![
            r.name.to_string(),
            fmt_time(r.epoch_native_ns / 1e9),
            r.pso_serial_ns.map_or("-".into(), |x| fmt_time(x / 1e9)),
            r.pso_threaded_ns.map_or("-".into(), |x| fmt_time(x / 1e9)),
            r.service_episode_ns.map_or("-".into(), |x| fmt_time(x / 1e9)),
        ]);
    }
    print!("{}", t.render());
}

/// One trajectory entry for this run.
fn entry_json(results: &[ClassResult], smoke: bool, threads: usize, label: &str) -> Json {
    let opt = |v: Option<f64>| v.map_or(Json::Null, Json::from);
    let round = |x: f64, digits: i32| -> f64 {
        let scale = 10f64.powi(digits);
        (x * scale).round() / scale
    };
    let classes: Vec<Json> = results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("class", Json::from(r.name)),
                ("n", Json::from(r.n)),
                ("m", Json::from(r.m)),
                ("q_edges", Json::from(r.q_edges)),
                ("g_edges", Json::from(r.g_edges)),
                ("mask_density", Json::from(round(r.mask_density, 4))),
                ("fitness_dense_ns", Json::from(round(r.fitness_dense_ns, 1))),
                ("fitness_sparse_ns", Json::from(round(r.fitness_sparse_ns, 1))),
                ("fitness_speedup", Json::from(round(r.fitness_speedup, 2))),
                ("epoch_native_ns", Json::from(round(r.epoch_native_ns, 1))),
                ("pso_serial_ns", opt(r.pso_serial_ns.map(|x| round(x, 1)))),
                ("pso_threaded_ns", opt(r.pso_threaded_ns.map(|x| round(x, 1)))),
                ("service_episode_ns", opt(r.service_episode_ns.map(|x| round(x, 1)))),
            ])
        })
        .collect();
    let largest = results.last().expect("nonempty");
    Json::obj(vec![
        ("label", Json::from(label)),
        ("smoke", Json::from(smoke)),
        ("measured", Json::from(true)),
        ("worker_threads", Json::from(threads)),
        ("classes", Json::Arr(classes)),
        ("largest_class", Json::from(largest.name)),
        ("largest_class_fitness_speedup", Json::from(round(largest.fitness_speedup, 2))),
    ])
}
