//! `bench_experiment` — replicated sweep campaigns over the serving
//! stack, tracked in `BENCH_experiment.json`.
//!
//! One run executes a full [`ExperimentGrid`] campaign — every
//! λ × shape × policy × shards × quota cell, N seeded replications per
//! cell on a bounded worker pool — plus the per-policy LBT search, and
//! appends the canonical summary document to the trajectory (schema
//! `immsched.bench_experiment/v1`).
//!
//! Campaign numbers come from the deterministic modeled-cluster
//! evaluator, so the summary is bit-identical for the same grid and
//! campaign seed regardless of machine or worker count; `--smoke`
//! re-runs the campaign once and asserts exactly that, along with the
//! quota tournament's adaptive-dominance acceptance property.
//!
//! `--live` additionally replays the first grid cell on the *real*
//! cluster (wall clock, `run_open_loop`) and records the cross-check
//! outside the deterministic summary.  `--report-out FILE` writes the
//! rendered LBT / tournament / per-cell report for CI artifacts.

use anyhow::Result;

use immsched::cluster::experiment::{
    live::run_live_cell, replication_seed, run_campaign, summary_json, ExperimentGrid,
};
use immsched::report::figures::{append_bench_entry, experiment_report, EXPERIMENT_BENCH_SCHEMA};
use immsched::util::json::{hex_u64, Json};

struct Args {
    smoke: bool,
    fresh: bool,
    live: bool,
    seed: u64,
    reps: Option<usize>,
    workers: usize,
    label: String,
    out: String,
    report_out: Option<String>,
}

fn parse_args() -> Result<Args> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let flag = |name: &str| argv.iter().position(|a| a == name).and_then(|i| argv.get(i + 1));
    let default_workers =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
    Ok(Args {
        smoke: argv.iter().any(|a| a == "--smoke"),
        fresh: argv.iter().any(|a| a == "--fresh"),
        live: argv.iter().any(|a| a == "--live"),
        seed: flag("--seed").map(|s| s.parse()).transpose()?.unwrap_or(42),
        reps: flag("--reps").map(|s| s.parse()).transpose()?,
        workers: flag("--workers")
            .map(|s| s.parse())
            .transpose()?
            .unwrap_or(default_workers)
            .max(1),
        label: flag("--label").cloned().unwrap_or_else(|| "local".into()),
        out: flag("--out").cloned().unwrap_or_else(|| {
            concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_experiment.json").into()
        }),
        report_out: flag("--report-out").cloned(),
    })
}

fn main() -> Result<()> {
    let args = parse_args()?;
    immsched::util::logging::init_from_env();

    let mut grid = if args.smoke {
        ExperimentGrid::smoke(args.seed)
    } else {
        ExperimentGrid::standard(args.seed)
    };
    if let Some(reps) = args.reps {
        grid.replications = reps.max(1);
    }
    let cells = grid.cells().len();
    println!(
        "[bench_experiment] smoke={} campaign_seed={} cells={cells} reps={} workers={}",
        args.smoke, args.seed, grid.replications, args.workers
    );

    let result = run_campaign(&grid, args.workers)?;
    let summary = summary_json(&grid, &result);

    let tables = experiment_report(&summary);
    for t in &tables {
        print!("{}", t.render());
    }
    if let Some(path) = &args.report_out {
        let mut report = String::new();
        for t in &tables {
            report.push_str(&t.render());
            report.push('\n');
        }
        std::fs::write(path, &report)?;
        println!("[bench_experiment] report written to {path}");
    }

    // ---- acceptance (smoke) -------------------------------------------
    if args.smoke {
        // determinism: the same grid re-runs byte-identically on a
        // different pool width
        let again = run_campaign(&grid, 1)?;
        let replay = summary_json(&grid, &again).render();
        assert_eq!(summary.render(), replay, "campaign summary is not deterministic across runs");

        // an LBT value per route policy
        let lbt = summary.get("lbt").and_then(Json::as_array).unwrap_or(&[]);
        assert_eq!(lbt.len(), grid.policies.len(), "missing LBT point for some policy");
        for p in lbt {
            assert!(p.get("lbt_rate").and_then(Json::as_f64).is_some(), "LBT point without a rate");
        }

        // a populated row per grid cell
        let rows = summary.get("cells").and_then(Json::as_array).unwrap_or(&[]).len();
        assert_eq!(rows, cells, "summary rows ({rows}) != grid cells ({cells})");

        // the adaptive quota wins or ties every static quota on SLO miss
        let tournament = summary.get("tournament").and_then(Json::as_array).unwrap_or(&[]);
        let miss_of = |name: &str| -> f64 {
            tournament
                .iter()
                .find(|q| q.get("quota").and_then(Json::as_str) == Some(name))
                .and_then(|q| q.get("slo_miss_rate").and_then(Json::as_f64))
                .unwrap_or(f64::NAN)
        };
        let adaptive = miss_of("adaptive");
        assert!(adaptive.is_finite(), "tournament has no adaptive row");
        for q in tournament {
            let name = q.get("quota").and_then(Json::as_str).unwrap_or("?");
            let miss = q.get("slo_miss_rate").and_then(Json::as_f64).unwrap_or(f64::NAN);
            assert!(
                adaptive <= miss + 1e-9,
                "adaptive quota (miss {adaptive:.4}) loses to {name} (miss {miss:.4})"
            );
        }
        println!("[bench_experiment] SMOKE OK");
    }

    // ---- optional live cross-check ------------------------------------
    let live = if args.live {
        let cell = grid.cells().into_iter().next().expect("grid has cells");
        let seed = replication_seed(grid.campaign_seed, cell.index, 0);
        let out = run_live_cell(&cell, seed)?;
        println!(
            "[bench_experiment] live cross-check: cell {} served {} / {} (wall)",
            cell.id(),
            out.get("served").and_then(Json::as_f64).unwrap_or(0.0),
            out.get("submitted").and_then(Json::as_f64).unwrap_or(0.0),
        );
        out
    } else {
        Json::Null
    };

    // ---- trajectory entry ---------------------------------------------
    let entry = Json::obj(vec![
        ("label", Json::from(args.label.as_str())),
        ("smoke", Json::from(args.smoke)),
        ("measured", Json::from(true)),
        ("campaign_seed", hex_u64(args.seed)),
        ("cells", Json::from(cells)),
        ("replications", Json::from(grid.replications)),
        ("summary", summary),
        ("live", live),
    ]);
    let count = append_bench_entry(&args.out, EXPERIMENT_BENCH_SCHEMA, entry, args.fresh)?;
    println!("[bench_experiment] wrote {} ({count} trajectory entries)", args.out);
    Ok(())
}
