//! `immsched` — CLI launcher for the IMMSched reproduction.
//!
//! Subcommands:
//!   selftest                      PJRT artifact round-trip + matcher sanity
//!   run [--config F] [--set K=V]  one simulation run, summary to stdout
//!   match --model M [...]         one interrupt episode on the coordinator
//!   cluster [--shards N] [...]    open-loop trace against the sharded cluster
//!   experiment [--smoke] [...]    replicated sweep campaign + LBT search
//!   shard-listen [--addr A] [...] host shards behind a TCP/UDS socket
//!   metrics [--watch MS|--in F]   observability plane: live registry or dump file
//!   info                          platforms, workloads, artifact registry
//!
//! The argument parser is hand-rolled (no clap offline; DESIGN.md §4).

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use std::sync::Arc;
use std::time::Duration;

use immsched::accel::{build_target_graph, Platform};
use immsched::cluster::driver::{run_open_loop, schedule_from_trace, DriverConfig};
use immsched::cluster::experiment::{run_campaign, summary_json, ExperimentGrid};
use immsched::cluster::net::{announce, ListenConfig, NetAddr, ShardListener, SocketShard};
use immsched::cluster::{
    policy_by_name, ClusterConfig, MatchCluster, RoutePolicy, ShardTransport, SupervisedFleet,
    SupervisorConfig, TransportConfig,
};
use immsched::config::Config;
use immsched::coordinator::{
    GlobalController, MatchEngine, MatchPath, MatchProblem, MatchService, QuantizedEngine,
    ServiceConfig, ServiceStats, UllmannEngine, Vf2Engine,
};
use immsched::matcher::PsoConfig;
use immsched::report::figures::experiment_report;
use immsched::runtime::ArtifactRegistry;
use immsched::scheduler::{
    build_trace, metrics, ArrivalProcess, FrameworkKind, Priority, SimConfig, Simulator,
    TraceConfig,
};
use immsched::util::json::{get_hex_u64, get_str, Json};
use immsched::util::table::{fmt_time, Table};
use immsched::workload::{build_model, tile_layer_graph, ModelId, TilingConfig, WorkloadClass};

fn main() {
    init_logger();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("selftest") => cmd_selftest(),
        Some("run") => cmd_run(&args[1..]),
        Some("match") => cmd_match(&args[1..]),
        Some("cluster") => cmd_cluster(&args[1..]),
        Some("experiment") => cmd_experiment(&args[1..]),
        Some("shard-worker") => cmd_shard_worker(),
        Some("shard-listen") => cmd_shard_listen(&args[1..]),
        Some("metrics") => cmd_metrics(&args[1..]),
        Some("info") => cmd_info(),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => bail!("unknown subcommand {other:?} (try `immsched help`)"),
    }
}

fn print_help() {
    println!(
        "immsched — interruptible multi-DNN scheduling (paper reproduction)\n\
         \n\
         USAGE: immsched <subcommand> [options]\n\
         \n\
         SUBCOMMANDS\n\
           selftest                         artifact + runtime + matcher smoke test\n\
           run  [--config FILE] [--set K=V ...]   run one simulation, print summary\n\
           match --model NAME [--platform edge|cloud] [--tiles N]\n\
                 [--engine pso|quantized|ullmann|vf2]\n\
                                            serve one urgent-task interrupt\n\
           cluster [--shards N] [--policy round-robin|least-queue|deadline-aware]\n\
                   [--rate R] [--horizon S] [--class simple|middle|complex]\n\
                   [--process poisson|bursty] [--seed S] [--process-shards]\n\
                   [--connect ADDR[,ADDR...]] [--obs-out FILE]\n\
                                            open-loop trace against a sharded cluster\n\
                                            (--process-shards: one shard-worker child\n\
                                             process per shard over the wire protocol;\n\
                                             --connect: dial running shard-listen\n\
                                             workers, one shard per address;\n\
                                             --obs-out: enable the observability\n\
                                             plane and write the flight-recorder\n\
                                             dump to FILE)\n\
           experiment [--smoke] [--seed S] [--reps N] [--workers N] [--out FILE]\n\
                                            replicated sweep campaign on the modeled\n\
                                            cluster: every rate x shape x policy x\n\
                                            shards x quota cell, the quota tournament,\n\
                                            and the per-policy LBT search (--out:\n\
                                            write the canonical summary JSON)\n\
           metrics [--watch MS] [--in FILE]\n\
                                            observability plane: run a small demo\n\
                                            workload and print the metric registry\n\
                                            (--watch: re-render every MS ms while it\n\
                                            runs; --in: render an immsched.obs/v1\n\
                                            dump file instead)\n\
           shard-worker                     host one match-service shard over framed\n\
                                            stdio (spawned by --process-shards; see\n\
                                            rust/README.md for the wire contract)\n\
           shard-listen [--addr tcp://H:P|unix:///path] [--max-conns N]\n\
                        [--registry ADDR --name NAME [--heartbeat-ms MS]]\n\
                                            host shards behind a listening socket, one\n\
                                            match service per accepted connection; with\n\
                                            --registry, join the fleet registry and\n\
                                            heartbeat until killed\n\
           info                             platforms, models, artifacts\n\
           help                             this text\n\
         \n\
         EXAMPLES\n\
           immsched run --set scheduler.name=\"isosched\" --set workload.class=\"complex\"\n\
           immsched match --model ResNet50 --platform edge\n\
           immsched cluster --shards 4 --policy deadline-aware --process bursty\n\
           immsched shard-listen --addr tcp://0.0.0.0:7070\n\
           immsched cluster --connect tcp://host-a:7070,tcp://host-b:7070"
    );
}

fn init_logger() {
    immsched::util::logging::set_max_level(immsched::util::logging::Level::Info);
    // IMMSCHED_LOG (error|warn|info|debug|off) wins over the default
    immsched::util::logging::init_from_env();
}

/// Parse `--config F` and repeated `--set key=value` into a Config.
fn parse_config(args: &[String]) -> Result<Config> {
    let mut cfg = Config::default();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--config" => {
                let path = args.get(i + 1).context("--config needs a path")?;
                cfg = Config::from_file(&PathBuf::from(path))?;
                i += 2;
            }
            "--set" => {
                let spec = args.get(i + 1).context("--set needs key=value")?;
                cfg.apply_override(spec)?;
                i += 2;
            }
            other => bail!("unknown option {other:?}"),
        }
    }
    Ok(cfg)
}

fn cmd_selftest() -> Result<()> {
    println!("== immsched selftest ==");
    // 1. artifacts
    let registry = ArtifactRegistry::discover(&ArtifactRegistry::default_dir());
    match &registry {
        Ok(r) => println!("artifacts: {} size classes", r.all().len()),
        Err(e) => println!("artifacts: MISSING ({e:#}) — fallback path will be used"),
    }
    // 2. match-service round trip on a small planted problem
    let service = MatchService::spawn(PsoConfig::default())?;
    let qd = immsched::graph::gen_chain(4, immsched::graph::NodeKind::Compute);
    let gd = immsched::graph::gen_chain(8, immsched::graph::NodeKind::Universal);
    let problem = MatchProblem::from_dags(&qd, &gd);
    let t0 = std::time::Instant::now();
    let resp = service.match_blocking(problem, Priority::Urgent, None)?;
    println!(
        "match service: matched={} path={} epochs={} in {}",
        resp.matched(),
        resp.path.name(),
        resp.epochs_run,
        fmt_time(t0.elapsed().as_secs_f64()),
    );
    if !resp.matched() {
        bail!("selftest failed: no mapping found for the planted chain");
    }
    // 3. quick simulation
    let cfg = Config::default();
    let platform = Platform::get(cfg.platform);
    let trace_cfg = TraceConfig {
        class: cfg.workload.class,
        arrival_rate: cfg.sim.arrival_rate,
        horizon: 0.02,
        seed: cfg.sim.seed,
        ..Default::default()
    };
    let tasks = build_trace(&trace_cfg, &platform);
    let n_tasks = tasks.len();
    let mut sim = Simulator::new(SimConfig::default());
    let res = sim.run(tasks, trace_cfg.horizon);
    let summary = metrics::summarize(&res);
    println!(
        "simulator: {n_tasks} tasks, {} completed, deadline rate {:.0}%",
        summary.completed,
        summary.deadline_rate * 100.0
    );
    println!("selftest OK");
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    let cfg = parse_config(args)?;
    let framework = FrameworkKind::from_name(&cfg.scheduler.name)
        .with_context(|| format!("unknown scheduler {:?}", cfg.scheduler.name))?;
    let platform = Platform::get(cfg.platform);
    let trace_cfg = TraceConfig {
        class: cfg.workload.class,
        background_tasks: cfg.sim.background_tasks,
        arrival_rate: cfg.sim.arrival_rate,
        process: ArrivalProcess::Poisson,
        horizon: cfg.sim.horizon,
        deadline_factor: cfg.sim.deadline_factor,
        batch: 16,
        tiling: TilingConfig {
            max_tiles: cfg.workload.max_tiles,
            split_factor: cfg.workload.split_factor,
        },
        seed: cfg.sim.seed,
    };
    let tasks = build_trace(&trace_cfg, &platform);
    println!(
        "running {} on {} / {:?}: {} tasks over {}s",
        framework.name(),
        platform.kind.name(),
        cfg.workload.class,
        tasks.len(),
        trace_cfg.horizon
    );
    let sim_cfg = SimConfig {
        platform_kind: cfg.platform,
        framework,
        pso: cfg.pso.to_pso_config(cfg.sim.seed),
        preemption_ratio: cfg.scheduler.preemption_ratio,
        background_streams: cfg.sim.background_tasks,
        ..Default::default()
    };
    let mut sim = Simulator::new(sim_cfg);
    let res = sim.run(tasks, trace_cfg.horizon);
    let s = metrics::summarize(&res);

    let mut t = Table::new(format!("{} summary", framework.name())).header(&["metric", "value"]);
    t.row(vec!["completed tasks".into(), s.completed.to_string()]);
    t.row(vec!["urgent mean total latency".into(), fmt_time(s.urgent_latency)]);
    t.row(vec!["urgent mean sched latency".into(), fmt_time(s.sched_latency)]);
    t.row(vec!["urgent deadline rate".into(), format!("{:.1}%", s.deadline_rate * 100.0)]);
    t.row(vec!["throughput".into(), format!("{:.1} tasks/s", s.throughput)]);
    t.row(vec!["energy".into(), format!("{:.3} J", s.energy_j)]);
    t.row(vec!["energy efficiency".into(), format!("{:.1} tasks/J", s.tasks_per_joule)]);
    print!("{}", t.render());
    Ok(())
}

fn cmd_match(args: &[String]) -> Result<()> {
    let mut model_name = String::from("MobileNetV2");
    let mut platform_name = String::from("edge");
    let mut engine_name = String::from("pso");
    let mut max_tiles = 16usize;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--model" => {
                model_name = args.get(i + 1).context("--model needs a name")?.clone();
                i += 2;
            }
            "--platform" => {
                platform_name = args.get(i + 1).context("--platform needs edge|cloud")?.clone();
                i += 2;
            }
            "--engine" => {
                engine_name = args
                    .get(i + 1)
                    .context("--engine needs pso|quantized|ullmann|vf2")?
                    .clone();
                i += 2;
            }
            "--tiles" => {
                max_tiles = args.get(i + 1).context("--tiles needs a number")?.parse()?;
                i += 2;
            }
            other => bail!("unknown option {other:?}"),
        }
    }
    const ENGINE_NAMES: [&str; 4] = ["pso", "quantized", "ullmann", "vf2"];
    if !ENGINE_NAMES.contains(&engine_name.as_str()) {
        bail!("unknown engine {engine_name:?} (one of {})", ENGINE_NAMES.join("|"));
    }
    let model = ModelId::ALL
        .iter()
        .find(|m| m.name().eq_ignore_ascii_case(&model_name))
        .copied()
        .with_context(|| format!("unknown model {model_name:?} (see `immsched info`)"))?;
    let platform = match platform_name.to_ascii_lowercase().as_str() {
        "edge" => Platform::edge(),
        "cloud" => Platform::cloud(),
        other => bail!("unknown platform {other:?}"),
    };

    let graph = build_model(model);
    let tiles = tile_layer_graph(&graph, TilingConfig { max_tiles, split_factor: 2 });
    let preemptible = vec![true; platform.engines];
    let (target, vertex_engine) = build_target_graph(&platform, &preemptible);
    let problem = MatchProblem::from_dags(&tiles.dag, &target);
    println!(
        "match: {} ({} tiles) -> {} ({} engines) via the {} engine chain",
        model.name(),
        tiles.len(),
        platform.kind.name(),
        target.len(),
        engine_name
    );

    // The same MatchService call serves every engine chain: the default
    // PSO/epoch+quantized chain, or a single swapped-in baseline.
    let service = if engine_name == "pso" {
        MatchService::spawn(PsoConfig::default())?
    } else {
        let selected = engine_name.clone();
        MatchService::spawn_with(
            ServiceConfig::default(),
            Box::new(move || {
                let engine: Box<dyn MatchEngine> = match selected.as_str() {
                    "quantized" => Box::new(QuantizedEngine::new(PsoConfig::default())),
                    "ullmann" => Box::new(UllmannEngine),
                    "vf2" => Box::new(Vf2Engine),
                    other => unreachable!("engine {other:?} passed validation but has no chain"),
                };
                GlobalController::with_engines(vec![engine])
            }),
        )?
    };
    let t0 = std::time::Instant::now();
    let resp = service.match_blocking(problem, Priority::Urgent, None)?;
    let elapsed = t0.elapsed().as_secs_f64();
    // Every disposition is reported explicitly — shed/cancelled/rejected
    // requests used to vanish into a misleading "INFEASIBLE" line.
    match resp.path {
        MatchPath::Shed => println!(
            "SHED by admission in {} (expired deadline or bounded-queue eviction)",
            fmt_time(elapsed)
        ),
        MatchPath::Cancelled => println!(
            "CANCELLED at the epoch barrier after {} epochs in {}{}",
            resp.epochs_run,
            fmt_time(elapsed),
            if resp.snapshot.is_some() { " (resume snapshot available)" } else { "" }
        ),
        MatchPath::Rejected => println!(
            "REJECTED in {} (empty candidate row — no total mapping can exist)",
            fmt_time(elapsed)
        ),
        _ => {
            if let Some(mp) = resp.mappings.first() {
                println!(
                    "FEASIBLE via {}{} after {} epochs in {} (fitness {:.3})",
                    resp.path.name(),
                    if resp.resumed { " (warm-started)" } else { "" },
                    resp.epochs_run,
                    fmt_time(elapsed),
                    resp.best_fitness
                );
                let engines: Vec<String> = mp
                    .iter()
                    .enumerate()
                    .filter_map(|(tile, &v)| v.map(|v| format!("t{tile}->e{}", vertex_engine[v])))
                    .collect();
                println!("mapping: {}", engines.join(" "));
            } else {
                println!(
                    "INFEASIBLE after {} epochs in {} (best fitness {:.3})",
                    resp.epochs_run,
                    fmt_time(elapsed),
                    resp.best_fitness
                );
            }
        }
    }
    print!("{}", service_summary_table(&service.stats()).render());
    Ok(())
}

/// Per-path disposition counts of one service — every submitted request
/// is accounted for (served / rejected / cancelled / resumed / shed),
/// not just the happy path.
fn service_summary_table(stats: &ServiceStats) -> Table {
    let c = stats.controller;
    let r = stats.router;
    let mut t = Table::new("service summary (per-path counts)").header(&["disposition", "count"]);
    t.row(vec!["requests (controller)".into(), c.requests.to_string()]);
    t.row(vec!["matched".into(), c.matched.to_string()]);
    t.row(vec!["served via fallback".into(), c.fallbacks.to_string()]);
    t.row(vec!["rejected (empty row)".into(), c.rejected.to_string()]);
    t.row(vec!["cancelled (preempt/quota)".into(), c.cancelled.to_string()]);
    t.row(vec!["resumed (warm start)".into(), c.resumed.to_string()]);
    t.row(vec!["shed: expired deadline".into(), r.shed_expired.to_string()]);
    t.row(vec!["shed: queue capacity".into(), r.shed_capacity.to_string()]);
    t.row(vec!["total epochs".into(), c.epochs_total.to_string()]);
    t
}

/// Host one `MatchService` shard over length-prefixed wire frames on
/// stdin/stdout — the child process half of `--process-shards`.  The
/// parent speaks first (`hello` with the shard config); logs go to
/// stderr, which the parent inherits.
fn cmd_shard_worker() -> Result<()> {
    immsched::cluster::transport::worker_serve(std::io::stdin(), std::io::stdout())
}

/// Host match-service shards behind a listening TCP or Unix-domain
/// socket — the multi-host worker.  The first stdout line announces
/// the concrete bound address (`shard-listen: listening on <addr>`) so
/// a parent that bound port 0 can read it back; with `--registry` the
/// worker also joins the fleet registry and heartbeats until killed.
fn cmd_shard_listen(args: &[String]) -> Result<()> {
    let mut spec = String::from("127.0.0.1:0");
    let mut max_conns = u64::MAX;
    let mut registry_spec: Option<String> = None;
    let mut name = String::from("worker");
    let mut heartbeat_ms = 100u64;
    let mut i = 0;
    while i < args.len() {
        let value = |i: usize| args.get(i + 1).context("option needs a value");
        match args[i].as_str() {
            "--addr" => {
                spec = value(i)?.clone();
                i += 2;
            }
            "--max-conns" => {
                max_conns = value(i)?.parse()?;
                i += 2;
            }
            "--registry" => {
                registry_spec = Some(value(i)?.clone());
                i += 2;
            }
            "--name" => {
                name = value(i)?.clone();
                i += 2;
            }
            "--heartbeat-ms" => {
                heartbeat_ms = value(i)?.parse()?;
                i += 2;
            }
            other => bail!("unknown option {other:?}"),
        }
    }
    let listener = ShardListener::bind(&NetAddr::parse(&spec)?)?;
    let addr = listener.local_addr().clone();
    // the announce line is a contract: spawn_shard_listener parses it
    println!("shard-listen: listening on {addr}");
    use std::io::Write as _;
    std::io::stdout().flush().ok();
    let _membership = match &registry_spec {
        Some(registry) => Some(announce(
            &NetAddr::parse(registry)?,
            &name,
            &addr,
            Duration::from_millis(heartbeat_ms),
        )?),
        None => None,
    };
    listener.serve(TransportConfig::default(), ListenConfig { max_conns })
}

fn cmd_cluster(args: &[String]) -> Result<()> {
    let mut shards = 2usize;
    let mut policy_name = String::from("deadline-aware");
    let mut rate = 150.0f64;
    let mut horizon = 0.05f64;
    let mut class = WorkloadClass::Simple;
    let mut process = ArrivalProcess::bursty_default();
    let mut seed = 42u64;
    let mut process_shards = false;
    let mut connect: Vec<String> = Vec::new();
    let mut obs_out: Option<PathBuf> = None;
    let mut i = 0;
    while i < args.len() {
        let value = |i: usize| args.get(i + 1).context("option needs a value");
        match args[i].as_str() {
            "--process-shards" => {
                process_shards = true;
                i += 1;
            }
            "--connect" => {
                connect = value(i)?.split(',').map(str::to_string).collect();
                i += 2;
            }
            "--obs-out" => {
                obs_out = Some(PathBuf::from(value(i)?));
                i += 2;
            }
            "--shards" => {
                shards = value(i)?.parse()?;
                i += 2;
            }
            "--policy" => {
                policy_name = value(i)?.clone();
                i += 2;
            }
            "--rate" => {
                rate = value(i)?.parse()?;
                i += 2;
            }
            "--horizon" => {
                horizon = value(i)?.parse()?;
                i += 2;
            }
            "--class" => {
                class = match value(i)?.as_str() {
                    "simple" => WorkloadClass::Simple,
                    "middle" => WorkloadClass::Middle,
                    "complex" => WorkloadClass::Complex,
                    other => bail!("unknown class {other:?}"),
                };
                i += 2;
            }
            "--process" => {
                process = match value(i)?.as_str() {
                    "poisson" => ArrivalProcess::Poisson,
                    "bursty" => ArrivalProcess::bursty_default(),
                    other => bail!("unknown process {other:?}"),
                };
                i += 2;
            }
            "--seed" => {
                seed = value(i)?.parse()?;
                i += 2;
            }
            other => bail!("unknown option {other:?}"),
        }
    }
    let policy: Box<dyn RoutePolicy> = policy_by_name(&policy_name).with_context(|| {
        format!("unknown policy {policy_name:?} (round-robin|least-queue|deadline-aware)")
    })?;
    if let Some(path) = &obs_out {
        immsched::obs::enable_all();
        immsched::obs::recorder::set_dump_path(Some(path.clone()));
    }

    let dcfg = DriverConfig {
        class,
        process,
        arrival_rate: rate,
        horizon,
        seed,
        ..Default::default()
    };
    let schedule = schedule_from_trace(&dcfg);
    if !connect.is_empty() {
        shards = connect.len();
    }
    let kind = if !connect.is_empty() {
        "socket"
    } else if process_shards {
        "out-of-process"
    } else {
        "in-process"
    };
    println!(
        "cluster: {shards} {kind} shards ({} policy), {} {} arrivals over {horizon}s — {} requests",
        policy_name,
        rate,
        process.name(),
        schedule.len()
    );
    let ccfg = ClusterConfig {
        shards,
        pso: PsoConfig { seed, ..Default::default() },
        ..Default::default()
    };
    let cluster = Arc::new(if !connect.is_empty() {
        let mut transports: Vec<Arc<dyn ShardTransport>> = Vec::with_capacity(connect.len());
        for addr in &connect {
            let shard = SocketShard::connect(NetAddr::parse(addr)?, ccfg.service, ccfg.pso)
                .with_context(|| format!("dialing shard listener {addr}"))?;
            transports.push(Arc::new(shard));
        }
        MatchCluster::with_transports(transports, policy, ccfg.resume_capacity)
    } else if process_shards {
        MatchCluster::spawn_process_shards(ccfg, policy)?
    } else {
        MatchCluster::spawn(ccfg, policy)?
    });
    let fleet = SupervisedFleet::new(cluster, SupervisorConfig::default());
    let report = run_open_loop(&fleet, &schedule, &dcfg)?;
    fleet.drain()?;
    print!("{}", report.table().render());
    println!(
        "{} submitted, {} served, {} shed, {} preempted, {} resumed, {} SLO misses in {}",
        report.submitted(),
        report.served(),
        report.count_path(MatchPath::Shed),
        report.cluster.preemptions(),
        report.resumed(),
        report.slo_misses(),
        fmt_time(report.wall_seconds)
    );
    println!(
        "supervision: {} probes, {} shard failures, {} replays, {} sheds at floor",
        report.failover.probes,
        report.failover.shards_failed,
        report.failover.replays,
        report.failover.shed_at_floor
    );
    if let Some(path) = &obs_out {
        // final dump so the file exists even on an incident-free run
        immsched::obs::recorder::dump_to_disk("run-complete");
        println!("obs: flight-recorder dump written to {}", path.display());
        print!("{}", immsched::obs::registry().render_text());
    }
    Ok(())
}

/// `immsched experiment`: run a replicated sweep campaign — every grid
/// cell × seeded replications on a bounded worker pool, the quota
/// tournament, and the per-policy LBT search — on the deterministic
/// modeled cluster, then print the rendered report.  `--out FILE`
/// additionally writes the canonical summary JSON (byte-identical for
/// the same grid and campaign seed).
fn cmd_experiment(args: &[String]) -> Result<()> {
    let mut smoke = false;
    let mut seed = 42u64;
    let mut reps: Option<usize> = None;
    let mut workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8);
    let mut out: Option<PathBuf> = None;
    let mut i = 0;
    while i < args.len() {
        let value = |i: usize| args.get(i + 1).context("option needs a value");
        match args[i].as_str() {
            "--smoke" => {
                smoke = true;
                i += 1;
            }
            "--seed" => {
                seed = value(i)?.parse()?;
                i += 2;
            }
            "--reps" => {
                reps = Some(value(i)?.parse()?);
                i += 2;
            }
            "--workers" => {
                workers = value(i)?.parse::<usize>()?.max(1);
                i += 2;
            }
            "--out" => {
                out = Some(PathBuf::from(value(i)?));
                i += 2;
            }
            other => bail!("unknown option {other:?}"),
        }
    }
    let mut grid = if smoke {
        ExperimentGrid::smoke(seed)
    } else {
        ExperimentGrid::standard(seed)
    };
    if let Some(r) = reps {
        grid.replications = r.max(1);
    }
    println!(
        "experiment: {} cells x {} replications (campaign seed {seed}, {workers} workers)",
        grid.cells().len(),
        grid.replications
    );
    let result = run_campaign(&grid, workers)?;
    let summary = summary_json(&grid, &result);
    for t in &experiment_report(&summary) {
        print!("{}", t.render());
    }
    if let Some(path) = &out {
        std::fs::write(path, summary.render())?;
        println!("experiment: summary written to {}", path.display());
    }
    Ok(())
}

/// `immsched metrics`: the exposition surface of the observability
/// plane.  With `--in FILE` it renders a flight-recorder dump; without,
/// it enables the plane, runs a small in-process demo workload, and
/// prints the metric registry (with `--watch MS`, re-rendered live at
/// that cadence while the workload runs).
fn cmd_metrics(args: &[String]) -> Result<()> {
    let mut input: Option<PathBuf> = None;
    let mut watch_ms: Option<u64> = None;
    let mut i = 0;
    while i < args.len() {
        let value = |i: usize| args.get(i + 1).context("option needs a value");
        match args[i].as_str() {
            "--in" => {
                input = Some(PathBuf::from(value(i)?));
                i += 2;
            }
            "--watch" => {
                watch_ms = Some(value(i)?.parse()?);
                i += 2;
            }
            other => bail!("unknown option {other:?}"),
        }
    }
    if let Some(path) = input {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading dump {}", path.display()))?;
        let doc = Json::parse(&text).context("parsing dump JSON")?;
        print!("{}", render_obs_dump(&doc)?);
        return Ok(());
    }

    immsched::obs::enable_all();
    let dcfg = DriverConfig {
        class: WorkloadClass::Simple,
        process: ArrivalProcess::bursty_default(),
        arrival_rate: 150.0,
        horizon: 0.05,
        seed: 42,
        ..Default::default()
    };
    let schedule = schedule_from_trace(&dcfg);
    let policy = policy_by_name("deadline-aware").context("built-in policy missing")?;
    let ccfg = ClusterConfig { shards: 2, ..Default::default() };
    let cluster = Arc::new(MatchCluster::spawn(ccfg, policy)?);
    let fleet = SupervisedFleet::new(cluster, SupervisorConfig::default());
    println!("metrics: driving {} requests through 2 in-process shards", schedule.len());
    let report = std::thread::scope(|s| {
        let driver = s.spawn(|| run_open_loop(&fleet, &schedule, &dcfg));
        if let Some(ms) = watch_ms {
            while !driver.is_finished() {
                std::thread::sleep(Duration::from_millis(ms));
                println!("---- registry ----");
                print!("{}", immsched::obs::registry().render_text());
            }
        }
        driver.join()
    });
    let report = match report {
        Ok(r) => r?,
        Err(_) => bail!("driver thread panicked"),
    };
    fleet.drain()?;
    println!(
        "---- registry (final: {} submitted, {} served) ----",
        report.submitted(),
        report.served()
    );
    print!("{}", immsched::obs::registry().render_text());
    Ok(())
}

/// Human rendering of an `immsched.obs/v1` dump document: the header,
/// the incident ring, the metric registry, and one line per request
/// timeline (`*` = terminal event, `~` = ingested from a worker).
fn render_obs_dump(doc: &Json) -> Result<String> {
    let schema = get_str(doc, "schema")?;
    if schema != immsched::obs::OBS_DUMP_SCHEMA {
        bail!(
            "unsupported dump schema {schema:?} (this build reads {:?})",
            immsched::obs::OBS_DUMP_SCHEMA
        );
    }
    let mut out = format!(
        "flight recorder dump: reason={:?} evicted={}\n",
        get_str(doc, "reason")?,
        get_hex_u64(doc, "evicted")?
    );
    let events = doc.get("events").and_then(Json::as_array).context("dump has no events")?;
    let mut t = Table::new("incident ring (oldest first)").header(&["seq", "kind", "fields"]);
    for ev in events {
        let fields = match ev.get("fields") {
            Some(Json::Obj(pairs)) => pairs
                .iter()
                .map(|(k, v)| format!("{k}={}", v.as_str().unwrap_or("?")))
                .collect::<Vec<_>>()
                .join(" "),
            _ => String::new(),
        };
        t.row(vec![get_hex_u64(ev, "seq")?.to_string(), get_str(ev, "kind")?.into(), fields]);
    }
    out.push_str(&t.render());
    let metrics = doc.get("metrics").context("dump has no metrics")?;
    let mut t = Table::new("metric registry").header(&["name", "kind", "value"]);
    if let Json::Obj(entries) = metrics {
        for (name, m) in entries {
            let kind = get_str(m, "kind")?;
            let value = match kind {
                "histogram" => format!(
                    "count={} mean={:.1}us",
                    m.get("count").and_then(Json::as_f64).unwrap_or(0.0),
                    m.get("mean_us").and_then(Json::as_f64).unwrap_or(0.0)
                ),
                _ => format!("{}", m.get("value").and_then(Json::as_f64).unwrap_or(0.0)),
            };
            t.row(vec![name.clone(), kind.into(), value]);
        }
    }
    out.push_str(&t.render());
    if let Some(Json::Obj(timelines)) = doc.get("timelines") {
        let mut t = Table::new("request timelines").header(&["request", "spans"]);
        for (id, spans) in timelines {
            let rendered = spans
                .as_array()
                .unwrap_or(&[])
                .iter()
                .map(|s| {
                    let name = get_str(s, "kind").unwrap_or("?");
                    let remote = s.get("remote").and_then(Json::as_bool).unwrap_or(false);
                    let terminal = s.get("terminal").and_then(Json::as_bool).unwrap_or(false);
                    format!(
                        "{}{name}{}",
                        if remote { "~" } else { "" },
                        if terminal { "*" } else { "" }
                    )
                })
                .collect::<Vec<_>>()
                .join(" ");
            t.row(vec![id.clone(), rendered]);
        }
        out.push_str(&t.render());
    }
    Ok(out)
}

fn cmd_info() -> Result<()> {
    let mut t = Table::new("Platforms (paper Table 2)")
        .header(&["platform", "engines", "MACs/engine", "clock", "SRAM/engine"]);
    for p in [Platform::edge(), Platform::cloud()] {
        t.row(vec![
            p.kind.name().into(),
            p.engines.to_string(),
            format!("{}x{}", p.array_rows, p.array_cols),
            format!("{:.0} MHz", p.clock_hz / 1e6),
            format!("{} KiB", p.sram_bytes / 1024),
        ]);
    }
    print!("{}", t.render());

    let mut t = Table::new("Workloads (paper §4.1.2)")
        .header(&["model", "class", "layers", "GMACs", "params (M)"]);
    for id in ModelId::ALL {
        let g = build_model(id);
        t.row(vec![
            id.name().into(),
            id.class().name().into(),
            g.len().to_string(),
            format!("{:.2}", g.total_macs() as f64 / 1e9),
            format!("{:.1}", g.total_weight_bytes() as f64 / 1e6),
        ]);
    }
    print!("{}", t.render());

    match ArtifactRegistry::discover(&ArtifactRegistry::default_dir()) {
        Ok(reg) => {
            let mut t = Table::new("AOT artifacts")
                .header(&["class", "n", "m", "particles", "K", "path"]);
            for a in reg.all() {
                t.row(vec![
                    a.name.clone(),
                    a.class.n.to_string(),
                    a.class.m.to_string(),
                    a.class.particles.to_string(),
                    a.class.k_steps.to_string(),
                    a.path.display().to_string(),
                ]);
            }
            print!("{}", t.render());
        }
        Err(e) => println!("artifacts: not built ({e:#})"),
    }
    Ok(())
}
