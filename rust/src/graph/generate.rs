//! Parametric graph generators — used by tests, property tests and the
//! Fig. 2b stability study (random query/target pairs of controlled
//! density).

use super::dag::{Dag, NodeId, NodeKind};
use crate::util::Rng;

/// Linear chain 0 -> 1 -> ... -> n-1.
pub fn gen_chain(n: usize, kind: NodeKind) -> Dag {
    let mut g = Dag::with_nodes(n, kind);
    for i in 0..n.saturating_sub(1) {
        g.add_edge(i, i + 1);
    }
    g
}

/// Complete binary out-tree with `n` nodes.
pub fn gen_tree(n: usize, kind: NodeKind) -> Dag {
    let mut g = Dag::with_nodes(n, kind);
    for i in 1..n {
        g.add_edge((i - 1) / 2, i);
    }
    g
}

/// 2-D grid DAG (rows x cols), edges right and down — the shape of a
/// systolic tile pipeline.
pub fn gen_grid_2d(rows: usize, cols: usize, kind: NodeKind) -> Dag {
    let mut g = Dag::with_nodes(rows * cols, kind);
    let id = |r: usize, c: usize| -> NodeId { r * cols + c };
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                g.add_edge(id(r, c), id(r, c + 1));
            }
            if r + 1 < rows {
                g.add_edge(id(r, c), id(r + 1, c));
            }
        }
    }
    g
}

/// Random DAG: each forward pair (i < j) gets an edge with prob `density`.
/// Guaranteed acyclic by construction (edges only i -> j with i < j).
pub fn gen_random_dag(n: usize, density: f64, rng: &mut Rng, kind: NodeKind) -> Dag {
    let mut g = Dag::with_nodes(n, kind);
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.chance(density) {
                g.add_edge(i, j);
            }
        }
    }
    g
}

/// Layered DAG: `widths[l]` nodes per layer, each node wired to 1..=fanout
/// random nodes of the next layer — the shape of a tiled DNN stage graph.
pub fn gen_dag_layered(widths: &[usize], fanout: usize, rng: &mut Rng, kind: NodeKind) -> Dag {
    let mut g = Dag::new();
    let mut layers: Vec<Vec<NodeId>> = Vec::new();
    for &w in widths {
        let layer: Vec<NodeId> = (0..w).map(|_| g.add_node(kind, 1.0)).collect();
        layers.push(layer);
    }
    for l in 0..layers.len().saturating_sub(1) {
        for &u in &layers[l] {
            let k = rng.range(1, fanout.min(layers[l + 1].len()));
            let mut targets: Vec<NodeId> = layers[l + 1].clone();
            rng.shuffle(&mut targets);
            for &v in targets.iter().take(k) {
                g.add_edge(u, v);
            }
        }
        // every next-layer node needs at least one producer
        for &v in &layers[l + 1] {
            if g.in_degree(v) == 0 {
                let u = *rng.choose(&layers[l]);
                g.add_edge(u, v);
            }
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topo::is_acyclic;

    #[test]
    fn chain_shape() {
        let g = gen_chain(5, NodeKind::Compute);
        assert_eq!(g.edge_count(), 4);
        assert!(is_acyclic(&g));
    }

    #[test]
    fn tree_shape() {
        let g = gen_tree(7, NodeKind::Compute);
        assert_eq!(g.edge_count(), 6);
        assert_eq!(g.sources(), vec![0]);
    }

    #[test]
    fn grid_shape() {
        let g = gen_grid_2d(3, 4, NodeKind::Universal);
        assert_eq!(g.len(), 12);
        // edges: right 3*3 + down 2*4 = 17
        assert_eq!(g.edge_count(), 17);
        assert!(is_acyclic(&g));
    }

    #[test]
    fn random_dag_acyclic_at_any_density() {
        let mut rng = Rng::new(3);
        for &d in &[0.0, 0.2, 0.5, 1.0] {
            let g = gen_random_dag(20, d, &mut rng, NodeKind::Compute);
            assert!(is_acyclic(&g), "density {d}");
        }
    }

    #[test]
    fn layered_every_node_connected() {
        let mut rng = Rng::new(5);
        let g = gen_dag_layered(&[3, 4, 4, 2], 2, &mut rng, NodeKind::Compute);
        assert_eq!(g.len(), 13);
        assert!(is_acyclic(&g));
        // all non-first-layer nodes have producers
        for v in 3..13 {
            assert!(g.in_degree(v) > 0, "node {v} orphaned");
        }
    }
}
