//! Directed-acyclic-graph substrate.
//!
//! Both sides of the subgraph-isomorphism formulation live here: the
//! *query* graph (the urgent DNN task's tile DAG) and the *target* graph
//! (the preemptible engine/PE topology).  The matcher consumes the dense
//! adjacency form ([`Dag::adjacency`]); the schedulers use the structural
//! queries (topo order, levels, reachability).

mod csr;
mod dag;
mod generate;
mod topo;

pub use csr::Csr;
pub use dag::{Dag, NodeId, NodeKind};
pub use generate::{gen_chain, gen_dag_layered, gen_grid_2d, gen_random_dag, gen_tree};
pub use topo::{is_acyclic, levels, reachability, topo_sort};
