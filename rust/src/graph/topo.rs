//! Structural queries over DAGs: topological order, levels, reachability.

use super::dag::{Dag, NodeId};

/// Kahn topological sort; `None` if the graph has a cycle.
pub fn topo_sort(g: &Dag) -> Option<Vec<NodeId>> {
    let n = g.len();
    let mut indeg: Vec<usize> = (0..n).map(|u| g.in_degree(u)).collect();
    let mut queue: Vec<NodeId> = (0..n).filter(|&u| indeg[u] == 0).collect();
    let mut order = Vec::with_capacity(n);
    let mut head = 0;
    while head < queue.len() {
        let u = queue[head];
        head += 1;
        order.push(u);
        for &v in g.successors(u) {
            indeg[v] -= 1;
            if indeg[v] == 0 {
                queue.push(v);
            }
        }
    }
    (order.len() == n).then_some(order)
}

/// Whether the graph is acyclic.
pub fn is_acyclic(g: &Dag) -> bool {
    topo_sort(g).is_some()
}

/// ASAP level of every node (longest path from any source).
pub fn levels(g: &Dag) -> Vec<usize> {
    let order = topo_sort(g).expect("levels() requires a DAG");
    let mut level = vec![0usize; g.len()];
    for &u in &order {
        for &v in g.successors(u) {
            level[v] = level[v].max(level[u] + 1);
        }
    }
    level
}

/// Dense transitive reachability: `out[u][v]` iff v reachable from u
/// (u != v).  O(V·E/64) via bitset rows propagated in reverse topo order.
pub fn reachability(g: &Dag) -> Vec<Vec<bool>> {
    let n = g.len();
    let words = n.div_ceil(64);
    let mut bits = vec![vec![0u64; words]; n];
    let order = topo_sort(g).expect("reachability() requires a DAG");
    for &u in order.iter().rev() {
        for &v in g.successors(u) {
            // u reaches v and everything v reaches.
            let (left, right) = if u < v {
                let (a, b) = bits.split_at_mut(v);
                (&mut a[u], &b[0])
            } else {
                let (a, b) = bits.split_at_mut(u);
                (&mut b[0], &a[v])
            };
            for (w, r) in left.iter_mut().zip(right) {
                *w |= r;
            }
            left[v / 64] |= 1u64 << (v % 64);
        }
    }
    bits.into_iter()
        .map(|row| (0..n).map(|v| row[v / 64] >> (v % 64) & 1 == 1).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::NodeKind;

    fn chain(n: usize) -> Dag {
        let mut g = Dag::with_nodes(n, NodeKind::Compute);
        for i in 0..n - 1 {
            g.add_edge(i, i + 1);
        }
        g
    }

    #[test]
    fn topo_respects_edges() {
        let mut g = Dag::with_nodes(5, NodeKind::Compute);
        g.add_edge(3, 1);
        g.add_edge(1, 4);
        g.add_edge(3, 0);
        g.add_edge(0, 2);
        let order = topo_sort(&g).unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; 5];
            for (i, &u) in order.iter().enumerate() {
                p[u] = i;
            }
            p
        };
        assert!(pos[3] < pos[1] && pos[1] < pos[4]);
        assert!(pos[3] < pos[0] && pos[0] < pos[2]);
    }

    #[test]
    fn levels_of_chain() {
        let g = chain(6);
        assert_eq!(levels(&g), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn reachability_of_chain() {
        let g = chain(4);
        let r = reachability(&g);
        assert!(r[0][3] && r[0][1] && r[1][3]);
        assert!(!r[3][0] && !r[2][1]);
        assert!(!r[0][0], "reachability excludes self unless via a path");
    }

    #[test]
    fn reachability_diamond() {
        let mut g = Dag::with_nodes(4, NodeKind::Compute);
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        let r = reachability(&g);
        assert!(r[0][3]);
        assert!(!r[1][2] && !r[2][1]);
    }
}
