//! Compressed-sparse-row (CSR) edge-list view of a DAG adjacency.
//!
//! The matcher hot path iterates *edges*, never n×m index grids: the
//! sparse fitness kernel and the feasibility verifier both walk a [`Csr`]
//! built once per episode. [`Csr::rebuild_from_flat`] re-points an
//! existing view at a new adjacency while reusing its allocations, which
//! is what keeps the epoch backend's steady state allocation-free.

use super::dag::Dag;
use crate::util::MatF;

/// CSR adjacency over `nodes` vertices: `col[row_ptr[u]..row_ptr[u+1]]`
/// holds u's successors in ascending order. Indices are `u32` — graphs
/// here are at most a few thousand vertices, and the narrow type halves
/// the hot loop's cache traffic.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Csr {
    nodes: usize,
    row_ptr: Vec<u32>,
    col: Vec<u32>,
}

impl Csr {
    /// Empty view with room for `nodes` vertices and `edges` edges, so a
    /// later [`Self::rebuild_from_flat`] within those bounds never
    /// allocates.
    pub fn with_capacity(nodes: usize, edges: usize) -> Self {
        let mut row_ptr = Vec::with_capacity(nodes + 1);
        row_ptr.push(0);
        Self { nodes: 0, row_ptr, col: Vec::with_capacity(edges) }
    }

    /// CSR view of a DAG's successor lists.
    pub fn from_dag(d: &Dag) -> Self {
        let mut csr = Csr::with_capacity(d.len(), d.edge_count());
        csr.nodes = d.len();
        for u in 0..d.len() {
            for &v in d.successors(u) {
                csr.col.push(v as u32);
            }
            csr.row_ptr.push(csr.col.len() as u32);
        }
        csr
    }

    /// Rebuild a view from an explicit edge list sorted by source
    /// vertex (the row-major order [`Self::edges`] emits — the wire
    /// codec's interchange form).  Per-row successor order is preserved
    /// verbatim, so `from_edge_pairs(nodes, edges().collect())` is the
    /// identity.  Out-of-range endpoints and unsorted sources are
    /// decode errors, never silent truncation.
    pub fn from_edge_pairs(nodes: usize, pairs: &[(u32, u32)]) -> anyhow::Result<Self> {
        let mut csr = Csr::with_capacity(nodes, pairs.len());
        csr.nodes = nodes;
        let mut row = 0usize;
        for &(u, v) in pairs {
            let (u, v) = (u as usize, v as usize);
            anyhow::ensure!(u < nodes && v < nodes, "edge ({u}, {v}) outside {nodes} vertices");
            anyhow::ensure!(u >= row, "edge list not sorted by source vertex at ({u}, {v})");
            while row < u {
                csr.row_ptr.push(csr.col.len() as u32);
                row += 1;
            }
            csr.col.push(v as u32);
        }
        while row < nodes {
            csr.row_ptr.push(csr.col.len() as u32);
            row += 1;
        }
        Ok(csr)
    }

    /// CSR view of a dense square {0,1} adjacency matrix.
    pub fn from_dense(a: &MatF) -> Self {
        assert_eq!(a.rows(), a.cols(), "adjacency must be square");
        let mut csr = Csr::with_capacity(a.rows(), 0);
        csr.rebuild_from_flat(a.as_slice(), a.rows());
        csr
    }

    /// Re-point the view at a flat row-major `nodes`×`nodes` {0,1}
    /// adjacency, reusing the existing buffers (no allocation when the
    /// capacity from [`Self::with_capacity`] covers the new graph).
    pub fn rebuild_from_flat(&mut self, adj: &[f32], nodes: usize) {
        assert_eq!(adj.len(), nodes * nodes, "square adjacency expected");
        self.nodes = nodes;
        self.row_ptr.clear();
        self.col.clear();
        self.row_ptr.push(0);
        for u in 0..nodes {
            let row = &adj[u * nodes..(u + 1) * nodes];
            for (v, &x) in row.iter().enumerate() {
                if x != 0.0 {
                    self.col.push(v as u32);
                }
            }
            self.row_ptr.push(self.col.len() as u32);
        }
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    pub fn edge_count(&self) -> usize {
        self.col.len()
    }

    /// Successors of `u` (ascending vertex ids).
    pub fn neighbors(&self, u: usize) -> &[u32] {
        &self.col[self.row_ptr[u] as usize..self.row_ptr[u + 1] as usize]
    }

    /// Iterate every edge `(u, v)` in row-major order.
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        (0..self.nodes)
            .flat_map(move |u| self.neighbors(u).iter().map(move |&v| (u as u32, v)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_random_dag, NodeKind};
    use crate::util::Rng;

    fn diamond() -> Dag {
        let mut g = Dag::with_nodes(4, NodeKind::Compute);
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        g
    }

    #[test]
    fn from_dag_matches_successors() {
        let g = diamond();
        let csr = Csr::from_dag(&g);
        assert_eq!(csr.nodes(), 4);
        assert_eq!(csr.edge_count(), 4);
        assert_eq!(csr.neighbors(0), &[1, 2]);
        assert_eq!(csr.neighbors(1), &[3]);
        assert_eq!(csr.neighbors(3), &[] as &[u32]);
    }

    #[test]
    fn from_dense_matches_from_dag() {
        let mut rng = Rng::new(11);
        for _ in 0..10 {
            let d = gen_random_dag(9, 0.3, &mut rng, NodeKind::Compute);
            let a = Csr::from_dag(&d);
            let b = Csr::from_dense(&d.adjacency());
            // successor lists are ascending either way
            for u in 0..d.len() {
                let mut want = a.neighbors(u).to_vec();
                want.sort_unstable();
                assert_eq!(b.neighbors(u), &want[..], "vertex {u}");
            }
            assert_eq!(a.edge_count(), b.edge_count());
        }
    }

    #[test]
    fn rebuild_reuses_capacity() {
        let d = diamond();
        let mut csr = Csr::with_capacity(8, 16);
        csr.rebuild_from_flat(d.adjacency().as_slice(), 4);
        assert_eq!(csr.edge_count(), 4);
        let cap_before = csr.col.capacity();
        csr.rebuild_from_flat(d.adjacency().as_slice(), 4);
        assert_eq!(csr.col.capacity(), cap_before);
        assert_eq!(csr.neighbors(0), &[1, 2]);
    }

    #[test]
    fn edges_iterates_all() {
        let csr = Csr::from_dag(&diamond());
        let edges: Vec<(u32, u32)> = csr.edges().collect();
        assert_eq!(edges, vec![(0, 1), (0, 2), (1, 3), (2, 3)]);
    }

    #[test]
    fn edge_pairs_round_trip_is_identity() {
        let mut rng = Rng::new(29);
        for _ in 0..10 {
            let d = gen_random_dag(11, 0.35, &mut rng, NodeKind::Compute);
            let csr = Csr::from_dag(&d);
            let pairs: Vec<(u32, u32)> = csr.edges().collect();
            let back = Csr::from_edge_pairs(csr.nodes(), &pairs).unwrap();
            assert_eq!(back, csr);
        }
        // trailing isolated vertices must keep their (empty) rows
        let back = Csr::from_edge_pairs(5, &[(0, 1)]).unwrap();
        assert_eq!(back.nodes(), 5);
        assert_eq!(back.neighbors(4), &[] as &[u32]);
    }

    #[test]
    fn edge_pairs_reject_malformed_lists() {
        assert!(Csr::from_edge_pairs(3, &[(0, 7)]).is_err(), "out-of-range target");
        assert!(Csr::from_edge_pairs(3, &[(9, 0)]).is_err(), "out-of-range source");
        assert!(Csr::from_edge_pairs(3, &[(2, 0), (0, 1)]).is_err(), "unsorted sources");
    }

    #[test]
    fn empty_graph() {
        let csr = Csr::from_dag(&Dag::new());
        assert_eq!(csr.nodes(), 0);
        assert_eq!(csr.edge_count(), 0);
        assert_eq!(csr.edges().count(), 0);
    }
}
