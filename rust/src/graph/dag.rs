//! Core DAG type with typed vertices.

use crate::util::MatF;

/// Vertex index within one [`Dag`].
pub type NodeId = usize;

/// Computation type of a vertex — drives the compatibility mask
/// (paper §3.2: "the computation type of each vertex, e.g. convolution
/// for compute-intensive tiles, max-pooling for comparison-intensive
/// tiles").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NodeKind {
    /// MAC-dominated tile (conv / matmul / attention score).
    Compute,
    /// Comparison-dominated tile (max-pool / argmax / top-k).
    Compare,
    /// Elementwise tile (activation, norm apply, residual add).
    Eltwise,
    /// Data-movement tile (concat / split / reshape).
    Move,
    /// A PE/engine in the target graph able to run any tile kind.
    Universal,
}

impl NodeKind {
    /// Can a query tile of kind `self` run on a target vertex of `other`?
    pub fn compatible_with(self, other: NodeKind) -> bool {
        matches!(other, NodeKind::Universal) || self == other
    }
}

/// Adjacency-list DAG with per-node kinds and weights.
///
/// Node weight = normalized compute cost of the tile (used by the
/// schedulers); edge direction = data dependency (u -> v means v consumes
/// u's output tile).
#[derive(Clone, Debug, Default)]
pub struct Dag {
    succ: Vec<Vec<NodeId>>,
    pred: Vec<Vec<NodeId>>,
    kinds: Vec<NodeKind>,
    weights: Vec<f64>,
}

impl Dag {
    /// Empty graph.
    pub fn new() -> Self {
        Self::default()
    }

    /// Graph with `n` nodes of the given kind and unit weight.
    pub fn with_nodes(n: usize, kind: NodeKind) -> Self {
        Self {
            succ: vec![Vec::new(); n],
            pred: vec![Vec::new(); n],
            kinds: vec![kind; n],
            weights: vec![1.0; n],
        }
    }

    /// Add a node, returning its id.
    pub fn add_node(&mut self, kind: NodeKind, weight: f64) -> NodeId {
        self.succ.push(Vec::new());
        self.pred.push(Vec::new());
        self.kinds.push(kind);
        self.weights.push(weight);
        self.kinds.len() - 1
    }

    /// Add edge u -> v.  Panics on self-loops; duplicate edges are ignored.
    pub fn add_edge(&mut self, u: NodeId, v: NodeId) {
        assert_ne!(u, v, "self-loop {u}");
        assert!(u < self.len() && v < self.len(), "edge ({u},{v}) out of range");
        if !self.succ[u].contains(&v) {
            self.succ[u].push(v);
            self.pred[v].push(u);
        }
    }

    pub fn len(&self) -> usize {
        self.kinds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kinds.is_empty()
    }

    pub fn edge_count(&self) -> usize {
        self.succ.iter().map(Vec::len).sum()
    }

    pub fn successors(&self, u: NodeId) -> &[NodeId] {
        &self.succ[u]
    }

    pub fn predecessors(&self, u: NodeId) -> &[NodeId] {
        &self.pred[u]
    }

    pub fn out_degree(&self, u: NodeId) -> usize {
        self.succ[u].len()
    }

    pub fn in_degree(&self, u: NodeId) -> usize {
        self.pred[u].len()
    }

    pub fn kind(&self, u: NodeId) -> NodeKind {
        self.kinds[u]
    }

    pub fn set_kind(&mut self, u: NodeId, k: NodeKind) {
        self.kinds[u] = k;
    }

    pub fn weight(&self, u: NodeId) -> f64 {
        self.weights[u]
    }

    pub fn set_weight(&mut self, u: NodeId, w: f64) {
        self.weights[u] = w;
    }

    pub fn total_weight(&self) -> f64 {
        self.weights.iter().sum()
    }

    pub fn has_edge(&self, u: NodeId, v: NodeId) -> bool {
        self.succ[u].contains(&v)
    }

    /// Nodes with no predecessors.
    pub fn sources(&self) -> Vec<NodeId> {
        (0..self.len()).filter(|&u| self.pred[u].is_empty()).collect()
    }

    /// Nodes with no successors.
    pub fn sinks(&self) -> Vec<NodeId> {
        (0..self.len()).filter(|&u| self.succ[u].is_empty()).collect()
    }

    /// Dense {0,1} adjacency matrix (row = source, col = destination) —
    /// the `Q` / `G` the matcher and the Pallas kernel consume.
    pub fn adjacency(&self) -> MatF {
        let n = self.len();
        let mut a = MatF::zeros(n, n);
        for (u, vs) in self.succ.iter().enumerate() {
            for &v in vs {
                a[(u, v)] = 1.0;
            }
        }
        a
    }

    /// Compressed-sparse-row view of the successor lists — the edge-list
    /// form the matcher hot path iterates (see [`super::Csr`]).
    pub fn csr(&self) -> super::Csr {
        super::Csr::from_dag(self)
    }

    /// Induced subgraph on `keep` (node ids renumbered by position).
    pub fn induced(&self, keep: &[NodeId]) -> Dag {
        let mut map = vec![usize::MAX; self.len()];
        for (new, &old) in keep.iter().enumerate() {
            map[old] = new;
        }
        let mut g = Dag::new();
        for &old in keep {
            g.add_node(self.kinds[old], self.weights[old]);
        }
        for &old in keep {
            for &v in &self.succ[old] {
                if map[v] != usize::MAX {
                    g.add_edge(map[old], map[v]);
                }
            }
        }
        g
    }

    /// Graphviz dot dump (debugging / docs).
    pub fn to_dot(&self, name: &str) -> String {
        let mut s = format!("digraph {name} {{\n");
        for u in 0..self.len() {
            s.push_str(&format!("  n{u} [label=\"{u}:{:?} w={:.2}\"];\n", self.kinds[u], self.weights[u]));
        }
        for (u, vs) in self.succ.iter().enumerate() {
            for &v in vs {
                s.push_str(&format!("  n{u} -> n{v};\n"));
            }
        }
        s.push('}');
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Dag {
        // 0 -> {1,2} -> 3
        let mut g = Dag::with_nodes(4, NodeKind::Compute);
        g.add_edge(0, 1);
        g.add_edge(0, 2);
        g.add_edge(1, 3);
        g.add_edge(2, 3);
        g
    }

    #[test]
    fn degrees_and_edges() {
        let g = diamond();
        assert_eq!(g.edge_count(), 4);
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.in_degree(3), 2);
        assert!(g.has_edge(0, 1));
        assert!(!g.has_edge(1, 0));
    }

    #[test]
    fn duplicate_edges_ignored() {
        let mut g = diamond();
        g.add_edge(0, 1);
        assert_eq!(g.edge_count(), 4);
    }

    #[test]
    fn sources_and_sinks() {
        let g = diamond();
        assert_eq!(g.sources(), vec![0]);
        assert_eq!(g.sinks(), vec![3]);
    }

    #[test]
    fn adjacency_matches_edges() {
        let g = diamond();
        let a = g.adjacency();
        assert_eq!(a[(0, 1)], 1.0);
        assert_eq!(a[(0, 2)], 1.0);
        assert_eq!(a[(1, 3)], 1.0);
        assert_eq!(a[(1, 2)], 0.0);
        assert_eq!(a.sum(), 4.0);
    }

    #[test]
    fn induced_subgraph_renumbers() {
        let g = diamond();
        let sub = g.induced(&[0, 1, 3]);
        assert_eq!(sub.len(), 3);
        assert!(sub.has_edge(0, 1)); // old 0->1
        assert!(sub.has_edge(1, 2)); // old 1->3
        assert_eq!(sub.edge_count(), 2);
    }

    #[test]
    fn kind_compatibility() {
        assert!(NodeKind::Compute.compatible_with(NodeKind::Universal));
        assert!(NodeKind::Compute.compatible_with(NodeKind::Compute));
        assert!(!NodeKind::Compute.compatible_with(NodeKind::Compare));
    }

    #[test]
    #[should_panic]
    fn self_loop_panics() {
        let mut g = Dag::with_nodes(1, NodeKind::Compute);
        g.add_edge(0, 0);
    }
}
