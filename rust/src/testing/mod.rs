//! Mini property-testing framework (offline substitute for `proptest`,
//! DESIGN.md §4).
//!
//! Deterministic: every failure reports the case index and seed so the
//! exact input replays.  Shrinking is size-based — generators receive a
//! `size` hint that the runner decreases while re-checking a failing
//! predicate, reporting the smallest size that still fails.
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath in this image;
//! // the same example executes in the unit tests below)
//! use immsched::testing::{property, Gen};
//! property("reverse twice is identity", 100, |g| {
//!     let v = g.vec_usize(0..g.size().max(1), 100);
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     v == w
//! });
//! ```

use crate::util::Rng;

/// Case generator handed to property closures.
pub struct Gen {
    rng: Rng,
    size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Self { rng: Rng::new(seed), size }
    }

    /// Current size hint (shrinks on failure).
    pub fn size(&self) -> usize {
        self.size
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn usize_in(&mut self, range: std::ops::Range<usize>) -> usize {
        assert!(range.start < range.end);
        self.rng.range(range.start, range.end - 1)
    }

    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn f32(&mut self) -> f32 {
        self.rng.f32()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    pub fn vec_usize(&mut self, range: std::ops::Range<usize>, max_len: usize) -> Vec<usize> {
        let len = self.rng.below(max_len + 1);
        (0..len).map(|_| self.rng.range(range.start, range.end - 1)).collect()
    }
}

/// Run `cases` random cases of `prop`; panic with a replayable report on
/// the first failure, after shrinking the size hint.
pub fn property(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen) -> bool) {
    let base_seed = 0xC0FFEE ^ name.len() as u64;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let size = 4 + case * 4; // grow sizes over the run
        let mut g = Gen::new(seed, size);
        if !prop(&mut g) {
            // shrink: halve the size until it passes, report last failure
            let mut failing_size = size;
            let mut s = size / 2;
            while s >= 1 {
                let mut g2 = Gen::new(seed, s);
                if prop(&mut g2) {
                    break;
                }
                failing_size = s;
                s /= 2;
            }
            panic!(
                "property '{name}' failed: case {case}, seed {seed:#x}, \
                 smallest failing size {failing_size}"
            );
        }
    }
}

/// Like [`property`] but the closure returns `Result` with a message.
pub fn property_res(
    name: &str,
    cases: usize,
    mut prop: impl FnMut(&mut Gen) -> Result<(), String>,
) {
    let mut last_err = String::new();
    let wrapped = |g: &mut Gen| -> bool {
        match prop(g) {
            Ok(()) => true,
            Err(e) => {
                last_err = e;
                false
            }
        }
    };
    // re-implement loop to include the error message
    let base_seed = 0xC0FFEE ^ name.len() as u64;
    let mut wrapped = wrapped;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let size = 4 + case * 4;
        let mut g = Gen::new(seed, size);
        if !wrapped(&mut g) {
            panic!("property '{name}' failed: case {case}, seed {seed:#x}: {last_err}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doc_example_reverse_identity() {
        property("reverse twice is identity", 100, |g| {
            let v = g.vec_usize(0..g.size().max(1), 100);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            v == w
        });
    }

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        property("always true", 50, |_| {
            count += 1;
            true
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always false' failed")]
    fn failing_property_panics_with_report() {
        property("always false", 10, |_| false);
    }

    #[test]
    fn generator_is_deterministic_per_seed() {
        let mut a = Gen::new(42, 10);
        let mut b = Gen::new(42, 10);
        for _ in 0..100 {
            assert_eq!(a.usize_in(0..1000), b.usize_in(0..1000));
        }
    }

    #[test]
    fn property_res_reports_message() {
        let result = std::panic::catch_unwind(|| {
            property_res("res check", 5, |g| {
                if g.size() > 8 {
                    Err("size exceeded".to_string())
                } else {
                    Ok(())
                }
            })
        });
        assert!(result.is_err());
    }
}
