//! Matcher cost model: wall-clock + energy of a scheduling episode,
//! on-accelerator (IMMSched) vs host-CPU serial (all baselines).
//!
//! This is where the paper's headline mechanism lives (Fig. 2a): a
//! serial CPU matcher pays `nodes_visited × per-node work` at CPU rates
//! and CPU power, while IMMSched pays `steps × per-step kernel` at MXU
//! rates with engine-parallel particles, plus a small controller/NoC
//! overhead per epoch.

use crate::accel::energy::EnergyModel;
use crate::accel::noc::NocModel;
use crate::accel::platform::Platform;
use crate::accel::timing::EngineTiming;
use crate::graph::NodeKind;

use super::quantized::QuantizedOutcome;
use super::ullmann::UllmannStats;

/// A scheduling episode's cost.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MatcherCost {
    pub seconds: f64,
    pub joules: f64,
}

impl MatcherCost {
    pub fn zero() -> Self {
        Self::default()
    }

    pub fn add(&mut self, other: MatcherCost) {
        self.seconds += other.seconds;
        self.joules += other.joules;
    }
}

/// Cost-model parameters.
#[derive(Clone, Copy, Debug)]
pub struct MatcherCostModel {
    /// Host CPU clock for the serial baselines (Hz).
    pub cpu_hz: f64,
    /// Effective scalar ops per CPU cycle for graph search (branchy
    /// pointer-chasing code: ~1 op/cycle).
    pub cpu_ops_per_cycle: f64,
    /// Host CPU package power while scheduling (W).
    pub cpu_watts: f64,
    /// Fixed CPU-side interrupt dispatch overhead (s): NPU driver
    /// round-trip + occupancy-state readback.  Paid by every CPU-side
    /// scheduler on every urgent arrival; IMMSched's on-accelerator
    /// controller avoids it entirely.
    pub cpu_dispatch_s: f64,
    /// Work per backtracking node: consistency checks against assigned
    /// rows + candidate scans, ≈ n·m scalar ops.
    pub ops_per_search_node_factor: f64,
    pub energy: EnergyModel,
}

impl Default for MatcherCostModel {
    fn default() -> Self {
        Self {
            cpu_hz: 3.0e9,
            cpu_ops_per_cycle: 1.0,
            cpu_watts: 15.0,
            cpu_dispatch_s: 2.0e-4,
            ops_per_search_node_factor: 1.0,
            energy: EnergyModel::default(),
        }
    }
}

impl MatcherCostModel {
    /// Cost of a *serial CPU* Ullmann episode (IsoSched baseline and the
    /// offline LTS schedulers' matching/placement searches).
    pub fn cpu_serial(&self, stats: &UllmannStats, n: usize, m: usize) -> MatcherCost {
        let node_ops = self.ops_per_search_node_factor * (n * m) as f64;
        let refine_ops = (n * m * (n + m)) as f64; // one sweep touches n·m cells × neighbor scans
        let total_ops =
            stats.nodes_visited as f64 * node_ops + stats.refine_passes as f64 * refine_ops;
        let seconds =
            self.cpu_dispatch_s + total_ops / (self.cpu_hz * self.cpu_ops_per_cycle);
        MatcherCost { seconds, joules: seconds * self.cpu_watts }
    }

    /// Cost of an *on-accelerator* quantized PSO episode (IMMSched).
    ///
    /// Particles run engine-parallel; each fused step's MAC work executes
    /// on the int8 array, elementwise work on the modified PEs; every
    /// epoch the controller broadcasts S*/S̄ and collects fitness over
    /// the NoC.
    pub fn accel_pso(
        &self,
        out: &QuantizedOutcome,
        n: usize,
        m: usize,
        particles: usize,
        platform: &Platform,
    ) -> MatcherCost {
        let timing = EngineTiming::of(platform);
        let noc = NocModel::of(platform);
        let steps = out.steps_run.max(1) as f64;
        let epochs = out.epochs_run.max(1) as f64;

        // per-particle per-step datapath work
        let macs_per_step = (n * m * m + n * n * m) as u64;
        let elt_per_step = (5 * n * m) as u64;
        let mac_cycles =
            crate::accel::timing::tile_cycles(&timing, NodeKind::Compute, macs_per_step);
        // eltwise uses one array row per lane: m lanes per cycle
        let elt_cycles = (elt_per_step as f64 / m as f64).ceil() as u64;
        let step_cycles = mac_cycles + elt_cycles;

        // engine-parallel rounds: ceil(particles / engines)
        let rounds = particles.div_ceil(platform.engines) as f64;
        let compute_seconds = steps * rounds * step_cycles as f64 / platform.clock_hz;

        // controller + NoC per epoch: broadcast S* and S̄ (2·n·m bytes u8)
        // to each active engine, gather fitness (4·particles bytes)
        let active = particles.min(platform.engines);
        let bcast_bytes = (2 * n * m) as u64;
        let mean_hops = (platform.mesh_cols + platform.mesh_rows()) as f64 / 2.0;
        let mut noc_seconds = 0.0;
        let mut noc_joules = 0.0;
        for _ in 0..active {
            noc_seconds += noc.transfer_seconds(0, platform.engines - 1, bcast_bytes)
                / active as f64; // links are parallel; serialization shared
            noc_joules +=
                bcast_bytes as f64 * 8.0 * mean_hops * self.energy.noc_bit_hop;
        }
        let gather_bytes = (4 * particles) as u64;
        noc_seconds += noc.transfer_seconds(0, platform.engines - 1, gather_bytes);
        noc_joules += gather_bytes as f64 * 8.0 * mean_hops * self.energy.noc_bit_hop;
        // consensus fusion on the controller: elite · n·m ops at clock,
        // plus the Ullmann-repair backtracking (≈ n comparisons/node)
        let controller_cycles =
            (4 * n * m) as f64 + out.repair_nodes as f64 * n as f64 / epochs;
        let controller_seconds = controller_cycles / platform.clock_hz;

        let seconds = compute_seconds + epochs * (noc_seconds + controller_seconds);

        // energy: datapath MACs + eltwise (as SRAM-streamed ops) + NoC + static
        let mac_j = out.mac_ops as f64 * self.energy.mac_int8;
        let elt_j = out.eltwise_ops as f64 * self.energy.mac_int8 * 0.5;
        let sram_j = (out.mac_ops / 64) as f64 * self.energy.sram_byte; // operand reuse 64x
        let static_j = self.energy.static_energy(active, seconds);
        let joules = mac_j + elt_j + sram_j + epochs * noc_joules + static_j;

        MatcherCost { seconds, joules }
    }

    /// Cost of running the *same PSO* serially on the CPU (ablation:
    /// parallelism contribution vs algorithm contribution).
    pub fn cpu_pso(&self, out: &QuantizedOutcome) -> MatcherCost {
        let total_ops = out.mac_ops as f64 + out.eltwise_ops as f64;
        // SIMD CPU: ~8 int ops/cycle for dense loops
        let seconds = total_ops / (self.cpu_hz * 8.0);
        MatcherCost { seconds, joules: seconds * self.cpu_watts }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_outcome(steps: usize, particles: usize, n: usize, m: usize) -> QuantizedOutcome {
        QuantizedOutcome {
            steps_run: steps,
            epochs_run: 1,
            mac_ops: (steps * particles * (n * m * m + n * n * m)) as u64,
            eltwise_ops: (steps * particles * 5 * n * m) as u64,
            argmax_ops: n as u64,
            ..Default::default()
        }
    }

    #[test]
    fn accel_pso_orders_of_magnitude_faster_than_cpu_serial() {
        // Fig. 2a mechanism: serial backtracking with ~1e6 visited nodes
        // vs a 16-particle, 64-step accelerated search.
        let model = MatcherCostModel::default();
        let p = Platform::edge();
        let (n, m) = (32, 64);
        let serial = model.cpu_serial(
            &UllmannStats { nodes_visited: 2_000_000, refine_passes: 10, refuted: 0 },
            n,
            m,
        );
        let accel = model.accel_pso(&fake_outcome(64, 16, n, m), n, m, 16, &p);
        assert!(
            serial.seconds > 50.0 * accel.seconds,
            "serial {} vs accel {}",
            serial.seconds,
            accel.seconds
        );
        assert!(serial.joules > 50.0 * accel.joules);
    }

    #[test]
    fn engine_parallelism_helps() {
        let model = MatcherCostModel::default();
        let p = Platform::edge();
        let (n, m) = (16, 32);
        let out = fake_outcome(32, 128, n, m);
        let few_engines = Platform { engines: 4, ..p };
        let t_many = model.accel_pso(&out, n, m, 128, &p).seconds;
        let t_few = model.accel_pso(&out, n, m, 128, &few_engines).seconds;
        assert!(t_few > 5.0 * t_many, "few {t_few} vs many {t_many}");
    }

    #[test]
    fn cpu_pso_slower_than_accel_pso() {
        let model = MatcherCostModel::default();
        let p = Platform::edge();
        let (n, m) = (32, 64);
        let out = fake_outcome(64, 16, n, m);
        let accel = model.accel_pso(&out, n, m, 16, &p);
        let cpu = model.cpu_pso(&out);
        assert!(cpu.seconds > accel.seconds);
    }

    #[test]
    fn costs_scale_with_work() {
        let model = MatcherCostModel::default();
        let p = Platform::edge();
        let a = model.accel_pso(&fake_outcome(16, 16, 16, 32), 16, 32, 16, &p);
        let b = model.accel_pso(&fake_outcome(64, 16, 16, 32), 16, 32, 16, &p);
        assert!(b.seconds > 2.0 * a.seconds);
        assert!(b.joules > 2.0 * a.joules);
    }
}
