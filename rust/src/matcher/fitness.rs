//! Edge-preserving fitness and feasibility verification (paper §3.3).
//!
//! Two fitness paths compute `-‖Q − S G Sᵀ‖²_F`:
//!
//! * [`edge_fitness`] — the dense reference: two dense matmuls plus a
//!   Frobenius distance, `O(n·m²)` per evaluation. Kept as the oracle
//!   the property tests cross-check against.
//! * [`FitnessKernel`] — the production hot path: Q and G are sparse
//!   {0,1} DAG adjacencies, so the kernel iterates their CSR edge lists
//!   and skips masked-out (zero) entries of S. With `|E|` edges and
//!   `nnz` surviving S entries the cost is `O(n·m + n·|E_G| + n·nnz)`,
//!   and every buffer lives in a caller-owned [`FitnessScratch`], so
//!   steady-state evaluation performs no heap allocation.
//!
//! The two agree exactly in real arithmetic (for {0,1} Q,
//! `‖Q−P‖² = |E_Q| − 2·Σ_{(i,k)∈E_Q} P_ik + ‖P‖²_F`); floating-point
//! summation order differs, so cross-checks compare with a tolerance.

use crate::graph::Csr;
use crate::util::MatF;

use super::Mapping;

/// `-‖Q − S G Sᵀ‖²_F` for one relaxed mapping S (the rust twin of the
/// Pallas kernel's fitness; the dense oracle the sparse kernel is
/// verified against).
pub fn edge_fitness(s: &MatF, q: &MatF, g: &MatF) -> f32 {
    debug_assert_eq!(s.rows(), q.rows());
    debug_assert_eq!(s.cols(), g.rows());
    let sg = s.matmul(g); // n×m
    let sgst = sg.matmul(&s.transpose()); // n×n
    -q.sq_dist(&sgst)
}

/// Caller-owned scratch for [`FitnessKernel`] evaluations. One per
/// worker thread; allocated once per episode (or held in the epoch
/// backend's persistent workspace) and reused across every step.
pub struct FitnessScratch {
    /// Sᵀ, m×n — transposed once so the edge loops read contiguously.
    st: Vec<f32>,
    /// R = G·Sᵀ, m×n — row j accumulates Sᵀ rows of j's successors.
    r: Vec<f32>,
    /// P = S·R = S G Sᵀ, n×n.
    p: Vec<f32>,
    /// One-hot S for the discrete ablation ([`FitnessKernel::eval_hard`]).
    hard: Vec<f32>,
}

impl FitnessScratch {
    pub fn new(n: usize, m: usize) -> Self {
        Self {
            st: vec![0.0; n * m],
            r: vec![0.0; n * m],
            p: vec![0.0; n * n],
            hard: vec![0.0; n * m],
        }
    }

    /// The discrete-ablation staging buffer (n×m); fill it with a
    /// hard-rounded S, then call [`FitnessKernel::eval_hard`].
    pub(crate) fn hard_mut(&mut self) -> &mut [f32] {
        &mut self.hard
    }
}

/// Sparse fitness kernel for one (Q, G) episode: CSR edge lists built
/// once (or rebuilt in place via [`Self::rebuild`] with zero
/// allocation), shared read-only across worker threads.
pub struct FitnessKernel {
    n: usize,
    m: usize,
    q: Csr,
    g: Csr,
}

impl FitnessKernel {
    /// Build from dense {0,1} adjacencies (every nonzero entry must be
    /// exactly 1.0 — DAG adjacencies and planted instances are).
    pub fn new(q: &MatF, g: &MatF) -> Self {
        assert_eq!(q.rows(), q.cols(), "Q must be square");
        assert_eq!(g.rows(), g.cols(), "G must be square");
        let mut kernel = Self::with_capacity(q.rows(), g.rows());
        kernel.rebuild(q.as_slice(), q.rows(), g.as_slice(), g.rows());
        kernel
    }

    /// Preallocate for the worst case at dims (n, m) so every later
    /// [`Self::rebuild`] within those bounds is allocation-free (the
    /// epoch backend holds one of these per size class).
    pub fn with_capacity(n: usize, m: usize) -> Self {
        Self {
            n,
            m,
            q: Csr::with_capacity(n, n * n),
            g: Csr::with_capacity(m, m * m),
        }
    }

    /// Re-point the kernel at a new flat (Q, G) pair, reusing buffers.
    ///
    /// Panics on non-{0,1} entries: the sparse identity assumes binary
    /// adjacencies, and a silent wrong fitness would steer the whole
    /// swarm — the O(n²+m²) scan is noise next to one epoch. Weighted
    /// graphs must use the dense [`edge_fitness`].
    pub fn rebuild(&mut self, q: &[f32], n: usize, g: &[f32], m: usize) {
        assert!(
            q.iter().chain(g).all(|&x| x == 0.0 || x == 1.0),
            "FitnessKernel requires {{0,1}} adjacencies (use edge_fitness for weighted graphs)"
        );
        self.n = n;
        self.m = m;
        self.q.rebuild_from_flat(q, n);
        self.g.rebuild_from_flat(g, m);
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn m(&self) -> usize {
        self.m
    }

    /// Query edge list (shared with the feasibility verifier).
    pub fn q_edges(&self) -> &Csr {
        &self.q
    }

    /// Target edge list.
    pub fn g_edges(&self) -> &Csr {
        &self.g
    }

    /// Fresh scratch sized for this kernel's dims.
    pub fn scratch(&self) -> FitnessScratch {
        FitnessScratch::new(self.n, self.m)
    }

    /// `-‖Q − S G Sᵀ‖²_F` for a flat row-major n×m S.
    pub fn eval(&self, s: &[f32], scratch: &mut FitnessScratch) -> f32 {
        let FitnessScratch { st, r, p, .. } = scratch;
        self.eval_core(s, st, r, p)
    }

    /// Evaluate the hard-rounded S previously written into the scratch's
    /// staging buffer (discrete ablation of Fig. 2b).
    pub(crate) fn eval_hard(&self, scratch: &mut FitnessScratch) -> f32 {
        let FitnessScratch { st, r, p, hard } = scratch;
        self.eval_core(hard, st, r, p)
    }

    fn eval_core(&self, s: &[f32], st: &mut [f32], r: &mut [f32], p: &mut [f32]) -> f32 {
        let (n, m) = (self.n, self.m);
        debug_assert_eq!(s.len(), n * m);
        let st = &mut st[..m * n];
        let r = &mut r[..m * n];
        let p = &mut p[..n * n];
        // 1. Sᵀ — one strided pass; every later access is contiguous.
        for i in 0..n {
            let srow = &s[i * m..(i + 1) * m];
            for (l, &x) in srow.iter().enumerate() {
                st[l * n + i] = x;
            }
        }
        // 2. R = G·Sᵀ by iterating target edges: row j of R is the sum
        //    of Sᵀ rows over j's successors (childless rows stay zero).
        r.fill(0.0);
        for j in 0..m {
            let succ = self.g.neighbors(j);
            if succ.is_empty() {
                continue;
            }
            let rj = &mut r[j * n..(j + 1) * n];
            for &l in succ {
                let stl = &st[l as usize * n..(l as usize + 1) * n];
                for (a, &b) in rj.iter_mut().zip(stl) {
                    *a += b;
                }
            }
        }
        // 3. P = S·R, skipping masked-out (zero) S entries — under a
        //    sparse compatibility mask this is the dominant saving.
        p.fill(0.0);
        for i in 0..n {
            let srow = &s[i * m..(i + 1) * m];
            let pi = &mut p[i * n..(i + 1) * n];
            for (j, &sij) in srow.iter().enumerate() {
                if sij == 0.0 {
                    continue;
                }
                let rj = &r[j * n..(j + 1) * n];
                for (a, &b) in pi.iter_mut().zip(rj) {
                    *a += sij * b;
                }
            }
        }
        // 4. ‖Q − P‖² = |E_Q| − 2·Σ_{(i,k)∈E_Q} P_ik + ‖P‖² (Q is {0,1}).
        let sum_sq: f32 = p.iter().map(|&x| x * x).sum();
        let mut cross = 0.0f32;
        for i in 0..n {
            for &k in self.q.neighbors(i) {
                cross += p[i * n + k as usize];
            }
        }
        -(self.q.edge_count() as f32 - 2.0 * cross + sum_sq)
    }
}

/// Ullmann's feasibility condition: `M̂ G M̂ᵀ` must cover Q, i.e. for
/// every query edge (i,k) there must be a target edge (M(i), M(k)).
/// Partial mappings (None entries) are infeasible.
///
/// Targets are resolved once in the totality pre-pass (no per-pair
/// unwraps), and each row's adjacency slice is scanned with an early
/// return. Hot paths that already own a CSR of Q should prefer
/// [`mapping_is_feasible_csr`], which skips the zero entries entirely.
pub fn mapping_is_feasible(mapping: &Mapping, q: &MatF, g: &MatF) -> bool {
    let n = q.rows();
    debug_assert_eq!(mapping.len(), n);
    let mut tmap = vec![0usize; n];
    if !resolve_targets(mapping, g.rows(), &mut tmap) {
        return false;
    }
    for (i, &ti) in tmap.iter().enumerate() {
        for (k, &qik) in q.row(i).iter().enumerate() {
            if qik != 0.0 && g[(ti, tmap[k])] == 0.0 {
                return false;
            }
        }
    }
    true
}

/// [`mapping_is_feasible`] against a prebuilt CSR of Q's edges — the
/// verify path the PSO barrier and the controller run on every projected
/// candidate (iterating the edge list skips the n² zero scan). The two
/// small O(n+m) scratch vectors here are epoch-barrier allocations, not
/// per-step ones — the zero-allocation guarantee covers the fused step
/// loop (`run_epoch_into`), which never verifies.
pub fn mapping_is_feasible_csr(mapping: &Mapping, q_edges: &Csr, g: &MatF) -> bool {
    let n = q_edges.nodes();
    debug_assert_eq!(mapping.len(), n);
    let mut tmap = vec![0usize; n];
    if !resolve_targets(mapping, g.rows(), &mut tmap) {
        return false;
    }
    for (i, &ti) in tmap.iter().enumerate() {
        for &k in q_edges.neighbors(i) {
            if g[(ti, tmap[k as usize])] == 0.0 {
                return false;
            }
        }
    }
    true
}

/// [`mapping_is_feasible`] over two CSR edge lists — the fully sparse
/// verify path of the typed request API ([`crate::coordinator::MatchRequest`]
/// carries both sides as [`Csr`] views, so no dense matrix is needed to
/// verify a projected candidate).  Neighbor lists are scanned linearly;
/// DAG out-degrees here are tiny.
pub fn mapping_is_feasible_sparse(mapping: &Mapping, q: &Csr, g: &Csr) -> bool {
    let n = q.nodes();
    debug_assert_eq!(mapping.len(), n);
    let mut tmap = vec![0usize; n];
    if !resolve_targets(mapping, g.nodes(), &mut tmap) {
        return false;
    }
    for (i, &ti) in tmap.iter().enumerate() {
        for &k in q.neighbors(i) {
            if !g.neighbors(ti).contains(&(tmap[k as usize] as u32)) {
                return false;
            }
        }
    }
    true
}

/// Totality + injectivity pre-pass: resolve `mapping` into `tmap`
/// (query vertex i → target `tmap[i]`). Returns false on partial,
/// out-of-range or non-injective mappings.
fn resolve_targets(mapping: &Mapping, m: usize, tmap: &mut [usize]) -> bool {
    let mut used = vec![false; m];
    for (slot, &mj) in tmap.iter_mut().zip(mapping) {
        match mj {
            None => return false,
            Some(j) => {
                if j >= m || used[j] {
                    return false;
                }
                used[j] = true;
                *slot = j;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_chain, gen_random_dag, NodeKind};
    use crate::util::Rng;

    #[test]
    fn perfect_embedding_zero_fitness() {
        let q = gen_chain(3, NodeKind::Compute).adjacency();
        let g = gen_chain(5, NodeKind::Universal).adjacency();
        // map i -> i+1; S one-hot
        let mut s = MatF::zeros(3, 5);
        for i in 0..3 {
            s[(i, i + 1)] = 1.0;
        }
        // SGS^T picks exactly the chain edges 1->2->3 => equals Q
        assert_eq!(edge_fitness(&s, &q, &g), 0.0);
        let kernel = FitnessKernel::new(&q, &g);
        let mut scratch = kernel.scratch();
        assert_eq!(kernel.eval(s.as_slice(), &mut scratch), 0.0);
    }

    #[test]
    fn wrong_embedding_negative_fitness() {
        let q = gen_chain(3, NodeKind::Compute).adjacency();
        let g = gen_chain(5, NodeKind::Universal).adjacency();
        let mut s = MatF::zeros(3, 5);
        s[(0, 0)] = 1.0;
        s[(1, 2)] = 1.0; // gap: 0->2 is not a target edge
        s[(2, 3)] = 1.0;
        assert!(edge_fitness(&s, &q, &g) < 0.0);
        let kernel = FitnessKernel::new(&q, &g);
        let mut scratch = kernel.scratch();
        assert!(kernel.eval(s.as_slice(), &mut scratch) < 0.0);
    }

    #[test]
    fn sparse_kernel_tracks_dense_on_random_pairs() {
        let mut rng = Rng::new(77);
        for trial in 0..30 {
            let n = 2 + (trial % 6);
            let m = n + 3 + (trial % 5);
            let q = gen_random_dag(n, 0.4, &mut rng, NodeKind::Compute).adjacency();
            let g = gen_random_dag(m, 0.3, &mut rng, NodeKind::Universal).adjacency();
            let mut s = MatF::from_fn(n, m, |_, _| {
                if rng.chance(0.6) {
                    rng.f32() + 1e-3
                } else {
                    0.0
                }
            });
            s.row_normalize();
            let dense = edge_fitness(&s, &q, &g);
            let kernel = FitnessKernel::new(&q, &g);
            let mut scratch = kernel.scratch();
            let sparse = kernel.eval(s.as_slice(), &mut scratch);
            let tol = 1e-4 * (1.0 + dense.abs());
            assert!(
                (dense - sparse).abs() <= tol,
                "trial {trial}: dense {dense} vs sparse {sparse}"
            );
        }
    }

    #[test]
    fn rebuild_repoints_without_stale_state() {
        let q1 = gen_chain(3, NodeKind::Compute).adjacency();
        let g1 = gen_chain(5, NodeKind::Universal).adjacency();
        let mut kernel = FitnessKernel::with_capacity(4, 6);
        kernel.rebuild(q1.as_slice(), 3, g1.as_slice(), 5);
        assert_eq!(kernel.q_edges().edge_count(), 2);
        assert_eq!(kernel.g_edges().edge_count(), 4);
        // smaller second episode: no leftovers from the first
        let q2 = gen_chain(2, NodeKind::Compute).adjacency();
        let g2 = gen_chain(3, NodeKind::Universal).adjacency();
        kernel.rebuild(q2.as_slice(), 2, g2.as_slice(), 3);
        assert_eq!(kernel.n(), 2);
        assert_eq!(kernel.m(), 3);
        assert_eq!(kernel.q_edges().edge_count(), 1);
        let mut s = MatF::zeros(2, 3);
        s[(0, 1)] = 1.0;
        s[(1, 2)] = 1.0;
        let mut scratch = kernel.scratch();
        assert_eq!(kernel.eval(s.as_slice(), &mut scratch), 0.0);
    }

    #[test]
    fn feasibility_accepts_true_embedding() {
        let q = gen_chain(3, NodeKind::Compute).adjacency();
        let g = gen_chain(5, NodeKind::Universal).adjacency();
        assert!(mapping_is_feasible(&vec![Some(2), Some(3), Some(4)], &q, &g));
    }

    #[test]
    fn feasibility_rejects_broken_edge() {
        let q = gen_chain(3, NodeKind::Compute).adjacency();
        let g = gen_chain(5, NodeKind::Universal).adjacency();
        assert!(!mapping_is_feasible(&vec![Some(0), Some(2), Some(3)], &q, &g));
    }

    #[test]
    fn feasibility_rejects_non_injective_and_partial() {
        let q = gen_chain(2, NodeKind::Compute).adjacency();
        let g = gen_chain(3, NodeKind::Universal).adjacency();
        assert!(!mapping_is_feasible(&vec![Some(1), Some(1)], &q, &g));
        assert!(!mapping_is_feasible(&vec![Some(0), None], &q, &g));
    }

    #[test]
    fn feasibility_csr_matches_dense_scan() {
        let mut rng = Rng::new(5);
        for _ in 0..40 {
            let n = rng.range(2, 6);
            let m = n + rng.range(1, 6);
            let qd = gen_random_dag(n, 0.5, &mut rng, NodeKind::Compute);
            let gd = gen_random_dag(m, 0.4, &mut rng, NodeKind::Universal);
            let (q, g) = (qd.adjacency(), gd.adjacency());
            let q_csr = qd.csr();
            // random mapping: mostly valid shape; sometimes None,
            // duplicate, or out of range — both checks must agree on all
            let mapping: Mapping = (0..n)
                .map(|_| if rng.chance(0.9) { Some(rng.below(m + 1)) } else { None })
                .collect();
            assert_eq!(
                mapping_is_feasible(&mapping, &q, &g),
                mapping_is_feasible_csr(&mapping, &q_csr, &g),
                "mapping {mapping:?}"
            );
        }
    }

    #[test]
    fn feasibility_sparse_matches_dense_scan() {
        let mut rng = Rng::new(13);
        for _ in 0..40 {
            let n = rng.range(2, 6);
            let m = n + rng.range(1, 6);
            let qd = gen_random_dag(n, 0.5, &mut rng, NodeKind::Compute);
            let gd = gen_random_dag(m, 0.4, &mut rng, NodeKind::Universal);
            let (q, g) = (qd.adjacency(), gd.adjacency());
            let (q_csr, g_csr) = (qd.csr(), gd.csr());
            let mapping: Mapping = (0..n)
                .map(|_| if rng.chance(0.9) { Some(rng.below(m + 1)) } else { None })
                .collect();
            assert_eq!(
                mapping_is_feasible(&mapping, &q, &g),
                mapping_is_feasible_sparse(&mapping, &q_csr, &g_csr),
                "mapping {mapping:?}"
            );
        }
    }
}
