//! Edge-preserving fitness and feasibility verification (paper §3.3).

use crate::util::MatF;

use super::Mapping;

/// `-‖Q − S G Sᵀ‖²_F` for one relaxed mapping S (the rust twin of the
/// Pallas kernel's fitness, used by the native matcher and the tests
/// that cross-check the artifact).
pub fn edge_fitness(s: &MatF, q: &MatF, g: &MatF) -> f32 {
    debug_assert_eq!(s.rows(), q.rows());
    debug_assert_eq!(s.cols(), g.rows());
    let sg = s.matmul(g); // n×m
    let sgst = sg.matmul(&s.transpose()); // n×n
    -q.sq_dist(&sgst)
}

/// Ullmann's feasibility condition: `M̂ G M̂ᵀ` must cover Q, i.e. for
/// every query edge (i,k) there must be a target edge (M(i), M(k)).
/// Partial mappings (None entries) are infeasible.
pub fn mapping_is_feasible(mapping: &Mapping, q: &MatF, g: &MatF) -> bool {
    let n = q.rows();
    debug_assert_eq!(mapping.len(), n);
    // injectivity + totality
    let mut used = vec![false; g.rows()];
    for &mj in mapping {
        match mj {
            None => return false,
            Some(j) => {
                if j >= g.rows() || used[j] {
                    return false;
                }
                used[j] = true;
            }
        }
    }
    for i in 0..n {
        for k in 0..n {
            if q[(i, k)] != 0.0 {
                let (ti, tk) = (mapping[i].unwrap(), mapping[k].unwrap());
                if g[(ti, tk)] == 0.0 {
                    return false;
                }
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_chain, NodeKind};

    #[test]
    fn perfect_embedding_zero_fitness() {
        let q = gen_chain(3, NodeKind::Compute).adjacency();
        let g = gen_chain(5, NodeKind::Universal).adjacency();
        // map i -> i+1; S one-hot
        let mut s = MatF::zeros(3, 5);
        for i in 0..3 {
            s[(i, i + 1)] = 1.0;
        }
        // SGS^T picks exactly the chain edges 1->2->3 => equals Q
        assert_eq!(edge_fitness(&s, &q, &g), 0.0);
    }

    #[test]
    fn wrong_embedding_negative_fitness() {
        let q = gen_chain(3, NodeKind::Compute).adjacency();
        let g = gen_chain(5, NodeKind::Universal).adjacency();
        let mut s = MatF::zeros(3, 5);
        s[(0, 0)] = 1.0;
        s[(1, 2)] = 1.0; // gap: 0->2 is not a target edge
        s[(2, 3)] = 1.0;
        assert!(edge_fitness(&s, &q, &g) < 0.0);
    }

    #[test]
    fn feasibility_accepts_true_embedding() {
        let q = gen_chain(3, NodeKind::Compute).adjacency();
        let g = gen_chain(5, NodeKind::Universal).adjacency();
        assert!(mapping_is_feasible(&vec![Some(2), Some(3), Some(4)], &q, &g));
    }

    #[test]
    fn feasibility_rejects_broken_edge() {
        let q = gen_chain(3, NodeKind::Compute).adjacency();
        let g = gen_chain(5, NodeKind::Universal).adjacency();
        assert!(!mapping_is_feasible(&vec![Some(0), Some(2), Some(3)], &q, &g));
    }

    #[test]
    fn feasibility_rejects_non_injective_and_partial() {
        let q = gen_chain(2, NodeKind::Compute).adjacency();
        let g = gen_chain(3, NodeKind::Universal).adjacency();
        assert!(!mapping_is_feasible(&vec![Some(1), Some(1)], &q, &g));
        assert!(!mapping_is_feasible(&vec![Some(0), None], &q, &g));
    }
}
