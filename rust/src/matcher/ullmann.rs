//! Serial Ullmann subgraph-isomorphism (Ullmann 1976) with the classic
//! neighborhood refinement.
//!
//! Three roles in this repo:
//! 1. the **IsoSched baseline** (serial CPU matcher — the thing the paper
//!    beats, Figs. 2a/6/7),
//! 2. the **refinement + verification** stage IMMSched applies to
//!    projected PSO candidates (Algorithm 1, lines 19–22),
//! 3. the ground-truth oracle for matcher property tests.

use crate::util::{MatF, Rng};

use super::{mapping_is_feasible, Mapping};

/// Search statistics (the serial-latency numbers of Fig. 2a come from
/// `nodes_visited` / `refine_passes` fed into the cost model).
#[derive(Clone, Copy, Debug, Default)]
pub struct UllmannStats {
    /// Backtracking nodes expanded.
    pub nodes_visited: u64,
    /// Refinement sweeps performed.
    pub refine_passes: u64,
    /// Candidate (i,j) pairs eliminated by refinement.
    pub refuted: u64,
}

/// One pass of Ullmann refinement over the candidate matrix.
///
/// `cand[i][j]` survives only if every query successor k of i has a
/// surviving candidate among j's target successors, and dually for
/// predecessors.  Returns `true` if anything changed.
fn refine_pass(cand: &mut MatF, q: &MatF, g: &MatF, stats: &mut UllmannStats) -> bool {
    let (n, m) = (cand.rows(), cand.cols());
    let mut changed = false;
    for i in 0..n {
        for j in 0..m {
            if cand[(i, j)] == 0.0 {
                continue;
            }
            let mut ok = true;
            // successors: every k with Q[i][k]=1 needs l with G[j][l]=1 and cand[k][l]=1
            'outer_succ: for k in 0..n {
                if q[(i, k)] != 0.0 {
                    for l in 0..m {
                        if g[(j, l)] != 0.0 && cand[(k, l)] != 0.0 {
                            continue 'outer_succ;
                        }
                    }
                    ok = false;
                    break;
                }
            }
            if ok {
                // predecessors: every k with Q[k][i]=1 needs l with G[l][j]=1 and cand[k][l]=1
                'outer_pred: for k in 0..n {
                    if q[(k, i)] != 0.0 {
                        for l in 0..m {
                            if g[(l, j)] != 0.0 && cand[(k, l)] != 0.0 {
                                continue 'outer_pred;
                            }
                        }
                        ok = false;
                        break;
                    }
                }
            }
            if !ok {
                cand[(i, j)] = 0.0;
                stats.refuted += 1;
                changed = true;
            }
        }
    }
    changed
}

/// Refine a candidate matrix to a fixed point.  Returns `false` if some
/// query vertex lost all candidates (infeasible).
pub fn ullmann_refine(cand: &mut MatF, q: &MatF, g: &MatF, stats: &mut UllmannStats) -> bool {
    loop {
        stats.refine_passes += 1;
        let changed = refine_pass(cand, q, g, stats);
        for i in 0..cand.rows() {
            if cand.row(i).iter().all(|&x| x == 0.0) {
                return false;
            }
        }
        if !changed {
            return true;
        }
    }
}

fn backtrack(
    row: usize,
    cand: &MatF,
    q: &MatF,
    g: &MatF,
    used: &mut Vec<bool>,
    assign: &mut Mapping,
    stats: &mut UllmannStats,
    budget: &mut u64,
) -> bool {
    if *budget == 0 {
        return false;
    }
    let n = q.rows();
    if row == n {
        return mapping_is_feasible(assign, q, g);
    }
    for j in 0..cand.cols() {
        if cand[(row, j)] == 0.0 || used[j] {
            continue;
        }
        // forward consistency with already-assigned rows
        let mut consistent = true;
        for prev in 0..row {
            let pj = assign[prev].unwrap();
            if (q[(prev, row)] != 0.0 && g[(pj, j)] == 0.0)
                || (q[(row, prev)] != 0.0 && g[(j, pj)] == 0.0)
            {
                consistent = false;
                break;
            }
        }
        if !consistent {
            continue;
        }
        stats.nodes_visited += 1;
        *budget = budget.saturating_sub(1);
        used[j] = true;
        assign[row] = Some(j);
        if backtrack(row + 1, cand, q, g, used, assign, stats, budget) {
            return true;
        }
        used[j] = false;
        assign[row] = None;
    }
    false
}

/// Full serial Ullmann: refinement + depth-first backtracking.
///
/// `budget` caps expanded nodes (the serial baseline in open-ended
/// scenarios must give up *eventually* to simulate its deadline misses).
/// Returns the first feasible mapping found and the search stats.
pub fn ullmann_find_first(
    mask: &MatF,
    q: &MatF,
    g: &MatF,
    budget: u64,
) -> (Option<Mapping>, UllmannStats) {
    let mut stats = UllmannStats::default();
    let mut cand = mask.clone();
    if !ullmann_refine(&mut cand, q, g, &mut stats) {
        return (None, stats);
    }
    let mut used = vec![false; g.rows()];
    let mut assign: Mapping = vec![None; q.rows()];
    let mut budget = budget;
    let found = backtrack(0, &cand, q, g, &mut used, &mut assign, &mut stats, &mut budget);
    (found.then_some(assign), stats)
}

/// Convenience for tests: random query embedded into a random supergraph,
/// returning (q, g, planted mapping).  The planted embedding guarantees a
/// solution exists.
pub fn plant_embedding(
    n: usize,
    m: usize,
    q_density: f64,
    extra_density: f64,
    rng: &mut Rng,
) -> (MatF, MatF, Vec<usize>) {
    assert!(n <= m);
    // random query DAG (i < j edges only)
    let mut q = MatF::zeros(n, n);
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.chance(q_density) {
                q[(i, j)] = 1.0;
            }
        }
    }
    // random injective order-preserving placement of query vertices into target
    let mut slots: Vec<usize> = (0..m).collect();
    rng.shuffle(&mut slots);
    let mut place: Vec<usize> = slots[..n].to_vec();
    place.sort_unstable(); // order-preserving keeps the target acyclic
    // target: planted edges + extra forward noise
    let mut g = MatF::zeros(m, m);
    for i in 0..n {
        for j in 0..n {
            if q[(i, j)] != 0.0 {
                g[(place[i], place[j])] = 1.0;
            }
        }
    }
    for a in 0..m {
        for b in (a + 1)..m {
            if rng.chance(extra_density) {
                g[(a, b)] = 1.0;
            }
        }
    }
    (q, g, place)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_chain, NodeKind};
    use crate::matcher::build_mask;

    #[test]
    fn finds_chain_in_longer_chain() {
        let qd = gen_chain(3, NodeKind::Compute);
        let gd = gen_chain(6, NodeKind::Universal);
        let (q, g) = (qd.adjacency(), gd.adjacency());
        let mask = build_mask(&qd, &gd);
        let (found, stats) = ullmann_find_first(&mask, &q, &g, 1_000_000);
        let mapping = found.expect("chain must embed");
        assert!(mapping_is_feasible(&mapping, &q, &g));
        assert!(stats.nodes_visited > 0);
    }

    #[test]
    fn rejects_impossible_embedding() {
        // query chain longer than target chain
        let qd = gen_chain(5, NodeKind::Compute);
        let gd = gen_chain(3, NodeKind::Universal);
        let mask = MatF::full(5, 3, 1.0);
        let (found, _) = ullmann_find_first(&mask, &qd.adjacency(), &gd.adjacency(), 1_000_000);
        assert!(found.is_none());
    }

    #[test]
    fn planted_embeddings_always_found() {
        let mut rng = Rng::new(17);
        for trial in 0..20 {
            let n = rng.range(3, 7);
            let m = n + rng.range(2, 8);
            let (q, g, _) = plant_embedding(n, m, 0.4, 0.2, &mut rng);
            let mask = MatF::full(n, m, 1.0);
            let (found, _) = ullmann_find_first(&mask, &q, &g, 10_000_000);
            let mapping = found.unwrap_or_else(|| panic!("trial {trial}: planted not found"));
            assert!(mapping_is_feasible(&mapping, &q, &g), "trial {trial}");
        }
    }

    #[test]
    fn refinement_prunes_isolated_candidates() {
        // query edge 0->1; target has an isolated vertex 2
        let mut q = MatF::zeros(2, 2);
        q[(0, 1)] = 1.0;
        let mut g = MatF::zeros(3, 3);
        g[(0, 1)] = 1.0;
        let mut cand = MatF::full(2, 3, 1.0);
        let mut stats = UllmannStats::default();
        assert!(ullmann_refine(&mut cand, &q, &g, &mut stats));
        // query 0 (has successor) cannot sit on targets 1,2 (no successors)
        assert_eq!(cand[(0, 1)], 0.0);
        assert_eq!(cand[(0, 2)], 0.0);
        assert_eq!(cand[(0, 0)], 1.0);
        assert!(stats.refuted >= 2);
    }

    #[test]
    fn budget_exhaustion_returns_none() {
        let mut rng = Rng::new(23);
        let (q, g, _) = plant_embedding(8, 20, 0.5, 0.3, &mut rng);
        let mask = MatF::full(8, 20, 1.0);
        let (found, _) = ullmann_find_first(&mask, &q, &g, 1); // 1 node budget
        assert!(found.is_none());
    }
}
