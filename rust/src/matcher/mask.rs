//! Global compatibility mask (paper §3.2).
//!
//! `mask[i][j] = 1` iff query tile i *could* map onto target vertex j:
//! the target vertex's kind accepts the tile's computation type, and its
//! in/out degrees can host the tile's (a target vertex needs at least as
//! many neighbors as the query vertex it hosts — the standard Ullmann
//! degree filter).

use crate::graph::Dag;
use crate::util::MatF;

/// Build the `n×m` compatibility mask between query `q` and target `g`.
pub fn build_mask(q: &Dag, g: &Dag) -> MatF {
    let (n, m) = (q.len(), g.len());
    let mut mask = MatF::zeros(n, m);
    for i in 0..n {
        for j in 0..m {
            let kind_ok = q.kind(i).compatible_with(g.kind(j));
            let deg_ok = g.out_degree(j) >= q.out_degree(i) && g.in_degree(j) >= q.in_degree(i);
            if kind_ok && deg_ok {
                mask[(i, j)] = 1.0;
            }
        }
    }
    mask
}

/// Whether any query vertex has an empty candidate row — an early
/// infeasibility witness (the scheduler uses it to reject an interrupt
/// without running the matcher at all).
pub fn has_empty_row(mask: &MatF) -> bool {
    (0..mask.rows()).any(|i| mask.row(i).iter().all(|&x| x == 0.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_chain, Dag, NodeKind};

    #[test]
    fn degree_filter_applies() {
        // query: 0 -> 1 -> 2 (middle vertex needs in>=1 and out>=1)
        let q = gen_chain(3, NodeKind::Compute);
        // target: chain of 4 universal vertices
        let g = gen_chain(4, NodeKind::Universal);
        let mask = build_mask(&q, &g);
        // query vertex 1 (in=1,out=1) can only host on target 1, 2
        assert_eq!(mask[(1, 0)], 0.0);
        assert_eq!(mask[(1, 1)], 1.0);
        assert_eq!(mask[(1, 2)], 1.0);
        assert_eq!(mask[(1, 3)], 0.0);
        // query source (out=1, in=0) fits targets 0..=2
        assert_eq!(mask[(0, 0)], 1.0);
        assert_eq!(mask[(0, 3)], 0.0);
    }

    #[test]
    fn kind_filter_applies() {
        let mut q = gen_chain(2, NodeKind::Compute);
        q.set_kind(1, NodeKind::Compare);
        let mut g = gen_chain(3, NodeKind::Compute);
        g.set_kind(1, NodeKind::Compare);
        let mask = build_mask(&q, &g);
        // compare tile only onto compare vertex
        assert_eq!(mask[(1, 1)], 1.0);
        assert_eq!(mask[(1, 2)], 0.0);
    }

    #[test]
    fn universal_targets_accept_everything() {
        let mut q = Dag::with_nodes(3, NodeKind::Compute);
        q.set_kind(1, NodeKind::Compare);
        q.set_kind(2, NodeKind::Eltwise);
        let g = Dag::with_nodes(3, NodeKind::Universal);
        let mask = build_mask(&q, &g);
        assert_eq!(mask.sum(), 9.0);
    }

    #[test]
    fn empty_row_detection() {
        let q = gen_chain(3, NodeKind::Compute);
        let g = Dag::with_nodes(3, NodeKind::Compare); // no edges, wrong kind
        let mask = build_mask(&q, &g);
        assert!(has_empty_row(&mask));
    }
}
