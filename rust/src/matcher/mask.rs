//! Global compatibility mask (paper §3.2).
//!
//! `mask[i][j] = 1` iff query tile i *could* map onto target vertex j:
//! the target vertex's kind accepts the tile's computation type, and its
//! in/out degrees can host the tile's (a target vertex needs at least as
//! many neighbors as the query vertex it hosts — the standard Ullmann
//! degree filter).
//!
//! The mask is built as a packed [`BitMask`] (one bit per (i,j) pair,
//! 64 candidates per word): feasibility witnesses like
//! [`BitMask::has_empty_row`] are word-wise, and the scheduler uses them
//! to reject an interrupt without running the matcher at all. The f32
//! form ([`BitMask::to_matf`] / [`build_mask`]) remains the interchange
//! type with the PSO state and the AOT artifact's calling convention.

use crate::graph::Dag;
use crate::util::MatF;

/// Packed n×m bitset: bit j of row i is set iff query vertex i may map
/// onto target vertex j. Rows are padded to whole 64-bit words; padding
/// bits are always zero.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitMask {
    rows: usize,
    cols: usize,
    words_per_row: usize,
    words: Vec<u64>,
}

impl BitMask {
    /// All-zero mask.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        let words_per_row = (cols + 63) / 64;
        Self { rows, cols, words_per_row, words: vec![0; rows * words_per_row] }
    }

    /// Pack a dense f32 mask (any nonzero entry sets the bit).
    pub fn from_matf(mask: &MatF) -> Self {
        let mut bits = Self::zeros(mask.rows(), mask.cols());
        for i in 0..mask.rows() {
            for (j, &x) in mask.row(i).iter().enumerate() {
                if x != 0.0 {
                    bits.set(i, j);
                }
            }
        }
        bits
    }

    /// Unpack into the f32 form the PSO state multiplies against.
    pub fn to_matf(&self) -> MatF {
        MatF::from_fn(self.rows, self.cols, |i, j| if self.get(i, j) { 1.0 } else { 0.0 })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> bool {
        debug_assert!(i < self.rows && j < self.cols);
        self.words[i * self.words_per_row + j / 64] & (1u64 << (j % 64)) != 0
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize) {
        debug_assert!(i < self.rows && j < self.cols);
        self.words[i * self.words_per_row + j / 64] |= 1u64 << (j % 64);
    }

    /// Row i's candidate set as packed words.
    pub fn row_words(&self, i: usize) -> &[u64] {
        &self.words[i * self.words_per_row..(i + 1) * self.words_per_row]
    }

    /// Whether any query vertex has an empty candidate row — an early
    /// infeasibility witness, checked one word (64 candidates) at a
    /// time. The scheduler rejects such interrupts before particle init.
    pub fn has_empty_row(&self) -> bool {
        (0..self.rows).any(|i| self.row_words(i).iter().all(|&w| w == 0))
    }

    /// Total candidate pairs (set bits).
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Fraction of (i,j) pairs that survive the filters.
    pub fn density(&self) -> f64 {
        if self.rows * self.cols == 0 {
            return 0.0;
        }
        self.count_ones() as f64 / (self.rows * self.cols) as f64
    }
}

/// Build the packed `n×m` compatibility mask between query `q` and
/// target `g`.
pub fn build_bitmask(q: &Dag, g: &Dag) -> BitMask {
    let (n, m) = (q.len(), g.len());
    let mut mask = BitMask::zeros(n, m);
    for i in 0..n {
        for j in 0..m {
            let kind_ok = q.kind(i).compatible_with(g.kind(j));
            let deg_ok = g.out_degree(j) >= q.out_degree(i) && g.in_degree(j) >= q.in_degree(i);
            if kind_ok && deg_ok {
                mask.set(i, j);
            }
        }
    }
    mask
}

/// Dense f32 form of [`build_bitmask`] — the interchange form the PSO
/// state and the epoch backends consume.
pub fn build_mask(q: &Dag, g: &Dag) -> MatF {
    build_bitmask(q, g).to_matf()
}

/// Whether any query vertex has an empty candidate row in a dense f32
/// mask. Prefer [`BitMask::has_empty_row`] where a packed mask exists —
/// it checks 64 candidates per word instead of scanning floats.
pub fn has_empty_row(mask: &MatF) -> bool {
    (0..mask.rows()).any(|i| mask.row(i).iter().all(|&x| x == 0.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_chain, Dag, NodeKind};

    #[test]
    fn degree_filter_applies() {
        // query: 0 -> 1 -> 2 (middle vertex needs in>=1 and out>=1)
        let q = gen_chain(3, NodeKind::Compute);
        // target: chain of 4 universal vertices
        let g = gen_chain(4, NodeKind::Universal);
        let mask = build_mask(&q, &g);
        // query vertex 1 (in=1,out=1) can only host on target 1, 2
        assert_eq!(mask[(1, 0)], 0.0);
        assert_eq!(mask[(1, 1)], 1.0);
        assert_eq!(mask[(1, 2)], 1.0);
        assert_eq!(mask[(1, 3)], 0.0);
        // query source (out=1, in=0) fits targets 0..=2
        assert_eq!(mask[(0, 0)], 1.0);
        assert_eq!(mask[(0, 3)], 0.0);
    }

    #[test]
    fn kind_filter_applies() {
        let mut q = gen_chain(2, NodeKind::Compute);
        q.set_kind(1, NodeKind::Compare);
        let mut g = gen_chain(3, NodeKind::Compute);
        g.set_kind(1, NodeKind::Compare);
        let mask = build_mask(&q, &g);
        // compare tile only onto compare vertex
        assert_eq!(mask[(1, 1)], 1.0);
        assert_eq!(mask[(1, 2)], 0.0);
    }

    #[test]
    fn universal_targets_accept_everything() {
        let mut q = Dag::with_nodes(3, NodeKind::Compute);
        q.set_kind(1, NodeKind::Compare);
        q.set_kind(2, NodeKind::Eltwise);
        let g = Dag::with_nodes(3, NodeKind::Universal);
        let mask = build_mask(&q, &g);
        assert_eq!(mask.sum(), 9.0);
        assert_eq!(build_bitmask(&q, &g).count_ones(), 9);
    }

    #[test]
    fn empty_row_detection() {
        let q = gen_chain(3, NodeKind::Compute);
        let g = Dag::with_nodes(3, NodeKind::Compare); // no edges, wrong kind
        let mask = build_mask(&q, &g);
        assert!(has_empty_row(&mask));
        assert!(build_bitmask(&q, &g).has_empty_row());
        assert!(BitMask::from_matf(&mask).has_empty_row());
    }

    #[test]
    fn bitmask_roundtrips_through_matf() {
        let q = gen_chain(5, NodeKind::Compute);
        let g = gen_chain(9, NodeKind::Universal);
        let bits = build_bitmask(&q, &g);
        let dense = bits.to_matf();
        assert_eq!(BitMask::from_matf(&dense), bits);
        for i in 0..5 {
            for j in 0..9 {
                assert_eq!(bits.get(i, j), dense[(i, j)] != 0.0, "({i},{j})");
            }
        }
    }

    #[test]
    fn bitmask_crosses_word_boundaries() {
        // 70 columns spans two words per row
        let mut bits = BitMask::zeros(2, 70);
        bits.set(0, 0);
        bits.set(0, 63);
        bits.set(0, 64);
        bits.set(1, 69);
        assert!(bits.get(0, 63));
        assert!(bits.get(0, 64));
        assert!(!bits.get(0, 65));
        assert!(bits.get(1, 69));
        assert_eq!(bits.count_ones(), 4);
        assert_eq!(bits.row_words(0).len(), 2);
        assert!(!bits.has_empty_row());
    }

    #[test]
    fn empty_row_word_check_matches_float_scan() {
        let mut dense = MatF::zeros(3, 130); // three words per row
        dense[(0, 5)] = 1.0;
        dense[(2, 129)] = 1.0;
        let bits = BitMask::from_matf(&dense);
        assert!(bits.has_empty_row()); // row 1 empty
        assert_eq!(bits.has_empty_row(), has_empty_row(&dense));
        let mut full = dense.clone();
        full[(1, 64)] = 1.0;
        let bits = BitMask::from_matf(&full);
        assert!(!bits.has_empty_row());
        assert_eq!(bits.has_empty_row(), has_empty_row(&full));
    }
}
