//! Native multi-particle optimizer (Algorithm 1) — the rust twin of the
//! AOT artifact, plus the *discrete* ablation of Fig. 2b.
//!
//! Two uses:
//! * the hardware-model execution path: the simulator charges the
//!   accelerator for exactly the work this implementation performs
//!   (steps × particles fused kernels, see [`super::cost`]);
//! * a fallback when artifacts are missing/corrupt (failure injection —
//!   the coordinator logs and degrades rather than aborting).
//!
//! The PJRT path ([`crate::runtime::EpochRunner`]) computes the same
//! epoch; integration tests cross-check the two.

use crate::util::{MatF, Rng};

use super::consensus::elite_consensus;
use super::fitness::{edge_fitness, mapping_is_feasible};
use super::projection::project_greedy;
use super::ullmann::{ullmann_find_first, UllmannStats};
use super::Mapping;

/// PSO hyperparameters (defaults follow the standard constricted swarm
/// plus the paper's consensus term).
#[derive(Clone, Copy, Debug)]
pub struct PsoConfig {
    /// Particles per epoch (mapped 1:1 onto engines).
    pub particles: usize,
    /// Outer epochs T (particles re-initialized each epoch, Algorithm 1
    /// line 4; S*, S̄ and the feasible set persist).
    pub epochs: usize,
    /// Fused inner steps K per epoch.
    pub steps: usize,
    /// Inertia.
    pub w: f32,
    /// Cognitive (particle-local best) pull.
    pub c1: f32,
    /// Social (global best) pull.
    pub c2: f32,
    /// Consensus pull (the paper's addition).
    pub c3: f32,
    /// Elites fused into the consensus matrix.
    pub elite: usize,
    /// Continuous relaxation on (true = IMMSched; false = the unstable
    /// discrete coupling of Fig. 2b).
    pub relaxed: bool,
    /// Stop at the first feasible mapping (production) or keep searching
    /// (benchmarks that want the full trace).
    pub early_exit: bool,
    /// Node budget for the bounded Ullmann repair of projected
    /// candidates.
    pub repair_budget: u64,
    pub seed: u64,
}

impl Default for PsoConfig {
    fn default() -> Self {
        Self {
            particles: 16,
            epochs: 8,
            steps: 8,
            w: 0.72,
            c1: 1.49,
            c2: 1.49,
            c3: 0.60,
            elite: 4,
            relaxed: true,
            early_exit: true,
            // Algorithm 1's UllmannRefine step needs headroom on branchy
            // queries (UNet skip tiles take ~10k nodes); the controller
            // is charged for every expanded node in the cost model.
            repair_budget: 100_000,
            seed: 0x1535EED,
        }
    }
}

/// Search outcome + enough telemetry to drive the figures.
#[derive(Clone, Debug, Default)]
pub struct PsoOutcome {
    /// Feasible mappings found (deduplicated).
    pub mappings: Vec<Mapping>,
    /// Best fitness reached (0 = perfect relaxed embedding).
    pub best_fitness: f32,
    /// Best-so-far fitness after every fused step (Fig. 2b traces).
    pub fitness_trace: Vec<f32>,
    /// Mean *current* fitness across particles after every fused step —
    /// the non-monotone signal whose oscillation Fig. 2b plots as
    /// "search stability".
    pub mean_fitness_trace: Vec<f32>,
    /// Epochs actually run.
    pub epochs_run: usize,
    /// Total fused steps executed (each = one kernel launch per particle).
    pub steps_run: usize,
    /// Ullmann repair statistics.
    pub repair_stats: UllmannStats,
    /// Fused step kernel invocations (steps_run × particles) — the unit
    /// the cost model charges.
    pub kernel_invocations: u64,
}

impl PsoOutcome {
    pub fn matched(&self) -> bool {
        !self.mappings.is_empty()
    }
}

/// One particle's state.
struct Particle {
    s: MatF,
    v: MatF,
    s_local: MatF,
    f_local: f32,
}

/// The native matcher.
pub struct PsoMatcher {
    pub config: PsoConfig,
}

impl PsoMatcher {
    pub fn new(config: PsoConfig) -> Self {
        Self { config }
    }

    /// Run Algorithm 1 on (mask, Q, G).
    pub fn run(&self, mask: &MatF, q: &MatF, g: &MatF) -> PsoOutcome {
        let cfg = &self.config;
        let (n, m) = (mask.rows(), mask.cols());
        assert_eq!(q.rows(), n);
        assert_eq!(g.rows(), m);
        let mut rng = Rng::new(cfg.seed);
        let mut out = PsoOutcome { best_fitness: f32::NEG_INFINITY, ..Default::default() };

        let mut s_star = init_particle_s(mask, &mut rng);
        let mut f_star = f32::NEG_INFINITY;
        let mut s_bar = s_star.clone();
        // deterministic in (mask, q, g) — run at most once per episode
        let mut repair_memo: Option<Option<Mapping>> = None;

        'epochs: for _t in 0..cfg.epochs {
            out.epochs_run += 1;
            // line 4: fresh particles each epoch
            let mut particles: Vec<Particle> = (0..cfg.particles)
                .map(|_| {
                    let s = init_particle_s(mask, &mut rng);
                    Particle {
                        v: MatF::zeros(n, m),
                        s_local: s.clone(),
                        f_local: f32::NEG_INFINITY,
                        s,
                    }
                })
                .collect();

            for _k in 0..cfg.steps {
                out.steps_run += 1;
                out.kernel_invocations += cfg.particles as u64;
                let mut f_sum = 0.0f32;
                for p in particles.iter_mut() {
                    step_particle(p, &s_star, &s_bar, mask, cfg, &mut rng);
                    let f = if cfg.relaxed {
                        edge_fitness(&p.s, q, g)
                    } else {
                        // discrete coupling (Fig. 2b ablation): evaluate on
                        // the hard-rounded one-hot projection of S
                        let hard = harden(&p.s, mask);
                        edge_fitness(&hard, q, g)
                    };
                    f_sum += f;
                    if f > p.f_local {
                        p.f_local = f;
                        p.s_local = p.s.clone();
                    }
                    if f > f_star {
                        f_star = f;
                        s_star = p.s.clone();
                    }
                }
                out.best_fitness = out.best_fitness.max(f_star);
                out.fitness_trace.push(f_star);
                out.mean_fitness_trace.push(f_sum / cfg.particles.max(1) as f32);
            }

            // lines 19-25: project, refine, verify, fuse consensus
            let fitnesses: Vec<f32> = particles.iter().map(|p| p.f_local).collect();
            for p in &particles {
                let candidate = project_greedy(&p.s, mask);
                let found = if mapping_is_feasible(&candidate, q, g) {
                    Some(candidate)
                } else {
                    // bounded Ullmann repair (Algorithm 1's UllmannRefine):
                    // restrict candidates to the mask and let refinement +
                    // a bounded backtrack fix the projection; memoized —
                    // it is deterministic in (mask, q, g)
                    match &repair_memo {
                        Some(memo) => memo.clone(),
                        None => {
                            let (repaired, stats) =
                                ullmann_find_first(mask, q, g, cfg.repair_budget);
                            out.repair_stats.nodes_visited += stats.nodes_visited;
                            out.repair_stats.refine_passes += stats.refine_passes;
                            out.repair_stats.refuted += stats.refuted;
                            repair_memo = Some(repaired.clone());
                            repaired
                        }
                    }
                };
                if let Some(mp) = found {
                    debug_assert!(mapping_is_feasible(&mp, q, g));
                    if !out.mappings.contains(&mp) {
                        out.mappings.push(mp);
                    }
                    if cfg.early_exit {
                        break 'epochs;
                    }
                }
            }
            let snapshots: Vec<MatF> = particles.iter().map(|p| p.s_local.clone()).collect();
            s_bar = elite_consensus(&snapshots, &fitnesses, cfg.elite);
        }
        out
    }
}

/// Random mask-respecting row-stochastic initialization.
fn init_particle_s(mask: &MatF, rng: &mut Rng) -> MatF {
    let mut s = MatF::from_fn(mask.rows(), mask.cols(), |_, _| rng.f32() + 1e-3);
    s.hadamard_assign(mask);
    s.row_normalize();
    s
}

/// Fused PSO step for one particle (the rust twin of the Pallas kernel).
fn step_particle(p: &mut Particle, s_star: &MatF, s_bar: &MatF, mask: &MatF, cfg: &PsoConfig, rng: &mut Rng) {
    let (n, m) = (p.s.rows(), p.s.cols());
    for i in 0..n {
        for j in 0..m {
            let r1 = rng.f32();
            let r2 = rng.f32();
            let r3 = rng.f32();
            let s = p.s[(i, j)];
            let vel = cfg.w * p.v[(i, j)]
                + cfg.c1 * r1 * (p.s_local[(i, j)] - s)
                + cfg.c2 * r2 * (s_star[(i, j)] - s)
                + cfg.c3 * r3 * (s_bar[(i, j)] - s);
            p.v[(i, j)] = vel;
            p.s[(i, j)] = (s + vel).clamp(0.0, 1.0);
        }
    }
    p.s.hadamard_assign(mask);
    p.s.row_normalize();
}

/// Hard rounding to an injective one-hot matrix (discrete ablation).
fn harden(s: &MatF, mask: &MatF) -> MatF {
    let assign = project_greedy(s, mask);
    let mut hard = MatF::zeros(s.rows(), s.cols());
    for (i, &mj) in assign.iter().enumerate() {
        if let Some(j) = mj {
            hard[(i, j)] = 1.0;
        }
    }
    hard
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_chain, NodeKind};
    use crate::matcher::{build_mask, ullmann::plant_embedding};

    fn chain_problem() -> (MatF, MatF, MatF) {
        let qd = gen_chain(4, NodeKind::Compute);
        let gd = gen_chain(8, NodeKind::Universal);
        let mask = build_mask(&qd, &gd);
        (mask, qd.adjacency(), gd.adjacency())
    }

    #[test]
    fn finds_chain_embedding() {
        let (mask, q, g) = chain_problem();
        let out = PsoMatcher::new(PsoConfig { seed: 7, ..Default::default() }).run(&mask, &q, &g);
        assert!(out.matched(), "no mapping found: best fitness {}", out.best_fitness);
        for mp in &out.mappings {
            assert!(mapping_is_feasible(mp, &q, &g));
        }
    }

    #[test]
    fn finds_planted_embeddings() {
        let mut rng = Rng::new(99);
        let mut found = 0;
        for trial in 0..10 {
            let (q, g, _) = plant_embedding(5, 12, 0.4, 0.15, &mut rng);
            let mask = MatF::full(5, 12, 1.0);
            let cfg = PsoConfig { seed: trial as u64, ..Default::default() };
            let out = PsoMatcher::new(cfg).run(&mask, &q, &g);
            if out.matched() {
                found += 1;
                assert!(mapping_is_feasible(&out.mappings[0], &q, &g));
            }
        }
        assert!(found >= 8, "only {found}/10 planted embeddings found");
    }

    #[test]
    fn trace_is_monotone_best_so_far() {
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { early_exit: false, epochs: 3, seed: 3, ..Default::default() };
        let out = PsoMatcher::new(cfg).run(&mask, &q, &g);
        for w in out.fitness_trace.windows(2) {
            assert!(w[1] >= w[0] - 1e-6, "trace decreased: {} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn relaxed_beats_discrete_in_final_fitness() {
        // Fig. 2b: continuous relaxation stabilizes the search.  Compare
        // mean best fitness across seeds.
        let mut rng = Rng::new(4242);
        let (q, g, _) = plant_embedding(6, 14, 0.4, 0.2, &mut rng);
        let mask = MatF::full(6, 14, 1.0);
        let run = |relaxed: bool, seed: u64| -> f32 {
            let cfg = PsoConfig {
                relaxed,
                early_exit: false,
                epochs: 2,
                steps: 12,
                seed,
                ..Default::default()
            };
            PsoMatcher::new(cfg).run(&mask, &q, &g).best_fitness
        };
        let relaxed_mean: f32 = (0..5).map(|s| run(true, s)).sum::<f32>() / 5.0;
        let discrete_mean: f32 = (0..5).map(|s| run(false, s)).sum::<f32>() / 5.0;
        assert!(
            relaxed_mean >= discrete_mean,
            "relaxed {relaxed_mean} worse than discrete {discrete_mean}"
        );
    }

    #[test]
    fn kernel_invocations_counted() {
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { early_exit: false, epochs: 2, steps: 4, particles: 8, seed: 1, ..Default::default() };
        let out = PsoMatcher::new(cfg).run(&mask, &q, &g);
        assert_eq!(out.steps_run, 8);
        assert_eq!(out.kernel_invocations, 64);
    }

    #[test]
    fn deterministic_given_seed() {
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { seed: 55, ..Default::default() };
        let a = PsoMatcher::new(cfg).run(&mask, &q, &g);
        let b = PsoMatcher::new(cfg).run(&mask, &q, &g);
        assert_eq!(a.mappings, b.mappings);
        assert_eq!(a.fitness_trace, b.fitness_trace);
    }
}
