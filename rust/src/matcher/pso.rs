//! Native multi-particle optimizer (Algorithm 1) — the rust twin of the
//! AOT artifact, plus the *discrete* ablation of Fig. 2b.
//!
//! Two uses:
//! * the hardware-model execution path: the simulator charges the
//!   accelerator for exactly the work this implementation performs
//!   (steps × particles fused kernels, see [`super::cost`]);
//! * the default epoch backend when no PJRT artifact is available
//!   ([`crate::runtime::NativeEpochBackend`] drives the same per-particle
//!   epoch at the artifact's padded dims).
//!
//! ## Hot-path layout
//!
//! Swarm state is struct-of-arrays: one flat buffer per field
//! (`s`/`v`/`s_local` stacked `particles × n·m`, `f_local` and the
//! per-step fitness record per particle), allocated once per episode and
//! reused every epoch. Fitness is the sparse [`FitnessKernel`] (CSR edge
//! iteration, per-worker [`FitnessScratch`]), so the fused step loop is
//! clone-free and allocation-free in steady state — the discrete
//! ablation (`relaxed: false`) is the one exception, its projection
//! allocates per step and is not a production path.
//!
//! ## Parallel structure
//!
//! The epoch mirrors the paper's data-dependency split: within one epoch
//! every particle runs its K fused steps against the *frozen* attractors
//! (S*, S̄) with no cross-particle dependency, so the per-particle work
//! fans out across threads (`std::thread::scope`, one forked RNG stream
//! and one scratch arena per worker). Everything that couples particles
//! — the global best S*, the elite-consensus S̄, projection + Ullmann
//! verification — happens at the epoch barrier on the (modeled) global
//! controller. Serial and threaded execution are bit-identical for a
//! given seed: particle initialization and RNG forks consume the master
//! stream in particle order, and the trace merge runs on one thread.

use crate::util::json::Json;
use crate::util::{row_normalize_in_place, MatF, Rng};

use super::consensus::elite_consensus_flat;
use super::fitness::{mapping_is_feasible_csr, FitnessKernel, FitnessScratch};
use super::projection::project_greedy_flat;
use super::ullmann::{ullmann_find_first, UllmannStats};
use super::Mapping;

/// PSO hyperparameters (defaults follow the standard constricted swarm
/// plus the paper's consensus term).
#[derive(Clone, Copy, Debug)]
pub struct PsoConfig {
    /// Particles per epoch (mapped 1:1 onto engines).
    pub particles: usize,
    /// Outer epochs T (particles re-initialized each epoch, Algorithm 1
    /// line 4; S*, S̄ and the feasible set persist).
    pub epochs: usize,
    /// Fused inner steps K per epoch.
    pub steps: usize,
    /// Inertia.
    pub w: f32,
    /// Cognitive (particle-local best) pull.
    pub c1: f32,
    /// Social (global best) pull.
    pub c2: f32,
    /// Consensus pull (the paper's addition).
    pub c3: f32,
    /// Elites fused into the consensus matrix.
    pub elite: usize,
    /// Continuous relaxation on (true = IMMSched; false = the unstable
    /// discrete coupling of Fig. 2b).
    pub relaxed: bool,
    /// Stop at the first feasible mapping (production) or keep searching
    /// (benchmarks that want the full trace).
    pub early_exit: bool,
    /// Node budget for the bounded Ullmann repair of projected
    /// candidates.
    pub repair_budget: u64,
    /// Worker threads for the intra-epoch particle fan-out (0 = one per
    /// available core, capped at the particle count). Only consulted on
    /// the threaded path.
    pub threads: usize,
    pub seed: u64,
}

impl Default for PsoConfig {
    fn default() -> Self {
        Self {
            particles: 16,
            epochs: 8,
            steps: 8,
            w: 0.72,
            c1: 1.49,
            c2: 1.49,
            c3: 0.60,
            elite: 4,
            relaxed: true,
            early_exit: true,
            // Algorithm 1's UllmannRefine step needs headroom on branchy
            // queries (UNet skip tiles take ~10k nodes); the controller
            // is charged for every expanded node in the cost model.
            repair_budget: 100_000,
            threads: 0,
            seed: 0x1535EED,
        }
    }
}

/// Epoch-barrier checkpoint of one episode's swarm attractors — the
/// persistent state a cancelled episode hands back so a resubmission
/// warm-starts instead of re-exploring from scratch (the cluster's
/// `ResumeStore` keys these by request id).
///
/// Everything the epoch loop carries *across* barriers is here: the
/// global best S*, the elite-consensus S̄, the best fitness, the epochs
/// already burned, the feasible set found so far, and the master RNG at
/// the barrier.  Restoring all of it makes a resumed episode
/// **bit-identical** to the uninterrupted run continued from the same
/// barrier (per-particle state is *not* needed: Algorithm 1 line 4
/// re-initializes particles fresh every epoch from the master stream).
///
/// S*/S̄ are stored unpadded (n×m row-major) so a snapshot survives
/// migration between shards whose backends pad to different size
/// classes.
#[derive(Clone, Debug, PartialEq)]
pub struct SwarmSnapshot {
    /// Query vertex count the snapshot was taken for.
    pub n: usize,
    /// Target vertex count the snapshot was taken for.
    pub m: usize,
    /// Unpadded n×m global-best relaxed mapping S* at the barrier.
    pub s_star: Vec<f32>,
    /// Unpadded n×m elite-consensus matrix S̄ at the barrier.
    pub s_bar: Vec<f32>,
    /// Best fitness reached before the barrier.
    pub best_fitness: f32,
    /// Whether any epoch actually improved S* (false = S* is still the
    /// cold init and the restore must not treat it as a real attractor).
    pub have_star: bool,
    /// Absolute epoch index to resume from (epochs completed so far).
    pub epochs_done: usize,
    /// Master RNG state at the barrier — the resumed episode replays the
    /// exact particle-init stream the uninterrupted run would have drawn.
    pub rng: Rng,
    /// Feasible mappings already found (non-`early_exit` episodes).
    pub mappings: Vec<Mapping>,
}

impl SwarmSnapshot {
    /// Whether this snapshot belongs to an (n, m)-shaped problem.  A
    /// mismatched snapshot is ignored (cold start), never an error: the
    /// caller may have resubmitted a different problem under an old id.
    pub fn fits(&self, n: usize, m: usize) -> bool {
        self.n == n && self.m == m && self.s_star.len() == n * m && self.s_bar.len() == n * m
    }

    /// Serialize for the shard wire protocol.  Encodings are
    /// **bit-exact**, never lossy-pretty (see the codec primitives in
    /// [`crate::util::json`]): f32 values travel as their u32 bit
    /// patterns (so ±inf/NaN and every subnormal survive — a JSON float
    /// would turn them into `null`) and the 64-bit RNG words as hex
    /// strings (f64-backed JSON numbers lose integer fidelity past
    /// 2^53).  A snapshot that crosses a process boundary through this
    /// codec resumes bit-identically to a same-process resume.
    pub fn to_json(&self) -> Json {
        use crate::util::json::{encode_opt_indices, f32_bits, f32_bits_arr, hex_u64};
        let mappings = self.mappings.iter().map(|mp| encode_opt_indices(mp)).collect();
        Json::obj(vec![
            ("n", Json::from(self.n)),
            ("m", Json::from(self.m)),
            ("s_star", f32_bits_arr(&self.s_star)),
            ("s_bar", f32_bits_arr(&self.s_bar)),
            ("best_fitness", f32_bits(self.best_fitness)),
            ("have_star", Json::from(self.have_star)),
            ("epochs_done", Json::from(self.epochs_done)),
            ("rng", Json::Arr(self.rng.state().iter().map(|&w| hex_u64(w)).collect())),
            ("mappings", Json::Arr(mappings)),
        ])
    }

    /// Inverse of [`Self::to_json`].  Shape inconsistencies (S*/S̄ not
    /// n×m, out-of-cap dimensions, an impossible all-zero RNG state)
    /// are decode errors: a malformed snapshot must be rejected at the
    /// boundary, not warm-start a subtly corrupted episode.
    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        use crate::util::json::{
            decode_opt_indices, get_bool, get_dim, get_f32_bits, get_f32_bits_arr, get_usize,
        };
        use anyhow::Context as _;
        let (n, m) = (get_dim(v, "n")?, get_dim(v, "m")?);
        let cells = n.checked_mul(m).context("snapshot shape overflows")?;
        let s_star = get_f32_bits_arr(v, "s_star")?;
        let s_bar = get_f32_bits_arr(v, "s_bar")?;
        anyhow::ensure!(
            s_star.len() == cells && s_bar.len() == cells,
            "snapshot S*/S̄ shape mismatch: {}x{} vs {} / {} entries",
            n,
            m,
            s_star.len(),
            s_bar.len()
        );
        let rng_words = v
            .get("rng")
            .and_then(Json::as_array)
            .context("snapshot missing rng state")?;
        anyhow::ensure!(rng_words.len() == 4, "rng state must be 4 words");
        let mut state = [0u64; 4];
        for (slot, w) in state.iter_mut().zip(rng_words) {
            let hex = w.as_str().context("rng word must be a hex string")?;
            *slot = u64::from_str_radix(hex, 16)
                .with_context(|| format!("bad rng word {hex:?}"))?;
        }
        // the all-zero state is xoshiro's fixed point — no legitimate
        // stream ever reaches it, so it can only mean corruption
        anyhow::ensure!(state != [0; 4], "snapshot rng state is all-zero (corrupt)");
        let mappings = v
            .get("mappings")
            .and_then(Json::as_array)
            .context("snapshot missing mappings")?
            .iter()
            .map(decode_opt_indices)
            .collect::<anyhow::Result<Vec<Mapping>>>()?;
        // the feasible set must actually fit the problem shape — a
        // garbage mapping that decoded "successfully" would otherwise
        // surface as a matched() response pointing at vertices the
        // target graph does not have
        for mp in &mappings {
            anyhow::ensure!(mp.len() == n, "snapshot mapping has {} slots, expected {n}", mp.len());
            for &slot in mp {
                if let Some(vtx) = slot {
                    anyhow::ensure!(vtx < m, "snapshot mapping targets vertex {vtx} >= {m}");
                }
            }
        }
        Ok(Self {
            n,
            m,
            s_star,
            s_bar,
            best_fitness: get_f32_bits(v, "best_fitness")?,
            have_star: get_bool(v, "have_star")?,
            epochs_done: get_usize(v, "epochs_done")?,
            rng: Rng::from_state(state),
            mappings,
        })
    }
}

/// Search outcome + enough telemetry to drive the figures.
#[derive(Clone, Debug, Default)]
pub struct PsoOutcome {
    /// Feasible mappings found (deduplicated).
    pub mappings: Vec<Mapping>,
    /// Best fitness reached (0 = perfect relaxed embedding).
    pub best_fitness: f32,
    /// Best-so-far fitness after every fused step (Fig. 2b traces).
    pub fitness_trace: Vec<f32>,
    /// Mean *current* fitness across particles after every fused step —
    /// the non-monotone signal whose oscillation Fig. 2b plots as
    /// "search stability".
    pub mean_fitness_trace: Vec<f32>,
    /// Epochs actually run.
    pub epochs_run: usize,
    /// Total fused steps executed (each = one kernel launch per particle).
    pub steps_run: usize,
    /// Ullmann repair statistics.
    pub repair_stats: UllmannStats,
    /// Fused step kernel invocations (steps_run × particles) — the unit
    /// the cost model charges.
    pub kernel_invocations: u64,
}

impl PsoOutcome {
    pub fn matched(&self) -> bool {
        !self.mappings.is_empty()
    }
}

/// The velocity-update coefficients one fused step needs.
#[derive(Clone, Copy, Debug)]
pub(crate) struct StepParams {
    pub w: f32,
    pub c1: f32,
    pub c2: f32,
    pub c3: f32,
    pub relaxed: bool,
}

impl StepParams {
    pub(crate) fn from_config(cfg: &PsoConfig) -> Self {
        Self { w: cfg.w, c1: cfg.c1, c2: cfg.c2, c3: cfg.c3, relaxed: cfg.relaxed }
    }
}

/// Minimum per-epoch work (particles × steps × n × m elements) before
/// the auto path spawns scoped threads: below this, per-epoch thread
/// spawn/join dominates the few microseconds of arithmetic and the
/// serial loop is faster on the interrupt hot path. `run_threaded`
/// bypasses the threshold (tests/benches force the fan-out).
pub(crate) const PARALLEL_WORK_THRESHOLD: usize = 1 << 15;

/// Resolve the worker count for one epoch fan-out. Only touches
/// `available_parallelism` when an explicit thread count is absent, so
/// pinned single-worker runs stay syscall- and allocation-free.
pub(crate) fn epoch_workers(threaded: bool, threads: usize, particles: usize) -> usize {
    if !threaded || particles <= 1 {
        return 1;
    }
    let requested = if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    };
    requested.clamp(1, particles)
}

/// Disjoint mutable views over one epoch's struct-of-arrays swarm state:
/// particle p owns `s[p·nm..(p+1)·nm]`, `fits[p·steps..(p+1)·steps]`,
/// `f_local[p]`, `rngs[p]`. The caller (matcher arena or backend
/// workspace) owns the backing buffers; nothing here allocates.
pub(crate) struct EpochSlices<'a> {
    pub s: &'a mut [f32],
    pub v: &'a mut [f32],
    pub s_local: &'a mut [f32],
    pub f_local: &'a mut [f32],
    pub fits: &'a mut [f32],
    pub rngs: &'a mut [Rng],
}

/// One particle's slice of the swarm state.
struct ParticleSlices<'a> {
    s: &'a mut [f32],
    v: &'a mut [f32],
    s_local: &'a mut [f32],
    f_local: &'a mut f32,
    fits: &'a mut [f32],
    rng: &'a mut Rng,
}

/// Run every particle's K-step epoch, serially or fanned out over scoped
/// threads. Particles are fully independent here (frozen attractors,
/// private RNG streams and per-worker scratch), so any worker count
/// produces identical results.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_epoch_slices(
    slices: EpochSlices<'_>,
    scratch: &mut [FitnessScratch],
    kernel: &FitnessKernel,
    s_star: &[f32],
    s_bar: &[f32],
    mask: &[f32],
    steps: usize,
    params: &StepParams,
    workers: usize,
) {
    let EpochSlices { s, v, s_local, f_local, fits, rngs } = slices;
    let particles = rngs.len();
    let (n, m) = (kernel.n(), kernel.m());
    let nm = n * m;
    debug_assert_eq!(s.len(), particles * nm);
    debug_assert_eq!(v.len(), particles * nm);
    debug_assert_eq!(s_local.len(), particles * nm);
    debug_assert_eq!(f_local.len(), particles);
    debug_assert_eq!(fits.len(), particles * steps);
    debug_assert_eq!(s_star.len(), nm);
    debug_assert_eq!(s_bar.len(), nm);
    debug_assert_eq!(mask.len(), nm);
    if particles == 0 || steps == 0 || nm == 0 {
        return;
    }
    let workers = workers.clamp(1, particles);
    assert!(scratch.len() >= workers, "need one scratch arena per worker");

    if workers == 1 {
        let arena = &mut scratch[0];
        for p in 0..particles {
            run_one_particle(
                ParticleSlices {
                    s: &mut s[p * nm..(p + 1) * nm],
                    v: &mut v[p * nm..(p + 1) * nm],
                    s_local: &mut s_local[p * nm..(p + 1) * nm],
                    f_local: &mut f_local[p],
                    fits: &mut fits[p * steps..(p + 1) * steps],
                    rng: &mut rngs[p],
                },
                arena,
                kernel,
                s_star,
                s_bar,
                mask,
                params,
            );
        }
        return;
    }

    // worker slabs: ceil(particles / workers) particles each, carved out
    // of every buffer with the same chunk count so slab p of one buffer
    // pairs with slab p of the others
    let per = (particles + workers - 1) / workers;
    std::thread::scope(|scope| {
        for (((((s_slab, v_slab), sl_slab), fl_slab), ft_slab), (rg_slab, arena)) in s
            .chunks_mut(per * nm)
            .zip(v.chunks_mut(per * nm))
            .zip(s_local.chunks_mut(per * nm))
            .zip(f_local.chunks_mut(per))
            .zip(fits.chunks_mut(per * steps))
            .zip(rngs.chunks_mut(per).zip(scratch.iter_mut()))
        {
            scope.spawn(move || {
                for (p, rng) in rg_slab.iter_mut().enumerate() {
                    run_one_particle(
                        ParticleSlices {
                            s: &mut s_slab[p * nm..(p + 1) * nm],
                            v: &mut v_slab[p * nm..(p + 1) * nm],
                            s_local: &mut sl_slab[p * nm..(p + 1) * nm],
                            f_local: &mut fl_slab[p],
                            fits: &mut ft_slab[p * steps..(p + 1) * steps],
                            rng,
                        },
                        arena,
                        kernel,
                        s_star,
                        s_bar,
                        mask,
                        params,
                    );
                }
            });
        }
    });
}

/// One particle's full epoch: K fused steps with local-best tracking.
/// The particle's *current* fitness after every step lands in its `fits`
/// slice (the per-step trace the barrier merges).
fn run_one_particle(
    p: ParticleSlices<'_>,
    scratch: &mut FitnessScratch,
    kernel: &FitnessKernel,
    s_star: &[f32],
    s_bar: &[f32],
    mask: &[f32],
    params: &StepParams,
) {
    let ParticleSlices { s, v, s_local, f_local, fits, rng } = p;
    let (n, m) = (kernel.n(), kernel.m());
    for slot in fits.iter_mut() {
        step_particle(s, v, s_local, s_star, s_bar, mask, m, params, rng);
        let f = if params.relaxed {
            kernel.eval(s, scratch)
        } else {
            // discrete coupling (Fig. 2b ablation): evaluate on the
            // hard-rounded one-hot projection of S (the projection
            // allocates — ablation only, never the production path)
            harden_into(s, mask, n, m, scratch.hard_mut());
            kernel.eval_hard(scratch)
        };
        *slot = f;
        if f > *f_local {
            *f_local = f;
            s_local.copy_from_slice(s);
        }
    }
}

/// Fused PSO step for one particle (the rust twin of the Pallas kernel).
/// Flat slice iteration in row-major order — the RNG is consumed three
/// draws per element exactly as the elementwise kernel folds its key.
#[allow(clippy::too_many_arguments)]
fn step_particle(
    s: &mut [f32],
    v: &mut [f32],
    s_local: &[f32],
    s_star: &[f32],
    s_bar: &[f32],
    mask: &[f32],
    cols: usize,
    params: &StepParams,
    rng: &mut Rng,
) {
    for ((((s_ij, v_ij), &l_ij), &star_ij), &bar_ij) in
        s.iter_mut().zip(v.iter_mut()).zip(s_local).zip(s_star).zip(s_bar)
    {
        let r1 = rng.f32();
        let r2 = rng.f32();
        let r3 = rng.f32();
        let cur = *s_ij;
        let vel = params.w * *v_ij
            + params.c1 * r1 * (l_ij - cur)
            + params.c2 * r2 * (star_ij - cur)
            + params.c3 * r3 * (bar_ij - cur);
        *v_ij = vel;
        *s_ij = (cur + vel).clamp(0.0, 1.0);
    }
    for (x, &mk) in s.iter_mut().zip(mask) {
        *x *= mk;
    }
    row_normalize_in_place(s, cols);
}

/// Random mask-respecting row-stochastic initialization of one flat n×m
/// particle (consumes exactly n·m draws regardless of the mask, keeping
/// the master stream aligned for any mask).
fn init_particle(s: &mut [f32], mask: &[f32], cols: usize, rng: &mut Rng) {
    for (x, &mk) in s.iter_mut().zip(mask) {
        *x = (rng.f32() + 1e-3) * mk;
    }
    row_normalize_in_place(s, cols);
}

/// Hard rounding to an injective one-hot matrix, written into `hard`
/// (discrete ablation).
fn harden_into(s: &[f32], mask: &[f32], n: usize, m: usize, hard: &mut [f32]) {
    let assign = project_greedy_flat(s, mask, n, m);
    hard.fill(0.0);
    for (i, &mj) in assign.iter().enumerate() {
        if let Some(j) = mj {
            hard[i * m + j] = 1.0;
        }
    }
}

/// Episode-lifetime swarm storage: every per-particle buffer the epoch
/// loop touches, allocated once up front. Epochs re-initialize in place.
struct SwarmArena {
    s: Vec<f32>,
    v: Vec<f32>,
    s_local: Vec<f32>,
    f_local: Vec<f32>,
    fits: Vec<f32>,
    rngs: Vec<Rng>,
    scratch: Vec<FitnessScratch>,
}

impl SwarmArena {
    fn new(particles: usize, n: usize, m: usize, steps: usize, workers: usize) -> Self {
        let nm = n * m;
        Self {
            s: vec![0.0; particles * nm],
            v: vec![0.0; particles * nm],
            s_local: vec![0.0; particles * nm],
            f_local: vec![f32::NEG_INFINITY; particles],
            fits: vec![f32::NEG_INFINITY; particles * steps],
            rngs: Vec::with_capacity(particles),
            scratch: (0..workers.max(1)).map(|_| FitnessScratch::new(n, m)).collect(),
        }
    }
}

/// The native matcher.
pub struct PsoMatcher {
    pub config: PsoConfig,
}

impl PsoMatcher {
    pub fn new(config: PsoConfig) -> Self {
        Self { config }
    }

    /// Run Algorithm 1 on (mask, Q, G). Uses the threaded epoch when the
    /// `parallel` feature is on, more than one particle is configured,
    /// and the per-epoch work is large enough to amortize thread spawns;
    /// results are identical to [`Self::run_serial`] either way.
    pub fn run(&self, mask: &MatF, q: &MatF, g: &MatF) -> PsoOutcome {
        self.run_impl(mask, q, g, self.auto_threaded(mask), None, &mut || false).0
    }

    /// Force the serial per-particle loop (baseline / determinism tests).
    pub fn run_serial(&self, mask: &MatF, q: &MatF, g: &MatF) -> PsoOutcome {
        self.run_impl(mask, q, g, false, None, &mut || false).0
    }

    /// Force the threaded epoch regardless of the `parallel` feature.
    pub fn run_threaded(&self, mask: &MatF, q: &MatF, g: &MatF) -> PsoOutcome {
        self.run_impl(mask, q, g, true, None, &mut || false).0
    }

    /// Interruptible, resumable episode — the warm-start entry point.
    ///
    /// * `resume`: warm-start from a prior barrier snapshot.  A snapshot
    ///   whose shape does not [`SwarmSnapshot::fits`] the problem is
    ///   ignored (cold start).
    /// * `interrupted`: polled once per epoch *barrier* (never
    ///   mid-kernel); returning `true` stops the episode there.
    ///
    /// Returns the outcome plus the barrier snapshot when interrupted
    /// short of the epoch budget (`None` when the episode completed).
    /// Guarantee: cold-run epochs `0..E` ≡ (run interrupted at barrier
    /// `t`, then resumed from its snapshot) — the concatenated fitness
    /// traces, the mappings and the best fitness are bit-identical,
    /// because the snapshot carries the master RNG alongside S*/S̄.
    pub fn run_resumable(
        &self,
        mask: &MatF,
        q: &MatF,
        g: &MatF,
        resume: Option<&SwarmSnapshot>,
        interrupted: &mut dyn FnMut() -> bool,
    ) -> (PsoOutcome, Option<SwarmSnapshot>) {
        self.run_impl(mask, q, g, self.auto_threaded(mask), resume, interrupted)
    }

    /// Whether the auto path fans the epoch out over scoped threads.
    fn auto_threaded(&self, mask: &MatF) -> bool {
        let work = self.config.particles * self.config.steps * mask.rows() * mask.cols();
        cfg!(feature = "parallel") && self.config.particles > 1 && work >= PARALLEL_WORK_THRESHOLD
    }

    fn run_impl(
        &self,
        mask: &MatF,
        q: &MatF,
        g: &MatF,
        threaded: bool,
        resume: Option<&SwarmSnapshot>,
        interrupted: &mut dyn FnMut() -> bool,
    ) -> (PsoOutcome, Option<SwarmSnapshot>) {
        let cfg = &self.config;
        let (n, m) = (mask.rows(), mask.cols());
        assert_eq!(q.rows(), n);
        assert_eq!(g.rows(), m);
        let mut out = PsoOutcome { best_fitness: f32::NEG_INFINITY, ..Default::default() };
        // Degenerate configs (no particles, no epochs, no steps) have
        // nothing to search: return the empty outcome instead of
        // panicking downstream (elite consensus asserts on empty input,
        // zero steps would feed NEG_INFINITY fitnesses to the consensus).
        if cfg.particles == 0 || cfg.epochs == 0 || cfg.steps == 0 {
            return (out, None);
        }
        let nm = n * m;
        let mask_flat = mask.as_slice();
        let params = StepParams::from_config(cfg);
        let kernel = FitnessKernel::new(q, g);
        let workers = epoch_workers(threaded, cfg.threads, cfg.particles);

        // episode-lifetime state: allocated once, reused every epoch.
        // Warm start: the snapshot replaces the cold attractor init *and*
        // the master RNG, so the resumed epochs replay the exact stream
        // the uninterrupted run would have drawn.
        let mut arena = SwarmArena::new(cfg.particles, n, m, cfg.steps, workers);
        let resume = resume.filter(|s| s.fits(n, m));
        let (mut rng, mut s_star, mut s_bar, mut f_star, start_epoch) = match resume {
            Some(snap) => {
                out.best_fitness = snap.best_fitness;
                out.mappings = snap.mappings.clone();
                let f_star =
                    if snap.have_star { snap.best_fitness } else { f32::NEG_INFINITY };
                (
                    snap.rng.clone(),
                    snap.s_star.clone(),
                    snap.s_bar.clone(),
                    f_star,
                    snap.epochs_done,
                )
            }
            None => {
                let mut rng = Rng::new(cfg.seed);
                let mut s_star = vec![0.0f32; nm];
                init_particle(&mut s_star, mask_flat, m, &mut rng);
                let s_bar = s_star.clone();
                (rng, s_star, s_bar, f32::NEG_INFINITY, 0)
            }
        };
        // deterministic in (mask, q, g) — run at most once per episode
        let mut repair_memo: Option<Option<Mapping>> = None;

        'epochs: for t in start_epoch..cfg.epochs {
            // epoch barrier: the episode's interruption point (cluster
            // preemption, deadline expiry, epoch-quota slicing)
            if interrupted() {
                return (
                    out.clone(),
                    Some(SwarmSnapshot {
                        n,
                        m,
                        s_star,
                        s_bar,
                        best_fitness: out.best_fitness,
                        have_star: f_star > f32::NEG_INFINITY,
                        epochs_done: t,
                        rng,
                        mappings: out.mappings,
                    }),
                );
            }
            out.epochs_run += 1;
            // line 4: fresh particles each epoch. Initialization and the
            // per-particle RNG forks consume the master stream in
            // particle order, so serial and threaded runs are identical.
            arena.rngs.clear();
            for i in 0..cfg.particles {
                init_particle(&mut arena.s[i * nm..(i + 1) * nm], mask_flat, m, &mut rng);
                arena.rngs.push(rng.fork(i as u64));
            }
            arena.s_local.copy_from_slice(&arena.s);
            arena.v.fill(0.0);
            arena.f_local.fill(f32::NEG_INFINITY);

            // the fused epoch: K steps per particle against the frozen
            // (S*, S̄) attractors — no cross-particle dependency until
            // the barrier below
            run_epoch_slices(
                EpochSlices {
                    s: &mut arena.s,
                    v: &mut arena.v,
                    s_local: &mut arena.s_local,
                    f_local: &mut arena.f_local,
                    fits: &mut arena.fits,
                    rngs: &mut arena.rngs,
                },
                &mut arena.scratch,
                &kernel,
                &s_star,
                &s_bar,
                mask_flat,
                cfg.steps,
                &params,
                workers,
            );

            // barrier part 1: merge the per-particle traces (single
            // thread, particle order — deterministic)
            let f_star_before = f_star;
            for k in 0..cfg.steps {
                out.steps_run += 1;
                out.kernel_invocations += cfg.particles as u64;
                let mut f_sum = 0.0f32;
                let mut step_best = f32::NEG_INFINITY;
                for p in 0..cfg.particles {
                    let f = arena.fits[p * cfg.steps + k];
                    f_sum += f;
                    step_best = step_best.max(f);
                }
                f_star = f_star.max(step_best);
                out.fitness_trace.push(f_star);
                out.mean_fitness_trace.push(f_sum / cfg.particles as f32);
            }
            out.best_fitness = out.best_fitness.max(f_star);

            // barrier part 2: fold the particle-local bests into S*
            // (copy into the episode-lifetime buffer, no clone)
            let mut best_idx: Option<usize> = None;
            let mut best_f = f_star_before;
            for (i, &f) in arena.f_local.iter().enumerate() {
                if f > best_f {
                    best_f = f;
                    best_idx = Some(i);
                }
            }
            if let Some(i) = best_idx {
                s_star.copy_from_slice(&arena.s_local[i * nm..(i + 1) * nm]);
            }

            // lines 19-25: project, refine, verify, fuse consensus
            for p in 0..cfg.particles {
                let s_view = &arena.s[p * nm..(p + 1) * nm];
                let candidate = project_greedy_flat(s_view, mask_flat, n, m);
                let found = if mapping_is_feasible_csr(&candidate, kernel.q_edges(), g) {
                    Some(candidate)
                } else {
                    // bounded Ullmann repair (Algorithm 1's UllmannRefine):
                    // restrict candidates to the mask and let refinement +
                    // a bounded backtrack fix the projection; memoized —
                    // it is deterministic in (mask, q, g)
                    match &repair_memo {
                        Some(memo) => memo.clone(),
                        None => {
                            let (repaired, stats) =
                                ullmann_find_first(mask, q, g, cfg.repair_budget);
                            out.repair_stats.nodes_visited += stats.nodes_visited;
                            out.repair_stats.refine_passes += stats.refine_passes;
                            out.repair_stats.refuted += stats.refuted;
                            repair_memo = Some(repaired.clone());
                            repaired
                        }
                    }
                };
                if let Some(mp) = found {
                    debug_assert!(super::fitness::mapping_is_feasible(&mp, q, g));
                    if !out.mappings.contains(&mp) {
                        out.mappings.push(mp);
                    }
                    if cfg.early_exit {
                        break 'epochs;
                    }
                }
            }
            elite_consensus_flat(
                &arena.s_local,
                cfg.particles,
                n,
                m,
                &arena.f_local,
                cfg.elite,
                &mut s_bar,
            );
        }
        (out, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_chain, NodeKind};
    use crate::matcher::fitness::mapping_is_feasible;
    use crate::matcher::{build_mask, ullmann::plant_embedding};

    fn chain_problem() -> (MatF, MatF, MatF) {
        let qd = gen_chain(4, NodeKind::Compute);
        let gd = gen_chain(8, NodeKind::Universal);
        let mask = build_mask(&qd, &gd);
        (mask, qd.adjacency(), gd.adjacency())
    }

    #[test]
    fn finds_chain_embedding() {
        let (mask, q, g) = chain_problem();
        let out = PsoMatcher::new(PsoConfig { seed: 7, ..Default::default() }).run(&mask, &q, &g);
        assert!(out.matched(), "no mapping found: best fitness {}", out.best_fitness);
        for mp in &out.mappings {
            assert!(mapping_is_feasible(mp, &q, &g));
        }
    }

    #[test]
    fn finds_planted_embeddings() {
        let mut rng = Rng::new(99);
        let mut found = 0;
        for trial in 0..10 {
            let (q, g, _) = plant_embedding(5, 12, 0.4, 0.15, &mut rng);
            let mask = MatF::full(5, 12, 1.0);
            let cfg = PsoConfig { seed: trial as u64, ..Default::default() };
            let out = PsoMatcher::new(cfg).run(&mask, &q, &g);
            if out.matched() {
                found += 1;
                assert!(mapping_is_feasible(&out.mappings[0], &q, &g));
            }
        }
        assert!(found >= 8, "only {found}/10 planted embeddings found");
    }

    #[test]
    fn trace_is_monotone_best_so_far() {
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { early_exit: false, epochs: 3, seed: 3, ..Default::default() };
        let out = PsoMatcher::new(cfg).run(&mask, &q, &g);
        for w in out.fitness_trace.windows(2) {
            assert!(w[1] >= w[0] - 1e-6, "trace decreased: {} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn relaxed_beats_discrete_in_final_fitness() {
        // Fig. 2b: continuous relaxation stabilizes the search.  Compare
        // mean best fitness across seeds.
        let mut rng = Rng::new(4242);
        let (q, g, _) = plant_embedding(6, 14, 0.4, 0.2, &mut rng);
        let mask = MatF::full(6, 14, 1.0);
        let run = |relaxed: bool, seed: u64| -> f32 {
            let cfg = PsoConfig {
                relaxed,
                early_exit: false,
                epochs: 2,
                steps: 12,
                seed,
                ..Default::default()
            };
            PsoMatcher::new(cfg).run(&mask, &q, &g).best_fitness
        };
        let relaxed_mean: f32 = (0..5).map(|s| run(true, s)).sum::<f32>() / 5.0;
        let discrete_mean: f32 = (0..5).map(|s| run(false, s)).sum::<f32>() / 5.0;
        assert!(
            relaxed_mean >= discrete_mean,
            "relaxed {relaxed_mean} worse than discrete {discrete_mean}"
        );
    }

    #[test]
    fn kernel_invocations_counted() {
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig {
            early_exit: false,
            epochs: 2,
            steps: 4,
            particles: 8,
            seed: 1,
            ..Default::default()
        };
        let out = PsoMatcher::new(cfg).run(&mask, &q, &g);
        assert_eq!(out.steps_run, 8);
        assert_eq!(out.kernel_invocations, 64);
    }

    #[test]
    fn deterministic_given_seed() {
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { seed: 55, ..Default::default() };
        let a = PsoMatcher::new(cfg).run(&mask, &q, &g);
        let b = PsoMatcher::new(cfg).run(&mask, &q, &g);
        assert_eq!(a.mappings, b.mappings);
        assert_eq!(a.fitness_trace, b.fitness_trace);
    }

    #[test]
    fn threaded_epoch_matches_serial() {
        // the headline determinism guarantee: the threaded epoch is
        // bit-identical to the serial per-particle loop
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { early_exit: false, epochs: 3, seed: 21, ..Default::default() };
        let matcher = PsoMatcher::new(cfg);
        let a = matcher.run_serial(&mask, &q, &g);
        let b = matcher.run_threaded(&mask, &q, &g);
        assert_eq!(a.mappings, b.mappings);
        assert_eq!(a.fitness_trace, b.fitness_trace);
        assert_eq!(a.mean_fitness_trace, b.mean_fitness_trace);
        assert_eq!(a.best_fitness, b.best_fitness);
        assert_eq!(a.steps_run, b.steps_run);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let (mask, q, g) = chain_problem();
        let base = PsoConfig { early_exit: false, epochs: 2, seed: 33, ..Default::default() };
        let one = PsoMatcher::new(PsoConfig { threads: 1, ..base }).run_threaded(&mask, &q, &g);
        let three = PsoMatcher::new(PsoConfig { threads: 3, ..base }).run_threaded(&mask, &q, &g);
        assert_eq!(one.fitness_trace, three.fitness_trace);
        assert_eq!(one.mappings, three.mappings);
    }

    /// The warm-start guarantee: interrupt at an epoch barrier, resume
    /// from the snapshot, and the continued run is bit-identical to the
    /// uninterrupted one — traces concatenate exactly, mappings and best
    /// fitness agree, and the epoch counts add up.
    #[test]
    fn resume_is_bit_identical_to_uninterrupted_run() {
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { early_exit: false, epochs: 6, seed: 77, ..Default::default() };
        let matcher = PsoMatcher::new(cfg);
        let (cold, none) = matcher.run_resumable(&mask, &q, &g, None, &mut || false);
        assert!(none.is_none(), "completed episode must not yield a snapshot");

        for barrier in [1usize, 3, 5] {
            let mut checks = 0usize;
            let (head, snap) = matcher.run_resumable(&mask, &q, &g, None, &mut || {
                checks += 1;
                checks > barrier
            });
            let snap = snap.expect("interrupted episode must yield a snapshot");
            assert_eq!(snap.epochs_done, barrier);
            assert_eq!(head.epochs_run, barrier);
            let (tail, done) = matcher.run_resumable(&mask, &q, &g, Some(&snap), &mut || false);
            assert!(done.is_none());
            assert_eq!(head.epochs_run + tail.epochs_run, cold.epochs_run, "barrier {barrier}");
            let mut trace = head.fitness_trace.clone();
            trace.extend_from_slice(&tail.fitness_trace);
            assert_eq!(trace, cold.fitness_trace, "barrier {barrier}: traces diverge");
            let mut mean = head.mean_fitness_trace.clone();
            mean.extend_from_slice(&tail.mean_fitness_trace);
            assert_eq!(mean, cold.mean_fitness_trace, "barrier {barrier}");
            assert_eq!(tail.mappings, cold.mappings, "barrier {barrier}: feasible sets diverge");
            assert_eq!(tail.best_fitness, cold.best_fitness, "barrier {barrier}");
        }
    }

    /// A snapshot for a different problem shape is ignored — the episode
    /// cold-starts instead of corrupting the swarm state.
    #[test]
    fn mismatched_snapshot_is_ignored() {
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { early_exit: false, epochs: 3, seed: 5, ..Default::default() };
        let matcher = PsoMatcher::new(cfg);
        let cold = matcher.run(&mask, &q, &g);
        let bogus = SwarmSnapshot {
            n: 2,
            m: 3,
            s_star: vec![0.5; 6],
            s_bar: vec![0.5; 6],
            best_fitness: -1.0,
            have_star: true,
            epochs_done: 1,
            rng: Rng::new(1),
            mappings: Vec::new(),
        };
        let (out, _) = matcher.run_resumable(&mask, &q, &g, Some(&bogus), &mut || false);
        assert_eq!(out.fitness_trace, cold.fitness_trace);
        assert_eq!(out.mappings, cold.mappings);
    }

    /// Interrupting before the first epoch yields an epochs_done=0
    /// snapshot whose resume reproduces the cold run exactly.
    #[test]
    fn zero_epoch_snapshot_resumes_to_cold_run() {
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { early_exit: false, epochs: 4, seed: 13, ..Default::default() };
        let matcher = PsoMatcher::new(cfg);
        let cold = matcher.run(&mask, &q, &g);
        let (head, snap) = matcher.run_resumable(&mask, &q, &g, None, &mut || true);
        assert_eq!(head.epochs_run, 0);
        let snap = snap.expect("snapshot at barrier 0");
        assert_eq!(snap.epochs_done, 0);
        let (tail, _) = matcher.run_resumable(&mask, &q, &g, Some(&snap), &mut || false);
        assert_eq!(tail.fitness_trace, cold.fitness_trace);
        assert_eq!(tail.mappings, cold.mappings);
    }

    #[test]
    fn zero_particles_is_empty_outcome() {
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { particles: 0, ..Default::default() };
        let out = PsoMatcher::new(cfg).run(&mask, &q, &g);
        assert!(!out.matched());
        assert_eq!(out.epochs_run, 0);
        assert_eq!(out.steps_run, 0);
        assert!(out.fitness_trace.is_empty());
        assert_eq!(out.best_fitness, f32::NEG_INFINITY);
    }

    #[test]
    fn zero_steps_is_empty_outcome() {
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { steps: 0, ..Default::default() };
        let out = PsoMatcher::new(cfg).run(&mask, &q, &g);
        assert!(!out.matched());
        assert_eq!(out.steps_run, 0);
        assert!(out.fitness_trace.is_empty());
    }

    #[test]
    fn zero_epochs_is_empty_outcome() {
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { epochs: 0, ..Default::default() };
        let out = PsoMatcher::new(cfg).run(&mask, &q, &g);
        assert!(!out.matched());
        assert_eq!(out.epochs_run, 0);
    }
}
