//! Native multi-particle optimizer (Algorithm 1) — the rust twin of the
//! AOT artifact, plus the *discrete* ablation of Fig. 2b.
//!
//! Two uses:
//! * the hardware-model execution path: the simulator charges the
//!   accelerator for exactly the work this implementation performs
//!   (steps × particles fused kernels, see [`super::cost`]);
//! * the default epoch backend when no PJRT artifact is available
//!   ([`crate::runtime::NativeEpochBackend`] drives the same per-particle
//!   epoch at the artifact's padded dims).
//!
//! ## Parallel structure
//!
//! The epoch mirrors the paper's data-dependency split: within one epoch
//! every particle runs its K fused steps against the *frozen* attractors
//! (S*, S̄) with no cross-particle dependency, so the per-particle work
//! fans out across threads (`std::thread::scope`, one forked RNG stream
//! per particle). Everything that couples particles — the global best
//! S*, the elite-consensus S̄, projection + Ullmann verification —
//! happens at the epoch barrier on the (modeled) global controller.
//! Serial and threaded execution are bit-identical for a given seed:
//! particle initialization and RNG forks consume the master stream in
//! particle order, and the trace merge runs on one thread.

use crate::util::{MatF, Rng};

use super::consensus::elite_consensus;
use super::fitness::{edge_fitness, mapping_is_feasible};
use super::projection::project_greedy;
use super::ullmann::{ullmann_find_first, UllmannStats};
use super::Mapping;

/// PSO hyperparameters (defaults follow the standard constricted swarm
/// plus the paper's consensus term).
#[derive(Clone, Copy, Debug)]
pub struct PsoConfig {
    /// Particles per epoch (mapped 1:1 onto engines).
    pub particles: usize,
    /// Outer epochs T (particles re-initialized each epoch, Algorithm 1
    /// line 4; S*, S̄ and the feasible set persist).
    pub epochs: usize,
    /// Fused inner steps K per epoch.
    pub steps: usize,
    /// Inertia.
    pub w: f32,
    /// Cognitive (particle-local best) pull.
    pub c1: f32,
    /// Social (global best) pull.
    pub c2: f32,
    /// Consensus pull (the paper's addition).
    pub c3: f32,
    /// Elites fused into the consensus matrix.
    pub elite: usize,
    /// Continuous relaxation on (true = IMMSched; false = the unstable
    /// discrete coupling of Fig. 2b).
    pub relaxed: bool,
    /// Stop at the first feasible mapping (production) or keep searching
    /// (benchmarks that want the full trace).
    pub early_exit: bool,
    /// Node budget for the bounded Ullmann repair of projected
    /// candidates.
    pub repair_budget: u64,
    /// Worker threads for the intra-epoch particle fan-out (0 = one per
    /// available core, capped at the particle count). Only consulted on
    /// the threaded path.
    pub threads: usize,
    pub seed: u64,
}

impl Default for PsoConfig {
    fn default() -> Self {
        Self {
            particles: 16,
            epochs: 8,
            steps: 8,
            w: 0.72,
            c1: 1.49,
            c2: 1.49,
            c3: 0.60,
            elite: 4,
            relaxed: true,
            early_exit: true,
            // Algorithm 1's UllmannRefine step needs headroom on branchy
            // queries (UNet skip tiles take ~10k nodes); the controller
            // is charged for every expanded node in the cost model.
            repair_budget: 100_000,
            threads: 0,
            seed: 0x1535EED,
        }
    }
}

/// Search outcome + enough telemetry to drive the figures.
#[derive(Clone, Debug, Default)]
pub struct PsoOutcome {
    /// Feasible mappings found (deduplicated).
    pub mappings: Vec<Mapping>,
    /// Best fitness reached (0 = perfect relaxed embedding).
    pub best_fitness: f32,
    /// Best-so-far fitness after every fused step (Fig. 2b traces).
    pub fitness_trace: Vec<f32>,
    /// Mean *current* fitness across particles after every fused step —
    /// the non-monotone signal whose oscillation Fig. 2b plots as
    /// "search stability".
    pub mean_fitness_trace: Vec<f32>,
    /// Epochs actually run.
    pub epochs_run: usize,
    /// Total fused steps executed (each = one kernel launch per particle).
    pub steps_run: usize,
    /// Ullmann repair statistics.
    pub repair_stats: UllmannStats,
    /// Fused step kernel invocations (steps_run × particles) — the unit
    /// the cost model charges.
    pub kernel_invocations: u64,
}

impl PsoOutcome {
    pub fn matched(&self) -> bool {
        !self.mappings.is_empty()
    }
}

/// One particle's swarm state (shared with the native epoch backend).
pub(crate) struct ParticleState {
    pub s: MatF,
    pub v: MatF,
    pub s_local: MatF,
    pub f_local: f32,
}

/// The velocity-update coefficients one fused step needs.
#[derive(Clone, Copy, Debug)]
pub(crate) struct StepParams {
    pub w: f32,
    pub c1: f32,
    pub c2: f32,
    pub c3: f32,
    pub relaxed: bool,
}

impl StepParams {
    pub(crate) fn from_config(cfg: &PsoConfig) -> Self {
        Self { w: cfg.w, c1: cfg.c1, c2: cfg.c2, c3: cfg.c3, relaxed: cfg.relaxed }
    }
}

/// A particle plus its private RNG stream and per-step fitness record for
/// one epoch.
pub(crate) struct EpochParticle {
    pub state: ParticleState,
    pub rng: Rng,
    pub fits: Vec<f32>,
}

/// Minimum per-epoch work (particles × steps × n × m elements) before
/// the auto path spawns scoped threads: below this, per-epoch thread
/// spawn/join dominates the few microseconds of arithmetic and the
/// serial loop is faster on the interrupt hot path. `run_threaded`
/// bypasses the threshold (tests/benches force the fan-out).
pub(crate) const PARALLEL_WORK_THRESHOLD: usize = 1 << 15;

/// The native matcher.
pub struct PsoMatcher {
    pub config: PsoConfig,
}

impl PsoMatcher {
    pub fn new(config: PsoConfig) -> Self {
        Self { config }
    }

    /// Run Algorithm 1 on (mask, Q, G). Uses the threaded epoch when the
    /// `parallel` feature is on, more than one particle is configured,
    /// and the per-epoch work is large enough to amortize thread spawns;
    /// results are identical to [`Self::run_serial`] either way.
    pub fn run(&self, mask: &MatF, q: &MatF, g: &MatF) -> PsoOutcome {
        let work = self.config.particles * self.config.steps * mask.rows() * mask.cols();
        let threaded = cfg!(feature = "parallel")
            && self.config.particles > 1
            && work >= PARALLEL_WORK_THRESHOLD;
        self.run_impl(mask, q, g, threaded)
    }

    /// Force the serial per-particle loop (baseline / determinism tests).
    pub fn run_serial(&self, mask: &MatF, q: &MatF, g: &MatF) -> PsoOutcome {
        self.run_impl(mask, q, g, false)
    }

    /// Force the threaded epoch regardless of the `parallel` feature.
    pub fn run_threaded(&self, mask: &MatF, q: &MatF, g: &MatF) -> PsoOutcome {
        self.run_impl(mask, q, g, true)
    }

    fn run_impl(&self, mask: &MatF, q: &MatF, g: &MatF, threaded: bool) -> PsoOutcome {
        let cfg = &self.config;
        let (n, m) = (mask.rows(), mask.cols());
        assert_eq!(q.rows(), n);
        assert_eq!(g.rows(), m);
        let mut out = PsoOutcome { best_fitness: f32::NEG_INFINITY, ..Default::default() };
        // Degenerate configs (no particles, no epochs, no steps) have
        // nothing to search: return the empty outcome instead of
        // panicking downstream (elite_consensus asserts on empty input,
        // zero steps would feed NEG_INFINITY fitnesses to the consensus).
        if cfg.particles == 0 || cfg.epochs == 0 || cfg.steps == 0 {
            return out;
        }
        let mut rng = Rng::new(cfg.seed);
        let params = StepParams::from_config(cfg);

        let mut s_star = init_particle_s(mask, &mut rng);
        let mut f_star = f32::NEG_INFINITY;
        let mut s_bar = s_star.clone();
        // deterministic in (mask, q, g) — run at most once per episode
        let mut repair_memo: Option<Option<Mapping>> = None;

        'epochs: for _t in 0..cfg.epochs {
            out.epochs_run += 1;
            // line 4: fresh particles each epoch. Initialization and the
            // per-particle RNG forks consume the master stream in
            // particle order, so serial and threaded runs are identical.
            let mut particles: Vec<EpochParticle> = (0..cfg.particles)
                .map(|i| {
                    let s = init_particle_s(mask, &mut rng);
                    let stream = rng.fork(i as u64);
                    EpochParticle {
                        state: ParticleState {
                            v: MatF::zeros(n, m),
                            s_local: s.clone(),
                            f_local: f32::NEG_INFINITY,
                            s,
                        },
                        rng: stream,
                        fits: Vec::new(),
                    }
                })
                .collect();

            // the fused epoch: K steps per particle against the frozen
            // (S*, S̄) attractors — no cross-particle dependency until
            // the barrier below
            run_epoch_particles(
                &mut particles,
                &s_star,
                &s_bar,
                mask,
                q,
                g,
                cfg.steps,
                &params,
                threaded,
                cfg.threads,
            );

            // barrier part 1: merge the per-particle traces (single
            // thread, particle order — deterministic)
            let f_star_before = f_star;
            for k in 0..cfg.steps {
                out.steps_run += 1;
                out.kernel_invocations += cfg.particles as u64;
                let mut f_sum = 0.0f32;
                let mut step_best = f32::NEG_INFINITY;
                for p in &particles {
                    let f = p.fits[k];
                    f_sum += f;
                    step_best = step_best.max(f);
                }
                f_star = f_star.max(step_best);
                out.fitness_trace.push(f_star);
                out.mean_fitness_trace.push(f_sum / cfg.particles as f32);
            }
            out.best_fitness = out.best_fitness.max(f_star);

            // barrier part 2: fold the particle-local bests into S*
            let mut best_idx: Option<usize> = None;
            let mut best_f = f_star_before;
            for (i, p) in particles.iter().enumerate() {
                if p.state.f_local > best_f {
                    best_f = p.state.f_local;
                    best_idx = Some(i);
                }
            }
            if let Some(i) = best_idx {
                s_star = particles[i].state.s_local.clone();
            }

            // lines 19-25: project, refine, verify, fuse consensus
            let fitnesses: Vec<f32> = particles.iter().map(|p| p.state.f_local).collect();
            for p in &particles {
                let candidate = project_greedy(&p.state.s, mask);
                let found = if mapping_is_feasible(&candidate, q, g) {
                    Some(candidate)
                } else {
                    // bounded Ullmann repair (Algorithm 1's UllmannRefine):
                    // restrict candidates to the mask and let refinement +
                    // a bounded backtrack fix the projection; memoized —
                    // it is deterministic in (mask, q, g)
                    match &repair_memo {
                        Some(memo) => memo.clone(),
                        None => {
                            let (repaired, stats) =
                                ullmann_find_first(mask, q, g, cfg.repair_budget);
                            out.repair_stats.nodes_visited += stats.nodes_visited;
                            out.repair_stats.refine_passes += stats.refine_passes;
                            out.repair_stats.refuted += stats.refuted;
                            repair_memo = Some(repaired.clone());
                            repaired
                        }
                    }
                };
                if let Some(mp) = found {
                    debug_assert!(mapping_is_feasible(&mp, q, g));
                    if !out.mappings.contains(&mp) {
                        out.mappings.push(mp);
                    }
                    if cfg.early_exit {
                        break 'epochs;
                    }
                }
            }
            let snapshots: Vec<MatF> =
                particles.iter().map(|p| p.state.s_local.clone()).collect();
            s_bar = elite_consensus(&snapshots, &fitnesses, cfg.elite);
        }
        out
    }
}

/// Run every particle's K-step epoch, serially or fanned out over scoped
/// threads. Particles are fully independent here (frozen attractors,
/// private RNG streams), so the two modes produce identical results.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_epoch_particles(
    particles: &mut [EpochParticle],
    s_star: &MatF,
    s_bar: &MatF,
    mask: &MatF,
    q: &MatF,
    g: &MatF,
    steps: usize,
    params: &StepParams,
    threaded: bool,
    threads: usize,
) {
    let workers = if !threaded {
        1
    } else {
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let requested = if threads > 0 { threads } else { avail };
        requested.clamp(1, particles.len().max(1))
    };
    if workers <= 1 {
        for p in particles.iter_mut() {
            p.fits = run_particle_epoch(
                &mut p.state,
                s_star,
                s_bar,
                mask,
                q,
                g,
                steps,
                params,
                &mut p.rng,
            );
        }
        return;
    }
    let chunk = (particles.len() + workers - 1) / workers;
    std::thread::scope(|scope| {
        for slab in particles.chunks_mut(chunk) {
            scope.spawn(move || {
                for p in slab.iter_mut() {
                    p.fits = run_particle_epoch(
                        &mut p.state,
                        s_star,
                        s_bar,
                        mask,
                        q,
                        g,
                        steps,
                        params,
                        &mut p.rng,
                    );
                }
            });
        }
    });
}

/// One particle's full epoch: K fused steps with local-best tracking.
/// Returns the particle's *current* fitness after every step (the
/// per-step trace the barrier merges).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_particle_epoch(
    p: &mut ParticleState,
    s_star: &MatF,
    s_bar: &MatF,
    mask: &MatF,
    q: &MatF,
    g: &MatF,
    steps: usize,
    params: &StepParams,
    rng: &mut Rng,
) -> Vec<f32> {
    let mut fits = Vec::with_capacity(steps);
    for _k in 0..steps {
        step_particle(p, s_star, s_bar, mask, params, rng);
        let f = if params.relaxed {
            edge_fitness(&p.s, q, g)
        } else {
            // discrete coupling (Fig. 2b ablation): evaluate on the
            // hard-rounded one-hot projection of S
            let hard = harden(&p.s, mask);
            edge_fitness(&hard, q, g)
        };
        fits.push(f);
        if f > p.f_local {
            p.f_local = f;
            p.s_local = p.s.clone();
        }
    }
    fits
}

/// Random mask-respecting row-stochastic initialization.
fn init_particle_s(mask: &MatF, rng: &mut Rng) -> MatF {
    let mut s = MatF::from_fn(mask.rows(), mask.cols(), |_, _| rng.f32() + 1e-3);
    s.hadamard_assign(mask);
    s.row_normalize();
    s
}

/// Fused PSO step for one particle (the rust twin of the Pallas kernel).
/// Flat slice iteration in row-major order — the RNG is consumed three
/// draws per element exactly as the elementwise kernel folds its key.
fn step_particle(
    p: &mut ParticleState,
    s_star: &MatF,
    s_bar: &MatF,
    mask: &MatF,
    params: &StepParams,
    rng: &mut Rng,
) {
    let ParticleState { s, v, s_local, .. } = p;
    for ((((s_ij, v_ij), &l_ij), &star_ij), &bar_ij) in s
        .as_mut_slice()
        .iter_mut()
        .zip(v.as_mut_slice().iter_mut())
        .zip(s_local.as_slice())
        .zip(s_star.as_slice())
        .zip(s_bar.as_slice())
    {
        let r1 = rng.f32();
        let r2 = rng.f32();
        let r3 = rng.f32();
        let cur = *s_ij;
        let vel = params.w * *v_ij
            + params.c1 * r1 * (l_ij - cur)
            + params.c2 * r2 * (star_ij - cur)
            + params.c3 * r3 * (bar_ij - cur);
        *v_ij = vel;
        *s_ij = (cur + vel).clamp(0.0, 1.0);
    }
    s.hadamard_assign(mask);
    s.row_normalize();
}

/// Hard rounding to an injective one-hot matrix (discrete ablation).
fn harden(s: &MatF, mask: &MatF) -> MatF {
    let assign = project_greedy(s, mask);
    let mut hard = MatF::zeros(s.rows(), s.cols());
    for (i, &mj) in assign.iter().enumerate() {
        if let Some(j) = mj {
            hard[(i, j)] = 1.0;
        }
    }
    hard
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_chain, NodeKind};
    use crate::matcher::{build_mask, ullmann::plant_embedding};

    fn chain_problem() -> (MatF, MatF, MatF) {
        let qd = gen_chain(4, NodeKind::Compute);
        let gd = gen_chain(8, NodeKind::Universal);
        let mask = build_mask(&qd, &gd);
        (mask, qd.adjacency(), gd.adjacency())
    }

    #[test]
    fn finds_chain_embedding() {
        let (mask, q, g) = chain_problem();
        let out = PsoMatcher::new(PsoConfig { seed: 7, ..Default::default() }).run(&mask, &q, &g);
        assert!(out.matched(), "no mapping found: best fitness {}", out.best_fitness);
        for mp in &out.mappings {
            assert!(mapping_is_feasible(mp, &q, &g));
        }
    }

    #[test]
    fn finds_planted_embeddings() {
        let mut rng = Rng::new(99);
        let mut found = 0;
        for trial in 0..10 {
            let (q, g, _) = plant_embedding(5, 12, 0.4, 0.15, &mut rng);
            let mask = MatF::full(5, 12, 1.0);
            let cfg = PsoConfig { seed: trial as u64, ..Default::default() };
            let out = PsoMatcher::new(cfg).run(&mask, &q, &g);
            if out.matched() {
                found += 1;
                assert!(mapping_is_feasible(&out.mappings[0], &q, &g));
            }
        }
        assert!(found >= 8, "only {found}/10 planted embeddings found");
    }

    #[test]
    fn trace_is_monotone_best_so_far() {
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { early_exit: false, epochs: 3, seed: 3, ..Default::default() };
        let out = PsoMatcher::new(cfg).run(&mask, &q, &g);
        for w in out.fitness_trace.windows(2) {
            assert!(w[1] >= w[0] - 1e-6, "trace decreased: {} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn relaxed_beats_discrete_in_final_fitness() {
        // Fig. 2b: continuous relaxation stabilizes the search.  Compare
        // mean best fitness across seeds.
        let mut rng = Rng::new(4242);
        let (q, g, _) = plant_embedding(6, 14, 0.4, 0.2, &mut rng);
        let mask = MatF::full(6, 14, 1.0);
        let run = |relaxed: bool, seed: u64| -> f32 {
            let cfg = PsoConfig {
                relaxed,
                early_exit: false,
                epochs: 2,
                steps: 12,
                seed,
                ..Default::default()
            };
            PsoMatcher::new(cfg).run(&mask, &q, &g).best_fitness
        };
        let relaxed_mean: f32 = (0..5).map(|s| run(true, s)).sum::<f32>() / 5.0;
        let discrete_mean: f32 = (0..5).map(|s| run(false, s)).sum::<f32>() / 5.0;
        assert!(
            relaxed_mean >= discrete_mean,
            "relaxed {relaxed_mean} worse than discrete {discrete_mean}"
        );
    }

    #[test]
    fn kernel_invocations_counted() {
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { early_exit: false, epochs: 2, steps: 4, particles: 8, seed: 1, ..Default::default() };
        let out = PsoMatcher::new(cfg).run(&mask, &q, &g);
        assert_eq!(out.steps_run, 8);
        assert_eq!(out.kernel_invocations, 64);
    }

    #[test]
    fn deterministic_given_seed() {
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { seed: 55, ..Default::default() };
        let a = PsoMatcher::new(cfg).run(&mask, &q, &g);
        let b = PsoMatcher::new(cfg).run(&mask, &q, &g);
        assert_eq!(a.mappings, b.mappings);
        assert_eq!(a.fitness_trace, b.fitness_trace);
    }

    #[test]
    fn threaded_epoch_matches_serial() {
        // the headline determinism guarantee: the threaded epoch is
        // bit-identical to the serial per-particle loop
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { early_exit: false, epochs: 3, seed: 21, ..Default::default() };
        let matcher = PsoMatcher::new(cfg);
        let a = matcher.run_serial(&mask, &q, &g);
        let b = matcher.run_threaded(&mask, &q, &g);
        assert_eq!(a.mappings, b.mappings);
        assert_eq!(a.fitness_trace, b.fitness_trace);
        assert_eq!(a.mean_fitness_trace, b.mean_fitness_trace);
        assert_eq!(a.best_fitness, b.best_fitness);
        assert_eq!(a.steps_run, b.steps_run);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let (mask, q, g) = chain_problem();
        let base = PsoConfig { early_exit: false, epochs: 2, seed: 33, ..Default::default() };
        let one = PsoMatcher::new(PsoConfig { threads: 1, ..base }).run_threaded(&mask, &q, &g);
        let three = PsoMatcher::new(PsoConfig { threads: 3, ..base }).run_threaded(&mask, &q, &g);
        assert_eq!(one.fitness_trace, three.fitness_trace);
        assert_eq!(one.mappings, three.mappings);
    }

    #[test]
    fn zero_particles_is_empty_outcome() {
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { particles: 0, ..Default::default() };
        let out = PsoMatcher::new(cfg).run(&mask, &q, &g);
        assert!(!out.matched());
        assert_eq!(out.epochs_run, 0);
        assert_eq!(out.steps_run, 0);
        assert!(out.fitness_trace.is_empty());
        assert_eq!(out.best_fitness, f32::NEG_INFINITY);
    }

    #[test]
    fn zero_steps_is_empty_outcome() {
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { steps: 0, ..Default::default() };
        let out = PsoMatcher::new(cfg).run(&mask, &q, &g);
        assert!(!out.matched());
        assert_eq!(out.steps_run, 0);
        assert!(out.fitness_trace.is_empty());
    }

    #[test]
    fn zero_epochs_is_empty_outcome() {
        let (mask, q, g) = chain_problem();
        let cfg = PsoConfig { epochs: 0, ..Default::default() };
        let out = PsoMatcher::new(cfg).run(&mask, &q, &g);
        assert!(!out.matched());
        assert_eq!(out.epochs_run, 0);
    }
}
