//! Elite consensus (paper §3.4: the global controller "fuses
//! multi-particle search results to produce a consensus-guided
//! exploration direction").
//!
//! S̄ is the fitness-weighted mean of the top-E particles' relaxed
//! mappings, renormalized row-stochastic.  It enters the velocity update
//! as the third attractor (after the particle-local and global bests),
//! pulling the swarm toward regions many good particles agree on.

use crate::util::MatF;

/// Particle indices ranked by fitness, best first. NaN is demoted below
/// every real fitness — bare `total_cmp` would rank +NaN above +inf.
/// Shared by the consensus fusion and the controller's elite selection
/// so the two ranking paths cannot diverge.
pub(crate) fn rank_fitness_desc(fitness: &[f32]) -> Vec<usize> {
    let key = |f: f32| if f.is_nan() { f32::NEG_INFINITY } else { f };
    let mut idx: Vec<usize> = (0..fitness.len()).collect();
    idx.sort_by(|&a, &b| key(fitness[b]).total_cmp(&key(fitness[a])));
    idx
}

/// Fuse the top-`elite` particles into a consensus matrix.
///
/// `particles[i]` is particle i's relaxed mapping; `fitness[i]` its
/// (negative, ≤ 0) edge-preserving fitness.  Weights are softmax-like:
/// `w_i = 1 / (1 + |f_i - f_best|)`, which keeps the best particle at
/// weight 1 and decays with fitness distance without needing exp() on
/// the modeled fixed-point controller.
///
/// Robust to degenerate fitness values: NaN sorts below every real
/// fitness, and non-finite weights — e.g. the NaN from
/// `-inf − -inf` when every `f_local` is still untouched — clamp to 0 so
/// they cannot poison S̄ for later epochs. When no elite carries usable
/// weight, the elites are averaged uniformly instead.
pub fn elite_consensus(particles: &[MatF], fitness: &[f32], elite: usize) -> MatF {
    assert_eq!(particles.len(), fitness.len());
    assert!(!particles.is_empty());
    let (n, m) = (particles[0].rows(), particles[0].cols());
    let mut acc = MatF::zeros(n, m);
    fuse_elites(
        |i| particles[i].as_slice(),
        particles.len(),
        fitness,
        elite,
        acc.as_mut_slice(),
        m,
    );
    acc
}

/// Flat twin of [`elite_consensus`] for the matcher's clone-free epoch
/// barrier: `particles` is `count` stacked row-major n×m snapshots
/// (struct-of-arrays swarm layout); the consensus is written into `out`
/// without copying a single snapshot.
pub(crate) fn elite_consensus_flat(
    particles: &[f32],
    count: usize,
    n: usize,
    m: usize,
    fitness: &[f32],
    elite: usize,
    out: &mut [f32],
) {
    assert_eq!(particles.len(), count * n * m);
    assert_eq!(fitness.len(), count);
    assert!(count > 0);
    assert_eq!(out.len(), n * m);
    let nm = n * m;
    fuse_elites(|i| &particles[i * nm..(i + 1) * nm], count, fitness, elite, out, m);
}

/// Shared fusion core: fitness-distance weights over the ranked elites,
/// uniform fallback when every weight clamps, row-stochastic output.
fn fuse_elites<'a>(
    snapshot: impl Fn(usize) -> &'a [f32],
    count: usize,
    fitness: &[f32],
    elite: usize,
    out: &mut [f32],
    cols: usize,
) {
    let elite = elite.max(1).min(count);
    let idx = rank_fitness_desc(fitness);
    let best_f = fitness[idx[0]];
    let weight = |f: f32| -> f32 {
        // equal fitness (including -inf == -inf) is distance 0, weight 1
        let dist = if f == best_f { 0.0 } else { (f - best_f).abs() };
        let w = 1.0 / (1.0 + dist);
        if w.is_finite() {
            w
        } else {
            0.0
        }
    };
    out.fill(0.0);
    let mut total_w = 0.0f32;
    for &i in idx.iter().take(elite) {
        let w = weight(fitness[i]);
        if w <= 0.0 {
            continue;
        }
        for (a, &p) in out.iter_mut().zip(snapshot(i)) {
            *a += w * p;
        }
        total_w += w;
    }
    if total_w > 0.0 {
        for a in out.iter_mut() {
            *a /= total_w;
        }
    } else {
        // every weight clamped (all-NaN fitness): uniform elite average
        for &i in idx.iter().take(elite) {
            for (a, &p) in out.iter_mut().zip(snapshot(i)) {
                *a += p / elite as f32;
            }
        }
    }
    crate::util::row_normalize_in_place(out, cols);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_stochastic(n: usize, m: usize, rng: &mut Rng) -> MatF {
        let mut s = MatF::from_fn(n, m, |_, _| rng.f32() + 1e-3);
        s.row_normalize();
        s
    }

    #[test]
    fn consensus_is_row_stochastic() {
        let mut rng = Rng::new(2);
        let parts: Vec<MatF> = (0..6).map(|_| random_stochastic(4, 8, &mut rng)).collect();
        let fit: Vec<f32> = (0..6).map(|i| -(i as f32)).collect();
        let c = elite_consensus(&parts, &fit, 3);
        for i in 0..4 {
            let s: f32 = c.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums {s}");
        }
    }

    #[test]
    fn single_elite_equals_best_particle() {
        let mut rng = Rng::new(3);
        let parts: Vec<MatF> = (0..4).map(|_| random_stochastic(3, 6, &mut rng)).collect();
        let fit = vec![-5.0, -1.0, -9.0, -2.0];
        let c = elite_consensus(&parts, &fit, 1);
        // best particle is index 1
        for (a, b) in c.as_slice().iter().zip(parts[1].as_slice()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn identical_particles_fixed_point() {
        let mut rng = Rng::new(4);
        let p = random_stochastic(3, 5, &mut rng);
        let parts = vec![p.clone(), p.clone(), p.clone()];
        let c = elite_consensus(&parts, &[-1.0, -1.0, -1.0], 3);
        for (a, b) in c.as_slice().iter().zip(p.as_slice()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn elite_larger_than_population_is_clamped() {
        let mut rng = Rng::new(5);
        let parts: Vec<MatF> = (0..2).map(|_| random_stochastic(2, 4, &mut rng)).collect();
        let c = elite_consensus(&parts, &[-1.0, -2.0], 99);
        assert_eq!(c.rows(), 2);
    }

    #[test]
    fn nan_fitness_does_not_panic_or_poison() {
        // regression: partial_cmp().unwrap() used to panic on NaN, and a
        // NaN weight silently zeroed/NaN-ed S̄ for all later epochs
        let mut rng = Rng::new(6);
        let parts: Vec<MatF> = (0..4).map(|_| random_stochastic(3, 6, &mut rng)).collect();
        let fit = vec![-2.0, f32::NAN, -1.0, f32::NAN];
        let c = elite_consensus(&parts, &fit, 3);
        assert!(c.as_slice().iter().all(|x| x.is_finite()), "consensus has non-finite entries");
        for i in 0..3 {
            let s: f32 = c.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums {s}");
        }
    }

    #[test]
    fn all_neg_infinity_fitness_gives_uniform_elite_average() {
        // regression: (-inf) − (-inf) = NaN used to poison every weight
        // when no particle had improved yet (e.g. a zero-step epoch)
        let mut rng = Rng::new(7);
        let parts: Vec<MatF> = (0..3).map(|_| random_stochastic(2, 5, &mut rng)).collect();
        let fit = vec![f32::NEG_INFINITY; 3];
        let c = elite_consensus(&parts, &fit, 3);
        assert!(c.as_slice().iter().all(|x| x.is_finite()));
        for i in 0..2 {
            let s: f32 = c.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums {s}");
        }
    }

    #[test]
    fn flat_consensus_matches_matf_version() {
        let mut rng = Rng::new(9);
        let (n, m, count) = (3usize, 7usize, 5usize);
        let parts: Vec<MatF> = (0..count).map(|_| random_stochastic(n, m, &mut rng)).collect();
        let fit: Vec<f32> = vec![-3.0, -1.5, f32::NAN, -0.5, -2.0];
        let dense = elite_consensus(&parts, &fit, 3);
        let mut flat = vec![0.0f32; count * n * m];
        for (i, p) in parts.iter().enumerate() {
            flat[i * n * m..(i + 1) * n * m].copy_from_slice(p.as_slice());
        }
        let mut out = vec![0.0f32; n * m];
        elite_consensus_flat(&flat, count, n, m, &fit, 3, &mut out);
        assert_eq!(out.as_slice(), dense.as_slice());
    }

    #[test]
    fn all_nan_fitness_falls_back_to_uniform() {
        let mut rng = Rng::new(8);
        let parts: Vec<MatF> = (0..2).map(|_| random_stochastic(2, 4, &mut rng)).collect();
        let c = elite_consensus(&parts, &[f32::NAN, f32::NAN], 2);
        assert!(c.as_slice().iter().all(|x| x.is_finite()));
        let s: f32 = c.row(0).iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }
}
