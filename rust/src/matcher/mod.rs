//! Subgraph-isomorphism engines — the algorithmic heart of the paper.
//!
//! * [`mask`] — the global compatibility mask `Mask ∈ {0,1}^{n×m}`
//!   (degree + computation-type feasibility, §3.2), built as a packed
//!   [`BitMask`] with a word-wise empty-row infeasibility witness.
//! * [`ullmann`] — the classic serial Ullmann algorithm with refinement
//!   and backtracking: both the IsoSched baseline and the final verifier
//!   IMMSched runs on projected candidates.
//! * [`fitness`] — the edge-preserving metric `-‖Q − S G Sᵀ‖²` (§3.3):
//!   the sparse CSR [`FitnessKernel`] hot path plus the dense
//!   [`edge_fitness`] oracle it is property-tested against.
//! * [`projection`] — relaxed S → discrete injective mapping M̂ (greedy
//!   argmax and Hungarian variants).
//! * [`consensus`] — the global controller's elite-consensus fusion S̄.
//! * [`pso`] — the multi-particle optimizer (native f32 twin of the AOT
//!   artifact; also the *discrete* ablation for Fig. 2b).
//! * [`quantized`] — the u8/i32 fixed-point matcher that models the
//!   int8 MAC datapath of §3.4 cycle-for-cycle.
//! * [`cost`] — cycle/energy cost of running the matcher on-accelerator
//!   vs on a host CPU (feeds Figs. 2a/6/7/8).

pub mod consensus;
pub mod cost;
pub mod fitness;
pub mod mask;
pub mod projection;
pub mod pso;
pub mod quantized;
pub mod ullmann;
pub mod vf2;

pub use consensus::elite_consensus;
pub use cost::{MatcherCost, MatcherCostModel};
pub use fitness::{
    edge_fitness, mapping_is_feasible, mapping_is_feasible_csr, mapping_is_feasible_sparse,
    FitnessKernel, FitnessScratch,
};
pub use mask::{build_bitmask, build_mask, has_empty_row, BitMask};
pub use projection::{project_greedy, project_greedy_flat, project_hungarian};
pub use pso::{PsoConfig, PsoMatcher, PsoOutcome, SwarmSnapshot};
pub use quantized::{QuantizedMatcher, QuantizedOutcome};
pub use ullmann::{ullmann_find_first, ullmann_refine, UllmannStats};
pub use vf2::{vf2_find_first, Vf2Stats};

/// A discrete query→target mapping: `assign[i] = Some(j)` maps query
/// vertex i to target vertex j (injective where `Some`).
pub type Mapping = Vec<Option<usize>>;
