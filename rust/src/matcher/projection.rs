//! Projection: relaxed mapping S → discrete injective mapping M̂
//! (Algorithm 1, line 19: "each query vertex maps to exactly one target
//! vertex, each target vertex matched by at most one query vertex").

use crate::util::MatF;

use super::Mapping;

/// Greedy projection: repeatedly take the globally largest s_ij among
/// unassigned rows/columns.  O(n·m·min(n,m)) — this is what the
/// lightweight on-chip controller runs (argmax is exactly the comparator
/// tree added in §3.4).
pub fn project_greedy(s: &MatF, mask: &MatF) -> Mapping {
    project_greedy_flat(s.as_slice(), mask.as_slice(), s.rows(), s.cols())
}

/// [`project_greedy`] over flat row-major buffers — the form the
/// struct-of-arrays swarm state hands the epoch barrier (no `MatF`
/// materialization on the hot path).
pub fn project_greedy_flat(s: &[f32], mask: &[f32], n: usize, m: usize) -> Mapping {
    debug_assert_eq!(s.len(), n * m);
    debug_assert_eq!(mask.len(), n * m);
    let mut assign: Mapping = vec![None; n];
    let mut row_done = vec![false; n];
    let mut col_done = vec![false; m];
    for _ in 0..n.min(m) {
        let mut best: Option<(usize, usize, f32)> = None;
        for i in 0..n {
            if row_done[i] {
                continue;
            }
            for j in 0..m {
                if col_done[j] || mask[i * m + j] == 0.0 {
                    continue;
                }
                let v = s[i * m + j];
                if best.map_or(true, |(_, _, bv)| v > bv) {
                    best = Some((i, j, v));
                }
            }
        }
        match best {
            Some((i, j, _)) => {
                assign[i] = Some(j);
                row_done[i] = true;
                col_done[j] = true;
            }
            None => break, // no mask-compatible pair left
        }
    }
    assign
}

/// Hungarian (Kuhn–Munkres) projection: maximum-weight injective
/// assignment under the mask.  Higher quality than greedy, used by the
/// ablation bench to quantify the greedy controller's loss.
pub fn project_hungarian(s: &MatF, mask: &MatF) -> Mapping {
    let (n, m) = (s.rows(), s.cols());
    if n == 0 {
        return Vec::new();
    }
    // pad to square cost matrix; maximize s -> minimize (max - s)
    let dim = n.max(m);
    let maxv = s.as_slice().iter().cloned().fold(0.0f32, f32::max).max(1.0);
    const FORBIDDEN: f32 = 1e6;
    let cost = |i: usize, j: usize| -> f32 {
        if i >= n || j >= m {
            maxv // dummy rows/cols: neutral cost
        } else if mask[(i, j)] == 0.0 {
            FORBIDDEN
        } else {
            maxv - s[(i, j)]
        }
    };

    // O(dim^3) Jonker-ish Hungarian with potentials
    let mut u = vec![0.0f32; dim + 1];
    let mut v = vec![0.0f32; dim + 1];
    let mut p = vec![dim; dim + 1]; // p[j] = row matched to col j (dim = none)
    let mut way = vec![0usize; dim + 1];
    for i in 0..dim {
        p[dim] = i;
        let mut j0 = dim;
        let mut minv = vec![f32::INFINITY; dim + 1];
        let mut used = vec![false; dim + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = f32::INFINITY;
            let mut j1 = dim;
            for j in 0..dim {
                if used[j] {
                    continue;
                }
                let cur = cost(i0, j) - u[i0 + 1] - v[j + 1];
                if cur < minv[j] {
                    minv[j] = cur;
                    way[j] = j0;
                }
                if minv[j] < delta {
                    delta = minv[j];
                    j1 = j;
                }
            }
            for j in 0..=dim {
                if used[j] {
                    let idx = if p[j] == dim { 0 } else { p[j] + 1 };
                    u[idx] += delta;
                    v[if j == dim { 0 } else { j + 1 }] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == dim {
                break;
            }
        }
        // augment along the alternating path
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == dim {
                break;
            }
        }
    }

    let mut assign: Mapping = vec![None; n];
    for j in 0..dim {
        let i = p[j];
        if i < n && j < m && mask[(i, j)] != 0.0 {
            assign[i] = Some(j);
        }
    }
    assign
}

/// Sum of selected S entries (projection quality metric).
pub fn projection_weight(s: &MatF, assign: &Mapping) -> f32 {
    assign
        .iter()
        .enumerate()
        .filter_map(|(i, &mj)| mj.map(|j| s[(i, j)]))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_s(n: usize, m: usize, seed: u64) -> (MatF, MatF) {
        let mut rng = Rng::new(seed);
        let mut s = MatF::from_fn(n, m, |_, _| rng.f32());
        let mask = MatF::full(n, m, 1.0);
        s.row_normalize();
        (s, mask)
    }

    fn is_injective(assign: &Mapping) -> bool {
        let mut seen = std::collections::BTreeSet::new();
        assign.iter().flatten().all(|j| seen.insert(*j))
    }

    #[test]
    fn greedy_is_total_and_injective_under_full_mask() {
        let (s, mask) = random_s(5, 9, 3);
        let a = project_greedy(&s, &mask);
        assert!(a.iter().all(Option::is_some));
        assert!(is_injective(&a));
    }

    #[test]
    fn greedy_respects_mask() {
        let (s, mut mask) = random_s(3, 5, 4);
        for j in 0..5 {
            mask[(1, j)] = 0.0;
        }
        mask[(1, 2)] = 1.0;
        let a = project_greedy(&s, &mask);
        assert_eq!(a[1], Some(2));
    }

    #[test]
    fn hungarian_at_least_as_good_as_greedy() {
        for seed in 0..20 {
            let (s, mask) = random_s(6, 10, seed);
            let wg = projection_weight(&s, &project_greedy(&s, &mask));
            let wh = projection_weight(&s, &project_hungarian(&s, &mask));
            assert!(wh >= wg - 1e-5, "seed {seed}: hungarian {wh} < greedy {wg}");
        }
    }

    #[test]
    fn hungarian_is_injective_and_respects_mask() {
        let mut rng = Rng::new(8);
        for _ in 0..10 {
            let n = rng.range(2, 6);
            let m = n + rng.range(0, 5);
            let mut s = MatF::from_fn(n, m, |_, _| rng.f32());
            s.row_normalize();
            let mask = MatF::from_fn(n, m, |_, _| if rng.chance(0.7) { 1.0 } else { 0.0 });
            let a = project_hungarian(&s, &mask);
            assert!(is_injective(&a));
            for (i, &mj) in a.iter().enumerate() {
                if let Some(j) = mj {
                    assert!(mask[(i, j)] != 0.0);
                }
            }
        }
    }

    #[test]
    fn one_hot_s_projects_to_itself() {
        let mut s = MatF::zeros(3, 5);
        s[(0, 4)] = 1.0;
        s[(1, 0)] = 1.0;
        s[(2, 2)] = 1.0;
        let mask = MatF::full(3, 5, 1.0);
        for proj in [project_greedy(&s, &mask), project_hungarian(&s, &mask)] {
            assert_eq!(proj, vec![Some(4), Some(0), Some(2)]);
        }
    }
}
