//! Fixed-point matcher (paper §3.4): the u8/i32 datapath model.
//!
//! Positions S live on the uniform u8 grid (code 0..=255 ↔ [0,1]); the
//! two fitness matmuls run in i32 exactly as the int8 MAC array with i32
//! accumulators would compute them; row renormalization multiplies by a
//! reconfigurable reciprocal instead of dividing (the divider was removed
//! from the PEs).  Velocities stay in f32 — they live on the lightweight
//! global controller, not the MAC array.
//!
//! This implementation is the *cycle-accounting twin* of the hardware:
//! [`super::cost::MatcherCostModel`] charges the accelerator exactly the
//! operation counts this code performs.

use crate::util::{MatF, Rng};

use super::fitness::mapping_is_feasible;
use super::projection::project_greedy;
use super::ullmann::ullmann_find_first;
use super::{Mapping, PsoConfig};

/// u8 quantization scale (code 255 = 1.0); shared with kernels/ref.py.
pub const Q8_SCALE: f32 = 255.0;

/// Quantized relaxed mapping: row-major u8 codes.
#[derive(Clone, Debug)]
pub struct MatQ8 {
    rows: usize,
    cols: usize,
    data: Vec<u8>,
}

impl MatQ8 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0; rows * cols] }
    }

    pub fn quantize(m: &MatF) -> Self {
        let data = m.as_slice().iter().map(|&x| quantize_code(x)).collect();
        Self { rows: m.rows(), cols: m.cols(), data }
    }

    pub fn dequantize(&self) -> MatF {
        MatF::from_vec(
            self.rows,
            self.cols,
            self.data.iter().map(|&c| c as f32 / Q8_SCALE).collect(),
        )
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> u8 {
        self.data[i * self.cols + j]
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }
}

#[inline]
fn quantize_code(x: f32) -> u8 {
    (x * Q8_SCALE).round().clamp(0.0, 255.0) as u8
}

/// i32 fitness on the integer datapath: `-‖Q·255² − S G Sᵀ‖²` rescaled.
///
/// `q`/`g` are binary; S codes are u8; the two matmuls accumulate in
/// integer precision (max per-entry value 255²·m ≈ 8.3M for m=128).
/// The host implementation accumulates exact integer products in f64
/// (products ≤ 2^31, sums ≤ 2^40 ≪ 2^53 — bit-exact) because the f32
/// lane-widening autovectorizes ~5× better than i64 MACs; the modeled
/// *hardware* still pays int8-MAC + i32-accumulate costs in the cost
/// model.
pub fn fitness_q8(s: &MatQ8, q: &MatF, g: &MatF) -> f32 {
    let (n, m) = (s.rows, s.cols);
    // sg[i][l] = sum_j s[i][j] * g[j][l]   (u8 × {0,1})
    let mut sg = vec![0.0f32; n * m];
    let g_flat = g.as_slice();
    for i in 0..n {
        let s_row = &s.data[i * m..(i + 1) * m];
        let sg_row = &mut sg[i * m..(i + 1) * m];
        for (j, &sij) in s_row.iter().enumerate() {
            if sij == 0 {
                continue;
            }
            let sij = sij as f32;
            let g_row = &g_flat[j * m..(j + 1) * m];
            for (o, &gv) in sg_row.iter_mut().zip(g_row) {
                *o += sij * gv; // gv ∈ {0,1}: exact integer in f32
            }
        }
    }
    // sgst[i][k] = sum_l sg[i][l] * s[k][l]; accumulate err on the fly
    let inv = 1.0f64 / (Q8_SCALE as f64 * Q8_SCALE as f64);
    let mut acc = 0.0f64;
    for i in 0..n {
        let sg_row = &sg[i * m..(i + 1) * m];
        for k in 0..n {
            let s_row = &s.data[k * m..(k + 1) * m];
            // 4-lane unrolled dot: f32 products are exact integers
            // (≤ 255·32640 < 2²⁴·2 ⇒ representable), accumulated in f64
            // lanes so the sum stays exact (< 2⁴⁰ ≪ 2⁵³)
            let mut lanes = [0.0f64; 4];
            let chunks = m / 4;
            for c in 0..chunks {
                let b = c * 4;
                lanes[0] += (sg_row[b] * s_row[b] as f32) as f64;
                lanes[1] += (sg_row[b + 1] * s_row[b + 1] as f32) as f64;
                lanes[2] += (sg_row[b + 2] * s_row[b + 2] as f32) as f64;
                lanes[3] += (sg_row[b + 3] * s_row[b + 3] as f32) as f64;
            }
            for l in chunks * 4..m {
                lanes[0] += (sg_row[l] * s_row[l] as f32) as f64;
            }
            let dot: f64 = lanes.iter().sum();
            let err = q[(i, k)] as f64 - dot * inv;
            acc += err * err;
        }
    }
    -(acc as f32)
}

/// Outcome of the quantized matcher + datapath op counts for the cost
/// model.
#[derive(Clone, Debug, Default)]
pub struct QuantizedOutcome {
    pub mappings: Vec<Mapping>,
    pub best_fitness: f32,
    pub epochs_run: usize,
    pub steps_run: usize,
    /// int8 MAC operations issued to the array model.
    pub mac_ops: u64,
    /// element-wise PE operations (velocity/position/mask/renorm).
    pub eltwise_ops: u64,
    /// vector argmax reductions (projection on the comparator tree).
    pub argmax_ops: u64,
    /// Ullmann-repair backtracking nodes expanded on the controller.
    pub repair_nodes: u64,
}

impl QuantizedOutcome {
    pub fn matched(&self) -> bool {
        !self.mappings.is_empty()
    }
}

/// The fixed-point matcher.  Reuses [`PsoConfig`]; `relaxed` is ignored
/// (the hardware always runs the relaxed algorithm).
pub struct QuantizedMatcher {
    pub config: PsoConfig,
}

struct QParticle {
    s: MatQ8,
    v: MatF,
    s_local: MatQ8,
    f_local: f32,
}

impl QuantizedMatcher {
    pub fn new(config: PsoConfig) -> Self {
        Self { config }
    }

    pub fn run(&self, mask: &MatF, q: &MatF, g: &MatF) -> QuantizedOutcome {
        let cfg = &self.config;
        let (n, m) = (mask.rows(), mask.cols());
        let mut rng = Rng::new(cfg.seed ^ 0x9_8765);
        let mut out = QuantizedOutcome { best_fitness: f32::NEG_INFINITY, ..Default::default() };

        let mut s_star = MatQ8::quantize(&random_s(mask, &mut rng));
        let mut f_star = f32::NEG_INFINITY;
        let mut s_bar = s_star.clone();
        let mut repair_memo: Option<Option<Mapping>> = None;

        'epochs: for _t in 0..cfg.epochs {
            out.epochs_run += 1;
            let mut particles: Vec<QParticle> = (0..cfg.particles)
                .map(|_| {
                    let s = MatQ8::quantize(&random_s(mask, &mut rng));
                    QParticle { v: MatF::zeros(n, m), s_local: s.clone(), f_local: f32::NEG_INFINITY, s }
                })
                .collect();

            for _k in 0..cfg.steps {
                out.steps_run += 1;
                for p in particles.iter_mut() {
                    self.step(p, &s_star, &s_bar, mask, &mut rng, &mut out);
                    let f = fitness_q8(&p.s, q, g);
                    // fitness matmuls: S·G (n·m·m MACs) + (SG)·Sᵀ (n·n·m)
                    out.mac_ops += (n * m * m + n * n * m) as u64;
                    if f > p.f_local {
                        p.f_local = f;
                        p.s_local = p.s.clone();
                    }
                    if f > f_star {
                        f_star = f;
                        s_star = p.s.clone();
                    }
                }
                out.best_fitness = out.best_fitness.max(f_star);
            }

            // projection on the comparator tree + Ullmann verify
            let fitnesses: Vec<f32> = particles.iter().map(|p| p.f_local).collect();
            for p in &particles {
                let sf = p.s.dequantize();
                out.argmax_ops += n as u64; // one row-argmax per query vertex
                let candidate = project_greedy(&sf, mask);
                let found = if mapping_is_feasible(&candidate, q, g) {
                    Some(candidate)
                } else {
                    // the repair is deterministic in (mask, q, g): run it
                    // once per episode, reuse the memoized answer after
                    match &repair_memo {
                        Some(memo) => memo.clone(),
                        None => {
                            let (rep, stats) =
                                ullmann_find_first(mask, q, g, cfg.repair_budget);
                            out.repair_nodes += stats.nodes_visited;
                            repair_memo = Some(rep.clone());
                            rep
                        }
                    }
                };
                if let Some(mp) = found {
                    if !out.mappings.contains(&mp) {
                        out.mappings.push(mp);
                    }
                    if cfg.early_exit {
                        break 'epochs;
                    }
                }
            }
            // controller-side consensus over dequantized elites
            let snaps: Vec<MatF> = particles.iter().map(|p| p.s_local.dequantize()).collect();
            s_bar = MatQ8::quantize(&super::consensus::elite_consensus(&snaps, &fitnesses, cfg.elite));
        }
        out
    }

    /// One fused fixed-point step: f32 controller math, u8 re-quantize,
    /// reciprocal-multiply renorm.
    fn step(
        &self,
        p: &mut QParticle,
        s_star: &MatQ8,
        s_bar: &MatQ8,
        mask: &MatF,
        rng: &mut Rng,
        out: &mut QuantizedOutcome,
    ) {
        let cfg = &self.config;
        let (n, m) = (p.v.rows(), p.v.cols());
        let inv = 1.0 / Q8_SCALE;
        let mut s_new = MatF::zeros(n, m);
        for i in 0..n {
            for j in 0..m {
                let s = p.s.get(i, j) as f32 * inv;
                let vel = cfg.w * p.v[(i, j)]
                    + cfg.c1 * rng.f32() * (p.s_local.get(i, j) as f32 * inv - s)
                    + cfg.c2 * rng.f32() * (s_star.get(i, j) as f32 * inv - s)
                    + cfg.c3 * rng.f32() * (s_bar.get(i, j) as f32 * inv - s);
                p.v[(i, j)] = vel;
                s_new[(i, j)] = (s + vel).clamp(0.0, 1.0);
            }
        }
        // velocity+position+clip+mask+renorm = 5 elementwise passes
        out.eltwise_ops += (5 * n * m) as u64;
        s_new.hadamard_assign(mask);
        s_new.row_normalize(); // reciprocal-multiply in hardware
        p.s = MatQ8::quantize(&s_new);
    }
}

fn random_s(mask: &MatF, rng: &mut Rng) -> MatF {
    let mut s = MatF::from_fn(mask.rows(), mask.cols(), |_, _| rng.f32() + 1e-3);
    s.hadamard_assign(mask);
    s.row_normalize();
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_chain, NodeKind};
    use crate::matcher::{build_mask, edge_fitness, ullmann::plant_embedding};

    #[test]
    fn quantize_roundtrip_on_grid() {
        let m = MatF::from_fn(4, 8, |i, j| ((i * 8 + j) as f32 / 31.0).min(1.0));
        let q = MatQ8::quantize(&m);
        let back = MatQ8::quantize(&q.dequantize());
        assert_eq!(q.data, back.data);
    }

    #[test]
    fn q8_fitness_tracks_float_fitness() {
        let mut rng = Rng::new(31);
        let (q, g, _) = plant_embedding(5, 10, 0.4, 0.2, &mut rng);
        for _ in 0..5 {
            let mask = MatF::full(5, 10, 1.0);
            let s = random_s(&mask, &mut rng);
            let f_float = edge_fitness(&s, &q, &g);
            let f_q8 = fitness_q8(&MatQ8::quantize(&s), &q, &g);
            let rel = (f_q8 - f_float).abs() / (f_float.abs() + 1.0);
            assert!(rel < 0.1, "q8 {f_q8} vs float {f_float} (rel {rel})");
        }
    }

    #[test]
    fn quantized_matcher_finds_chain() {
        let qd = gen_chain(4, NodeKind::Compute);
        let gd = gen_chain(8, NodeKind::Universal);
        let mask = build_mask(&qd, &gd);
        let cfg = PsoConfig { seed: 77, ..Default::default() };
        let out = QuantizedMatcher::new(cfg).run(&mask, &qd.adjacency(), &gd.adjacency());
        assert!(out.matched());
        assert!(mapping_is_feasible(&out.mappings[0], &qd.adjacency(), &gd.adjacency()));
    }

    #[test]
    fn op_counters_populate() {
        let qd = gen_chain(3, NodeKind::Compute);
        let gd = gen_chain(6, NodeKind::Universal);
        let mask = build_mask(&qd, &gd);
        let cfg = PsoConfig { epochs: 1, steps: 2, particles: 4, early_exit: false, seed: 5, ..Default::default() };
        let out = QuantizedMatcher::new(cfg).run(&mask, &qd.adjacency(), &gd.adjacency());
        let (n, m) = (3u64, 6u64);
        assert_eq!(out.mac_ops, 2 * 4 * (n * m * m + n * n * m));
        assert_eq!(out.eltwise_ops, 2 * 4 * 5 * n * m);
        assert!(out.argmax_ops >= n);
    }
}
