//! VF2 subgraph-isomorphism (Cordella et al. 2004) — the second serial
//! baseline the paper cites (§2.2: "traditional serial algorithms (e.g.,
//! GsPM, VF2, VF3) ... exhibit strong serial dependencies").
//!
//! VF2 grows a partial mapping along the *frontier* of already-mapped
//! vertices, pruning with look-ahead counts on in/out terminal sets —
//! typically far fewer expanded states than Ullmann's row-order
//! backtracking, but just as irreducibly serial.  The ablation bench
//! compares both serial engines against the parallel PSO matcher.

use crate::util::MatF;

use super::fitness::mapping_is_feasible;
use super::Mapping;

/// VF2 search statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct Vf2Stats {
    /// Search states expanded.
    pub states: u64,
    /// Candidate pairs rejected by feasibility rules.
    pub pruned: u64,
}

struct Vf2<'a> {
    q: &'a MatF,
    g: &'a MatF,
    mask: &'a MatF,
    n: usize,
    m: usize,
    core_q: Vec<Option<usize>>, // query -> target
    core_g: Vec<Option<usize>>, // target -> query
    stats: Vf2Stats,
    budget: u64,
}

impl<'a> Vf2<'a> {
    fn new(mask: &'a MatF, q: &'a MatF, g: &'a MatF, budget: u64) -> Self {
        let (n, m) = (q.rows(), g.rows());
        Self {
            q,
            g,
            mask,
            n,
            m,
            core_q: vec![None; n],
            core_g: vec![None; m],
            stats: Vf2Stats::default(),
            budget,
        }
    }

    /// Syntactic feasibility of adding (qu, gv): every mapped neighbor
    /// relation of qu must be mirrored by gv.
    fn consistent(&self, qu: usize, gv: usize) -> bool {
        for (qk, &mapped) in self.core_q.iter().enumerate() {
            let Some(gk) = mapped else { continue };
            // query edges qu->qk / qk->qu must exist in the target image
            if self.q[(qu, qk)] != 0.0 && self.g[(gv, gk)] == 0.0 {
                return false;
            }
            if self.q[(qk, qu)] != 0.0 && self.g[(gk, gv)] == 0.0 {
                return false;
            }
        }
        true
    }

    /// Look-ahead: the target vertex must have at least as many unmapped
    /// in/out neighbors as the query vertex needs (1-look-ahead cut).
    fn lookahead(&self, qu: usize, gv: usize) -> bool {
        let q_out_need = (0..self.n)
            .filter(|&k| self.q[(qu, k)] != 0.0 && self.core_q[k].is_none())
            .count();
        let g_out_have = (0..self.m)
            .filter(|&l| self.g[(gv, l)] != 0.0 && self.core_g[l].is_none())
            .count();
        if g_out_have < q_out_need {
            return false;
        }
        let q_in_need = (0..self.n)
            .filter(|&k| self.q[(k, qu)] != 0.0 && self.core_q[k].is_none())
            .count();
        let g_in_have = (0..self.m)
            .filter(|&l| self.g[(l, gv)] != 0.0 && self.core_g[l].is_none())
            .count();
        g_in_have >= q_in_need
    }

    /// Next query vertex to extend: an unmapped vertex adjacent to the
    /// mapped core if one exists (frontier-first), else the first
    /// unmapped vertex.
    fn next_query(&self) -> Option<usize> {
        let mut fallback = None;
        for u in 0..self.n {
            if self.core_q[u].is_some() {
                continue;
            }
            if fallback.is_none() {
                fallback = Some(u);
            }
            let frontier = (0..self.n).any(|k| {
                self.core_q[k].is_some() && (self.q[(u, k)] != 0.0 || self.q[(k, u)] != 0.0)
            });
            if frontier {
                return Some(u);
            }
        }
        fallback
    }

    fn search(&mut self, depth: usize) -> bool {
        if depth == self.n {
            return true;
        }
        if self.stats.states >= self.budget {
            return false;
        }
        let Some(qu) = self.next_query() else { return false };
        for gv in 0..self.m {
            if self.core_g[gv].is_some() || self.mask[(qu, gv)] == 0.0 {
                continue;
            }
            if !self.consistent(qu, gv) || !self.lookahead(qu, gv) {
                self.stats.pruned += 1;
                continue;
            }
            self.stats.states += 1;
            self.core_q[qu] = Some(gv);
            self.core_g[gv] = Some(qu);
            if self.search(depth + 1) {
                return true;
            }
            self.core_q[qu] = None;
            self.core_g[gv] = None;
        }
        false
    }
}

/// Find the first embedding with VF2 (or `None` on exhaustion/budget).
pub fn vf2_find_first(mask: &MatF, q: &MatF, g: &MatF, budget: u64) -> (Option<Mapping>, Vf2Stats) {
    let mut vf2 = Vf2::new(mask, q, g, budget);
    let found = vf2.search(0);
    let stats = vf2.stats;
    if found {
        let mapping = vf2.core_q.clone();
        debug_assert!(mapping_is_feasible(&mapping, q, g));
        (Some(mapping), stats)
    } else {
        (None, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_chain, NodeKind};
    use crate::matcher::ullmann::{plant_embedding, ullmann_find_first};
    use crate::matcher::build_mask;
    use crate::util::Rng;

    #[test]
    fn finds_chain_embedding() {
        let qd = gen_chain(3, NodeKind::Compute);
        let gd = gen_chain(6, NodeKind::Universal);
        let mask = build_mask(&qd, &gd);
        let (found, stats) = vf2_find_first(&mask, &qd.adjacency(), &gd.adjacency(), 1_000_000);
        let mp = found.expect("chain embeds");
        assert!(mapping_is_feasible(&mp, &qd.adjacency(), &gd.adjacency()));
        assert!(stats.states >= 3);
    }

    #[test]
    fn agrees_with_ullmann_on_planted_instances() {
        let mut rng = Rng::new(71);
        for trial in 0..25 {
            let n = rng.range(3, 7);
            let m = n + rng.range(2, 8);
            let (q, g, _) = plant_embedding(n, m, 0.4, 0.2, &mut rng);
            let mask = MatF::full(n, m, 1.0);
            let (vf2, _) = vf2_find_first(&mask, &q, &g, 10_000_000);
            let (ull, _) = ullmann_find_first(&mask, &q, &g, 10_000_000);
            assert_eq!(vf2.is_some(), ull.is_some(), "trial {trial}: engines disagree");
            if let Some(mp) = vf2 {
                assert!(mapping_is_feasible(&mp, &q, &g), "trial {trial}");
            }
        }
    }

    #[test]
    fn rejects_impossible_embedding() {
        let qd = gen_chain(5, NodeKind::Compute);
        let gd = gen_chain(3, NodeKind::Universal);
        let mask = MatF::full(5, 3, 1.0);
        let (found, _) = vf2_find_first(&mask, &qd.adjacency(), &gd.adjacency(), 1_000_000);
        assert!(found.is_none());
    }

    #[test]
    fn respects_mask() {
        let qd = gen_chain(2, NodeKind::Compute);
        let gd = gen_chain(4, NodeKind::Universal);
        let mut mask = build_mask(&qd, &gd);
        // forbid query 0 on target 0 — the only other chain start is 1/2
        mask[(0, 0)] = 0.0;
        let (found, _) = vf2_find_first(&mask, &qd.adjacency(), &gd.adjacency(), 1_000_000);
        let mp = found.unwrap();
        assert_ne!(mp[0], Some(0));
    }

    #[test]
    fn budget_exhaustion_returns_none() {
        let mut rng = Rng::new(73);
        let (q, g, _) = plant_embedding(8, 20, 0.5, 0.3, &mut rng);
        let mask = MatF::full(8, 20, 1.0);
        let (found, _) = vf2_find_first(&mask, &q, &g, 1);
        assert!(found.is_none());
    }

    #[test]
    fn vf2_prunes_more_than_it_expands_on_dense_targets() {
        let mut rng = Rng::new(79);
        let (q, g, _) = plant_embedding(6, 16, 0.5, 0.4, &mut rng);
        let mask = MatF::full(6, 16, 1.0);
        let (_, stats) = vf2_find_first(&mask, &q, &g, 10_000_000);
        assert!(stats.states > 0);
    }
}
