//! Shared PJRT CPU client.
//!
//! PJRT client construction is expensive (thread pools, allocator) and the
//! `xla` crate's client is not `Sync`-shareable across arbitrary threads,
//! so the coordinator creates one [`RuntimeClient`] and keeps it on the
//! controller thread; everything reaching the runtime goes through the
//! controller's channel (DESIGN.md §8: single-owner hot path, no locks).

use anyhow::{Context, Result};

/// Wrapper over the PJRT CPU client.
pub struct RuntimeClient {
    client: xla::PjRtClient,
}

impl RuntimeClient {
    /// Create the CPU client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    /// Platform name ("cpu" / "Host").
    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Number of addressable devices.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Compile an HLO-text file into a loaded executable.
    pub fn compile_hlo_text(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    /// Access to the raw client (tests).
    pub fn raw(&self) -> &xla::PjRtClient {
        &self.client
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let c = RuntimeClient::cpu().expect("client");
        assert!(c.device_count() >= 1);
    }
}
