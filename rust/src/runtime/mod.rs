//! Epoch runtime: execution backends for the fused PSO epoch.
//!
//! The controller speaks the [`EpochBackend`] trait and never cares
//! which substrate serves an epoch:
//!
//! * [`NativeEpochBackend`] — pure-rust epoch at the artifact's padded
//!   dims (default; always compiled, threads across particles under the
//!   `parallel` feature);
//! * [`EpochRunner`] — AOT-compiled HLO-text artifacts through the PJRT
//!   CPU client (`pjrt` feature; the only place the crate touches the
//!   `xla` crate).
//!
//! The interchange contract with `python/compile/aot.py`:
//!
//! * artifacts are HLO **text** (`pso_epoch_<class>.hlo.txt`) — jax ≥ 0.5
//!   serialized protos carry 64-bit instruction ids the bundled
//!   xla_extension 0.5.1 rejects; the text parser reassigns ids;
//! * `artifacts/manifest.txt` lists `name n m particles k_steps` per class;
//! * the entry computation takes 11 parameters
//!   `(S, V, S_local, f_local, S*, S̄, Mask, Q, G, seed, coefs)` and
//!   returns a 5-tuple `(S', V', S_local', f_local', f_last)`
//!   (lowered with `return_tuple=True`).
//!
//! [`ArtifactRegistry`] (XLA-free) discovers artifacts either way, so
//! `immsched info` reports them even in a default build.

mod artifact;
pub mod backend;
#[cfg(feature = "pjrt")]
mod client;
mod matcher_exec;

pub use artifact::{Artifact, ArtifactRegistry, SizeClass};
pub use backend::{
    default_backends, BackendKind, EpochBackend, NativeEpochBackend, NATIVE_SIZE_CLASSES,
};
#[cfg(feature = "pjrt")]
pub use client::RuntimeClient;
pub use matcher_exec::{EpochInputs, EpochOutputs};
#[cfg(feature = "pjrt")]
pub use matcher_exec::EpochRunner;
