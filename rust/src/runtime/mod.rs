//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only place the crate touches the `xla` crate.  The
//! interchange contract with `python/compile/aot.py`:
//!
//! * artifacts are HLO **text** (`pso_epoch_<class>.hlo.txt`) — jax ≥ 0.5
//!   serialized protos carry 64-bit instruction ids the bundled
//!   xla_extension 0.5.1 rejects; the text parser reassigns ids;
//! * `artifacts/manifest.txt` lists `name n m particles k_steps` per class;
//! * the entry computation takes 11 parameters
//!   `(S, V, S_local, f_local, S*, S̄, Mask, Q, G, seed, coefs)` and
//!   returns a 5-tuple `(S', V', S_local', f_local', f_last)`
//!   (lowered with `return_tuple=True`).
//!
//! [`EpochRunner`] owns one compiled executable per size class and reuses
//! flat buffers so the interrupt hot path performs no allocation beyond
//! what PJRT itself requires.

mod artifact;
mod client;
mod matcher_exec;

pub use artifact::{Artifact, ArtifactRegistry, SizeClass};
pub use client::RuntimeClient;
pub use matcher_exec::{EpochInputs, EpochOutputs, EpochRunner};
