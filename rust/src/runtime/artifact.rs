//! Artifact registry: discovery + metadata for the AOT size classes.
//!
//! `make artifacts` writes `artifacts/manifest.txt` with one line per
//! lowered size class (`name n m particles k_steps`); this module parses
//! it, locates the HLO files, and picks the smallest class that fits a
//! given (query, target) problem — queries are padded up to the class
//! dims with isolated vertices and an all-zero mask (padding rows cannot
//! influence the fitness of real rows because their S rows are zero).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One AOT size class (must mirror python/compile/model.py::SIZE_CLASSES).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SizeClass {
    /// Max query vertices (padded n).
    pub n: usize,
    /// Max target vertices (padded m).
    pub m: usize,
    /// Particle count per epoch.
    pub particles: usize,
    /// Fused PSO steps per epoch.
    pub k_steps: usize,
}

impl SizeClass {
    /// Whether a (n_query, m_target) problem fits in this class.
    pub fn fits(&self, n: usize, m: usize) -> bool {
        n <= self.n && m <= self.m
    }

    /// Working-set cost proxy used to order classes (smaller = cheaper).
    pub fn cost(&self) -> usize {
        self.particles * self.n * self.m
    }
}

/// A discovered artifact: metadata + path to the HLO text.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub class: SizeClass,
    pub path: PathBuf,
}

/// All artifacts from a manifest, ordered by ascending cost.
#[derive(Clone, Debug, Default)]
pub struct ArtifactRegistry {
    artifacts: Vec<Artifact>,
}

impl ArtifactRegistry {
    /// Parse `<dir>/manifest.txt` and verify the HLO files exist.
    pub fn discover(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {} (run `make artifacts`)", manifest.display()))?;
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 5 {
                bail!("manifest line {}: expected 5 fields, got {:?}", lineno + 1, parts);
            }
            let name = parts[0].to_string();
            let nums: Vec<usize> = parts[1..]
                .iter()
                .map(|p| p.parse().with_context(|| format!("manifest line {}", lineno + 1)))
                .collect::<Result<_>>()?;
            let path = dir.join(format!("pso_epoch_{name}.hlo.txt"));
            if !path.exists() {
                bail!("manifest references missing artifact {}", path.display());
            }
            artifacts.push(Artifact {
                name,
                class: SizeClass { n: nums[0], m: nums[1], particles: nums[2], k_steps: nums[3] },
                path,
            });
        }
        if artifacts.is_empty() {
            bail!("manifest {} lists no artifacts", manifest.display());
        }
        artifacts.sort_by_key(|a| a.class.cost());
        Ok(Self { artifacts })
    }

    /// All artifacts, cheapest first.
    pub fn all(&self) -> &[Artifact] {
        &self.artifacts
    }

    /// Smallest class that fits the given problem dims.
    pub fn select(&self, n: usize, m: usize) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.class.fits(n, m))
    }

    /// Look up by class name.
    pub fn by_name(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Default artifact directory: `$IMMSCHED_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("IMMSCHED_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str, classes: &[&str]) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), body).unwrap();
        for c in classes {
            std::fs::write(dir.join(format!("pso_epoch_{c}.hlo.txt")), "HloModule fake").unwrap();
        }
    }

    #[test]
    fn parses_manifest_and_orders_by_cost() {
        let dir = std::env::temp_dir().join("immsched_test_manifest_a");
        write_manifest(&dir, "big 64 128 16 8\ntiny 8 16 8 8\n", &["big", "tiny"]);
        let reg = ArtifactRegistry::discover(&dir).unwrap();
        assert_eq!(reg.all().len(), 2);
        assert_eq!(reg.all()[0].name, "tiny");
        assert_eq!(reg.select(10, 10).unwrap().name, "big"); // n=10 > tiny.n=8
        assert_eq!(reg.select(4, 10).unwrap().name, "tiny");
        assert!(reg.select(100, 10).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_an_error() {
        let dir = std::env::temp_dir().join("immsched_test_manifest_b");
        write_manifest(&dir, "ghost 8 16 8 8\n", &[]);
        assert!(ArtifactRegistry::discover(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_line_is_an_error() {
        let dir = std::env::temp_dir().join("immsched_test_manifest_c");
        write_manifest(&dir, "bad 8 16\n", &["bad"]);
        assert!(ArtifactRegistry::discover(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
