//! Pluggable epoch execution backends.
//!
//! The global controller drives Algorithm 1 one *epoch* at a time
//! through the [`EpochBackend`] trait: hand in the flat
//! [`EpochInputs`] (particle states + frozen S*/S̄ attractors + problem
//! matrices) plus a reusable [`EpochOutputs`], get back the advanced
//! states + per-particle local bests. Two implementations exist:
//!
//! * [`NativeEpochBackend`] (always compiled, the default): the pure-rust
//!   twin of the AOT artifact, reusing the [`crate::matcher::pso`]
//!   per-particle epoch at the artifact's padded dims. Fans out across
//!   threads under the `parallel` feature. The backend owns a persistent
//!   per-size-class workspace (sparse fitness kernel, per-worker scratch
//!   arenas, RNG streams), so a steady-state `run_epoch_into` against a
//!   caller-reused `EpochOutputs` performs **zero heap allocations** —
//!   the particle state advances inside the caller's flat buffers, no
//!   `MatF` is ever materialized.
//! * [`crate::runtime::EpochRunner`] (`pjrt` feature): the compiled HLO
//!   artifact through the PJRT CPU client.
//!
//! Both honor the same calling convention pinned by
//! `python/compile/model.py::epoch_fn`, so the controller is oblivious
//! to which one serves an interrupt.

use anyhow::Result;

use crate::matcher::pso::{
    epoch_workers, run_epoch_slices, EpochSlices, StepParams, PARALLEL_WORK_THRESHOLD,
};
use crate::matcher::{FitnessKernel, FitnessScratch};
use crate::util::Rng;

use super::artifact::SizeClass;
use super::matcher_exec::{EpochInputs, EpochOutputs};

/// Which execution substrate a backend runs on (telemetry / path
/// reporting).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-rust epoch (no XLA involved).
    Native,
    /// AOT HLO artifact through PJRT.
    Pjrt,
}

/// One compiled/installed epoch executor for one size class.
pub trait EpochBackend {
    /// Padded dims + particle count this backend serves.
    fn class(&self) -> SizeClass;
    /// Human-readable size-class name ("small", "medium", ...).
    fn name(&self) -> &str;
    /// Execution substrate (drives `MatchPath` telemetry).
    fn kind(&self) -> BackendKind;
    /// Advance every particle by the class's K fused steps, writing the
    /// advanced states into `out` (buffers are resized to the class
    /// dims; pass the same `EpochOutputs` every epoch to keep the
    /// steady state allocation-free).
    fn run_epoch_into(&mut self, inputs: &EpochInputs, out: &mut EpochOutputs) -> Result<()>;
    /// Convenience wrapper allocating fresh outputs per call.
    fn run_epoch(&mut self, inputs: &EpochInputs) -> Result<EpochOutputs> {
        let mut out = EpochOutputs::zeros(self.class());
        self.run_epoch_into(inputs, &mut out)?;
        Ok(out)
    }
}

/// Mirror of `python/compile/model.py::SIZE_CLASSES` — the size classes
/// the native backend instantiates when no artifacts are available.
pub const NATIVE_SIZE_CLASSES: [(&str, SizeClass); 4] = [
    ("small", SizeClass { n: 8, m: 16, particles: 8, k_steps: 8 }),
    ("medium", SizeClass { n: 16, m: 32, particles: 16, k_steps: 8 }),
    ("large", SizeClass { n: 32, m: 64, particles: 16, k_steps: 8 }),
    ("xlarge", SizeClass { n: 64, m: 128, particles: 16, k_steps: 8 }),
];

/// Persistent per-size-class scratch: everything `run_epoch_into` needs
/// beyond the caller's flat buffers, preallocated at worst-case capacity
/// so the steady state never touches the allocator.
struct Workspace {
    /// Sparse fitness kernel; CSR capacity covers a fully dense (Q, G)
    /// at the class dims, so per-interrupt rebuilds are allocation-free.
    kernel: FitnessKernel,
    /// One scratch arena per potential worker (≤ particles).
    scratch: Vec<FitnessScratch>,
    /// Per-step fitness record, `particles × k_steps`.
    fits: Vec<f32>,
    /// Forked per-particle RNG streams (refilled in place per epoch).
    rngs: Vec<Rng>,
}

impl Workspace {
    fn new(class: SizeClass) -> Self {
        let (p, n, m) = (class.particles, class.n, class.m);
        Self {
            kernel: FitnessKernel::with_capacity(n, m),
            // worst case one worker per particle — with_threads can ask
            // for any fan-out without outgrowing the scratch pool
            scratch: (0..p.max(1)).map(|_| FitnessScratch::new(n, m)).collect(),
            fits: vec![f32::NEG_INFINITY; p * class.k_steps],
            rngs: Vec::with_capacity(p),
        }
    }
}

/// The pure-rust epoch executor: same contract as the PJRT artifact,
/// no XLA anywhere.
pub struct NativeEpochBackend {
    name: String,
    class: SizeClass,
    /// Worker threads for the particle fan-out (0 = one per core).
    threads: usize,
    /// Continuous relaxation (true = IMMSched; false = the discrete
    /// coupling of the Fig. 2b ablation).
    relaxed: bool,
    ws: Workspace,
}

impl NativeEpochBackend {
    pub fn new(name: impl Into<String>, class: SizeClass) -> Self {
        Self { name: name.into(), class, threads: 0, relaxed: true, ws: Workspace::new(class) }
    }

    /// Cap the intra-epoch worker count (0 = auto). Results are
    /// identical for any worker count; this only bounds CPU use (and
    /// `with_threads(1)` pins the allocation-free serial path).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Select the fitness coupling. Only the native backend can run the
    /// discrete ablation — the PJRT artifact is lowered relaxed-only.
    pub fn with_relaxed(mut self, relaxed: bool) -> Self {
        self.relaxed = relaxed;
        self
    }

    /// One backend per default size class, cheapest first.
    pub fn default_set() -> Vec<NativeEpochBackend> {
        NATIVE_SIZE_CLASSES
            .iter()
            .map(|(name, class)| NativeEpochBackend::new(*name, *class))
            .collect()
    }
}

/// The default backend set for a controller: one native backend per size
/// class (boxed for the controller's trait-object storage).
pub fn default_backends() -> Vec<Box<dyn EpochBackend>> {
    NativeEpochBackend::default_set()
        .into_iter()
        .map(|b| Box::new(b) as Box<dyn EpochBackend>)
        .collect()
}

impl EpochBackend for NativeEpochBackend {
    fn class(&self) -> SizeClass {
        self.class
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Native
    }

    fn run_epoch_into(&mut self, inputs: &EpochInputs, out: &mut EpochOutputs) -> Result<()> {
        inputs.validate(self.class)?;
        let (p_cnt, n, m) = (self.class.particles, self.class.n, self.class.m);
        let k_steps = self.class.k_steps;
        let nm = n * m;
        let params = StepParams {
            w: inputs.coefs[0],
            c1: inputs.coefs[1],
            c2: inputs.coefs[2],
            c3: inputs.coefs[3],
            relaxed: self.relaxed,
        };

        // the epoch advances the particle state *inside* the caller's
        // output buffers — borrow + copy_from_slice, never a fresh MatF
        out.s.resize(p_cnt * nm, 0.0);
        out.s.copy_from_slice(&inputs.s);
        out.v.resize(p_cnt * nm, 0.0);
        out.v.copy_from_slice(&inputs.v);
        out.s_local.resize(p_cnt * nm, 0.0);
        out.s_local.copy_from_slice(&inputs.s_local);
        out.f_local.resize(p_cnt, 0.0);
        out.f_local.copy_from_slice(&inputs.f_local);
        out.f_last.resize(p_cnt, 0.0);

        let work = p_cnt * k_steps * nm;
        let threaded =
            cfg!(feature = "parallel") && p_cnt > 1 && work >= PARALLEL_WORK_THRESHOLD;
        let workers = epoch_workers(threaded, self.threads, p_cnt);

        let Workspace { kernel, scratch, fits, rngs } = &mut self.ws;
        kernel.rebuild(&inputs.q, n, &inputs.g, m);
        // one independent RNG stream per particle, forked in index order
        // (the artifact folds its threefry key the same way)
        let mut master = Rng::new(inputs.seed as u64 ^ 0xAE70_C41E);
        rngs.clear();
        for i in 0..p_cnt {
            rngs.push(master.fork(i as u64));
        }

        run_epoch_slices(
            EpochSlices {
                s: &mut out.s,
                v: &mut out.v,
                s_local: &mut out.s_local,
                f_local: &mut out.f_local,
                fits: &mut fits[..p_cnt * k_steps],
                rngs: &mut rngs[..],
            },
            scratch,
            kernel,
            &inputs.s_star,
            &inputs.s_bar,
            &inputs.mask,
            k_steps,
            &params,
            workers,
        );

        for (i, fl) in out.f_last.iter_mut().enumerate() {
            *fl = if k_steps > 0 {
                fits[i * k_steps + k_steps - 1]
            } else {
                f32::NEG_INFINITY
            };
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_backend() -> NativeEpochBackend {
        let (name, class) = NATIVE_SIZE_CLASSES[0];
        NativeEpochBackend::new(name, class)
    }

    fn random_inputs(class: SizeClass, seed: u64) -> EpochInputs {
        let (p, n, m) = (class.particles, class.n, class.m);
        let mut rng = Rng::new(seed);
        let mut inputs = EpochInputs::zeros(class);
        inputs.mask.iter_mut().for_each(|x| *x = 1.0);
        for x in inputs.q.iter_mut() {
            *x = if rng.chance(0.25) { 1.0 } else { 0.0 };
        }
        for x in inputs.g.iter_mut() {
            *x = if rng.chance(0.5) { 1.0 } else { 0.0 };
        }
        for part in 0..p {
            for i in 0..n {
                let row = &mut inputs.s[(part * n + i) * m..(part * n + i + 1) * m];
                let mut sum = 0.0;
                for x in row.iter_mut() {
                    *x = rng.f32() + 1e-3;
                    sum += *x;
                }
                row.iter_mut().for_each(|x| *x /= sum);
            }
        }
        inputs.s_local.copy_from_slice(&inputs.s);
        inputs.s_star.copy_from_slice(&inputs.s[..n * m]);
        inputs.s_bar.copy_from_slice(&inputs.s[..n * m]);
        inputs.seed = 42;
        inputs
    }

    /// The native backend honors the artifact's structural contract:
    /// stochastic S' rows, finite local bests dominating the final step.
    #[test]
    fn native_epoch_preserves_invariants() {
        let mut backend = small_backend();
        let class = backend.class();
        let (p, n, m) = (class.particles, class.n, class.m);
        let inputs = random_inputs(class, 1);
        let out = backend.run_epoch(&inputs).expect("epoch");
        assert_eq!(out.s.len(), p * n * m);
        assert_eq!(out.f_local.len(), p);
        assert_eq!(out.f_last.len(), p);
        for part in 0..p {
            for i in 0..n {
                let sum: f32 = out.s[(part * n + i) * m..(part * n + i + 1) * m].iter().sum();
                assert!((sum - 1.0).abs() < 1e-3, "row sum {sum}");
            }
        }
        for part in 0..p {
            assert!(out.f_local[part].is_finite());
            assert!(out.f_local[part] >= out.f_last[part] - 1e-3);
        }
    }

    /// Same inputs → same outputs, regardless of thread interleaving —
    /// and regardless of whether the outputs buffer is fresh or reused.
    #[test]
    fn native_epoch_is_deterministic() {
        let mut backend = small_backend();
        let inputs = random_inputs(backend.class(), 2);
        let a = backend.run_epoch(&inputs).expect("epoch a");
        let b = backend.run_epoch(&inputs).expect("epoch b");
        assert_eq!(a.s, b.s);
        assert_eq!(a.v, b.v);
        assert_eq!(a.f_local, b.f_local);
        assert_eq!(a.f_last, b.f_last);
        // reused outputs buffer: identical again
        let mut reused = EpochOutputs::zeros(backend.class());
        backend.run_epoch_into(&inputs, &mut reused).expect("epoch c");
        assert_eq!(a.s, reused.s);
        assert_eq!(a.f_last, reused.f_last);
    }

    /// The worker-count knob bounds CPU use only — never the numbers.
    #[test]
    fn thread_cap_does_not_change_results() {
        let (name, class) = NATIVE_SIZE_CLASSES[0];
        let inputs = random_inputs(class, 4);
        let auto = NativeEpochBackend::new(name, class).run_epoch(&inputs).expect("auto");
        let pinned = NativeEpochBackend::new(name, class)
            .with_threads(1)
            .run_epoch(&inputs)
            .expect("pinned");
        assert_eq!(auto.s, pinned.s);
        assert_eq!(auto.f_local, pinned.f_local);
    }

    /// Padding rows (zero mask) must stay zero through the epoch.
    #[test]
    fn padding_rows_stay_zero() {
        let mut backend = small_backend();
        let class = backend.class();
        let (p, n, m) = (class.particles, class.n, class.m);
        let mut inputs = random_inputs(class, 3);
        // zero the mask + S rows of the bottom half (padding region)
        for i in n / 2..n {
            inputs.mask[i * m..(i + 1) * m].iter_mut().for_each(|x| *x = 0.0);
            for part in 0..p {
                inputs.s[(part * n + i) * m..(part * n + i + 1) * m]
                    .iter_mut()
                    .for_each(|x| *x = 0.0);
                inputs.s_local[(part * n + i) * m..(part * n + i + 1) * m]
                    .iter_mut()
                    .for_each(|x| *x = 0.0);
            }
        }
        let out = backend.run_epoch(&inputs).expect("epoch");
        for part in 0..p {
            for i in n / 2..n {
                let row = &out.s[(part * n + i) * m..(part * n + i + 1) * m];
                assert!(row.iter().all(|&x| x == 0.0), "padding row leaked mass");
            }
        }
    }

    #[test]
    fn wrong_shape_is_rejected() {
        let mut backend = small_backend();
        let mut inputs = EpochInputs::zeros(backend.class());
        inputs.s.pop();
        assert!(backend.run_epoch(&inputs).is_err());
    }

    #[test]
    fn default_set_is_ordered_and_fits() {
        let set = NativeEpochBackend::default_set();
        assert_eq!(set.len(), NATIVE_SIZE_CLASSES.len());
        assert!(set.windows(2).all(|w| w[0].class().cost() <= w[1].class().cost()));
        assert!(set.iter().any(|b| b.class().fits(4, 8)));
    }
}
