//! Epoch I/O contract + the PJRT epoch executor.
//!
//! [`EpochInputs`]/[`EpochOutputs`] are the flat interchange types every
//! [`super::backend::EpochBackend`] speaks; they are XLA-free and always
//! compiled. [`EpochRunner`] (behind the `pjrt` feature) wraps one
//! compiled size class.  The calling convention (argument order, shapes,
//! 5-tuple output) is pinned by `python/compile/model.py::epoch_fn` —
//! change either side only with the other.

use anyhow::{ensure, Result};
#[cfg(feature = "pjrt")]
use anyhow::Context;

use super::artifact::SizeClass;

/// Flat row-major epoch inputs at the class's padded dims.
///
/// `s`, `v`, `s_local` are `(particles, n, m)`; `f_local` is `(particles,)`;
/// `s_star`, `s_bar`, `mask` are `(n, m)`; `q` is `(n, n)`; `g` is `(m, m)`.
#[derive(Clone, Debug)]
pub struct EpochInputs {
    pub s: Vec<f32>,
    pub v: Vec<f32>,
    pub s_local: Vec<f32>,
    pub f_local: Vec<f32>,
    pub s_star: Vec<f32>,
    pub s_bar: Vec<f32>,
    pub mask: Vec<f32>,
    pub q: Vec<f32>,
    pub g: Vec<f32>,
    pub seed: u32,
    /// `[w, c1, c2, c3]` PSO coefficients.
    pub coefs: [f32; 4],
}

impl EpochInputs {
    /// Zero-initialized inputs for a size class (S rows all-zero; callers
    /// fill real data and masks).
    pub fn zeros(class: SizeClass) -> Self {
        let (p, n, m) = (class.particles, class.n, class.m);
        Self {
            s: vec![0.0; p * n * m],
            v: vec![0.0; p * n * m],
            s_local: vec![0.0; p * n * m],
            f_local: vec![f32::NEG_INFINITY; p],
            s_star: vec![0.0; n * m],
            s_bar: vec![0.0; n * m],
            mask: vec![0.0; n * m],
            q: vec![0.0; n * n],
            g: vec![0.0; m * m],
            seed: 0,
            coefs: [0.72, 1.49, 1.49, 0.6],
        }
    }

    /// Check every buffer against the class's padded dims.
    pub(crate) fn validate(&self, class: SizeClass) -> Result<()> {
        let (p, n, m) = (class.particles, class.n, class.m);
        ensure!(self.s.len() == p * n * m, "s len {} != {}", self.s.len(), p * n * m);
        ensure!(self.v.len() == p * n * m, "v len mismatch");
        ensure!(self.s_local.len() == p * n * m, "s_local len mismatch");
        ensure!(self.f_local.len() == p, "f_local len mismatch");
        ensure!(self.s_star.len() == n * m, "s_star len mismatch");
        ensure!(self.s_bar.len() == n * m, "s_bar len mismatch");
        ensure!(self.mask.len() == n * m, "mask len mismatch");
        ensure!(self.q.len() == n * n, "q len mismatch");
        ensure!(self.g.len() == m * m, "g len mismatch");
        Ok(())
    }
}

/// Flat epoch outputs (same layout as the corresponding inputs).
#[derive(Clone, Debug)]
pub struct EpochOutputs {
    pub s: Vec<f32>,
    pub v: Vec<f32>,
    pub s_local: Vec<f32>,
    pub f_local: Vec<f32>,
    pub f_last: Vec<f32>,
}

impl EpochOutputs {
    /// Zero-initialized outputs at a class's padded dims. Allocate one
    /// per episode and pass it to `run_epoch_into` every epoch — the
    /// native backend then runs allocation-free in steady state.
    pub fn zeros(class: SizeClass) -> Self {
        let (p, n, m) = (class.particles, class.n, class.m);
        Self {
            s: vec![0.0; p * n * m],
            v: vec![0.0; p * n * m],
            s_local: vec![0.0; p * n * m],
            f_local: vec![f32::NEG_INFINITY; p],
            f_last: vec![f32::NEG_INFINITY; p],
        }
    }
}

/// A compiled `pso_epoch` executable for one size class.
#[cfg(feature = "pjrt")]
pub struct EpochRunner {
    class: SizeClass,
    name: String,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl EpochRunner {
    /// Compile the artifact on the given client.
    pub fn load(
        client: &super::client::RuntimeClient,
        artifact: &super::artifact::Artifact,
    ) -> Result<Self> {
        let exe = client
            .compile_hlo_text(&artifact.path)
            .with_context(|| format!("loading epoch artifact '{}'", artifact.name))?;
        Ok(Self { class: artifact.class, name: artifact.name.clone(), exe })
    }

    pub fn class(&self) -> SizeClass {
        self.class
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute one epoch.  Shapes are checked against the size class.
    pub fn run(&self, inputs: &EpochInputs) -> Result<EpochOutputs> {
        inputs.validate(self.class)?;
        let (p, n, m) = (
            self.class.particles as i64,
            self.class.n as i64,
            self.class.m as i64,
        );
        let lit3 = |data: &[f32]| -> Result<xla::Literal> {
            Ok(xla::Literal::vec1(data).reshape(&[p, n, m])?)
        };
        let lit2 = |data: &[f32], r: i64, c: i64| -> Result<xla::Literal> {
            Ok(xla::Literal::vec1(data).reshape(&[r, c])?)
        };
        let args: Vec<xla::Literal> = vec![
            lit3(&inputs.s)?,
            lit3(&inputs.v)?,
            lit3(&inputs.s_local)?,
            xla::Literal::vec1(&inputs.f_local),
            lit2(&inputs.s_star, n, m)?,
            lit2(&inputs.s_bar, n, m)?,
            lit2(&inputs.mask, n, m)?,
            lit2(&inputs.q, n, n)?,
            lit2(&inputs.g, m, m)?,
            xla::Literal::scalar(inputs.seed),
            xla::Literal::vec1(&inputs.coefs),
        ];
        let result = self
            .exe
            .execute::<xla::Literal>(&args)
            .context("executing pso_epoch")?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching epoch outputs")?
            .to_tuple()
            .context("decomposing epoch output tuple")?;
        ensure!(tuple.len() == 5, "expected 5 outputs, got {}", tuple.len());
        let mut it = tuple.into_iter();
        let mut take = |what: &str| -> Result<Vec<f32>> {
            it.next()
                .with_context(|| format!("missing output {what}"))?
                .to_vec::<f32>()
                .with_context(|| format!("reading output {what}"))
        };
        Ok(EpochOutputs {
            s: take("s")?,
            v: take("v")?,
            s_local: take("s_local")?,
            f_local: take("f_local")?,
            f_last: take("f_last")?,
        })
    }
}

#[cfg(feature = "pjrt")]
impl super::backend::EpochBackend for EpochRunner {
    fn class(&self) -> SizeClass {
        self.class
    }

    fn name(&self) -> &str {
        &self.name
    }

    fn kind(&self) -> super::backend::BackendKind {
        super::backend::BackendKind::Pjrt
    }

    fn run_epoch_into(&mut self, inputs: &EpochInputs, out: &mut EpochOutputs) -> Result<()> {
        // PJRT owns its device buffers; the host-side copy is inherent
        // to the literal transfer, so no workspace reuse here.
        *out = self.run(inputs)?;
        Ok(())
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use crate::runtime::{ArtifactRegistry, RuntimeClient};

    fn registry() -> Option<ArtifactRegistry> {
        ArtifactRegistry::discover(&ArtifactRegistry::default_dir()).ok()
    }

    /// End-to-end PJRT smoke: load the smallest artifact, run one epoch,
    /// and check the structural invariants the L2 model guarantees.
    #[test]
    fn epoch_runs_and_preserves_invariants() {
        let Some(reg) = registry() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let client = RuntimeClient::cpu().expect("client");
        let artifact = &reg.all()[0];
        let runner = EpochRunner::load(&client, artifact).expect("compile");
        let class = runner.class();
        let (p, n, m) = (class.particles, class.n, class.m);

        let mut rng = crate::util::Rng::new(1);
        let mut inputs = EpochInputs::zeros(class);
        // Full mask, sparse random graphs, row-stochastic random S.
        inputs.mask.iter_mut().for_each(|x| *x = 1.0);
        for i in 0..n * n {
            inputs.q[i] = if rng.chance(0.25) { 1.0 } else { 0.0 };
        }
        for i in 0..m * m {
            inputs.g[i] = if rng.chance(0.5) { 1.0 } else { 0.0 };
        }
        for part in 0..p {
            for i in 0..n {
                let row = &mut inputs.s[(part * n + i) * m..(part * n + i + 1) * m];
                let mut sum = 0.0;
                for x in row.iter_mut() {
                    *x = rng.f32() + 1e-3;
                    sum += *x;
                }
                row.iter_mut().for_each(|x| *x /= sum);
            }
        }
        inputs.s_local.copy_from_slice(&inputs.s);
        inputs.s_star.copy_from_slice(&inputs.s[..n * m]);
        inputs.s_bar.copy_from_slice(&inputs.s[..n * m]);
        inputs.seed = 42;

        let out = runner.run(&inputs).expect("epoch");
        assert_eq!(out.s.len(), p * n * m);
        assert_eq!(out.f_local.len(), p);
        assert_eq!(out.f_last.len(), p);
        // Rows of S' are stochastic.
        for part in 0..p {
            for i in 0..n {
                let sum: f32 = out.s[(part * n + i) * m..(part * n + i + 1) * m].iter().sum();
                assert!((sum - 1.0).abs() < 1e-3, "row sum {sum}");
            }
        }
        // Local best dominates final fitness, and everything is finite.
        for part in 0..p {
            assert!(out.f_local[part].is_finite());
            assert!(out.f_local[part] >= out.f_last[part] - 1e-3);
        }
        // Determinism: same inputs -> same outputs.
        let out2 = runner.run(&inputs).expect("epoch 2");
        assert_eq!(out.s, out2.s);
        assert_eq!(out.f_last, out2.f_last);
    }
}
