//! Middle-class workloads: EfficientNet-B0, NASNet-A, PNASNet-5
//! (paper §4.1.2 — "typically used in NAS").  These graphs are much
//! *branchier* than the Simple class: NAS cells have multi-input
//! concatenations, which is exactly the topological complexity the
//! subgraph matcher has to absorb.

use crate::workload::layers::{Layer, LayerGraph, LayerOp};

/// EfficientNet-B0 (Tan & Le, ICML'19): MBConv blocks, SE omitted from
/// topology (its FLOPs are folded into the expand conv weight).
pub fn efficientnet_b0() -> LayerGraph {
    let mut g = LayerGraph::new("EfficientNet-B0");
    let mut prev = g.push(Layer::build("stem", LayerOp::Conv { k: 3, s: 2 }, 112, 3, 32));

    // (expansion, channels, repeats, stride, kernel)
    let cfg: [(usize, usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1, 3),
        (6, 24, 2, 2, 3),
        (6, 40, 2, 2, 5),
        (6, 80, 3, 2, 3),
        (6, 112, 3, 1, 5),
        (6, 192, 4, 2, 5),
        (6, 320, 1, 1, 3),
    ];
    let mut hw = 112;
    let mut cin = 32;
    for (bi, &(t, c, n, s, k)) in cfg.iter().enumerate() {
        for r in 0..n {
            let stride = if r == 0 { s } else { 1 };
            if stride == 2 {
                hw /= 2;
            }
            let hidden = cin * t;
            let name = |p: &str| format!("mb{bi}.{r}.{p}");
            let expand = if t != 1 {
                g.push_after(
                    Layer::build(name("expand"), LayerOp::PwConv, if stride == 2 { hw * 2 } else { hw }, cin, hidden),
                    prev,
                )
            } else {
                prev
            };
            let dw = g.push_after(
                Layer::build(name("dw"), LayerOp::DwConv { k, s: stride }, hw, hidden, hidden),
                expand,
            );
            let proj = g.push_after(Layer::build(name("proj"), LayerOp::PwConv, hw, hidden, c), dw);
            if stride == 1 && cin == c {
                let add = g.push_after(Layer::build(name("add"), LayerOp::Eltwise, hw, c, c), proj);
                g.connect(prev, add);
                prev = add;
            } else {
                prev = proj;
            }
            cin = c;
        }
    }
    let head = g.push_after(Layer::build("head", LayerOp::PwConv, 7, cin, 1280), prev);
    let pool = g.push_after(Layer::build("gap", LayerOp::Pool { k: 7, s: 7 }, 1, 1280, 1280), head);
    g.push_after(Layer::build("fc", LayerOp::Linear, 1, 1280, 1000), pool);
    g
}

/// One NASNet/PNASNet-style cell: `n_branches` parallel branch pairs over
/// two inputs, concatenated.  Returns the concat layer id.
fn nas_cell(
    g: &mut LayerGraph,
    name: &str,
    input_a: usize,
    input_b: usize,
    hw: usize,
    cin: usize,
    cout_per_branch: usize,
    n_branches: usize,
    stride: usize,
) -> usize {
    let mut branch_outs = Vec::new();
    for b in 0..n_branches {
        let src = if b % 2 == 0 { input_a } else { input_b };
        let bname = |p: &str| format!("{name}.br{b}.{p}");
        // alternate separable-conv (dw+pw) and pooling branches, the two
        // op families NAS cells are built from
        let out = if b % 3 == 2 {
            g.push_after(
                Layer::build(bname("pool"), LayerOp::Pool { k: 3, s: stride }, hw, cin, cin),
                src,
            )
        } else {
            let k = if b % 2 == 0 { 5 } else { 3 };
            let dw = g.push_after(
                Layer::build(bname("dw"), LayerOp::DwConv { k, s: stride }, hw, cin, cin),
                src,
            );
            g.push_after(Layer::build(bname("pw"), LayerOp::PwConv, hw, cin, cout_per_branch), dw)
        };
        branch_outs.push(out);
    }
    let cat = g.push(Layer::build(
        format!("{name}.cat"),
        LayerOp::Concat,
        hw,
        cout_per_branch * n_branches,
        cout_per_branch * n_branches,
    ));
    for &b in &branch_outs {
        g.connect(b, cat);
    }
    cat
}

/// NASNet-A (mobile) — Zoph et al., CVPR'18: stem + 4 normal cells per
/// stack, reduction cells between stacks, 5-branch cells.
pub fn nasnet_a() -> LayerGraph {
    nas_like("NASNet-A", 4, 5, 44)
}

/// PNASNet-5 (mobile) — Liu et al., ECCV'18: 3 cells per stack with
/// 5-branch cells and a wider stem.
pub fn pnasnet_5() -> LayerGraph {
    nas_like("PNASNet-5", 3, 5, 54)
}

fn nas_like(name: &str, cells_per_stack: usize, branches: usize, stem_ch: usize) -> LayerGraph {
    let mut g = LayerGraph::new(name);
    let stem = g.push(Layer::build("stem", LayerOp::Conv { k: 3, s: 2 }, 112, 3, stem_ch));

    let mut hw = 112;
    let mut ch = stem_ch;
    let mut prev_prev = stem;
    let mut prev = stem;
    for stack in 0..3 {
        if stack > 0 {
            // reduction cell halves HW, doubles channels
            hw /= 2;
            ch *= 2;
            let cat = nas_cell(
                &mut g,
                &format!("red{stack}"),
                prev,
                prev_prev,
                hw,
                ch / 2,
                ch / branches.max(1),
                branches,
                2,
            );
            prev_prev = prev;
            prev = cat;
        }
        for c in 0..cells_per_stack {
            let cat = nas_cell(
                &mut g,
                &format!("s{stack}c{c}"),
                prev,
                prev_prev,
                hw,
                ch,
                ch / branches.max(1),
                branches,
                1,
            );
            prev_prev = prev;
            prev = cat;
        }
    }
    let pool = g.push_after(Layer::build("gap", LayerOp::Pool { k: 7, s: 7 }, 1, ch, ch), prev);
    g.push_after(Layer::build("fc", LayerOp::Linear, 1, ch, 1000), pool);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::is_acyclic;
    use crate::workload::layers::LayerOp;

    #[test]
    fn efficientnet_builds() {
        let g = efficientnet_b0();
        assert!(g.len() > 50);
        assert!(is_acyclic(&g.to_dag()));
    }

    #[test]
    fn nas_cells_have_high_fan_in_concats() {
        for g in [nasnet_a(), pnasnet_5()] {
            let dag = g.to_dag();
            let max_fan_in = (0..g.len())
                .filter(|&i| matches!(g.layers[i].op, LayerOp::Concat))
                .map(|i| dag.in_degree(i))
                .max()
                .unwrap();
            assert!(max_fan_in >= 5, "{}: fan-in {max_fan_in}", g.name);
            assert!(is_acyclic(&dag), "{}", g.name);
        }
    }

    #[test]
    fn middle_class_is_branchier_than_simple() {
        // topological complexity proxy: edges per node
        let branchiness = |g: &LayerGraph| g.edges().len() as f64 / g.len() as f64;
        let nas = branchiness(&nasnet_a());
        let mb = branchiness(&super::super::cnn_simple::mobilenet_v2());
        assert!(nas > mb, "nas {nas} <= mobilenet {mb}");
    }
}
