//! Simple-class workloads: MobileNetV2, ResNet50, UNet (paper §4.1.2 —
//! "commonly used in AR/VR").  Input 224×224×3 (UNet 256×256×1).

use crate::workload::layers::{Layer, LayerGraph, LayerOp};

/// MobileNetV2 (Sandler et al., CVPR'18): 17 inverted-residual
/// bottlenecks with expansion 6 (first block 1), width multiplier 1.0.
pub fn mobilenet_v2() -> LayerGraph {
    let mut g = LayerGraph::new("MobileNetV2");
    // stem: conv3x3 s2, 3->32
    let mut prev = g.push(Layer::build("stem", LayerOp::Conv { k: 3, s: 2 }, 112, 3, 32));

    // (t expansion, c out, n repeats, s stride) per the paper's Table 2
    let cfg: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let mut hw = 112;
    let mut cin = 32;
    for (bi, &(t, c, n, s)) in cfg.iter().enumerate() {
        for r in 0..n {
            let stride = if r == 0 { s } else { 1 };
            if stride == 2 {
                hw /= 2;
            }
            let hidden = cin * t;
            let name = |p: &str| format!("b{bi}.{r}.{p}");
            // expand (skip when t == 1)
            let expand = if t != 1 {
                let id = g.push_after(Layer::build(name("expand"), LayerOp::PwConv, if stride == 2 { hw * 2 } else { hw }, cin, hidden), prev);
                id
            } else {
                prev
            };
            let dw = g.push_after(
                Layer::build(name("dw"), LayerOp::DwConv { k: 3, s: stride }, hw, hidden, hidden),
                expand,
            );
            let proj = g.push_after(Layer::build(name("proj"), LayerOp::PwConv, hw, hidden, c), dw);
            // residual add when stride 1 and cin == cout
            if stride == 1 && cin == c {
                let add = g.push_after(Layer::build(name("add"), LayerOp::Eltwise, hw, c, c), proj);
                g.connect(prev, add);
                prev = add;
            } else {
                prev = proj;
            }
            cin = c;
        }
    }
    // head: 1x1 conv to 1280, pool, fc
    let head = g.push_after(Layer::build("head", LayerOp::PwConv, 7, cin, 1280), prev);
    let pool = g.push_after(Layer::build("gap", LayerOp::Pool { k: 7, s: 7 }, 1, 1280, 1280), head);
    g.push_after(Layer::build("fc", LayerOp::Linear, 1, 1280, 1000), pool);
    g
}

/// ResNet50 (He et al.): stem + [3,4,6,3] bottleneck stages.
pub fn resnet50() -> LayerGraph {
    let mut g = LayerGraph::new("ResNet50");
    let stem = g.push(Layer::build("stem", LayerOp::Conv { k: 7, s: 2 }, 112, 3, 64));
    let mut prev = g.push_after(Layer::build("maxpool", LayerOp::Pool { k: 3, s: 2 }, 56, 64, 64), stem);

    let stages: [(usize, usize, usize, usize); 4] =
        [(64, 256, 3, 56), (128, 512, 4, 28), (256, 1024, 6, 14), (512, 2048, 3, 7)];
    let mut cin = 64;
    for (si, &(mid, cout, blocks, hw)) in stages.iter().enumerate() {
        for b in 0..blocks {
            let stride = if b == 0 && si > 0 { 2 } else { 1 };
            let name = |p: &str| format!("s{si}.{b}.{p}");
            let c1 = g.push_after(Layer::build(name("c1"), LayerOp::PwConv, hw, cin, mid), prev);
            let c2 = g.push_after(Layer::build(name("c2"), LayerOp::Conv { k: 3, s: stride }, hw, mid, mid), c1);
            let c3 = g.push_after(Layer::build(name("c3"), LayerOp::PwConv, hw, mid, cout), c2);
            let add = g.push_after(Layer::build(name("add"), LayerOp::Eltwise, hw, cout, cout), c3);
            if b == 0 {
                // projection shortcut
                let proj = g.push_after(Layer::build(name("down"), LayerOp::PwConv, hw, cin, cout), prev);
                g.connect(proj, add);
            } else {
                g.connect(prev, add);
            }
            prev = add;
            cin = cout;
        }
    }
    let pool = g.push_after(Layer::build("gap", LayerOp::Pool { k: 7, s: 7 }, 1, 2048, 2048), prev);
    g.push_after(Layer::build("fc", LayerOp::Linear, 1, 2048, 1000), pool);
    g
}

/// UNet (Ronneberger et al.): 4-level encoder/decoder with skip concats,
/// base width 64, input 256×256.
pub fn unet() -> LayerGraph {
    let mut g = LayerGraph::new("UNet");
    let widths = [64usize, 128, 256, 512];
    let mut hw = 256;
    let mut cin = 1;
    let mut skips: Vec<(usize, usize, usize)> = Vec::new(); // (layer id, hw, ch)
    let mut prev = usize::MAX;

    // encoder
    for (level, &w) in widths.iter().enumerate() {
        let name = |p: &str| format!("enc{level}.{p}");
        let c1 = Layer::build(name("c1"), LayerOp::Conv { k: 3, s: 1 }, hw, cin, w);
        let c1 = if prev == usize::MAX { g.push(c1) } else { g.push_after(c1, prev) };
        let c2 = g.push_after(Layer::build(name("c2"), LayerOp::Conv { k: 3, s: 1 }, hw, w, w), c1);
        skips.push((c2, hw, w));
        let pool = g.push_after(Layer::build(name("pool"), LayerOp::Pool { k: 2, s: 2 }, hw / 2, w, w), c2);
        prev = pool;
        hw /= 2;
        cin = w;
    }

    // bottleneck
    let bott1 = g.push_after(Layer::build("bott.c1", LayerOp::Conv { k: 3, s: 1 }, hw, 512, 1024), prev);
    let mut up_prev = g.push_after(Layer::build("bott.c2", LayerOp::Conv { k: 3, s: 1 }, hw, 1024, 1024), bott1);
    let mut c = 1024;

    // decoder
    for (level, &(skip_id, skip_hw, skip_w)) in skips.iter().enumerate().rev() {
        let name = |p: &str| format!("dec{level}.{p}");
        let up = g.push_after(Layer::build(name("up"), LayerOp::Upsample { factor: 2 }, skip_hw, c, skip_w), up_prev);
        let cat = g.push_after(Layer::build(name("cat"), LayerOp::Concat, skip_hw, skip_w * 2, skip_w * 2), up);
        g.connect(skip_id, cat);
        let c1 = g.push_after(Layer::build(name("c1"), LayerOp::Conv { k: 3, s: 1 }, skip_hw, skip_w * 2, skip_w), cat);
        let c2 = g.push_after(Layer::build(name("c2"), LayerOp::Conv { k: 3, s: 1 }, skip_hw, skip_w, skip_w), c1);
        up_prev = c2;
        c = skip_w;
    }
    g.push_after(Layer::build("out", LayerOp::PwConv, 256, 64, 2), up_prev);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::is_acyclic;

    #[test]
    fn mobilenet_block_count() {
        let g = mobilenet_v2();
        // 17 bottlenecks * 3-4 layers + stem + head + pool + fc
        assert!(g.len() > 50, "got {}", g.len());
        assert!(is_acyclic(&g.to_dag()));
    }

    #[test]
    fn resnet50_has_53_convs() {
        let g = resnet50();
        let convs = g
            .layers
            .iter()
            .filter(|l| matches!(l.op, LayerOp::Conv { .. } | LayerOp::PwConv))
            .count();
        // 1 stem + 16 blocks*3 + 4 downsample + fc-as-linear(excluded) = 53
        assert_eq!(convs, 53, "conv count");
    }

    #[test]
    fn unet_skips_create_concat_fan_in() {
        let g = unet();
        let dag = g.to_dag();
        let concats: Vec<usize> = (0..g.len())
            .filter(|&i| matches!(g.layers[i].op, LayerOp::Concat))
            .collect();
        assert_eq!(concats.len(), 4);
        for &c in &concats {
            assert_eq!(dag.in_degree(c), 2, "concat {c} must have skip + up");
        }
    }

    #[test]
    fn unet_is_heaviest_simple_model() {
        // paper calls UNet the "middle workload" of the Cloud profiling
        // scenario — it out-MACs the two classifiers at 256².
        assert!(unet().total_macs() > mobilenet_v2().total_macs());
    }
}
