//! The nine paper workloads, grouped by topological complexity class
//! (paper §4.1.2, Fig. 6):
//!
//! * **Simple** (AR/VR): MobileNetV2, ResNet50, UNet
//! * **Middle** (NAS-derived): EfficientNet-B0, NASNet-A, PNASNet-5
//! * **Complex** (LLMs): DeepSeek-7B, Qwen-7B, Llama-3-8B
//!
//! Builders are architecture-faithful in topology and per-layer geometry
//! (channel/dim counts, kernel sizes, block multiplicities from the
//! papers' configs); weights are irrelevant to scheduling (DESIGN.md §4).

mod cnn_simple;
mod llm;
mod nas;

pub use cnn_simple::{mobilenet_v2, resnet50, unet};
pub use llm::{deepseek_7b, llama3_8b, qwen_7b, LlmConfig};
pub use nas::{efficientnet_b0, nasnet_a, pnasnet_5};

use super::layers::LayerGraph;

/// Workload complexity classes of the evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WorkloadClass {
    Simple,
    Middle,
    Complex,
}

impl WorkloadClass {
    pub const ALL: [WorkloadClass; 3] =
        [WorkloadClass::Simple, WorkloadClass::Middle, WorkloadClass::Complex];

    pub fn name(self) -> &'static str {
        match self {
            WorkloadClass::Simple => "Simple",
            WorkloadClass::Middle => "Middle",
            WorkloadClass::Complex => "Complex",
        }
    }

    /// The three member models of the class.
    pub fn models(self) -> [ModelId; 3] {
        match self {
            WorkloadClass::Simple => [ModelId::MobileNetV2, ModelId::ResNet50, ModelId::UNet],
            WorkloadClass::Middle => {
                [ModelId::EfficientNetB0, ModelId::NasNetA, ModelId::PNasNet5]
            }
            WorkloadClass::Complex => [ModelId::DeepSeek7B, ModelId::Qwen7B, ModelId::Llama3_8B],
        }
    }
}

/// All nine evaluated models.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelId {
    MobileNetV2,
    ResNet50,
    UNet,
    EfficientNetB0,
    NasNetA,
    PNasNet5,
    DeepSeek7B,
    Qwen7B,
    Llama3_8B,
}

impl ModelId {
    pub const ALL: [ModelId; 9] = [
        ModelId::MobileNetV2,
        ModelId::ResNet50,
        ModelId::UNet,
        ModelId::EfficientNetB0,
        ModelId::NasNetA,
        ModelId::PNasNet5,
        ModelId::DeepSeek7B,
        ModelId::Qwen7B,
        ModelId::Llama3_8B,
    ];

    pub fn name(self) -> &'static str {
        match self {
            ModelId::MobileNetV2 => "MobileNetV2",
            ModelId::ResNet50 => "ResNet50",
            ModelId::UNet => "UNet",
            ModelId::EfficientNetB0 => "EfficientNet-B0",
            ModelId::NasNetA => "NASNet-A",
            ModelId::PNasNet5 => "PNASNet-5",
            ModelId::DeepSeek7B => "DeepSeek-7B",
            ModelId::Qwen7B => "Qwen-7B",
            ModelId::Llama3_8B => "Llama-3-8B",
        }
    }

    pub fn class(self) -> WorkloadClass {
        match self {
            ModelId::MobileNetV2 | ModelId::ResNet50 | ModelId::UNet => WorkloadClass::Simple,
            ModelId::EfficientNetB0 | ModelId::NasNetA | ModelId::PNasNet5 => {
                WorkloadClass::Middle
            }
            ModelId::DeepSeek7B | ModelId::Qwen7B | ModelId::Llama3_8B => WorkloadClass::Complex,
        }
    }
}

/// Build the layer graph of any evaluated model.
pub fn build_model(id: ModelId) -> LayerGraph {
    match id {
        ModelId::MobileNetV2 => mobilenet_v2(),
        ModelId::ResNet50 => resnet50(),
        ModelId::UNet => unet(),
        ModelId::EfficientNetB0 => efficientnet_b0(),
        ModelId::NasNetA => nasnet_a(),
        ModelId::PNasNet5 => pnasnet_5(),
        ModelId::DeepSeek7B => deepseek_7b(),
        ModelId::Qwen7B => qwen_7b(),
        ModelId::Llama3_8B => llama3_8b(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::is_acyclic;

    #[test]
    fn all_models_build_and_are_dags() {
        for id in ModelId::ALL {
            let g = build_model(id);
            assert!(!g.is_empty(), "{:?} empty", id);
            assert!(is_acyclic(&g.to_dag()), "{:?} cyclic", id);
            assert!(g.total_macs() > 0, "{:?} zero MACs", id);
        }
    }

    #[test]
    fn complexity_classes_ordered() {
        // The paper's classes are ordered by *topological* complexity;
        // compute-wise the LLM class must still dominate both CNN classes
        // (UNet at 256² makes Simple compute-heavy, which is fine — it is
        // the paper's own profiling "middle workload" example).
        let macs = |c: WorkloadClass| -> u64 {
            c.models().iter().map(|&m| build_model(m).total_macs()).sum()
        };
        let simple = macs(WorkloadClass::Simple);
        let middle = macs(WorkloadClass::Middle);
        let complex = macs(WorkloadClass::Complex);
        assert!(complex > middle, "complex {complex} <= middle {middle}");
        assert!(complex > simple, "complex {complex} <= simple {simple}");
        // topological complexity: edges/node rises Simple -> Middle
        let branchiness = |c: WorkloadClass| -> f64 {
            c.models()
                .iter()
                .map(|&m| {
                    let g = build_model(m);
                    g.edges().len() as f64 / g.len() as f64
                })
                .sum::<f64>()
                / 3.0
        };
        assert!(branchiness(WorkloadClass::Middle) > branchiness(WorkloadClass::Simple));
    }

    #[test]
    fn known_mac_scales() {
        // MobileNetV2 ~0.3 GMACs, ResNet50 ~4 GMACs @224 (published numbers).
        let mb = build_model(ModelId::MobileNetV2).total_macs() as f64 / 1e9;
        let rn = build_model(ModelId::ResNet50).total_macs() as f64 / 1e9;
        assert!((0.15..0.9).contains(&mb), "MobileNetV2 {mb} GMACs");
        assert!((2.0..8.0).contains(&rn), "ResNet50 {rn} GMACs");
        // 7B LLMs: ~7e9 MACs per token (1 MAC per weight); we model a
        // short generation window, so total is tokens * ~7 GMAC.
        let qw = build_model(ModelId::Qwen7B).total_macs() as f64 / 1e9;
        assert!(qw > 50.0, "Qwen-7B {qw} GMACs too small");
    }

    #[test]
    fn class_membership_consistent() {
        for class in WorkloadClass::ALL {
            for m in class.models() {
                assert_eq!(m.class(), class);
            }
        }
    }
}
