//! Complex-class workloads: DeepSeek-7B, Qwen-7B, Llama-3-8B
//! (paper §4.1.2 — "deeper models with higher computational and
//! communication complexity").
//!
//! The scheduler sees a *generation window* of `tokens` decode steps over
//! the transformer block graph: per block QKV/out projections + attention
//! + gated MLP, all expressed in the layer IR.  Config numbers are from
//! the models' published configs (hidden size, layer count, FFN dim,
//! GQA heads).

use crate::workload::layers::{Layer, LayerGraph, LayerOp};

/// Transformer architecture parameters.
#[derive(Clone, Copy, Debug)]
pub struct LlmConfig {
    pub name: &'static str,
    pub layers: usize,
    pub hidden: usize,
    pub ffn: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub vocab: usize,
    /// Tokens in the modeled generation window (scheduling granularity).
    pub tokens: usize,
}

/// DeepSeek-LLM-7B config (Bi et al. 2024).
pub const DEEPSEEK_7B: LlmConfig = LlmConfig {
    name: "DeepSeek-7B",
    layers: 30,
    hidden: 4096,
    ffn: 11008,
    heads: 32,
    kv_heads: 32,
    vocab: 102400,
    tokens: 16,
};

/// Qwen-7B config (Bai et al. 2023).
pub const QWEN_7B: LlmConfig = LlmConfig {
    name: "Qwen-7B",
    layers: 32,
    hidden: 4096,
    ffn: 11008,
    heads: 32,
    kv_heads: 32,
    vocab: 151936,
    tokens: 16,
};

/// Llama-3-8B config (Dubey et al. 2024) — GQA with 8 KV heads.
pub const LLAMA3_8B: LlmConfig = LlmConfig {
    name: "Llama-3-8B",
    layers: 32,
    hidden: 4096,
    ffn: 14336,
    heads: 32,
    kv_heads: 8,
    vocab: 128256,
    tokens: 16,
};

/// Build the layer graph of one decode window of a transformer.
pub fn build_llm(cfg: LlmConfig) -> LayerGraph {
    let mut g = LayerGraph::new(cfg.name);
    let h = cfg.hidden;
    let kv_dim = h * cfg.kv_heads / cfg.heads;

    let mut prev = g.push(Layer::build("embed", LayerOp::Embed, 1, cfg.vocab, h));
    for l in 0..cfg.layers {
        let name = |p: &str| format!("l{l}.{p}");
        // pre-attention norm
        let n1 = g.push_after(Layer::build(name("ln1"), LayerOp::Norm, 1, h, h), prev);
        // QKV projections fan out from the norm
        let q = g.push_after(Layer::build(name("q"), LayerOp::Linear, 1, h, h), n1);
        let k = g.push_after(Layer::build(name("k"), LayerOp::Linear, 1, h, kv_dim), n1);
        let v = g.push_after(Layer::build(name("v"), LayerOp::Linear, 1, h, kv_dim), n1);
        // attention joins q,k,v; out_hw = tokens in window (score is L×L)
        let attn = g.push(Layer::build(
            name("attn"),
            LayerOp::Attention { heads: cfg.heads },
            cfg.tokens,
            h,
            h,
        ));
        g.connect(q, attn);
        g.connect(k, attn);
        g.connect(v, attn);
        let o = g.push_after(Layer::build(name("o"), LayerOp::Linear, 1, h, h), attn);
        // residual 1
        let r1 = g.push_after(Layer::build(name("add1"), LayerOp::Eltwise, 1, h, h), o);
        g.connect(prev, r1);
        // MLP: norm -> (gate, up) -> mul -> down
        let n2 = g.push_after(Layer::build(name("ln2"), LayerOp::Norm, 1, h, h), r1);
        let gate = g.push_after(Layer::build(name("gate"), LayerOp::Linear, 1, h, cfg.ffn), n2);
        let up = g.push_after(Layer::build(name("up"), LayerOp::Linear, 1, h, cfg.ffn), n2);
        let mul = g.push(Layer::build(name("mul"), LayerOp::Eltwise, 1, cfg.ffn, cfg.ffn));
        g.connect(gate, mul);
        g.connect(up, mul);
        let down = g.push_after(Layer::build(name("down"), LayerOp::Linear, 1, cfg.ffn, h), mul);
        // residual 2
        let r2 = g.push_after(Layer::build(name("add2"), LayerOp::Eltwise, 1, h, h), down);
        g.connect(r1, r2);
        prev = r2;
    }
    let norm_f = g.push_after(Layer::build("ln_f", LayerOp::Norm, 1, h, h), prev);
    g.push_after(Layer::build("lm_head", LayerOp::Linear, 1, h, cfg.vocab), norm_f);

    // Scale per-layer MACs by the token window: every decode step re-runs
    // the block stack.  (Weights are shared; activations scale.)
    for layer in &mut g.layers {
        layer.macs *= cfg.tokens as u64;
        layer.act_bytes *= cfg.tokens as u64;
    }
    g
}

pub fn deepseek_7b() -> LayerGraph {
    build_llm(DEEPSEEK_7B)
}

pub fn qwen_7b() -> LayerGraph {
    build_llm(QWEN_7B)
}

pub fn llama3_8b() -> LayerGraph {
    build_llm(LLAMA3_8B)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::is_acyclic;

    #[test]
    fn llm_param_counts_are_plausible() {
        // weight bytes (int8) ≈ parameter count; 7-8B expected.
        for (g, lo, hi) in [
            (deepseek_7b(), 6.0e9, 8.5e9),
            (qwen_7b(), 6.5e9, 9.0e9),
            (llama3_8b(), 7.0e9, 9.5e9),
        ] {
            let params = g.total_weight_bytes() as f64;
            assert!(
                (lo..hi).contains(&params),
                "{}: {params:.2e} params out of [{lo:.1e},{hi:.1e})",
                g.name
            );
        }
    }

    #[test]
    fn llm_graphs_are_dags_with_residual_fan_in() {
        let g = llama3_8b();
        let dag = g.to_dag();
        assert!(is_acyclic(&dag));
        // every add has 2 producers
        let adds = (0..g.len()).filter(|&i| g.layers[i].name.contains("add"));
        for a in adds {
            assert_eq!(dag.in_degree(a), 2, "residual {} fan-in", g.layers[a].name);
        }
    }

    #[test]
    fn gqa_shrinks_kv_projections() {
        let llama = llama3_8b();
        let qwen = qwen_7b();
        let kv_macs = |g: &LayerGraph| -> u64 {
            g.layers.iter().filter(|l| l.name.ends_with(".k")).map(|l| l.macs).sum()
        };
        assert!(kv_macs(&llama) < kv_macs(&qwen), "GQA must reduce K-proj MACs");
    }

    #[test]
    fn macs_scale_with_token_window() {
        // projections scale linearly with the window, attention scores
        // quadratically — doubling tokens gives a factor in (2, 4)
        let mut cfg = QWEN_7B;
        cfg.tokens = 32;
        let double = build_llm(cfg).total_macs() as f64;
        let single = qwen_7b().total_macs() as f64;
        let ratio = double / single;
        assert!(ratio > 2.0 && ratio < 4.0, "ratio {ratio}");
    }
}
