//! Layer-level IR: operator kinds, shapes, FLOPs/bytes accounting.

use crate::graph::{Dag, NodeKind};

/// Operator taxonomy — coarse enough to cover all nine paper workloads,
/// fine enough to drive the compatibility mask and the cost model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LayerOp {
    /// Standard convolution (kernel k×k, stride s).
    Conv { k: usize, s: usize },
    /// Depthwise convolution.
    DwConv { k: usize, s: usize },
    /// Pointwise (1×1) convolution.
    PwConv,
    /// Fully connected / matmul.
    Linear,
    /// Multi-head attention score+context matmuls (LLM blocks).
    Attention { heads: usize },
    /// Max/avg pooling (comparison-dominated).
    Pool { k: usize, s: usize },
    /// Normalization (BN/LN/RMSNorm).
    Norm,
    /// Activation / elementwise (ReLU, GeLU, SiLU, residual add).
    Eltwise,
    /// Tensor concat (UNet skips, NASNet cells).
    Concat,
    /// Up-sampling / transposed conv (UNet decoder).
    Upsample { factor: usize },
    /// Embedding lookup (LLM front).
    Embed,
}

impl LayerOp {
    /// Map onto the matcher's vertex kinds (paper §3.2: compute type
    /// compatibility).
    pub fn node_kind(self) -> NodeKind {
        match self {
            LayerOp::Conv { .. }
            | LayerOp::DwConv { .. }
            | LayerOp::PwConv
            | LayerOp::Linear
            | LayerOp::Attention { .. }
            | LayerOp::Upsample { .. }
            | LayerOp::Embed => NodeKind::Compute,
            LayerOp::Pool { .. } => NodeKind::Compare,
            LayerOp::Norm | LayerOp::Eltwise => NodeKind::Eltwise,
            LayerOp::Concat => NodeKind::Move,
        }
    }
}

/// One layer instance: operator + tensor geometry + derived costs.
#[derive(Clone, Debug)]
pub struct Layer {
    pub name: String,
    pub op: LayerOp,
    /// Output spatial size (H = W assumed square; 1 for LLM token dims).
    pub out_hw: usize,
    /// Input channels (or model dim for LLM layers).
    pub cin: usize,
    /// Output channels (or model dim).
    pub cout: usize,
    /// Multiply-accumulate count for one inference of this layer.
    pub macs: u64,
    /// Bytes of activations read + written (int8 tensors assumed).
    pub act_bytes: u64,
    /// Bytes of weights (int8).
    pub weight_bytes: u64,
}

impl Layer {
    /// Build a layer, deriving MACs/bytes from the geometry.
    pub fn build(name: impl Into<String>, op: LayerOp, out_hw: usize, cin: usize, cout: usize) -> Self {
        let hw2 = (out_hw * out_hw) as u64;
        let (macs, weight_bytes): (u64, u64) = match op {
            LayerOp::Conv { k, .. } => {
                let kk = (k * k) as u64;
                (hw2 * cout as u64 * cin as u64 * kk, cin as u64 * cout as u64 * kk)
            }
            LayerOp::DwConv { k, .. } => {
                let kk = (k * k) as u64;
                (hw2 * cout as u64 * kk, cout as u64 * kk)
            }
            LayerOp::PwConv => (hw2 * cout as u64 * cin as u64, cin as u64 * cout as u64),
            LayerOp::Linear => (cin as u64 * cout as u64, cin as u64 * cout as u64),
            LayerOp::Attention { .. } => {
                // score (L·L·d) + context (L·L·d) with L = out_hw tokens,
                // d = cin; QKV/out projections are modeled as separate
                // Linear layers by the LLM builder.
                (2 * hw2 * cin as u64, 0)
            }
            LayerOp::Pool { k, .. } => (hw2 * cout as u64 * (k * k) as u64 / 4, 0),
            LayerOp::Norm | LayerOp::Eltwise => (hw2 * cout as u64 / 2, 0),
            LayerOp::Concat => (0, 0),
            LayerOp::Upsample { factor } => (hw2 * cout as u64 * (factor * factor) as u64, 0),
            LayerOp::Embed => (0, cin as u64 * cout as u64),
        };
        let act_bytes = hw2 * (cin as u64 + cout as u64);
        Self { name: name.into(), op, out_hw, cin, cout, macs, act_bytes, weight_bytes }
    }
}

/// A DNN as a DAG of layers.
#[derive(Clone, Debug, Default)]
pub struct LayerGraph {
    pub name: String,
    pub layers: Vec<Layer>,
    edges: Vec<(usize, usize)>,
}

impl LayerGraph {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), ..Default::default() }
    }

    /// Append a layer; returns its index.
    pub fn push(&mut self, layer: Layer) -> usize {
        self.layers.push(layer);
        self.layers.len() - 1
    }

    /// Append a layer wired after `prev`.
    pub fn push_after(&mut self, layer: Layer, prev: usize) -> usize {
        let id = self.push(layer);
        self.connect(prev, id);
        id
    }

    pub fn connect(&mut self, from: usize, to: usize) {
        assert!(from < self.layers.len() && to < self.layers.len());
        assert_ne!(from, to);
        if !self.edges.contains(&(from, to)) {
            self.edges.push((from, to));
        }
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    pub fn edges(&self) -> &[(usize, usize)] {
        &self.edges
    }

    /// Total MACs of one inference.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    /// Total activation traffic in bytes.
    pub fn total_act_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.act_bytes).sum()
    }

    /// Total weight bytes.
    pub fn total_weight_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_bytes).sum()
    }

    /// Lower to the generic DAG (node weight = normalized MACs).
    pub fn to_dag(&self) -> Dag {
        let max_macs = self.layers.iter().map(|l| l.macs).max().unwrap_or(1).max(1);
        let mut g = Dag::new();
        for l in &self.layers {
            g.add_node(l.op.node_kind(), l.macs as f64 / max_macs as f64);
        }
        for &(u, v) in &self.edges {
            g.add_edge(u, v);
        }
        g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_macs_formula() {
        // 3x3 conv, 64->128 channels, 56x56 output.
        let l = Layer::build("c", LayerOp::Conv { k: 3, s: 1 }, 56, 64, 128);
        assert_eq!(l.macs, 56 * 56 * 128 * 64 * 9);
        assert_eq!(l.weight_bytes, 64 * 128 * 9);
    }

    #[test]
    fn dwconv_much_cheaper_than_conv() {
        let c = Layer::build("c", LayerOp::Conv { k: 3, s: 1 }, 28, 256, 256);
        let d = Layer::build("d", LayerOp::DwConv { k: 3, s: 1 }, 28, 256, 256);
        assert!(c.macs > 100 * d.macs);
    }

    #[test]
    fn linear_macs() {
        let l = Layer::build("fc", LayerOp::Linear, 1, 4096, 11008);
        assert_eq!(l.macs, 4096 * 11008);
    }

    #[test]
    fn graph_wiring_and_totals() {
        let mut g = LayerGraph::new("t");
        let a = g.push(Layer::build("a", LayerOp::PwConv, 14, 32, 64));
        let b = g.push_after(Layer::build("b", LayerOp::Pool { k: 2, s: 2 }, 7, 64, 64), a);
        g.connect(a, b); // duplicate ignored
        assert_eq!(g.edges().len(), 1);
        assert_eq!(g.total_macs(), g.layers[0].macs + g.layers[1].macs);
        let dag = g.to_dag();
        assert_eq!(dag.len(), 2);
        assert!(dag.has_edge(0, 1));
        assert_eq!(dag.kind(1), NodeKind::Compare);
    }
}
