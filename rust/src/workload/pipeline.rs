//! DAG-to-Pipeline (ReMap, Zhao et al. 2022): map a tile DAG onto a
//! linear cascade of pipeline stages so that TSS engines stream tile
//! outputs to their successors over on-chip links.
//!
//! Stages must respect dependencies (a tile's stage ≥ its producers') and
//! should balance compute weight so the pipeline's steady-state interval
//! is minimized.  We assign ASAP levels and then merge adjacent levels
//! greedily until `num_stages` is reached, balancing per-stage weight.

use crate::graph::{levels, Dag};

/// A stage assignment for every node of a DAG.
#[derive(Clone, Debug)]
pub struct PipelineAssignment {
    /// stage index per node (0-based, monotone along edges).
    pub stage_of: Vec<usize>,
    pub num_stages: usize,
    /// total node weight per stage.
    pub stage_weight: Vec<f64>,
}

impl PipelineAssignment {
    /// Pipeline interval proxy: the heaviest stage.
    pub fn bottleneck(&self) -> f64 {
        self.stage_weight.iter().copied().fold(0.0, f64::max)
    }

    /// Load imbalance: max/mean stage weight (1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        let mean = self.stage_weight.iter().sum::<f64>() / self.num_stages.max(1) as f64;
        if mean == 0.0 {
            1.0
        } else {
            self.bottleneck() / mean
        }
    }
}

/// Assign nodes to at most `num_stages` pipeline stages.
pub fn assign_pipeline(dag: &Dag, num_stages: usize) -> PipelineAssignment {
    assert!(num_stages >= 1);
    let lvl = levels(dag);
    let max_level = lvl.iter().copied().max().unwrap_or(0);
    let n_levels = max_level + 1;

    // weight per level
    let mut level_weight = vec![0.0f64; n_levels];
    for u in 0..dag.len() {
        level_weight[lvl[u]] += dag.weight(u);
    }

    // merge consecutive levels into `num_stages` contiguous groups with
    // balanced weight: greedy cut at running-weight quantiles
    let stages = num_stages.min(n_levels);
    let total: f64 = level_weight.iter().sum();
    let per_stage = total / stages as f64;
    let mut stage_of_level = vec![0usize; n_levels];
    let mut acc = 0.0;
    let mut stage = 0;
    for (l, &w) in level_weight.iter().enumerate() {
        // open a new stage when the current one is full (but never exceed
        // the stage budget count)
        if acc >= per_stage * (stage + 1) as f64 && stage + 1 < stages {
            stage += 1;
        }
        stage_of_level[l] = stage;
        acc += w;
    }

    let stage_of: Vec<usize> = (0..dag.len()).map(|u| stage_of_level[lvl[u]]).collect();
    let mut stage_weight = vec![0.0f64; stages];
    for u in 0..dag.len() {
        stage_weight[stage_of[u]] += dag.weight(u);
    }
    PipelineAssignment { stage_of, num_stages: stages, stage_weight }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen_chain, gen_dag_layered, NodeKind};
    use crate::util::Rng;

    #[test]
    fn stages_monotone_along_edges() {
        let mut rng = Rng::new(2);
        let dag = gen_dag_layered(&[4, 6, 6, 4, 2], 3, &mut rng, NodeKind::Compute);
        let asg = assign_pipeline(&dag, 3);
        for u in 0..dag.len() {
            for &v in dag.successors(u) {
                assert!(asg.stage_of[u] <= asg.stage_of[v], "edge {u}->{v} goes backwards");
            }
        }
    }

    #[test]
    fn chain_splits_evenly() {
        let dag = gen_chain(12, NodeKind::Compute);
        let asg = assign_pipeline(&dag, 4);
        assert_eq!(asg.num_stages, 4);
        assert!(asg.imbalance() < 1.5, "imbalance {}", asg.imbalance());
    }

    #[test]
    fn more_stages_never_increase_bottleneck() {
        let mut rng = Rng::new(4);
        let dag = gen_dag_layered(&[3, 5, 5, 5, 3, 2], 2, &mut rng, NodeKind::Compute);
        let b2 = assign_pipeline(&dag, 2).bottleneck();
        let b4 = assign_pipeline(&dag, 4).bottleneck();
        assert!(b4 <= b2 + 1e-9, "b4 {b4} > b2 {b2}");
    }

    #[test]
    fn single_stage_holds_everything() {
        let dag = gen_chain(5, NodeKind::Compute);
        let asg = assign_pipeline(&dag, 1);
        assert!(asg.stage_of.iter().all(|&s| s == 0));
        assert!((asg.stage_weight[0] - dag.total_weight()).abs() < 1e-9);
    }
}
