//! DNN workload substrate: layer IR, the nine paper models, tiling and
//! pipeline construction.
//!
//! Scheduling never touches weights — only topology and per-layer compute
//! / memory volumes matter — so each model builder produces an
//! architecture-faithful [`LayerGraph`] (ops, tensor shapes, FLOPs,
//! bytes) from the published configs.
//!
//! The paper's preemptible-DAG construction is reproduced in two steps:
//! 1. [`tiling`] — IsoSched's *Layer Concatenate-and-Split*: adjacent
//!    layers are concatenated into segments sized for one engine, then
//!    split spatially into tiles → the query DAG the matcher sees.
//! 2. [`pipeline`] — ReMap's *DAG-to-Pipeline*: tiles are assigned to
//!    pipeline stages (ASAP levels balanced by weight) so cascaded
//!    engines stream tile outputs over the on-chip NoC (the TSS paradigm).

pub mod layers;
pub mod models;
pub mod pipeline;
pub mod tiling;

pub use layers::{Layer, LayerGraph, LayerOp};
pub use models::{build_model, ModelId, WorkloadClass};
pub use pipeline::{assign_pipeline, PipelineAssignment};
pub use tiling::{tile_layer_graph, TileDag, TilingConfig};
