//! Layer Concatenate-and-Split (IsoSched §3, reused by IMMSched §3.1):
//! lower a layer graph to the *tile DAG* that becomes the matcher's query
//! graph.
//!
//! Two phases:
//! 1. **Concatenate** — consecutive chain layers are fused into segments
//!    bounded by a MAC budget, so one segment ≙ the work one engine holds
//!    resident at a time (cascaded-layer pattern of TSS).
//! 2. **Split** — each segment is split spatially into `split_factor`
//!    parallel tiles; inter-segment data dependencies become halo-style
//!    tile edges (tile j of the consumer reads the spatially-overlapping
//!    tiles of the producer).
//!
//! The result is bounded to `max_tiles` vertices so it fits an AOT
//! matcher size class (queries are padded up to the class's n).

use crate::graph::{Dag, NodeId, NodeKind};

use super::layers::LayerGraph;

/// Tiling parameters.
#[derive(Clone, Copy, Debug)]
pub struct TilingConfig {
    /// Upper bound on the number of tiles (query-graph vertices).
    pub max_tiles: usize,
    /// Spatial split factor per segment (1 = no spatial split).
    pub split_factor: usize,
}

impl Default for TilingConfig {
    fn default() -> Self {
        // 16 tiles keeps the query well under the preemptible-engine
        // count (32 on Edge at ratio 0.5), so feasible embeddings are
        // plentiful — matching n into barely-n targets is near-perfect-
        // matching and fails spuriously.
        Self { max_tiles: 16, split_factor: 2 }
    }
}

/// Per-tile bookkeeping.
#[derive(Clone, Debug)]
pub struct TileInfo {
    /// Which segment this tile belongs to.
    pub segment: usize,
    /// Spatial index within the segment.
    pub split_idx: usize,
    /// MACs carried by this tile.
    pub macs: u64,
    /// Activation bytes in+out for this tile.
    pub act_bytes: u64,
}

/// The query DAG plus per-tile metadata.
#[derive(Clone, Debug)]
pub struct TileDag {
    pub dag: Dag,
    pub tiles: Vec<TileInfo>,
    /// Number of segments before splitting.
    pub num_segments: usize,
}

impl TileDag {
    pub fn len(&self) -> usize {
        self.dag.len()
    }

    pub fn is_empty(&self) -> bool {
        self.dag.is_empty()
    }

    pub fn total_macs(&self) -> u64 {
        self.tiles.iter().map(|t| t.macs).sum()
    }
}

/// Segment = a run of fused layers (concatenate phase output).
struct Segment {
    layers: Vec<usize>,
    macs: u64,
    act_bytes: u64,
    kind: NodeKind,
}

/// Phase 1: greedy chain fusion under a MAC budget.
///
/// Walk the layer DAG in topo order; a layer joins its predecessor's
/// segment when it is the *only* consumer of a single-successor producer
/// (pure chain) and the budget allows; otherwise it opens a new segment.
fn concatenate(g: &LayerGraph, budget: u64) -> (Vec<Segment>, Vec<usize>) {
    let dag = g.to_dag();
    let order = crate::graph::topo_sort(&dag).expect("layer graph must be a DAG");
    let mut seg_of = vec![usize::MAX; g.len()];
    let mut segments: Vec<Segment> = Vec::new();

    for &u in &order {
        let layer = &g.layers[u];
        let mergeable = dag.in_degree(u) == 1 && {
            let p = dag.predecessors(u)[0];
            dag.out_degree(p) == 1 && seg_of[p] != usize::MAX
        };
        let target = if mergeable {
            let p = dag.predecessors(u)[0];
            let s = seg_of[p];
            (segments[s].macs + layer.macs <= budget).then_some(s)
        } else {
            None
        };
        match target {
            Some(s) => {
                segments[s].layers.push(u);
                segments[s].macs += layer.macs;
                segments[s].act_bytes += layer.act_bytes;
                // dominant kind = kind of the heaviest layer so far
                if layer.macs > g.layers[segments[s].layers[0]].macs {
                    segments[s].kind = layer.op.node_kind();
                }
                seg_of[u] = s;
            }
            None => {
                segments.push(Segment {
                    layers: vec![u],
                    macs: layer.macs,
                    act_bytes: layer.act_bytes,
                    kind: layer.op.node_kind(),
                });
                seg_of[u] = segments.len() - 1;
            }
        }
    }
    (segments, seg_of)
}

/// Phase 2: spatial split + halo wiring.
fn split(
    g: &LayerGraph,
    segments: &[Segment],
    seg_of: &[usize],
    split_factor: usize,
) -> TileDag {
    let mut dag = Dag::new();
    let mut tiles = Vec::new();
    // tile ids per segment
    let mut tiles_of: Vec<Vec<NodeId>> = Vec::with_capacity(segments.len());
    let max_macs = segments.iter().map(|s| s.macs).max().unwrap_or(1).max(1);

    for (si, seg) in segments.iter().enumerate() {
        // tiny segments are not worth splitting (they'd produce zero-work
        // tiles that only inflate the query graph)
        let splits = if seg.macs * 4 >= max_macs as u64 { split_factor } else { 1 };
        let mut ids = Vec::with_capacity(splits);
        for sp in 0..splits {
            let id = dag.add_node(seg.kind, seg.macs as f64 / splits as f64 / max_macs as f64);
            tiles.push(TileInfo {
                segment: si,
                split_idx: sp,
                macs: seg.macs / splits as u64,
                act_bytes: seg.act_bytes / splits as u64,
            });
            ids.push(id);
        }
        tiles_of.push(ids);
    }

    // segment-level edges from the layer graph
    let mut seg_edges: Vec<(usize, usize)> = Vec::new();
    for &(u, v) in g.edges() {
        let (su, sv) = (seg_of[u], seg_of[v]);
        if su != sv && !seg_edges.contains(&(su, sv)) {
            seg_edges.push((su, sv));
        }
    }
    // halo wiring: consumer tile j depends on the producer tiles covering
    // its spatial slice [j/sv, (j+1)/sv)
    for (su, sv) in seg_edges {
        let (pu, pv) = (tiles_of[su].len(), tiles_of[sv].len());
        for j in 0..pv {
            let lo = j * pu / pv;
            let hi = ((j + 1) * pu).div_ceil(pv).min(pu);
            for i in lo..hi.max(lo + 1) {
                dag.add_edge(tiles_of[su][i.min(pu - 1)], tiles_of[sv][j]);
            }
        }
    }
    TileDag { dag, tiles, num_segments: segments.len() }
}

/// Phase 1b: coarsen the segment graph down to `target` segments by
/// contracting edges that cannot create cycles.
///
/// Chain fusion alone cannot pass branch points (residual adds, concat
/// fan-ins), so graphs like ResNet bottom out well above the tile
/// budget.  An edge (u, v) of the segment DAG is contractible iff no
/// *other* predecessor of v is reachable from u — contracting it then
/// merges two order-adjacent segments without introducing a cycle.  We
/// repeatedly contract the contractible edge with the smallest combined
/// weight (keeps segments balanced).
fn coarsen(g: &LayerGraph, segments: &mut Vec<Segment>, seg_of: &mut [usize], target: usize) {
    while segments.len() > target.max(1) {
        let s = segments.len();
        // segment-level edges + reachability
        let mut adj = vec![vec![false; s]; s];
        for &(a, b) in g.edges() {
            let (sa, sb) = (seg_of[a], seg_of[b]);
            if sa != sb {
                adj[sa][sb] = true;
            }
        }
        // transitive closure by DFS from every segment (index order is
        // NOT topological after earlier contractions)
        let mut reach = vec![vec![false; s]; s];
        for start in 0..s {
            let mut stack: Vec<usize> = (0..s).filter(|&v| adj[start][v]).collect();
            while let Some(v) = stack.pop() {
                if !reach[start][v] {
                    reach[start][v] = true;
                    stack.extend((0..s).filter(|&w| adj[v][w]));
                }
            }
        }
        // best contractible edge (u,v): no other predecessor p of v with
        // u ->* p
        let mut best: Option<(usize, usize, u64)> = None;
        for u in 0..s {
            'edges: for v in 0..s {
                if !adj[u][v] {
                    continue;
                }
                for p in 0..s {
                    if p != u && adj[p][v] && reach[u][p] {
                        continue 'edges;
                    }
                }
                let w = segments[u].macs + segments[v].macs;
                if best.map_or(true, |(_, _, bw)| w < bw) {
                    best = Some((u, v, w));
                }
            }
        }
        let Some((u, v, _)) = best else { break };
        // merge the two endpoints, keeping the smaller index stable
        let (keep, rem) = if u < v { (u, v) } else { (v, u) };
        let removed = segments.remove(rem);
        segments[keep].macs += removed.macs;
        segments[keep].act_bytes += removed.act_bytes;
        segments[keep].layers.extend(removed.layers);
        for so in seg_of.iter_mut() {
            if *so == rem {
                *so = keep;
            } else if *so > rem {
                *so -= 1;
            }
        }
    }
}

/// Full Layer Concatenate-and-Split lowering.
///
/// Chain-fuses under a MAC budget, coarsens the segment DAG to the tile
/// budget (the paper bounds the query size to keep subgraph matching
/// tractable; we bound it to an AOT size class), then splits spatially.
pub fn tile_layer_graph(g: &LayerGraph, cfg: TilingConfig) -> TileDag {
    assert!(cfg.max_tiles >= 2, "max_tiles too small");
    assert!(cfg.split_factor >= 1);
    let total = g.total_macs().max(1);
    let desired_segments = (cfg.max_tiles / cfg.split_factor).max(1);
    let budget = (total / desired_segments as u64).max(1);

    let (mut segments, mut seg_of) = concatenate(g, budget);
    coarsen(g, &mut segments, &mut seg_of, desired_segments);
    let tiled = split(g, &segments, &seg_of, cfg.split_factor);
    if tiled.len() <= cfg.max_tiles {
        return tiled;
    }
    // split inflated past the budget (uneven splittable segments):
    // retry without spatial split
    split(g, &segments, &seg_of, 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::is_acyclic;
    use crate::workload::models::{build_model, ModelId};

    #[test]
    fn tiles_bounded_and_acyclic_for_all_models() {
        for id in ModelId::ALL {
            let g = build_model(id);
            let t = tile_layer_graph(&g, TilingConfig { max_tiles: 32, split_factor: 2 });
            assert!(t.len() <= 32, "{:?}: {} tiles", id, t.len());
            assert!(t.len() >= 2, "{:?}: degenerate tiling", id);
            assert!(is_acyclic(&t.dag), "{:?}", id);
        }
    }

    #[test]
    fn macs_conserved_up_to_split_rounding() {
        let g = build_model(ModelId::ResNet50);
        let t = tile_layer_graph(&g, TilingConfig::default());
        let total = g.total_macs() as f64;
        let tiled = t.total_macs() as f64;
        assert!((tiled - total).abs() / total < 0.01, "tiled {tiled} vs {total}");
    }

    #[test]
    fn split_factor_increases_parallel_width() {
        let g = build_model(ModelId::UNet);
        let narrow = tile_layer_graph(&g, TilingConfig { max_tiles: 32, split_factor: 1 });
        let wide = tile_layer_graph(&g, TilingConfig { max_tiles: 32, split_factor: 2 });
        assert!(wide.len() >= narrow.len());
        // wide tiling contains multi-tile segments
        assert!(wide.tiles.iter().any(|t| t.split_idx > 0));
    }

    #[test]
    fn segments_respect_dependencies() {
        // tile edges only point from earlier to later segments
        let g = build_model(ModelId::MobileNetV2);
        let t = tile_layer_graph(&g, TilingConfig::default());
        for u in 0..t.len() {
            for &v in t.dag.successors(u) {
                assert!(
                    t.tiles[u].segment != t.tiles[v].segment,
                    "intra-segment tile edge {u}->{v}"
                );
            }
        }
    }
}
