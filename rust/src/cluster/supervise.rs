//! [`SupervisedFleet`]: fault-tolerant supervision over a
//! [`MatchCluster`]'s shard transports.
//!
//! The cluster routes; this layer keeps the routed work *alive*.  A
//! heartbeat thread probes every shard on a fixed cadence (refreshing
//! the cluster's status cache as a side effect, which is what keeps
//! routing off the per-submit status tax).  A shard that fails
//! [`ShardTransport::healthy`] — or misses
//! [`SupervisorConfig::miss_threshold`] consecutive probes — is
//! declared dead: every in-flight request the fleet tracked on it is
//! **replayed** onto the surviving shards (or a respawned replacement,
//! if a respawner is installed), warm-starting from the last persisted
//! barrier snapshot so a crash mid-episode loses at most one epoch
//! quota of work.
//!
//! Replay is bounded: [`SupervisorConfig::max_replays`] attempts with
//! exponential backoff, and none at all once live capacity falls below
//! [`SupervisorConfig::capacity_floor`] — past either limit the fleet
//! degrades gracefully, answering the request itself with a
//! [`MatchPath::Shed`] response that *carries the warm-start snapshot
//! back to the caller* (shedding must never destroy persisted episode
//! progress).
//!
//! Crash-safety of resume state: [`MatchCluster::resubmit`] takes the
//! snapshot out of the [`super::ResumeStore`] destructively, so a
//! shard that dies holding the only copy would strand the episode at
//! zero.  The fleet therefore keeps its own copy of the last snapshot
//! it handed out ([`FlightRecord`]'s `resume`) and replays from
//! whichever is newer — the store's (a later barrier was reached) or
//! its own (the crash predated any barrier reply).
//!
//! Everything here is exercised deterministically by the
//! [`super::chaos`] transport under ordinary `cargo test`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::{MatchPath, MatchProblem, MatchResponse, RequestId};
use crate::matcher::SwarmSnapshot;
use crate::obs::metrics::{publish_failover, well};
use crate::obs::recorder;
use crate::obs::trace::{span_with, SpanKind};
use crate::scheduler::Priority;

use super::policy::ShardId;
use super::transport::{lock_recover, ShardTransport};
use super::{ClusterTicket, MatchCluster};

/// Supervision knobs.  Defaults suit tests and modest fleets; long
/// control timeouts (see [`super::TransportConfig`]) stretch how long
/// a *wedged* (as opposed to dead) worker takes to detect, since a
/// wedged probe blocks until its timeout.
#[derive(Clone, Copy, Debug)]
pub struct SupervisorConfig {
    /// Heartbeat cadence — every shard is probed this often.
    pub heartbeat_interval: Duration,
    /// Consecutive failed probes before a shard is declared dead (a
    /// transport reporting `healthy() == false` is declared dead
    /// immediately, without waiting out the streak).
    pub miss_threshold: u32,
    /// Replay attempts per request before degrading to a shed answer.
    pub max_replays: u32,
    /// First replay backoff; doubles per attempt.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
    /// Minimum live shards for replay/admission; below it the fleet
    /// sheds instead of queueing onto a doomed remnant.
    pub capacity_floor: usize,
    /// Poll cadence for [`SupervisedFleet::wait`].
    pub poll: Duration,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        Self {
            heartbeat_interval: Duration::from_millis(100),
            miss_threshold: 3,
            max_replays: 3,
            backoff_base: Duration::from_millis(5),
            backoff_cap: Duration::from_millis(250),
            capacity_floor: 1,
            poll: Duration::from_micros(500),
        }
    }
}

/// Supervision telemetry (monotonic counters; snapshot via
/// [`SupervisedFleet::failover`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct FailoverStats {
    /// Heartbeat probes issued.
    pub probes: u64,
    /// Probes that failed (the shard may still be within its miss
    /// streak).
    pub probe_failures: u64,
    /// Shards declared dead.
    pub shards_failed: u64,
    /// Requests successfully replayed off a dead shard.
    pub replays: u64,
    /// Dead shards replaced via the installed respawner.
    pub respawns: u64,
    /// Requests degraded to a shed answer (replay budget exhausted or
    /// capacity below the floor).
    pub shed_at_floor: u64,
}

#[derive(Debug, Default)]
struct Counters {
    probes: AtomicU64,
    probe_failures: AtomicU64,
    shards_failed: AtomicU64,
    replays: AtomicU64,
    respawns: AtomicU64,
    shed_at_floor: AtomicU64,
}

/// Per-shard liveness bookkeeping.
#[derive(Clone, Copy, Debug, Default)]
struct ShardHealth {
    misses: u32,
    dead: bool,
}

/// Everything needed to replay one in-flight request from scratch —
/// the fleet's in-flight inventory entry.
struct FlightRecord {
    /// The live routed submission (`None` only for requests the fleet
    /// answered itself, where `done` is `Some`).
    ticket: Option<ClusterTicket>,
    problem: MatchProblem,
    priority: Priority,
    timeout: Option<f64>,
    /// Fleet-held copy of the last warm-start snapshot handed to a
    /// shard — the crash-replay source when the store's copy was
    /// destructively taken by the submission that died.
    resume: Option<SwarmSnapshot>,
    replays: u32,
    /// A replay is in progress on another thread; pollers must not
    /// touch the ticket.
    replaying: bool,
    /// A fleet-synthesized answer (shed at the floor) awaiting pickup.
    done: Option<MatchResponse>,
}

type Respawner = Box<dyn Fn(ShardId) -> Result<Arc<dyn ShardTransport>> + Send + Sync>;

/// The supervision layer.  Construct with [`SupervisedFleet::new`]
/// (spawns the heartbeat), submit/wait through it instead of the raw
/// cluster, and worker deaths become replays instead of hangs.
pub struct SupervisedFleet {
    cluster: Arc<MatchCluster>,
    cfg: SupervisorConfig,
    flights: Mutex<BTreeMap<RequestId, FlightRecord>>,
    health: Mutex<Vec<ShardHealth>>,
    respawner: Mutex<Option<Respawner>>,
    counters: Counters,
    stop: Arc<AtomicBool>,
    heartbeat: Mutex<Option<JoinHandle<()>>>,
}

impl SupervisedFleet {
    /// Wrap `cluster` and start the heartbeat thread.
    pub fn new(cluster: Arc<MatchCluster>, cfg: SupervisorConfig) -> Arc<Self> {
        let shards = cluster.shard_count();
        let fleet = Arc::new(Self {
            cluster,
            cfg,
            flights: Mutex::new(BTreeMap::new()),
            health: Mutex::new(vec![ShardHealth::default(); shards]),
            respawner: Mutex::new(None),
            counters: Counters::default(),
            stop: Arc::new(AtomicBool::new(false)),
            heartbeat: Mutex::new(None),
        });
        let weak = Arc::downgrade(&fleet);
        let stop = Arc::clone(&fleet.stop);
        let interval = cfg.heartbeat_interval;
        let handle = thread::Builder::new()
            .name("fleet-heartbeat".into())
            .spawn(move || heartbeat_loop(&weak, &stop, interval));
        match handle {
            Ok(h) => *lock_recover(&fleet.heartbeat) = Some(h),
            Err(e) => crate::log_warn!("fleet heartbeat thread failed to spawn: {e}"),
        }
        fleet
    }

    /// Install a respawner: called with a dead shard's id, it returns
    /// a replacement transport the fleet swaps into the cluster before
    /// replaying the victim's requests. For socket fleets,
    /// [`crate::cluster::net::registry_respawner`] builds one that
    /// waits (bounded) for a replacement worker to join the
    /// [`crate::cluster::net::WorkerRegistry`] and dials it.
    pub fn set_respawn(
        &self,
        f: impl Fn(ShardId) -> Result<Arc<dyn ShardTransport>> + Send + Sync + 'static,
    ) {
        *lock_recover(&self.respawner) = Some(Box::new(f));
    }

    /// The supervised cluster (telemetry reads stats through this).
    pub fn cluster(&self) -> &MatchCluster {
        &self.cluster
    }

    /// Shards not currently declared dead.
    pub fn live_shards(&self) -> usize {
        lock_recover(&self.health).iter().filter(|h| !h.dead).count()
    }

    /// Supervision counters so far.
    pub fn failover(&self) -> FailoverStats {
        FailoverStats {
            probes: self.counters.probes.load(Ordering::Relaxed),
            probe_failures: self.counters.probe_failures.load(Ordering::Relaxed),
            shards_failed: self.counters.shards_failed.load(Ordering::Relaxed),
            replays: self.counters.replays.load(Ordering::Relaxed),
            respawns: self.counters.respawns.load(Ordering::Relaxed),
            shed_at_floor: self.counters.shed_at_floor.load(Ordering::Relaxed),
        }
    }

    /// The shard currently serving `id` (`None` once answered, or for
    /// fleet-answered requests).
    pub fn shard_of(&self, id: RequestId) -> Option<ShardId> {
        lock_recover(&self.flights)
            .get(&id)
            .and_then(|rec| rec.ticket.as_ref().map(|t| t.shard))
    }

    /// Submit through the fleet: routed by the cluster's policy,
    /// tracked in the in-flight inventory, retried (with fresh ids)
    /// over transient submission errors, shed outright below the
    /// capacity floor.  Returns the id to [`Self::wait`] on.
    pub fn submit(
        &self,
        problem: MatchProblem,
        priority: Priority,
        timeout: Option<f64>,
    ) -> Result<RequestId> {
        let mut attempt: u32 = 0;
        while attempt < self.cfg.max_replays.max(1) {
            attempt += 1;
            if self.live_shards() < self.cfg.capacity_floor {
                return Ok(self.shed_new(problem, priority, timeout));
            }
            match self.cluster.submit(problem.clone(), priority, timeout) {
                Ok(ticket) => {
                    let id = ticket.id;
                    lock_recover(&self.flights).insert(
                        id,
                        FlightRecord {
                            ticket: Some(ticket),
                            problem,
                            priority,
                            timeout,
                            resume: None,
                            replays: 0,
                            replaying: false,
                            done: None,
                        },
                    );
                    return Ok(id);
                }
                Err(e) => {
                    crate::log_warn!("fleet submit attempt {attempt} failed: {e:#}");
                    thread::sleep(self.backoff(attempt));
                }
            }
        }
        Ok(self.shed_new(problem, priority, timeout))
    }

    /// Resubmit an answered (typically quota-cancelled) request under
    /// its original id, warm-starting from its persisted snapshot —
    /// the fleet keeps its own copy of the snapshot it hands out, so a
    /// crash mid-resume can still replay from the same barrier.
    pub fn resubmit(
        &self,
        id: RequestId,
        problem: MatchProblem,
        priority: Priority,
        timeout: Option<f64>,
    ) -> Result<()> {
        let resume = self.cluster.resume_store().take(id);
        let ticket = match self.cluster.resubmit_carrying(
            id,
            problem.clone(),
            priority,
            timeout,
            resume.clone(),
        ) {
            Ok(ticket) => ticket,
            Err(e) => {
                // a failed resubmission (e.g. routed onto a shard that
                // just died) must not destroy the snapshot it took
                if let Some(snapshot) = resume {
                    self.cluster.resume_store().save(id, snapshot);
                }
                return Err(e);
            }
        };
        let mut flights = lock_recover(&self.flights);
        match flights.get_mut(&id) {
            Some(rec) => {
                rec.ticket = Some(ticket);
                rec.problem = problem;
                rec.priority = priority;
                rec.timeout = timeout;
                if resume.is_some() {
                    rec.resume = resume;
                }
                rec.replaying = false;
                rec.done = None;
            }
            None => {
                flights.insert(
                    id,
                    FlightRecord {
                        ticket: Some(ticket),
                        problem,
                        priority,
                        timeout,
                        resume,
                        replays: 0,
                        replaying: false,
                        done: None,
                    },
                );
            }
        }
        Ok(())
    }

    /// Non-blocking poll for `id`'s answer.  A poll that finds the
    /// serving shard dead (or the reply lost) triggers the replay path
    /// instead of spinning forever — the answer then arrives from a
    /// surviving shard on a later poll.
    pub fn try_wait(&self, id: RequestId) -> Option<MatchResponse> {
        let needs_replay = {
            let mut flights = lock_recover(&self.flights);
            let rec = flights.get_mut(&id)?;
            if let Some(done) = rec.done.take() {
                flights.remove(&id);
                return Some(done);
            }
            if rec.replaying {
                return None;
            }
            let ticket = rec.ticket.as_ref()?;
            if let Some(resp) = ticket.try_wait() {
                // keep the freshest barrier for crash-replay of any
                // follow-up slice resubmitted under this id
                if resp.snapshot.is_some() {
                    rec.resume.clone_from(&resp.snapshot);
                }
                flights.remove(&id);
                return Some(resp);
            }
            let shard = ticket.shard;
            ticket.lost()
                || !ticket.healthy()
                || lock_recover(&self.health).get(shard).is_some_and(|h| h.dead)
        };
        if needs_replay {
            self.replay(id);
        }
        None
    }

    /// Block until `id` is answered — by its shard, a replay onto a
    /// surviving shard, or the fleet itself (a shed at the floor).
    pub fn wait(&self, id: RequestId) -> Result<MatchResponse> {
        // lint:allow(no-unbounded-retry): every failure path converges — replay is
        // bounded by max_replays and then answers the record with a shed response
        loop {
            if let Some(resp) = self.try_wait(id) {
                return Ok(resp);
            }
            if !lock_recover(&self.flights).contains_key(&id) {
                bail!("request {id} is not in flight on this fleet");
            }
            thread::sleep(self.cfg.poll);
        }
    }

    /// Stop the heartbeat and drain the cluster.
    pub fn drain(&self) -> Result<()> {
        self.stop_heartbeat();
        self.cluster.drain()
    }

    fn backoff(&self, attempt: u32) -> Duration {
        let factor = 1u32 << attempt.saturating_sub(1).min(16);
        (self.cfg.backoff_base * factor).min(self.cfg.backoff_cap)
    }

    fn stop_heartbeat(&self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(handle) = lock_recover(&self.heartbeat).take() {
            let _ = handle.join();
        }
    }

    /// Answer a request the fleet cannot place: mint an id, record a
    /// shed response carrying any warm-start snapshot back.
    fn shed_new(
        &self,
        problem: MatchProblem,
        priority: Priority,
        timeout: Option<f64>,
    ) -> RequestId {
        let id = self.cluster.allocate_request_id();
        let done = Some(shed_response(id, None));
        self.counters.shed_at_floor.fetch_add(1, Ordering::Relaxed);
        well::CLUSTER_SHED_AT_FLOOR.inc();
        span_with(id, SpanKind::Shed, || "reason=capacity-floor".to_string());
        if recorder::enabled() {
            recorder::record(
                "shed-floor",
                vec![
                    ("id".into(), id.to_string()),
                    ("live_shards".into(), self.live_shards().to_string()),
                    ("floor".into(), self.cfg.capacity_floor.to_string()),
                ],
            );
            recorder::dump_to_disk("shed-at-floor");
        }
        lock_recover(&self.flights).insert(
            id,
            FlightRecord {
                ticket: None,
                problem,
                priority,
                timeout,
                resume: None,
                replays: 0,
                replaying: false,
                done,
            },
        );
        id
    }

    /// One heartbeat sweep: probe every shard, advance miss streaks,
    /// declare deaths, respawn (if possible) and rescue the dead
    /// shard's in-flight requests.
    fn probe_all(&self) {
        for shard in 0..self.cluster.shard_count() {
            if self.stop.load(Ordering::Relaxed) {
                return;
            }
            let alive = self.cluster.shard_healthy(shard);
            // a transport that *knows* it is dead gets no probe — a
            // wedged probe would block for the control timeout
            let probed_ok = alive && self.cluster.probe(shard).is_ok();
            self.counters.probes.fetch_add(1, Ordering::Relaxed);
            let newly_dead = {
                let mut health = lock_recover(&self.health);
                let Some(h) = health.get_mut(shard) else { continue };
                if probed_ok {
                    // a respawned or recovered shard silently rejoins
                    h.misses = 0;
                    h.dead = false;
                    false
                } else {
                    self.counters.probe_failures.fetch_add(1, Ordering::Relaxed);
                    h.misses = h.misses.saturating_add(1);
                    let dead_now = !alive || h.misses >= self.cfg.miss_threshold;
                    let newly = dead_now && !h.dead;
                    h.dead = h.dead || dead_now;
                    newly
                }
            };
            if newly_dead {
                self.counters.shards_failed.fetch_add(1, Ordering::Relaxed);
                well::CLUSTER_SHARDS_FAILED.inc();
                crate::log_warn!(
                    { shard = shard, healthy = alive },
                    "shard declared dead; failing over its in-flight requests"
                );
                if recorder::enabled() {
                    recorder::record(
                        "shard-dead",
                        vec![
                            ("shard".into(), shard.to_string()),
                            ("healthy".into(), alive.to_string()),
                            ("live_shards".into(), self.live_shards().to_string()),
                        ],
                    );
                    recorder::dump_to_disk("shard-dead");
                }
                self.try_respawn(shard);
                self.rescue_shard(shard);
            }
        }
        publish_failover(&self.failover());
    }

    /// Replace a dead shard's transport via the installed respawner
    /// (if any); on success the shard rejoins the live set immediately.
    fn try_respawn(&self, shard: ShardId) {
        let guard = lock_recover(&self.respawner);
        let Some(respawn) = guard.as_ref() else { return };
        match respawn(shard) {
            Ok(transport) => {
                self.cluster.replace_transport(shard, transport);
                if let Some(h) = lock_recover(&self.health).get_mut(shard) {
                    h.misses = 0;
                    h.dead = false;
                }
                self.counters.respawns.fetch_add(1, Ordering::Relaxed);
                if recorder::enabled() {
                    recorder::record("respawn", vec![("shard".into(), shard.to_string())]);
                }
            }
            Err(e) => crate::log_warn!({ shard = shard }, "respawn failed: {e:#}"),
        }
    }

    /// Replay every tracked request currently ticketed on `shard`.
    fn rescue_shard(&self, shard: ShardId) {
        let victims: Vec<RequestId> = lock_recover(&self.flights)
            .iter()
            .filter(|(_, rec)| {
                rec.done.is_none()
                    && !rec.replaying
                    && rec.ticket.as_ref().is_some_and(|t| t.shard == shard)
            })
            .map(|(id, _)| *id)
            .collect();
        for id in victims {
            self.replay(id);
        }
    }

    /// Replay one request whose shard died: bounded attempts with
    /// exponential backoff, warm-starting from the freshest snapshot
    /// (store first, fleet copy as the crash fallback); exhaustion or
    /// a capacity floor violation degrades to a shed answer carrying
    /// the snapshot back.
    fn replay(&self, id: RequestId) {
        let (problem, priority, timeout, mut replays, resume_copy) = {
            let mut flights = lock_recover(&self.flights);
            let Some(rec) = flights.get_mut(&id) else { return };
            if rec.done.is_some() || rec.replaying {
                return;
            }
            rec.replaying = true;
            (rec.problem.clone(), rec.priority, rec.timeout, rec.replays, rec.resume.clone())
        };
        while replays < self.cfg.max_replays {
            replays += 1;
            thread::sleep(self.backoff(replays));
            if self.live_shards() < self.cfg.capacity_floor {
                break;
            }
            let resume = self.cluster.resume_store().take(id).or_else(|| resume_copy.clone());
            match self.cluster.resubmit_carrying(
                id,
                problem.clone(),
                priority,
                timeout,
                resume.clone(),
            ) {
                Ok(ticket) => {
                    self.counters.replays.fetch_add(1, Ordering::Relaxed);
                    well::CLUSTER_REPLAYS.inc();
                    span_with(id, SpanKind::Replay, || {
                        format!("attempt={replays} shard={}", ticket.shard)
                    });
                    if recorder::enabled() {
                        recorder::record(
                            "replay",
                            vec![
                                ("id".into(), id.to_string()),
                                ("attempt".into(), replays.to_string()),
                                ("shard".into(), ticket.shard.to_string()),
                            ],
                        );
                    }
                    let mut flights = lock_recover(&self.flights);
                    if let Some(rec) = flights.get_mut(&id) {
                        rec.ticket = Some(ticket);
                        rec.replays = replays;
                        if resume.is_some() {
                            rec.resume = resume;
                        }
                        rec.replaying = false;
                    }
                    return;
                }
                Err(e) => {
                    crate::log_warn!(
                        { id = id, attempt = replays, budget = self.cfg.max_replays },
                        "replay failed: {e:#}"
                    );
                }
            }
        }
        // degraded: answer the request ourselves, handing the
        // warm-start snapshot back so no episode progress is destroyed
        let snapshot = self.cluster.resume_store().take(id).or(resume_copy);
        self.counters.shed_at_floor.fetch_add(1, Ordering::Relaxed);
        well::CLUSTER_SHED_AT_FLOOR.inc();
        span_with(id, SpanKind::Shed, || format!("reason=replay-exhausted replays={replays}"));
        if recorder::enabled() {
            recorder::record(
                "shed-floor",
                vec![("id".into(), id.to_string()), ("replays".into(), replays.to_string())],
            );
            recorder::dump_to_disk("shed-at-floor");
        }
        let mut flights = lock_recover(&self.flights);
        if let Some(rec) = flights.get_mut(&id) {
            rec.replays = replays;
            rec.replaying = false;
            rec.ticket = None;
            rec.done = Some(shed_response(id, snapshot));
        }
    }
}

impl Drop for SupervisedFleet {
    fn drop(&mut self) {
        self.stop_heartbeat();
    }
}

/// The fleet's graceful-degradation answer (mirrors the service's own
/// shed semantics: empty mappings, the snapshot handed back).
fn shed_response(id: RequestId, snapshot: Option<SwarmSnapshot>) -> MatchResponse {
    MatchResponse {
        id,
        mappings: Vec::new(),
        best_fitness: f32::NEG_INFINITY,
        epochs_run: 0,
        host_seconds: 0.0,
        path: MatchPath::Shed,
        resumed: false,
        snapshot,
    }
}

/// The heartbeat body: sweep until the fleet is dropped or drained.
fn heartbeat_loop(fleet: &Weak<SupervisedFleet>, stop: &AtomicBool, interval: Duration) {
    // lint:allow(no-unbounded-retry): runs until drop/drain sets the stop flag —
    // the thread must outlive no fleet
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let Some(fleet) = fleet.upgrade() else { return };
        fleet.probe_all();
        drop(fleet);
        thread::sleep(interval);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterConfig, InProcessShard, RoundRobin};
    use crate::coordinator::ServiceConfig;
    use crate::graph::{gen_chain, NodeKind};
    use crate::matcher::PsoConfig;

    fn chain_problem(n: usize, m: usize) -> MatchProblem {
        let qd = gen_chain(n, NodeKind::Compute);
        let gd = gen_chain(m, NodeKind::Universal);
        MatchProblem::from_dags(&qd, &gd)
    }

    fn fast_cfg() -> SupervisorConfig {
        SupervisorConfig {
            heartbeat_interval: Duration::from_millis(10),
            backoff_base: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(5),
            ..Default::default()
        }
    }

    #[test]
    fn healthy_fleet_is_transparent() {
        let cfg = ClusterConfig {
            shards: 2,
            pso: PsoConfig { seed: 3, ..Default::default() },
            ..Default::default()
        };
        let cluster =
            Arc::new(MatchCluster::spawn(cfg, Box::<RoundRobin>::default()).unwrap());
        let fleet = SupervisedFleet::new(cluster, fast_cfg());
        let id = fleet.submit(chain_problem(4, 8), Priority::Normal, None).unwrap();
        let resp = fleet.wait(id).unwrap();
        assert!(resp.matched());
        let stats = fleet.failover();
        assert_eq!(stats.shards_failed, 0);
        assert_eq!(stats.replays, 0);
        assert_eq!(fleet.live_shards(), 2);
        fleet.drain().unwrap();
    }

    #[test]
    fn below_capacity_floor_submissions_shed_instead_of_queueing() {
        let cfg = ClusterConfig {
            shards: 1,
            pso: PsoConfig { seed: 4, ..Default::default() },
            ..Default::default()
        };
        let cluster =
            Arc::new(MatchCluster::spawn(cfg, Box::<RoundRobin>::default()).unwrap());
        let fleet = SupervisedFleet::new(
            cluster,
            SupervisorConfig { capacity_floor: 2, ..fast_cfg() },
        );
        // one live shard < floor of two: the fleet answers directly
        let id = fleet.submit(chain_problem(3, 6), Priority::Normal, None).unwrap();
        let resp = fleet.wait(id).unwrap();
        assert_eq!(resp.path, MatchPath::Shed);
        assert_eq!(fleet.failover().shed_at_floor, 1);
        fleet.drain().unwrap();
    }

    #[test]
    fn respawner_replaces_a_dead_transport() {
        let pso = PsoConfig { seed: 9, ..Default::default() };
        let transports: Vec<Arc<dyn ShardTransport>> = vec![Arc::new(
            InProcessShard::spawn(ServiceConfig::default(), pso).unwrap(),
        )];
        let cluster = Arc::new(MatchCluster::with_transports(
            transports,
            Box::<RoundRobin>::default(),
            64,
        ));
        let fleet = SupervisedFleet::new(Arc::clone(&cluster), fast_cfg());
        fleet.set_respawn(move |_| {
            let t: Arc<dyn ShardTransport> =
                Arc::new(InProcessShard::spawn(ServiceConfig::default(), pso)?);
            Ok(t)
        });
        fleet.try_respawn(0);
        assert_eq!(fleet.failover().respawns, 1);
        // the replacement transport serves new work
        let id = fleet.submit(chain_problem(4, 8), Priority::Normal, None).unwrap();
        assert!(fleet.wait(id).unwrap().matched());
        fleet.drain().unwrap();
    }
}
