//! [`FaultInjectingTransport`]: a deterministic fault-injection
//! decorator over any [`ShardTransport`].
//!
//! Robustness claims are worthless untested, and real worker crashes
//! are miserable to reproduce.  This module makes every failure mode
//! the supervision layer handles *scriptable*: a [`ChaosSchedule`]
//! maps submission sequence numbers (per decorated transport, starting
//! at 0) to faults, so "the worker dies under the third request" is a
//! one-line schedule entry and an ordinary `cargo test` — no signals,
//! no sleeps-and-hope, no flakes.
//!
//! Faults ([`ChaosFault`]):
//!
//! * `Delay` — sleep before forwarding (plus a small seeded jitter),
//!   modeling a slow shard;
//! * `DropReply` — forward the submission but swallow its response
//!   forever; the decorated transport reports the id [`lost`], which is
//!   what supervision keys replay on;
//! * `Garbage` / `Truncate` — deliver a malformed frame through
//!   [`ShardTransport::inject_frame_fault`], poisoning (or wedging)
//!   the connection exactly the way a corrupted pipe would;
//! * `Kill` — [`ShardTransport::abort`]: the worker dies *now*,
//!   mid-episode, un-drained.
//!
//! Determinism: the schedule is keyed by sequence number, the jitter
//! RNG is seeded, and all bookkeeping uses ordered collections — the
//! same seed + schedule produces the same per-request disposition on
//! every run, which the chaos tests assert literally.
//!
//! [`lost`]: ShardTransport::lost

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::coordinator::{MatchProblem, MatchResponse, RequestId};
use crate::matcher::SwarmSnapshot;
use crate::obs::metrics::{publish_chaos, well};
use crate::obs::recorder;
use crate::obs::trace::{span_with, SpanKind};
use crate::scheduler::Priority;
use crate::util::Rng;

use super::transport::{lock_recover, FrameFault, ShardTransport};
use super::wire::ShardStatus;

/// One scripted fault, applied when its scheduled submission arrives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosFault {
    /// Sleep this long (plus ≤ 1 ms of seeded jitter) before
    /// forwarding the submission.
    Delay(Duration),
    /// Forward the submission but swallow its reply forever; the id is
    /// reported [`ShardTransport::lost`] so supervision replays it.
    DropReply,
    /// Deliver a well-framed, undecodable payload to the shard — the
    /// connection-poisoning fault (the worker finishes pending work,
    /// then exits).
    Garbage,
    /// Deliver a frame header that promises more bytes than follow —
    /// the wedged-connection fault (control round-trips time out).
    Truncate,
    /// Kill the shard's execution resources immediately, un-drained.
    Kill,
}

impl ChaosFault {
    fn spec(&self) -> String {
        match self {
            ChaosFault::Delay(d) => format!("delay={}", d.as_millis()),
            ChaosFault::DropReply => "drop".to_string(),
            ChaosFault::Garbage => "garbage".to_string(),
            ChaosFault::Truncate => "truncate".to_string(),
            ChaosFault::Kill => "kill".to_string(),
        }
    }
}

/// Scripted faults keyed by per-transport submission sequence number
/// (the first submission through the decorator is sequence 0).
#[derive(Clone, Debug, Default)]
pub struct ChaosSchedule {
    entries: BTreeMap<u64, ChaosFault>,
}

impl ChaosSchedule {
    /// Builder: fault the `seq`-th submission.
    #[must_use]
    pub fn at(mut self, seq: u64, fault: ChaosFault) -> Self {
        self.entries.insert(seq, fault);
        self
    }

    /// Parse the CLI spec format: comma-separated `SEQ:FAULT` entries
    /// where `FAULT` is `kill`, `drop`, `garbage`, `truncate`, or
    /// `delay=MILLIS` — e.g. `"2:kill,5:garbage,9:delay=25"`.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut schedule = Self::default();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let Some((seq, fault)) = entry.split_once(':') else {
                bail!("chaos entry {entry:?} is not SEQ:FAULT");
            };
            let seq: u64 = seq
                .trim()
                .parse()
                .map_err(|e| anyhow::anyhow!("chaos entry {entry:?}: bad sequence ({e})"))?;
            let fault = match fault.trim() {
                "kill" => ChaosFault::Kill,
                "drop" => ChaosFault::DropReply,
                "garbage" => ChaosFault::Garbage,
                "truncate" => ChaosFault::Truncate,
                other => match other.strip_prefix("delay=") {
                    Some(ms) => {
                        let ms: u64 = ms.parse().map_err(|e| {
                            anyhow::anyhow!("chaos entry {entry:?}: bad delay ({e})")
                        })?;
                        ChaosFault::Delay(Duration::from_millis(ms))
                    }
                    None => bail!(
                        "chaos entry {entry:?}: unknown fault {other:?} \
                         (expected kill|drop|garbage|truncate|delay=MS)"
                    ),
                },
            };
            schedule.entries.insert(seq, fault);
        }
        Ok(schedule)
    }

    /// Canonical spec string (sequence order) — telemetry records this
    /// so a chaotic run is reproducible from its trajectory alone.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for (seq, fault) in &self.entries {
            if !out.is_empty() {
                out.push(',');
            }
            let _ = write!(out, "{seq}:{}", fault.spec());
        }
        out
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }
}

/// Counters for faults actually applied (a snapshot; the live counters
/// are atomics inside the transport).
#[derive(Clone, Copy, Debug, Default)]
pub struct ChaosStats {
    pub delays: u64,
    pub dropped_replies: u64,
    pub garbage_frames: u64,
    pub truncated_frames: u64,
    pub kills: u64,
    /// Frame faults the inner transport could not realize (it has no
    /// frame boundary — e.g. an in-process shard).
    pub unsupported: u64,
}

#[derive(Debug, Default)]
struct Counters {
    delays: AtomicU64,
    dropped_replies: AtomicU64,
    garbage_frames: AtomicU64,
    truncated_frames: AtomicU64,
    kills: AtomicU64,
    unsupported: AtomicU64,
}

/// The fault-injection decorator.  Wrap any transport, hand the result
/// to a cluster, and the scripted faults fire as submissions flow
/// through — everything else delegates to the inner transport.
pub struct FaultInjectingTransport {
    inner: Arc<dyn ShardTransport>,
    schedule: ChaosSchedule,
    /// Seeded jitter source for `Delay` faults (determinism: same seed
    /// → same jitter sequence).
    rng: Mutex<Rng>,
    /// Submissions seen so far — the schedule key.
    seq: AtomicU64,
    /// Ids whose replies this decorator swallows.
    dropped: Mutex<BTreeSet<RequestId>>,
    counters: Counters,
}

impl FaultInjectingTransport {
    pub fn new(inner: Arc<dyn ShardTransport>, schedule: ChaosSchedule, seed: u64) -> Self {
        Self {
            inner,
            schedule,
            rng: Mutex::new(Rng::new(seed)),
            seq: AtomicU64::new(0),
            dropped: Mutex::new(BTreeSet::new()),
            counters: Counters::default(),
        }
    }

    /// Faults applied so far.
    pub fn stats(&self) -> ChaosStats {
        ChaosStats {
            delays: self.counters.delays.load(Ordering::Relaxed),
            dropped_replies: self.counters.dropped_replies.load(Ordering::Relaxed),
            garbage_frames: self.counters.garbage_frames.load(Ordering::Relaxed),
            truncated_frames: self.counters.truncated_frames.load(Ordering::Relaxed),
            kills: self.counters.kills.load(Ordering::Relaxed),
            unsupported: self.counters.unsupported.load(Ordering::Relaxed),
        }
    }

    /// The scripted schedule (telemetry reads its summary).
    pub fn schedule(&self) -> &ChaosSchedule {
        &self.schedule
    }

    fn frame_fault(&self, fault: FrameFault) {
        match self.inner.inject_frame_fault(fault) {
            Ok(()) => {
                let counter = match fault {
                    FrameFault::Garbage => &self.counters.garbage_frames,
                    FrameFault::Truncated => &self.counters.truncated_frames,
                };
                counter.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => {
                self.counters.unsupported.fetch_add(1, Ordering::Relaxed);
                crate::log_warn!("chaos frame fault unsupported by inner transport: {e:#}");
            }
        }
    }
}

impl ShardTransport for FaultInjectingTransport {
    fn kind(&self) -> &'static str {
        match self.inner.kind() {
            "process" => "chaos+process",
            "in-process" => "chaos+in-process",
            _ => "chaos",
        }
    }

    fn submit(
        &self,
        id: RequestId,
        problem: MatchProblem,
        priority: Priority,
        timeout: Option<f64>,
        resume: Option<SwarmSnapshot>,
    ) -> Result<()> {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let fault = self.schedule.entries.get(&seq).copied();
        if !matches!(fault, Some(ChaosFault::DropReply)) {
            // an un-faulted resubmission of a previously dropped id
            // supersedes the drop — its new reply flows normally
            lock_recover(&self.dropped).remove(&id);
        }
        if let Some(fault) = fault {
            well::CHAOS_FAULTS.inc();
            span_with(id, SpanKind::Fault, || format!("seq={seq} fault={}", fault.spec()));
            if recorder::enabled() {
                recorder::record(
                    "chaos-fault",
                    vec![
                        ("id".into(), id.to_string()),
                        ("seq".into(), seq.to_string()),
                        ("fault".into(), fault.spec()),
                    ],
                );
            }
        }
        match fault {
            None => {}
            Some(ChaosFault::Delay(base)) => {
                let jitter_us = lock_recover(&self.rng).next_u64() % 1_000;
                thread::sleep(base + Duration::from_micros(jitter_us));
                self.counters.delays.fetch_add(1, Ordering::Relaxed);
            }
            Some(ChaosFault::DropReply) => {
                lock_recover(&self.dropped).insert(id);
                self.counters.dropped_replies.fetch_add(1, Ordering::Relaxed);
            }
            Some(ChaosFault::Garbage) => self.frame_fault(FrameFault::Garbage),
            Some(ChaosFault::Truncate) => self.frame_fault(FrameFault::Truncated),
            Some(ChaosFault::Kill) => {
                self.counters.kills.fetch_add(1, Ordering::Relaxed);
                self.inner.abort();
            }
        }
        if fault.is_some() {
            publish_chaos(&self.stats());
        }
        self.inner.submit(id, problem, priority, timeout, resume)
    }

    fn cancel(&self, id: RequestId) {
        self.inner.cancel(id);
    }

    fn status(&self) -> Result<ShardStatus> {
        self.inner.status()
    }

    fn try_response(&self, id: RequestId) -> Option<MatchResponse> {
        if lock_recover(&self.dropped).contains(&id) {
            // swallow the inner reply (if it ever arrives) — the id
            // stays lost until a resubmission supersedes the drop
            let _ = self.inner.try_response(id);
            return None;
        }
        self.inner.try_response(id)
    }

    fn wait_response(&self, id: RequestId) -> Result<MatchResponse> {
        if lock_recover(&self.dropped).contains(&id) {
            bail!("chaos dropped the reply for request {id}");
        }
        self.inner.wait_response(id)
    }

    fn drain(&self) -> Result<()> {
        self.inner.drain()
    }

    fn healthy(&self) -> bool {
        self.inner.healthy()
    }

    fn lost(&self, id: RequestId) -> bool {
        lock_recover(&self.dropped).contains(&id) || self.inner.lost(id)
    }

    fn abort(&self) {
        self.inner.abort();
    }

    fn inject_frame_fault(&self, fault: FrameFault) -> Result<()> {
        self.inner.inject_frame_fault(fault)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::transport::InProcessShard;
    use crate::coordinator::ServiceConfig;
    use crate::graph::{gen_chain, NodeKind};
    use crate::matcher::PsoConfig;

    fn chain_problem(n: usize, m: usize) -> MatchProblem {
        let qd = gen_chain(n, NodeKind::Compute);
        let gd = gen_chain(m, NodeKind::Universal);
        MatchProblem::from_dags(&qd, &gd)
    }

    #[test]
    fn schedule_spec_round_trips() {
        let spec = "2:kill,5:garbage,7:drop,9:delay=25,11:truncate";
        let schedule = ChaosSchedule::parse(spec).unwrap();
        assert_eq!(schedule.len(), 5);
        assert_eq!(schedule.summary(), spec, "parse → summary must be the identity");
        assert!(ChaosSchedule::parse("1:frobnicate").is_err());
        assert!(ChaosSchedule::parse("nope").is_err());
        assert!(ChaosSchedule::parse("").unwrap().is_empty());
    }

    #[test]
    fn dropped_reply_is_lost_until_resubmission_supersedes() {
        let inner: Arc<dyn ShardTransport> = Arc::new(
            InProcessShard::spawn(
                ServiceConfig::default(),
                PsoConfig { seed: 5, ..Default::default() },
            )
            .unwrap(),
        );
        let chaos = FaultInjectingTransport::new(
            inner,
            ChaosSchedule::default().at(0, ChaosFault::DropReply),
            42,
        );
        let problem = chain_problem(3, 6);
        chaos.submit(1, problem.clone(), Priority::Normal, None, None).unwrap();
        assert!(chaos.lost(1), "a dropped reply must read as lost");
        assert!(chaos.try_response(1).is_none(), "the swallowed reply must never surface");
        assert!(chaos.wait_response(1).is_err());
        // resubmission (sequence 1: no fault) supersedes the drop
        chaos.submit(1, problem, Priority::Normal, None, None).unwrap();
        assert!(!chaos.lost(1));
        let resp = chaos.wait_response(1).unwrap();
        assert!(resp.matched());
        assert_eq!(chaos.stats().dropped_replies, 1);
        chaos.drain().unwrap();
    }

    #[test]
    fn frame_faults_on_frameless_transport_count_as_unsupported() {
        let inner: Arc<dyn ShardTransport> = Arc::new(
            InProcessShard::spawn(
                ServiceConfig::default(),
                PsoConfig { seed: 6, ..Default::default() },
            )
            .unwrap(),
        );
        let chaos = FaultInjectingTransport::new(
            inner,
            ChaosSchedule::default().at(0, ChaosFault::Garbage),
            7,
        );
        assert_eq!(chaos.kind(), "chaos+in-process");
        chaos.submit(1, chain_problem(3, 6), Priority::Normal, None, None).unwrap();
        assert!(chaos.wait_response(1).unwrap().matched(), "the submission still flows");
        assert_eq!(chaos.stats().unsupported, 1);
        assert_eq!(chaos.stats().garbage_frames, 0);
        chaos.drain().unwrap();
    }
}
