//! [`ResumeStore`]: the cluster's persistence layer for cancelled
//! episodes.
//!
//! A preempted (or quota-sliced) episode answers `Cancelled` with a
//! [`SwarmSnapshot`] — the S*/S̄ attractors, the feasible set and the
//! episode RNG at the barrier.  The store keeps those snapshots keyed by
//! request id so a resubmission (to the same shard or migrated to
//! another) warm-starts from where the victim stopped instead of
//! re-exploring from scratch.  Snapshots are padding-agnostic, so a
//! resume is safe across shards whose backends pad to different size
//! classes.
//!
//! The store is bounded: at capacity the oldest snapshot is evicted
//! (a victim that never resubmits must not leak its swarm state
//! forever).  All operations are lock-per-call; nothing here sits on a
//! matching hot path.

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::coordinator::RequestId;
use crate::matcher::SwarmSnapshot;

/// Counters describing the store's traffic.
#[derive(Clone, Copy, Debug, Default)]
pub struct ResumeStats {
    /// Snapshots persisted from cancelled episodes.
    pub saved: u64,
    /// Snapshots consumed by warm-start resubmissions.
    pub taken: u64,
    /// Snapshots evicted at capacity before anyone resumed them.
    pub evicted: u64,
}

/// Bounded snapshot store keyed by request id.
#[derive(Debug)]
pub struct ResumeStore {
    inner: Mutex<Inner>,
    saved: AtomicU64,
    taken: AtomicU64,
    evicted: AtomicU64,
}

#[derive(Debug, Default)]
struct Inner {
    /// BTreeMap, not HashMap: any future iteration (debug dumps,
    /// drain-to-wire) must see id order, not per-process hash order.
    snapshots: BTreeMap<RequestId, SwarmSnapshot>,
    /// Insertion order for capacity eviction (ids may appear stale after
    /// a take; they are skipped).
    order: VecDeque<RequestId>,
    capacity: usize,
}

impl Default for ResumeStore {
    fn default() -> Self {
        Self::with_capacity(1024)
    }
}

impl ResumeStore {
    /// Store holding at most `capacity` snapshots (≥ 1).
    pub fn with_capacity(capacity: usize) -> Self {
        Self {
            inner: Mutex::new(Inner {
                snapshots: BTreeMap::new(),
                order: VecDeque::new(),
                capacity: capacity.max(1),
            }),
            saved: AtomicU64::new(0),
            taken: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
        }
    }

    /// Persist a cancelled episode's barrier snapshot (replacing any
    /// earlier snapshot for the same id — the newest barrier wins).
    pub fn save(&self, id: RequestId, snapshot: SwarmSnapshot) {
        let mut inner = self.inner.lock().unwrap();
        if inner.snapshots.insert(id, snapshot).is_none() {
            inner.order.push_back(id);
        }
        while inner.snapshots.len() > inner.capacity {
            // evict the oldest still-live snapshot
            match inner.order.pop_front() {
                Some(old) => {
                    if inner.snapshots.remove(&old).is_some() {
                        self.evicted.fetch_add(1, Ordering::Relaxed);
                    }
                }
                None => break,
            }
        }
        self.saved.fetch_add(1, Ordering::Relaxed);
    }

    /// Consume the snapshot for `id`, if one is persisted.  Taking is
    /// destructive: a warm start must not be replayed twice from the
    /// same barrier (the resumed episode produces a *newer* snapshot if
    /// it is cancelled again).
    pub fn take(&self, id: RequestId) -> Option<SwarmSnapshot> {
        let snap = self.inner.lock().unwrap().snapshots.remove(&id);
        if snap.is_some() {
            self.taken.fetch_add(1, Ordering::Relaxed);
        }
        snap
    }

    /// Non-destructive read of the snapshot for `id` (a clone; the
    /// persisted copy stays).  Fleet supervision peeks before handing a
    /// snapshot to a replacement shard, so a second crash mid-replay
    /// can still warm-start from the same barrier — ordinary warm
    /// starts must keep using the destructive [`Self::take`].
    pub fn peek(&self, id: RequestId) -> Option<SwarmSnapshot> {
        self.inner.lock().unwrap().snapshots.get(&id).cloned()
    }

    /// Whether a snapshot is persisted for `id`.
    pub fn contains(&self, id: RequestId) -> bool {
        self.inner.lock().unwrap().snapshots.contains_key(&id)
    }

    /// Snapshots currently persisted.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().snapshots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> ResumeStats {
        ResumeStats {
            saved: self.saved.load(Ordering::Relaxed),
            taken: self.taken.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn snap(epochs_done: usize) -> SwarmSnapshot {
        SwarmSnapshot {
            n: 2,
            m: 3,
            s_star: vec![0.5; 6],
            s_bar: vec![0.5; 6],
            best_fitness: -1.0,
            have_star: true,
            epochs_done,
            rng: Rng::new(7),
            mappings: Vec::new(),
        }
    }

    #[test]
    fn save_take_round_trip_is_destructive() {
        let store = ResumeStore::default();
        store.save(9, snap(4));
        assert!(store.contains(9));
        assert_eq!(store.take(9).expect("persisted").epochs_done, 4);
        assert!(store.take(9).is_none(), "a snapshot must not warm-start twice");
        let stats = store.stats();
        assert_eq!((stats.saved, stats.taken), (1, 1));
    }

    #[test]
    fn peek_is_non_destructive() {
        let store = ResumeStore::default();
        store.save(3, snap(6));
        assert_eq!(store.peek(3).expect("persisted").epochs_done, 6);
        assert!(store.contains(3), "peek must leave the snapshot in place");
        assert_eq!(store.stats().taken, 0, "peek is not a take");
        assert_eq!(store.take(3).expect("still persisted").epochs_done, 6);
    }

    #[test]
    fn newest_barrier_wins_for_one_id() {
        let store = ResumeStore::default();
        store.save(1, snap(2));
        store.save(1, snap(7));
        assert_eq!(store.len(), 1);
        assert_eq!(store.take(1).unwrap().epochs_done, 7);
    }

    #[test]
    fn capacity_evicts_oldest() {
        let store = ResumeStore::with_capacity(2);
        store.save(1, snap(1));
        store.save(2, snap(2));
        store.save(3, snap(3));
        assert_eq!(store.len(), 2);
        assert!(!store.contains(1), "oldest snapshot must be evicted");
        assert!(store.contains(2) && store.contains(3));
        assert_eq!(store.stats().evicted, 1);
    }
}
