//! [`WorkerRegistry`] and the versioned `immsched.fleet-wire/v1`
//! membership protocol: how the router *discovers* workers.
//!
//! The fleet protocol is deliberately tiny — three messages framed with
//! the same length-prefixed codec as the shard wire:
//!
//! | message                   | reply                  | meaning |
//! |---------------------------|------------------------|---------|
//! | `join {name, addr}`       | `welcome {worker}`     | a worker offers its dialable shard address |
//! | `heartbeat {worker}`      | `ack`                  | liveness; refreshes the worker's lease |
//! | `leave {worker}`          | `ack`                  | polite departure |
//!
//! A worker's membership connection doubles as its lease: when the
//! connection drops (machine death, `kill -9`), the server-side handler
//! marks every worker it joined as left — an *implicit leave* — so a
//! dead machine disappears from `live()` without waiting out the
//! heartbeat window.  A worker that stays connected but silent ages out
//! of `live()` once its last heartbeat is older than the liveness
//! window, and [`WorkerRegistry::evict_stale`] garbage-collects it.
//!
//! [`registry_respawner`] closes the loop with PR 7's supervision: a
//! [`super::super::SupervisedFleet`] respawner that *waits for a
//! registry join* (bounded) instead of forking a process — a dead
//! machine's in-flight requests replay onto whichever worker joins
//! next.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::ServiceConfig;
use crate::matcher::PsoConfig;
use crate::util::json::{get_hex_u64, get_str, hex_u64, Json};

use super::super::transport::{lock_recover, ShardTransport, TransportConfig};
use super::super::wire::{read_frame, write_frame};
use super::super::ShardId;
use super::socket::{ReconnectConfig, SocketShard};
use super::{NetAddr, NetListener, NetStream};

/// Protocol version tag carried by every fleet frame.  Bump on any
/// layout change: a mixed-version worker/registry pair must fail
/// loudly, not mis-track membership.
pub const FLEET_SCHEMA: &str = "immsched.fleet-wire/v1";

/// Budget for one membership round-trip (join, heartbeat ack).
const REGISTRY_IO_TIMEOUT: Duration = Duration::from_secs(5);

/// Accept-loop poll cadence while no connection is pending.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Handler read-timeout: how often an idle membership connection
/// re-checks the server's stop flag.
const HANDLER_POLL: Duration = Duration::from_millis(25);

/// Poll cadence while waiting for workers to join.
const JOIN_POLL: Duration = Duration::from_millis(2);

// ---------------------------------------------------------------------------
// fleet message codec
// ---------------------------------------------------------------------------

/// Worker → registry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FleetMsg {
    /// Offer a worker: `name` for telemetry, `addr` the dialable shard
    /// endpoint (a [`NetAddr`] spec).
    Join { name: String, addr: String },
    /// Refresh the worker's liveness lease.
    Heartbeat { worker: u64 },
    /// Polite departure (connection drop is the implicit form).
    Leave { worker: u64 },
}

/// Registry → worker.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FleetReply {
    /// Join accepted; `worker` is the registry-assigned id.
    Welcome { worker: u64 },
    /// Heartbeat/leave acknowledged.
    Ack,
    /// Protocol-level rejection (bad address, unknown worker).
    Error { context: String },
}

fn fleet_envelope(t: &str, mut fields: Vec<(&str, Json)>) -> Json {
    let mut all = vec![("schema", Json::from(FLEET_SCHEMA)), ("t", Json::from(t))];
    all.append(&mut fields);
    Json::obj(all)
}

fn check_fleet_envelope(v: &Json) -> Result<&str> {
    let schema = get_str(v, "schema")?;
    anyhow::ensure!(
        schema == FLEET_SCHEMA,
        "fleet wire schema mismatch: peer speaks {schema:?}, this side {FLEET_SCHEMA:?}"
    );
    get_str(v, "t")
}

/// Encode one worker → registry message.
pub fn encode_fleet_msg(msg: &FleetMsg) -> Json {
    match msg {
        FleetMsg::Join { name, addr } => fleet_envelope(
            "join",
            vec![("name", Json::from(name.as_str())), ("addr", Json::from(addr.as_str()))],
        ),
        FleetMsg::Heartbeat { worker } => {
            fleet_envelope("heartbeat", vec![("worker", hex_u64(*worker))])
        }
        FleetMsg::Leave { worker } => fleet_envelope("leave", vec![("worker", hex_u64(*worker))]),
    }
}

/// Decode one worker → registry message.
pub fn decode_fleet_msg(v: &Json) -> Result<FleetMsg> {
    Ok(match check_fleet_envelope(v)? {
        "join" => FleetMsg::Join {
            name: get_str(v, "name")?.to_string(),
            addr: get_str(v, "addr")?.to_string(),
        },
        "heartbeat" => FleetMsg::Heartbeat { worker: get_hex_u64(v, "worker")? },
        "leave" => FleetMsg::Leave { worker: get_hex_u64(v, "worker")? },
        other => bail!("unknown fleet message type {other:?}"),
    })
}

/// Encode one registry → worker reply.
pub fn encode_fleet_reply(reply: &FleetReply) -> Json {
    match reply {
        FleetReply::Welcome { worker } => {
            fleet_envelope("welcome", vec![("worker", hex_u64(*worker))])
        }
        FleetReply::Ack => fleet_envelope("ack", vec![]),
        FleetReply::Error { context } => {
            fleet_envelope("error", vec![("context", Json::from(context.as_str()))])
        }
    }
}

/// Decode one registry → worker reply.
pub fn decode_fleet_reply(v: &Json) -> Result<FleetReply> {
    Ok(match check_fleet_envelope(v)? {
        "welcome" => FleetReply::Welcome { worker: get_hex_u64(v, "worker")? },
        "ack" => FleetReply::Ack,
        "error" => FleetReply::Error { context: get_str(v, "context")?.to_string() },
        other => bail!("unknown fleet reply type {other:?}"),
    })
}

// ---------------------------------------------------------------------------
// the registry
// ---------------------------------------------------------------------------

/// One registered worker.
#[derive(Clone, Debug)]
pub struct WorkerEntry {
    /// Registry-assigned id (unique for the registry's lifetime).
    pub worker: u64,
    /// Worker-chosen name (telemetry only).
    pub name: String,
    /// The dialable shard endpoint the worker advertised.
    pub addr: NetAddr,
    pub joined_at: Instant,
    pub last_beat: Instant,
}

struct RegistryState {
    workers: BTreeMap<u64, WorkerEntry>,
    next_id: u64,
}

/// Fleet membership: who has joined, and who is heartbeat-live.
pub struct WorkerRegistry {
    state: Mutex<RegistryState>,
    window: Duration,
}

impl WorkerRegistry {
    /// A registry whose workers stay live for `window` past their last
    /// heartbeat (a join counts as a heartbeat).
    pub fn new(window: Duration) -> Self {
        Self { state: Mutex::new(RegistryState { workers: BTreeMap::new(), next_id: 1 }), window }
    }

    pub fn liveness_window(&self) -> Duration {
        self.window
    }

    /// Register a worker; returns its registry-assigned id.
    pub fn join(&self, name: &str, addr: NetAddr) -> u64 {
        let mut state = lock_recover(&self.state);
        let worker = state.next_id;
        state.next_id += 1;
        let now = Instant::now();
        state.workers.insert(
            worker,
            WorkerEntry { worker, name: name.to_string(), addr, joined_at: now, last_beat: now },
        );
        crate::log_debug!("fleet: worker {worker} ({name:?}) joined");
        worker
    }

    /// Refresh a worker's lease; `false` if the worker is unknown
    /// (never joined, left, or already evicted).
    pub fn heartbeat(&self, worker: u64) -> bool {
        match lock_recover(&self.state).workers.get_mut(&worker) {
            Some(entry) => {
                entry.last_beat = Instant::now();
                true
            }
            None => false,
        }
    }

    /// Remove a worker; `false` if it was not registered.
    pub fn leave(&self, worker: u64) -> bool {
        let removed = lock_recover(&self.state).workers.remove(&worker).is_some();
        if removed {
            crate::log_debug!("fleet: worker {worker} left");
        }
        removed
    }

    /// Workers whose last heartbeat is within the liveness window —
    /// the only ones the router may dial.
    pub fn live(&self) -> Vec<WorkerEntry> {
        let state = lock_recover(&self.state);
        state.workers.values().filter(|w| w.last_beat.elapsed() <= self.window).cloned().collect()
    }

    /// Drop every worker whose lease has lapsed; returns how many.
    pub fn evict_stale(&self) -> usize {
        let mut state = lock_recover(&self.state);
        let before = state.workers.len();
        let window = self.window;
        state.workers.retain(|_, w| w.last_beat.elapsed() <= window);
        let evicted = before - state.workers.len();
        if evicted > 0 {
            crate::log_debug!("fleet: evicted {evicted} stale workers");
        }
        evicted
    }

    /// Block (bounded by `budget`) until at least `min_workers` workers
    /// are live; returns whatever is live at that point.
    pub fn wait_for_live(&self, min_workers: usize, budget: Duration) -> Vec<WorkerEntry> {
        let started = Instant::now();
        while started.elapsed() <= budget {
            let live = self.live();
            if live.len() >= min_workers {
                return live;
            }
            std::thread::sleep(JOIN_POLL);
        }
        self.live()
    }
}

// ---------------------------------------------------------------------------
// the server side
// ---------------------------------------------------------------------------

/// A listening [`WorkerRegistry`]: an accept loop that speaks the fleet
/// protocol, one handler thread per membership connection.  Dropping
/// the server stops the accept loop.
pub struct RegistryServer {
    registry: Arc<WorkerRegistry>,
    addr: NetAddr,
    stop: Arc<AtomicBool>,
    accept: Mutex<Option<JoinHandle<()>>>,
}

impl RegistryServer {
    /// Bind `addr` (TCP port 0 picks an ephemeral port) with the given
    /// liveness window.
    pub fn bind(addr: &NetAddr, window: Duration) -> Result<Self> {
        let (listener, addr) = NetListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let registry = Arc::new(WorkerRegistry::new(window));
        let stop = Arc::new(AtomicBool::new(false));
        let thread_registry = Arc::clone(&registry);
        let thread_stop = Arc::clone(&stop);
        let accept = std::thread::Builder::new()
            .name("immsched-fleet-accept".into())
            .spawn(move || accept_loop(listener, thread_registry, thread_stop))?;
        Ok(Self { registry, addr, stop, accept: Mutex::new(Some(accept)) })
    }

    /// The membership the accept loop maintains.
    pub fn registry(&self) -> Arc<WorkerRegistry> {
        Arc::clone(&self.registry)
    }

    /// The concrete bound address workers announce to.
    pub fn addr(&self) -> &NetAddr {
        &self.addr
    }
}

impl Drop for RegistryServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(handle) = lock_recover(&self.accept).take() {
            let _ = handle.join();
        }
    }
}

/// Whether an error is a read-timeout (idle poll), not a broken peer.
fn is_timeout(e: &anyhow::Error) -> bool {
    e.downcast_ref::<std::io::Error>().is_some_and(|io| {
        matches!(io.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
    })
}

fn accept_loop(listener: NetListener, registry: Arc<WorkerRegistry>, stop: Arc<AtomicBool>) {
    // lint:allow(no-unbounded-retry): runs for the registry server's lifetime; the stop flag (set on drop) ends it
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok(stream) => {
                let conn_registry = Arc::clone(&registry);
                let conn_stop = Arc::clone(&stop);
                let spawned = std::thread::Builder::new()
                    .name("immsched-fleet-conn".into())
                    .spawn(move || serve_fleet_conn(conn_registry, stream, conn_stop));
                if let Err(e) = spawned {
                    crate::log_warn!("cannot spawn a fleet connection handler: {e:#}");
                }
            }
            Err(e) if is_timeout(&e) => std::thread::sleep(ACCEPT_POLL),
            Err(e) => {
                crate::log_warn!("fleet accept failed: {e:#}");
                std::thread::sleep(ACCEPT_POLL);
            }
        }
    }
}

/// One membership connection: answer fleet messages until EOF, a
/// protocol fault, or server stop; then mark everything this
/// connection joined as left (the implicit leave).
fn serve_fleet_conn(registry: Arc<WorkerRegistry>, mut stream: NetStream, stop: Arc<AtomicBool>) {
    if stream.set_read_timeout(Some(HANDLER_POLL)).is_err() {
        return;
    }
    let mut joined: Vec<u64> = Vec::new();
    // lint:allow(no-unbounded-retry): runs for the connection's lifetime; EOF, a protocol fault, or the stop flag ends it
    while !stop.load(Ordering::Acquire) {
        let frame = match read_frame(&mut stream) {
            Ok(Some(frame)) => frame,
            Ok(None) => break,
            // between frames the socket is idle, so the poll timeout
            // fires on the first prefix byte and framing stays in sync
            Err(e) if is_timeout(&e) => continue,
            Err(e) => {
                crate::log_warn!("fleet connection broke: {e:#}");
                break;
            }
        };
        let reply = match decode_fleet_msg(&frame) {
            Ok(FleetMsg::Join { name, addr }) => match NetAddr::parse(&addr) {
                Ok(addr) => {
                    let worker = registry.join(&name, addr);
                    joined.push(worker);
                    FleetReply::Welcome { worker }
                }
                Err(e) => FleetReply::Error { context: format!("bad worker address: {e:#}") },
            },
            Ok(FleetMsg::Heartbeat { worker }) => {
                if registry.heartbeat(worker) {
                    FleetReply::Ack
                } else {
                    FleetReply::Error { context: format!("unknown worker {worker}") }
                }
            }
            Ok(FleetMsg::Leave { worker }) => {
                joined.retain(|w| *w != worker);
                registry.leave(worker);
                FleetReply::Ack
            }
            Err(e) => {
                // undecodable frames are connection-fatal, mirroring
                // the shard wire: out-of-sync framing poisons
                // everything after it
                crate::log_warn!("undecodable fleet frame, closing the connection: {e:#}");
                break;
            }
        };
        if write_frame(&mut stream, &encode_fleet_reply(&reply)).is_err() {
            break;
        }
    }
    for worker in joined {
        registry.leave(worker);
    }
}

// ---------------------------------------------------------------------------
// the worker side
// ---------------------------------------------------------------------------

/// A worker's live membership: the join succeeded, heartbeats run on a
/// background thread, and dropping the handle sends a polite leave.
pub struct Announcer {
    worker: u64,
    stop: Arc<AtomicBool>,
    beat: Mutex<Option<JoinHandle<()>>>,
}

impl Announcer {
    /// The registry-assigned worker id.
    pub fn worker(&self) -> u64 {
        self.worker
    }

    /// Stop heartbeating and leave the registry (idempotent).
    pub fn halt(&self) {
        self.stop.store(true, Ordering::Release);
        if let Some(handle) = lock_recover(&self.beat).take() {
            let _ = handle.join();
        }
    }
}

impl Drop for Announcer {
    fn drop(&mut self) {
        self.halt();
    }
}

/// Join `registry_addr` as `name`, advertising `advertise` as the
/// dialable shard endpoint, then heartbeat every `interval` on a
/// background thread until the [`Announcer`] is dropped.
pub fn announce(
    registry_addr: &NetAddr,
    name: &str,
    advertise: &NetAddr,
    interval: Duration,
) -> Result<Announcer> {
    let mut stream = registry_addr
        .connect(REGISTRY_IO_TIMEOUT)
        .with_context(|| format!("dialing the registry at {registry_addr}"))?;
    stream
        .set_read_timeout(Some(REGISTRY_IO_TIMEOUT))
        .context("arming the membership read timeout")?;
    let join = FleetMsg::Join { name: name.to_string(), addr: advertise.to_string() };
    write_frame(&mut stream, &encode_fleet_msg(&join)).context("sending the join")?;
    let reply = read_frame(&mut stream)
        .context("reading the join reply")?
        .context("registry closed the connection before answering the join")?;
    let worker = match decode_fleet_reply(&reply)? {
        FleetReply::Welcome { worker } => worker,
        FleetReply::Error { context } => bail!("registry rejected the join: {context}"),
        other => bail!("unexpected join reply {other:?}"),
    };
    let stop = Arc::new(AtomicBool::new(false));
    let beat_stop = Arc::clone(&stop);
    let beat = std::thread::Builder::new().name("immsched-fleet-announce".into()).spawn(
        move || {
            // lint:allow(no-unbounded-retry): heartbeats for the worker's lifetime; the stop flag or a broken registry link ends it
            while !beat_stop.load(Ordering::Acquire) {
                std::thread::sleep(interval);
                if beat_stop.load(Ordering::Acquire) {
                    break;
                }
                let beat_msg = encode_fleet_msg(&FleetMsg::Heartbeat { worker });
                if write_frame(&mut stream, &beat_msg).is_err() {
                    break;
                }
                match read_frame(&mut stream) {
                    Ok(Some(_)) => {}
                    Ok(None) | Err(_) => break,
                }
            }
            let _ = write_frame(&mut stream, &encode_fleet_msg(&FleetMsg::Leave { worker }));
        },
    )?;
    Ok(Announcer { worker, stop, beat: Mutex::new(Some(beat)) })
}

// ---------------------------------------------------------------------------
// discovery → cluster wiring
// ---------------------------------------------------------------------------

/// Dial every heartbeat-live worker and hand back one transport per
/// worker (plus the worker id behind each slot, so supervision can map
/// a dead slot back to its registry entry).  Errors if the registry
/// has no live workers, or any dial fails.
pub fn shards_from_registry(
    registry: &WorkerRegistry,
    service: ServiceConfig,
    pso: PsoConfig,
    tcfg: TransportConfig,
    rcfg: ReconnectConfig,
) -> Result<(Vec<Arc<dyn ShardTransport>>, Vec<u64>)> {
    let live = registry.live();
    anyhow::ensure!(!live.is_empty(), "the registry has no live workers to build a cluster from");
    let mut transports: Vec<Arc<dyn ShardTransport>> = Vec::with_capacity(live.len());
    let mut workers = Vec::with_capacity(live.len());
    for entry in &live {
        let shard = SocketShard::connect_with(entry.addr.clone(), service, pso, tcfg, rcfg)
            .with_context(|| format!("dialing worker {:?} at {}", entry.name, entry.addr))?;
        transports.push(Arc::new(shard));
        workers.push(entry.worker);
    }
    Ok((transports, workers))
}

/// A respawner for [`SupervisedFleet::set_respawn`]: when a shard
/// slot dies, wait (bounded by `join_budget`) for a heartbeat-live
/// worker no other slot is assigned to — typically a fresh join — dial
/// it, and record the slot → worker assignment.  "Respawn" becomes
/// "wait for a registry join".
///
/// `assigned` maps each cluster slot to the registry worker serving it
/// (seed it from [`shards_from_registry`]'s second return).  The dead
/// slot's stale assignment keeps its (possibly still heartbeat-live)
/// victim worker from being re-picked.
///
/// [`SupervisedFleet::set_respawn`]: super::super::SupervisedFleet::set_respawn
#[allow(clippy::too_many_arguments)]
pub fn registry_respawner(
    registry: Arc<WorkerRegistry>,
    assigned: Arc<Mutex<BTreeMap<ShardId, u64>>>,
    service: ServiceConfig,
    pso: PsoConfig,
    tcfg: TransportConfig,
    rcfg: ReconnectConfig,
    join_budget: Duration,
) -> impl Fn(ShardId) -> Result<Arc<dyn ShardTransport>> + Send + Sync + 'static {
    move |slot| {
        let started = Instant::now();
        while started.elapsed() <= join_budget {
            let taken: BTreeSet<u64> = lock_recover(&assigned).values().copied().collect();
            let replacement = registry.live().into_iter().find(|w| !taken.contains(&w.worker));
            if let Some(entry) = replacement {
                let shard =
                    SocketShard::connect_with(entry.addr.clone(), service, pso, tcfg, rcfg)?;
                lock_recover(&assigned).insert(slot, entry.worker);
                crate::log_debug!(
                    "shard {slot} respawned onto registry worker {} ({:?}) at {}",
                    entry.worker,
                    entry.name,
                    entry.addr
                );
                return Ok(Arc::new(shard));
            }
            std::thread::sleep(JOIN_POLL);
        }
        bail!("no unassigned live worker joined the registry within {join_budget:?} for shard {slot}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_messages_round_trip() {
        let msgs = vec![
            FleetMsg::Join { name: "npu-box-3".into(), addr: "tcp://10.0.0.3:7070".into() },
            FleetMsg::Heartbeat { worker: u64::MAX - 7 },
            FleetMsg::Leave { worker: 3 },
        ];
        for msg in &msgs {
            let back = decode_fleet_msg(&encode_fleet_msg(msg)).unwrap();
            assert_eq!(&back, msg);
        }
        let replies = vec![
            FleetReply::Welcome { worker: 1 << 60 },
            FleetReply::Ack,
            FleetReply::Error { context: "nope".into() },
        ];
        for reply in &replies {
            let back = decode_fleet_reply(&encode_fleet_reply(reply)).unwrap();
            assert_eq!(&back, reply);
        }
    }

    #[test]
    fn fleet_schema_mismatch_fails_loudly() {
        let mut doc = encode_fleet_msg(&FleetMsg::Heartbeat { worker: 1 });
        if let Json::Obj(fields) = &mut doc {
            fields[0].1 = Json::from("immsched.fleet-wire/v0");
        }
        let err = decode_fleet_msg(&doc).unwrap_err().to_string();
        assert!(err.contains("schema mismatch"), "{err}");
    }

    #[test]
    fn liveness_window_separates_live_from_stale() {
        let registry = WorkerRegistry::new(Duration::from_millis(40));
        let a = registry.join("a", NetAddr::Tcp("127.0.0.1:1".into()));
        let b = registry.join("b", NetAddr::Tcp("127.0.0.1:2".into()));
        assert_eq!(registry.live().len(), 2);
        // only a heartbeats past the window
        std::thread::sleep(Duration::from_millis(30));
        assert!(registry.heartbeat(a));
        std::thread::sleep(Duration::from_millis(25));
        let live = registry.live();
        assert_eq!(live.len(), 1, "b's lease must have lapsed");
        assert_eq!(live[0].worker, a);
        assert_eq!(registry.evict_stale(), 1);
        assert!(!registry.heartbeat(b), "an evicted worker must re-join, not heartbeat");
        assert!(registry.leave(a));
        assert_eq!(registry.live().len(), 0);
    }
}
