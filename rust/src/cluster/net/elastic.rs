//! Registry-driven fleet elasticity: grow and retire shard slots
//! against the observed queue depth.
//!
//! The cluster's slot count is fixed at construction (`views()`,
//! `stats()` and routing are all indexed by slot), so elasticity works
//! *within* the slots: retiring a shard swaps a [`RetiredShard`]
//! placeholder into its slot — it reports the degraded queue depth, so
//! every routing policy already avoids it — and growing swaps a real
//! transport back in via the spawner (typically a registry dial).
//! Capacity planning therefore sets `max_shards` at spawn time and
//! lets the scaler decide how many slots are *live*.
//!
//! The policy itself is the pure function [`scale_decision`], kept
//! free of I/O so it can be tested as a table; [`ElasticScaler::step`]
//! applies one decision to a live cluster.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::coordinator::{MatchProblem, MatchResponse, RequestId};
use crate::matcher::SwarmSnapshot;
use crate::scheduler::Priority;

use super::super::transport::{lock_recover, ShardTransport};
use super::super::wire::ShardStatus;
use super::super::{MatchCluster, DEGRADED_QUEUE_DEPTH};

/// Elasticity thresholds, all in queued requests *per live shard*.
#[derive(Clone, Copy, Debug)]
pub struct ElasticityConfig {
    /// Grow when the total queue depth exceeds this many requests per
    /// live shard (and a retired slot is available to fill).
    pub grow_above: usize,
    /// Retire when the total queue depth falls below this many
    /// requests per live shard (and more than `min_shards` are live).
    pub shrink_below: usize,
    /// Never retire below this many live shards.
    pub min_shards: usize,
    /// Never grow above this many live shards (the slot count caps it
    /// regardless).
    pub max_shards: usize,
}

impl Default for ElasticityConfig {
    fn default() -> Self {
        Self { grow_above: 4, shrink_below: 1, min_shards: 1, max_shards: usize::MAX }
    }
}

/// One elasticity verdict.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleAction {
    /// Fill a retired slot with a fresh worker.
    Grow,
    /// Drain and retire one live shard.
    Shrink,
    /// Load sits inside the hysteresis band — do nothing.
    Hold,
}

/// The pure scaling policy: what to do with `live` live shards and
/// `total_queue_depth` queued requests across them.  `Grow` whenever
/// the fleet is below `min_shards`; otherwise grow on
/// `depth > grow_above · live` (capped by `max_shards`) and shrink on
/// `depth < shrink_below · live` (floored by `min_shards`).
pub fn scale_decision(
    cfg: &ElasticityConfig,
    live: usize,
    total_queue_depth: usize,
) -> ScaleAction {
    if live < cfg.min_shards {
        return ScaleAction::Grow;
    }
    if live < cfg.max_shards && total_queue_depth > cfg.grow_above.saturating_mul(live) {
        return ScaleAction::Grow;
    }
    if live > cfg.min_shards && total_queue_depth < cfg.shrink_below.saturating_mul(live) {
        return ScaleAction::Shrink;
    }
    ScaleAction::Hold
}

/// The placeholder transport occupying a retired slot.  It reports the
/// degraded queue depth — the same sentinel a dead worker's failed
/// probe caches — so every routing policy already knows to route
/// around it, and it rejects anything routed at it anyway.
#[derive(Debug, Default)]
pub struct RetiredShard;

impl ShardTransport for RetiredShard {
    fn kind(&self) -> &'static str {
        "retired"
    }

    fn submit(
        &self,
        id: RequestId,
        _problem: MatchProblem,
        _priority: Priority,
        _timeout: Option<f64>,
        _resume: Option<SwarmSnapshot>,
    ) -> Result<()> {
        bail!("request {id}: this shard slot is retired")
    }

    fn cancel(&self, _id: RequestId) {}

    fn status(&self) -> Result<ShardStatus> {
        Ok(ShardStatus { queue_depth: DEGRADED_QUEUE_DEPTH, ..ShardStatus::default() })
    }

    fn try_response(&self, _id: RequestId) -> Option<MatchResponse> {
        None
    }

    fn wait_response(&self, id: RequestId) -> Result<MatchResponse> {
        bail!("request {id}: a retired shard slot holds no responses")
    }

    fn drain(&self) -> Result<()> {
        Ok(())
    }
}

/// Applies [`scale_decision`] to a live [`MatchCluster`]: retire swaps
/// a [`RetiredShard`] into the slot after draining the incumbent; grow
/// refills a retired slot from the spawner (typically
/// [`super::registry::shards_from_registry`]'s dialer or a
/// [`super::SocketShard`] factory).
pub struct ElasticScaler {
    cluster: Arc<MatchCluster>,
    cfg: ElasticityConfig,
    spawner: Box<dyn Fn() -> Result<Arc<dyn ShardTransport>> + Send + Sync>,
    /// Which slots hold a live transport (false = retired placeholder).
    live: Mutex<Vec<bool>>,
    grows: AtomicU64,
    retires: AtomicU64,
}

impl ElasticScaler {
    /// Wrap `cluster`, whose every slot is assumed live.  `spawner`
    /// produces a replacement transport when a retired slot regrows.
    pub fn new(
        cluster: Arc<MatchCluster>,
        cfg: ElasticityConfig,
        spawner: impl Fn() -> Result<Arc<dyn ShardTransport>> + Send + Sync + 'static,
    ) -> Self {
        let slots = cluster.shard_count();
        Self {
            cluster,
            cfg,
            spawner: Box::new(spawner),
            live: Mutex::new(vec![true; slots]),
            grows: AtomicU64::new(0),
            retires: AtomicU64::new(0),
        }
    }

    /// How many slots currently hold a live transport.
    pub fn live_count(&self) -> usize {
        lock_recover(&self.live).iter().filter(|l| **l).count()
    }

    /// `(grows, retires)` applied over this scaler's lifetime.
    pub fn churn(&self) -> (u64, u64) {
        (self.grows.load(Ordering::Acquire), self.retires.load(Ordering::Acquire))
    }

    /// Observe the cluster and apply at most one scaling action;
    /// returns what was actually done (a `Grow` verdict with no
    /// retired slot left to fill degrades to `Hold`).
    pub fn step(&self) -> Result<ScaleAction> {
        let views = self.cluster.views();
        let live = lock_recover(&self.live).clone();
        let live_count = live.iter().filter(|l| **l).count();
        // a degraded depth is a dead-or-retired sentinel, not load
        let depth: usize = views
            .iter()
            .filter(|v| live.get(v.shard).copied().unwrap_or(false) && !v.is_degraded())
            .map(|v| v.queue_depth)
            .sum();
        match scale_decision(&self.cfg, live_count, depth) {
            ScaleAction::Grow => self.grow(),
            ScaleAction::Shrink => {
                // retire the emptiest live shard: cheapest to drain
                let victim = views
                    .iter()
                    .filter(|v| live.get(v.shard).copied().unwrap_or(false))
                    .min_by_key(|v| v.queue_depth)
                    .map(|v| v.shard);
                match victim {
                    Some(slot) => self.retire(slot).map(|()| ScaleAction::Shrink),
                    None => Ok(ScaleAction::Hold),
                }
            }
            ScaleAction::Hold => Ok(ScaleAction::Hold),
        }
    }

    /// Fill the lowest retired slot from the spawner; `Hold` if every
    /// slot is already live.
    pub fn grow(&self) -> Result<ScaleAction> {
        let slot = {
            let live = lock_recover(&self.live);
            live.iter().position(|l| !*l)
        };
        let Some(slot) = slot else {
            return Ok(ScaleAction::Hold);
        };
        let shard = (self.spawner)().context("spawning a replacement shard")?;
        self.cluster.replace_transport(slot, shard);
        if let Some(live) = lock_recover(&self.live).get_mut(slot) {
            *live = true;
        }
        self.grows.fetch_add(1, Ordering::AcqRel);
        crate::log_debug!("elastic: slot {slot} regrown");
        Ok(ScaleAction::Grow)
    }

    /// Drain `slot`'s transport and swap in the retired placeholder.
    /// New routing sees the degraded placeholder immediately; the
    /// incumbent finishes (and keeps serving) its already-issued
    /// tickets before its handle drops.
    pub fn retire(&self, slot: usize) -> Result<()> {
        let incumbent = self.cluster.transport(slot);
        self.cluster.replace_transport(slot, Arc::new(RetiredShard));
        if let Some(live) = lock_recover(&self.live).get_mut(slot) {
            *live = false;
        }
        self.retires.fetch_add(1, Ordering::AcqRel);
        incumbent.drain().with_context(|| format!("draining retired slot {slot}"))?;
        crate::log_debug!("elastic: slot {slot} retired");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::super::{ClusterConfig, InProcessShard, LeastQueueDepth};
    use super::*;
    use crate::coordinator::ServiceConfig;
    use crate::graph::{gen_chain, NodeKind};
    use crate::matcher::PsoConfig;

    #[test]
    fn scale_decision_table() {
        let cfg =
            ElasticityConfig { grow_above: 4, shrink_below: 1, min_shards: 1, max_shards: 3 };
        // below the floor: always grow
        assert_eq!(scale_decision(&cfg, 0, 0), ScaleAction::Grow);
        // 2 live, depth 9 > 4·2: grow
        assert_eq!(scale_decision(&cfg, 2, 9), ScaleAction::Grow);
        // at the cap: the same load holds
        assert_eq!(scale_decision(&cfg, 3, 100), ScaleAction::Hold);
        // 2 live, depth 1 < 1·2: shrink
        assert_eq!(scale_decision(&cfg, 2, 1), ScaleAction::Shrink);
        // at the floor: an empty queue holds
        assert_eq!(scale_decision(&cfg, 1, 0), ScaleAction::Hold);
        // inside the band: hold
        assert_eq!(scale_decision(&cfg, 2, 5), ScaleAction::Hold);
    }

    #[test]
    fn retire_then_regrow_round_trips_a_slot() {
        let pso = PsoConfig { seed: 9, ..Default::default() };
        let cfg = ClusterConfig { shards: 2, pso, ..Default::default() };
        let cluster =
            Arc::new(MatchCluster::spawn(cfg, Box::new(LeastQueueDepth)).unwrap());
        let scaler = ElasticScaler::new(
            Arc::clone(&cluster),
            ElasticityConfig { min_shards: 1, ..Default::default() },
            move || Ok(Arc::new(InProcessShard::spawn(ServiceConfig::default(), pso)?)),
        );
        assert_eq!(scaler.live_count(), 2);

        scaler.retire(1).unwrap();
        assert_eq!(scaler.live_count(), 1);
        assert_eq!(cluster.transport(1).kind(), "retired");
        // the retired slot reads as degraded, so routing avoids it and
        // submissions still land on the live shard
        let qd = gen_chain(3, NodeKind::Compute);
        let gd = gen_chain(6, NodeKind::Universal);
        for _ in 0..3 {
            let ticket = cluster
                .submit(MatchProblem::from_dags(&qd, &gd), Priority::Normal, None)
                .unwrap();
            assert_eq!(ticket.shard, 0, "routing must avoid the retired slot");
            assert!(ticket.wait().unwrap().matched());
        }

        assert_eq!(scaler.grow().unwrap(), ScaleAction::Grow);
        assert_eq!(scaler.live_count(), 2);
        assert_eq!(cluster.transport(1).kind(), "in-process");
        let ticket = cluster
            .submit(MatchProblem::from_dags(&qd, &gd), Priority::Normal, None)
            .unwrap();
        assert!(ticket.wait().unwrap().matched());
        // every slot live again: growing further holds
        assert_eq!(scaler.grow().unwrap(), ScaleAction::Hold);
        assert_eq!(scaler.churn(), (1, 1));
    }
}
