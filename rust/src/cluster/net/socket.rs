//! [`SocketShard`]: a [`ShardTransport`] over a stream socket, with
//! reconnect-with-resume.
//!
//! The shard dials a [`super::ShardListener`] (TCP or Unix-domain),
//! performs the Hello → Ready handshake, and then speaks exactly the
//! framed [`wire`] protocol `ProcessShard` speaks over stdio.  What the
//! socket adds is *link supervision*: every submission is recorded in
//! an in-flight table (problem, priority, relative timeout, warm-start
//! snapshot) until its response arrives, and when the connection breaks
//! the link thread redials under a bounded exponential backoff
//! ([`ReconnectConfig`]) and resubmits every unanswered request from
//! its persisted [`SwarmSnapshot`] — so a severed link costs zero lost
//! epochs and the resumed episode is bit-identical to an uninterrupted
//! one.  Undecodable frames are connection-fatal (framing is out of
//! sync); the redial gives the session a fresh frame boundary.
//!
//! Liveness semantics: the shard stays `healthy()` while redialing —
//! supervision must not fail over a link that is about to heal — and
//! reports dead (with every unanswered request `lost()`) only once the
//! redial budget is exhausted or the shard is closed.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::{MatchProblem, MatchResponse, RequestId, ServiceConfig};
use crate::matcher::{PsoConfig, SwarmSnapshot};
use crate::obs::metrics::well;
use crate::obs::recorder;
use crate::obs::trace::{self, span_with, SpanKind};
use crate::scheduler::Priority;
use crate::util::json::Json;

use super::super::transport::{lock_recover, submit_trace_ctx, ShardTransport, TransportConfig};
use super::super::wire::{
    self, decode_reply, encode_msg, read_frame, write_frame, ShardMsg, ShardReply, ShardStatus,
};
use super::{NetAddr, NetStream};

/// Redial policy for a severed connection: how many attempts one outage
/// may consume, and the exponential backoff between them.
#[derive(Clone, Copy, Debug)]
pub struct ReconnectConfig {
    /// Redial attempts per outage before the shard is declared dead.
    pub max_redials: u32,
    /// Backoff before the first redial; doubles per attempt.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
}

impl Default for ReconnectConfig {
    fn default() -> Self {
        Self {
            max_redials: 5,
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(500),
        }
    }
}

/// Link-supervision counters (telemetry + test assertions).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReconnectStats {
    /// Redial attempts made (successful or not).
    pub redials: u64,
    /// Requests resubmitted onto a healed link from their snapshots.
    pub resubmits: u64,
}

/// One recorded submission, kept until its response arrives so a
/// healed link can replay it exactly as first submitted.
struct Inflight {
    problem: MatchProblem,
    priority: Priority,
    timeout: Option<f64>,
    resume: Option<SwarmSnapshot>,
    /// Link generation the request was last written on (0 = never
    /// written — e.g. submitted while the link was down).  After a
    /// redial bumps the generation, every entry with an older stamp is
    /// resubmitted.
    sent_gen: u64,
}

/// The write half of the live session, plus its generation counter.
struct Link {
    /// `None` while a redial is in progress or after shutdown.
    stream: Option<NetStream>,
    /// Bumped on every successful (re)dial; generation 1 is the
    /// original connection.
    generation: u64,
}

struct DemuxState {
    responses: BTreeMap<RequestId, MatchResponse>,
    /// The link is gone for good (redial budget exhausted or shard
    /// closed); waiting for anything not already demuxed is hopeless.
    dead: bool,
}

struct Control {
    stats_rx: mpsc::Receiver<ShardStatus>,
    drained_rx: mpsc::Receiver<u64>,
}

struct Inner {
    addr: NetAddr,
    service: ServiceConfig,
    pso: PsoConfig,
    tcfg: TransportConfig,
    rcfg: ReconnectConfig,
    link: Mutex<Link>,
    state: Mutex<DemuxState>,
    arrived: Condvar,
    /// Freshest status piggybacked on a reply (or answered to a stats
    /// round-trip), consumed by [`ShardTransport::take_pushed_status`].
    pushed: Mutex<Option<(Instant, ShardStatus)>>,
    inflight: Mutex<BTreeMap<RequestId, Inflight>>,
    control: Mutex<Control>,
    stats_tx: mpsc::Sender<ShardStatus>,
    drained_tx: mpsc::Sender<u64>,
    /// Set by drain/abort: no more submissions, no more redials.
    closed: AtomicBool,
    redials: AtomicU64,
    resubmits: AtomicU64,
}

/// A shard reached over a stream socket — see the module docs.
pub struct SocketShard {
    inner: Arc<Inner>,
    reader: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl SocketShard {
    /// Dial `addr` with default timing and redial policies.
    pub fn connect(addr: NetAddr, service: ServiceConfig, pso: PsoConfig) -> Result<Self> {
        Self::connect_with(addr, service, pso, TransportConfig::default(), Default::default())
    }

    /// [`Self::connect`] with explicit transport timing and redial
    /// knobs (tests shrink both to force outages in milliseconds).
    pub fn connect_with(
        addr: NetAddr,
        service: ServiceConfig,
        pso: PsoConfig,
        tcfg: TransportConfig,
        rcfg: ReconnectConfig,
    ) -> Result<Self> {
        let stream = dial(&addr, service, pso, &tcfg)?;
        let read_half = stream.try_clone().context("splitting the dialed stream")?;
        let (stats_tx, stats_rx) = mpsc::channel();
        let (drained_tx, drained_rx) = mpsc::channel();
        let inner = Arc::new(Inner {
            addr,
            service,
            pso,
            tcfg,
            rcfg,
            link: Mutex::new(Link { stream: Some(stream), generation: 1 }),
            state: Mutex::new(DemuxState { responses: BTreeMap::new(), dead: false }),
            arrived: Condvar::new(),
            pushed: Mutex::new(None),
            inflight: Mutex::new(BTreeMap::new()),
            control: Mutex::new(Control { stats_rx, drained_rx }),
            stats_tx,
            drained_tx,
            closed: AtomicBool::new(false),
            redials: AtomicU64::new(0),
            resubmits: AtomicU64::new(0),
        });
        let link_inner = Arc::clone(&inner);
        let reader = std::thread::Builder::new()
            .name("immsched-socket-link".into())
            .spawn(move || link_loop(link_inner, read_half))?;
        Ok(Self { inner, reader: Mutex::new(Some(reader)) })
    }

    /// Link-supervision counters so far.
    pub fn reconnect_stats(&self) -> ReconnectStats {
        ReconnectStats {
            redials: self.inner.redials.load(Ordering::Relaxed),
            resubmits: self.inner.resubmits.load(Ordering::Relaxed),
        }
    }

    /// Test hook: sever the live connection *without* closing the shard
    /// — the link thread observes the broken stream and redials, which
    /// is exactly what a flaky network does.
    pub fn sever(&self) {
        if let Some(stream) = lock_recover(&self.inner.link).stream.take() {
            stream.shutdown_both();
        }
    }

    fn send(&self, msg: &ShardMsg) -> Result<()> {
        let mut link = lock_recover(&self.inner.link);
        match link.stream.as_mut() {
            Some(stream) => write_frame(stream, &encode_msg(msg)),
            None => bail!("socket shard link to {} is down", self.inner.addr),
        }
    }

    fn close_link(&self) {
        if let Some(stream) = lock_recover(&self.inner.link).stream.take() {
            stream.shutdown_both();
        }
    }

    fn join_reader(&self) {
        if let Some(handle) = lock_recover(&self.reader).take() {
            let _ = handle.join();
        }
    }
}

impl ShardTransport for SocketShard {
    fn kind(&self) -> &'static str {
        "socket"
    }

    fn submit(
        &self,
        id: RequestId,
        problem: MatchProblem,
        priority: Priority,
        timeout: Option<f64>,
        resume: Option<SwarmSnapshot>,
    ) -> Result<()> {
        if self.inner.closed.load(Ordering::Acquire) {
            bail!("socket shard closed: no further submissions accepted");
        }
        if lock_recover(&self.inner.state).dead {
            bail!("socket shard link to {} is dead (redial budget exhausted)", self.inner.addr);
        }
        // record before writing (and under the link lock, so a redial's
        // resubmission sweep cannot run between the two): if the write
        // is lost to a severed link, the sweep finds the entry and
        // replays it on the healed session
        let mut link = lock_recover(&self.inner.link);
        let generation = link.generation;
        lock_recover(&self.inner.inflight).insert(
            id,
            Inflight {
                problem: problem.clone(),
                priority,
                timeout,
                resume: resume.clone(),
                sent_gen: 0,
            },
        );
        if let Some(stream) = link.stream.as_mut() {
            let msg =
                ShardMsg::Submit { id, problem, priority, timeout, resume, trace: submit_trace_ctx(id) };
            match write_frame(stream, &encode_msg(&msg)) {
                Ok(()) => {
                    if let Some(entry) = lock_recover(&self.inner.inflight).get_mut(&id) {
                        entry.sent_gen = generation;
                    }
                }
                Err(e) => {
                    // the link thread will notice the broken stream and
                    // redial; the entry just recorded rides along
                    crate::log_warn!("submit {id} write failed, deferred to redial: {e:#}");
                }
            }
        }
        Ok(())
    }

    fn cancel(&self, id: RequestId) {
        // best-effort: if the link is down, the redial resubmits the
        // request and the caller may cancel again
        let _ = self.send(&ShardMsg::Cancel { id });
    }

    fn status(&self) -> Result<ShardStatus> {
        let control = lock_recover(&self.inner.control);
        // a reply that arrived after an earlier call timed out would
        // otherwise answer *this* request and desync every later one
        // lint:allow(no-unbounded-retry): drains already-buffered stale replies; try_recv never blocks
        while control.stats_rx.try_recv().is_ok() {}
        self.send(&ShardMsg::Stats)?;
        control
            .stats_rx
            .recv_timeout(self.inner.tcfg.control_timeout)
            .context("socket shard did not answer a stats request")
    }

    fn try_response(&self, id: RequestId) -> Option<MatchResponse> {
        lock_recover(&self.inner.state).responses.remove(&id)
    }

    fn wait_response(&self, id: RequestId) -> Result<MatchResponse> {
        let mut state = lock_recover(&self.inner.state);
        // lint:allow(no-unbounded-retry): parked on a condvar; the link thread notifies on every arrival and on death
        loop {
            if let Some(resp) = state.responses.remove(&id) {
                return Ok(resp);
            }
            if state.dead {
                bail!("socket shard link died before answering request {id}");
            }
            state = self.inner.arrived.wait(state).unwrap_or_else(PoisonError::into_inner);
        }
    }

    fn drain(&self) -> Result<()> {
        let control = lock_recover(&self.inner.control);
        self.inner.closed.store(true, Ordering::Release);
        // lint:allow(no-unbounded-retry): drains already-buffered stale replies; try_recv never blocks
        while control.drained_rx.try_recv().is_ok() {}
        self.send(&ShardMsg::Drain)?;
        let answered = control
            .drained_rx
            .recv_timeout(self.inner.tcfg.control_timeout)
            .context("socket shard did not acknowledge the drain")?;
        drop(control);
        crate::log_debug!("socket shard to {} drained after {answered} responses", self.inner.addr);
        self.close_link();
        self.join_reader();
        Ok(())
    }

    fn healthy(&self) -> bool {
        // a redial in progress is still healthy — failing over a link
        // that is about to heal would double-run its requests
        !lock_recover(&self.inner.state).dead
    }

    fn lost(&self, id: RequestId) -> bool {
        let state = lock_recover(&self.inner.state);
        state.dead && !state.responses.contains_key(&id)
    }

    fn abort(&self) {
        self.inner.closed.store(true, Ordering::Release);
        self.close_link();
        {
            let mut state = lock_recover(&self.inner.state);
            state.dead = true;
        }
        self.inner.arrived.notify_all();
        self.join_reader();
    }

    fn take_pushed_status(&self) -> Option<(Instant, ShardStatus)> {
        lock_recover(&self.inner.pushed).take()
    }
}

impl Drop for SocketShard {
    fn drop(&mut self) {
        if self.inner.closed.load(Ordering::Acquire) {
            self.join_reader();
            return;
        }
        if self.drain().is_err() {
            self.abort();
        }
    }
}

/// Connect + handshake: Hello (carrying the shard config) out, Ready
/// (proving the schema) back, under the control timeout.
fn dial(
    addr: &NetAddr,
    service: ServiceConfig,
    pso: PsoConfig,
    tcfg: &TransportConfig,
) -> Result<NetStream> {
    let mut stream = addr.connect(tcfg.control_timeout)?;
    stream
        .set_read_timeout(Some(tcfg.control_timeout))
        .context("arming the handshake read timeout")?;
    write_frame(&mut stream, &encode_msg(&ShardMsg::Hello { service, pso }))
        .with_context(|| format!("sending the hello to {addr}"))?;
    let first = read_frame(&mut stream)
        .with_context(|| format!("reading the handshake reply from {addr}"))?
        .with_context(|| format!("{addr} closed the connection before answering the hello"))?;
    match decode_reply(&first)? {
        ShardReply::Ready { schema } if schema == wire::WIRE_SCHEMA => {}
        ShardReply::Ready { schema } => {
            bail!("listener {addr} speaks {schema:?}, expected {:?}", wire::WIRE_SCHEMA)
        }
        ShardReply::Error { context } => bail!("listener {addr} rejected the hello: {context}"),
        other => bail!("unexpected handshake reply from {addr}: {other:?}"),
    }
    stream.set_read_timeout(None).context("disarming the handshake read timeout")?;
    Ok(stream)
}

/// The link thread: demux replies off the live session; when the
/// stream breaks, redial within the configured budget and resubmit
/// everything unanswered; mark the shard dead when the budget is spent
/// or the shard closes.
fn link_loop(inner: Arc<Inner>, mut read_half: NetStream) {
    // One iteration = one read on the live session.  The loop ends via
    // the closed flag or the bounded redial budget below.
    loop {
        match read_frame(&mut read_half) {
            Ok(Some(frame)) => match route_reply(&inner, &frame) {
                Ok(()) => continue,
                Err(e) => {
                    // undecodable reply: the framing is out of sync and
                    // every later frame is suspect — connection-fatal.
                    // A redial gives the session a fresh frame boundary.
                    crate::log_warn!("undecodable reply from {}, severing: {e:#}", inner.addr);
                }
            },
            Ok(None) | Err(_) => {}
        }
        // the session is over (EOF, I/O error, or a fatal decode)
        read_half.shutdown_both();
        if inner.closed.load(Ordering::Acquire) {
            break;
        }
        match redial_within_budget(&inner) {
            Some(next_read_half) => read_half = next_read_half,
            None => break,
        }
    }
    lock_recover(&inner.state).dead = true;
    inner.arrived.notify_all();
}

/// Route one decoded reply to its waiter/slot.
fn route_reply(inner: &Inner, frame: &Json) -> Result<()> {
    match decode_reply(frame)? {
        ShardReply::Response { response, status, spans } => {
            lock_recover(&inner.inflight).remove(&response.id);
            trace::ingest_remote(spans);
            if let Some(status) = status {
                *lock_recover(&inner.pushed) = Some((Instant::now(), status));
            }
            lock_recover(&inner.state).responses.insert(response.id, response);
            inner.arrived.notify_all();
        }
        ShardReply::Stats(status) => {
            *lock_recover(&inner.pushed) = Some((Instant::now(), status.clone()));
            let _ = inner.stats_tx.send(status);
        }
        ShardReply::Drained { answered } => {
            let _ = inner.drained_tx.send(answered);
        }
        ShardReply::Error { context } => {
            crate::log_warn!("socket shard error reply from {}: {context}", inner.addr);
        }
        ShardReply::Ready { .. } => {
            crate::log_warn!("socket shard peer {} sent a stray ready frame", inner.addr);
        }
    }
    Ok(())
}

/// Exponential backoff for redial `attempt` (1-based), capped.
fn redial_backoff(rcfg: &ReconnectConfig, attempt: u32) -> Duration {
    let doublings = attempt.saturating_sub(1).min(16);
    rcfg.backoff_base.saturating_mul(1u32 << doublings).min(rcfg.backoff_cap)
}

/// Redial under the configured budget; on success the new session's
/// read half comes back and every unanswered request has been
/// resubmitted onto it.  `None` = budget exhausted (or shard closed).
fn redial_within_budget(inner: &Inner) -> Option<NetStream> {
    let mut attempt: u32 = 0;
    while attempt < inner.rcfg.max_redials {
        attempt += 1;
        inner.redials.fetch_add(1, Ordering::Relaxed);
        well::NET_REDIALS.inc();
        if recorder::enabled() {
            recorder::record(
                "redial",
                vec![
                    ("addr".into(), inner.addr.to_string()),
                    ("attempt".into(), attempt.to_string()),
                    ("budget".into(), inner.rcfg.max_redials.to_string()),
                ],
            );
        }
        if attempt == 1 && trace::enabled() {
            // stamp the outage onto every request it strands
            for id in lock_recover(&inner.inflight).keys() {
                span_with(*id, SpanKind::Redial, || format!("addr={}", inner.addr));
            }
        }
        std::thread::sleep(redial_backoff(&inner.rcfg, attempt));
        if inner.closed.load(Ordering::Acquire) {
            return None;
        }
        match reconnect(inner) {
            Ok(read_half) => {
                crate::log_debug!(
                    "socket shard link to {} healed on redial {attempt}/{}",
                    inner.addr,
                    inner.rcfg.max_redials
                );
                return Some(read_half);
            }
            Err(e) => {
                crate::log_warn!(
                    { addr = inner.addr, attempt = attempt, budget = inner.rcfg.max_redials },
                    "socket shard redial failed: {e:#}"
                );
            }
        }
    }
    crate::log_warn!(
        { addr = inner.addr, redials = inner.rcfg.max_redials },
        "socket shard link is dead, redial budget exhausted"
    );
    if recorder::enabled() {
        recorder::record(
            "link-dead",
            vec![
                ("addr".into(), inner.addr.to_string()),
                ("redials".into(), inner.redials.load(Ordering::Relaxed).to_string()),
                (
                    "stranded".into(),
                    lock_recover(&inner.inflight).len().to_string(),
                ),
            ],
        );
        recorder::dump_to_disk("link-dead");
    }
    None
}

/// One redial attempt: dial + handshake, install the new write half
/// under a bumped generation, and resubmit every in-flight request not
/// yet written on this generation (oldest id first), each from its
/// persisted warm-start snapshot.
fn reconnect(inner: &Inner) -> Result<NetStream> {
    let stream = dial(&inner.addr, inner.service, inner.pso, &inner.tcfg)?;
    let read_half = stream.try_clone().context("splitting the redialed stream")?;
    let mut link = lock_recover(&inner.link);
    link.generation += 1;
    let generation = link.generation;
    link.stream = Some(stream);
    let mut inflight = lock_recover(&inner.inflight);
    for (id, entry) in inflight.iter_mut() {
        if entry.sent_gen >= generation {
            continue;
        }
        let msg = ShardMsg::Submit {
            id: *id,
            problem: entry.problem.clone(),
            priority: entry.priority,
            timeout: entry.timeout,
            resume: entry.resume.clone(),
            trace: submit_trace_ctx(*id),
        };
        match link.stream.as_mut() {
            Some(stream) => write_frame(stream, &encode_msg(&msg))
                .with_context(|| format!("resubmitting request {id} after a redial"))?,
            None => bail!("link stream vanished mid-resubmission"),
        }
        entry.sent_gen = generation;
        inner.resubmits.fetch_add(1, Ordering::Relaxed);
        well::NET_RESUBMITS.inc();
        span_with(*id, SpanKind::Resubmit, || format!("generation={generation}"));
    }
    Ok(read_half)
}
