//! `cluster/net/`: shards on the network — the multi-host serving
//! subsystem.
//!
//! Everything the cluster already proved across a *process* boundary
//! (the framed [`super::wire`] codec, bit-identical snapshot
//! migration, supervised failover) is lifted here onto real stream
//! sockets, so shards can live on other machines:
//!
//! * [`NetAddr`] / [`NetStream`] — one address type over TCP and
//!   Unix-domain stream sockets (`tcp://host:port`, `unix:///path`, or
//!   a bare `host:port`), and one stream type the codec reads/writes.
//! * [`socket`] — [`SocketShard`], a [`super::ShardTransport`] that
//!   dials a listener and speaks the wire protocol over the socket,
//!   with reconnect-with-resume: a severed link is redialed under a
//!   bounded exponential backoff and every unanswered request is
//!   resubmitted from its persisted warm-start snapshot.
//! * [`listen`] — [`ShardListener`], the serving side: an accept loop
//!   that runs one `worker_serve` session (one `MatchService`) per
//!   connection; the `immsched shard-listen` subcommand wraps it.
//! * [`registry`] — [`WorkerRegistry`] and the versioned
//!   `immsched.fleet-wire/v1` join/leave/heartbeat protocol, so the
//!   router *discovers* workers instead of being handed them, and a
//!   supervised fleet's "respawn" becomes "wait for a registry join".
//! * [`elastic`] — registry-driven fleet elasticity: grow/retire shard
//!   slots against the observed queue depth.

pub mod elastic;
pub mod listen;
pub mod registry;
pub mod socket;

pub use elastic::{scale_decision, ElasticScaler, ElasticityConfig, RetiredShard, ScaleAction};
pub use listen::{spawn_shard_listener, ListenConfig, ListenerChild, ShardListener};
pub use registry::{
    announce, registry_respawner, shards_from_registry, Announcer, FleetMsg, FleetReply,
    RegistryServer, WorkerEntry, WorkerRegistry, FLEET_SCHEMA,
};
pub use socket::{ReconnectConfig, ReconnectStats, SocketShard};

use std::fmt;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream, ToSocketAddrs};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::time::Duration;

use anyhow::{Context, Result};

/// A shard endpoint: a TCP `host:port` or a Unix-domain socket path.
///
/// Parsed from `tcp://host:port`, `unix:///path/to.sock`, or a bare
/// `host:port` (TCP).  `Display` renders the canonical prefixed form,
/// which `parse` accepts back — addresses survive a trip through the
/// fleet wire protocol or a CLI flag unchanged.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum NetAddr {
    /// TCP endpoint as `host:port` (resolved at connect time).
    Tcp(String),
    /// Unix-domain stream socket path.
    Uds(PathBuf),
}

impl NetAddr {
    /// Parse an endpoint spec (see the type docs for accepted forms).
    pub fn parse(spec: &str) -> Result<Self> {
        if let Some(path) = spec.strip_prefix("unix://") {
            anyhow::ensure!(!path.is_empty(), "empty unix socket path in {spec:?}");
            return Ok(Self::Uds(PathBuf::from(path)));
        }
        let hostport = spec.strip_prefix("tcp://").unwrap_or(spec);
        anyhow::ensure!(
            hostport.contains(':'),
            "TCP address {spec:?} must be host:port (or use unix:///path for a socket file)"
        );
        Ok(Self::Tcp(hostport.to_string()))
    }

    /// Dial this endpoint (TCP connects under `timeout`; a UDS connect
    /// is local and immediate).
    pub fn connect(&self, timeout: Duration) -> Result<NetStream> {
        match self {
            Self::Tcp(hostport) => {
                let addr = hostport
                    .to_socket_addrs()
                    .with_context(|| format!("resolving {hostport:?}"))?
                    .next()
                    .with_context(|| format!("{hostport:?} resolves to no address"))?;
                let stream = TcpStream::connect_timeout(&addr, timeout)
                    .with_context(|| format!("connecting to tcp://{hostport}"))?;
                // the protocol is strictly request/response-framed and
                // every frame is flushed — Nagle only adds latency
                stream.set_nodelay(true).context("setting TCP_NODELAY")?;
                Ok(NetStream::Tcp(stream))
            }
            Self::Uds(path) => {
                let stream = UnixStream::connect(path)
                    .with_context(|| format!("connecting to unix://{}", path.display()))?;
                Ok(NetStream::Unix(stream))
            }
        }
    }
}

impl fmt::Display for NetAddr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Tcp(hostport) => write!(f, "tcp://{hostport}"),
            Self::Uds(path) => write!(f, "unix://{}", path.display()),
        }
    }
}

/// One connected stream socket, TCP or Unix-domain, behind a single
/// `Read`/`Write` type so the wire codec and `worker_serve` loop are
/// family-blind.
#[derive(Debug)]
pub enum NetStream {
    Tcp(TcpStream),
    Unix(UnixStream),
}

impl NetStream {
    /// A second handle on the same socket (reader/writer split — both
    /// halves share the underlying descriptor, so a shutdown through
    /// either unblocks the other).
    pub fn try_clone(&self) -> Result<Self> {
        Ok(match self {
            Self::Tcp(s) => Self::Tcp(s.try_clone().context("cloning a TCP stream")?),
            Self::Unix(s) => Self::Unix(s.try_clone().context("cloning a UDS stream")?),
        })
    }

    /// Shut down both directions; blocked reads on any clone return.
    /// Best-effort — an already-closed socket is fine.
    pub fn shutdown_both(&self) {
        match self {
            Self::Tcp(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
            Self::Unix(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }

    /// Arm (or disarm, with `None`) a read timeout on the socket.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        match self {
            Self::Tcp(s) => s.set_read_timeout(timeout).context("setting a TCP read timeout"),
            Self::Unix(s) => s.set_read_timeout(timeout).context("setting a UDS read timeout"),
        }
    }
}

impl Read for NetStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Self::Tcp(s) => s.read(buf),
            Self::Unix(s) => s.read(buf),
        }
    }
}

impl Write for NetStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Self::Tcp(s) => s.write(buf),
            Self::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Self::Tcp(s) => s.flush(),
            Self::Unix(s) => s.flush(),
        }
    }
}

/// A bound listening socket, TCP or Unix-domain — shared by the shard
/// listener and the registry server.  Dropping a UDS listener removes
/// its socket file.
pub(crate) enum NetListener {
    Tcp(TcpListener),
    Uds { listener: UnixListener, path: PathBuf },
}

impl NetListener {
    /// Bind `addr`.  TCP port 0 binds an ephemeral port; the returned
    /// address is the concrete one peers can dial.  A stale UDS socket
    /// file (from a killed predecessor) is removed first.
    pub(crate) fn bind(addr: &NetAddr) -> Result<(Self, NetAddr)> {
        match addr {
            NetAddr::Tcp(hostport) => {
                let listener = TcpListener::bind(hostport.as_str())
                    .with_context(|| format!("binding tcp://{hostport}"))?;
                let local = listener.local_addr().context("reading the bound TCP address")?;
                Ok((Self::Tcp(listener), NetAddr::Tcp(local.to_string())))
            }
            NetAddr::Uds(path) => {
                if path.exists() {
                    std::fs::remove_file(path).with_context(|| {
                        format!("removing the stale socket file {}", path.display())
                    })?;
                }
                let listener = UnixListener::bind(path)
                    .with_context(|| format!("binding unix://{}", path.display()))?;
                Ok((Self::Uds { listener, path: path.clone() }, addr.clone()))
            }
        }
    }

    /// Accept one connection (blocking).
    pub(crate) fn accept(&self) -> Result<NetStream> {
        match self {
            Self::Tcp(listener) => {
                let (stream, peer) = listener.accept().context("accepting a TCP connection")?;
                stream.set_nodelay(true).context("setting TCP_NODELAY")?;
                crate::log_debug!("accepted connection from {peer}");
                Ok(NetStream::Tcp(stream))
            }
            Self::Uds { listener, .. } => {
                let (stream, _) = listener.accept().context("accepting a UDS connection")?;
                Ok(NetStream::Unix(stream))
            }
        }
    }

    /// Switch the accept loop between blocking and polling mode.
    pub(crate) fn set_nonblocking(&self, nonblocking: bool) -> Result<()> {
        match self {
            Self::Tcp(listener) => listener
                .set_nonblocking(nonblocking)
                .context("toggling nonblocking accept on a TCP listener"),
            Self::Uds { listener, .. } => listener
                .set_nonblocking(nonblocking)
                .context("toggling nonblocking accept on a UDS listener"),
        }
    }
}

impl Drop for NetListener {
    fn drop(&mut self) {
        if let Self::Uds { path, .. } = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_specs_parse_and_render_canonically() {
        let tcp = NetAddr::parse("127.0.0.1:7070").unwrap();
        assert_eq!(tcp, NetAddr::Tcp("127.0.0.1:7070".into()));
        assert_eq!(tcp.to_string(), "tcp://127.0.0.1:7070");
        assert_eq!(NetAddr::parse(&tcp.to_string()).unwrap(), tcp);

        let uds = NetAddr::parse("unix:///tmp/immsched.sock").unwrap();
        assert_eq!(uds, NetAddr::Uds(PathBuf::from("/tmp/immsched.sock")));
        assert_eq!(uds.to_string(), "unix:///tmp/immsched.sock");
        assert_eq!(NetAddr::parse(&uds.to_string()).unwrap(), uds);

        assert!(NetAddr::parse("no-port-here").is_err());
        assert!(NetAddr::parse("unix://").is_err());
    }
}
