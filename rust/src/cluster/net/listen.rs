//! [`ShardListener`]: the serving side of the socket transport.
//!
//! An accept loop over a [`NetListener`] (TCP or Unix-domain) that runs
//! one [`worker_serve`] session — one `MatchService`, the exact loop a
//! `shard-worker` child runs over stdio — per accepted connection, on
//! its own thread.  Drain-on-disconnect comes for free: `worker_serve`
//! treats EOF as a drain request, so a router that vanishes never
//! strands episodes half-reported.
//!
//! [`spawn_shard_listener`] is the out-of-process form: it spawns
//! `immsched shard-listen` as a child, parses the announce line for the
//! bound address (letting tests bind port 0), and kills the child on
//! drop — the "machine" the multi-host tests power off.
//!
//! [`worker_serve`]: super::super::transport::worker_serve

use std::io::{BufRead, BufReader};
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::super::transport::{worker_serve_with, TransportConfig};
use super::{NetAddr, NetListener, NetStream};

/// Accept-loop knobs.
#[derive(Clone, Copy, Debug)]
pub struct ListenConfig {
    /// Connections served before [`ShardListener::serve`] returns —
    /// the accept loop's bound.  The default is effectively "serve
    /// forever"; tests set the exact number of sessions they dial.
    pub max_conns: u64,
}

impl Default for ListenConfig {
    fn default() -> Self {
        Self { max_conns: u64::MAX }
    }
}

/// A bound shard endpoint — see the module docs.
pub struct ShardListener {
    socket: NetListener,
    addr: NetAddr,
}

impl ShardListener {
    /// Bind `addr` (TCP port 0 picks an ephemeral port; a stale UDS
    /// socket file is replaced).
    pub fn bind(addr: &NetAddr) -> Result<Self> {
        let (socket, addr) = NetListener::bind(addr)?;
        Ok(Self { socket, addr })
    }

    /// The concrete bound address peers can dial.
    pub fn local_addr(&self) -> &NetAddr {
        &self.addr
    }

    /// Accept and serve connections, one `MatchService` per connection,
    /// until `lcfg.max_conns` have been accepted; then join every
    /// session and return.
    pub fn serve(&self, tcfg: TransportConfig, lcfg: ListenConfig) -> Result<()> {
        let mut sessions = Vec::new();
        let mut accepted: u64 = 0;
        while accepted < lcfg.max_conns {
            accepted += 1;
            let stream = self.socket.accept()?;
            let session = std::thread::Builder::new()
                .name("immsched-shard-conn".into())
                .spawn(move || serve_conn(stream, tcfg))?;
            sessions.push(session);
        }
        for session in sessions {
            let _ = session.join();
        }
        Ok(())
    }
}

/// One connection's lifetime: split the stream and run the worker loop.
fn serve_conn(stream: NetStream, tcfg: TransportConfig) {
    let read_half = match stream.try_clone() {
        Ok(half) => half,
        Err(e) => {
            crate::log_warn!("cannot split an accepted connection: {e:#}");
            return;
        }
    };
    if let Err(e) = worker_serve_with(read_half, stream, tcfg) {
        crate::log_warn!("shard connection ended with an error: {e:#}");
    }
}

/// An `immsched shard-listen` child process (the out-of-process worker
/// "machine").  Killed and reaped on drop.
pub struct ListenerChild {
    child: Child,
    addr: NetAddr,
}

impl ListenerChild {
    /// The address the child announced (concrete even when spawned on
    /// port 0).
    pub fn addr(&self) -> &NetAddr {
        &self.addr
    }

    /// Kill the listener process — the machine-failure fault the
    /// multi-host failover tests inject.
    pub fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for ListenerChild {
    fn drop(&mut self) {
        self.kill();
    }
}

/// Spawn `immsched shard-listen --addr <spec> [extra…]` and wait (up to
/// `announce_timeout`) for its `shard-listen: listening on <addr>`
/// announce line.
pub fn spawn_shard_listener(
    bin: &Path,
    spec: &str,
    extra: &[&str],
    announce_timeout: Duration,
) -> Result<ListenerChild> {
    let mut child = Command::new(bin)
        .arg("shard-listen")
        .arg("--addr")
        .arg(spec)
        .args(extra)
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .with_context(|| format!("spawning shard listener {}", bin.display()))?;
    let reap = |mut child: Child, e: anyhow::Error| -> anyhow::Error {
        let _ = child.kill();
        let _ = child.wait();
        e
    };
    let Some(stdout) = child.stdout.take() else {
        return Err(reap(child, anyhow::anyhow!("shard listener spawned without piped stdout")));
    };
    // read the announce line on a helper thread so a child that dies
    // before binding fails the spawn after a timeout instead of
    // hanging it; afterwards the thread keeps the pipe drained so the
    // child can never block on a full stdout buffer
    let (announce_tx, announce_rx) = mpsc::channel();
    std::thread::spawn(move || {
        let mut reader = BufReader::new(stdout);
        let mut line = String::new();
        let _ = reader.read_line(&mut line);
        let _ = announce_tx.send(line);
        let _ = std::io::copy(&mut reader, &mut std::io::sink());
    });
    let line = match announce_rx.recv_timeout(announce_timeout) {
        Ok(line) => line,
        Err(_) => {
            let e = anyhow::anyhow!("shard listener did not announce within {announce_timeout:?}");
            return Err(reap(child, e));
        }
    };
    let Some(spec) = line.trim().strip_prefix("shard-listen: listening on ") else {
        return Err(reap(child, anyhow::anyhow!("unexpected announce line {line:?}")));
    };
    let addr = match NetAddr::parse(spec) {
        Ok(addr) => addr,
        Err(e) => return Err(reap(child, e)),
    };
    Ok(ListenerChild { child, addr })
}
