//! Wall-clock validation of a grid cell against the *real* serving
//! stack.
//!
//! The canonical campaign numbers come from the modeled evaluator
//! (`model`), which is bit-deterministic.  This module cross-checks a
//! cell on the live path — real `MatchCluster` shards behind a
//! `SupervisedFleet`, driven by `cluster::driver::run_open_loop` — so
//! the harness's claims stay anchored to the system it models.  Wall
//! results are machine-dependent by nature and are therefore reported
//! *outside* the deterministic summary document (a separate `live`
//! field on the bench entry), never merged into it.

use std::sync::Arc;

use crate::cluster::driver::{run_open_loop, schedule_from_trace, DriverConfig};
use crate::cluster::policy::policy_by_name;
use crate::cluster::{ClusterConfig, MatchCluster, SupervisedFleet, SupervisorConfig};
use crate::coordinator::ServiceConfig;
use crate::matcher::PsoConfig;
use crate::util::json::Json;
use crate::workload::TilingConfig;
use crate::Result;

use super::grid::CellConfig;

/// Run one live replication of `cell` and report its wall-clock
/// outcomes as a JSON fragment.
pub fn run_live_cell(cell: &CellConfig, seed: u64) -> Result<Json> {
    let driver_cfg = DriverConfig {
        class: cell.class,
        platform: cell.platform,
        process: cell.process,
        arrival_rate: cell.rate,
        horizon: cell.horizon,
        background_tasks: cell.background_tasks,
        deadline_factor: cell.deadline_factor,
        tiling: TilingConfig::default(),
        seed,
        time_scale: 0.0,
        resubmit_cancelled: true,
    };
    let schedule = schedule_from_trace(&driver_cfg);

    let pso = PsoConfig { seed, ..PsoConfig::default() };
    // the quota seam in action on the live stack: size the service's
    // epoch quota from the cell's offered rate
    let epoch_quota = cell.quota.service_quota(cell.rate, pso.epochs);
    let policy = policy_by_name(&cell.policy)
        .ok_or_else(|| anyhow::anyhow!("unknown route policy {:?}", cell.policy))?;
    let cluster = MatchCluster::spawn(
        ClusterConfig {
            shards: cell.shards,
            service: ServiceConfig { epoch_quota, ..ServiceConfig::default() },
            pso,
            resume_capacity: 1024,
        },
        policy,
    )?;
    let fleet = SupervisedFleet::new(Arc::new(cluster), SupervisorConfig::default());
    let report = run_open_loop(&fleet, &schedule, &driver_cfg)?;
    fleet.drain()?;

    Ok(Json::obj(vec![
        ("cell", Json::from(cell.id().as_str())),
        ("epoch_quota", epoch_quota.map_or(Json::Null, Json::from)),
        ("submitted", Json::from(report.submitted())),
        ("served", Json::from(report.served())),
        ("resumed", Json::from(report.resumed())),
        ("slo_misses", Json::from(report.slo_misses())),
        ("mean_latency_s", Json::from(report.mean_latency())),
        ("p95_latency_s", Json::from(report.latency_percentile(95.0))),
        ("preemptions", Json::from(report.cluster.preemptions())),
        ("wall_seconds", Json::from(report.wall_seconds)),
    ]))
}
