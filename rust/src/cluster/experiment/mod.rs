//! `cluster::experiment` — replicated sweep campaigns, policy
//! tournaments, and LBT search over the serving stack.
//!
//! The serving cluster can run one trace at one arrival rate; every
//! production question is comparative ("which route policy sustains the
//! highest load at <1% SLO miss?", "which epoch quota minimizes
//! preemption waste?").  This subsystem answers them reproducibly:
//!
//! * [`ExperimentGrid`] declares a campaign as the cartesian product
//!   λ × arrival shape × route policy × shard count × epoch quota, with
//!   N seeded replications per cell derived deterministically from one
//!   campaign seed;
//! * [`run_campaign`] executes every (cell × replication) on a bounded
//!   worker pool and merges results in deterministic cell order, so the
//!   campaign is a pure function of the grid — bit-identical across
//!   runs, machines, and pool widths;
//! * [`lbt::bisect_max_rate`] finds each policy's maximum sustainable
//!   load at a configurable SLO-miss threshold within an explicit probe
//!   budget (the paper's Fig. 7 LBT curve);
//! * [`QuotaSpec`] is the epoch-quota seam: static quotas plus the
//!   rate-adaptive policy that the tournament demonstrates dominates
//!   every static choice;
//! * [`summary_json`] renders the whole campaign into one canonical
//!   document consumed by `report::figures` and the tracked
//!   `BENCH_experiment.json` trajectory.
//!
//! Evaluation runs in *modeled* time (see [`model`]) so wall-clock
//! never contaminates campaign numbers; [`live::run_live_cell`] keeps a
//! wall-clock cross-check against the real stack available for
//! validation.

pub mod grid;
pub mod lbt;
pub mod live;
pub mod model;
pub mod quota;
pub mod replicate;
pub mod summary;

pub use grid::{rate_for_load, replication_seed, CellConfig, ExperimentGrid, ALL_POLICIES};
pub use lbt::{bisect_max_rate, LbtConfig, LbtOutcome, LbtPoint};
pub use model::{evaluate_cell, CellRun};
pub use quota::{QuotaPolicy, QuotaSpec, RateWindow, EPISODE_EPOCHS};
pub use replicate::{agg, run_campaign, tournament, AggStat, CampaignResult, CellSummary};
pub use summary::summary_json;
