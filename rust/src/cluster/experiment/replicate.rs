//! Campaign execution: run every (cell × replication) job of a grid on
//! a bounded worker pool, then merge and aggregate in deterministic
//! cell order.
//!
//! Workers claim jobs from an atomic counter and write each result into
//! its pre-assigned slot, so thread interleaving affects only *when* a
//! result lands, never *where* — the merged campaign is a pure function
//! of the grid.  `tests/experiment.rs` proves it by running the same
//! grid with different pool widths and asserting byte-identical
//! summaries.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::util::stats::{ci95, mean, stddev};
use crate::Result;

use super::grid::{replication_seed, CellConfig, ExperimentGrid};
use super::lbt::{lbt_curve, LbtPoint};
use super::model::{evaluate_cell, CellRun};

/// Mean ± spread of one metric across a cell's replications.
#[derive(Clone, Copy, Debug)]
pub struct AggStat {
    pub mean: f64,
    pub stddev: f64,
    pub ci95: f64,
}

/// NaN-safe aggregation of replication samples.
pub fn agg(samples: &[f64]) -> AggStat {
    AggStat { mean: mean(samples), stddev: stddev(samples), ci95: ci95(samples) }
}

/// One cell's replication-aggregated results.
#[derive(Clone, Debug)]
pub struct CellSummary {
    pub cell: CellConfig,
    pub reps: usize,
    pub slo_miss_rate: AggStat,
    /// Mean per-replication latency percentiles (s); NaN when no
    /// replication completed anything.
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    /// Mean fraction of retired epochs burned on resume overhead.
    pub preempt_waste: AggStat,
    pub submitted_mean: f64,
    pub served_mean: f64,
    pub shed_mean: f64,
    pub preemptions_mean: f64,
    pub resumes_mean: f64,
}

/// A fully executed campaign.
#[derive(Clone, Debug)]
pub struct CampaignResult {
    pub cells: Vec<CellSummary>,
    pub lbt: Vec<LbtPoint>,
}

/// Execute the full campaign: every grid cell × replication on a pool
/// of `workers` threads, then the per-policy LBT search.
pub fn run_campaign(grid: &ExperimentGrid, workers: usize) -> Result<CampaignResult> {
    let cells = grid.cells();
    let reps = grid.replications.max(1);
    let job_cap = cells.len() * reps;
    let runs: Mutex<Vec<Option<CellRun>>> = Mutex::new((0..job_cap).map(|_| None).collect());
    let first_error: Mutex<Option<String>> = Mutex::new(None);
    let next = AtomicUsize::new(0);
    let pool = workers.clamp(1, job_cap.max(1));

    std::thread::scope(|scope| {
        for _ in 0..pool {
            scope.spawn(|| {
                // claim-loop: bounded by job_cap, one claim per pass
                loop {
                    let slot = next.fetch_add(1, Ordering::Relaxed);
                    if slot >= job_cap {
                        break;
                    }
                    let cell = &cells[slot / reps];
                    let rep = slot % reps;
                    let seed = replication_seed(grid.campaign_seed, cell.index, rep);
                    match evaluate_cell(cell, seed) {
                        Ok(run) => {
                            let mut guard =
                                runs.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                            guard[slot] = Some(run);
                        }
                        Err(e) => {
                            let mut guard = first_error
                                .lock()
                                .unwrap_or_else(std::sync::PoisonError::into_inner);
                            guard.get_or_insert_with(|| format!("cell {}: {e}", cell.id()));
                        }
                    }
                }
            });
        }
    });

    let error = first_error.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
    if let Some(e) = error {
        anyhow::bail!("campaign replication failed: {e}");
    }
    let runs = runs.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);

    // merge in deterministic cell order: slot layout is cell-major
    let mut summaries = Vec::with_capacity(cells.len());
    for (ci, cell) in cells.iter().enumerate() {
        let cell_runs: Vec<&CellRun> = runs[ci * reps..(ci + 1) * reps]
            .iter()
            .map(|r| r.as_ref().expect("all replications completed or we bailed"))
            .collect();
        summaries.push(summarize_cell(cell.clone(), &cell_runs));
    }

    let lbt = lbt_curve(grid)?;
    Ok(CampaignResult { cells: summaries, lbt })
}

fn summarize_cell(cell: CellConfig, runs: &[&CellRun]) -> CellSummary {
    let metric = |f: &dyn Fn(&CellRun) -> f64| -> Vec<f64> {
        runs.iter().map(|&r| f(r)).collect()
    };
    let pct = |q: f64| -> f64 {
        let per_rep: Vec<f64> = runs
            .iter()
            .map(|r| {
                let mut s = r.latencies.clone();
                s.percentile(q)
            })
            .collect();
        mean(&per_rep)
    };
    CellSummary {
        reps: runs.len(),
        slo_miss_rate: agg(&metric(&|r| r.slo_miss_rate())),
        p50_s: pct(50.0),
        p95_s: pct(95.0),
        p99_s: pct(99.0),
        preempt_waste: agg(&metric(&|r| r.preempt_waste())),
        submitted_mean: mean(&metric(&|r| r.submitted as f64)),
        served_mean: mean(&metric(&|r| r.served as f64)),
        shed_mean: mean(&metric(&|r| r.shed as f64)),
        preemptions_mean: mean(&metric(&|r| r.preemptions as f64)),
        resumes_mean: mean(&metric(&|r| r.resumes as f64)),
        cell,
    }
}

/// The quota tournament: mean SLO-miss rate per quota spec across every
/// cell that used it, in grid quota order.  Returns
/// `(quota name, mean miss rate, cells)` rows.
pub fn tournament(grid: &ExperimentGrid, result: &CampaignResult) -> Vec<(String, f64, usize)> {
    grid.quotas
        .iter()
        .map(|q| {
            let name = q.name();
            let misses: Vec<f64> = result
                .cells
                .iter()
                .filter(|c| c.cell.quota.name() == name)
                .map(|c| c.slo_miss_rate.mean)
                .collect();
            (name, mean(&misses), misses.len())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn agg_handles_degenerate_inputs() {
        let a = agg(&[]);
        assert!(a.mean.is_nan());
        assert_eq!(a.stddev, 0.0);
        let b = agg(&[0.25, 0.35]);
        assert!((b.mean - 0.3).abs() < 1e-12);
        assert!(b.ci95 > 0.0);
    }
}
