//! Canonical campaign summary document.
//!
//! One deterministic [`Json`] object per campaign: grid description,
//! per-cell aggregates in grid order, the per-policy LBT curve, and the
//! quota tournament.  Field order is fixed and every number is a pure
//! function of (grid, campaign seed), so two runs of the same campaign
//! render byte-identical text — the property `tests/experiment.rs`
//! asserts and CI's smoke step re-proves on every push.

use crate::util::json::{hex_u64, Json};

use super::grid::ExperimentGrid;
use super::replicate::{tournament, AggStat, CampaignResult};

/// Non-finite metrics (empty-cell percentiles, 0/0 rates) become JSON
/// `null` explicitly rather than relying on the renderer's last-resort
/// degradation.
fn num(x: f64) -> Json {
    if x.is_finite() {
        Json::from(x)
    } else {
        Json::Null
    }
}

fn agg_json(a: &AggStat) -> Json {
    Json::obj(vec![("mean", num(a.mean)), ("stddev", num(a.stddev)), ("ci95", num(a.ci95))])
}

/// Render the full campaign into its canonical summary document.
pub fn summary_json(grid: &ExperimentGrid, result: &CampaignResult) -> Json {
    let grid_json = Json::obj(vec![
        ("class", Json::from(grid.class.name())),
        ("platform", Json::from(grid.platform.name())),
        ("horizon_s", num(grid.horizon)),
        ("deadline_factor", num(grid.deadline_factor)),
        ("background_tasks", Json::from(grid.background_tasks)),
        ("rates", Json::Arr(grid.rates.iter().map(|&r| num(r)).collect())),
        ("shapes", Json::Arr(grid.shapes.iter().map(|s| Json::from(s.name())).collect())),
        ("policies", Json::Arr(grid.policies.iter().map(|p| Json::from(p.as_str())).collect())),
        ("shard_counts", Json::Arr(grid.shard_counts.iter().map(|&s| Json::from(s)).collect())),
        ("quotas", Json::Arr(grid.quotas.iter().map(|q| Json::from(q.name().as_str())).collect())),
    ]);

    let cells: Vec<Json> = result
        .cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("id", Json::from(c.cell.id().as_str())),
                ("rate", num(c.cell.rate)),
                ("shape", Json::from(c.cell.process.name())),
                ("policy", Json::from(c.cell.policy.as_str())),
                ("shards", Json::from(c.cell.shards)),
                ("quota", Json::from(c.cell.quota.name().as_str())),
                ("reps", Json::from(c.reps)),
                ("submitted_mean", num(c.submitted_mean)),
                ("served_mean", num(c.served_mean)),
                ("shed_mean", num(c.shed_mean)),
                ("slo_miss_rate", agg_json(&c.slo_miss_rate)),
                ("p50_s", num(c.p50_s)),
                ("p95_s", num(c.p95_s)),
                ("p99_s", num(c.p99_s)),
                ("preempt_waste", agg_json(&c.preempt_waste)),
                ("preemptions_mean", num(c.preemptions_mean)),
                ("resumes_mean", num(c.resumes_mean)),
            ])
        })
        .collect();

    let lbt: Vec<Json> = result
        .lbt
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("policy", Json::from(p.policy.as_str())),
                ("lbt_rate", num(p.outcome.rate)),
                ("target_miss", num(p.target_miss)),
                ("probes", Json::from(p.outcome.probes)),
                ("saturated_budget", Json::from(p.outcome.saturated_budget)),
            ])
        })
        .collect();

    let rows = tournament(grid, result);
    let best = rows
        .iter()
        .map(|(_, miss, _)| *miss)
        .fold(f64::INFINITY, |a, b| if b.is_nan() { a } else { a.min(b) });
    let tournament_json: Vec<Json> = rows
        .iter()
        .map(|(name, miss, cells)| {
            Json::obj(vec![
                ("quota", Json::from(name.as_str())),
                ("slo_miss_rate", num(*miss)),
                ("cells", Json::from(*cells)),
                ("best", Json::from(!miss.is_nan() && *miss <= best + 1e-12)),
            ])
        })
        .collect();

    Json::obj(vec![
        ("campaign_seed", hex_u64(grid.campaign_seed)),
        ("replications", Json::from(grid.replications)),
        ("grid", grid_json),
        ("cells", Json::Arr(cells)),
        ("lbt", Json::Arr(lbt)),
        ("tournament", Json::Arr(tournament_json)),
    ])
}
