//! Deterministic modeled-cluster evaluation of one grid cell.
//!
//! The live open-loop driver (`cluster::driver`) measures wall-clock
//! latencies, which makes every run machine- and load-dependent — fine
//! for validation, useless for a campaign that must be bit-identical
//! across runs and machines.  This evaluator instead advances *modeled*
//! time through a small discrete-event simulation of the sharded
//! cluster while reusing the real building blocks everywhere behavior
//! matters:
//!
//! * arrivals come from the real trace generator ([`build_trace`]);
//! * per-task service demand comes from the real execution model
//!   ([`ExecModel::tss`]) on the engine share the matcher would claim;
//! * routing decisions are made by the *real* [`RoutePolicy`]
//!   implementations over synthesized [`ShardView`]s;
//! * slicing follows the epoch-quota semantics of the live service:
//!   episodes run in epoch-quantized slices, preemption lands on epoch
//!   barriers, and every warm-start resume pays a fixed epoch overhead
//!   (mirroring snapshot restore).
//!
//! Every quantity is a pure function of the cell config and seed, so a
//! campaign is replayable bit-for-bit.

use crate::accel::Platform;
use crate::cluster::policy::{policy_by_name, ShardView};
use crate::coordinator::ServiceStats;
use crate::scheduler::exec_model::ExecModel;
use crate::scheduler::{build_trace, Priority, TraceConfig};
use crate::util::Summary;
use crate::Result;

use super::grid::CellConfig;
use super::quota::{QuotaPolicy, RateWindow, EPISODE_EPOCHS};

/// Epochs charged to every warm-start resume (snapshot restore +
/// re-freeze), mirroring the live service's resume tax.
const RESUME_OVERHEAD_EPOCHS: u32 = 2;

/// Per-shard admission queue capacity; arrivals routed to a full shard
/// are shed.  Mirrors `ServiceConfig::queue_depth`'s default.
const QUEUE_CAP: usize = 64;

/// Aggregate counters from one replication of one cell.
#[derive(Clone, Debug, Default)]
pub struct CellRun {
    pub submitted: usize,
    pub served: usize,
    pub shed: usize,
    /// Shed requests plus completions past their deadline.
    pub slo_misses: usize,
    pub preemptions: u64,
    pub resumes: u64,
    /// Epochs burned on warm-start restores (work that served no
    /// request).
    pub waste_epochs: u64,
    /// Productive epochs retired.
    pub work_epochs: u64,
    /// Modeled sojourn times of completed requests (s).
    pub latencies: Summary,
}

impl CellRun {
    /// Fraction of submitted requests that missed their SLO (shed or
    /// late).  NaN when nothing was submitted.
    pub fn slo_miss_rate(&self) -> f64 {
        self.slo_misses as f64 / self.submitted as f64
    }

    /// Fraction of all retired epochs that were resume overhead.
    pub fn preempt_waste(&self) -> f64 {
        let total = self.work_epochs + self.waste_epochs;
        if total == 0 {
            return 0.0;
        }
        self.waste_epochs as f64 / total as f64
    }
}

/// One admitted request's modeled state.
struct Job {
    arrival: f64,
    deadline: Option<f64>,
    priority: Priority,
    /// Modeled seconds per epoch for this task (isolated service time
    /// spread over [`EPISODE_EPOCHS`]).
    epoch_secs: f64,
    /// Epochs still to retire.
    remaining: u32,
    /// Warm-start resumes so far (drives the per-slice overhead).
    resumes: u32,
}

/// A slice in flight on one shard.
#[derive(Clone, Copy)]
struct Running {
    job: usize,
    /// Epochs of resume overhead charged to this slice.
    overhead: u32,
    /// Productive epochs this slice will retire (unless truncated).
    epochs: u32,
    started: f64,
    done_at: f64,
    /// Whether a preemption shortened the slice below its plan.
    truncated: bool,
}

#[derive(Default)]
struct Shard {
    queue: Vec<usize>,
    running: Option<Running>,
}

struct Sim {
    jobs: Vec<Job>,
    shards: Vec<Shard>,
    policy: Box<dyn crate::cluster::RoutePolicy>,
    quota: Box<dyn QuotaPolicy>,
    window: RateWindow,
    out: CellRun,
}

/// Run one seeded replication of `cell` to completion in modeled time.
pub fn evaluate_cell(cell: &CellConfig, seed: u64) -> Result<CellRun> {
    let platform = Platform::get(cell.platform);
    let trace_cfg = TraceConfig {
        class: cell.class,
        background_tasks: cell.background_tasks,
        arrival_rate: cell.rate,
        process: cell.process,
        horizon: cell.horizon,
        deadline_factor: cell.deadline_factor,
        seed,
        ..TraceConfig::default()
    };
    let tasks = build_trace(&trace_cfg, &platform);
    let exec = ExecModel::new(platform);

    let jobs: Vec<Job> = tasks
        .iter()
        .map(|t| {
            let claim = t.tiles.len().clamp(1, platform.engines);
            let service = exec.tss(t, claim).seconds.max(1e-9);
            Job {
                arrival: t.arrival,
                deadline: t.deadline,
                priority: t.priority,
                epoch_secs: service / EPISODE_EPOCHS as f64,
                remaining: EPISODE_EPOCHS,
                resumes: 0,
            }
        })
        .collect();

    let policy = policy_by_name(&cell.policy)
        .ok_or_else(|| anyhow::anyhow!("unknown route policy {:?}", cell.policy))?;

    let mut sim = Sim {
        shards: (0..cell.shards.max(1)).map(|_| Shard::default()).collect(),
        policy,
        quota: cell.quota.policy(),
        // the offered base rate is the prior until enough urgent
        // arrivals have been observed
        window: RateWindow::new(cell.rate),
        out: CellRun { submitted: jobs.len(), ..CellRun::default() },
        jobs,
    };
    sim.run()?;
    Ok(sim.out)
}

impl Sim {
    fn run(&mut self) -> Result<()> {
        let total_epochs: u64 = self.jobs.iter().map(|j| u64::from(j.remaining)).sum();
        // Every iteration either admits one arrival or retires ≥1 epoch
        // of a running slice, so this budget is a generous upper bound;
        // exceeding it means the event loop stopped making progress.
        let overhead = u64::from(RESUME_OVERHEAD_EPOCHS);
        let mut step_budget = self.jobs.len() as u64 * 4 + total_epochs * (2 + overhead) + 64;
        let mut next_arrival = 0usize;
        loop {
            step_budget = step_budget.saturating_sub(1);
            if step_budget == 0 {
                anyhow::bail!("modeled cell evaluation exceeded its step budget");
            }
            let arrival = if next_arrival < self.jobs.len() {
                Some(self.jobs[next_arrival].arrival)
            } else {
                None
            };
            let completion = self
                .shards
                .iter()
                .enumerate()
                .filter_map(|(s, sh)| sh.running.map(|r| (r.done_at, s)))
                .min_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            match (arrival, completion) {
                (None, None) => break,
                (Some(at), Some((ct, shard))) => {
                    // completions at the same instant run first so the
                    // freed shard is visible to the arrival's router
                    if ct <= at {
                        self.complete(shard);
                    } else {
                        self.admit(next_arrival, at);
                        next_arrival += 1;
                    }
                }
                (Some(at), None) => {
                    self.admit(next_arrival, at);
                    next_arrival += 1;
                }
                (None, Some((_, shard))) => self.complete(shard),
            }
        }
        Ok(())
    }

    /// Route one fresh arrival at modeled time `t`.
    fn admit(&mut self, job: usize, t: f64) {
        if self.jobs[job].priority == Priority::Urgent {
            self.window.observe(t);
        }
        self.route(job, t);
    }

    /// Route `job` (fresh or resumed) through the real policy.
    fn route(&mut self, job: usize, t: f64) {
        let views: Vec<ShardView> = self
            .shards
            .iter()
            .enumerate()
            .map(|(s, sh)| ShardView {
                shard: s,
                queue_depth: sh.queue.len(),
                in_flight: sh.running.map(|r| self.jobs[r.job].priority),
                stats: ServiceStats::default(),
            })
            .collect();
        let target = self
            .policy
            .route(self.jobs[job].priority, self.jobs[job].deadline, &views)
            .min(self.shards.len() - 1);

        if self.shards[target].queue.len() >= QUEUE_CAP {
            self.out.shed += 1;
            self.out.slo_misses += 1;
            return;
        }
        self.shards[target].queue.push(job);

        // epoch-barrier preemption: a strictly lower-priority slice in
        // flight on the chosen shard is truncated to its next barrier
        if let Some(r) = self.shards[target].running {
            if self.jobs[r.job].priority < self.jobs[job].priority {
                self.truncate(target, t);
            }
        } else {
            self.start_next(target, t);
        }
    }

    /// Shorten the running slice on `shard` to the next epoch barrier
    /// at or after modeled time `t` (at least one epoch always retires,
    /// matching the engine's zero-budget→one-epoch convention).
    fn truncate(&mut self, shard: usize, t: f64) {
        let Some(r) = self.shards[shard].running.as_mut() else { return };
        let epoch = self.jobs[r.job].epoch_secs;
        let overhead_secs = f64::from(r.overhead) * epoch;
        let body_elapsed = (t - r.started - overhead_secs).max(0.0);
        let barrier = (body_elapsed / epoch).ceil() as u32;
        let barrier = barrier.clamp(1, r.epochs);
        if barrier < r.epochs {
            r.epochs = barrier;
            r.done_at = r.started + overhead_secs + f64::from(barrier) * epoch;
            r.truncated = true;
        }
    }

    /// Retire the slice running on `shard`; complete or re-route its
    /// job, then refill the shard.
    fn complete(&mut self, shard: usize) {
        let Some(r) = self.shards[shard].running.take() else { return };
        let t = r.done_at;
        self.out.work_epochs += u64::from(r.epochs);
        self.out.waste_epochs += u64::from(r.overhead);
        if r.truncated {
            self.out.preemptions += 1;
        }
        let job = &mut self.jobs[r.job];
        job.remaining = job.remaining.saturating_sub(r.epochs);
        if job.remaining == 0 {
            self.out.served += 1;
            let latency = t - job.arrival;
            self.out.latencies.add(latency);
            if job.deadline.is_some_and(|d| t > d) {
                self.out.slo_misses += 1;
            }
        } else {
            job.resumes += 1;
            self.out.resumes += 1;
            self.route(r.job, t);
        }
        if self.shards[shard].running.is_none() {
            self.start_next(shard, t);
        }
    }

    /// Pop the best queued request (highest priority, then earliest
    /// arrival, then lowest id) and start its next slice; expired
    /// requests are shed on pop, exactly like live admission.
    fn start_next(&mut self, shard: usize, t: f64) {
        let cap = self.shards[shard].queue.len();
        // each pass either sheds one expired request or starts a slice,
        // so `cap` passes always drain or occupy the shard
        for _ in 0..cap {
            let queue = &self.shards[shard].queue;
            let Some(pick) = queue
                .iter()
                .enumerate()
                .min_by(|(_, &a), (_, &b)| {
                    let ja = &self.jobs[a];
                    let jb = &self.jobs[b];
                    jb.priority
                        .cmp(&ja.priority)
                        .then(ja.arrival.total_cmp(&jb.arrival))
                        .then(a.cmp(&b))
                })
                .map(|(i, _)| i)
            else {
                return;
            };
            let job_idx = self.shards[shard].queue.remove(pick);
            let job = &self.jobs[job_idx];
            if job.deadline.is_some_and(|d| d < t) {
                self.out.shed += 1;
                self.out.slo_misses += 1;
                continue;
            }
            let quota = self.quota.episode_quota(self.window.rate()).map(|q| q.max(1));
            let epochs = quota.map_or(job.remaining, |q| q.min(job.remaining));
            let overhead = if job.resumes > 0 { RESUME_OVERHEAD_EPOCHS } else { 0 };
            let done_at = t + f64::from(epochs + overhead) * job.epoch_secs;
            self.shards[shard].running = Some(Running {
                job: job_idx,
                overhead,
                epochs,
                started: t,
                done_at,
                truncated: false,
            });
            return;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::PlatformKind;
    use crate::cluster::experiment::grid::ExperimentGrid;
    use crate::cluster::experiment::QuotaSpec;
    use crate::scheduler::ArrivalProcess;
    use crate::workload::WorkloadClass;

    fn cell(rate: f64, quota: QuotaSpec) -> CellConfig {
        CellConfig {
            index: 0,
            rate,
            process: ArrivalProcess::Poisson,
            policy: "least-queue".to_string(),
            shards: 2,
            quota,
            class: WorkloadClass::Simple,
            platform: PlatformKind::Edge,
            horizon: 0.2,
            deadline_factor: 3.0,
            background_tasks: 2,
        }
    }

    #[test]
    fn every_submission_terminates_exactly_once() {
        let run = evaluate_cell(&cell(200.0, QuotaSpec::Static(Some(8))), 7).expect("evaluates");
        assert!(run.submitted > 0);
        assert_eq!(run.served + run.shed, run.submitted);
        assert_eq!(run.latencies.count(), run.served);
    }

    #[test]
    fn same_seed_is_bit_identical_and_seeds_differ() {
        let c = cell(300.0, QuotaSpec::Static(Some(8)));
        let a = evaluate_cell(&c, 11).expect("evaluates");
        let b = evaluate_cell(&c, 11).expect("evaluates");
        assert_eq!(a.served, b.served);
        assert_eq!(a.slo_misses, b.slo_misses);
        assert_eq!(a.work_epochs, b.work_epochs);
        assert_eq!(a.latencies.sum().to_bits(), b.latencies.sum().to_bits());
        let c2 = evaluate_cell(&c, 12).expect("evaluates");
        assert!(
            a.latencies.sum().to_bits() != c2.latencies.sum().to_bits()
                || a.submitted != c2.submitted,
            "different seeds should differ somewhere"
        );
    }

    #[test]
    fn overload_drives_misses_up() {
        let grid_rate =
            super::super::grid::rate_for_load(WorkloadClass::Simple, PlatformKind::Edge, 2, 1.0);
        let light = evaluate_cell(&cell(grid_rate * 0.2, QuotaSpec::Static(None)), 5)
            .expect("evaluates");
        let heavy = evaluate_cell(&cell(grid_rate * 3.0, QuotaSpec::Static(None)), 5)
            .expect("evaluates");
        assert!(
            heavy.slo_miss_rate() > light.slo_miss_rate(),
            "3× overload ({}) should miss more than 0.2× load ({})",
            heavy.slo_miss_rate(),
            light.slo_miss_rate()
        );
    }

    #[test]
    fn slicing_pays_resume_overhead() {
        let unsliced =
            evaluate_cell(&cell(250.0, QuotaSpec::Static(None)), 9).expect("evaluates");
        assert_eq!(unsliced.resumes, 0, "no quota, no resumes");
        assert_eq!(unsliced.waste_epochs, 0);
        let sliced =
            evaluate_cell(&cell(250.0, QuotaSpec::Static(Some(4))), 9).expect("evaluates");
        assert!(sliced.resumes > 0, "a 4-epoch quota must slice 64-epoch episodes");
        assert!(sliced.preempt_waste() > 0.0);
    }

    #[test]
    fn smoke_grid_cells_all_evaluate() {
        let grid = ExperimentGrid::smoke(42);
        for c in grid.cells().iter().take(6) {
            let run = evaluate_cell(c, 1).expect("cell evaluates");
            assert!(run.submitted > 0);
        }
    }
}
