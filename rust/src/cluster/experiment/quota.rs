//! The epoch-quota seam: how large a slice each service episode gets
//! before it yields back to the queue.
//!
//! A *small* quota keeps the cluster responsive under burst — an urgent
//! arrival waits at most one short slice behind an in-flight episode of
//! equal priority (cross-priority arrivals preempt at the epoch barrier
//! regardless).  A *large* quota (or none) avoids warm-start resume
//! overhead when the system is idle enough that nothing ever queues.
//! No static choice wins both regimes, which is exactly what the
//! [`QuotaSpec::Adaptive`] policy exploits: it sizes the slice from the
//! observed urgent arrival rate — long slices when idle, short slices
//! under burst — and the tournament in `replicate` demonstrates it
//! dominates every static quota across the grid.

/// Modeled episode length in epochs.  The deterministic evaluator
/// expresses every task's service demand in these units; quotas are
/// slices out of this budget.  Mirrors the default
/// `PsoConfig::epochs`-scale episode the live service runs.
pub const EPISODE_EPOCHS: u32 = 64;

/// Declarative quota axis of an experiment grid cell.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QuotaSpec {
    /// A fixed per-slice epoch quota; `None` disables slicing (episodes
    /// run to completion unless preempted by a higher priority).
    Static(Option<u32>),
    /// Rate-adaptive slicing: no quota at or below `low_rate` arrivals/s,
    /// the shortest slice (`min_quota`) at or above `high_rate`, and a
    /// linear interpolation from `max_quota` down to `min_quota` in
    /// between.
    Adaptive { low_rate: f64, high_rate: f64, min_quota: u32, max_quota: u32 },
}

impl QuotaSpec {
    /// Stable display/grouping name ("static:none", "static:8",
    /// "adaptive").
    pub fn name(&self) -> String {
        match self {
            QuotaSpec::Static(None) => "static:none".to_string(),
            QuotaSpec::Static(Some(q)) => format!("static:{q}"),
            QuotaSpec::Adaptive { .. } => "adaptive".to_string(),
        }
    }

    /// The quota this spec prescribes at an observed arrival rate.
    pub fn quota_at(&self, rate: f64) -> Option<u32> {
        match *self {
            QuotaSpec::Static(q) => q,
            QuotaSpec::Adaptive { low_rate, high_rate, min_quota, max_quota } => {
                if rate <= low_rate {
                    None
                } else if rate >= high_rate {
                    Some(min_quota.max(1))
                } else {
                    let span = (high_rate - low_rate).max(1e-9);
                    let frac = (rate - low_rate) / span;
                    let q = max_quota as f64 - (max_quota - min_quota.min(max_quota)) as f64 * frac;
                    Some((q.round() as u32).max(1))
                }
            }
        }
    }

    /// Instantiate the runtime policy for one replication.
    pub fn policy(&self) -> Box<dyn QuotaPolicy> {
        Box::new(SpecQuota(*self))
    }

    /// Live-cluster seam: map the spec to a `ServiceConfig::epoch_quota`
    /// given the offered rate and the service's real per-episode epoch
    /// count (the modeled evaluator always uses [`EPISODE_EPOCHS`]).
    pub fn service_quota(&self, offered_rate: f64, service_epochs: usize) -> Option<usize> {
        self.quota_at(offered_rate)
            .map(|q| ((q as usize * service_epochs) / EPISODE_EPOCHS as usize).max(1))
    }
}

/// Sizes the epoch slice for the *next* episode from the arrival rate
/// observed so far.  Implementations must be deterministic functions of
/// their inputs — the evaluator replays them bit-identically.
pub trait QuotaPolicy: Send {
    fn episode_quota(&mut self, observed_rate: f64) -> Option<u32>;
}

/// The shipped policy: defers to its [`QuotaSpec`].  Static specs ignore
/// the observed rate entirely.
struct SpecQuota(QuotaSpec);

impl QuotaPolicy for SpecQuota {
    fn episode_quota(&mut self, observed_rate: f64) -> Option<u32> {
        self.0.quota_at(observed_rate)
    }
}

/// Sliding-window estimator of the urgent arrival rate, seeded with the
/// cell's offered base rate as a prior so early episodes are not sized
/// from a handful of samples.
#[derive(Clone, Debug)]
pub struct RateWindow {
    /// Ring buffer of the most recent urgent arrival times.
    times: Vec<f64>,
    head: usize,
    filled: usize,
    prior: f64,
}

/// Window width: enough arrivals to straddle a burst, few enough to
/// react within one.
const RATE_WINDOW: usize = 32;

/// Minimum observations before the empirical estimate displaces the
/// prior.
const RATE_MIN_SAMPLES: usize = 4;

impl RateWindow {
    pub fn new(prior_rate: f64) -> Self {
        Self { times: vec![0.0; RATE_WINDOW], head: 0, filled: 0, prior: prior_rate.max(0.0) }
    }

    /// Record one urgent arrival at absolute time `t` (non-decreasing).
    pub fn observe(&mut self, t: f64) {
        self.times[self.head] = t;
        self.head = (self.head + 1) % RATE_WINDOW;
        self.filled = (self.filled + 1).min(RATE_WINDOW);
    }

    /// Current rate estimate: (n−1) arrivals over the window span, or
    /// the prior while the window is still warming up.
    pub fn rate(&self) -> f64 {
        if self.filled < RATE_MIN_SAMPLES {
            return self.prior;
        }
        let newest = self.times[(self.head + RATE_WINDOW - 1) % RATE_WINDOW];
        let oldest_idx =
            if self.filled < RATE_WINDOW { 0 } else { self.head % RATE_WINDOW };
        let oldest = self.times[oldest_idx];
        let span = newest - oldest;
        if span <= 1e-9 {
            return self.prior;
        }
        (self.filled - 1) as f64 / span
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_spec_ignores_rate() {
        assert_eq!(QuotaSpec::Static(None).quota_at(1e9), None);
        assert_eq!(QuotaSpec::Static(Some(8)).quota_at(0.0), Some(8));
        let mut p = QuotaSpec::Static(Some(8)).policy();
        assert_eq!(p.episode_quota(123.0), Some(8));
    }

    #[test]
    fn adaptive_spec_interpolates_between_regimes() {
        let spec =
            QuotaSpec::Adaptive { low_rate: 100.0, high_rate: 500.0, min_quota: 8, max_quota: 32 };
        assert_eq!(spec.quota_at(50.0), None, "idle: no slicing");
        assert_eq!(spec.quota_at(100.0), None, "at the low threshold: still idle");
        assert_eq!(spec.quota_at(1000.0), Some(8), "saturated: shortest slice");
        let mid = spec.quota_at(300.0).expect("mid-regime slices");
        assert!((8..=32).contains(&mid), "mid quota {mid} outside [8,32]");
        // monotone: more load never lengthens the slice
        let q1 = spec.quota_at(200.0).unwrap_or(u32::MAX);
        let q2 = spec.quota_at(400.0).unwrap_or(u32::MAX);
        assert!(q2 <= q1, "quota must shrink with load: {q1} -> {q2}");
    }

    #[test]
    fn service_quota_scales_to_service_epochs() {
        let spec = QuotaSpec::Static(Some(16));
        // 16/64 of a 128-epoch service episode = 32 epochs
        assert_eq!(spec.service_quota(0.0, 128), Some(32));
        assert_eq!(QuotaSpec::Static(None).service_quota(0.0, 128), None);
        // tiny services still get a ≥1-epoch slice
        assert_eq!(QuotaSpec::Static(Some(1)).service_quota(0.0, 2), Some(1));
    }

    #[test]
    fn rate_window_warms_up_from_prior_then_tracks_observations() {
        let mut w = RateWindow::new(100.0);
        assert_eq!(w.rate(), 100.0, "empty window returns the prior");
        // 200/s steady stream: arrivals every 5 ms
        for i in 0..64 {
            w.observe(i as f64 * 0.005);
        }
        let r = w.rate();
        assert!((r - 200.0).abs() < 20.0, "windowed estimate {r} should be ~200");
    }
}
