//! Declarative experiment grids: the cartesian product of
//! λ × arrival shape × route policy × shard count × epoch quota, plus
//! the campaign seed and replication count.
//!
//! Cells are enumerated in one fixed nested order (rate, shape, policy,
//! shards, quota) and every replication seed is derived from the
//! campaign seed through the same forked-RNG chain, so a grid is a
//! *complete* description of a campaign: two runs of the same grid are
//! bit-identical regardless of worker-pool interleaving.

use crate::accel::{Platform, PlatformKind};
use crate::scheduler::exec_model::ExecModel;
use crate::scheduler::{ArrivalProcess, Priority, Task};
use crate::util::Rng;
use crate::workload::{TilingConfig, WorkloadClass};

use super::lbt::LbtConfig;
use super::quota::QuotaSpec;

/// Route policies every shipped grid sweeps (the `policy_by_name`
/// vocabulary).
pub const ALL_POLICIES: [&str; 3] = ["round-robin", "least-queue", "deadline-aware"];

/// One campaign's full parameter space.
#[derive(Clone, Debug)]
pub struct ExperimentGrid {
    pub class: WorkloadClass,
    pub platform: PlatformKind,
    /// Trace horizon per replication (s of modeled time).
    pub horizon: f64,
    /// Urgent deadline = arrival + factor × isolated exec estimate.
    pub deadline_factor: f64,
    /// Concurrent background streams per replication.
    pub background_tasks: usize,
    /// λ axis (urgent arrivals/s).
    pub rates: Vec<f64>,
    /// Arrival-shape axis.
    pub shapes: Vec<ArrivalProcess>,
    /// Route-policy axis (`policy_by_name` names).
    pub policies: Vec<String>,
    /// Shard-count axis.
    pub shard_counts: Vec<usize>,
    /// Epoch-quota axis.
    pub quotas: Vec<QuotaSpec>,
    /// Seeded replications per cell.
    pub replications: usize,
    /// Root seed every replication seed derives from.
    pub campaign_seed: u64,
    /// LBT search budget (shared by every per-policy bisection).
    pub lbt: LbtConfig,
}

/// One point of the grid, fully self-describing (carries the shared
/// trace parameters so the evaluator needs nothing but the cell).
#[derive(Clone, Debug)]
pub struct CellConfig {
    /// Position in grid enumeration order; namespaces the cell's
    /// replication seeds.
    pub index: usize,
    pub rate: f64,
    pub process: ArrivalProcess,
    pub policy: String,
    pub shards: usize,
    pub quota: QuotaSpec,
    pub class: WorkloadClass,
    pub platform: PlatformKind,
    pub horizon: f64,
    pub deadline_factor: f64,
    pub background_tasks: usize,
}

impl CellConfig {
    /// Stable human-readable cell id used in reports and summaries.
    pub fn id(&self) -> String {
        format!(
            "r{:.1}/{}/{}/s{}/{}",
            self.rate,
            self.process.name(),
            self.policy,
            self.shards,
            self.quota.name()
        )
    }
}

impl ExperimentGrid {
    /// Enumerate all cells in the canonical nested order.
    pub fn cells(&self) -> Vec<CellConfig> {
        let mut out = Vec::new();
        let mut index = 0;
        for &rate in &self.rates {
            for &process in &self.shapes {
                for policy in &self.policies {
                    for &shards in &self.shard_counts {
                        for &quota in &self.quotas {
                            out.push(CellConfig {
                                index,
                                rate,
                                process,
                                policy: policy.clone(),
                                shards,
                                quota,
                                class: self.class,
                                platform: self.platform,
                                horizon: self.horizon,
                                deadline_factor: self.deadline_factor,
                                background_tasks: self.background_tasks,
                            });
                            index += 1;
                        }
                    }
                }
            }
        }
        out
    }

    /// Tiny CI-speed grid: two calibrated load levels (comfortable vs
    /// overloaded) × two shapes × all policies × {no quota, short static
    /// quota, adaptive}, 2 replications.  Small enough for `--smoke` and
    /// the test suite, rich enough that the quota tournament has both a
    /// regime where slicing hurts and one where it saves the SLO.
    pub fn smoke(campaign_seed: u64) -> Self {
        let class = WorkloadClass::Simple;
        let platform = PlatformKind::Edge;
        let shards = 2;
        let r_low = rate_for_load(class, platform, shards, 0.25);
        let r_high = rate_for_load(class, platform, shards, 1.6);
        Self {
            class,
            platform,
            horizon: 40.0 / r_low,
            deadline_factor: 3.0,
            background_tasks: 2,
            rates: vec![r_low, r_high],
            shapes: vec![ArrivalProcess::Poisson, ArrivalProcess::bursty_default()],
            policies: ALL_POLICIES.iter().map(|p| p.to_string()).collect(),
            shard_counts: vec![shards],
            quotas: vec![
                QuotaSpec::Static(None),
                QuotaSpec::Static(Some(8)),
                QuotaSpec::Adaptive {
                    low_rate: r_low * 2.5,
                    high_rate: r_high * 0.6,
                    min_quota: 8,
                    max_quota: 32,
                },
            ],
            replications: 2,
            campaign_seed,
            lbt: LbtConfig { hi0: r_high, ..LbtConfig::smoke() },
        }
    }

    /// The full campaign grid: three load levels × three shapes × all
    /// policies × {2, 4} shards × four quotas, 5 replications.
    pub fn standard(campaign_seed: u64) -> Self {
        let class = WorkloadClass::Simple;
        let platform = PlatformKind::Edge;
        let r1 = rate_for_load(class, platform, 2, 0.5);
        let r2 = rate_for_load(class, platform, 2, 1.0);
        let r3 = rate_for_load(class, platform, 2, 1.6);
        Self {
            class,
            platform,
            horizon: 120.0 / r1,
            deadline_factor: 3.0,
            background_tasks: 2,
            rates: vec![r1, r2, r3],
            shapes: vec![
                ArrivalProcess::Poisson,
                ArrivalProcess::bursty_default(),
                ArrivalProcess::diurnal_default(),
            ],
            policies: ALL_POLICIES.iter().map(|p| p.to_string()).collect(),
            shard_counts: vec![2, 4],
            quotas: vec![
                QuotaSpec::Static(None),
                QuotaSpec::Static(Some(8)),
                QuotaSpec::Static(Some(16)),
                QuotaSpec::Adaptive {
                    low_rate: r1 * 1.25,
                    high_rate: r3 * 0.6,
                    min_quota: 8,
                    max_quota: 32,
                },
            ],
            replications: 5,
            campaign_seed,
            lbt: LbtConfig { hi0: r3, ..LbtConfig::default() },
        }
    }
}

/// Replication-seed derivation: campaign seed → per-cell stream → per-
/// replication stream.  Pure function of its arguments, so workers can
/// compute seeds independently in any order and two runs of the same
/// grid use identical randomness everywhere.
pub fn replication_seed(campaign_seed: u64, cell_index: usize, replication: usize) -> u64 {
    let mut root = Rng::new(campaign_seed);
    let mut cell = root.fork(cell_index as u64);
    cell.fork(replication as u64).next_u64()
}

/// Seed namespace offset for LBT probe evaluations, disjoint from any
/// realistic grid's cell indices.
pub const LBT_SEED_SPACE: usize = 1 << 32;

/// λ that offers `load` erlangs of urgent work per shard: the mean
/// isolated service time of the class's members (at the trace's default
/// batch of 16) inverted and scaled by shard count.  Grids calibrated
/// through this hit the same utilization regimes on every platform
/// model, rather than hard-coding rates that saturate one platform and
/// idle another.
pub fn rate_for_load(
    class: WorkloadClass,
    platform: PlatformKind,
    shards: usize,
    load: f64,
) -> f64 {
    let p = Platform::get(platform);
    let exec = ExecModel::new(p);
    let models = class.models();
    let mut total = 0.0;
    for (i, model) in models.iter().enumerate() {
        let task =
            Task::new(i, *model, Priority::Urgent, 0.0, TilingConfig::default()).with_batch(16);
        let claim = task.tiles.len().clamp(1, p.engines);
        total += exec.tss(&task, claim).seconds;
    }
    let mean_service = (total / models.len() as f64).max(1e-9);
    load * shards.max(1) as f64 / mean_service
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cells_enumerate_in_stable_order_with_dense_indices() {
        let grid = ExperimentGrid::smoke(7);
        let cells = grid.cells();
        let expected = grid.rates.len()
            * grid.shapes.len()
            * grid.policies.len()
            * grid.shard_counts.len()
            * grid.quotas.len();
        assert_eq!(cells.len(), expected);
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
        // quota is the innermost axis: the first cells differ only by quota
        assert_eq!(cells[0].rate.to_bits(), cells[1].rate.to_bits());
        assert_eq!(cells[0].policy, cells[1].policy);
        assert_ne!(cells[0].quota, cells[1].quota);
    }

    #[test]
    fn replication_seeds_are_deterministic_and_distinct() {
        assert_eq!(replication_seed(42, 3, 1), replication_seed(42, 3, 1));
        let mut seeds = vec![];
        for cell in 0..8 {
            for rep in 0..4 {
                seeds.push(replication_seed(42, cell, rep));
            }
        }
        seeds.sort_unstable();
        let len_before = seeds.len();
        seeds.dedup();
        assert_eq!(seeds.len(), len_before, "seed collision across cells/reps");
        assert_ne!(replication_seed(42, 0, 0), replication_seed(43, 0, 0));
    }

    #[test]
    fn rate_for_load_scales_with_shards_and_rho() {
        let base = rate_for_load(WorkloadClass::Simple, PlatformKind::Edge, 2, 0.5);
        assert!(base > 0.0 && base.is_finite());
        let doubled = rate_for_load(WorkloadClass::Simple, PlatformKind::Edge, 4, 0.5);
        assert!((doubled / base - 2.0).abs() < 1e-9);
        let hotter = rate_for_load(WorkloadClass::Simple, PlatformKind::Edge, 2, 1.0);
        assert!((hotter / base - 2.0).abs() < 1e-9);
    }
}
