//! LBT (load-bearing throughput) search: the maximum sustainable
//! arrival rate per route policy at a configurable SLO-miss threshold —
//! the experiment-harness analogue of the paper's Fig. 7 curve.
//!
//! Unlike `scheduler::metrics::lbt_sweep` (which probes the single-node
//! simulator), this search drives the deterministic modeled cluster and
//! carries an explicit, *accounted* iteration budget: every probe is
//! counted and the total is bounded by `doublings + bisections + 1`,
//! which `tests/experiment.rs` asserts on a synthetic monotone curve.

use crate::Result;

use super::grid::{replication_seed, CellConfig, ExperimentGrid, LBT_SEED_SPACE};
use super::model::evaluate_cell;
use super::quota::QuotaSpec;

/// Search budget and target for one LBT bisection.
#[derive(Clone, Copy, Debug)]
pub struct LbtConfig {
    /// SLO-miss rate the sustained load may not exceed.
    pub target_miss: f64,
    /// Initial upper probe rate (arrivals/s); doubled while sustainable.
    pub hi0: f64,
    /// Maximum bracket doublings before the search gives up growing.
    pub max_doublings: u32,
    /// Bisection refinements once the bracket is established.
    pub bisections: u32,
}

impl Default for LbtConfig {
    fn default() -> Self {
        Self { target_miss: 0.1, hi0: 100.0, max_doublings: 4, bisections: 10 }
    }
}

impl LbtConfig {
    /// Reduced-budget search for `--smoke` and tests.
    pub fn smoke() -> Self {
        Self { bisections: 5, ..Self::default() }
    }

    /// The hard probe-count ceiling this budget implies.
    pub fn probe_budget(&self) -> usize {
        (self.max_doublings + self.bisections + 1) as usize
    }
}

/// Outcome of one bounded search.
#[derive(Clone, Debug)]
pub struct LbtOutcome {
    /// Highest rate confirmed sustainable (miss ≤ target).  0.0 when
    /// even the first probe missed its SLO target.
    pub rate: f64,
    /// Probes actually spent (≤ `LbtConfig::probe_budget()`).
    pub probes: usize,
    /// Whether the search hit the doubling cap while still sustainable
    /// (the true LBT lies above `rate`).
    pub saturated_budget: bool,
}

/// One policy's point on the LBT curve.
#[derive(Clone, Debug)]
pub struct LbtPoint {
    pub policy: String,
    pub outcome: LbtOutcome,
    pub target_miss: f64,
}

/// Bounded bracket-then-bisect search for the largest `x` with
/// `probe(x) <= target`, assuming `probe` is (noisily) monotone
/// non-decreasing.  Spends at most `cfg.probe_budget()` probe calls.
pub fn bisect_max_rate(mut probe: impl FnMut(f64) -> f64, cfg: &LbtConfig) -> LbtOutcome {
    let mut probes = 0usize;
    let mut lo = 0.0_f64; // highest rate confirmed sustainable
    let mut hi = cfg.hi0.max(1e-9);

    // grow the bracket while the upper probe is still sustainable
    let mut bracketed = false;
    for _ in 0..=cfg.max_doublings {
        probes += 1;
        if probe(hi) <= cfg.target_miss {
            lo = hi;
            hi *= 2.0;
        } else {
            bracketed = true;
            break;
        }
    }
    if !bracketed {
        // sustainable all the way to the doubling cap: report the last
        // confirmed rate and flag that the budget, not the system,
        // stopped the search
        return LbtOutcome { rate: lo, probes, saturated_budget: true };
    }

    for _ in 0..cfg.bisections {
        let mid = 0.5 * (lo + hi);
        probes += 1;
        if probe(mid) <= cfg.target_miss {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    LbtOutcome { rate: lo, probes, saturated_budget: false }
}

/// The per-policy LBT curve for a grid: Poisson arrivals (the paper's
/// LBT definition), the grid's first shard count, and its adaptive
/// quota if one is present (else the first quota), with each probe
/// averaging the SLO-miss rate over the grid's replication count.
pub fn lbt_curve(grid: &ExperimentGrid) -> Result<Vec<LbtPoint>> {
    let shards = grid.shard_counts.first().copied().unwrap_or(2);
    let quota = grid
        .quotas
        .iter()
        .find(|q| matches!(q, QuotaSpec::Adaptive { .. }))
        .or_else(|| grid.quotas.first())
        .copied()
        .unwrap_or(QuotaSpec::Static(None));
    let reps = grid.replications.max(1);

    let mut curve = Vec::new();
    for (pi, policy) in grid.policies.iter().enumerate() {
        let mut error: Option<String> = None;
        let outcome = bisect_max_rate(
            |rate| {
                let cell = CellConfig {
                    index: LBT_SEED_SPACE + pi,
                    rate,
                    process: crate::scheduler::ArrivalProcess::Poisson,
                    policy: policy.clone(),
                    shards,
                    quota,
                    class: grid.class,
                    platform: grid.platform,
                    horizon: grid.horizon,
                    deadline_factor: grid.deadline_factor,
                    background_tasks: grid.background_tasks,
                };
                let mut miss_sum = 0.0;
                for rep in 0..reps {
                    let seed = replication_seed(grid.campaign_seed, cell.index, rep);
                    match evaluate_cell(&cell, seed) {
                        Ok(run) => miss_sum += run.slo_miss_rate(),
                        Err(e) => {
                            error.get_or_insert_with(|| e.to_string());
                            // treat a failed probe as unsustainable so the
                            // search still terminates within budget
                            miss_sum += 1.0;
                        }
                    }
                }
                miss_sum / reps as f64
            },
            &grid.lbt,
        );
        if let Some(e) = error {
            anyhow::bail!("LBT probe failed for policy {policy}: {e}");
        }
        curve.push(LbtPoint { policy: policy.clone(), outcome, target_miss: grid.lbt.target_miss });
    }
    Ok(curve)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bisection_converges_on_a_monotone_curve_within_budget() {
        let cfg = LbtConfig { target_miss: 0.1, hi0: 10.0, max_doublings: 6, bisections: 20 };
        // miss rate ramps through the target at rate 130
        let mut calls = 0usize;
        let out = bisect_max_rate(
            |r| {
                calls += 1;
                (r / 1300.0).min(1.0)
            },
            &cfg,
        );
        assert_eq!(calls, out.probes);
        assert!(out.probes <= cfg.probe_budget(), "{} probes > budget", out.probes);
        assert!(!out.saturated_budget);
        assert!((out.rate - 130.0).abs() < 1.0, "LBT {} should be ~130", out.rate);
    }

    #[test]
    fn always_sustainable_curve_saturates_the_doubling_budget() {
        let cfg = LbtConfig { target_miss: 0.5, hi0: 1.0, max_doublings: 3, bisections: 8 };
        let out = bisect_max_rate(|_| 0.0, &cfg);
        assert!(out.saturated_budget);
        assert_eq!(out.probes, cfg.max_doublings as usize + 1);
        // last confirmed rate: hi0 · 2^max_doublings
        assert!((out.rate - 8.0).abs() < 1e-12);
    }

    #[test]
    fn never_sustainable_curve_reports_zero() {
        let cfg = LbtConfig::smoke();
        let out = bisect_max_rate(|_| 1.0, &cfg);
        assert_eq!(out.rate, 0.0);
        assert!(out.probes <= cfg.probe_budget());
    }
}
